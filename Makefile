# Local developer entry points. `make ci` reproduces the full CI matrix
# (.github/workflows/ci.yml) in one command — the documented pre-push
# check. Individual targets mirror the CI jobs one to one.

CARGO ?= cargo

.PHONY: ci build test fmt clippy bench-smoke sweep-determinism clean

ci: build test fmt clippy bench-smoke sweep-determinism
	@echo "CI matrix green"

build:
	$(CARGO) build --release --workspace

test:
	$(CARGO) test -q --workspace

fmt:
	$(CARGO) fmt --all -- --check

# Advisory, like CI's continue-on-error: report findings, don't fail.
clippy:
	-$(CARGO) clippy --workspace --all-targets -- -D warnings

bench-smoke:
	for b in collectives table_layer_extraction sim_end_to_end fig6_translation_time; do \
		MODTRANS_BENCH_SAMPLES=2 $(CARGO) bench --bench $$b || exit 1; \
	done

sweep-determinism: build
	./target/release/modtrans sweep --threads 1 -o sweep_t1.json
	./target/release/modtrans sweep --threads 8 -o sweep_t8.json
	diff sweep_t1.json sweep_t8.json
	rm -f sweep_t1.json sweep_t8.json

clean:
	$(CARGO) clean
	rm -f sweep_t1.json sweep_t8.json
