# Local developer entry points. `make ci` reproduces the full CI matrix
# (.github/workflows/ci.yml) in one command — the documented pre-push
# check. Individual targets mirror the CI jobs one to one.

CARGO ?= cargo

BENCHES := collectives table_layer_extraction sim_end_to_end fig6_translation_time sweep_throughput event_queue

.PHONY: ci build test fmt clippy docs lint bench-smoke sweep-determinism \
	fleet-smoke perf-gate-test check-ci-sync clean

ci: build test fmt clippy docs lint bench-smoke sweep-determinism \
	fleet-smoke perf-gate-test check-ci-sync
	@echo "CI matrix green"

build:
	$(CARGO) build --release --workspace

test:
	$(CARGO) test -q --workspace

fmt:
	$(CARGO) fmt --all -- --check

# Gating, like CI: clippy findings fail the build.
clippy:
	$(CARGO) clippy --workspace --all-targets -- -D warnings

# Gating, like CI: rustdoc warnings fail the build.
docs:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --workspace

# Gating, like CI: the modtrans-lint static pass (rules in
# analysis/rules.toml) must report zero findings over rust/src. This
# replaces the retired grep-based hot-path-alloc-guard — its patterns
# live on as the `no-string-alloc` and `no-label-string` rules, plus
# the finer-grained per-function, panic-path, and determinism rules.
lint: build
	./target/release/modtrans-lint

# Writes BENCH_<name>.json per bench into bench-out/ (perf trajectory).
# Depends on build: the sweep_throughput fleet series re-invokes the CLI
# binary.
bench-smoke: build
	mkdir -p bench-out
	for b in $(BENCHES); do \
		MODTRANS_BENCH_SAMPLES=2 MODTRANS_BENCH_OUT=bench-out $(CARGO) bench --bench $$b || exit 1; \
	done

sweep-determinism: build
	./target/release/modtrans sweep --threads 1 -o sweep_t1.json
	./target/release/modtrans sweep --threads 8 -o sweep_t8.json
	diff sweep_t1.json sweep_t8.json
	./target/release/modtrans sweep --threads 1 --hbm-gib 1 --skip-infeasible -o sweep_p1.json
	./target/release/modtrans sweep --threads 8 --hbm-gib 1 --skip-infeasible -o sweep_p8.json
	diff sweep_p1.json sweep_p8.json
	rm -rf ircache
	./target/release/modtrans sweep --threads 4 --cache-dir ircache -o cache_cold.json
	./target/release/modtrans sweep --threads 4 --cache-dir ircache -o cache_warm.json
	python3 -c 'import json; c=json.load(open("cache_cold.json")); w=json.load(open("cache_warm.json")); assert w["translations"]==0 and w["cache_loads"]==w["models"], "warm run not load-only"; assert w["ranked"]==c["ranked"], "cache changed the ranking"'
	./target/release/modtrans check
	./target/release/modtrans translate zoo:mlp --format et-json -o check_trace.et.json
	./target/release/modtrans check check_trace.et.json
	./target/release/modtrans check --cache-dir ircache --quiet
	rm -f check_trace.et.json
	./target/release/modtrans sweep --threads 2 --shard 1/2 -o shard1.json
	./target/release/modtrans sweep --threads 2 --shard 2/2 -o shard2.json
	./target/release/modtrans sweep-merge shard1.json shard2.json -o merged.json
	python3 -c 'import json; a=json.load(open("merged.json")); b=json.load(open("sweep_t1.json")); assert a["ranked"]==b["ranked"], "shard merge diverged"'
	./target/release/modtrans sweep --threads 1 --top 5 -o sweep_top_t1.json
	./target/release/modtrans sweep --threads 8 --top 5 -o sweep_top_t8.json
	diff sweep_top_t1.json sweep_top_t8.json
	python3 scripts/check_prune.py sweep_t1.json sweep_top_t1.json 5
	./target/release/modtrans sweep mlp --topologies "ring,ring:2x300g@700ns/rail:2x50g@2us/switch:4x1g@5us+direct" --threads 1 -o sweep_nd1.json
	./target/release/modtrans sweep mlp --topologies "ring,ring:2x300g@700ns/rail:2x50g@2us/switch:4x1g@5us+direct" --threads 8 -o sweep_nd8.json
	diff sweep_nd1.json sweep_nd8.json
	./target/release/modtrans check --network rust/configs/ndim_codesign.json --quiet
	rm -f sweep_t1.json sweep_t8.json sweep_p1.json sweep_p8.json shard1.json shard2.json merged.json cache_cold.json cache_warm.json
	rm -f sweep_top_t1.json sweep_top_t8.json sweep_nd1.json sweep_nd8.json
	rm -rf ircache

# The fleet acceptance check, mirroring CI's fleet-smoke job: a cold
# 4-process fleet (shared cache pre-warmed by one in-process translation
# pass) and a warm re-run must both rank byte-identically to the
# monolithic sweep with every worker reporting 0 translations; an
# interrupted journaled fleet must resume with zero re-simulations; and
# the work-stealing scheduler must keep every worker busy on a skewed
# grid.
fleet-smoke: build
	rm -rf fleet-cache fleet-work fleet-work-warm fleet-journal fleet-work-crash fleet-work-resume fleet-work-skew
	./target/release/modtrans sweep --threads 2 -o fleet_mono.json
	./target/release/modtrans sweep fleet --procs 4 --threads 2 \
		--cache-dir fleet-cache --work-dir fleet-work \
		--status-out fleet_status.json --json-out fleet_merged.json
	python3 scripts/check_fleet.py fleet_mono.json fleet_merged.json fleet_status.json
	./target/release/modtrans sweep fleet --procs 4 --threads 2 \
		--cache-dir fleet-cache --work-dir fleet-work-warm \
		--status-out warm_status.json --json-out warm_merged.json
	python3 scripts/check_fleet.py fleet_mono.json warm_merged.json warm_status.json --warm
	if ./target/release/modtrans sweep fleet --procs 1 --threads 2 --lease 2 --retries 0 \
		--cache-dir fleet-cache --work-dir fleet-work-crash \
		--journal fleet-journal --failpoint 1@2; then \
		echo "failpoint fleet run unexpectedly succeeded"; exit 1; fi
	./target/release/modtrans sweep fleet --procs 4 --threads 2 \
		--cache-dir fleet-cache --work-dir fleet-work-resume \
		--journal fleet-journal --resume \
		--status-out resume_status.json --json-out resume_merged.json
	python3 scripts/check_fleet.py fleet_mono.json resume_merged.json resume_status.json --warm --resume
	./target/release/modtrans sweep vgg16,mlp --threads 2 --cache-dir fleet-cache -o skew_mono.json
	./target/release/modtrans sweep fleet vgg16,mlp --procs 2 --threads 2 \
		--cache-dir fleet-cache --work-dir fleet-work-skew \
		--status-out skew_status.json --json-out skew_merged.json
	python3 scripts/check_fleet.py skew_mono.json skew_merged.json skew_status.json --warm --skew
	rm -rf fleet-cache fleet-work fleet-work-warm fleet-journal fleet-work-crash fleet-work-resume fleet-work-skew
	rm -f fleet_mono.json fleet_merged.json fleet_status.json warm_merged.json warm_status.json
	rm -f resume_merged.json resume_status.json skew_mono.json skew_merged.json skew_status.json

# Unit tests for the perf-trajectory gate (scripts/perf_diff.py --gate).
perf-gate-test:
	python3 scripts/test_perf_diff.py

# CI/Makefile drift check: every ci.yml job must run its `make` target,
# so `make ci` keeps reproducing the full CI matrix locally.
check-ci-sync:
	python3 scripts/check_ci_sync.py

clean:
	$(CARGO) clean
	rm -f sweep_t1.json sweep_t8.json sweep_p1.json sweep_p8.json shard1.json shard2.json merged.json cache_cold.json cache_warm.json
	rm -f sweep_top_t1.json sweep_top_t8.json sweep_nd1.json sweep_nd8.json
	rm -f fleet_mono.json fleet_merged.json fleet_status.json warm_merged.json warm_status.json
	rm -f resume_merged.json resume_status.json skew_mono.json skew_merged.json skew_status.json
	rm -f check_trace.et.json
	rm -rf bench-out ircache fleet-cache fleet-work fleet-work-warm fleet-journal fleet-work-crash fleet-work-resume fleet-work-skew
