//! Pipeline-parallel training study (GPipe-style, paper §2.1's
//! Gpipe/PipeDream discussion): stage-count and microbatch sweeps on the
//! GPT-2-small transformer, comparing the simulated bubble fraction with
//! the analytic GPipe formula (S−1)/(M+S−1).
//!
//! ```sh
//! cargo run --release --example pipeline_training
//! ```

use modtrans::compute::SystolicCompute;
use modtrans::sim::{simulate, Network, PipelineSchedule, SimConfig, TopologyKind};
use modtrans::translator::{extract, to_workload, TranslateOpts};
use modtrans::util::human_time;
use modtrans::util::table::Table;
use modtrans::workload::Parallelism;
use modtrans::zoo::{self, WeightFill, ZooOpts};

fn main() -> modtrans::Result<()> {
    let model = zoo::get("gpt2-small", ZooOpts { weights: WeightFill::Empty })?;
    let batch = 8i64;
    let summary = extract(&model, batch)?;
    // Boundary activation: one transformer residual stream [B, T, d].
    let boundary = (batch * 1024 * 768 * 4) as u64;
    let opts =
        TranslateOpts { parallelism: Parallelism::Pipeline, npus: 8, mp_group: 4, batch, zero: modtrans::translator::ZeroStage::None };
    let w = to_workload(&summary, opts, &SystolicCompute::new(batch))?;
    println!(
        "gpt2-small: {} weight layers, boundary activation {} per microbatch-full-batch\n",
        w.layers.len(),
        modtrans::util::human_bytes(boundary)
    );

    let run = |stages: usize, micro: usize| -> modtrans::Result<(u64, f64)> {
        let cfg = SimConfig {
            network: Network::single(TopologyKind::Ring, stages, 300.0, 700.0),
            iterations: 2,
            stages,
            microbatches: micro,
            boundary_bytes: boundary,
            ..Default::default()
        };
        let r = simulate(&w, &cfg)?;
        Ok((r.iteration_ns, r.compute_utilization))
    };

    println!("== microbatch sweep at 4 stages ==");
    let mut t = Table::new(vec!["Microbatches", "Iteration", "Utilization", "GPipe bubble (S-1)/(M+S-1)"]);
    for m in [1usize, 2, 4, 8, 16, 32] {
        let (iter_ns, util) = run(4, m)?;
        let bubble = 3.0 / (m as f64 + 3.0);
        t.row(vec![
            m.to_string(),
            human_time(iter_ns as f64 * 1e-9),
            format!("{:.1}%", util * 100.0),
            format!("{:.1}%", bubble * 100.0),
        ]);
    }
    println!("{t}");

    println!("== stage sweep at 16 microbatches ==");
    let mut t2 = Table::new(vec!["Stages", "Iteration", "Utilization"]);
    for s in [2usize, 4, 8, 16] {
        let (iter_ns, util) = run(s, 16)?;
        t2.row(vec![
            s.to_string(),
            human_time(iter_ns as f64 * 1e-9),
            format!("{:.1}%", util * 100.0),
        ]);
    }
    println!("{t2}");

    // GPipe vs 1F1B (PipeDream-flush). Both are flush schedules with the
    // SAME bubble — the simulator confirms the iteration times tie — but
    // 1F1B caps in-flight microbatches at the stage depth, so its
    // activation memory stays flat while GPipe's grows with M.
    println!("== schedule ablation: GPipe vs 1F1B (4 stages) ==");
    use modtrans::translator::{memory_per_npu, MemoryOpts};
    let mut t3 = Table::new(vec![
        "Microbatches",
        "GPipe iter",
        "1F1B iter",
        "GPipe act mem/NPU",
        "1F1B act mem/NPU",
    ]);
    for m in [4usize, 8, 16, 32] {
        let mut times = Vec::new();
        for sched in [PipelineSchedule::GPipe, PipelineSchedule::OneFOneB] {
            let cfg = SimConfig {
                network: Network::single(TopologyKind::Ring, 4, 300.0, 700.0),
                iterations: 2,
                stages: 4,
                microbatches: m,
                boundary_bytes: boundary,
                schedule: sched,
                ..Default::default()
            };
            times.push(simulate(&w, &cfg)?.iteration_ns);
        }
        let mem_opts = |ofob: bool| MemoryOpts { microbatches: m, one_f_one_b: ofob, ..Default::default() };
        let gm = memory_per_npu(&summary, opts, mem_opts(false));
        let om = memory_per_npu(&summary, opts, mem_opts(true));
        t3.row(vec![
            m.to_string(),
            human_time(times[0] as f64 * 1e-9),
            human_time(times[1] as f64 * 1e-9),
            modtrans::util::human_bytes(gm.activations),
            modtrans::util::human_bytes(om.activations),
        ]);
    }
    println!("{t3}");
    Ok(())
}
