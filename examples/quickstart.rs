//! Quickstart: the 60-second ModTrans tour.
//!
//! Builds ResNet-50 from the zoo, serializes it to real ONNX bytes,
//! translates it (the paper's pipeline: deserialize → extract → emit),
//! prints the first table rows, and runs the translated workload through
//! the distributed-training simulator.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use modtrans::compute::SystolicCompute;
use modtrans::onnx::encode_model;
use modtrans::sim::{simulate, Network, SimConfig};
use modtrans::translator::{extract_from_bytes, to_workload, TranslateOpts};
use modtrans::util::table::Table;
use modtrans::util::{human_bytes, human_time};
use modtrans::workload::Parallelism;
use modtrans::zoo::{self, WeightFill, ZooOpts};
use std::time::Instant;

fn main() -> modtrans::Result<()> {
    // 1. "Get classic models from the model zoo by only giving the name."
    let model = zoo::get("resnet50", ZooOpts { weights: WeightFill::Zeros })?;
    let bytes = encode_model(&model);
    println!(
        "resnet50.onnx: {} on the wire, {} parameters\n",
        human_bytes(bytes.len() as u64),
        model.num_parameters()
    );

    // 2. Translate: ONNX bytes → layer table + ASTRA-sim workload.
    let t0 = Instant::now();
    let summary = extract_from_bytes(&bytes, 32)?;
    let opts = TranslateOpts {
        parallelism: Parallelism::Data,
        npus: 16,
        mp_group: 4,
        batch: 32, zero: modtrans::translator::ZeroStage::None };
    let workload = to_workload(&summary, opts, &SystolicCompute::new(32))?;
    let translation = t0.elapsed();

    let mut table = Table::new(vec!["Layer Name", "Variables", "Data Type", "Model Size"]);
    for l in summary.layers.iter().take(5) {
        table.row(vec![
            l.name.clone(),
            l.variables.to_string(),
            l.dtype.to_string(),
            l.weight_bytes.to_string(),
        ]);
    }
    println!("{table}... ({} layers total)\n", summary.layers.len());
    println!(
        "translation took {} (paper budget: < 1 s)\n",
        human_time(translation.as_secs_f64())
    );

    // 3. Save the workload file (the simulator input of paper Fig. 3).
    let path = std::env::temp_dir().join("resnet50_dp.txt");
    std::fs::write(&path, workload.emit())?;
    println!("wrote {} ({} layers, DATA parallel)", path.display(), workload.layers.len());

    // 4. Simulate 2 training iterations on an 8x4 two-tier cluster.
    let cfg = SimConfig { network: Network::two_tier(8, 4), iterations: 2, ..Default::default() };
    let report = simulate(&workload, &cfg)?;
    println!(
        "\nsimulated ResNet-50 DP training on 32 NPUs (8-NPU nodes x 4):\n  \
         iteration: {}   compute util: {:.1}%   exposed comm: {}",
        human_time(report.iteration_ns as f64 * 1e-9),
        report.compute_utilization * 100.0,
        human_time(report.exposed_ns as f64 * 1e-9),
    );
    Ok(())
}
