//! Shard-fleet demo: one call launches N sweep shard *processes*, warms
//! them from a shared IR cache (a single cold translation pass), and
//! merges their reports — and the merged ranking is byte-identical to
//! the single-process sweep of the same grid.
//!
//! The fleet re-invokes the `modtrans` CLI binary, so build it first:
//!
//! ```sh
//! cargo build --release
//! cargo run --release --example fleet_sweep
//! ```

use modtrans::sweep::fleet::locate_binary;
use modtrans::sweep::{run_fleet, run_sweep, FleetOpts, SweepConfig, SweepGrid};
use modtrans::util::human_time;
use std::time::Instant;

fn main() -> modtrans::Result<()> {
    let Some(binary) = locate_binary() else {
        eprintln!(
            "fleet_sweep: modtrans binary not found next to this example — run \
             `cargo build --release` first (or point MODTRANS_BIN at it)"
        );
        return Ok(());
    };

    let grid = SweepGrid::default();
    let cfg = SweepConfig { threads: 2, ..Default::default() };
    let procs = 4;
    let scenarios = grid.expand().len();
    println!(
        "fleeting {scenarios} scenarios across {procs} shard processes \
         ({} threads each) via {}",
        cfg.threads,
        binary.display(),
    );

    let opts = FleetOpts { procs, binary: Some(binary), ..Default::default() };
    let t0 = Instant::now();
    let fleet = run_fleet(&grid, &cfg, &opts)?;
    let wall = t0.elapsed();
    println!(
        "done in {} — pre-warm ran {} translation(s); the {} shards ran {} \
         (the shared cache makes every shard load-only)\n",
        human_time(wall.as_secs_f64()),
        fleet.prewarm_translations,
        fleet.shards.len(),
        fleet.shard_translations(),
    );
    for s in &fleet.shards {
        println!(
            "  shard {}/{}: {} scenario(s), {} attempt(s), {} cache load(s)",
            s.shard.0, s.shard.1, s.scenarios, s.attempts, s.cache_loads,
        );
    }
    println!();
    print!("{}", fleet.merged.render_text());

    // The acceptance property: process orchestration must not change a
    // single byte of the ranking.
    let mono = run_sweep(&grid, &cfg)?;
    assert_eq!(
        fleet.merged.render_text(),
        mono.render_text(),
        "fleet ranking must be byte-identical to the single-process sweep"
    );
    println!("\nfleet ranking is byte-identical to the single-process sweep");
    Ok(())
}
