//! Scenario-sweep demo: the experiment-scale workflow the paper's cheap
//! translation unlocks — explore a (model × parallelism × topology ×
//! collective) design space in one command, with each model translated
//! exactly once and the simulations fanned out across a worker pool.
//!
//! Also demonstrates the determinism guarantee (the ranked JSON from a
//! 1-thread run is byte-identical to the multi-threaded run) and the
//! branch-and-bound `--top K` mode, whose pruned top-K is exactly the
//! exhaustive ranking's prefix.
//!
//! ```sh
//! cargo run --release --example sweep_grid
//! ```

use modtrans::sim::{NetworkSpec, TopologyKind};
use modtrans::sweep::{run_sweep, CommSchedule, SweepConfig, SweepGrid};
use modtrans::util::human_time;
use modtrans::workload::Parallelism;
use std::time::Instant;

fn main() -> modtrans::Result<()> {
    let grid = SweepGrid {
        models: vec!["mlp".into(), "resnet18".into()],
        parallelisms: vec![Parallelism::Data, Parallelism::Model],
        // Two bare legacy tokens next to a 2-dimension hierarchy with an
        // explicit per-dimension algorithm — one network axis covers both.
        networks: vec![
            NetworkSpec::from_kind(TopologyKind::Ring),
            NetworkSpec::from_kind(TopologyKind::Switch),
            NetworkSpec::parse("ring:4x300g@700ns/switch:4x25g@5us+direct")?,
        ],
        collectives: vec![CommSchedule::Direct, CommSchedule::Pipelined],
    };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let cfg = SweepConfig { threads, batch: 16, ..Default::default() };

    let scenarios = grid.expand().len();
    println!(
        "sweeping {scenarios} scenarios ({} models x {} parallelisms x {} networks x {} collectives) on {threads} threads",
        grid.models.len(),
        grid.parallelisms.len(),
        grid.networks.len(),
        grid.collectives.len(),
    );

    let t0 = Instant::now();
    let report = run_sweep(&grid, &cfg)?;
    let wall = t0.elapsed();
    println!(
        "done in {} — {} translations for {} scenarios (cache reuse: {:.0}x)\n",
        human_time(wall.as_secs_f64()),
        report.translations,
        report.ranked.len(),
        report.ranked.len() as f64 / report.translations.max(1) as f64,
    );
    print!("{}", report.render_text());

    // Determinism: a single-threaded run must produce identical JSON.
    let serial = run_sweep(&grid, &SweepConfig { threads: 1, ..cfg })?;
    let a = report.to_json().to_json_pretty();
    let b = serial.to_json().to_json_pretty();
    assert_eq!(a, b, "ranked output must not depend on thread count");
    println!("\ndeterminism check: 1-thread and {threads}-thread runs agree byte-for-byte");

    // Branch-and-bound pruning: `--top K` skips simulating any scenario
    // whose analytic lower bound already exceeds the K-th best simulated
    // iteration — and still reports exactly the exhaustive top-K.
    let k = 3;
    let pruned = run_sweep(&grid, &SweepConfig { top_k: Some(k), ..cfg })?;
    let full_json = report.to_json();
    let exhaustive_prefix = full_json.get("ranked").and_then(|v| v.as_arr()).expect("ranked");
    let pruned_json = pruned.to_json();
    let pruned_ranked = pruned_json.get("ranked").and_then(|v| v.as_arr()).expect("ranked");
    assert_eq!(
        pruned_ranked,
        &exhaustive_prefix[..k],
        "pruned top-K must match the exhaustive prefix"
    );
    println!(
        "top-{k} pruning: {} of {} scenarios simulated, {} skipped by the analytic lower bound \
         ({} bounds evaluated) — ranking byte-identical to the exhaustive prefix",
        pruned.scenarios_simulated, scenarios, pruned.scenarios_pruned, pruned.bounds_evaluated,
    );
    Ok(())
}
