//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! 1. **L1+L2 via PJRT (runtime path)** — loads the AOT-compiled
//!    `mlp_train_step` artifact (JAX graph whose every GEMM is the Pallas
//!    kernel) and trains the MLP for 300 steps on a synthetic
//!    projection-labeled dataset, logging the loss curve from rust.
//! 2. **Calibration** — times the GEMM artifacts and derives measured
//!    per-layer compute costs.
//! 3. **L3 (coordinator path)** — translates ResNet-50 with the measured
//!    compute model and simulates distributed training, reporting the
//!    paper's headline metric (translation cost) alongside.
//!
//! Requires `make artifacts`.
//!
//! ```sh
//! cargo run --release --example end_to_end
//! ```

use modtrans::calibrate::{Calibration, MeasuredCompute};
use modtrans::onnx::encode_model;
use modtrans::runtime::Runtime;
use modtrans::sim::{simulate, Network, SimConfig};
use modtrans::translator::{extract_from_bytes, to_workload, TranslateOpts};
use modtrans::util::rng::Rng;
use modtrans::util::{human_bytes, human_time};
use modtrans::workload::Parallelism;
use modtrans::zoo::{self, WeightFill, ZooOpts};
use std::path::Path;
use std::time::Instant;

const D_IN: usize = 784;
const HIDDEN: usize = 256;
const D_OUT: usize = 10;
const BATCH: usize = 128;
const STEPS: usize = 300;

fn main() -> modtrans::Result<()> {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("mlp_train_step.hlo.txt").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(2);
    }

    // ---- Part 1: train the MLP from rust through PJRT ----
    let mut rt = Runtime::cpu()?;
    let n = rt.load_dir(artifacts)?;
    println!("loaded {n} AOT artifacts on {}", rt.platform());

    let mut rng = Rng::new(7);
    let mut normal = |n: usize, scale: f32| -> Vec<f32> {
        (0..n)
            .map(|_| {
                let u1 = rng.f64().max(1e-12);
                let u2 = rng.f64();
                ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32 * scale
            })
            .collect()
    };
    let mut w1 = normal(D_IN * HIDDEN, (2.0f32 / D_IN as f32).sqrt());
    let mut b1 = vec![0.0f32; HIDDEN];
    let mut w2 = normal(HIDDEN * D_OUT, (2.0f32 / HIDDEN as f32).sqrt());
    let mut b2 = vec![0.0f32; D_OUT];
    let proj = normal(D_IN * D_OUT, 1.0);

    println!("\ntraining 784-256-10 MLP for {STEPS} steps (batch {BATCH}) via PJRT:");
    let train_start = Instant::now();
    let mut first_loss = 0.0f32;
    let mut last_loss = 0.0f32;
    for step in 0..STEPS {
        let x = normal(BATCH * D_IN, 1.0);
        let mut y = vec![0.0f32; BATCH * D_OUT];
        for r in 0..BATCH {
            let mut best = (0usize, f32::MIN);
            for c in 0..D_OUT {
                let mut acc = 0.0f32;
                for k in 0..D_IN {
                    acc += x[r * D_IN + k] * proj[k * D_OUT + c];
                }
                if acc > best.1 {
                    best = (c, acc);
                }
            }
            y[r * D_OUT + best.0] = 1.0;
        }
        let s_w1 = [D_IN as i64, HIDDEN as i64];
        let s_b1 = [HIDDEN as i64];
        let s_w2 = [HIDDEN as i64, D_OUT as i64];
        let s_b2 = [D_OUT as i64];
        let s_x = [BATCH as i64, D_IN as i64];
        let s_y = [BATCH as i64, D_OUT as i64];
        let outs = rt.execute_f32_tuple(
            "mlp_train_step",
            &[
                (&w1, &s_w1),
                (&b1, &s_b1),
                (&w2, &s_w2),
                (&b2, &s_b2),
                (&x, &s_x),
                (&y, &s_y),
            ],
            5,
        )?;
        let mut it = outs.into_iter();
        w1 = it.next().unwrap();
        b1 = it.next().unwrap();
        w2 = it.next().unwrap();
        b2 = it.next().unwrap();
        let loss = it.next().unwrap()[0];
        if step == 0 {
            first_loss = loss;
        }
        last_loss = loss;
        if step % 30 == 0 || step == STEPS - 1 {
            println!("  step {step:4}  loss {loss:.4}");
        }
    }
    println!(
        "loss {first_loss:.4} -> {last_loss:.4} over {STEPS} steps in {}",
        human_time(train_start.elapsed().as_secs_f64())
    );
    assert!(last_loss < first_loss, "training must reduce the loss");

    // ---- Part 2: calibration ----
    println!("\ncalibrating GEMM artifacts (5 reps each):");
    let cal = Calibration::measure(&rt, 5)?;
    for (g, ns) in &cal.entries {
        println!(
            "  gemm {:>4}x{:<4}x{:<4} {:>12}",
            g.m,
            g.k,
            g.n,
            human_time(*ns as f64 * 1e-9)
        );
    }

    // ---- Part 3: translate + simulate with measured compute ----
    let model = zoo::get("resnet50", ZooOpts { weights: WeightFill::Zeros })?;
    let bytes = encode_model(&model);
    let t0 = Instant::now();
    let summary = extract_from_bytes(&bytes, 32)?;
    let mc = MeasuredCompute { cal, batch: 32 };
    let w = to_workload(
        &summary,
        TranslateOpts { parallelism: Parallelism::Data, npus: 32, mp_group: 4, batch: 32, zero: modtrans::translator::ZeroStage::None },
        &mc,
    )?;
    let translation = t0.elapsed();
    println!(
        "\ntranslated resnet50 ({} on the wire) with MEASURED compute in {}",
        human_bytes(bytes.len() as u64),
        human_time(translation.as_secs_f64())
    );
    assert!(translation.as_secs_f64() < 1.0, "paper headline: translation < 1 s");

    let cfg = SimConfig { network: Network::two_tier(8, 4), iterations: 2, ..Default::default() };
    let r = simulate(&w, &cfg)?;
    println!(
        "simulated DP training on 32 NPUs: iteration {}  compute util {:.1}%  events {}",
        human_time(r.iteration_ns as f64 * 1e-9),
        r.compute_utilization * 100.0,
        r.events
    );
    println!("\nend-to-end OK: Pallas kernel -> JAX graph -> HLO -> PJRT -> translator -> simulator");
    Ok(())
}
