//! Parallelism sweep: which strategy wins for which model?
//!
//! Reproduces the design-space exploration ASTRA-sim exists for (paper
//! §2.2): DATA / MODEL / HYBRID across batch sizes for a conv net (VGG16)
//! and a transformer (GPT-2 tiny), on the same 16-NPU ring. The expected
//! *shape*: data parallelism wins for CNNs at moderate batch; model/
//! hybrid strategies close the gap as parameter traffic outgrows
//! activation traffic.
//!
//! ```sh
//! cargo run --release --example parallelism_sweep
//! ```

use modtrans::compute::SystolicCompute;
use modtrans::sim::{simulate, Network, SimConfig, TopologyKind};
use modtrans::translator::{extract, to_workload, TranslateOpts};
use modtrans::util::human_time;
use modtrans::util::table::Table;
use modtrans::workload::Parallelism;
use modtrans::zoo::{self, WeightFill, ZooOpts};

fn main() -> modtrans::Result<()> {
    let strategies = [
        ("DATA", Parallelism::Data),
        ("MODEL", Parallelism::Model),
        ("HYBRID_DM", Parallelism::HybridDataModel),
    ];
    for model_name in ["vgg16", "gpt2-tiny"] {
        let model = zoo::get(model_name, ZooOpts { weights: WeightFill::Empty })?;
        println!("== {model_name} on 16 NPUs (ring, 100 GB/s, 500 ns) ==");
        let mut t = Table::new(vec!["Batch", "DATA", "MODEL", "HYBRID_DM", "Winner"]);
        for batch in [4i64, 16, 64, 256] {
            let summary = extract(&model, batch)?;
            let compute = SystolicCompute::new(batch);
            let mut times = Vec::new();
            for (_, par) in strategies {
                let opts = TranslateOpts { parallelism: par, npus: 16, mp_group: 4, batch, zero: modtrans::translator::ZeroStage::None };
                let w = to_workload(&summary, opts, &compute)?;
                let cfg = SimConfig {
                    network: Network::single(TopologyKind::Ring, 16, 100.0, 500.0),
                    iterations: 2,
                    ..Default::default()
                };
                times.push(simulate(&w, &cfg)?.iteration_ns);
            }
            let winner = strategies[times
                .iter()
                .enumerate()
                .min_by_key(|(_, t)| **t)
                .unwrap()
                .0]
                .0;
            t.row(vec![
                batch.to_string(),
                human_time(times[0] as f64 * 1e-9),
                human_time(times[1] as f64 * 1e-9),
                human_time(times[2] as f64 * 1e-9),
                winner.to_string(),
            ]);
        }
        println!("{t}");
    }
    Ok(())
}
