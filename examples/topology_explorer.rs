//! Topology explorer: the HW side of the SW/HW co-design space (paper
//! Fig. 1) — how interconnect choice changes collective cost and
//! end-to-end training time.
//!
//! Part 1 prints raw all-reduce completion times per topology and scale;
//! part 2 runs translated ResNet-50 DATA-parallel training on each;
//! part 3 shows the hierarchical-collective payoff of a two-tier fabric.
//!
//! ```sh
//! cargo run --release --example topology_explorer
//! ```

use modtrans::compute::SystolicCompute;
use modtrans::sim::{
    collective_ns, simulate, CollectiveAlgo, NetDim, Network, SimConfig, TopologyKind,
};
use modtrans::translator::{extract, to_workload, TranslateOpts};
use modtrans::util::human_time;
use modtrans::util::table::Table;
use modtrans::workload::{CommType, Parallelism};
use modtrans::zoo::{self, WeightFill, ZooOpts};

const KINDS: [TopologyKind; 6] = [
    TopologyKind::Ring,
    TopologyKind::FullyConnected,
    TopologyKind::Switch,
    TopologyKind::Torus2D,
    TopologyKind::RailOptimized,
    TopologyKind::Dragonfly,
];

fn main() -> modtrans::Result<()> {
    // Part 1: collective microcosts (100 MB all-reduce) under each
    // topology's default algorithm.
    println!("== all-reduce of 100 MB, per topology (100 GB/s links, 500 ns hops) ==");
    let mut t = Table::new(vec![
        "NPUs", "ring", "fully_connected", "switch", "torus2d", "rail", "dragonfly",
    ]);
    for n in [4usize, 16, 64, 256] {
        let mut row = vec![n.to_string()];
        for kind in KINDS {
            let dim = NetDim::new(kind, n, 100.0, 500.0);
            let ns = collective_ns(CommType::AllReduce, 100 << 20, dim.algo, &dim);
            row.push(human_time(ns as f64 * 1e-9));
        }
        t.row(row);
    }
    println!("{t}");

    // Part 1b: the same fabric under different collective algorithms —
    // the SW half of the co-design space. On a 64-port switch the
    // latency-bound small payload favors halving-doubling's log2 steps
    // while the bandwidth-bound large payload favors direct exchange.
    println!("== algorithm choice on one 64-port switch (25 GB/s, 5 us) ==");
    let mut ta = Table::new(vec!["Payload", "ring", "hd", "direct"]);
    for bytes in [1u64 << 16, 100 << 20] {
        let mut row = vec![modtrans::util::human_bytes(bytes)];
        for algo in [CollectiveAlgo::Ring, CollectiveAlgo::HalvingDoubling, CollectiveAlgo::Direct]
        {
            let dim = NetDim::new(TopologyKind::Switch, 64, 25.0, 5000.0);
            let ns = collective_ns(CommType::AllReduce, bytes, algo, &dim);
            row.push(human_time(ns as f64 * 1e-9));
        }
        ta.row(row);
    }
    println!("{ta}");

    // Part 2: end-to-end VGG-16 DP iteration per topology. VGG's 528 MB
    // of weights over slow 10 GB/s links outruns the backward-overlap
    // window, so the interconnect choice is visible end to end.
    let model = zoo::get("vgg16", ZooOpts { weights: WeightFill::Empty })?;
    let summary = extract(&model, 32)?;
    let opts = TranslateOpts { parallelism: Parallelism::Data, npus: 64, mp_group: 4, batch: 32, zero: modtrans::translator::ZeroStage::None };
    let w = to_workload(&summary, opts, &SystolicCompute::new(32))?;
    println!("== VGG-16 DATA-parallel iteration, 64 NPUs (10 GB/s ethernet-class links) ==");
    let mut t2 = Table::new(vec!["Topology", "Iteration", "Compute util", "Exposed comm"]);
    for kind in KINDS {
        let cfg = SimConfig {
            network: Network::single(kind, 64, 10.0, 5000.0),
            iterations: 2,
            ..Default::default()
        };
        let r = simulate(&w, &cfg)?;
        t2.row(vec![
            kind.token().to_string(),
            human_time(r.iteration_ns as f64 * 1e-9),
            format!("{:.1}%", r.compute_utilization * 100.0),
            human_time(r.exposed_ns as f64 * 1e-9),
        ]);
    }
    println!("{t2}");

    // Part 3: two-tier vs flat — the hierarchical-collective payoff.
    println!("== two-tier (8-NPU NVLink nodes x 8, hierarchical all-reduce) ==");
    let cfg = SimConfig { network: Network::two_tier(8, 8), iterations: 2, ..Default::default() };
    let r = simulate(&w, &cfg)?;
    println!(
        "iteration {}  compute util {:.1}%  dim0 busy {}  dim1 busy {}",
        human_time(r.iteration_ns as f64 * 1e-9),
        r.compute_utilization * 100.0,
        human_time(r.net_busy_ns[0] as f64 * 1e-9),
        human_time(r.net_busy_ns[1] as f64 * 1e-9),
    );
    Ok(())
}
