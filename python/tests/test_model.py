"""L2 model checks: explicit backward == jax.grad reference; training
step actually learns on a synthetic task."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def _batch(seed):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (model.MLP_BATCH, model.MLP_IN), jnp.float32)
    labels = jax.random.randint(ky, (model.MLP_BATCH,), 0, model.MLP_OUT)
    y = jax.nn.one_hot(labels, model.MLP_OUT, dtype=jnp.float32)
    return x, y


def test_explicit_backward_matches_jax_grad():
    params = model.mlp_init(0)
    x, y = _batch(1)
    got = model.mlp_train_step(*params, x, y)
    want = model.mlp_train_step_ref(*params, x, y)
    for g, w, name in zip(got, want, ["w1", "b1", "w2", "b2", "loss"]):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=2e-4, atol=2e-4, err_msg=name
        )


def test_training_reduces_loss():
    params = model.mlp_init(42)
    # Learnable synthetic task: labels derived from a fixed random
    # projection of the inputs.
    key = jax.random.PRNGKey(7)
    proj = jax.random.normal(key, (model.MLP_IN, model.MLP_OUT), jnp.float32)
    losses = []
    step = jax.jit(model.mlp_train_step)
    for i in range(60):
        kx = jax.random.PRNGKey(100 + i)
        x = jax.random.normal(kx, (model.MLP_BATCH, model.MLP_IN), jnp.float32)
        y = jax.nn.one_hot(jnp.argmax(x @ proj, axis=-1), model.MLP_OUT, dtype=jnp.float32)
        *params, loss = step(*params, x, y)
        losses.append(float(loss))
    head = sum(losses[:5]) / 5
    tail = sum(losses[-5:]) / 5
    assert tail < head * 0.9, f"no learning: {head:.3f} -> {tail:.3f}"


def test_shapes_and_finiteness():
    params = model.mlp_init(3)
    x, y = _batch(4)
    w1, b1, w2, b2, loss = model.mlp_train_step(*params, x, y)
    assert w1.shape == (model.MLP_IN, model.MLP_HIDDEN)
    assert b1.shape == (model.MLP_HIDDEN,)
    assert w2.shape == (model.MLP_HIDDEN, model.MLP_OUT)
    assert b2.shape == (model.MLP_OUT,)
    assert np.isfinite(float(loss))
    for t in (w1, b1, w2, b2):
        assert bool(jnp.isfinite(t).all())


def test_transformer_ffn_matches_ref():
    from compile.kernels import transformer_ffn_ref

    k = jax.random.PRNGKey(11)
    ks = jax.random.split(k, 7)
    x = jax.random.normal(ks[0], (model.FFN_TOKENS, model.FFN_D), jnp.float32)
    gamma = jax.random.normal(ks[1], (model.FFN_D,)) * 0.1 + 1.0
    beta = jax.random.normal(ks[2], (model.FFN_D,)) * 0.1
    w1 = jax.random.normal(ks[3], (model.FFN_D, model.FFN_HIDDEN)) * 0.02
    b1 = jnp.zeros((model.FFN_HIDDEN,))
    w2 = jax.random.normal(ks[4], (model.FFN_HIDDEN, model.FFN_D)) * 0.02
    b2 = jnp.zeros((model.FFN_D,))
    (got,) = model.transformer_ffn(x, gamma, beta, w1, b1, w2, b2)
    want = transformer_ffn_ref(x, gamma, beta, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_transformer_ffn_residual_identity_with_zero_weights():
    # w2 = 0 collapses the block to the identity: out == x exactly.
    x = jax.random.normal(jax.random.PRNGKey(3), (model.FFN_TOKENS, model.FFN_D))
    (out,) = model.transformer_ffn(
        x,
        jnp.ones((model.FFN_D,)),
        jnp.zeros((model.FFN_D,)),
        jnp.ones((model.FFN_D, model.FFN_HIDDEN)),
        jnp.zeros((model.FFN_HIDDEN,)),
        jnp.zeros((model.FFN_HIDDEN, model.FFN_D)),
        jnp.zeros((model.FFN_D,)),
    )
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
