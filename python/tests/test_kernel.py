"""Pallas matmul kernel vs pure-jnp reference — the core L1 correctness
signal, swept over shapes and dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, matmul_ref, vmem_footprint_bytes


def _rand(shape, dtype, seed):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (128, 128, 128),   # exactly one block
        (256, 256, 256),   # multi-block, divisible
        (64, 64, 64),      # smaller than a block
        (1, 1, 1),         # degenerate
        (130, 257, 65),    # every dim non-divisible
        (128, 1, 128),     # skinny K
        (1, 512, 1),       # vector-vector-ish
    ],
)
def test_matmul_matches_ref_f32(m, k, n):
    x = _rand((m, k), jnp.float32, 0)
    w = _rand((k, n), jnp.float32, 1)
    got = matmul(x, w)
    want = matmul_ref(x, w)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
    bm=st.sampled_from([32, 64, 128]),
    bn=st.sampled_from([32, 64, 128]),
    bk=st.sampled_from([32, 64, 128]),
)
def test_matmul_hypothesis_shape_sweep(m, k, n, bm, bn, bk):
    x = _rand((m, k), jnp.float32, m * 7 + k)
    w = _rand((k, n), jnp.float32, n * 13 + k)
    got = matmul(x, w, block_m=bm, block_n=bn, block_k=bk)
    want = matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    x = _rand((96, 96), dtype, 2)
    w = _rand((96, 96), dtype, 3)
    got = np.asarray(matmul(x, w), dtype=np.float32)
    want = np.asarray(matmul_ref(x, w), dtype=np.float32)
    tol = 1e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_inner_dim_mismatch_raises():
    x = jnp.zeros((4, 5))
    w = jnp.zeros((6, 7))
    with pytest.raises(ValueError):
        matmul(x, w)


def test_zero_inputs_give_zero():
    x = jnp.zeros((130, 70))
    w = jnp.zeros((70, 33))
    out = matmul(x, w)
    assert out.shape == (130, 33)
    assert float(jnp.abs(out).max()) == 0.0


def test_vmem_footprint_under_budget():
    # Default tiling must fit VMEM (~16 MiB) with ample double-buffer room.
    assert vmem_footprint_bytes() == (128 * 128 * 3) * 4
    assert vmem_footprint_bytes() < 16 * 1024 * 1024 // 4


# ---- layernorm kernel ----

from compile.kernels import layernorm, layernorm_ref  # noqa: E402


@pytest.mark.parametrize("n,d", [(128, 768), (1, 16), (130, 257), (64, 64)])
def test_layernorm_matches_ref(n, d):
    x = _rand((n, d), jnp.float32, n + d)
    g = _rand((d,), jnp.float32, 5)
    b = _rand((d,), jnp.float32, 6)
    got = layernorm(x, g, b)
    want = layernorm_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 300), d=st.integers(2, 512), br=st.sampled_from([32, 128]))
def test_layernorm_hypothesis_sweep(n, d, br):
    x = _rand((n, d), jnp.float32, n * 31 + d)
    g = _rand((d,), jnp.float32, 1)
    b = _rand((d,), jnp.float32, 2)
    got = layernorm(x, g, b, block_rows=br)
    want = layernorm_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_layernorm_output_statistics():
    # With unit gamma / zero beta each row is ~N(0, 1).
    x = _rand((64, 1024), jnp.float32, 9) * 5.0 + 3.0
    out = layernorm(x, jnp.ones((1024,)), jnp.zeros((1024,)))
    assert abs(float(out.mean())) < 1e-3
    assert abs(float(out.var()) - 1.0) < 1e-2


def test_layernorm_bad_affine_shape_raises():
    with pytest.raises(ValueError):
        layernorm(jnp.zeros((4, 8)), jnp.zeros((9,)), jnp.zeros((8,)))
