"""AOT lowering smoke tests: HLO text emission is well-formed."""

import jax
import jax.numpy as jnp

from compile import aot, model


def test_gemm_lowering_produces_hlo_text():
    text = aot.lower_gemm(128, 128, 128)
    assert text.startswith("HloModule")
    # Parameters and a dot/conv-like op must appear.
    assert "parameter(0)" in text
    assert "f32[128,128]" in text


def test_menu_matches_rust_calibrate():
    # Keep in lock-step with rust/src/calibrate/mod.rs::GEMM_MENU.
    assert aot.MENU == [
        (128, 128, 128),
        (256, 256, 256),
        (512, 512, 512),
        (1024, 1024, 1024),
        (256, 2048, 512),
    ]


def test_train_step_lowering_has_all_outputs():
    text = aot.lower_train_step()
    assert text.startswith("HloModule")
    # Root is a 5-tuple: 4 params + scalar loss.
    assert f"f32[{model.MLP_IN},{model.MLP_HIDDEN}]" in text
    assert "f32[]" in text


def test_lowered_gemm_executes_in_process():
    # Round-trip through XLA in-process (compile+run the text's source
    # computation) — mirrors what the rust runtime does out-of-process.
    xs = jnp.ones((128, 128), jnp.float32)
    ws = jnp.full((128, 128), 0.5, jnp.float32)
    (out,) = jax.jit(model.gemm_fn)(xs, ws)
    assert out.shape == (128, 128)
    assert abs(float(out[0, 0]) - 64.0) < 1e-3


def test_transformer_ffn_lowering():
    text = aot.lower_transformer_ffn()
    assert text.startswith("HloModule")
    assert f"f32[{model.FFN_TOKENS},{model.FFN_D}]" in text
    assert f"f32[{model.FFN_D},{model.FFN_HIDDEN}]" in text
