"""AOT lowering: JAX/Pallas graphs → HLO text artifacts for the rust
runtime.

Interchange format is HLO **text**, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that the image's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits:
* ``gemm_MxKxN.hlo.txt`` for every shape in ``MENU`` (must stay in sync
  with ``rust/src/calibrate/mod.rs::GEMM_MENU``);
* ``mlp_train_step.hlo.txt`` — the end-to-end training step.
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Must match rust/src/calibrate/mod.rs::GEMM_MENU.
MENU = [
    (128, 128, 128),
    (256, 256, 256),
    (512, 512, 512),
    (1024, 1024, 1024),
    (256, 2048, 512),
]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_gemm(m: int, k: int, n: int) -> str:
    xs = jax.ShapeDtypeStruct((m, k), jnp.float32)
    ws = jax.ShapeDtypeStruct((k, n), jnp.float32)
    return to_hlo_text(jax.jit(model.gemm_fn).lower(xs, ws))


def lower_transformer_ffn() -> str:
    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((model.FFN_TOKENS, model.FFN_D), f32),
        jax.ShapeDtypeStruct((model.FFN_D,), f32),
        jax.ShapeDtypeStruct((model.FFN_D,), f32),
        jax.ShapeDtypeStruct((model.FFN_D, model.FFN_HIDDEN), f32),
        jax.ShapeDtypeStruct((model.FFN_HIDDEN,), f32),
        jax.ShapeDtypeStruct((model.FFN_HIDDEN, model.FFN_D), f32),
        jax.ShapeDtypeStruct((model.FFN_D,), f32),
    ]
    return to_hlo_text(jax.jit(model.transformer_ffn).lower(*args))


def lower_train_step() -> str:
    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((model.MLP_IN, model.MLP_HIDDEN), f32),
        jax.ShapeDtypeStruct((model.MLP_HIDDEN,), f32),
        jax.ShapeDtypeStruct((model.MLP_HIDDEN, model.MLP_OUT), f32),
        jax.ShapeDtypeStruct((model.MLP_OUT,), f32),
        jax.ShapeDtypeStruct((model.MLP_BATCH, model.MLP_IN), f32),
        jax.ShapeDtypeStruct((model.MLP_BATCH, model.MLP_OUT), f32),
    ]
    return to_hlo_text(jax.jit(model.mlp_train_step).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated artifact names to (re)build; default all",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    jobs = {}
    for m, k, n in MENU:
        jobs[f"gemm_{m}x{k}x{n}"] = lambda m=m, k=k, n=n: lower_gemm(m, k, n)
    jobs["mlp_train_step"] = lower_train_step
    jobs["transformer_ffn"] = lower_transformer_ffn

    for name, fn in jobs.items():
        if only is not None and name not in only:
            continue
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = fn()
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)


if __name__ == "__main__":
    main()
