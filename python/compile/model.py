"""L2: JAX compute graphs built on the L1 Pallas matmul kernel.

Two graph families are AOT-lowered for the rust runtime:

* ``gemm_fn`` — a bare kernel invocation per calibration menu shape; the
  rust `calibrate` module times these to derive measured per-layer
  compute costs (the stand-in for the paper's SCALE-sim/GPU profiling).
* ``mlp_train_step`` — a complete training step (forward, backward,
  SGD update) for a 784-256-10 MLP with the forward *and* backward GEMMs
  expressed through the Pallas kernel. Backward is written explicitly
  (d_logits → dW2/db2 → dh → dW1/db1) because ``jax.grad`` cannot
  differentiate through ``pallas_call`` without a custom VJP — and the
  explicit form keeps every GEMM on the L1 kernel, which is the point.

The rust end-to-end example (`examples/end_to_end.rs`) drives
``mlp_train_step`` for a few hundred steps on synthetic data and logs the
loss curve, proving all three layers compose.
"""

import jax
import jax.numpy as jnp

from .kernels import layernorm, matmul

# MLP dimensions baked into the artifact (rust side mirrors these).
MLP_IN, MLP_HIDDEN, MLP_OUT, MLP_BATCH = 784, 256, 10, 128
MLP_LR = 0.05


def gemm_fn(x, w):
    """A single L1-kernel GEMM, the calibration unit."""
    return (matmul(x, w),)


# Transformer FFN dimensions baked into the artifact.
FFN_TOKENS, FFN_D, FFN_HIDDEN = 128, 768, 3072


def transformer_ffn(x, gamma, beta, w1, b1, w2, b2):
    """Pre-LN transformer feed-forward block: ``x + W2·gelu(W1·LN(x))``.

    Both the LayerNorm and the two GEMMs run through L1 Pallas kernels —
    this is the per-block compute a pipeline stage of the gpt2 zoo models
    executes; the rust runtime integration test drives this artifact.
    """
    h = layernorm(x, gamma, beta)
    h = jax.nn.gelu(matmul(h, w1) + b1)
    return (x + matmul(h, w2) + b2,)


def _softmax_xent_and_dlogits(logits, y_onehot):
    """Mean CE loss and its gradient wrt logits (explicit backward)."""
    z = logits - logits.max(axis=-1, keepdims=True)
    ez = jnp.exp(z)
    p = ez / ez.sum(axis=-1, keepdims=True)
    logp = z - jnp.log(ez.sum(axis=-1, keepdims=True))
    loss = -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))
    dlogits = (p - y_onehot) / logits.shape[0]
    return loss, dlogits


def mlp_train_step(w1, b1, w2, b2, x, y_onehot):
    """One SGD step; returns updated params + loss.

    All four GEMMs (fwd x@W1, fwd h@W2, bwd dlogits@W2ᵀ, bwd grads) run
    through the Pallas kernel.
    """
    # ---- forward ----
    a1 = matmul(x, w1) + b1
    h = jnp.maximum(a1, 0.0)
    logits = matmul(h, w2) + b2

    # ---- backward (explicit) ----
    loss, dlogits = _softmax_xent_and_dlogits(logits, y_onehot)
    dw2 = matmul(h.T, dlogits)
    db2 = dlogits.sum(axis=0)
    dh = matmul(dlogits, w2.T)
    da1 = dh * (a1 > 0.0)
    dw1 = matmul(x.T, da1)
    db1 = da1.sum(axis=0)

    # ---- SGD ----
    return (
        w1 - MLP_LR * dw1,
        b1 - MLP_LR * db1,
        w2 - MLP_LR * dw2,
        b2 - MLP_LR * db2,
        loss,
    )


def mlp_init(seed: int = 0):
    """He-initialized MLP parameters (mirrored by the rust driver)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w1 = jax.random.normal(k1, (MLP_IN, MLP_HIDDEN), jnp.float32) * (2.0 / MLP_IN) ** 0.5
    b1 = jnp.zeros((MLP_HIDDEN,), jnp.float32)
    w2 = jax.random.normal(k2, (MLP_HIDDEN, MLP_OUT), jnp.float32) * (2.0 / MLP_HIDDEN) ** 0.5
    b2 = jnp.zeros((MLP_OUT,), jnp.float32)
    return w1, b1, w2, b2


def mlp_train_step_ref(w1, b1, w2, b2, x, y_onehot):
    """jnp-only + jax.grad reference for the explicit backward (pytest)."""

    def loss_fn(params):
        from .kernels.ref import mlp_forward_ref, softmax_xent_ref

        logits = mlp_forward_ref(params, x)
        return softmax_xent_ref(logits, y_onehot)

    params = (w1, b1, w2, b2)
    loss, grads = jax.value_and_grad(loss_fn)(params)
    new = tuple(p - MLP_LR * g for p, g in zip(params, grads))
    return (*new, loss)
