"""L1: fused LayerNorm Pallas kernel.

Row-tiled: the grid walks blocks of `block_rows` rows; each step loads a
`(block_rows, d)` tile into VMEM, computes mean/variance along the feature
axis in one pass, and writes the normalized+affine result — the classic
fusion that avoids materializing mean/var/normalized intermediates in HBM.
On TPU the feature axis stays in-lane (d is the minor dimension), so the
reductions are cheap vector ops; `interpret=True` as always for CPU PJRT.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    norm = (x - mean) * jax.lax.rsqrt(var + eps)
    o_ref[...] = norm * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "eps"))
def layernorm(x, gamma, beta, *, block_rows: int = 128, eps: float = 1e-5):
    """LayerNorm over the last axis of a 2-D input via Pallas.

    `x: (n, d)`, `gamma/beta: (d,)`. Rows are padded to a multiple of
    `block_rows` and sliced back; padding rows normalize garbage that is
    discarded, never read.
    """
    n, d = x.shape
    if gamma.shape != (d,) or beta.shape != (d,):
        raise ValueError(f"affine params must be ({d},), got {gamma.shape}/{beta.shape}")
    br = min(block_rows, n)
    np_ = _cdiv(n, br) * br
    xp = jnp.pad(x, ((0, np_ - n), (0, 0))) if np_ != n else x
    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(np_ // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, d), x.dtype),
        interpret=True,
    )(xp, gamma, beta)
    return out[:n]
