"""Pure-jnp oracles for the Pallas kernels and the L2 model.

Every kernel has a reference here; pytest asserts allclose between kernel
and reference across a hypothesis-driven shape/dtype sweep.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Reference for :func:`compile.kernels.matmul.matmul`."""
    return jnp.matmul(x, w)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """Reference for :func:`compile.kernels.layernorm.layernorm`."""
    mean = x.mean(-1, keepdims=True)
    var = ((x - mean) ** 2).mean(-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta


def transformer_ffn_ref(x, gamma, beta, w1, b1, w2, b2):
    """Reference pre-LN FFN block: x + W2·gelu(W1·LN(x))."""
    import jax
    h = layernorm_ref(x, gamma, beta)
    h = jax.nn.gelu(h @ w1 + b1)
    return x + h @ w2 + b2


def mlp_forward_ref(params, x):
    """Reference 2-layer MLP forward: relu(x@w1+b1)@w2+b2 (logits)."""
    w1, b1, w2, b2 = params
    h = jnp.maximum(x @ w1 + b1, 0.0)
    return h @ w2 + b2


def softmax_xent_ref(logits, y_onehot):
    """Mean softmax cross-entropy."""
    logz = jnp.log(jnp.sum(jnp.exp(logits - logits.max(-1, keepdims=True)), -1))
    logp = logits - logits.max(-1, keepdims=True) - logz[..., None]
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))
