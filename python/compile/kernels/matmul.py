"""L1: tiled Pallas matmul kernel.

The compute hot-spot of every layer ModTrans extracts (conv-as-im2col,
dense, attention projections) is a GEMM, so the single L1 kernel is a
block-tiled matmul shaped for the MXU:

* the grid iterates ``(M/bm, N/bn, K/bk)``; each step multiplies a
  ``(bm, bk)`` LHS tile by a ``(bk, bn)`` RHS tile and accumulates into
  the ``(bm, bn)`` output tile in VMEM (``o_ref`` revisited across the
  innermost k steps — Pallas keeps the block resident);
* default 128x128x128 tiles match the 128x128 systolic array modeled by
  ``rust/src/compute`` (SCALE-sim WS dataflow) — the same tiling story in
  both the analytical model and the kernel (DESIGN.md
  §Hardware-Adaptation);
* VMEM footprint per step = (bm*bk + bk*bn + bm*bn) * 4 B = 192 KiB at
  the defaults, far under the ~16 MiB VMEM budget, leaving room for
  double buffering.

``interpret=True`` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; lowering in interpret mode produces plain HLO the rust
runtime can run (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _matmul_kernel(x_ref, w_ref, o_ref, *, k_steps: int):
    """One grid step: accumulate x_tile @ w_tile into the output tile."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)
    del k_steps  # shape bookkeeping only


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k")
)
def matmul(x, w, *, block_m: int = 128, block_n: int = 128, block_k: int = 128):
    """``x @ w`` via the tiled Pallas kernel.

    Inputs of any (M, K) x (K, N) shape; non-multiples of the block sizes
    are zero-padded and the result sliced back, so numerics match
    ``jnp.matmul`` exactly for float32.
    """
    (m, k), (k2, n) = x.shape, w.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {x.shape} @ {w.shape}")
    out_dtype = jnp.promote_types(x.dtype, w.dtype)

    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    mp, np_, kp = _cdiv(m, bm) * bm, _cdiv(n, bn) * bn, _cdiv(k, bk) * bk
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k))) if (mp, kp) != (m, k) else x
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n))) if (kp, np_) != (k, n) else w

    k_steps = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp)
    return out[:m, :n]


def vmem_footprint_bytes(
    block_m: int = 128, block_n: int = 128, block_k: int = 128, elem: int = 4
) -> int:
    """Per-step VMEM residency of the kernel (DESIGN.md §Perf)."""
    return (block_m * block_k + block_k * block_n + block_m * block_n) * elem
