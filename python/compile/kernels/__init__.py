"""L1 Pallas kernels + pure-jnp references."""

from .layernorm import layernorm  # noqa: F401
from .matmul import matmul, vmem_footprint_bytes  # noqa: F401
from .ref import (  # noqa: F401
    layernorm_ref,
    matmul_ref,
    mlp_forward_ref,
    softmax_xent_ref,
    transformer_ffn_ref,
)
