"""Build-time compile package: L2 JAX models + L1 Pallas kernels + AOT.

Python runs ONLY at build time (``make artifacts``); the rust coordinator
loads the lowered HLO and never imports this package at runtime.
"""
