//! Discrete-event simulation core: a task graph executed over exclusive
//! resources.
//!
//! This is the substrate under the ASTRA-sim-style system/workload layers:
//! *tasks* (compute phases, collectives, point-to-point sends) declare
//! dependencies and a resource (an NPU's compute stream, a network
//! dimension); the engine runs the earliest-finishing task first,
//! releasing dependents as their inputs complete. Resources serve one task
//! at a time and order their backlog FIFO or LIFO — the two communication
//! scheduling policies the paper's §2.2 describes.

use crate::error::{Error, Result};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Index of a task in its [`TaskGraph`].
pub type TaskId = usize;

/// Index of a resource registered with the engine.
pub type ResourceId = usize;

/// Queue discipline for a contended resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// First in, first out.
    Fifo,
    /// Last in, first out (ASTRA-sim's LIFO communication scheduling).
    Lifo,
}

/// A node in the task graph.
#[derive(Debug, Clone)]
pub struct Task {
    /// Service time in nanoseconds once the resource is acquired.
    pub duration_ns: u64,
    /// Resource this task occupies exclusively while running.
    pub resource: ResourceId,
    /// Tasks that must finish before this one becomes ready.
    pub deps: Vec<TaskId>,
    /// Free-form label (layer/phase) used in reports.
    pub label: String,
}

/// A task graph under construction.
#[derive(Debug, Default, Clone)]
pub struct TaskGraph {
    tasks: Vec<Task>,
}

impl TaskGraph {
    /// Empty graph.
    pub fn new() -> TaskGraph {
        TaskGraph::default()
    }

    /// Add a task; returns its id.
    pub fn add(
        &mut self,
        label: impl Into<String>,
        resource: ResourceId,
        duration_ns: u64,
        deps: &[TaskId],
    ) -> TaskId {
        let id = self.tasks.len();
        self.tasks.push(Task {
            duration_ns,
            resource,
            deps: deps.to_vec(),
            label: label.into(),
        });
        id
    }

    /// Number of tasks.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// True when no tasks have been added.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Task accessor.
    pub fn task(&self, id: TaskId) -> &Task {
        &self.tasks[id]
    }
}

/// A registered resource.
#[derive(Debug, Clone)]
struct Resource {
    policy: Policy,
    /// Pending ready tasks (ordered per policy). FIFO pops advance
    /// `head` instead of shifting the vector (O(1) amortized); the dead
    /// prefix is compacted once it dominates.
    backlog: Vec<TaskId>,
    /// First live element of `backlog` (FIFO cursor).
    head: usize,
    /// Currently running task, if any.
    running: Option<TaskId>,
    /// Accumulated busy time.
    busy_ns: u64,
    label: String,
}

impl Resource {
    fn backlog_is_empty(&self) -> bool {
        self.head >= self.backlog.len()
    }

    fn push(&mut self, id: TaskId) {
        self.backlog.push(id);
    }

    fn pop(&mut self) -> TaskId {
        match self.policy {
            Policy::Fifo => {
                let id = self.backlog[self.head];
                self.head += 1;
                // Compact when the dead prefix dominates the live tail.
                if self.head > 32 && self.head * 2 > self.backlog.len() {
                    self.backlog.drain(..self.head);
                    self.head = 0;
                }
                id
            }
            Policy::Lifo => self.backlog.pop().expect("pop on empty backlog"),
        }
    }
}

/// Execution record for one task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Time the task became ready (all deps finished).
    pub ready_ns: u64,
    /// Time the resource was acquired.
    pub start_ns: u64,
    /// Completion time.
    pub finish_ns: u64,
}

/// Simulation output: per-task spans and per-resource utilization.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Span per task id.
    pub spans: Vec<Span>,
    /// Busy nanoseconds per resource id.
    pub busy_ns: Vec<u64>,
    /// Resource labels (index-aligned with `busy_ns`).
    pub resource_labels: Vec<String>,
    /// Makespan: completion time of the last task.
    pub makespan_ns: u64,
    /// Number of events processed (== number of tasks).
    pub events: usize,
}

impl Schedule {
    /// Total queueing delay (start - ready) across tasks on a resource.
    pub fn queueing_ns(&self, resource: ResourceId, graph: &TaskGraph) -> u64 {
        self.spans
            .iter()
            .enumerate()
            .filter(|(id, _)| graph.task(*id).resource == resource)
            .map(|(_, s)| s.start_ns - s.ready_ns)
            .sum()
    }
}

/// The engine: resources + run loop.
#[derive(Debug, Default)]
pub struct Engine {
    resources: Vec<Resource>,
}

impl Engine {
    /// Engine with no resources.
    pub fn new() -> Engine {
        Engine::default()
    }

    /// Register a resource; returns its id.
    pub fn add_resource(&mut self, label: impl Into<String>, policy: Policy) -> ResourceId {
        let id = self.resources.len();
        self.resources.push(Resource {
            policy,
            backlog: Vec::new(),
            head: 0,
            running: None,
            busy_ns: 0,
            label: label.into(),
        });
        id
    }

    /// Execute the graph to completion. Fails on dangling resource ids or
    /// if the graph deadlocks (dependency cycle).
    pub fn run(&mut self, graph: &TaskGraph) -> Result<Schedule> {
        let n = graph.len();
        for t in &graph.tasks {
            if t.resource >= self.resources.len() {
                return Err(Error::sim(format!(
                    "task '{}' references unknown resource {}",
                    t.label, t.resource
                )));
            }
            for &d in &t.deps {
                if d >= n {
                    return Err(Error::sim(format!(
                        "task '{}' depends on unknown task {d}",
                        t.label
                    )));
                }
            }
        }

        // Dependency bookkeeping.
        let mut pending: Vec<usize> = graph.tasks.iter().map(|t| t.deps.len()).collect();
        let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for (id, t) in graph.tasks.iter().enumerate() {
            for &d in &t.deps {
                dependents[d].push(id);
            }
        }

        let mut spans = vec![Span { ready_ns: 0, start_ns: 0, finish_ns: 0 }; n];
        // Completion event heap: (finish time, seq, task). seq keeps
        // deterministic FIFO order among equal-time completions.
        let mut heap: BinaryHeap<Reverse<(u64, u64, TaskId)>> = BinaryHeap::new();
        let mut seq: u64 = 0;

        for r in &mut self.resources {
            r.backlog.clear();
            r.head = 0;
            r.running = None;
            r.busy_ns = 0;
        }

        let mut now: u64 = 0;
        let mut completed = 0usize;

        // Seed: tasks with no deps are ready at t=0.
        for id in 0..n {
            if pending[id] == 0 {
                spans[id].ready_ns = 0;
                self.resources[graph.tasks[id].resource].backlog.push(id);
            }
        }
        for rid in 0..self.resources.len() {
            Self::dispatch(&mut self.resources[rid], graph, &mut spans, 0, &mut heap, &mut seq);
        }

        while let Some(Reverse((t, _, id))) = heap.pop() {
            now = t;
            completed += 1;
            spans[id].finish_ns = now;
            let rid = graph.tasks[id].resource;
            self.resources[rid].running = None;

            // Wake dependents.
            for &dep in &dependents[id] {
                pending[dep] -= 1;
                if pending[dep] == 0 {
                    spans[dep].ready_ns = now;
                    self.resources[graph.tasks[dep].resource].push(dep);
                }
            }
            // Re-dispatch every resource that may have gained work (the
            // completing task's own resource plus dependents' resources).
            Self::dispatch(&mut self.resources[rid], graph, &mut spans, now, &mut heap, &mut seq);
            for &dep in &dependents[id] {
                let drid = graph.tasks[dep].resource;
                Self::dispatch(
                    &mut self.resources[drid],
                    graph,
                    &mut spans,
                    now,
                    &mut heap,
                    &mut seq,
                );
            }
        }

        if completed != n {
            return Err(Error::sim(format!(
                "deadlock: {completed}/{n} tasks completed (dependency cycle?)"
            )));
        }

        Ok(Schedule {
            spans,
            busy_ns: self.resources.iter().map(|r| r.busy_ns).collect(),
            resource_labels: self.resources.iter().map(|r| r.label.clone()).collect(),
            makespan_ns: now,
            events: completed,
        })
    }

    /// If `res` is idle and has backlog, start its next task per policy.
    fn dispatch(
        res: &mut Resource,
        graph: &TaskGraph,
        spans: &mut [Span],
        now: u64,
        heap: &mut BinaryHeap<Reverse<(u64, u64, TaskId)>>,
        seq: &mut u64,
    ) {
        if res.running.is_some() || res.backlog_is_empty() {
            return;
        }
        let id = res.pop();
        let dur = graph.tasks[id].duration_ns;
        spans[id].start_ns = now;
        res.running = Some(id);
        res.busy_ns += dur;
        heap.push(Reverse((now + dur, *seq, id)));
        *seq += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_chain_sums_durations() {
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let cpu = eng.add_resource("cpu", Policy::Fifo);
        let a = g.add("a", cpu, 10, &[]);
        let b = g.add("b", cpu, 20, &[a]);
        let c = g.add("c", cpu, 30, &[b]);
        let s = eng.run(&g).unwrap();
        assert_eq!(s.makespan_ns, 60);
        assert_eq!(s.spans[c].start_ns, 30);
        assert_eq!(s.busy_ns[cpu], 60);
    }

    #[test]
    fn independent_tasks_on_distinct_resources_overlap() {
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let r0 = eng.add_resource("r0", Policy::Fifo);
        let r1 = eng.add_resource("r1", Policy::Fifo);
        g.add("a", r0, 100, &[]);
        g.add("b", r1, 70, &[]);
        let s = eng.run(&g).unwrap();
        assert_eq!(s.makespan_ns, 100);
    }

    #[test]
    fn contention_serializes() {
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let r = eng.add_resource("net", Policy::Fifo);
        g.add("a", r, 100, &[]);
        g.add("b", r, 100, &[]);
        let s = eng.run(&g).unwrap();
        assert_eq!(s.makespan_ns, 200);
        assert_eq!(s.queueing_ns(r, &g), 100);
    }

    #[test]
    fn fifo_vs_lifo_ordering() {
        // Three comm tasks become ready in order a, b, c while the resource
        // is busy with "hold". FIFO runs a,b,c; LIFO runs c,b,a.
        let build = TaskGraph::new;
        for (policy, expect_first) in [(Policy::Fifo, "a"), (Policy::Lifo, "c")] {
            let mut g = build();
            let mut eng = Engine::new();
            let cpu = eng.add_resource("cpu", Policy::Fifo);
            let net = eng.add_resource("net", policy);
            let hold = g.add("hold", net, 100, &[]);
            // Ready at staggered times via cpu chain.
            let t1 = g.add("cpu1", cpu, 10, &[]);
            let t2 = g.add("cpu2", cpu, 10, &[t1]);
            let t3 = g.add("cpu3", cpu, 10, &[t2]);
            let a = g.add("a", net, 50, &[t1]);
            let b = g.add("b", net, 50, &[t2]);
            let c = g.add("c", net, 50, &[t3]);
            let s = eng.run(&g).unwrap();
            let _ = hold;
            // First net task to start after hold finishes at t=100:
            let first = [a, b, c]
                .into_iter()
                .min_by_key(|&id| s.spans[id].start_ns)
                .unwrap();
            assert_eq!(g.task(first).label, expect_first, "{policy:?}");
        }
    }

    #[test]
    fn diamond_dependencies() {
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let r0 = eng.add_resource("r0", Policy::Fifo);
        let r1 = eng.add_resource("r1", Policy::Fifo);
        let a = g.add("a", r0, 10, &[]);
        let b = g.add("b", r0, 20, &[a]);
        let c = g.add("c", r1, 5, &[a]);
        let d = g.add("d", r0, 1, &[b, c]);
        let s = eng.run(&g).unwrap();
        assert_eq!(s.spans[d].ready_ns, 30); // max(b=30, c=15)
        assert_eq!(s.makespan_ns, 31);
    }

    #[test]
    fn cycle_is_detected_not_hung() {
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let r = eng.add_resource("r", Policy::Fifo);
        // Manual cycle: a → b → a. Construct via deps on future ids.
        let a = g.add("a", r, 1, &[1]);
        let _b = g.add("b", r, 1, &[a]);
        assert!(eng.run(&g).is_err());
    }

    #[test]
    fn bad_resource_id_is_error() {
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let _ = eng.add_resource("r", Policy::Fifo);
        g.add("a", 5, 1, &[]);
        assert!(eng.run(&g).is_err());
    }

    #[test]
    fn zero_duration_tasks_complete() {
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let r = eng.add_resource("r", Policy::Fifo);
        let a = g.add("a", r, 0, &[]);
        let b = g.add("b", r, 0, &[a]);
        let s = eng.run(&g).unwrap();
        assert_eq!(s.makespan_ns, 0);
        assert_eq!(s.spans[b].finish_ns, 0);
    }

    #[test]
    fn determinism_same_inputs_same_schedule() {
        let build_and_run = || {
            let mut g = TaskGraph::new();
            let mut eng = Engine::new();
            let cpu = eng.add_resource("cpu", Policy::Fifo);
            let net = eng.add_resource("net", Policy::Lifo);
            let mut prev: Option<TaskId> = None;
            for i in 0..50 {
                let deps: Vec<TaskId> = prev.into_iter().collect();
                let c = g.add(format!("c{i}"), cpu, 7 + (i % 5), &deps);
                g.add(format!("n{i}"), net, 13 + (i % 3), &[c]);
                prev = Some(c);
            }
            let s = eng.run(&g).unwrap();
            (s.makespan_ns, s.spans.iter().map(|x| x.start_ns).collect::<Vec<_>>())
        };
        assert_eq!(build_and_run(), build_and_run());
    }
}
