//! Discrete-event simulation core: a task graph executed over exclusive
//! resources.
//!
//! This is the substrate under the ASTRA-sim-style system/workload layers:
//! *tasks* (compute phases, collectives, point-to-point sends) declare
//! dependencies and a resource (an NPU's compute stream, a network
//! dimension); the engine runs the earliest-finishing task first,
//! releasing dependents as their inputs complete. Resources serve one task
//! at a time and order their backlog FIFO or LIFO — the two communication
//! scheduling policies the paper's §2.2 describes.
//!
//! # Event core
//!
//! Completions are ordered by a monotone integer-time
//! [`CalendarQueue`](super::queue::CalendarQueue) rather than a
//! comparison-based binary heap, and the run loop is *batched*: every
//! iteration drains **all** events sharing the minimum timestamp in one
//! queue operation, then processes that completion wave event by event.
//! Within a wave the engine still dispatches incrementally — the
//! completing task's resource first, then each newly-woken dependent's
//! resource in first-wake order, deduplicated per event — because
//! deferring dispatch to the end of a wave would be *unsound*: a LIFO
//! backlog must see each wake as it happens (incremental dispatch starts
//! the first-woken task; a deferred pass would start the last-woken),
//! and the dispatch counter `seq` is the pop-order tiebreaker among
//! equal finish times, so even all-FIFO configurations would reorder.
//! The dedup is exact: repeated dispatch calls on an already-busy
//! resource were always no-ops.
//!
//! Per-task state read on the hot path — durations and resource ids —
//! lives in structure-of-arrays slabs inside the [`TaskGraph`]
//! ([`TaskGraph::durations`] / [`TaskGraph::resources`]), so `dispatch`
//! and the wake loop index two dense `u64`/`usize` arrays instead of
//! striding through 40-byte [`Task`] records.
//!
//! # Allocation discipline
//!
//! The hot path is allocation-free in steady state:
//!
//! * Tasks carry a `Copy` [`TaskTag`] instead of a label `String`, and
//!   their dependency lists live in one shared pool inside the
//!   [`TaskGraph`] (CSR layout) instead of a per-task `Vec`.
//! * All O(tasks) run-loop buffers (pending counts, the dependents CSR,
//!   the calendar queue, the wave batch, the dirty-resource set,
//!   per-task spans) live in a reusable [`RunScratch`];
//!   [`Engine::run_into`] only grows them, never reallocates once warm.
//! * [`Engine`] resource slots (and their backlog vectors) are reused
//!   across [`Engine::reset`] / [`Engine::add_resource`] cycles.

use super::queue::CalendarQueue;
use super::tag::TaskTag;
use crate::error::{Error, Result};

/// Index of a task in its [`TaskGraph`].
pub type TaskId = usize;

/// Index of a resource registered with the engine.
pub type ResourceId = usize;

/// Queue discipline for a contended resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// First in, first out.
    Fifo,
    /// Last in, first out (ASTRA-sim's LIFO communication scheduling).
    Lifo,
}

/// A node in the task graph. `Copy`: the dependency list lives in the
/// graph's shared pool, referenced by range.
#[derive(Debug, Clone, Copy)]
pub struct Task {
    /// Service time in nanoseconds once the resource is acquired.
    pub duration_ns: u64,
    /// Resource this task occupies exclusively while running.
    pub resource: ResourceId,
    /// Compact identity (rendered to a string only on demand).
    pub tag: TaskTag,
    deps_start: u32,
    deps_len: u32,
}

/// A task graph under construction. Reusable: [`TaskGraph::clear`] drops
/// the tasks but keeps both buffers' capacity, so rebuilding the next
/// scenario's graph allocates nothing once warm.
#[derive(Debug, Default, Clone)]
pub struct TaskGraph {
    tasks: Vec<Task>,
    dep_pool: Vec<TaskId>,
    /// SoA mirror of `tasks[i].duration_ns` — the only per-task field
    /// `dispatch` reads, kept dense so the run loop never strides
    /// through full `Task` records.
    durs: Vec<u64>,
    /// SoA mirror of `tasks[i].resource` for the wake/release path.
    ress: Vec<ResourceId>,
}

impl TaskGraph {
    /// Empty graph.
    pub fn new() -> TaskGraph {
        TaskGraph::default()
    }

    /// Add a task; returns its id.
    // lint: hot-path
    pub fn add(
        &mut self,
        tag: TaskTag,
        resource: ResourceId,
        duration_ns: u64,
        deps: &[TaskId],
    ) -> TaskId {
        let id = self.tasks.len();
        let deps_start = self.dep_pool.len() as u32;
        self.dep_pool.extend_from_slice(deps);
        self.tasks.push(Task {
            duration_ns,
            resource,
            tag,
            deps_start,
            deps_len: deps.len() as u32,
        });
        self.durs.push(duration_ns);
        self.ress.push(resource);
        id
    }

    /// Number of tasks.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// Number of entries in the shared dependency pool (total dep-list
    /// length across all tasks).
    pub fn num_deps(&self) -> usize {
        self.dep_pool.len()
    }

    /// True when no tasks have been added.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Task accessor.
    pub fn task(&self, id: TaskId) -> &Task {
        &self.tasks[id]
    }

    /// The dependency list of a task.
    pub fn deps_of(&self, id: TaskId) -> &[TaskId] {
        let t = &self.tasks[id];
        &self.dep_pool[t.deps_start as usize..(t.deps_start + t.deps_len) as usize]
    }

    /// Dense per-task durations, indexed by [`TaskId`] (SoA slab for the
    /// dispatch hot path).
    pub fn durations(&self) -> &[u64] {
        &self.durs
    }

    /// Dense per-task resource ids, indexed by [`TaskId`] (SoA slab for
    /// the wake/release hot path).
    pub fn resources(&self) -> &[ResourceId] {
        &self.ress
    }

    /// Drop all tasks but keep the allocated capacity (scratch reuse).
    pub fn clear(&mut self) {
        self.tasks.clear();
        self.dep_pool.clear();
        self.durs.clear();
        self.ress.clear();
    }

    /// Pre-size both buffers (e.g. from the workload's layer count).
    pub fn reserve(&mut self, tasks: usize, deps: usize) {
        self.tasks.reserve(tasks);
        self.dep_pool.reserve(deps);
        self.durs.reserve(tasks);
        self.ress.reserve(tasks);
    }
}

/// A registered resource.
#[derive(Debug, Clone)]
struct Resource {
    policy: Policy,
    /// Pending ready tasks (ordered per policy). FIFO pops advance
    /// `head` instead of shifting the vector (O(1) amortized); the dead
    /// prefix is compacted once it dominates.
    backlog: Vec<TaskId>,
    /// First live element of `backlog` (FIFO cursor).
    head: usize,
    /// Currently running task, if any.
    running: Option<TaskId>,
    /// Accumulated busy time.
    busy_ns: u64,
    /// Accumulated queueing delay (start − ready) over dispatched tasks.
    queue_ns: u64,
}

impl Resource {
    fn backlog_is_empty(&self) -> bool {
        self.head >= self.backlog.len()
    }

    fn push(&mut self, id: TaskId) {
        self.backlog.push(id);
    }

    // lint: hot-path
    fn pop(&mut self) -> TaskId {
        match self.policy {
            Policy::Fifo => {
                let id = self.backlog[self.head];
                self.head += 1;
                // Compact when the dead prefix dominates the live tail.
                if self.head > 32 && self.head * 2 > self.backlog.len() {
                    self.backlog.drain(..self.head);
                    self.head = 0;
                }
                id
            }
            // lint: allow(no-panic) — dispatch checks backlog_is_empty() first
            Policy::Lifo => self.backlog.pop().expect("pop on empty backlog"),
        }
    }
}

/// Execution record for one task.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Span {
    /// Time the task became ready (all deps finished).
    pub ready_ns: u64,
    /// Time the resource was acquired.
    pub start_ns: u64,
    /// Completion time.
    pub finish_ns: u64,
}

/// Simulation output: per-task spans and per-resource totals. Reusable —
/// [`Engine::run_into`] clears and refills it in place.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    /// Span per task id.
    pub spans: Vec<Span>,
    /// Busy nanoseconds per resource id.
    pub busy_ns: Vec<u64>,
    /// Total queueing delay (start − ready) per resource id, accumulated
    /// during the run (no post-hoc O(tasks) scan).
    pub queueing: Vec<u64>,
    /// Makespan: completion time of the last task.
    pub makespan_ns: u64,
    /// Number of events processed (== number of tasks).
    pub events: usize,
}

impl Schedule {
    /// Total queueing delay (start − ready) across tasks on a resource.
    pub fn queueing_ns(&self, resource: ResourceId) -> u64 {
        self.queueing.get(resource).copied().unwrap_or(0)
    }
}

/// Reusable O(tasks) run-loop buffers plus the [`Schedule`] they fill.
/// Carried across [`Engine::run_into`] calls so steady-state runs do not
/// touch the allocator.
#[derive(Debug, Default)]
pub struct RunScratch {
    /// The schedule produced by the latest run.
    pub schedule: Schedule,
    pending: Vec<usize>,
    dep_off: Vec<usize>,
    dep_cursor: Vec<usize>,
    dependents: Vec<TaskId>,
    /// Completion events, ordered `(finish_time, seq, task)` — the
    /// calendar queue pops byte-identically to the old binary heap.
    queue: CalendarQueue,
    /// The current completion wave: every task finishing at the popped
    /// timestamp, in `seq` order.
    batch: Vec<TaskId>,
    /// Per-event dirty-resource set (the completing resource plus each
    /// newly-woken dependent's resource, first-wake order, deduplicated
    /// via `dirty_mark`).
    dirty: Vec<ResourceId>,
    /// `dirty_mark[rid] == epoch` ⇔ `rid` is already in `dirty` for the
    /// current event (O(1) dedup without clearing a bitmap per event).
    dirty_mark: Vec<u64>,
    epoch: u64,
}

/// The engine: resources + run loop. Resource slots (and their backlog
/// buffers) are reused across [`Engine::reset`] cycles.
#[derive(Debug, Default)]
pub struct Engine {
    resources: Vec<Resource>,
    live: usize,
}

impl Engine {
    /// Engine with no resources.
    pub fn new() -> Engine {
        Engine::default()
    }

    /// Forget all registered resources but keep their slots (and backlog
    /// capacity) for reuse by subsequent [`Engine::add_resource`] calls.
    pub fn reset(&mut self) {
        self.live = 0;
    }

    /// Register a resource; returns its id. After a [`Engine::reset`],
    /// slots left over from the previous scenario are reused in place.
    pub fn add_resource(&mut self, policy: Policy) -> ResourceId {
        let id = self.live;
        if let Some(r) = self.resources.get_mut(id) {
            r.policy = policy;
            r.backlog.clear();
            r.head = 0;
            r.running = None;
            r.busy_ns = 0;
            r.queue_ns = 0;
        } else {
            self.resources.push(Resource {
                policy,
                backlog: Vec::new(),
                head: 0,
                running: None,
                busy_ns: 0,
                queue_ns: 0,
            });
        }
        self.live += 1;
        id
    }

    /// Number of live resources.
    pub fn num_resources(&self) -> usize {
        self.live
    }

    /// Execute the graph to completion, allocating fresh buffers.
    /// Convenience wrapper over [`Engine::run_into`] for one-shot runs.
    pub fn run(&mut self, graph: &TaskGraph) -> Result<Schedule> {
        let mut scratch = RunScratch::default();
        self.run_into(graph, &mut scratch)?;
        Ok(scratch.schedule)
    }

    /// Execute the graph to completion into `scratch` (the result lands
    /// in `scratch.schedule`). Fails on dangling resource ids or if the
    /// graph deadlocks (dependency cycle). Steady-state reuse of the same
    /// scratch performs no heap allocation.
    // lint: hot-path
    pub fn run_into(&mut self, graph: &TaskGraph, scratch: &mut RunScratch) -> Result<()> {
        let n = graph.len();
        let live = self.live;
        for (id, t) in graph.tasks.iter().enumerate() {
            if t.resource >= live {
                // lint: allow(no-alloc) — cold error path
                return Err(Error::sim(format!(
                    "task '{}' references unknown resource {}",
                    t.tag, t.resource
                )));
            }
            for &d in graph.deps_of(id) {
                if d >= n {
                    // lint: allow(no-alloc) — cold error path
                    return Err(Error::sim(format!(
                        "task '{}' depends on unknown task {d}",
                        t.tag
                    )));
                }
            }
        }

        let sc = scratch;

        // Dependency bookkeeping: pending counts + dependents in CSR form
        // (offsets into one shared buffer — no per-task Vec).
        sc.pending.clear();
        sc.pending.extend(graph.tasks.iter().map(|t| t.deps_len as usize));
        sc.dep_off.clear();
        sc.dep_off.resize(n + 1, 0);
        for &d in &graph.dep_pool {
            sc.dep_off[d + 1] += 1;
        }
        for i in 0..n {
            sc.dep_off[i + 1] += sc.dep_off[i];
        }
        sc.dep_cursor.clear();
        sc.dep_cursor.extend_from_slice(&sc.dep_off[..n]);
        sc.dependents.clear();
        sc.dependents.resize(graph.dep_pool.len(), 0);
        for id in 0..n {
            for &d in graph.deps_of(id) {
                sc.dependents[sc.dep_cursor[d]] = id;
                sc.dep_cursor[d] += 1;
            }
        }

        let spans = &mut sc.schedule.spans;
        spans.clear();
        spans.resize(n, Span::default());
        // Completion events: (finish time, seq, task). seq keeps
        // deterministic FIFO order among equal-time completions.
        sc.queue.clear();
        sc.dirty_mark.clear();
        sc.dirty_mark.resize(live, 0);
        sc.epoch = 0;
        let mut seq: u64 = 0;

        for r in &mut self.resources[..live] {
            r.backlog.clear();
            r.head = 0;
            r.running = None;
            r.busy_ns = 0;
            r.queue_ns = 0;
        }

        // SoA slabs: the only per-task state the event loop touches.
        let dur_slab = graph.durations();
        let res_slab = graph.resources();

        let mut now: u64 = 0;
        let mut completed = 0usize;

        // Seed: tasks with no deps are ready at t=0.
        for id in 0..n {
            if sc.pending[id] == 0 {
                self.resources[res_slab[id]].push(id);
            }
        }
        for res in &mut self.resources[..live] {
            Self::dispatch(res, dur_slab, spans, 0, &mut sc.queue, &mut seq);
        }

        // Batched event loop: drain the whole completion wave at the
        // minimum timestamp in one queue operation, then process it
        // event by event. Dispatch stays *incremental* within the wave
        // (completing resource first, then newly-woken dependents'
        // resources in first-wake order) — LIFO backlogs and the
        // seq-based pop tiebreak both depend on that order, so a
        // deferred per-wave dispatch pass would change schedules. The
        // per-event dedup is exact: dispatching an already-busy
        // resource was always a no-op.
        while let Some(t) = sc.queue.pop_batch_into(&mut sc.batch) {
            now = t;
            for &id in &sc.batch {
                completed += 1;
                spans[id].finish_ns = now;
                let rid = res_slab[id];
                self.resources[rid].running = None;

                sc.epoch += 1;
                sc.dirty.clear();
                sc.dirty.push(rid);
                sc.dirty_mark[rid] = sc.epoch;

                // Wake dependents, collecting their resources once each.
                let (lo, hi) = (sc.dep_off[id], sc.dep_off[id + 1]);
                for &dep in &sc.dependents[lo..hi] {
                    sc.pending[dep] -= 1;
                    if sc.pending[dep] == 0 {
                        spans[dep].ready_ns = now;
                        let drid = res_slab[dep];
                        self.resources[drid].push(dep);
                        if sc.dirty_mark[drid] != sc.epoch {
                            sc.dirty_mark[drid] = sc.epoch;
                            sc.dirty.push(drid);
                        }
                    }
                }
                for &wake in &sc.dirty {
                    let res = &mut self.resources[wake];
                    Self::dispatch(res, dur_slab, spans, now, &mut sc.queue, &mut seq);
                }
            }
        }

        if completed != n {
            // lint: allow(no-alloc) — cold error path
            return Err(Error::sim(format!(
                "deadlock: {completed}/{n} tasks completed (dependency cycle?)"
            )));
        }

        sc.schedule.makespan_ns = now;
        sc.schedule.events = completed;
        sc.schedule.busy_ns.clear();
        sc.schedule.busy_ns.extend(self.resources[..live].iter().map(|r| r.busy_ns));
        sc.schedule.queueing.clear();
        sc.schedule.queueing.extend(self.resources[..live].iter().map(|r| r.queue_ns));
        Ok(())
    }

    /// If `res` is idle and has backlog, start its next task per policy.
    // lint: hot-path
    fn dispatch(
        res: &mut Resource,
        durs: &[u64],
        spans: &mut [Span],
        now: u64,
        queue: &mut CalendarQueue,
        seq: &mut u64,
    ) {
        if res.running.is_some() || res.backlog_is_empty() {
            return;
        }
        let id = res.pop();
        let dur = durs[id];
        spans[id].start_ns = now;
        res.queue_ns += now - spans[id].ready_ns;
        res.running = Some(id);
        res.busy_ns += dur;
        queue.push(now + dur, *seq, id);
        *seq += 1;
    }
}

/// Structural verifier for a built [`TaskGraph`]: the data-level twin of
/// the `modtrans-lint` source pass (see *Static guarantees* in the crate
/// docs). Checks, in order:
///
/// 1. **Slab sync** — the SoA duration/resource slabs mirror the task
///    records exactly (same length, same values).
/// 2. **CSR well-formedness** — every task's dependency range is
///    contiguous in the shared pool (no gaps, no overlap, no orphaned
///    tail entries) and in bounds.
/// 3. **Id ranges** — every resource id is `< num_resources` and every
///    dependency id names an existing task.
/// 4. **Acyclicity** — Kahn's algorithm over the dependency relation; a
///    self-dependency counts as a cycle.
/// 5. **Creation order** — dependencies point strictly backward, the
///    invariant every builder in [`crate::sim::training`] maintains and
///    the event loop's seeding logic relies on.
///
/// This is a cold-path diagnostic (it allocates freely); the engine's own
/// `run_into` keeps only the cheap range checks on its hot path.
pub fn verify_graph(g: &TaskGraph, num_resources: usize) -> Result<()> {
    let n = g.tasks.len();
    if g.durs.len() != n || g.ress.len() != n {
        return Err(Error::verify(format!(
            "task graph slabs out of sync: {n} tasks, {} duration slots, {} resource slots",
            g.durs.len(),
            g.ress.len()
        )));
    }
    let pool = g.dep_pool.len();
    let mut cursor = 0usize;
    for (id, t) in g.tasks.iter().enumerate() {
        let start = t.deps_start as usize;
        let len = t.deps_len as usize;
        let end = match start.checked_add(len) {
            Some(end) if start == cursor && end <= pool => end,
            _ => {
                return Err(Error::verify(format!(
                    "task {id}: dep range {start}+{len} is not contiguous in the \
                     {pool}-entry pool (cursor at {cursor})"
                )));
            }
        };
        cursor = end;
        if g.durs[id] != t.duration_ns || g.ress[id] != t.resource {
            return Err(Error::verify(format!(
                "task {id}: SoA slab diverges from the task record"
            )));
        }
        if t.resource >= num_resources {
            return Err(Error::verify(format!(
                "task {id}: resource id {} out of range ({num_resources} registered)",
                t.resource
            )));
        }
        for &d in &g.dep_pool[start..end] {
            if d >= n {
                return Err(Error::verify(format!(
                    "task {id}: dependency {d} out of range ({n} tasks)"
                )));
            }
        }
    }
    if cursor != pool {
        return Err(Error::verify(format!(
            "{} orphaned dep-pool entries after the last task",
            pool - cursor
        )));
    }

    // Kahn's algorithm: peel zero-pending tasks until none remain. Runs
    // before the creation-order check so a genuine cycle reports as a
    // cycle, not as its incidental forward edge.
    let mut pending: Vec<usize> = g.tasks.iter().map(|t| t.deps_len as usize).collect();
    let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for id in 0..n {
        for &d in g.deps_of(id) {
            dependents[d].push(id);
        }
    }
    let mut ready: Vec<TaskId> = (0..n).filter(|&id| pending[id] == 0).collect();
    let mut processed = 0usize;
    while let Some(id) = ready.pop() {
        processed += 1;
        for &dep in &dependents[id] {
            pending[dep] -= 1;
            if pending[dep] == 0 {
                ready.push(dep);
            }
        }
    }
    if processed != n {
        let stuck = (0..n).find(|&id| pending[id] != 0).unwrap_or(0);
        return Err(Error::verify(format!(
            "dependency cycle involving task {stuck} ({processed}/{n} tasks orderable)"
        )));
    }

    for id in 0..n {
        for &d in g.deps_of(id) {
            if d >= id {
                return Err(Error::verify(format!(
                    "task {id}: forward dependency on task {d} \
                     (builders emit creation-ordered graphs)"
                )));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::tag::TagPhase;

    fn tag(i: usize) -> TaskTag {
        TaskTag::adhoc(i)
    }

    #[test]
    fn serial_chain_sums_durations() {
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let cpu = eng.add_resource(Policy::Fifo);
        let a = g.add(tag(0), cpu, 10, &[]);
        let b = g.add(tag(1), cpu, 20, &[a]);
        let c = g.add(tag(2), cpu, 30, &[b]);
        let s = eng.run(&g).unwrap();
        assert_eq!(s.makespan_ns, 60);
        assert_eq!(s.spans[c].start_ns, 30);
        assert_eq!(s.busy_ns[cpu], 60);
    }

    #[test]
    fn independent_tasks_on_distinct_resources_overlap() {
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let r0 = eng.add_resource(Policy::Fifo);
        let r1 = eng.add_resource(Policy::Fifo);
        g.add(tag(0), r0, 100, &[]);
        g.add(tag(1), r1, 70, &[]);
        let s = eng.run(&g).unwrap();
        assert_eq!(s.makespan_ns, 100);
    }

    #[test]
    fn contention_serializes_and_queueing_is_precomputed() {
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let r = eng.add_resource(Policy::Fifo);
        g.add(tag(0), r, 100, &[]);
        g.add(tag(1), r, 100, &[]);
        let s = eng.run(&g).unwrap();
        assert_eq!(s.makespan_ns, 200);
        // Second task waits 100 ns; totals are accumulated during the
        // run, not recomputed by scanning tasks.
        assert_eq!(s.queueing_ns(r), 100);
        assert_eq!(s.queueing, vec![100]);
    }

    #[test]
    fn fifo_vs_lifo_ordering() {
        // Three comm tasks become ready in order a, b, c while the
        // resource is busy with "hold". FIFO runs a first; LIFO runs c.
        for (policy, pick_expected) in [(Policy::Fifo, 0usize), (Policy::Lifo, 2usize)] {
            let mut g = TaskGraph::new();
            let mut eng = Engine::new();
            let cpu = eng.add_resource(Policy::Fifo);
            let net = eng.add_resource(policy);
            let hold = g.add(tag(0), net, 100, &[]);
            // Ready at staggered times via cpu chain.
            let t1 = g.add(tag(1), cpu, 10, &[]);
            let t2 = g.add(tag(2), cpu, 10, &[t1]);
            let t3 = g.add(tag(3), cpu, 10, &[t2]);
            let a = g.add(tag(4), net, 50, &[t1]);
            let b = g.add(tag(5), net, 50, &[t2]);
            let c = g.add(tag(6), net, 50, &[t3]);
            let s = eng.run(&g).unwrap();
            let _ = hold;
            // First net task to start after hold finishes at t=100:
            let abc = [a, b, c];
            let first = abc.into_iter().min_by_key(|&id| s.spans[id].start_ns).unwrap();
            assert_eq!(first, abc[pick_expected], "{policy:?}");
        }
    }

    #[test]
    fn diamond_dependencies() {
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let r0 = eng.add_resource(Policy::Fifo);
        let r1 = eng.add_resource(Policy::Fifo);
        let a = g.add(tag(0), r0, 10, &[]);
        let b = g.add(tag(1), r0, 20, &[a]);
        let c = g.add(tag(2), r1, 5, &[a]);
        let d = g.add(tag(3), r0, 1, &[b, c]);
        let s = eng.run(&g).unwrap();
        assert_eq!(s.spans[d].ready_ns, 30); // max(b=30, c=15)
        assert_eq!(s.makespan_ns, 31);
        assert_eq!(g.deps_of(d), &[b, c]);
    }

    #[test]
    fn cycle_is_detected_not_hung() {
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let r = eng.add_resource(Policy::Fifo);
        // Manual cycle: a → b → a. Construct via deps on future ids.
        let a = g.add(tag(0), r, 1, &[1]);
        let _b = g.add(tag(1), r, 1, &[a]);
        assert!(eng.run(&g).is_err());
    }

    #[test]
    fn bad_resource_id_is_error() {
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let _ = eng.add_resource(Policy::Fifo);
        g.add(tag(0), 5, 1, &[]);
        assert!(eng.run(&g).is_err());
    }

    #[test]
    fn zero_duration_tasks_complete() {
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let r = eng.add_resource(Policy::Fifo);
        let a = g.add(tag(0), r, 0, &[]);
        let b = g.add(tag(1), r, 0, &[a]);
        let s = eng.run(&g).unwrap();
        assert_eq!(s.makespan_ns, 0);
        assert_eq!(s.spans[b].finish_ns, 0);
    }

    #[test]
    fn same_time_wave_keeps_incremental_lifo_dispatch() {
        // Eight producers on distinct resources all finish at t=100 (one
        // completion wave) and each wakes a dependent on one shared LIFO
        // resource. Incremental dispatch within the wave means the
        // *first* wake (d0, from the first-popped completion) starts
        // immediately — it is alone in the backlog when its producer's
        // event is processed — and the remaining deps then run in LIFO
        // order d7, d6, ..., d1. A deferred per-wave dispatch pass would
        // see all eight queued and start d7 first instead.
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        let shared = eng.add_resource(Policy::Lifo);
        let mut deps = Vec::new();
        for i in 0..8usize {
            let r = eng.add_resource(Policy::Fifo);
            let p = g.add(tag(i), r, 100, &[]);
            deps.push(g.add(tag(100 + i), shared, 10, &[p]));
        }
        let s = eng.run(&g).unwrap();
        assert_eq!(s.spans[deps[0]].start_ns, 100);
        for (k, i) in (1..8).rev().enumerate() {
            assert_eq!(s.spans[deps[i]].start_ns, 110 + 10 * k as u64, "dep {i}");
        }
        assert_eq!(s.makespan_ns, 180);
    }

    #[test]
    fn soa_slabs_mirror_tasks_across_clear() {
        let mut g = TaskGraph::new();
        g.add(tag(0), 3, 17, &[]);
        g.add(tag(1), 1, 5, &[0]);
        assert_eq!(g.durations(), &[17, 5]);
        assert_eq!(g.resources(), &[3, 1]);
        g.clear();
        assert!(g.durations().is_empty() && g.resources().is_empty());
        g.add(tag(2), 0, 9, &[]);
        assert_eq!(g.durations(), &[9]);
        assert_eq!(g.resources(), &[0]);
    }

    #[test]
    fn determinism_same_inputs_same_schedule() {
        let build_and_run = || {
            let mut g = TaskGraph::new();
            let mut eng = Engine::new();
            let cpu = eng.add_resource(Policy::Fifo);
            let net = eng.add_resource(Policy::Lifo);
            let mut prev: Option<TaskId> = None;
            for i in 0..50u64 {
                let deps: Vec<TaskId> = prev.into_iter().collect();
                let c = g.add(TaskTag::flat(0, TagPhase::Fwd, i as usize), cpu, 7 + (i % 5), &deps);
                g.add(TaskTag::flat(0, TagPhase::Wg, i as usize), net, 13 + (i % 3), &[c]);
                prev = Some(c);
            }
            let s = eng.run(&g).unwrap();
            (s.makespan_ns, s.spans.iter().map(|x| x.start_ns).collect::<Vec<_>>())
        };
        assert_eq!(build_and_run(), build_and_run());
    }

    #[test]
    fn scratch_reuse_reproduces_one_shot_run() {
        // run_into with a warm scratch must match Engine::run exactly.
        let build = |g: &mut TaskGraph, eng: &mut Engine| {
            g.clear();
            eng.reset();
            let cpu = eng.add_resource(Policy::Fifo);
            let net = eng.add_resource(Policy::Fifo);
            let mut prev: Option<TaskId> = None;
            for i in 0..40u64 {
                let deps: Vec<TaskId> = prev.into_iter().collect();
                let c = g.add(tag(i as usize), cpu, 5 + (i % 7), &deps);
                g.add(tag(100 + i as usize), net, 11 + (i % 4), &[c]);
                prev = Some(c);
            }
        };
        let mut g = TaskGraph::new();
        let mut eng = Engine::new();
        build(&mut g, &mut eng);
        let one_shot = eng.run(&g).unwrap();

        let mut scratch = RunScratch::default();
        for _ in 0..3 {
            build(&mut g, &mut eng);
            eng.run_into(&g, &mut scratch).unwrap();
            assert_eq!(scratch.schedule.makespan_ns, one_shot.makespan_ns);
            assert_eq!(scratch.schedule.spans, one_shot.spans);
            assert_eq!(scratch.schedule.busy_ns, one_shot.busy_ns);
            assert_eq!(scratch.schedule.queueing, one_shot.queueing);
        }
    }

    #[test]
    fn engine_reset_reuses_slots_with_fresh_state() {
        let mut eng = Engine::new();
        let r0 = eng.add_resource(Policy::Fifo);
        let mut g = TaskGraph::new();
        g.add(tag(0), r0, 50, &[]);
        let s = eng.run(&g).unwrap();
        assert_eq!(s.busy_ns[r0], 50);

        eng.reset();
        assert_eq!(eng.num_resources(), 0);
        let r0 = eng.add_resource(Policy::Lifo);
        assert_eq!(r0, 0);
        assert_eq!(eng.num_resources(), 1);
        g.clear();
        g.add(tag(0), r0, 7, &[]);
        let s = eng.run(&g).unwrap();
        assert_eq!(s.busy_ns, vec![7]);
        // A task referencing the now-dead second slot must error.
        g.clear();
        g.add(tag(0), 1, 1, &[]);
        assert!(eng.run(&g).is_err());
    }

    #[test]
    fn graph_clear_keeps_capacity_and_resets_ids() {
        let mut g = TaskGraph::new();
        let a = g.add(tag(0), 0, 1, &[]);
        g.add(tag(1), 0, 1, &[a]);
        assert_eq!(g.len(), 2);
        g.clear();
        assert!(g.is_empty());
        let b = g.add(tag(0), 0, 1, &[]);
        assert_eq!(b, 0);
        assert!(g.deps_of(b).is_empty());
    }

    #[test]
    fn verify_graph_accepts_well_formed_graphs() {
        let mut g = TaskGraph::new();
        let a = g.add(tag(0), 0, 10, &[]);
        let b = g.add(tag(1), 1, 20, &[a]);
        g.add(tag(2), 0, 1, &[a, b]);
        assert!(verify_graph(&g, 2).is_ok());
        assert_eq!(g.num_deps(), 3);
        g.clear();
        assert!(verify_graph(&g, 0).is_ok());
    }

    #[test]
    fn verify_graph_rejects_out_of_range_ids() {
        let mut g = TaskGraph::new();
        g.add(tag(0), 5, 1, &[]);
        let err = verify_graph(&g, 1).unwrap_err().to_string();
        assert!(err.contains("resource id 5 out of range"), "{err}");

        let mut g = TaskGraph::new();
        g.add(tag(0), 0, 1, &[10]);
        let err = verify_graph(&g, 1).unwrap_err().to_string();
        assert!(err.contains("dependency 10 out of range"), "{err}");
    }

    #[test]
    fn verify_graph_rejects_cycles_and_forward_deps() {
        // a → b → a: a genuine cycle reports as a cycle...
        let mut g = TaskGraph::new();
        let a = g.add(tag(0), 0, 1, &[1]);
        g.add(tag(1), 0, 1, &[a]);
        let err = verify_graph(&g, 1).unwrap_err().to_string();
        assert!(err.contains("dependency cycle"), "{err}");

        // ...a self-dependency counts as one...
        let mut g = TaskGraph::new();
        g.add(tag(0), 0, 1, &[0]);
        let err = verify_graph(&g, 1).unwrap_err().to_string();
        assert!(err.contains("dependency cycle"), "{err}");

        // ...and an acyclic forward edge reports as an ordering defect.
        let mut g = TaskGraph::new();
        g.add(tag(0), 0, 1, &[1]);
        g.add(tag(1), 0, 1, &[]);
        let err = verify_graph(&g, 1).unwrap_err().to_string();
        assert!(err.contains("forward dependency on task 1"), "{err}");
    }
}
