//! System layer: maps workload collectives onto network dimensions and
//! builds their task sequences (the ASTRA-sim "system layer" that provides
//! topology-aware collectives, generates traffic for the network layer,
//! and schedules collectives across links).
//!
//! * Activations (fwd / input-grad collectives) run on the innermost
//!   (scale-up) dimension — model-parallel groups live inside a node.
//! * Weight-gradient all-reduces run **hierarchically**: reduce-scatter on
//!   the scale-up dimension, all-reduce of the shard on the scale-out
//!   dimension(s), all-gather back — each leg occupying its dimension's
//!   resource, so concurrent collectives contend per fabric exactly like
//!   ASTRA-sim's queue model.
//!
//! The expansion is allocation-free per collective: tasks are identified
//! by [`TaskTag`]s (no label strings) and the per-chunk tails live in a
//! fixed stack buffer.

use super::collectives::{collective_ns, ChunkCfg};
use super::engine::{Policy, ResourceId, TaskGraph, TaskId};
use super::network::Network;
use super::tag::{TagComm, TaskTag};
use crate::workload::CommType;

/// Upper bound on chunk pipelining; keeps the hierarchical expansion's
/// per-chunk tail list in a fixed stack buffer (no heap allocation in the
/// hot loop). Configured chunk counts are clamped to this.
pub const MAX_CHUNKS: usize = 64;

/// System-layer configuration.
#[derive(Debug, Clone, Copy)]
pub struct SystemConfig {
    /// Queue discipline on each network dimension (paper §2.2: FIFO/LIFO).
    pub scheduling: Policy,
    /// Chunk pipelining for collectives.
    pub chunks: ChunkCfg,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig { scheduling: Policy::Fifo, chunks: ChunkCfg::default() }
    }
}

/// Routes collectives to network-dimension resources.
pub struct CommRouter<'a> {
    /// The network description.
    pub net: &'a Network,
    /// Engine resource id per network dimension (index-aligned with
    /// `net.dims`; borrowed so sweep workers can reuse one buffer).
    pub dim_resources: &'a [ResourceId],
    /// Chunking config.
    pub chunks: ChunkCfg,
}

impl<'a> CommRouter<'a> {
    /// Create a router (dimension resources must be pre-registered, one
    /// per `net.dims` entry, in order).
    pub fn new(net: &'a Network, dim_resources: &'a [ResourceId], chunks: ChunkCfg) -> Self {
        assert_eq!(net.dims.len(), dim_resources.len());
        CommRouter { net, dim_resources, chunks }
    }

    /// Append the task sequence realizing `comm` over `bytes`, starting
    /// after `deps`. Returns the id of the final task (or `None` for
    /// `CommType::None` / zero bytes — callers keep their deps).
    ///
    /// `base` is the issuing task's tag; every emitted task carries it
    /// with a [`TagComm`] annotation. `prefer_scale_up` pins
    /// single-dimension collectives (activations) to dim 0; otherwise
    /// weight-grad traffic uses the hierarchical all-dim route.
    // lint: hot-path
    pub fn issue(
        &self,
        g: &mut TaskGraph,
        base: TaskTag,
        comm: CommType,
        bytes: u64,
        deps: &[TaskId],
        prefer_scale_up: bool,
    ) -> Option<TaskId> {
        if comm == CommType::None || bytes == 0 {
            return None;
        }
        let dims = &self.net.dims;
        if dims.len() == 1 || prefer_scale_up {
            let d = &dims[0];
            let ns = collective_ns(comm, bytes, d.algo, d);
            let tag = base.with_comm(TagComm::Coll { kind: comm, dim: 0 });
            return Some(g.add(tag, self.dim_resources[0], ns, deps));
        }
        match comm {
            CommType::AllReduce => {
                // Hierarchical: RS(dim0) → AR(dim1.. on shard) → AG(dim0),
                // split into `chunks` sub-collectives whose legs pipeline
                // across the dimension resources (chunk k's scale-out
                // all-reduce overlaps chunk k+1's reduce-scatter).
                let c = self.chunks.chunks.clamp(1, MAX_CHUNKS);
                let chunk_bytes = (bytes / c as u64).max(1);
                let d0 = &dims[0];
                let mut chunk_tails: [TaskId; MAX_CHUNKS] = [0; MAX_CHUNKS];
                for (k, tail) in chunk_tails.iter_mut().enumerate().take(c) {
                    let rs = collective_ns(CommType::ReduceScatter, chunk_bytes, d0.algo, d0);
                    let rs_tag = base.with_comm(TagComm::Rs { chunk: k as u8 });
                    let mut last = g.add(rs_tag, self.dim_resources[0], rs, deps);
                    let mut shard = chunk_bytes / d0.npus.max(1) as u64;
                    for (i, d) in dims.iter().enumerate().skip(1) {
                        let ar = collective_ns(CommType::AllReduce, shard, d.algo, d);
                        let ar_tag = base.with_comm(TagComm::Ar { chunk: k as u8, dim: i as u8 });
                        last = g.add(ar_tag, self.dim_resources[i], ar, &[last]);
                        shard = (shard / d.npus.max(1) as u64).max(1);
                    }
                    let ag = collective_ns(CommType::AllGather, chunk_bytes, d0.algo, d0);
                    let ag_tag = base.with_comm(TagComm::Ag { chunk: k as u8 });
                    *tail = g.add(ag_tag, self.dim_resources[0], ag, &[last]);
                }
                if c == 1 {
                    Some(chunk_tails[0])
                } else {
                    // Zero-duration join so dependents wait for all chunks.
                    let join = base.with_comm(TagComm::Join);
                    Some(g.add(join, self.dim_resources[0], 0, &chunk_tails[..c]))
                }
            }
            // Gather/scatter/all-to-all for activations stay on the
            // scale-up dimension by construction (prefer_scale_up), but a
            // scale-out request falls through to the outermost dimension.
            other => {
                let i = dims.len() - 1;
                let ns = collective_ns(other, bytes, dims[i].algo, &dims[i]);
                let tag = base.with_comm(TagComm::Coll { kind: other, dim: i as u8 });
                Some(g.add(tag, self.dim_resources[i], ns, deps))
            }
        }
    }

    /// Point-to-point stage-boundary transfer on the outermost dimension.
    // lint: hot-path
    pub fn p2p(
        &self,
        g: &mut TaskGraph,
        base: TaskTag,
        bytes: u64,
        deps: &[TaskId],
    ) -> Option<TaskId> {
        if bytes == 0 {
            return None;
        }
        let i = self.net.dims.len() - 1;
        let ns = super::collectives::p2p_ns(bytes, &self.net.dims[i]);
        let tag = base.with_comm(TagComm::P2p { dim: i as u8 });
        Some(g.add(tag, self.dim_resources[i], ns, deps))
    }
}

#[cfg(test)]
mod tests {
    use super::super::engine::Engine;
    use super::super::network::{Network, TopologyKind};
    use super::*;

    fn setup(net: &Network) -> (Engine, Vec<ResourceId>) {
        let mut eng = Engine::new();
        let rs: Vec<ResourceId> = net.dims.iter().map(|_| eng.add_resource(Policy::Fifo)).collect();
        (eng, rs)
    }

    fn base() -> TaskTag {
        TaskTag::adhoc(0)
    }

    #[test]
    fn single_dim_allreduce_is_one_task() {
        let net = Network::single(TopologyKind::Ring, 8, 100.0, 500.0);
        let (mut eng, rs) = setup(&net);
        let router = CommRouter::new(&net, &rs, ChunkCfg::default());
        let mut g = TaskGraph::new();
        let t = router.issue(&mut g, base(), CommType::AllReduce, 1 << 20, &[], false);
        assert!(t.is_some());
        assert_eq!(g.len(), 1);
        let s = eng.run(&g).unwrap();
        assert!(s.makespan_ns > 0);
    }

    #[test]
    fn two_tier_allreduce_is_hierarchical() {
        let net = Network::two_tier(8, 4);
        let (mut eng, rs) = setup(&net);
        let router = CommRouter::new(&net, &rs, ChunkCfg { chunks: 4 });
        let mut g = TaskGraph::new();
        router.issue(&mut g, base(), CommType::AllReduce, 64 << 20, &[], false);
        // 4 chunks × (RS + AR + AG) + join.
        assert_eq!(g.len(), 4 * 3 + 1);
        let s = eng.run(&g).unwrap();
        // Both dims saw traffic.
        assert!(s.busy_ns[0] > 0 && s.busy_ns[1] > 0);
        // Pipelined: makespan strictly less than the serialized sum of all
        // leg durations, but at least the busiest dimension.
        assert!(s.makespan_ns < s.busy_ns[0] + s.busy_ns[1]);
        assert!(s.makespan_ns >= s.busy_ns[0].max(s.busy_ns[1]));
    }

    #[test]
    fn chunk_pipelining_reduces_hierarchical_makespan() {
        let net = Network::two_tier(8, 4);
        let run = |chunks: usize| {
            let (mut eng, rs) = setup(&net);
            let router = CommRouter::new(&net, &rs, ChunkCfg { chunks });
            let mut g = TaskGraph::new();
            router.issue(&mut g, base(), CommType::AllReduce, 256 << 20, &[], false);
            eng.run(&g).unwrap().makespan_ns
        };
        let t1 = run(1);
        let t8 = run(8);
        assert!(t8 < t1, "chunked hierarchical all-reduce should pipeline: {t8} vs {t1}");
    }

    #[test]
    fn chunk_count_is_clamped_to_stack_buffer() {
        let net = Network::two_tier(8, 4);
        let (mut eng, rs) = setup(&net);
        let router = CommRouter::new(&net, &rs, ChunkCfg { chunks: 10_000 });
        let mut g = TaskGraph::new();
        router.issue(&mut g, base(), CommType::AllReduce, 64 << 20, &[], false);
        assert_eq!(g.len(), MAX_CHUNKS * 3 + 1);
        assert!(eng.run(&g).is_ok());
    }

    #[test]
    fn activations_pin_to_scale_up() {
        let net = Network::two_tier(8, 4);
        let (mut eng, rs) = setup(&net);
        let router = CommRouter::new(&net, &rs, ChunkCfg::default());
        let mut g = TaskGraph::new();
        router.issue(&mut g, base(), CommType::AllGather, 1 << 20, &[], true);
        assert_eq!(g.len(), 1);
        let s = eng.run(&g).unwrap();
        assert!(s.busy_ns[0] > 0);
        assert_eq!(s.busy_ns[1], 0);
    }

    #[test]
    fn none_and_zero_bytes_produce_no_tasks() {
        let net = Network::two_tier(8, 4);
        let (_, rs) = setup(&net);
        let router = CommRouter::new(&net, &rs, ChunkCfg::default());
        let mut g = TaskGraph::new();
        assert!(router.issue(&mut g, base(), CommType::None, 100, &[], false).is_none());
        assert!(router.issue(&mut g, base(), CommType::AllReduce, 0, &[], false).is_none());
        assert!(router.p2p(&mut g, base(), 0, &[]).is_none());
        assert!(g.is_empty());
    }

    #[test]
    fn p2p_uses_outermost_dim() {
        let net = Network::two_tier(8, 4);
        let (mut eng, rs) = setup(&net);
        let router = CommRouter::new(&net, &rs, ChunkCfg::default());
        let mut g = TaskGraph::new();
        router.p2p(&mut g, base(), 1 << 20, &[]);
        let s = eng.run(&g).unwrap();
        assert_eq!(s.busy_ns[0], 0);
        assert!(s.busy_ns[1] > 0);
    }
}
