//! Workload layer: drives the training loop over the task-graph engine
//! (the ASTRA-sim layer that "runs the training loop algorithms for the
//! specified deep learning models and generates the sets of data to be
//! communicated during each iteration").
//!
//! Two schedule builders:
//!
//! * [`build_iteration_graph`] — DATA / MODEL / HYBRID strategies. All
//!   NPUs execute symmetric timelines under the analytical network model,
//!   so one representative per-NPU timeline is simulated against the
//!   shared network-dimension resources: forward chain, backward chain
//!   (weight-grad collectives issued asynchronously and overlapped,
//!   input-grad collectives blocking the next layer — exactly the
//!   dependency structure ASTRA-sim's workload layer creates), optimizer
//!   updates gating the next iteration's forward.
//! * [`build_pipeline_graph`] — GPipe-style microbatch pipeline across
//!   stages with point-to-point boundary transfers.
//!
//! Both builders are allocation-free per task: tasks carry [`TaskTag`]s
//! (no label strings), and [`simulate_with`] threads a reusable
//! [`SimScratch`] arena through graph build and execution so steady-state
//! reruns (the sweep worker loop) do not touch the allocator.

use super::engine::{Engine, Policy, ResourceId, RunScratch, Schedule, TaskGraph, TaskId};
use super::network::Network;
use super::system::{CommRouter, SystemConfig, MAX_CHUNKS};
use super::tag::{TagPhase, TaskTag};
use crate::error::{Error, Result};
use crate::workload::{CommType, Parallelism, Workload};

/// Pipeline schedule family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineSchedule {
    /// GPipe: all forwards, flush, all backwards. Bubble (S-1)/(M+S-1),
    /// peak activation memory ∝ M.
    GPipe,
    /// 1F1B (PipeDream-flush): backward for microbatch m starts as soon
    /// as its own forward is done; at most S−s microbatches in flight per
    /// stage. Same bubble as GPipe-flush but activation memory ∝ S.
    OneFOneB,
}

/// Simulation configuration: network + system + loop shape.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// The network description.
    pub network: Network,
    /// System-layer scheduling configuration.
    pub system: SystemConfig,
    /// Training iterations to simulate.
    pub iterations: usize,
    /// Pipeline stages (PIPELINE parallelism only).
    pub stages: usize,
    /// Microbatches per iteration (PIPELINE only).
    pub microbatches: usize,
    /// Stage-boundary activation bytes (PIPELINE only); the translator's
    /// `ModelSummary` supplies this, or it can be set explicitly.
    pub boundary_bytes: u64,
    /// Pipeline schedule family (PIPELINE only).
    pub schedule: PipelineSchedule,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            network: Network::two_tier(8, 4),
            system: SystemConfig::default(),
            iterations: 2,
            stages: 4,
            microbatches: 8,
            boundary_bytes: 1 << 20,
            schedule: PipelineSchedule::GPipe,
        }
    }
}

/// Per-layer time attribution (flat strategies).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerBreakdown {
    /// Layer name from the workload row.
    pub name: String,
    /// Compute time attributed to the layer across all iterations (ns).
    pub compute_ns: u64,
    /// Collective service time attributed to the layer (ns).
    pub comm_ns: u64,
}

/// Simulation result.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// End-to-end simulated time for all iterations (ns).
    pub total_ns: u64,
    /// Time per iteration (ns) — total / iterations.
    pub iteration_ns: u64,
    /// Per-worker compute busy time (ns).
    pub compute_busy_ns: Vec<u64>,
    /// Per-network-dimension busy time (ns).
    pub net_busy_ns: Vec<u64>,
    /// Communication time not hidden by compute: makespan − max compute
    /// busy (ns) — the "exposed" communication cost.
    pub exposed_ns: u64,
    /// Events (tasks) processed.
    pub events: usize,
    /// Compute utilization of the busiest worker, 0..1.
    pub compute_utilization: f64,
    /// Per-layer time attribution (populated for DATA/MODEL/HYBRID runs;
    /// empty for pipeline, where stages — not layers — are the unit).
    pub breakdown: Vec<LayerBreakdown>,
}

impl SimReport {
    fn from_schedule(s: &Schedule, compute_res: &[usize], net_res: &[usize], iters: usize) -> SimReport {
        let compute_busy_ns: Vec<u64> = compute_res.iter().map(|&r| s.busy_ns[r]).collect();
        let net_busy_ns: Vec<u64> = net_res.iter().map(|&r| s.busy_ns[r]).collect();
        let max_busy = compute_busy_ns.iter().copied().max().unwrap_or(0);
        SimReport {
            total_ns: s.makespan_ns,
            iteration_ns: s.makespan_ns / iters.max(1) as u64,
            exposed_ns: s.makespan_ns.saturating_sub(max_busy),
            compute_utilization: if s.makespan_ns > 0 {
                max_busy as f64 / s.makespan_ns as f64
            } else {
                0.0
            },
            compute_busy_ns,
            net_busy_ns,
            events: s.events,
            breakdown: Vec::new(),
        }
    }
}

/// Reusable simulation arena: engine resource slots, task graph, run-loop
/// buffers and resource-id scratch, carried across scenarios (one per
/// sweep worker) so steady-state simulations perform no per-task heap
/// allocation.
///
/// Contract: every [`simulate_with`] call fully re-initializes the parts
/// it uses — a scratch can be reused across *any* sequence of workloads
/// and configs, and results are identical to a fresh scratch.
#[derive(Debug, Default)]
pub struct SimScratch {
    /// Engine (resource slots + backlog buffers are reused).
    pub engine: Engine,
    /// Task graph (cleared per scenario; capacity persists).
    pub graph: TaskGraph,
    /// Run-loop buffers + the schedule output of the latest run.
    pub run: RunScratch,
    dim_res: Vec<ResourceId>,
    stage_res: Vec<ResourceId>,
    flat: FlatBuffers,
    pipe: PipeBuffers,
}

impl SimScratch {
    /// Fresh, empty scratch (buffers grow on first use).
    pub fn new() -> SimScratch {
        SimScratch::default()
    }
}

/// Reusable temporaries for [`build_iteration_graph`].
#[derive(Debug, Default)]
struct FlatBuffers {
    prev_updates: Vec<TaskId>,
    chain: Vec<TaskId>,
    wg_comm: Vec<(usize, TaskId)>,
}

/// Reusable temporaries for [`build_pipeline_graph`] (flat
/// `[stage × microbatch]` id grids plus the gate/dep lists).
#[derive(Debug, Default)]
struct PipeBuffers {
    fwd: Vec<TaskId>,
    arrive: Vec<TaskId>,
    bwd: Vec<TaskId>,
    barrive: Vec<TaskId>,
    gate: Vec<TaskId>,
    deps: Vec<TaskId>,
}

/// Simulate a workload end to end (one-shot: allocates a fresh scratch).
pub fn simulate(workload: &Workload, cfg: &SimConfig) -> Result<SimReport> {
    let mut scratch = SimScratch::default();
    simulate_with(workload, cfg, &mut scratch)
}

/// Simulate a workload end to end, reusing `scratch` buffers. This is the
/// sweep hot path: after the first call, steady-state reruns build and
/// execute the task graph without allocating.
pub fn simulate_with(
    workload: &Workload,
    cfg: &SimConfig,
    scratch: &mut SimScratch,
) -> Result<SimReport> {
    cfg.network.validate()?;
    if workload.layers.is_empty() {
        return Err(Error::sim("workload has no layers"));
    }
    match workload.parallelism {
        Parallelism::Pipeline => simulate_pipeline(workload, cfg, scratch),
        _ => simulate_flat(workload, cfg, scratch),
    }
}

/// DATA / MODEL / HYBRID: representative-NPU timeline.
fn simulate_flat(
    workload: &Workload,
    cfg: &SimConfig,
    scratch: &mut SimScratch,
) -> Result<SimReport> {
    let n = workload.layers.len();
    scratch.engine.reset();
    let cpu = scratch.engine.add_resource(Policy::Fifo);
    scratch.dim_res.clear();
    for _ in &cfg.network.dims {
        scratch.dim_res.push(scratch.engine.add_resource(cfg.system.scheduling));
    }
    let router = CommRouter::new(&cfg.network, &scratch.dim_res, cfg.system.chunks);
    scratch.graph.clear();
    // Pre-size from the workload shape: per layer per iteration at most
    // fwd+wg+ig+upd compute tasks plus three collective expansions of at
    // most 3·chunks+1 tasks each (hierarchical RS/AR/AG legs + join).
    let per_coll = 3 * cfg.system.chunks.chunks.clamp(1, MAX_CHUNKS) + 1;
    scratch.graph.reserve(
        cfg.iterations * n * (4 + 3 * per_coll),
        cfg.iterations * n * (6 + 3 * per_coll),
    );
    build_iteration_graph_into(
        workload,
        cfg.iterations,
        cpu,
        &router,
        &mut scratch.graph,
        &mut scratch.flat,
    );
    scratch.engine.run_into(&scratch.graph, &mut scratch.run)?;
    let s = &scratch.run.schedule;
    let mut report = SimReport::from_schedule(s, &[cpu], &scratch.dim_res, cfg.iterations);
    report.breakdown = attribute_layers(workload, &scratch.graph, s, cpu);
    Ok(report)
}

/// Attribute task durations back to workload layers via their tags —
/// a direct index into the layer list (no label parsing, no hash map).
fn attribute_layers(
    workload: &Workload,
    g: &TaskGraph,
    s: &Schedule,
    cpu: ResourceId,
) -> Vec<LayerBreakdown> {
    let n = workload.layers.len();
    let mut acc = vec![(0u64, 0u64); n];
    for id in 0..g.len() {
        let t = g.task(id);
        if matches!(t.tag.phase, TagPhase::Adhoc) {
            continue;
        }
        let li = t.tag.layer as usize;
        if li >= n {
            continue;
        }
        let dur = s.spans[id].finish_ns - s.spans[id].start_ns;
        if t.resource == cpu {
            acc[li].0 += dur;
        } else {
            acc[li].1 += dur;
        }
    }
    workload
        .layers
        .iter()
        .zip(acc)
        .map(|(l, (c, m))| LayerBreakdown { name: l.name.clone(), compute_ns: c, comm_ns: m })
        .collect()
}

/// Build the DATA/MODEL/HYBRID iteration task graph (public for tests and
/// ablation benches; allocates its own temporaries — the scratch-reusing
/// simulate path goes through the `_into` variant).
pub fn build_iteration_graph(
    workload: &Workload,
    iterations: usize,
    cpu: ResourceId,
    router: &CommRouter<'_>,
    g: &mut TaskGraph,
) {
    build_iteration_graph_into(workload, iterations, cpu, router, g, &mut FlatBuffers::default());
}

/// [`build_iteration_graph`] with caller-owned temporaries: allocation-
/// free once the buffers are warm.
// lint: hot-path
fn build_iteration_graph_into(
    workload: &Workload,
    iterations: usize,
    cpu: ResourceId,
    router: &CommRouter<'_>,
    g: &mut TaskGraph,
    bufs: &mut FlatBuffers,
) {
    // Gate that the next iteration's first forward waits on: the previous
    // iteration's per-layer update tasks.
    let prev_updates = &mut bufs.prev_updates;
    let chain = &mut bufs.chain;
    let wg_comm_tasks = &mut bufs.wg_comm;
    prev_updates.clear();
    for it in 0..iterations {
        // ---- forward ----
        chain.clear();
        chain.extend(prev_updates.drain(..));
        for (i, l) in workload.layers.iter().enumerate() {
            let tag = TaskTag::flat(it, TagPhase::Fwd, i);
            let fwd = g.add(tag, cpu, l.fwd.compute_ns, chain.as_slice());
            chain.clear();
            // Blocking activation collective (MODEL/HYBRID): the next
            // layer's forward depends on it.
            match router.issue(g, tag, l.fwd.comm, l.fwd.comm_bytes, &[fwd], true) {
                Some(c) => chain.push(c),
                None => chain.push(fwd),
            }
        }

        // ---- backward (reverse layer order) ----
        // chain currently holds the last layer's forward completion.
        wg_comm_tasks.clear();
        for (i, l) in workload.layers.iter().enumerate().rev() {
            // Weight-grad compute, then async all-reduce (non-blocking).
            let wg_tag = TaskTag::flat(it, TagPhase::Wg, i);
            let wg = g.add(wg_tag, cpu, l.weight_grad.compute_ns, chain.as_slice());
            let wg_comm =
                router.issue(g, wg_tag, l.weight_grad.comm, l.weight_grad.comm_bytes, &[wg], false);
            wg_comm_tasks.push((i, wg_comm.unwrap_or(wg)));
            // Input-grad compute; its collective blocks the next layer.
            let ig_tag = TaskTag::flat(it, TagPhase::Ig, i);
            let ig = g.add(ig_tag, cpu, l.input_grad.compute_ns, &[wg]);
            chain.clear();
            match router.issue(g, ig_tag, l.input_grad.comm, l.input_grad.comm_bytes, &[ig], true) {
                Some(c) => chain.push(c),
                None => chain.push(ig),
            }
        }

        // ---- optimizer updates ----
        // Each layer's update waits for its gradient all-reduce; updates
        // run on the compute stream and gate the next iteration.
        for &(i, dep) in wg_comm_tasks.iter() {
            let l = &workload.layers[i];
            let u = g.add(TaskTag::flat(it, TagPhase::Upd, i), cpu, l.update_ns, &[dep]);
            prev_updates.push(u);
        }
    }
}

/// PIPELINE: GPipe-style schedule over contiguous stage partitions.
fn simulate_pipeline(
    workload: &Workload,
    cfg: &SimConfig,
    scratch: &mut SimScratch,
) -> Result<SimReport> {
    let n = workload.layers.len();
    let stages = cfg.stages.clamp(1, n);
    if cfg.microbatches == 0 {
        return Err(Error::sim("pipeline needs >=1 microbatch"));
    }
    let micro = cfg.microbatches;

    // Partition layers into contiguous stages balanced by compute time.
    let bounds = partition_by_compute(workload, stages);

    scratch.engine.reset();
    scratch.stage_res.clear();
    for _ in 0..stages {
        scratch.stage_res.push(scratch.engine.add_resource(Policy::Fifo));
    }
    scratch.dim_res.clear();
    for _ in &cfg.network.dims {
        scratch.dim_res.push(scratch.engine.add_resource(cfg.system.scheduling));
    }
    let router = CommRouter::new(&cfg.network, &scratch.dim_res, cfg.system.chunks);
    scratch.graph.clear();
    let per_coll = 3 * cfg.system.chunks.chunks.clamp(1, MAX_CHUNKS) + 1;
    scratch.graph.reserve(
        cfg.iterations * stages * (4 * micro + per_coll + 1),
        cfg.iterations * stages * (8 * micro + per_coll + 2),
    );
    build_pipeline_graph_into(
        workload,
        cfg,
        &bounds,
        &scratch.stage_res,
        &router,
        &mut scratch.graph,
        &mut scratch.pipe,
    );
    scratch.engine.run_into(&scratch.graph, &mut scratch.run)?;
    let s = &scratch.run.schedule;
    Ok(SimReport::from_schedule(s, &scratch.stage_res, &scratch.dim_res, cfg.iterations))
}

/// Build the pipeline task graph over pre-partitioned stages (public for
/// tests and ablation benches; allocates its own temporaries — the
/// scratch-reusing simulate path goes through the `_into` variant).
/// `bounds` is a `stages+1`-element layer partition as produced by
/// [`partition_by_compute`]; `stage_cpu` holds one compute resource per
/// stage.
pub fn build_pipeline_graph(
    workload: &Workload,
    cfg: &SimConfig,
    bounds: &[usize],
    stage_cpu: &[ResourceId],
    router: &CommRouter<'_>,
    g: &mut TaskGraph,
) {
    let mut bufs = PipeBuffers::default();
    build_pipeline_graph_into(workload, cfg, bounds, stage_cpu, router, g, &mut bufs);
}

/// [`build_pipeline_graph`] with caller-owned temporaries: allocation-
/// free once the buffers are warm.
// lint: hot-path
fn build_pipeline_graph_into(
    workload: &Workload,
    cfg: &SimConfig,
    bounds: &[usize],
    stage_cpu: &[ResourceId],
    router: &CommRouter<'_>,
    g: &mut TaskGraph,
    bufs: &mut PipeBuffers,
) {
    const NONE: TaskId = usize::MAX;
    let stages = stage_cpu.len();
    let micro = cfg.microbatches.max(1);

    // Per-stage fwd/bwd durations (per microbatch: workload rows describe
    // the full batch, so divide by microbatch count).
    let stage_time = |s: usize, f: &dyn Fn(&crate::workload::LayerSpec) -> u64| -> u64 {
        workload.layers[bounds[s]..bounds[s + 1]].iter().map(f).sum::<u64>() / micro as u64
    };

    let mb_boundary = cfg.boundary_bytes / micro as u64;
    let idx = |s: usize, m: usize| s * micro + m;
    // Flat [stage × microbatch] id grids (no per-stage Vec-of-Vec).
    let cells = stages * micro;
    let fwd = &mut bufs.fwd;
    let arrive = &mut bufs.arrive;
    let bwd = &mut bufs.bwd;
    let barrive = &mut bufs.barrive;
    fwd.clear();
    fwd.resize(cells, NONE);
    arrive.clear();
    arrive.resize(cells, NONE);
    bwd.clear();
    bwd.resize(cells, NONE);
    barrive.clear();
    barrive.resize(cells, NONE);
    let prev_iter_gate = &mut bufs.gate;
    prev_iter_gate.clear();
    let deps = &mut bufs.deps;

    for it in 0..cfg.iterations {
        fwd.fill(NONE);
        arrive.fill(NONE);
        bwd.fill(NONE);
        barrive.fill(NONE);
        for m in 0..micro {
            for s in 0..stages {
                deps.clear();
                if s == 0 && m == 0 {
                    deps.extend(prev_iter_gate.drain(..));
                }
                if m > 0 {
                    deps.push(fwd[idx(s, m - 1)]); // stage serialization
                }
                if s > 0 {
                    debug_assert_ne!(arrive[idx(s, m)], NONE, "boundary arrival");
                    deps.push(arrive[idx(s, m)]);
                }
                let tag = TaskTag::pipe(it, TagPhase::PipeFwd, s, m);
                let dur = stage_time(s, &|l| l.fwd.compute_ns);
                let t = g.add(tag, stage_cpu[s], dur, deps.as_slice());
                fwd[idx(s, m)] = t;
                if s + 1 < stages {
                    let send = router.p2p(g, tag, mb_boundary, &[t]);
                    arrive[idx(s + 1, m)] = send.unwrap_or(t);
                }
            }
        }

        // Backward. GPipe: begins after ALL forwards (flush). 1F1B:
        // microbatch m's backward needs only its own forward — the
        // in-flight cap is enforced on the forward side below.
        for m in 0..micro {
            for s in (0..stages).rev() {
                let gate = match cfg.schedule {
                    PipelineSchedule::GPipe => fwd[idx(s, micro - 1)],
                    PipelineSchedule::OneFOneB => fwd[idx(s, m)],
                };
                deps.clear();
                deps.push(gate);
                if m > 0 {
                    deps.push(bwd[idx(s, m - 1)]);
                }
                if s + 1 < stages {
                    debug_assert_ne!(barrive[idx(s, m)], NONE, "grad arrival");
                    deps.push(barrive[idx(s, m)]);
                }
                let tag = TaskTag::pipe(it, TagPhase::PipeBwd, s, m);
                let t = g.add(
                    tag,
                    stage_cpu[s],
                    stage_time(s, &|l| l.input_grad.compute_ns + l.weight_grad.compute_ns),
                    deps.as_slice(),
                );
                bwd[idx(s, m)] = t;
                if s > 0 {
                    let send = router.p2p(g, tag, mb_boundary, &[t]);
                    barrive[idx(s - 1, m)] = send.unwrap_or(t);
                }
            }
        }

        // Per-stage gradient all-reduce (DP across replicas) + update gate.
        for s in 0..stages {
            let wg_bytes: u64 = workload.layers[bounds[s]..bounds[s + 1]]
                .iter()
                .filter(|l| l.weight_grad.comm == CommType::AllReduce)
                .map(|l| l.weight_grad.comm_bytes)
                .sum();
            let upd_ns: u64 =
                workload.layers[bounds[s]..bounds[s + 1]].iter().map(|l| l.update_ns).sum();
            let last_bwd = bwd[idx(s, micro - 1)];
            let wg_tag = TaskTag::pipe(it, TagPhase::PipeWg, s, 0);
            let comm = router.issue(g, wg_tag, CommType::AllReduce, wg_bytes, &[last_bwd], false);
            let dep = comm.unwrap_or(last_bwd);
            let upd_tag = TaskTag::pipe(it, TagPhase::PipeUpd, s, 0);
            let u = g.add(upd_tag, stage_cpu[s], upd_ns, &[dep]);
            prev_iter_gate.push(u);
        }
    }
}

/// Shape summary returned by [`verify_workload`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GraphCheck {
    /// Tasks in the verified graph.
    pub tasks: usize,
    /// Total dependency-pool entries.
    pub deps: usize,
    /// Resources registered (compute streams + network dimensions).
    pub resources: usize,
}

/// Build the task graph for `workload` under `cfg` exactly as
/// [`simulate_with`] would — same resources, same builder, same router —
/// then run [`super::engine::verify_graph`] over it instead of executing
/// it. This is the data-level leg of `modtrans check`: it proves the
/// schedule builders uphold the graph invariants for a concrete scenario
/// without paying for the event loop.
pub fn verify_workload(workload: &Workload, cfg: &SimConfig) -> Result<GraphCheck> {
    cfg.network.validate()?;
    if workload.layers.is_empty() {
        return Err(Error::sim("workload has no layers"));
    }
    let mut scratch = SimScratch::default();
    match workload.parallelism {
        Parallelism::Pipeline => {
            let stages = cfg.stages.clamp(1, workload.layers.len());
            if cfg.microbatches == 0 {
                return Err(Error::sim("pipeline needs >=1 microbatch"));
            }
            let bounds = partition_by_compute(workload, stages);
            for _ in 0..stages {
                scratch.stage_res.push(scratch.engine.add_resource(Policy::Fifo));
            }
            for _ in &cfg.network.dims {
                scratch.dim_res.push(scratch.engine.add_resource(cfg.system.scheduling));
            }
            let router = CommRouter::new(&cfg.network, &scratch.dim_res, cfg.system.chunks);
            build_pipeline_graph_into(
                workload,
                cfg,
                &bounds,
                &scratch.stage_res,
                &router,
                &mut scratch.graph,
                &mut scratch.pipe,
            );
        }
        _ => {
            let cpu = scratch.engine.add_resource(Policy::Fifo);
            for _ in &cfg.network.dims {
                scratch.dim_res.push(scratch.engine.add_resource(cfg.system.scheduling));
            }
            let router = CommRouter::new(&cfg.network, &scratch.dim_res, cfg.system.chunks);
            build_iteration_graph_into(
                workload,
                cfg.iterations,
                cpu,
                &router,
                &mut scratch.graph,
                &mut scratch.flat,
            );
        }
    }
    super::engine::verify_graph(&scratch.graph, scratch.engine.num_resources())?;
    Ok(GraphCheck {
        tasks: scratch.graph.len(),
        deps: scratch.graph.num_deps(),
        resources: scratch.engine.num_resources(),
    })
}

/// Contiguous partition of layers into `stages` groups with balanced
/// forward compute (greedy prefix split).
pub fn partition_by_compute(workload: &Workload, stages: usize) -> Vec<usize> {
    partition_compute_costs(workload.layers.len(), stages, |i| workload.layers[i].fwd.compute_ns)
}

/// Index-accessor core of [`partition_by_compute`]: partition `n` layers
/// into `stages` contiguous groups balancing `cost_ns(i)` (forward
/// compute). Shared with the sweep's analytic bound pass
/// ([`crate::sweep::bound`]), which partitions over the cached IR's cost
/// slots — both sides MUST split identically or the bound's per-stage
/// busy times would describe a different pipeline than the one
/// simulated.
pub fn partition_compute_costs(
    n: usize,
    stages: usize,
    cost_ns: impl Fn(usize) -> u64,
) -> Vec<usize> {
    let total: u64 = (0..n).map(|i| cost_ns(i).max(1)).sum();
    let target = total / stages as u64;
    let mut bounds = vec![0usize];
    let mut acc = 0u64;
    for i in 0..n {
        acc += cost_ns(i).max(1);
        if acc >= target && bounds.len() < stages && n - (i + 1) >= stages - bounds.len() {
            bounds.push(i + 1);
            acc = 0;
        }
    }
    // The greedy split can come up short when compute is concentrated in
    // the tail; force the remaining boundaries so every stage is nonempty.
    while bounds.len() < stages {
        let last = *bounds.last().unwrap_or(&0);
        // Distribute remaining layers evenly over remaining stages.
        let remaining_stages = stages + 1 - bounds.len();
        let step = ((n - last) / remaining_stages).max(1);
        bounds.push(last + step);
    }
    bounds.push(n);
    debug_assert!(bounds.windows(2).all(|w| w[1] > w[0]), "bad partition {bounds:?}");
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::network::TopologyKind;
    use crate::workload::{LayerSpec, Phase};

    fn mk_workload(p: Parallelism, layers: usize, compute_ns: u64, comm_bytes: u64) -> Workload {
        Workload {
            parallelism: p,
            layers: (0..layers)
                .map(|i| LayerSpec {
                    name: format!("l{i}"),
                    reserved: -1,
                    fwd: Phase {
                        compute_ns,
                        comm: if p == Parallelism::Model {
                            CommType::AllGather
                        } else {
                            CommType::None
                        },
                        comm_bytes: if p == Parallelism::Model { comm_bytes } else { 0 },
                    },
                    input_grad: Phase::compute_only(compute_ns),
                    weight_grad: Phase {
                        compute_ns,
                        comm: if p == Parallelism::Data { CommType::AllReduce } else { CommType::None },
                        comm_bytes: if p == Parallelism::Data { comm_bytes } else { 0 },
                    },
                    update_ns: 10,
                })
                .collect(),
        }
    }

    fn cfg_ring(npus: usize) -> SimConfig {
        SimConfig {
            network: Network::single(TopologyKind::Ring, npus, 100.0, 500.0),
            iterations: 2,
            ..Default::default()
        }
    }

    #[test]
    fn dp_overlaps_allreduce_with_backward() {
        let w = mk_workload(Parallelism::Data, 8, 50_000, 1 << 20);
        let r = simulate(&w, &cfg_ring(8)).unwrap();
        // Sanity: nonzero and bounded below by pure compute.
        let compute_per_iter: u64 = w.total_compute_ns();
        assert!(r.iteration_ns >= compute_per_iter);
        // Overlap: exposed comm must be far less than the serial sum of
        // all all-reduces (first 7 overlap with remaining backward).
        assert!(r.exposed_ns < r.net_busy_ns[0], "no overlap happened");
        assert!(r.compute_utilization > 0.5);
    }

    #[test]
    fn model_parallel_comm_is_blocking() {
        let w = mk_workload(Parallelism::Model, 8, 1_000, 8 << 20);
        let r = simulate(&w, &cfg_ring(8)).unwrap();
        // With huge blocking all-gathers and tiny compute, utilization
        // must be poor: comm dominates the critical path.
        assert!(r.compute_utilization < 0.2);
        assert!(r.net_busy_ns[0] > r.compute_busy_ns[0]);
    }

    #[test]
    fn dp_time_grows_with_comm_size() {
        let small = simulate(&mk_workload(Parallelism::Data, 8, 1_000, 1 << 16), &cfg_ring(8)).unwrap();
        let big = simulate(&mk_workload(Parallelism::Data, 8, 1_000, 64 << 20), &cfg_ring(8)).unwrap();
        assert!(big.iteration_ns > small.iteration_ns);
    }

    #[test]
    fn pipeline_bubble_shrinks_with_more_microbatches() {
        let mut w = mk_workload(Parallelism::Data, 16, 100_000, 0);
        w.parallelism = Parallelism::Pipeline;
        let mut cfg = cfg_ring(4);
        cfg.stages = 4;
        cfg.boundary_bytes = 1 << 16;
        cfg.microbatches = 2;
        let few = simulate(&w, &cfg).unwrap();
        cfg.microbatches = 16;
        let many = simulate(&w, &cfg).unwrap();
        // GPipe bubble fraction (S-1)/(M+S-1): more microbatches → higher
        // utilization and lower iteration time.
        assert!(many.iteration_ns < few.iteration_ns);
        assert!(many.compute_utilization > few.compute_utilization);
    }

    #[test]
    fn pipeline_respects_stage_dependencies() {
        let mut w = mk_workload(Parallelism::Data, 4, 10_000, 0);
        w.parallelism = Parallelism::Pipeline;
        let mut cfg = cfg_ring(4);
        cfg.stages = 4;
        cfg.microbatches = 1;
        cfg.iterations = 1;
        cfg.boundary_bytes = 0;
        let r = simulate(&w, &cfg).unwrap();
        // One microbatch through 4 stages: fwd 4×10k + bwd 4×20k serial =
        // 120k + updates.
        assert!(r.total_ns >= 120_000);
        assert!(r.total_ns < 150_000);
    }

    #[test]
    fn breakdown_attributes_all_layers() {
        let w = mk_workload(Parallelism::Data, 6, 10_000, 1 << 20);
        let r = simulate(&w, &cfg_ring(8)).unwrap();
        assert_eq!(r.breakdown.len(), 6);
        for (b, l) in r.breakdown.iter().zip(w.layers.iter()) {
            assert_eq!(b.name, l.name);
            // 2 iterations × (fwd+ig+wg) compute + update.
            assert_eq!(b.compute_ns, 2 * (3 * 10_000 + 10));
            assert!(b.comm_ns > 0, "{}: allreduce time missing", b.name);
        }
        // Conservation: attributed comm equals the dimension busy time.
        let total_comm: u64 = r.breakdown.iter().map(|b| b.comm_ns).sum();
        assert_eq!(total_comm, r.net_busy_ns[0]);
    }

    #[test]
    fn one_f_one_b_not_worse_than_gpipe() {
        let mut w = mk_workload(Parallelism::Data, 16, 100_000, 0);
        w.parallelism = Parallelism::Pipeline;
        let mut cfg = cfg_ring(4);
        cfg.stages = 4;
        cfg.microbatches = 8;
        cfg.boundary_bytes = 1 << 16;
        cfg.schedule = PipelineSchedule::GPipe;
        let gpipe = simulate(&w, &cfg).unwrap();
        cfg.schedule = PipelineSchedule::OneFOneB;
        let ofob = simulate(&w, &cfg).unwrap();
        // 1F1B removes the flush barrier: backward work starts earlier, so
        // the makespan can only shrink (or tie).
        assert!(
            ofob.total_ns <= gpipe.total_ns,
            "1F1B {} should not exceed GPipe {}",
            ofob.total_ns,
            gpipe.total_ns
        );
        // Both run the same amount of compute.
        assert_eq!(
            gpipe.compute_busy_ns.iter().sum::<u64>(),
            ofob.compute_busy_ns.iter().sum::<u64>()
        );
    }

    #[test]
    fn partition_balances_compute() {
        let w = mk_workload(Parallelism::Data, 10, 1000, 0);
        let b = partition_by_compute(&w, 3);
        assert_eq!(b.first(), Some(&0));
        assert_eq!(b.last(), Some(&10));
        assert_eq!(b.len(), 4);
        // Each stage nonempty.
        for w2 in b.windows(2) {
            assert!(w2[1] > w2[0]);
        }
    }

    #[test]
    fn lifo_vs_fifo_changes_schedule_not_totals_much() {
        let w = mk_workload(Parallelism::Data, 12, 5_000, 4 << 20);
        let mut cfg = cfg_ring(8);
        cfg.system.scheduling = Policy::Fifo;
        let fifo = simulate(&w, &cfg).unwrap();
        cfg.system.scheduling = Policy::Lifo;
        let lifo = simulate(&w, &cfg).unwrap();
        // Both complete the same work.
        assert_eq!(fifo.net_busy_ns[0], lifo.net_busy_ns[0]);
        // Schedules may differ in makespan; totals within 2x.
        assert!(lifo.total_ns < fifo.total_ns * 2);
    }

    #[test]
    fn empty_workload_is_error() {
        let w = Workload { parallelism: Parallelism::Data, layers: vec![] };
        assert!(simulate(&w, &cfg_ring(4)).is_err());
        assert!(verify_workload(&w, &cfg_ring(4)).is_err());
    }

    #[test]
    fn verify_workload_matches_simulated_graph_shape() {
        // Flat: the verified graph is the one simulate_with would run.
        let dp = mk_workload(Parallelism::Data, 8, 20_000, 2 << 20);
        let cfg = cfg_ring(8);
        let check = verify_workload(&dp, &cfg).unwrap();
        let r = simulate(&dp, &cfg).unwrap();
        assert_eq!(check.tasks, r.events);
        assert!(check.deps > 0);
        assert_eq!(check.resources, 1 + cfg.network.dims.len());

        // Pipeline: stage resources replace the single compute stream.
        let mut pp = mk_workload(Parallelism::Data, 12, 30_000, 0);
        pp.parallelism = Parallelism::Pipeline;
        let mut cfg = cfg_ring(4);
        cfg.stages = 4;
        cfg.microbatches = 4;
        let check = verify_workload(&pp, &cfg).unwrap();
        let r = simulate(&pp, &cfg).unwrap();
        assert_eq!(check.tasks, r.events);
        assert_eq!(check.resources, 4 + cfg.network.dims.len());
    }

    #[test]
    fn more_npus_cost_more_allreduce_on_ring() {
        let w = mk_workload(Parallelism::Data, 6, 1_000, 32 << 20);
        let r8 = simulate(&w, &cfg_ring(8)).unwrap();
        let r64 = simulate(&w, &cfg_ring(64)).unwrap();
        // Ring all-reduce latency term grows with N; bandwidth term fixed.
        assert!(r64.iteration_ns > r8.iteration_ns);
    }

    #[test]
    fn scratch_reuse_is_equivalent_to_fresh_scratch() {
        // The SimScratch reuse contract: any sequence of workloads and
        // configs through one scratch matches one-shot simulation exactly.
        let mut scratch = SimScratch::new();
        let dp = mk_workload(Parallelism::Data, 8, 20_000, 2 << 20);
        let mp = mk_workload(Parallelism::Model, 5, 9_000, 1 << 20);
        let mut pp = mk_workload(Parallelism::Data, 12, 30_000, 0);
        pp.parallelism = Parallelism::Pipeline;
        let mut pp_cfg = cfg_ring(4);
        pp_cfg.stages = 4;
        pp_cfg.microbatches = 4;
        let cases: Vec<(&Workload, SimConfig)> = vec![
            (&dp, cfg_ring(8)),
            (&mp, cfg_ring(16)),
            (&pp, pp_cfg),
            (&dp, cfg_ring(64)),
        ];
        for round in 0..3 {
            for &(w, ref cfg) in &cases {
                let fresh = simulate(w, cfg).unwrap();
                let reused = simulate_with(w, cfg, &mut scratch).unwrap();
                assert_eq!(reused.total_ns, fresh.total_ns, "round {round}");
                assert_eq!(reused.iteration_ns, fresh.iteration_ns);
                assert_eq!(reused.compute_busy_ns, fresh.compute_busy_ns);
                assert_eq!(reused.net_busy_ns, fresh.net_busy_ns);
                assert_eq!(reused.events, fresh.events);
                assert_eq!(reused.breakdown, fresh.breakdown);
            }
        }
    }
}
