//! Topology-aware collective algorithms (the ASTRA-sim system layer's
//! collective scheduler).
//!
//! Completion-time models follow the standard α-β formulation
//! (`steps × latency + moved_bytes / bandwidth`), e.g. ring all-reduce
//! `2(N-1)(α + (M/N)/β)`. Ring-style schedules keep every link busy every
//! phase, so chunking cannot speed up a single collective; chunk
//! pipelining pays off when a collective spans *multiple* network
//! dimensions, which [`crate::sim::system`] realizes by splitting the
//! payload into [`ChunkCfg::chunks`] sub-collectives whose legs overlap
//! across dimension resources.
//!
//! Per topology:
//! * **Ring** — bandwidth-optimal ring schedules.
//! * **FullyConnected** — direct single-phase exchanges.
//! * **Switch** — recursive halving/doubling through the switch
//!   (`log2 N` phases), full payload serialized at the NIC each phase.
//! * **Torus2D** — dimension-ordered: reduce-scatter on rows, all-reduce
//!   on columns over the row-sharded payload, all-gather on rows.

use super::network::{NetDim, TopologyKind};
use crate::workload::CommType;

/// Chunking configuration for hierarchical (multi-dimension) pipelining.
#[derive(Debug, Clone, Copy)]
pub struct ChunkCfg {
    /// Number of pipeline chunks a multi-dimension collective is split
    /// into (≥ 1); 1 disables pipelining.
    pub chunks: usize,
}

impl Default for ChunkCfg {
    fn default() -> Self {
        ChunkCfg { chunks: 4 }
    }
}

/// Completion time in ns for `comm` moving `bytes` across `dim.npus`
/// participants of `dim`.
///
/// `bytes` semantics match the workload file: for ALLREDUCE it is the full
/// gradient buffer per NPU; for ALLGATHER the gathered output size; for
/// REDUCESCATTER the input size; for ALLTOALL the per-NPU send total.
pub fn collective_ns(comm: CommType, bytes: u64, dim: &NetDim) -> u64 {
    let n = dim.npus as f64;
    if dim.npus <= 1 || bytes == 0 {
        return 0;
    }
    let m = bytes as f64;
    let t = match comm {
        CommType::None => 0.0,
        CommType::AllReduce => match dim.kind {
            // Reduce-scatter + all-gather, each N-1 phases of M/N chunks.
            TopologyKind::Ring => phases(2.0 * (n - 1.0), m / n, dim),
            // Direct: each NPU sends its shard to every peer, twice
            // (reduce then broadcast), all links in parallel.
            TopologyKind::FullyConnected => 2.0 * dim.hop_ns(m / n),
            // Halving/doubling through the switch: 2·log2(N) phases, the
            // i-th moving M/2^i; total bytes ≈ 2M(N-1)/N at the NIC.
            TopologyKind::Switch => {
                let steps = 2.0 * n.log2().ceil();
                steps * dim.latency_ns + 2.0 * dim.ser_ns(m * (n - 1.0) / n)
            }
            TopologyKind::Torus2D => {
                let (r, cdim) = dim.torus_dims();
                let (r, cd) = (r as f64, cdim as f64);
                // RS along rows (r-1 phases of M/r), AR along cols on the
                // row shard (2(c-1) phases of M/(r·c)), AG along rows.
                phases(r - 1.0, m / r, dim)
                    + phases(2.0 * (cd - 1.0), m / (r * cd), dim)
                    + phases(r - 1.0, m / r, dim)
            }
        },
        CommType::AllGather | CommType::ReduceScatter => match dim.kind {
            TopologyKind::Ring => phases(n - 1.0, m / n, dim),
            TopologyKind::FullyConnected => dim.hop_ns(m / n),
            TopologyKind::Switch => {
                n.log2().ceil() * dim.latency_ns + dim.ser_ns(m * (n - 1.0) / n)
            }
            TopologyKind::Torus2D => {
                let (r, cdim) = dim.torus_dims();
                let (r, cd) = (r as f64, cdim as f64);
                phases(r - 1.0, m / r, dim) + phases(cd - 1.0, m / (r * cd), dim)
            }
        },
        CommType::AllToAll => match dim.kind {
            // Each NPU exchanges M/N with every peer.
            TopologyKind::FullyConnected => dim.hop_ns(m / n),
            // Ring: average hop distance N/4 (bidirectional), N-1 partners.
            TopologyKind::Ring => {
                (n - 1.0) * dim.latency_ns + dim.ser_ns(m * (n - 1.0) / n) * (n / 4.0).max(1.0)
            }
            // Switch: serialized at the NIC: M(N-1)/N out.
            TopologyKind::Switch => {
                2.0 * dim.latency_ns + dim.ser_ns(m * (n - 1.0) / n)
            }
            TopologyKind::Torus2D => {
                let (r, cdim) = dim.torus_dims();
                let (r, cd) = (r as f64, cdim as f64);
                (r + cd - 2.0) * dim.latency_ns
                    + dim.ser_ns(m * (n - 1.0) / n) * ((r + cd) / 4.0).max(1.0)
            }
        },
    };
    t.ceil() as u64
}

/// `steps` sequential phases, each moving `phase_bytes` on every link
/// concurrently (ring-style schedules keep all links busy every phase, so
/// intra-collective chunking cannot reduce this — pipelining gains come
/// from overlapping *dimensions*, which the system layer's chunked
/// hierarchical route provides).
fn phases(steps: f64, phase_bytes: f64, dim: &NetDim) -> f64 {
    steps * dim.hop_ns(phase_bytes)
}

/// Point-to-point transfer time (pipeline-parallel stage boundary).
pub fn p2p_ns(bytes: u64, dim: &NetDim) -> u64 {
    if bytes == 0 {
        return 0;
    }
    dim.hop_ns(bytes as f64).ceil() as u64
}

/// Theoretical lower bound for an all-reduce on any topology: each NPU
/// must send and receive `2·M·(N-1)/N` bytes through its slowest port.
pub fn allreduce_lower_bound_ns(bytes: u64, dim: &NetDim) -> u64 {
    let n = dim.npus as f64;
    if dim.npus <= 1 {
        return 0;
    }
    (2.0 * bytes as f64 * (n - 1.0) / n / dim.bandwidth_gbps).ceil() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring(n: usize) -> NetDim {
        NetDim { kind: TopologyKind::Ring, npus: n, bandwidth_gbps: 100.0, latency_ns: 500.0 }
    }

    fn dim(kind: TopologyKind, n: usize) -> NetDim {
        NetDim { kind, npus: n, bandwidth_gbps: 100.0, latency_ns: 500.0 }
    }

    const MB: u64 = 1 << 20;

    #[test]
    fn ring_allreduce_matches_textbook() {
        let d = ring(8);
        let t = collective_ns(CommType::AllReduce, 8 * MB, &d);
        // 2(N-1) × (α + (M/N)/β) = 14 × (500 + 1MiB/100GBps)
        let expect = 14.0 * (500.0 + (MB as f64) / 100.0);
        assert!((t as f64 - expect).abs() < 2.0, "{t} vs {expect}");
    }

    #[test]
    fn linearity_in_bandwidth_term() {
        // Doubling bandwidth should roughly halve the serialization part.
        let slow = ring(8);
        let fast = NetDim { bandwidth_gbps: 200.0, ..slow };
        let big = 256 * MB;
        let ts = collective_ns(CommType::AllReduce, big, &slow) as f64;
        let tf = collective_ns(CommType::AllReduce, big, &fast) as f64;
        let ratio = ts / tf;
        assert!(ratio > 1.9 && ratio < 2.1, "ratio {ratio}");
    }

    #[test]
    fn respects_lower_bound() {
        for kind in [
            TopologyKind::Ring,
            TopologyKind::FullyConnected,
            TopologyKind::Switch,
            TopologyKind::Torus2D,
        ] {
            for n in [2usize, 4, 8, 16, 64] {
                let d = dim(kind, n);
                let t = collective_ns(CommType::AllReduce, 64 * MB, &d);
                let lb = allreduce_lower_bound_ns(64 * MB, &d);
                // The port bound assumes one link per NPU; FullyConnected
                // has N-1 parallel links, so its aggregate-bandwidth bound
                // is lb/(N-1). No topology may beat that.
                let relaxed = lb / (n as u64 - 1).max(1);
                assert!(t >= relaxed, "{kind:?} N={n}: {t} < relaxed lb {relaxed}");
                if kind == TopologyKind::Ring {
                    // Single-port topology must respect the full bound.
                    assert!(t >= lb, "Ring N={n}: {t} < lb {lb}");
                }
            }
        }
    }

    #[test]
    fn monotonic_in_bytes_and_npus() {
        for kind in [
            TopologyKind::Ring,
            TopologyKind::FullyConnected,
            TopologyKind::Switch,
            TopologyKind::Torus2D,
        ] {
            let d8 = dim(kind, 8);
            let mut prev = 0;
            for mb in [1u64, 4, 16, 64, 256] {
                let t = collective_ns(CommType::AllReduce, mb * MB, &d8);
                assert!(t > prev, "{kind:?}: not monotone in bytes");
                prev = t;
            }
            // Ring time grows with N at fixed payload; others stay ~flat
            // or grow slowly — only assert no pathological shrink to zero.
            let t2 = collective_ns(CommType::AllReduce, 64 * MB, &dim(kind, 2));
            assert!(t2 > 0);
        }
    }

    #[test]
    fn trivial_cases_are_free() {
        let d = ring(1);
        assert_eq!(collective_ns(CommType::AllReduce, MB, &d), 0);
        let d8 = ring(8);
        assert_eq!(collective_ns(CommType::AllReduce, 0, &d8), 0);
        assert_eq!(collective_ns(CommType::None, MB, &d8), 0);
    }

    #[test]
    fn allgather_is_half_of_allreduce_on_ring() {
        let d = ring(8);
        let ar = collective_ns(CommType::AllReduce, 8 * MB, &d);
        let ag = collective_ns(CommType::AllGather, 8 * MB, &d);
        // Equal up to the two formulas' independent ceil() rounding.
        assert!((ar as i64 - (ag as i64) * 2).abs() <= 2, "{ar} vs 2x{ag}");
    }

    #[test]
    fn fc_beats_ring_for_large_payload() {
        let big = 256 * MB;
        let r = collective_ns(CommType::AllReduce, big, &ring(16));
        let f = collective_ns(CommType::AllReduce, big, &dim(TopologyKind::FullyConnected, 16));
        assert!(f < r, "fully-connected should beat ring: {f} vs {r}");
    }

    #[test]
    fn p2p_is_single_hop() {
        let d = ring(8);
        assert_eq!(p2p_ns(0, &d), 0);
        let t = p2p_ns(MB, &d);
        assert!((t as f64 - d.hop_ns(MB as f64)).abs() < 1.0);
    }

    #[test]
    fn alltoall_scales_with_fanout() {
        let d = dim(TopologyKind::FullyConnected, 8);
        let t8 = collective_ns(CommType::AllToAll, 8 * MB, &d);
        let d64 = dim(TopologyKind::FullyConnected, 64);
        let t64 = collective_ns(CommType::AllToAll, 8 * MB, &d64);
        // Same per-NPU payload spread across more peers → smaller per-link
        // messages → cheaper per-phase on FC.
        assert!(t64 < t8);
    }
}
