//! Collective completion-time models (the ASTRA-sim system layer's
//! collective scheduler) — algorithm-selected, topology-checked.
//!
//! Completion-time models follow the standard α-β formulation
//! (`steps × latency + moved_bytes / bandwidth`), e.g. ring all-reduce
//! `2(N-1)(α + (M/N)/β)`. Ring-style schedules keep every link busy every
//! phase, so chunking cannot speed up a single collective; chunk
//! pipelining pays off when a collective spans *multiple* network
//! dimensions, which [`crate::sim::system`] realizes by splitting the
//! payload into [`ChunkCfg::chunks`] sub-collectives whose legs overlap
//! across dimension resources.
//!
//! Since the N-dim co-design redesign the *algorithm* is an explicit
//! argument ([`CollectiveAlgo`], carried per dimension by
//! [`NetDim::algo`]) instead of being implied by the topology. Per
//! algorithm:
//!
//! * **Ring** — bandwidth-optimal ring schedule: `2(N-1)` phases of
//!   `M/N` (reduce-scatter + all-gather).
//! * **HalvingDoubling** — recursive halving/doubling: `2·log2(N)`
//!   latency-bound phases, `2M(N-1)/N` total bytes at each port.
//! * **Direct** — single-phase pairwise exchange, twice (reduce then
//!   broadcast), all peer links in parallel.
//! * **DimOrdered** — the torus schedule: reduce-scatter on rows,
//!   all-reduce on columns over the row-sharded payload, all-gather on
//!   rows (uses [`NetDim::torus_dims`]).
//!
//! The topology constrains which algorithms are *realizable*
//! ([`CollectiveAlgo::admissible_on`], enforced by [`NetDim::validate`]
//! at simulation / config / verify boundaries) and supplies the link
//! parameters; all-to-all — a fixed traffic pattern, not a schedulable
//! algorithm — stays topology-shaped (ring hop distance, switch
//! store-and-forward, torus Manhattan paths, rail planes, dragonfly's
//! 3-hop local-global-local worst case).

use super::network::{CollectiveAlgo, NetDim, TopologyKind};
use crate::workload::CommType;

/// Chunking configuration for hierarchical (multi-dimension) pipelining.
#[derive(Debug, Clone, Copy)]
pub struct ChunkCfg {
    /// Number of pipeline chunks a multi-dimension collective is split
    /// into (≥ 1); 1 disables pipelining.
    pub chunks: usize,
}

impl Default for ChunkCfg {
    fn default() -> Self {
        ChunkCfg { chunks: 4 }
    }
}

/// Completion time in ns for `comm` moving `bytes` across `dim.npus`
/// participants of `dim`, running `algo` (pass [`NetDim::algo`] for the
/// dimension's configured algorithm, or
/// [`CollectiveAlgo::default_for`]`(dim.kind)` for the legacy implicit
/// pairing — the two agree for validated dimensions built via
/// [`NetDim::new`]).
///
/// `bytes` semantics match the workload file: for ALLREDUCE it is the full
/// gradient buffer per NPU; for ALLGATHER the gathered output size; for
/// REDUCESCATTER the input size; for ALLTOALL the per-NPU send total.
///
/// The function is total: inadmissible (algo × topology) pairs still
/// evaluate (admissibility is enforced by [`NetDim::validate`] at the
/// simulation and config boundaries, where a typed error can name the
/// scenario), and `DimOrdered` falls back to factoring `npus` whatever
/// the kind.
// lint: hot-path
pub fn collective_ns(comm: CommType, bytes: u64, algo: CollectiveAlgo, dim: &NetDim) -> u64 {
    let n = dim.npus as f64;
    if dim.npus <= 1 || bytes == 0 {
        return 0;
    }
    let m = bytes as f64;
    let t = match comm {
        CommType::None => 0.0,
        CommType::AllReduce => match algo {
            // Reduce-scatter + all-gather, each N-1 phases of M/N chunks.
            CollectiveAlgo::Ring => phases(2.0 * (n - 1.0), m / n, dim),
            // Direct: each NPU sends its shard to every peer, twice
            // (reduce then broadcast), all links in parallel.
            CollectiveAlgo::Direct => 2.0 * dim.hop_ns(m / n),
            // Halving/doubling: 2·log2(N) phases, the i-th moving M/2^i;
            // total bytes ≈ 2M(N-1)/N at the port.
            CollectiveAlgo::HalvingDoubling => {
                let steps = 2.0 * n.log2().ceil();
                steps * dim.latency_ns + 2.0 * dim.ser_ns(m * (n - 1.0) / n)
            }
            CollectiveAlgo::DimOrdered => {
                let (r, cdim) = dim.torus_dims();
                let (r, cd) = (r as f64, cdim as f64);
                // RS along rows (r-1 phases of M/r), AR along cols on the
                // row shard (2(c-1) phases of M/(r·c)), AG along rows.
                phases(r - 1.0, m / r, dim)
                    + phases(2.0 * (cd - 1.0), m / (r * cd), dim)
                    + phases(r - 1.0, m / r, dim)
            }
        },
        CommType::AllGather | CommType::ReduceScatter => match algo {
            CollectiveAlgo::Ring => phases(n - 1.0, m / n, dim),
            CollectiveAlgo::Direct => dim.hop_ns(m / n),
            CollectiveAlgo::HalvingDoubling => {
                n.log2().ceil() * dim.latency_ns + dim.ser_ns(m * (n - 1.0) / n)
            }
            CollectiveAlgo::DimOrdered => {
                let (r, cdim) = dim.torus_dims();
                let (r, cd) = (r as f64, cdim as f64);
                phases(r - 1.0, m / r, dim) + phases(cd - 1.0, m / (r * cd), dim)
            }
        },
        // All-to-all is a fixed pattern, not an algorithm choice: its
        // cost is shaped by the physical arrangement alone.
        CommType::AllToAll => match dim.kind {
            // Each NPU exchanges M/N with every peer.
            TopologyKind::FullyConnected => dim.hop_ns(m / n),
            // Ring: average hop distance N/4 (bidirectional), N-1 partners.
            TopologyKind::Ring => {
                (n - 1.0) * dim.latency_ns + dim.ser_ns(m * (n - 1.0) / n) * (n / 4.0).max(1.0)
            }
            // Switch: serialized at the NIC: M(N-1)/N out.
            TopologyKind::Switch => 2.0 * dim.latency_ns + dim.ser_ns(m * (n - 1.0) / n),
            // Rails: parallel non-blocking switch planes — switch cost.
            TopologyKind::RailOptimized => {
                2.0 * dim.latency_ns + dim.ser_ns(m * (n - 1.0) / n)
            }
            // Dragonfly: worst-case minimal path is local-global-local.
            TopologyKind::Dragonfly => {
                3.0 * dim.latency_ns + dim.ser_ns(m * (n - 1.0) / n)
            }
            TopologyKind::Torus2D => {
                let (r, cdim) = dim.torus_dims();
                let (r, cd) = (r as f64, cdim as f64);
                (r + cd - 2.0) * dim.latency_ns
                    + dim.ser_ns(m * (n - 1.0) / n) * ((r + cd) / 4.0).max(1.0)
            }
        },
    };
    t.ceil() as u64
}

/// `steps` sequential phases, each moving `phase_bytes` on every link
/// concurrently (ring-style schedules keep all links busy every phase, so
/// intra-collective chunking cannot reduce this — pipelining gains come
/// from overlapping *dimensions*, which the system layer's chunked
/// hierarchical route provides).
fn phases(steps: f64, phase_bytes: f64, dim: &NetDim) -> f64 {
    steps * dim.hop_ns(phase_bytes)
}

/// Point-to-point transfer time (pipeline-parallel stage boundary).
pub fn p2p_ns(bytes: u64, dim: &NetDim) -> u64 {
    if bytes == 0 {
        return 0;
    }
    dim.hop_ns(bytes as f64).ceil() as u64
}

/// Theoretical lower bound for an all-reduce on any topology: each NPU
/// must send and receive `2·M·(N-1)/N` bytes through its slowest port.
pub fn allreduce_lower_bound_ns(bytes: u64, dim: &NetDim) -> u64 {
    let n = dim.npus as f64;
    if dim.npus <= 1 {
        return 0;
    }
    (2.0 * bytes as f64 * (n - 1.0) / n / dim.bandwidth_gbps).ceil() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring(n: usize) -> NetDim {
        NetDim::new(TopologyKind::Ring, n, 100.0, 500.0)
    }

    fn dim(kind: TopologyKind, n: usize) -> NetDim {
        NetDim::new(kind, n, 100.0, 500.0)
    }

    /// Default-algorithm shorthand: the legacy implicit pairing.
    fn coll(comm: CommType, bytes: u64, d: &NetDim) -> u64 {
        collective_ns(comm, bytes, d.algo, d)
    }

    const MB: u64 = 1 << 20;

    const ALL_KINDS: [TopologyKind; 6] = [
        TopologyKind::Ring,
        TopologyKind::FullyConnected,
        TopologyKind::Switch,
        TopologyKind::Torus2D,
        TopologyKind::RailOptimized,
        TopologyKind::Dragonfly,
    ];

    const ALL_ALGOS: [CollectiveAlgo; 4] = [
        CollectiveAlgo::Ring,
        CollectiveAlgo::HalvingDoubling,
        CollectiveAlgo::Direct,
        CollectiveAlgo::DimOrdered,
    ];

    /// Sizes valid for every kind (torus needs composite factorizations).
    const SIZES: [usize; 4] = [4, 8, 16, 64];

    #[test]
    fn ring_allreduce_matches_textbook() {
        let d = ring(8);
        let t = coll(CommType::AllReduce, 8 * MB, &d);
        // 2(N-1) × (α + (M/N)/β) = 14 × (500 + 1MiB/100GBps)
        let expect = 14.0 * (500.0 + (MB as f64) / 100.0);
        assert!((t as f64 - expect).abs() < 2.0, "{t} vs {expect}");
    }

    #[test]
    fn linearity_in_bandwidth_term() {
        // Doubling bandwidth should roughly halve the serialization part.
        let slow = ring(8);
        let fast = NetDim { bandwidth_gbps: 200.0, ..slow };
        let big = 256 * MB;
        let ts = coll(CommType::AllReduce, big, &slow) as f64;
        let tf = coll(CommType::AllReduce, big, &fast) as f64;
        let ratio = ts / tf;
        assert!(ratio > 1.9 && ratio < 2.1, "ratio {ratio}");
    }

    /// The legacy per-topology match, verbatim — the reference the
    /// decoupled `collective_ns(comm, bytes, algo, dim)` must reproduce
    /// byte-for-byte under the default topology→algorithm mapping, so
    /// every pre-redesign ranking is unchanged.
    fn legacy_collective_ns(comm: CommType, bytes: u64, dim: &NetDim) -> u64 {
        let n = dim.npus as f64;
        if dim.npus <= 1 || bytes == 0 {
            return 0;
        }
        let m = bytes as f64;
        let t = match comm {
            CommType::None => 0.0,
            CommType::AllReduce => match dim.kind {
                TopologyKind::Ring => phases(2.0 * (n - 1.0), m / n, dim),
                TopologyKind::FullyConnected => 2.0 * dim.hop_ns(m / n),
                TopologyKind::Switch => {
                    let steps = 2.0 * n.log2().ceil();
                    steps * dim.latency_ns + 2.0 * dim.ser_ns(m * (n - 1.0) / n)
                }
                _ => {
                    let (r, cdim) = dim.torus_dims();
                    let (r, cd) = (r as f64, cdim as f64);
                    phases(r - 1.0, m / r, dim)
                        + phases(2.0 * (cd - 1.0), m / (r * cd), dim)
                        + phases(r - 1.0, m / r, dim)
                }
            },
            CommType::AllGather | CommType::ReduceScatter => match dim.kind {
                TopologyKind::Ring => phases(n - 1.0, m / n, dim),
                TopologyKind::FullyConnected => dim.hop_ns(m / n),
                TopologyKind::Switch => {
                    n.log2().ceil() * dim.latency_ns + dim.ser_ns(m * (n - 1.0) / n)
                }
                _ => {
                    let (r, cdim) = dim.torus_dims();
                    let (r, cd) = (r as f64, cdim as f64);
                    phases(r - 1.0, m / r, dim) + phases(cd - 1.0, m / (r * cd), dim)
                }
            },
            CommType::AllToAll => match dim.kind {
                TopologyKind::FullyConnected => dim.hop_ns(m / n),
                TopologyKind::Ring => {
                    (n - 1.0) * dim.latency_ns
                        + dim.ser_ns(m * (n - 1.0) / n) * (n / 4.0).max(1.0)
                }
                TopologyKind::Switch => 2.0 * dim.latency_ns + dim.ser_ns(m * (n - 1.0) / n),
                _ => {
                    let (r, cdim) = dim.torus_dims();
                    let (r, cd) = (r as f64, cdim as f64);
                    (r + cd - 2.0) * dim.latency_ns
                        + dim.ser_ns(m * (n - 1.0) / n) * ((r + cd) / 4.0).max(1.0)
                }
            },
        };
        t.ceil() as u64
    }

    #[test]
    fn default_algorithm_mapping_is_byte_identical_to_the_legacy_model() {
        let legacy_kinds = [
            TopologyKind::Ring,
            TopologyKind::FullyConnected,
            TopologyKind::Switch,
            TopologyKind::Torus2D,
        ];
        for kind in legacy_kinds {
            for n in [2usize, 4, 8, 16, 64] {
                let d = dim(kind, n);
                for comm in [
                    CommType::AllReduce,
                    CommType::AllGather,
                    CommType::ReduceScatter,
                    CommType::AllToAll,
                ] {
                    for mb in [0u64, 1, 4, 64, 256] {
                        let bytes = mb * MB + mb; // off-round payloads too
                        assert_eq!(
                            collective_ns(comm, bytes, CollectiveAlgo::default_for(kind), &d),
                            legacy_collective_ns(comm, bytes, &d),
                            "{kind:?} {comm:?} n={n} bytes={bytes}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn every_admissible_combination_respects_the_lower_bound() {
        for kind in ALL_KINDS {
            for algo in ALL_ALGOS.into_iter().filter(|a| a.admissible_on(kind)) {
                for n in SIZES {
                    let d = NetDim { algo, ..dim(kind, n) };
                    assert!(d.validate().is_ok(), "{kind:?}+{algo:?} n={n}");
                    let t = collective_ns(CommType::AllReduce, 64 * MB, algo, &d);
                    let lb = allreduce_lower_bound_ns(64 * MB, &d);
                    // The port bound assumes one link per NPU; Direct uses
                    // N-1 parallel links, so its aggregate-bandwidth bound
                    // is lb/(N-1). No algorithm may beat that.
                    let relaxed = if algo == CollectiveAlgo::Direct {
                        lb / (n as u64 - 1).max(1)
                    } else {
                        lb
                    };
                    assert!(
                        t >= relaxed,
                        "{kind:?}+{algo:?} N={n}: {t} < relaxed lb {relaxed}"
                    );
                }
            }
        }
    }

    #[test]
    fn every_admissible_combination_is_monotone_in_bytes() {
        for kind in ALL_KINDS {
            for algo in ALL_ALGOS.into_iter().filter(|a| a.admissible_on(kind)) {
                for comm in [CommType::AllReduce, CommType::AllGather, CommType::AllToAll] {
                    let d = NetDim { algo, ..dim(kind, 16) };
                    let mut prev = 0;
                    for mb in [1u64, 4, 16, 64, 256] {
                        let t = collective_ns(comm, mb * MB, algo, &d);
                        assert!(t > prev, "{kind:?}+{algo:?} {comm:?}: not monotone in bytes");
                        prev = t;
                    }
                }
            }
        }
    }

    #[test]
    fn every_admissible_combination_has_free_trivial_cases() {
        for kind in ALL_KINDS {
            for algo in ALL_ALGOS.into_iter().filter(|a| a.admissible_on(kind)) {
                let d1 = NetDim { algo, ..dim(kind, 1) };
                assert_eq!(collective_ns(CommType::AllReduce, MB, algo, &d1), 0);
                let d = NetDim { algo, ..dim(kind, 16) };
                assert_eq!(collective_ns(CommType::AllReduce, 0, algo, &d), 0);
                assert_eq!(collective_ns(CommType::None, MB, algo, &d), 0);
            }
        }
    }

    #[test]
    fn algorithm_choice_changes_cost_on_the_same_fabric() {
        // The whole point of co-design: on one switch fabric, the three
        // admissible algorithms price differently — latency-dominated
        // payloads favor fewer phases, bandwidth-dominated ones favor
        // parallel links.
        let d = dim(TopologyKind::Switch, 16);
        let small = 4 * 1024;
        let hd = collective_ns(CommType::AllReduce, small, CollectiveAlgo::HalvingDoubling, &d);
        let rg = collective_ns(CommType::AllReduce, small, CollectiveAlgo::Ring, &d);
        assert!(hd < rg, "tiny payload: 2·log2(N) phases beat 2(N-1): {hd} vs {rg}");
        let big = 256 * MB;
        let hd = collective_ns(CommType::AllReduce, big, CollectiveAlgo::HalvingDoubling, &d);
        let di = collective_ns(CommType::AllReduce, big, CollectiveAlgo::Direct, &d);
        assert!(di < hd, "huge payload: direct parallel links beat HD: {di} vs {hd}");
    }

    #[test]
    fn respects_lower_bound() {
        for kind in [
            TopologyKind::Ring,
            TopologyKind::FullyConnected,
            TopologyKind::Switch,
            TopologyKind::Torus2D,
        ] {
            // Composite sizes only: a validated torus needs both factors
            // > 1 (primes are now typed config errors).
            for n in [4usize, 8, 16, 64] {
                let d = dim(kind, n);
                let t = coll(CommType::AllReduce, 64 * MB, &d);
                let lb = allreduce_lower_bound_ns(64 * MB, &d);
                // The port bound assumes one link per NPU; FullyConnected
                // has N-1 parallel links, so its aggregate-bandwidth bound
                // is lb/(N-1). No topology may beat that.
                let relaxed = lb / (n as u64 - 1).max(1);
                assert!(t >= relaxed, "{kind:?} N={n}: {t} < relaxed lb {relaxed}");
                if kind == TopologyKind::Ring {
                    // Single-port topology must respect the full bound.
                    assert!(t >= lb, "Ring N={n}: {t} < lb {lb}");
                }
            }
        }
    }

    #[test]
    fn trivial_cases_are_free() {
        let d = ring(1);
        assert_eq!(coll(CommType::AllReduce, MB, &d), 0);
        let d8 = ring(8);
        assert_eq!(coll(CommType::AllReduce, 0, &d8), 0);
        assert_eq!(coll(CommType::None, MB, &d8), 0);
    }

    #[test]
    fn allgather_is_half_of_allreduce_on_ring() {
        let d = ring(8);
        let ar = coll(CommType::AllReduce, 8 * MB, &d);
        let ag = coll(CommType::AllGather, 8 * MB, &d);
        // Equal up to the two formulas' independent ceil() rounding.
        assert!((ar as i64 - (ag as i64) * 2).abs() <= 2, "{ar} vs 2x{ag}");
    }

    #[test]
    fn fc_beats_ring_for_large_payload() {
        let big = 256 * MB;
        let r = coll(CommType::AllReduce, big, &ring(16));
        let f = coll(CommType::AllReduce, big, &dim(TopologyKind::FullyConnected, 16));
        assert!(f < r, "fully-connected should beat ring: {f} vs {r}");
    }

    #[test]
    fn p2p_is_single_hop() {
        let d = ring(8);
        assert_eq!(p2p_ns(0, &d), 0);
        let t = p2p_ns(MB, &d);
        assert!((t as f64 - d.hop_ns(MB as f64)).abs() < 1.0);
    }

    #[test]
    fn alltoall_scales_with_fanout() {
        let d = dim(TopologyKind::FullyConnected, 8);
        let t8 = coll(CommType::AllToAll, 8 * MB, &d);
        let d64 = dim(TopologyKind::FullyConnected, 64);
        let t64 = coll(CommType::AllToAll, 8 * MB, &d64);
        // Same per-NPU payload spread across more peers → smaller per-link
        // messages → cheaper per-phase on FC.
        assert!(t64 < t8);
    }

    #[test]
    fn alltoall_covers_the_new_kinds() {
        let rail = dim(TopologyKind::RailOptimized, 16);
        let fly = dim(TopologyKind::Dragonfly, 16);
        let sw = dim(TopologyKind::Switch, 16);
        let (tr, tf, ts) = (
            coll(CommType::AllToAll, 8 * MB, &rail),
            coll(CommType::AllToAll, 8 * MB, &fly),
            coll(CommType::AllToAll, 8 * MB, &sw),
        );
        assert!(tr > 0 && tf > 0);
        assert_eq!(tr, ts, "a rail plane prices all-to-all like its switch");
        assert!(tf > ts, "dragonfly pays an extra global-link hop");
    }
}
