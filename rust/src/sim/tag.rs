//! Compact task identity: the allocation-free replacement for the old
//! heap-allocated `String` task labels.
//!
//! Every task the workload layer emits is identified by *iteration ×
//! phase × layer* (plus a microbatch/chunk ordinal and a communication
//! annotation). A [`TaskTag`] packs that into a small `Copy` struct, so
//! building a task graph performs **zero per-task string allocations**;
//! the human-readable label (`it0.fwd.L17:ALLREDUCE@dim0`-style) is
//! rendered on demand via `Display` — only on error paths and in
//! reports, never in the simulation hot loop.

use crate::workload::CommType;
use std::fmt;

/// Training-loop phase a task belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TagPhase {
    /// Ad-hoc task (hand-built graphs, benches, engine tests).
    #[default]
    Adhoc,
    /// Forward compute / activation collective (flat strategies).
    Fwd,
    /// Weight-gradient compute / gradient collective.
    Wg,
    /// Input-gradient compute / collective.
    Ig,
    /// Optimizer update.
    Upd,
    /// Pipeline forward (`layer` = stage, `sub` = microbatch).
    PipeFwd,
    /// Pipeline backward (`layer` = stage, `sub` = microbatch).
    PipeBwd,
    /// Pipeline per-stage gradient sync (`layer` = stage).
    PipeWg,
    /// Pipeline per-stage optimizer update (`layer` = stage).
    PipeUpd,
}

/// Communication annotation attached to a task, mirroring the suffix the
/// old string labels carried (`:ALLREDUCE@dim0`, `:RS.c3@dim0`, …).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TagComm {
    /// Pure compute — no communication annotation.
    #[default]
    None,
    /// Single-shot collective on one network dimension.
    Coll {
        /// Collective kind.
        kind: CommType,
        /// Network dimension index.
        dim: u8,
    },
    /// Hierarchical all-reduce leg: reduce-scatter of chunk `chunk` on
    /// the scale-up dimension.
    Rs {
        /// Chunk ordinal.
        chunk: u8,
    },
    /// Hierarchical all-reduce leg: scale-out all-reduce of a chunk's
    /// shard on dimension `dim`.
    Ar {
        /// Chunk ordinal.
        chunk: u8,
        /// Network dimension index.
        dim: u8,
    },
    /// Hierarchical all-reduce leg: all-gather of chunk `chunk` back on
    /// the scale-up dimension.
    Ag {
        /// Chunk ordinal.
        chunk: u8,
    },
    /// Zero-duration join of the per-chunk tails.
    Join,
    /// Point-to-point stage-boundary transfer on dimension `dim`.
    P2p {
        /// Network dimension index.
        dim: u8,
    },
}

/// Compact task identity (16 bytes, `Copy`): iteration × phase × layer
/// (× microbatch/chunk × comm annotation).
///
/// `layer` is the workload layer index for flat strategies, the stage
/// index for pipeline phases, and a free-form ordinal for
/// [`TagPhase::Adhoc`] tasks. `sub` is the microbatch for pipeline
/// phases and unused elsewhere (counters saturate rather than wrap, so a
/// tag is always safe to render).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TaskTag {
    /// Training iteration.
    pub iter: u16,
    /// Phase discriminator.
    pub phase: TagPhase,
    /// Layer / stage / ad-hoc ordinal.
    pub layer: u32,
    /// Microbatch (pipeline) ordinal.
    pub sub: u16,
    /// Communication annotation.
    pub comm: TagComm,
}

impl TaskTag {
    /// Tag for a flat-strategy task: iteration × phase × layer index.
    pub fn flat(iter: usize, phase: TagPhase, layer: usize) -> TaskTag {
        TaskTag {
            iter: saturate_u16(iter),
            phase,
            layer: saturate_u32(layer),
            sub: 0,
            comm: TagComm::None,
        }
    }

    /// Tag for a pipeline task: iteration × phase × stage × microbatch.
    pub fn pipe(iter: usize, phase: TagPhase, stage: usize, microbatch: usize) -> TaskTag {
        TaskTag {
            iter: saturate_u16(iter),
            phase,
            layer: saturate_u32(stage),
            sub: saturate_u16(microbatch),
            comm: TagComm::None,
        }
    }

    /// Tag for a hand-built task (benches, tests): just an ordinal.
    pub fn adhoc(ordinal: usize) -> TaskTag {
        TaskTag { layer: saturate_u32(ordinal), ..TaskTag::default() }
    }

    /// The same tag with a communication annotation attached.
    pub fn with_comm(self, comm: TagComm) -> TaskTag {
        TaskTag { comm, ..self }
    }
}

fn saturate_u16(v: usize) -> u16 {
    v.min(u16::MAX as usize) as u16
}

fn saturate_u32(v: usize) -> u32 {
    v.min(u32::MAX as usize) as u32
}

impl fmt::Display for TaskTag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.phase {
            TagPhase::Adhoc => write!(f, "task{}", self.layer)?,
            TagPhase::Fwd => write!(f, "it{}.fwd.L{}", self.iter, self.layer)?,
            TagPhase::Wg => write!(f, "it{}.wg.L{}", self.iter, self.layer)?,
            TagPhase::Ig => write!(f, "it{}.ig.L{}", self.iter, self.layer)?,
            TagPhase::Upd => write!(f, "it{}.upd.L{}", self.iter, self.layer)?,
            TagPhase::PipeFwd => write!(f, "it{}.f.s{}.m{}", self.iter, self.layer, self.sub)?,
            TagPhase::PipeBwd => write!(f, "it{}.b.s{}.m{}", self.iter, self.layer, self.sub)?,
            TagPhase::PipeWg => write!(f, "it{}.wg.s{}", self.iter, self.layer)?,
            TagPhase::PipeUpd => write!(f, "it{}.upd.s{}", self.iter, self.layer)?,
        }
        match self.comm {
            TagComm::None => Ok(()),
            TagComm::Coll { kind, dim } => write!(f, ":{}@dim{}", kind.token(), dim),
            TagComm::Rs { chunk } => write!(f, ":RS.c{chunk}@dim0"),
            TagComm::Ar { chunk, dim } => write!(f, ":AR.c{chunk}@dim{dim}"),
            TagComm::Ag { chunk } => write!(f, ":AG.c{chunk}@dim0"),
            TagComm::Join => write!(f, ":join"),
            TagComm::P2p { dim } => write!(f, ":P2P@dim{dim}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_are_small_and_copy() {
        // The whole point: a tag must not regress to heap data.
        assert!(std::mem::size_of::<TaskTag>() <= 16);
        let t = TaskTag::flat(1, TagPhase::Fwd, 17);
        let u = t; // Copy, not move.
        assert_eq!(t, u);
    }

    #[test]
    fn render_matches_label_shapes() {
        assert_eq!(TaskTag::flat(0, TagPhase::Fwd, 3).to_string(), "it0.fwd.L3");
        assert_eq!(
            TaskTag::flat(2, TagPhase::Wg, 5)
                .with_comm(TagComm::Coll { kind: CommType::AllReduce, dim: 0 })
                .to_string(),
            "it2.wg.L5:ALLREDUCE@dim0"
        );
        let ar = TaskTag::flat(0, TagPhase::Wg, 1).with_comm(TagComm::Ar { chunk: 3, dim: 1 });
        assert_eq!(ar.to_string(), "it0.wg.L1:AR.c3@dim1");
        assert_eq!(TaskTag::pipe(1, TagPhase::PipeFwd, 2, 7).to_string(), "it1.f.s2.m7");
        let p2p = TaskTag::pipe(0, TagPhase::PipeBwd, 1, 0).with_comm(TagComm::P2p { dim: 1 });
        assert_eq!(p2p.to_string(), "it0.b.s1.m0:P2P@dim1");
        assert_eq!(TaskTag::adhoc(9).to_string(), "task9");
    }

    #[test]
    fn counters_saturate_instead_of_wrapping() {
        let t = TaskTag::pipe(1 << 20, TagPhase::PipeFwd, 7, 1 << 20);
        assert_eq!(t.iter, u16::MAX);
        assert_eq!(t.sub, u16::MAX);
    }
}
