//! The distributed-training simulator (ASTRA-sim-class substrate).
//!
//! Layered exactly like the system the paper targets (§2.2, Fig. 2):
//!
//! * [`engine`] — discrete-event core (task graph over exclusive
//!   resources with FIFO/LIFO queueing), batch-dispatching whole
//!   same-timestamp completion waves per event-loop iteration.
//! * [`queue`] — the allocation-free, monotone integer-time calendar
//!   queue ordering the engine's completion events (byte-identical pop
//!   order to a `(finish, seq, task)` min-heap).
//! * [`network`] — analytical network layer: N-dimension hierarchical
//!   topologies (ring / fully-connected / switch / torus / rail-optimized
//!   / dragonfly) with per-link latency + bandwidth, a per-dimension
//!   [`CollectiveAlgo`] with an admissibility check, and the typed
//!   [`NetworkSpec`] compact-string grammar (the Garnet/ns-3 stand-in).
//! * [`collectives`] — algorithm-selected collective completion-time
//!   models (`collective_ns(comm, bytes, algo, dim)`) with chunk
//!   pipelining.
//! * [`system`] — maps workload collectives onto network dimensions
//!   (hierarchical all-reduce, scale-up activation traffic) and applies
//!   the communication scheduling policy.
//! * [`tag`] — compact `Copy` task identity ([`tag::TaskTag`]), the
//!   allocation-free replacement for label strings.
//! * [`training`] — the workload layer: training-loop schedules for
//!   DATA / MODEL / HYBRID / PIPELINE parallelism, consuming the
//!   [`crate::workload::Workload`] descriptions ModTrans emits.

pub mod collectives;
pub mod engine;
pub mod network;
pub mod queue;
pub mod system;
pub mod tag;
pub mod training;

pub use collectives::{collective_ns, ChunkCfg};
pub use engine::{verify_graph, Engine, Policy, RunScratch, Schedule, TaskGraph};
pub use network::{
    CollectiveAlgo, DimSpec, NetDim, Network, NetworkSpec, TopologyKind, MAX_DIMS,
};
pub use queue::CalendarQueue;
pub use system::{CommRouter, SystemConfig};
pub use tag::{TagComm, TagPhase, TaskTag};
pub use training::{
    partition_compute_costs, simulate, simulate_with, verify_workload, GraphCheck, LayerBreakdown,
    PipelineSchedule, SimConfig, SimReport, SimScratch,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::translator::{to_workload, ConstantCompute, RooflineCompute, TranslateOpts};
    use crate::workload::Parallelism;
    use crate::zoo::{self, WeightFill, ZooOpts};

    /// End-to-end inside the library: zoo → translate → simulate.
    #[test]
    fn resnet50_translated_workload_simulates() {
        let m = zoo::get("resnet50", ZooOpts { weights: WeightFill::Empty }).unwrap();
        let summary = crate::translator::extract(&m, 32).unwrap();
        let opts = TranslateOpts { parallelism: Parallelism::Data, ..Default::default() };
        let w = to_workload(&summary, opts, &RooflineCompute::default()).unwrap();
        let cfg = SimConfig { iterations: 2, ..Default::default() };
        let r = simulate(&w, &cfg).unwrap();
        assert!(r.total_ns > 0);
        assert!(r.events > 54 * 4);
        assert!(r.compute_utilization > 0.0 && r.compute_utilization <= 1.0);
    }

    #[test]
    fn dp_beats_mp_for_conv_nets_on_fast_interconnect() {
        // The classic result the simulator must reproduce: CNNs with small
        // weights & large activations prefer data parallelism.
        let m = zoo::get("resnet50", ZooOpts { weights: WeightFill::Empty }).unwrap();
        let summary = crate::translator::extract(&m, 32).unwrap();
        let compute = ConstantCompute(20_000);
        let cfg = SimConfig { iterations: 2, ..Default::default() };
        let dp = {
            let w = to_workload(
                &summary,
                TranslateOpts { parallelism: Parallelism::Data, ..Default::default() },
                &compute,
            )
            .unwrap();
            simulate(&w, &cfg).unwrap()
        };
        let mp = {
            let w = to_workload(
                &summary,
                TranslateOpts { parallelism: Parallelism::Model, ..Default::default() },
                &compute,
            )
            .unwrap();
            simulate(&w, &cfg).unwrap()
        };
        assert!(
            dp.iteration_ns < mp.iteration_ns,
            "DP {} should beat MP {} for ResNet-50 at batch 32",
            dp.iteration_ns,
            mp.iteration_ns
        );
    }
}
