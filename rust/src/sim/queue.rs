//! Calendar-queue event scheduler: the allocation-free, monotone
//! integer-time completion queue under the discrete-event engine.
//!
//! The engine's original completion queue was a
//! `BinaryHeap<Reverse<(u64, u64, TaskId)>>` — correct, but every push
//! and pop pays a comparison-driven sift through a pointer-ordered heap,
//! and same-timestamp batches (the common case in synchronous training
//! graphs, where whole layers of tasks finish together) cost one full
//! pop each. [`CalendarQueue`] replaces it with a classic
//! calendar/ladder-queue hybrid specialized to the engine's access
//! pattern:
//!
//! * **Monotone time.** Pop times never decrease, and pushes are always
//!   `>= ` the last popped time (a completion scheduled *now* or later).
//!   This is the DES invariant that lets the queue keep a one-way
//!   cursor instead of a general priority structure.
//! * **Windowed wheel.** 64 buckets cover a contiguous window of
//!   `64 << shift` nanoseconds; an event at time `t` lands in slot
//!   `(t >> shift) - win_base`. A `u64` occupancy bitmask turns
//!   find-next-nonempty-bucket into one `trailing_zeros`.
//! * **Overflow + adaptive width.** Events beyond the window wait in an
//!   overflow list. When the wheel drains, the queue *rotates*: it
//!   rescales `shift` so the entire pending span fits the 64-slot
//!   window and re-buckets the overflow — so the bucket width tracks
//!   the workload's actual event spacing (ns-scale micro-graphs and
//!   ms-scale training iterations both bucket well) with no tuning
//!   parameter.
//! * **Exact heap order.** Buckets are sorted lazily by `(time, seq)`
//!   the first time they are popped from (and re-sorted only after new
//!   pushes land in them), so the pop sequence is *byte-identical* to
//!   the old heap's `(finish_time, seq, task)` order — the property
//!   every golden makespan and thread-count determinism diff rests on.
//! * **Batch pop.** [`CalendarQueue::pop_batch_into`] drains *all*
//!   events sharing the minimum timestamp in one bucket operation, so
//!   the engine's run loop processes a whole completion wave per
//!   iteration instead of re-entering the queue per event.
//!
//! # Allocation discipline
//!
//! Steady state performs no heap allocation: buckets, the overflow
//! list and the caller's batch buffer only grow, and
//! [`CalendarQueue::clear`] keeps every capacity for the next run
//! (the same contract as the rest of `RunScratch`). Rotation reuses
//! the overflow buffer via `mem::take`.

use super::engine::TaskId;

/// Number of wheel slots. A `u64` bitmask indexes them, so this is
/// fixed at 64 — the occupancy scan is a single `trailing_zeros`.
const SLOTS: usize = 64;

/// One scheduled completion: `(time, seq, task)`. `seq` is the
/// engine's global dispatch counter, which makes every key unique and
/// pins FIFO order among equal-time completions — exactly the tuple
/// the old binary heap ordered on.
type Event = (u64, u64, TaskId);

/// Monotone integer-time calendar queue over `(time, seq, task)`
/// events. See the module docs for the structure; the public contract
/// is:
///
/// * `push(time, ..)` requires `time >= ` the last popped time (debug
///   asserted). Seeding at time 0 before the first pop is always valid.
/// * `pop` / `pop_batch_into` return events in exactly ascending
///   `(time, seq)` order — byte-identical to a min-heap over the same
///   tuples.
#[derive(Debug)]
pub struct CalendarQueue {
    /// Wheel slots; slot `i` holds events whose global bucket index
    /// (`time >> shift`) equals `win_base + i`. Unordered until the
    /// slot is popped from (lazy sort).
    buckets: Vec<Vec<Event>>,
    /// Events whose bucket index falls beyond the current window; moved
    /// into the wheel (with a freshly adapted width) on rotation.
    overflow: Vec<Event>,
    /// Bit `i` set ⇔ `buckets[i]` is non-empty.
    occupied: u64,
    /// Bit `i` set ⇔ `buckets[i]` received a push since it was last
    /// sorted.
    unsorted: u64,
    /// log2 of the bucket width in time units.
    shift: u32,
    /// Global bucket index mapped to slot 0. Only changes on rotation,
    /// which requires an empty wheel — so a slot never mixes events
    /// from two different global buckets (no calendar "years").
    win_base: u64,
    /// Last popped timestamp — the monotone floor for pushes.
    floor: u64,
    /// Total events queued (wheel + overflow).
    len: usize,
}

impl Default for CalendarQueue {
    fn default() -> Self {
        CalendarQueue::new()
    }
}

impl CalendarQueue {
    /// Empty queue. The initial bucket width is 1 time unit — the first
    /// rotation re-derives the width from the actual pending span, so
    /// the queue self-tunes to any workload timescale.
    pub fn new() -> CalendarQueue {
        CalendarQueue {
            buckets: vec![Vec::new(); SLOTS],
            overflow: Vec::new(),
            occupied: 0,
            unsorted: 0,
            shift: 0,
            win_base: 0,
            floor: 0,
            len: 0,
        }
    }

    /// Number of queued events.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no events are queued.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Drop all events and reset the cursor to time 0, keeping every
    /// bucket's capacity (scratch reuse across runs). The adapted
    /// bucket width is kept too: repeat runs at the same timescale skip
    /// the first re-adaptation, and a changed timescale re-adapts on
    /// the first rotation anyway.
    // lint: hot-path
    pub fn clear(&mut self) {
        for b in &mut self.buckets {
            b.clear();
        }
        self.overflow.clear();
        self.occupied = 0;
        self.unsorted = 0;
        self.win_base = 0;
        self.floor = 0;
        self.len = 0;
    }

    /// Schedule `(time, seq, task)`. `time` must be `>= ` the last
    /// popped timestamp (the DES monotonicity contract — a completion
    /// can only be scheduled at or after *now*).
    // lint: hot-path
    pub fn push(&mut self, time: u64, seq: u64, task: TaskId) {
        debug_assert!(
            time >= self.floor,
            "calendar queue is monotone: push at {time} before floor {}",
            self.floor
        );
        let g = time >> self.shift;
        debug_assert!(g >= self.win_base, "push landed behind the window");
        if g >= self.win_base && g - self.win_base < SLOTS as u64 {
            let slot = (g - self.win_base) as usize;
            self.buckets[slot].push((time, seq, task));
            self.occupied |= 1 << slot;
            self.unsorted |= 1 << slot;
        } else {
            self.overflow.push((time, seq, task));
        }
        self.len += 1;
    }

    /// Pop the single minimum event by `(time, seq)`. Used by the
    /// differential tests; the engine uses [`CalendarQueue::pop_batch_into`].
    // lint: hot-path
    pub fn pop(&mut self) -> Option<Event> {
        let slot = self.min_slot()?;
        let b = &mut self.buckets[slot];
        let e = b.remove(0);
        if b.is_empty() {
            self.occupied &= !(1 << slot);
        }
        self.len -= 1;
        self.floor = e.0;
        Some(e)
    }

    /// Drain every event sharing the minimum timestamp into `out` (in
    /// ascending `seq` order — the old heap's order among equal-time
    /// events), clearing `out` first. Returns that timestamp, or `None`
    /// when the queue is empty. One bucket operation serves the whole
    /// completion wave.
    // lint: hot-path
    pub fn pop_batch_into(&mut self, out: &mut Vec<TaskId>) -> Option<u64> {
        out.clear();
        let slot = self.min_slot()?;
        let b = &mut self.buckets[slot];
        let t = b[0].0;
        let k = b.iter().take_while(|e| e.0 == t).count();
        out.extend(b.drain(..k).map(|e| e.2));
        if b.is_empty() {
            self.occupied &= !(1 << slot);
        }
        self.len -= k;
        self.floor = t;
        Some(t)
    }

    /// Locate (and lazily sort) the slot holding the global minimum.
    /// Rotates the wheel first when every pending event sits in
    /// overflow.
    // lint: hot-path
    fn min_slot(&mut self) -> Option<usize> {
        if self.len == 0 {
            return None;
        }
        if self.occupied == 0 {
            self.rotate();
        }
        let slot = self.occupied.trailing_zeros() as usize;
        if self.unsorted & (1 << slot) != 0 {
            // Keys are unique (seq is a per-run counter), so the
            // unstable sort is deterministic.
            self.buckets[slot].sort_unstable();
            self.unsorted &= !(1 << slot);
        }
        Some(slot)
    }

    /// Re-derive the bucket width from the pending span and move every
    /// overflow event into the (empty) wheel. Called only when
    /// `occupied == 0` and `overflow` is non-empty, so re-bucketing
    /// never has to merge with live slots.
    // lint: hot-path
    fn rotate(&mut self) {
        debug_assert!(self.occupied == 0 && !self.overflow.is_empty());
        let mut ov = std::mem::take(&mut self.overflow);
        let mut min_t = u64::MAX;
        let mut max_t = 0u64;
        for e in &ov {
            min_t = min_t.min(e.0);
            max_t = max_t.max(e.0);
        }
        // Smallest width whose 64-slot window covers the whole span:
        // finest resolution (fewest same-bucket sorts) that still
        // empties the overflow in one rotation.
        let mut shift = 0u32;
        while (max_t >> shift) - (min_t >> shift) >= SLOTS as u64 {
            shift += 1;
        }
        self.shift = shift;
        self.win_base = min_t >> shift;
        for e in ov.drain(..) {
            let slot = ((e.0 >> shift) - self.win_base) as usize;
            self.buckets[slot].push(e);
            self.occupied |= 1 << slot;
            self.unsorted |= 1 << slot;
        }
        self.overflow = ov; // keep the buffer's capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    #[test]
    fn empty_queue_pops_none() {
        let mut q = CalendarQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
        let mut batch = Vec::new();
        assert_eq!(q.pop_batch_into(&mut batch), None);
        assert!(batch.is_empty());
    }

    #[test]
    fn pops_in_time_then_seq_order() {
        let mut q = CalendarQueue::new();
        q.push(30, 0, 7);
        q.push(10, 1, 8);
        q.push(10, 2, 9);
        q.push(20, 3, 1);
        assert_eq!(q.pop(), Some((10, 1, 8)));
        assert_eq!(q.pop(), Some((10, 2, 9)));
        assert_eq!(q.pop(), Some((20, 3, 1)));
        assert_eq!(q.pop(), Some((30, 0, 7)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn batch_pop_drains_exactly_the_equal_time_prefix() {
        let mut q = CalendarQueue::new();
        for (seq, id) in [(0u64, 4usize), (1, 2), (2, 9)] {
            q.push(100, seq, id);
        }
        q.push(101, 3, 5);
        let mut batch = Vec::new();
        assert_eq!(q.pop_batch_into(&mut batch), Some(100));
        assert_eq!(batch, vec![4, 2, 9]); // seq order, not id order
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_batch_into(&mut batch), Some(101));
        assert_eq!(batch, vec![5]);
        assert!(q.is_empty());
    }

    #[test]
    fn same_time_pushes_during_a_wave_come_back_next_batch() {
        // Zero-duration dispatch: the engine pops a batch at t, then
        // pushes new completions at the same t with higher seqs. They
        // must pop in a follow-up batch at the same timestamp.
        let mut q = CalendarQueue::new();
        q.push(50, 0, 0);
        q.push(50, 1, 1);
        let mut batch = Vec::new();
        assert_eq!(q.pop_batch_into(&mut batch), Some(50));
        assert_eq!(batch, vec![0, 1]);
        q.push(50, 2, 2); // scheduled mid-wave
        q.push(60, 3, 3);
        assert_eq!(q.pop_batch_into(&mut batch), Some(50));
        assert_eq!(batch, vec![2]);
        assert_eq!(q.pop_batch_into(&mut batch), Some(60));
        assert_eq!(batch, vec![3]);
    }

    #[test]
    fn distant_events_rotate_through_overflow() {
        let mut q = CalendarQueue::new();
        // Far beyond the initial 64-unit window: exercises overflow +
        // width adaptation.
        q.push(1_000_000_000, 0, 1);
        q.push(5, 1, 2);
        q.push(2_000_000_000, 2, 3);
        assert_eq!(q.pop(), Some((5, 1, 2)));
        assert_eq!(q.pop(), Some((1_000_000_000, 0, 1)));
        // Push near the new floor, interleaved with the far event.
        q.push(1_000_000_001, 3, 4);
        assert_eq!(q.pop(), Some((1_000_000_001, 3, 4)));
        assert_eq!(q.pop(), Some((2_000_000_000, 2, 3)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn bucket_boundary_timestamps_order_correctly() {
        // Times straddling power-of-two bucket edges for every width
        // the adaptive rotation might pick.
        let mut q = CalendarQueue::new();
        let times = [63u64, 64, 65, 127, 128, 4095, 4096, 4097, 1 << 20];
        for (seq, &t) in times.iter().enumerate() {
            q.push(t, seq as u64, seq);
        }
        let mut popped = Vec::new();
        while let Some((t, _, _)) = q.pop() {
            popped.push(t);
        }
        let mut expect = times.to_vec();
        expect.sort_unstable();
        assert_eq!(popped, expect);
    }

    #[test]
    fn clear_resets_for_a_fresh_run_and_keeps_working() {
        let mut q = CalendarQueue::new();
        q.push(1 << 40, 0, 1);
        assert_eq!(q.pop(), Some((1 << 40, 0, 1)));
        q.clear();
        assert!(q.is_empty());
        // After clear the floor is back at 0: a new run may seed small
        // timestamps even though the previous run ended far out.
        q.push(3, 0, 9);
        q.push(1, 1, 8);
        assert_eq!(q.pop(), Some((1, 1, 8)));
        assert_eq!(q.pop(), Some((3, 0, 9)));
    }

    /// The core contract: against a `BinaryHeap<Reverse<Event>>` fed the
    /// identical monotone push/pop schedule, every popped event matches
    /// byte for byte — across narrow, wide, and same-time-heavy
    /// distributions, including power-of-two boundary times.
    #[test]
    fn differential_vs_binary_heap_randomized() {
        for (seed, spread) in
            [(1u64, 3u64), (2, 1000), (3, 1 << 30), (4, 1), (5, 64), (6, 1 << 44)]
        {
            let mut rng = Rng::new(seed);
            let mut cal = CalendarQueue::new();
            let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
            let mut floor = 0u64;
            let mut seq = 0u64;
            for step in 0..5_000usize {
                let push = heap.is_empty() || rng.chance(0.55);
                if push {
                    // Monotone contract: never below the last pop. Bias
                    // toward exact boundary/equal times to stress the
                    // batching and sorting paths.
                    let t = match rng.below(4) {
                        0 => floor,
                        1 => (floor + rng.below(spread)) & !(spread.max(2) / 2),
                        _ => floor + rng.below(spread),
                    };
                    let t = t.max(floor);
                    cal.push(t, seq, step);
                    heap.push(Reverse((t, seq, step)));
                    seq += 1;
                } else {
                    let expect = heap.pop().map(|Reverse(e)| e);
                    let got = cal.pop();
                    assert_eq!(got, expect, "seed {seed} spread {spread} step {step}");
                    floor = got.expect("heap was non-empty").0;
                }
            }
            // Drain both completely.
            while let Some(Reverse(e)) = heap.pop() {
                assert_eq!(cal.pop(), Some(e));
            }
            assert_eq!(cal.pop(), None);
        }
    }

    /// Batch pops must agree with draining the heap one event at a time.
    #[test]
    fn differential_batch_pop_vs_binary_heap() {
        let mut rng = Rng::new(42);
        let mut cal = CalendarQueue::new();
        let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
        let mut floor = 0u64;
        let mut seq = 0u64;
        let mut batch = Vec::new();
        for round in 0..400usize {
            // Bursts of equal-time events: the shape synchronous layers
            // produce.
            let burst_t = floor + rng.below(500);
            for _ in 0..rng.range(1, 6) {
                let t = if rng.chance(0.7) { burst_t } else { floor + rng.below(500) };
                cal.push(t, seq, seq as usize);
                heap.push(Reverse((t, seq, seq as usize)));
                seq += 1;
            }
            let t = cal.pop_batch_into(&mut batch).expect("events pending");
            floor = t;
            for (i, &task) in batch.iter().enumerate() {
                let Reverse(e) = heap.pop().expect("heap shorter than batch");
                assert_eq!((t, task), (e.0, e.2), "round {round} item {i}");
            }
            // The batch must be maximal: the next heap event (if any)
            // has a strictly later time.
            if let Some(Reverse(e)) = heap.peek() {
                assert!(e.0 > t, "round {round}: batch left an equal-time event behind");
            }
        }
    }
}
