//! The typed `NetworkSpec` grammar — the one textual form of a network,
//! used uniformly by the CLI (`--network`, `--topologies`), config JSON
//! (`{"spec": "..."}`), the sweep fingerprint/grid digest, and report
//! scenario labels.
//!
//! ## Grammar
//!
//! ```text
//! spec  := dim ("/" dim)*
//! dim   := kind [":" npus "x" bw "g" "@" lat] ["+" algo]
//! kind  := ring | fully_connected | fc | switch | torus2d
//!        | rail | rail-optimized | dragonfly
//! algo  := ring | hd | halving-doubling | direct | dim-ordered
//! lat   := <number> ("ns" | "us")
//! ```
//!
//! Examples:
//!
//! * `ring` — a bare legacy token: one ring dimension whose size, link
//!   parameters and algorithm are filled from sweep-config defaults
//!   ([`NetworkSpec::materialize`]). Round-trips byte-identically, so
//!   legacy grids keep their exact report labels and grid digests.
//! * `ring:8x300g@700ns/switch:16x25g@5us` — a fully-specified two-tier
//!   cluster, algorithms defaulted per topology.
//! * `ring:4x300g@700ns/rail:4x50g@2us+hd/switch:2x25g@5us+direct` — a
//!   3-dimension hierarchy with explicit per-dimension algorithms.
//!
//! [`std::fmt::Display`] emits the canonical spelling (aliases like `fc`
//! and `halving-doubling` normalize; omitted fields stay omitted), and
//! `parse ∘ Display` is the identity — pinned by round-trip tests here
//! and in the CLI integration suite.

use super::{CollectiveAlgo, Network, TopologyKind};
use crate::error::{Error, Result};
use std::fmt;

/// One dimension of a [`NetworkSpec`]: the topology kind plus optional
/// size / link / algorithm overrides. `None` fields are filled from
/// sweep-config defaults at [`NetworkSpec::materialize`] time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DimSpec {
    /// Physical arrangement (always explicit).
    pub kind: TopologyKind,
    /// NPUs in this dimension's group (`None` = config default).
    pub npus: Option<usize>,
    /// Per-link bandwidth in GB/s (`None` = config default).
    pub bandwidth_gbps: Option<f64>,
    /// Per-hop latency in ns (`None` = config default).
    pub latency_ns: Option<f64>,
    /// Collective algorithm (`None` = the topology's implicit default,
    /// [`CollectiveAlgo::default_for`]).
    pub algo: Option<CollectiveAlgo>,
}

impl DimSpec {
    /// A bare legacy dimension: just the kind, everything else default.
    pub fn bare(kind: TopologyKind) -> DimSpec {
        DimSpec { kind, npus: None, bandwidth_gbps: None, latency_ns: None, algo: None }
    }
}

/// A parsed network specification: an ordered list of [`DimSpec`]s plus
/// the cached canonical label (so rank keys and report rows read the
/// label without re-rendering or allocating).
#[derive(Debug, Clone)]
pub struct NetworkSpec {
    dims: Vec<DimSpec>,
    label: String,
}

impl NetworkSpec {
    /// Build from dimension specs (canonicalizes the label).
    pub fn new(dims: Vec<DimSpec>) -> Result<NetworkSpec> {
        if dims.is_empty() {
            return Err(Error::Config("network spec needs at least one dimension".into()));
        }
        if dims.len() > super::MAX_DIMS {
            return Err(Error::Config(format!(
                "network spec has {} dimensions (max {})",
                dims.len(),
                super::MAX_DIMS
            )));
        }
        for d in &dims {
            if let Some(algo) = d.algo {
                if !algo.admissible_on(d.kind) {
                    return Err(Error::Config(format!(
                        "collective algorithm '{}' is not realizable on a '{}' dimension",
                        algo.token(),
                        d.kind.token()
                    )));
                }
            }
            if d.npus == Some(0) {
                return Err(Error::Config("network spec: dimension with 0 npus".into()));
            }
            if matches!(d.bandwidth_gbps, Some(b) if b <= 0.0) {
                return Err(Error::Config("network spec: bandwidth must be positive".into()));
            }
            if matches!(d.latency_ns, Some(l) if l < 0.0) {
                return Err(Error::Config("network spec: latency must be non-negative".into()));
            }
        }
        let label = render_label(&dims);
        Ok(NetworkSpec { dims, label })
    }

    /// A single bare legacy dimension — `NetworkSpec::from_kind(Ring)`
    /// displays as `"ring"`, exactly the pre-redesign token.
    pub fn from_kind(kind: TopologyKind) -> NetworkSpec {
        let dims = vec![DimSpec::bare(kind)];
        let label = render_label(&dims);
        NetworkSpec { dims, label }
    }

    /// Fully-explicit spec describing an existing [`Network`].
    pub fn from_network(net: &Network) -> NetworkSpec {
        let dims: Vec<DimSpec> = net
            .dims
            .iter()
            .map(|d| DimSpec {
                kind: d.kind,
                npus: Some(d.npus),
                bandwidth_gbps: Some(d.bandwidth_gbps),
                latency_ns: Some(d.latency_ns),
                // Emit the algorithm only when it differs from the
                // topology default, keeping labels minimal and stable.
                algo: if d.algo == CollectiveAlgo::default_for(d.kind) {
                    None
                } else {
                    Some(d.algo)
                },
            })
            .collect();
        let label = render_label(&dims);
        NetworkSpec { dims, label }
    }

    /// Parse the compact grammar (see module docs). Typed
    /// [`Error::Config`]s name the offending fragment.
    pub fn parse(s: &str) -> Result<NetworkSpec> {
        let s = s.trim();
        if s.is_empty() {
            return Err(Error::Config("empty network spec".into()));
        }
        let mut dims = Vec::new();
        for part in s.split('/') {
            dims.push(parse_dim(part.trim())?);
        }
        NetworkSpec::new(dims)
    }

    /// The canonical label (what `Display` prints) — cached, so callers
    /// on the rank-key path borrow it without allocating.
    pub fn label(&self) -> &str {
        &self.label
    }

    /// The dimension specs.
    pub fn dims(&self) -> &[DimSpec] {
        &self.dims
    }

    /// Fill omitted fields from defaults and build a validated
    /// [`Network`]. A bare legacy token (e.g. `"ring"`) materializes to
    /// exactly `Network::single(kind, npus, bandwidth_gbps, latency_ns)`
    /// — the pre-redesign construction, byte for byte.
    pub fn materialize(&self, npus: usize, bandwidth_gbps: f64, latency_ns: f64) -> Result<Network> {
        let dims: Vec<super::NetDim> = self
            .dims
            .iter()
            .map(|d| super::NetDim {
                kind: d.kind,
                algo: d.algo.unwrap_or_else(|| CollectiveAlgo::default_for(d.kind)),
                npus: d.npus.unwrap_or(npus),
                bandwidth_gbps: d.bandwidth_gbps.unwrap_or(bandwidth_gbps),
                latency_ns: d.latency_ns.unwrap_or(latency_ns),
            })
            .collect();
        let net = Network { dims };
        net.validate()?;
        Ok(net)
    }

    /// Build a [`Network`] from a fully-specified spec (every dimension
    /// carries explicit size, bandwidth, and latency) — the config-file
    /// path, where there are no sweep defaults to fill from.
    pub fn to_network(&self) -> Result<Network> {
        for d in &self.dims {
            if d.npus.is_none() || d.bandwidth_gbps.is_none() || d.latency_ns.is_none() {
                return Err(Error::Config(format!(
                    "network spec '{}': every dimension needs explicit size, bandwidth and \
                     latency when used as a full config (e.g. '{}:8x300g@700ns')",
                    self.label,
                    d.kind.token()
                )));
            }
        }
        // All fields present, so the defaults below are never consulted.
        self.materialize(1, 1.0, 0.0)
    }
}

impl fmt::Display for NetworkSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label)
    }
}

impl PartialEq for NetworkSpec {
    fn eq(&self, other: &Self) -> bool {
        self.label == other.label
    }
}

impl Eq for NetworkSpec {}

impl PartialOrd for NetworkSpec {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for NetworkSpec {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.label.cmp(&other.label)
    }
}

/// Parse one `kind[:NxBWg@LAT][+algo]` fragment.
fn parse_dim(part: &str) -> Result<DimSpec> {
    if part.is_empty() {
        return Err(Error::Config("network spec: empty dimension".into()));
    }
    let (head, algo) = match part.rsplit_once('+') {
        Some((h, a)) => (h, Some(CollectiveAlgo::from_token(a)?)),
        None => (part, None),
    };
    let (kind_tok, params) = match head.split_once(':') {
        Some((k, p)) => (k, Some(p)),
        None => (head, None),
    };
    let kind = TopologyKind::from_token(kind_tok)?;
    let mut dim = DimSpec { kind, npus: None, bandwidth_gbps: None, latency_ns: None, algo };
    if let Some(p) = params {
        let (sizes, lat) = p.split_once('@').ok_or_else(|| {
            Error::Config(format!("network spec dimension '{part}': expected 'NxBWg@LAT'"))
        })?;
        let (npus_s, bw_s) = sizes.split_once('x').ok_or_else(|| {
            Error::Config(format!("network spec dimension '{part}': expected 'NxBWg' sizes"))
        })?;
        let npus: usize = npus_s.parse().map_err(|_| {
            Error::Config(format!("network spec dimension '{part}': bad npu count '{npus_s}'"))
        })?;
        let bw_num = bw_s.strip_suffix('g').ok_or_else(|| {
            Error::Config(format!(
                "network spec dimension '{part}': bandwidth '{bw_s}' must end in 'g' (GB/s)"
            ))
        })?;
        let bw: f64 = bw_num.parse().map_err(|_| {
            Error::Config(format!("network spec dimension '{part}': bad bandwidth '{bw_s}'"))
        })?;
        let lat_ns: f64 = if let Some(us) = lat.strip_suffix("us") {
            1000.0
                * us.parse::<f64>().map_err(|_| {
                    Error::Config(format!("network spec dimension '{part}': bad latency '{lat}'"))
                })?
        } else if let Some(ns) = lat.strip_suffix("ns") {
            ns.parse().map_err(|_| {
                Error::Config(format!("network spec dimension '{part}': bad latency '{lat}'"))
            })?
        } else {
            return Err(Error::Config(format!(
                "network spec dimension '{part}': latency '{lat}' must end in 'ns' or 'us'"
            )));
        };
        dim.npus = Some(npus);
        dim.bandwidth_gbps = Some(bw);
        dim.latency_ns = Some(lat_ns);
    }
    Ok(dim)
}

/// Render the canonical label for a dimension list.
fn render_label(dims: &[DimSpec]) -> String {
    let mut out = String::new();
    for (i, d) in dims.iter().enumerate() {
        if i > 0 {
            out.push('/');
        }
        out.push_str(d.kind.token());
        if let (Some(n), Some(bw), Some(lat)) = (d.npus, d.bandwidth_gbps, d.latency_ns) {
            out.push(':');
            out.push_str(&n.to_string());
            out.push('x');
            out.push_str(&fmt_num(bw));
            out.push('g');
            out.push('@');
            // Whole microseconds render as `Nus`, everything else `Nns`.
            if lat >= 1000.0 && (lat / 1000.0).fract() == 0.0 {
                out.push_str(&fmt_num(lat / 1000.0));
                out.push_str("us");
            } else {
                out.push_str(&fmt_num(lat));
                out.push_str("ns");
            }
        }
        if let Some(algo) = d.algo {
            out.push('+');
            out.push_str(algo.token());
        }
    }
    out
}

/// Minimal float rendering: whole values print as integers.
fn fmt_num(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_legacy_tokens_round_trip_unchanged() {
        for tok in ["ring", "fully_connected", "switch", "torus2d", "rail", "dragonfly"] {
            let spec = NetworkSpec::parse(tok).unwrap();
            assert_eq!(spec.to_string(), tok, "bare token must round-trip byte-identically");
            assert_eq!(spec.dims().len(), 1);
            assert_eq!(spec.dims()[0].npus, None);
            assert_eq!(spec.dims()[0].algo, None);
        }
        // Aliases normalize to the canonical token (the same spelling
        // legacy `TopologyKind::token()` put in report labels).
        assert_eq!(NetworkSpec::parse("fc").unwrap().to_string(), "fully_connected");
        assert_eq!(NetworkSpec::parse("rail-optimized").unwrap().to_string(), "rail");
    }

    #[test]
    fn full_grammar_round_trips() {
        for s in [
            "ring:8x300g@700ns",
            "ring:8x300g@700ns/switch:16x25g@5us",
            "ring:4x300g@700ns/rail:4x50g@2us+hd/switch:2x25g@5us+direct",
            "torus2d:16x100g@900ns",
            "fully_connected:8x200g@350ns+ring",
            "dragonfly:32x12.5g@3500ns",
            "switch:4x25g@1234ns",
        ] {
            let spec = NetworkSpec::parse(s).unwrap();
            assert_eq!(spec.to_string(), s, "canonical spec must round-trip");
            let re = NetworkSpec::parse(&spec.to_string()).unwrap();
            assert_eq!(re, spec);
        }
    }

    #[test]
    fn aliases_normalize_in_full_specs() {
        let spec = NetworkSpec::parse("fc:8x200g@350ns+halving-doubling").unwrap();
        assert_eq!(spec.to_string(), "fully_connected:8x200g@350ns+hd");
        let spec = NetworkSpec::parse("switch:4x25g@5000ns").unwrap();
        assert_eq!(spec.to_string(), "switch:4x25g@5us", "whole us canonicalize");
    }

    #[test]
    fn materialize_fills_defaults_like_legacy_single() {
        let spec = NetworkSpec::parse("ring").unwrap();
        let net = spec.materialize(8, 100.0, 500.0).unwrap();
        assert_eq!(net.dims.len(), 1);
        let d = &net.dims[0];
        assert_eq!(d.kind, TopologyKind::Ring);
        assert_eq!(d.algo, CollectiveAlgo::Ring);
        assert_eq!(d.npus, 8);
        assert_eq!(d.bandwidth_gbps, 100.0);
        assert_eq!(d.latency_ns, 500.0);
    }

    #[test]
    fn explicit_fields_override_defaults() {
        let spec = NetworkSpec::parse("ring:4x300g@700ns/switch:2x25g@5us+direct").unwrap();
        let net = spec.materialize(64, 1.0, 1.0).unwrap();
        assert_eq!(net.dims[0].npus, 4);
        assert_eq!(net.dims[0].bandwidth_gbps, 300.0);
        assert_eq!(net.dims[0].latency_ns, 700.0);
        assert_eq!(net.dims[1].algo, CollectiveAlgo::Direct);
        assert_eq!(net.total_npus(), 8);
    }

    #[test]
    fn to_network_requires_full_specification() {
        assert!(NetworkSpec::parse("ring").unwrap().to_network().is_err());
        let net = NetworkSpec::parse("ring:8x300g@700ns").unwrap().to_network().unwrap();
        assert_eq!(net.dims[0].npus, 8);
    }

    #[test]
    fn from_network_round_trips_through_the_grammar() {
        let net = Network::two_tier(8, 4);
        let spec = NetworkSpec::from_network(&net);
        assert_eq!(spec.to_string(), "ring:8x300g@700ns/switch:4x25g@5us");
        let back = spec.to_network().unwrap();
        assert_eq!(back.dims.len(), 2);
        assert_eq!(back.dims[1].algo, CollectiveAlgo::HalvingDoubling);
    }

    #[test]
    fn parse_rejects_malformed_and_inadmissible_specs() {
        for bad in [
            "",
            "/",
            "blimp",
            "ring:8",
            "ring:8x300g",
            "ring:8x300@700ns",
            "ring:8x300g@700",
            "ring:ax300g@700ns",
            "ring+psychic",
            "ring+hd",          // inadmissible algo × topology
            "torus2d+direct",   // inadmissible algo × topology
            "ring:0x300g@700ns",
            "ring/ring/ring/ring/ring/ring/ring/ring/ring", // > MAX_DIMS
        ] {
            assert!(NetworkSpec::parse(bad).is_err(), "'{bad}' should be rejected");
        }
    }

    #[test]
    fn prime_torus_is_rejected_at_materialize_time() {
        let spec = NetworkSpec::parse("torus2d:7x100g@900ns").unwrap();
        let err = spec.to_network().expect_err("prime torus must fail validation");
        assert!(err.to_string().contains("7 npus"), "{err}");
    }

    #[test]
    fn ordering_is_by_canonical_label() {
        let a = NetworkSpec::parse("fully_connected").unwrap();
        let b = NetworkSpec::parse("ring").unwrap();
        assert!(a < b);
        assert_eq!(a, NetworkSpec::parse("fc").unwrap());
    }
}
