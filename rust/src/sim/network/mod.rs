//! Analytical network layer (the Garnet / ns-3 stand-in).
//!
//! ASTRA-sim separates the *logical* topology (what the collectives see)
//! from the *physical* one (what the packets traverse); its analytical
//! backend — which this module reproduces — models each physical link as
//! `latency + bytes/bandwidth` and composes collective phases over the
//! logical dimensions. A [`Network`] is an ordered list of dimensions
//! (e.g. intra-package ring + inter-package switch), mirroring the
//! scale-up/scale-out fabric split of Fig. 1.

use crate::error::{Error, Result};
use crate::json::Value;

/// Physical arrangement of one network dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyKind {
    /// Unidirectional ring (NVLink-style neighbor mesh).
    Ring,
    /// Every pair directly connected.
    FullyConnected,
    /// All NPUs hang off one switch (store-and-forward).
    Switch,
    /// 2-D torus; collectives run dimension-ordered rings.
    Torus2D,
}

impl TopologyKind {
    /// Parse a config token.
    pub fn from_token(s: &str) -> Result<TopologyKind> {
        Ok(match s {
            "ring" => TopologyKind::Ring,
            "fully_connected" | "fc" => TopologyKind::FullyConnected,
            "switch" => TopologyKind::Switch,
            "torus2d" => TopologyKind::Torus2D,
            other => return Err(Error::Config(format!("unknown topology '{other}'"))),
        })
    }

    /// Canonical token.
    pub fn token(self) -> &'static str {
        match self {
            TopologyKind::Ring => "ring",
            TopologyKind::FullyConnected => "fully_connected",
            TopologyKind::Switch => "switch",
            TopologyKind::Torus2D => "torus2d",
        }
    }
}

/// One network dimension: topology + size + per-link characteristics.
#[derive(Debug, Clone, Copy)]
pub struct NetDim {
    /// Physical arrangement.
    pub kind: TopologyKind,
    /// NPUs in this dimension's group.
    pub npus: usize,
    /// Per-link bandwidth in GB/s (= bytes/ns).
    pub bandwidth_gbps: f64,
    /// Per-hop latency in ns.
    pub latency_ns: f64,
}

impl NetDim {
    /// Serialization time for `bytes` on one link (ns), excluding latency.
    pub fn ser_ns(&self, bytes: f64) -> f64 {
        bytes / self.bandwidth_gbps
    }

    /// One-hop transfer time for `bytes` (ns).
    pub fn hop_ns(&self, bytes: f64) -> f64 {
        self.latency_ns + self.ser_ns(bytes)
    }

    /// Rows/cols factorization for Torus2D (nearest square).
    pub fn torus_dims(&self) -> (usize, usize) {
        let mut r = (self.npus as f64).sqrt() as usize;
        while r > 1 && self.npus % r != 0 {
            r -= 1;
        }
        (r.max(1), self.npus / r.max(1))
    }

    /// Validate the dimension parameters.
    pub fn validate(&self) -> Result<()> {
        if self.npus == 0 {
            return Err(Error::Config("dimension with 0 npus".into()));
        }
        if self.bandwidth_gbps <= 0.0 {
            return Err(Error::Config("bandwidth must be positive".into()));
        }
        if self.latency_ns < 0.0 {
            return Err(Error::Config("latency must be non-negative".into()));
        }
        Ok(())
    }
}

/// A multi-dimensional network: `dims[0]` is the innermost (scale-up)
/// dimension; later dimensions scale out. Total NPUs = ∏ dims.npus.
#[derive(Debug, Clone)]
pub struct Network {
    /// Ordered dimensions.
    pub dims: Vec<NetDim>,
}

impl Network {
    /// Single-dimension network.
    pub fn single(kind: TopologyKind, npus: usize, bandwidth_gbps: f64, latency_ns: f64) -> Network {
        Network { dims: vec![NetDim { kind, npus, bandwidth_gbps, latency_ns }] }
    }

    /// A typical two-tier cluster: `local` NPUs on a fast ring per node,
    /// `nodes` nodes behind a switch.
    pub fn two_tier(local: usize, nodes: usize) -> Network {
        Network {
            dims: vec![
                NetDim {
                    kind: TopologyKind::Ring,
                    npus: local,
                    bandwidth_gbps: 300.0, // NVLink-class
                    latency_ns: 700.0,
                },
                NetDim {
                    kind: TopologyKind::Switch,
                    npus: nodes,
                    bandwidth_gbps: 25.0, // 200 Gb NIC-class
                    latency_ns: 5000.0,
                },
            ],
        }
    }

    /// Total NPU count.
    pub fn total_npus(&self) -> usize {
        self.dims.iter().map(|d| d.npus).product()
    }

    /// Validate all dimensions.
    pub fn validate(&self) -> Result<()> {
        if self.dims.is_empty() {
            return Err(Error::Config("network needs at least one dimension".into()));
        }
        for d in &self.dims {
            d.validate()?;
        }
        Ok(())
    }

    /// Parse from a JSON config value:
    /// `{"dims": [{"topology": "ring", "npus": 8, "bandwidth_gbps": 300,
    ///             "latency_ns": 700}, ...]}`
    pub fn from_json(v: &Value) -> Result<Network> {
        let dims_v = v
            .get("dims")
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::Config("network config: missing 'dims' array".into()))?;
        let mut dims = Vec::with_capacity(dims_v.len());
        for d in dims_v {
            dims.push(NetDim {
                kind: TopologyKind::from_token(d.req_str("topology")?)?,
                npus: d.req_u64("npus")? as usize,
                bandwidth_gbps: d.req_f64("bandwidth_gbps")?,
                latency_ns: d.req_f64("latency_ns")?,
            });
        }
        let n = Network { dims };
        n.validate()?;
        Ok(n)
    }

    /// Emit the JSON config form.
    pub fn to_json(&self) -> Value {
        use std::collections::BTreeMap;
        let dims: Vec<Value> = self
            .dims
            .iter()
            .map(|d| {
                let mut m = BTreeMap::new();
                m.insert("topology".to_string(), Value::Str(d.kind.token().into()));
                m.insert("npus".to_string(), Value::Num(d.npus as f64));
                m.insert("bandwidth_gbps".to_string(), Value::Num(d.bandwidth_gbps));
                m.insert("latency_ns".to_string(), Value::Num(d.latency_ns));
                Value::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("dims".to_string(), Value::Arr(dims));
        Value::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_time_math() {
        let d = NetDim {
            kind: TopologyKind::Ring,
            npus: 8,
            bandwidth_gbps: 100.0,
            latency_ns: 500.0,
        };
        // 1 MB at 100 GB/s = 10486 ns serialization + 500 latency.
        assert!((d.hop_ns(1_048_576.0) - (500.0 + 10485.76)).abs() < 0.01);
    }

    #[test]
    fn torus_factorization() {
        let mk = |n| NetDim {
            kind: TopologyKind::Torus2D,
            npus: n,
            bandwidth_gbps: 1.0,
            latency_ns: 0.0,
        };
        assert_eq!(mk(16).torus_dims(), (4, 4));
        assert_eq!(mk(12).torus_dims(), (3, 4));
        assert_eq!(mk(7).torus_dims(), (1, 7));
    }

    #[test]
    fn totals_and_validation() {
        let n = Network::two_tier(8, 16);
        assert_eq!(n.total_npus(), 128);
        assert!(n.validate().is_ok());
        let bad = Network::single(TopologyKind::Ring, 0, 1.0, 0.0);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let n = Network::two_tier(4, 2);
        let v = n.to_json();
        let n2 = Network::from_json(&v).unwrap();
        assert_eq!(n2.dims.len(), 2);
        assert_eq!(n2.dims[0].npus, 4);
        assert_eq!(n2.dims[1].kind, TopologyKind::Switch);
        assert_eq!(n2.dims[1].bandwidth_gbps, 25.0);
    }

    #[test]
    fn json_rejects_bad_config() {
        let v = crate::json::parse(r#"{"dims": [{"topology": "blimp", "npus": 2, "bandwidth_gbps": 1, "latency_ns": 0}]}"#).unwrap();
        assert!(Network::from_json(&v).is_err());
        let v = crate::json::parse(r#"{}"#).unwrap();
        assert!(Network::from_json(&v).is_err());
    }
}
