//! Analytical network layer (the Garnet / ns-3 stand-in).
//!
//! ASTRA-sim separates the *logical* topology (what the collectives see)
//! from the *physical* one (what the packets traverse); its analytical
//! backend — which this module reproduces — models each physical link as
//! `latency + bytes/bandwidth` and composes collective phases over the
//! logical dimensions. A [`Network`] is an ordered list of dimensions
//! (`dims[0]` innermost/scale-up, later dimensions scale out), mirroring
//! the hierarchical fabric split of Fig. 1 — generalized to
//! N ≤ [`MAX_DIMS`] dimensions.
//!
//! Each dimension is a *resource with a policy*: a [`TopologyKind`]
//! (the physical arrangement) **plus** an explicit [`CollectiveAlgo`]
//! (the schedule collectives run over that arrangement). The two are
//! decoupled — ASTRA-sim 2.0's per-dimension collective co-design — and
//! [`NetDim::validate`] rejects pairs the fabric cannot realize with a
//! typed [`Error::Config`] (see [`CollectiveAlgo::admissible_on`]),
//! enforced at the same boundaries as `ir::verify`: simulation entry,
//! workload verification, config parsing, and `modtrans check`.
//!
//! The compact textual form of a network — used uniformly by the CLI,
//! config JSON, the sweep fingerprint and report scenario labels — is
//! the [`NetworkSpec`] grammar in [`spec`], e.g.
//! `ring:8x300g@700ns/switch:16x25g@5us+hd`.

use crate::error::{Error, Result};
use crate::json::Value;

pub mod spec;
pub use spec::{DimSpec, NetworkSpec};

/// Hard cap on network dimensions. Keeps the per-dimension accumulators
/// in the sweep's analytic bound pass (and the router's leg math) in
/// fixed stack buffers, like `MAX_CHUNKS` does for chunk pipelining.
pub const MAX_DIMS: usize = 8;

/// Physical arrangement of one network dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TopologyKind {
    /// Unidirectional ring (NVLink-style neighbor mesh).
    Ring,
    /// Every pair directly connected.
    FullyConnected,
    /// All NPUs hang off one switch (store-and-forward).
    Switch,
    /// 2-D torus; must factor into a non-degenerate rows×cols grid.
    Torus2D,
    /// Rail-optimized: one parallel switch plane ("rail") per local NPU
    /// index, so same-index peers across nodes reach each other in one
    /// switch hop without crossing rails (the GPU-cluster scale-out
    /// fabric ASTRA-sim 2.0 models).
    RailOptimized,
    /// Dragonfly: all-to-all connected router groups joined by global
    /// links; any pair is reachable in ≤ 3 hops (local-global-local).
    Dragonfly,
}

impl TopologyKind {
    /// Parse a config token (canonical tokens plus deprecated aliases).
    pub fn from_token(s: &str) -> Result<TopologyKind> {
        Ok(match s {
            "ring" => TopologyKind::Ring,
            "fully_connected" | "fc" => TopologyKind::FullyConnected,
            "switch" => TopologyKind::Switch,
            "torus2d" => TopologyKind::Torus2D,
            "rail" | "rail-optimized" | "rail_optimized" => TopologyKind::RailOptimized,
            "dragonfly" => TopologyKind::Dragonfly,
            other => return Err(Error::Config(format!("unknown topology '{other}'"))),
        })
    }

    /// Canonical token.
    pub fn token(self) -> &'static str {
        match self {
            TopologyKind::Ring => "ring",
            TopologyKind::FullyConnected => "fully_connected",
            TopologyKind::Switch => "switch",
            TopologyKind::Torus2D => "torus2d",
            TopologyKind::RailOptimized => "rail",
            TopologyKind::Dragonfly => "dragonfly",
        }
    }
}

/// The collective *algorithm* a dimension's collectives run — decoupled
/// from [`TopologyKind`], which only constrains what is realizable (see
/// [`CollectiveAlgo::admissible_on`]). The α-β completion-time model for
/// each algorithm lives in [`crate::sim::collectives::collective_ns`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum CollectiveAlgo {
    /// Bandwidth-optimal ring schedule: `2(N-1)` phases of `M/N`.
    Ring,
    /// Recursive halving/doubling: `2·log2(N)` latency-bound phases,
    /// `2M(N-1)/N` total bytes serialized at each port.
    HalvingDoubling,
    /// Direct single-phase exchange: every peer pair moves its shard
    /// concurrently over dedicated paths.
    Direct,
    /// Dimension-ordered (torus): reduce-scatter on rows, all-reduce on
    /// columns over the row shard, all-gather on rows.
    DimOrdered,
}

impl CollectiveAlgo {
    /// Parse a config token (canonical tokens plus long-form aliases).
    pub fn from_token(s: &str) -> Result<CollectiveAlgo> {
        Ok(match s {
            "ring" => CollectiveAlgo::Ring,
            "hd" | "halving-doubling" | "halving_doubling" => CollectiveAlgo::HalvingDoubling,
            "direct" => CollectiveAlgo::Direct,
            "dim-ordered" | "dim_ordered" | "dimension-ordered" => CollectiveAlgo::DimOrdered,
            other => return Err(Error::Config(format!("unknown collective algorithm '{other}'"))),
        })
    }

    /// Canonical token (the `+algo` suffix spelling in the
    /// [`NetworkSpec`] grammar).
    pub fn token(self) -> &'static str {
        match self {
            CollectiveAlgo::Ring => "ring",
            CollectiveAlgo::HalvingDoubling => "hd",
            CollectiveAlgo::Direct => "direct",
            CollectiveAlgo::DimOrdered => "dim-ordered",
        }
    }

    /// The algorithm a topology ran *implicitly* before algorithms became
    /// explicit — pinned by byte-identity tests so every legacy scenario
    /// ranks exactly as it did when `collective_ns` matched on
    /// [`TopologyKind`].
    pub fn default_for(kind: TopologyKind) -> CollectiveAlgo {
        match kind {
            TopologyKind::Ring => CollectiveAlgo::Ring,
            TopologyKind::FullyConnected => CollectiveAlgo::Direct,
            TopologyKind::Switch => CollectiveAlgo::HalvingDoubling,
            TopologyKind::Torus2D => CollectiveAlgo::DimOrdered,
            // Rails are parallel non-blocking switch planes.
            TopologyKind::RailOptimized => CollectiveAlgo::HalvingDoubling,
            // Dragonfly's global links give all-to-all group reachability.
            TopologyKind::Dragonfly => CollectiveAlgo::Direct,
        }
    }

    /// Can this algorithm's communication pattern be realized on `kind`
    /// without links the fabric does not have?
    ///
    /// * `Ring` embeds in every connected fabric (a logical ring needs
    ///   only a Hamiltonian cycle), so it is admissible everywhere.
    /// * `HalvingDoubling` needs distance-`2^i` partner exchanges every
    ///   phase — congestion-free only through a switch, rails, a
    ///   fully-connected mesh, or dragonfly global links; on a ring or
    ///   torus the long-haul phases would multiplex one physical link.
    /// * `Direct` needs a dedicated path per peer pair — fully-connected
    ///   meshes, non-blocking switches, rails, and dragonfly only.
    /// * `DimOrdered` is the torus schedule: it needs the rows×cols
    ///   factorization, so it is admissible on `Torus2D` alone.
    pub fn admissible_on(self, kind: TopologyKind) -> bool {
        use CollectiveAlgo::*;
        use TopologyKind::*;
        match self {
            Ring => true,
            HalvingDoubling | Direct => {
                matches!(kind, FullyConnected | Switch | RailOptimized | Dragonfly)
            }
            DimOrdered => kind == Torus2D,
        }
    }
}

/// One network dimension: topology + collective algorithm + size +
/// per-link characteristics.
#[derive(Debug, Clone, Copy)]
pub struct NetDim {
    /// Physical arrangement.
    pub kind: TopologyKind,
    /// Collective algorithm run over this dimension (must be admissible
    /// on `kind`; checked by [`NetDim::validate`]).
    pub algo: CollectiveAlgo,
    /// NPUs in this dimension's group.
    pub npus: usize,
    /// Per-link bandwidth in GB/s (= bytes/ns).
    pub bandwidth_gbps: f64,
    /// Per-hop latency in ns.
    pub latency_ns: f64,
}

impl NetDim {
    /// A dimension running `kind`'s default algorithm
    /// ([`CollectiveAlgo::default_for`]) — the legacy implicit pairing.
    pub fn new(kind: TopologyKind, npus: usize, bandwidth_gbps: f64, latency_ns: f64) -> NetDim {
        NetDim { kind, algo: CollectiveAlgo::default_for(kind), npus, bandwidth_gbps, latency_ns }
    }

    /// Serialization time for `bytes` on one link (ns), excluding latency.
    pub fn ser_ns(&self, bytes: f64) -> f64 {
        bytes / self.bandwidth_gbps
    }

    /// One-hop transfer time for `bytes` (ns).
    pub fn hop_ns(&self, bytes: f64) -> f64 {
        self.latency_ns + self.ser_ns(bytes)
    }

    /// Rows/cols factorization for Torus2D (nearest square). Degenerate
    /// `(1, N)` results are rejected by [`NetDim::validate`], so a
    /// validated torus dimension always has both factors > 1.
    pub fn torus_dims(&self) -> (usize, usize) {
        let mut r = (self.npus as f64).sqrt() as usize;
        while r > 1 && self.npus % r != 0 {
            r -= 1;
        }
        (r.max(1), self.npus / r.max(1))
    }

    /// Validate the dimension parameters: positive size/bandwidth,
    /// non-negative latency, a factorable torus grid, and an
    /// algorithm × topology pair the fabric can realize.
    pub fn validate(&self) -> Result<()> {
        if self.npus == 0 {
            return Err(Error::Config("dimension with 0 npus".into()));
        }
        if self.bandwidth_gbps <= 0.0 {
            return Err(Error::Config("bandwidth must be positive".into()));
        }
        if self.latency_ns < 0.0 {
            return Err(Error::Config("latency must be non-negative".into()));
        }
        if self.kind == TopologyKind::Torus2D && self.npus > 1 {
            let (r, c) = self.torus_dims();
            if r < 2 {
                return Err(Error::Config(format!(
                    "torus2d dimension of {} npus does not factor into a rows x cols grid \
                     (prime size degenerates to 1x{}, which is a ring, not a torus): \
                     use a composite npu count or a ring dimension",
                    self.npus, c
                )));
            }
        }
        if !self.algo.admissible_on(self.kind) {
            return Err(Error::Config(format!(
                "collective algorithm '{}' is not realizable on a '{}' dimension \
                 (admissible: {})",
                self.algo.token(),
                self.kind.token(),
                admissible_tokens(self.kind)
            )));
        }
        Ok(())
    }
}

/// Comma-joined admissible algorithm tokens for `kind` (error messages).
fn admissible_tokens(kind: TopologyKind) -> String {
    let mut out = String::new();
    for algo in [
        CollectiveAlgo::Ring,
        CollectiveAlgo::HalvingDoubling,
        CollectiveAlgo::Direct,
        CollectiveAlgo::DimOrdered,
    ] {
        if algo.admissible_on(kind) {
            if !out.is_empty() {
                out.push_str(", ");
            }
            out.push_str(algo.token());
        }
    }
    out
}

/// A multi-dimensional network: `dims[0]` is the innermost (scale-up)
/// dimension; later dimensions scale out. Total NPUs = ∏ dims.npus.
#[derive(Debug, Clone)]
pub struct Network {
    /// Ordered dimensions.
    pub dims: Vec<NetDim>,
}

impl Network {
    /// Single-dimension network running the topology's default algorithm.
    pub fn single(kind: TopologyKind, npus: usize, bandwidth_gbps: f64, latency_ns: f64) -> Network {
        Network { dims: vec![NetDim::new(kind, npus, bandwidth_gbps, latency_ns)] }
    }

    /// A typical two-tier cluster: `local` NPUs on a fast ring per node,
    /// `nodes` nodes behind a switch.
    pub fn two_tier(local: usize, nodes: usize) -> Network {
        Network {
            dims: vec![
                // NVLink-class scale-up ring.
                NetDim::new(TopologyKind::Ring, local, 300.0, 700.0),
                // 200 Gb NIC-class scale-out switch.
                NetDim::new(TopologyKind::Switch, nodes, 25.0, 5000.0),
            ],
        }
    }

    /// Total NPU count.
    pub fn total_npus(&self) -> usize {
        self.dims.iter().map(|d| d.npus).product()
    }

    /// Validate all dimensions (size, link parameters, torus
    /// factorability, algorithm admissibility) and the dimension count.
    pub fn validate(&self) -> Result<()> {
        if self.dims.is_empty() {
            return Err(Error::Config("network needs at least one dimension".into()));
        }
        if self.dims.len() > MAX_DIMS {
            return Err(Error::Config(format!(
                "network has {} dimensions (max {MAX_DIMS})",
                self.dims.len()
            )));
        }
        for d in &self.dims {
            d.validate()?;
        }
        Ok(())
    }

    /// Parse from a JSON config value. Two forms:
    ///
    /// * the [`NetworkSpec`] grammar (canonical):
    ///   `{"spec": "ring:8x300g@700ns/switch:4x25g@5us+hd"}` — every
    ///   dimension must be fully specified (no config-level defaults to
    ///   fill from here);
    /// * the legacy per-dimension object array (deprecated alias):
    ///   `{"dims": [{"topology": "ring", "npus": 8, "bandwidth_gbps":
    ///   300, "latency_ns": 700, "algo": "ring"}, ...]}` — `"algo"` is
    ///   optional and defaults to the topology's implicit algorithm.
    pub fn from_json(v: &Value) -> Result<Network> {
        if let Some(s) = v.get("spec").and_then(Value::as_str) {
            let spec = NetworkSpec::parse(s)?;
            return spec.to_network();
        }
        let dims_v = v
            .get("dims")
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::Config("network config: missing 'spec' or 'dims'".into()))?;
        let mut dims = Vec::with_capacity(dims_v.len());
        for d in dims_v {
            let kind = TopologyKind::from_token(d.req_str("topology")?)?;
            let algo = match d.get("algo").and_then(Value::as_str) {
                Some(a) => CollectiveAlgo::from_token(a)?,
                None => CollectiveAlgo::default_for(kind),
            };
            dims.push(NetDim {
                kind,
                algo,
                npus: d.req_u64("npus")? as usize,
                bandwidth_gbps: d.req_f64("bandwidth_gbps")?,
                latency_ns: d.req_f64("latency_ns")?,
            });
        }
        let n = Network { dims };
        n.validate()?;
        Ok(n)
    }

    /// Emit the JSON config form (canonical: the [`NetworkSpec`] string).
    pub fn to_json(&self) -> Value {
        use std::collections::BTreeMap;
        let mut m = BTreeMap::new();
        m.insert("spec".to_string(), Value::Str(NetworkSpec::from_network(self).to_string()));
        Value::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_time_math() {
        let d = NetDim::new(TopologyKind::Ring, 8, 100.0, 500.0);
        // 1 MB at 100 GB/s = 10486 ns serialization + 500 latency.
        assert!((d.hop_ns(1_048_576.0) - (500.0 + 10485.76)).abs() < 0.01);
    }

    #[test]
    fn torus_factorization() {
        let mk = |n| NetDim::new(TopologyKind::Torus2D, n, 1.0, 0.0);
        assert_eq!(mk(16).torus_dims(), (4, 4));
        assert_eq!(mk(12).torus_dims(), (3, 4));
        // Primes degenerate to (1, N) — which validate() now rejects.
        assert_eq!(mk(7).torus_dims(), (1, 7));
    }

    #[test]
    fn torus_validate_rejects_non_factorable_sizes() {
        for n in [2usize, 3, 5, 7, 13] {
            let d = NetDim::new(TopologyKind::Torus2D, n, 1.0, 0.0);
            let err = d.validate().expect_err("prime torus must be rejected");
            let msg = err.to_string();
            assert!(
                msg.contains(&format!("{n} npus")),
                "error must name the size: {msg}"
            );
        }
        for n in [4usize, 6, 9, 12, 16, 64] {
            assert!(NetDim::new(TopologyKind::Torus2D, n, 1.0, 0.0).validate().is_ok());
        }
        // A 1-NPU dimension is trivially fine (no collective runs).
        assert!(NetDim::new(TopologyKind::Torus2D, 1, 1.0, 0.0).validate().is_ok());
    }

    #[test]
    fn admissibility_matrix() {
        use CollectiveAlgo::*;
        use TopologyKind::*;
        // Ring algorithm embeds everywhere.
        for kind in [Ring, FullyConnected, Switch, Torus2D, RailOptimized, Dragonfly] {
            assert!(CollectiveAlgo::Ring.admissible_on(kind));
        }
        // HD / Direct need switched or all-to-all fabrics.
        for algo in [HalvingDoubling, Direct] {
            for kind in [FullyConnected, Switch, RailOptimized, Dragonfly] {
                assert!(algo.admissible_on(kind), "{algo:?} on {kind:?}");
            }
            for kind in [Ring, Torus2D] {
                assert!(!algo.admissible_on(kind), "{algo:?} on {kind:?}");
            }
        }
        // Dimension-ordered is the torus schedule, nothing else.
        for kind in [Ring, FullyConnected, Switch, RailOptimized, Dragonfly] {
            assert!(!DimOrdered.admissible_on(kind));
        }
        assert!(DimOrdered.admissible_on(Torus2D));
        // The defaults are always admissible.
        for kind in [Ring, FullyConnected, Switch, Torus2D, RailOptimized, Dragonfly] {
            assert!(CollectiveAlgo::default_for(kind).admissible_on(kind));
        }
    }

    #[test]
    fn inadmissible_algo_is_a_typed_config_error() {
        let d = NetDim {
            kind: TopologyKind::Ring,
            algo: CollectiveAlgo::HalvingDoubling,
            npus: 8,
            bandwidth_gbps: 100.0,
            latency_ns: 500.0,
        };
        let err = d.validate().expect_err("hd on a ring must be rejected");
        match err {
            Error::Config(msg) => {
                assert!(msg.contains("hd"), "{msg}");
                assert!(msg.contains("ring"), "{msg}");
            }
            other => panic!("expected Error::Config, got {other:?}"),
        }
    }

    #[test]
    fn token_round_trips_cover_new_kinds_and_aliases() {
        for kind in [
            TopologyKind::Ring,
            TopologyKind::FullyConnected,
            TopologyKind::Switch,
            TopologyKind::Torus2D,
            TopologyKind::RailOptimized,
            TopologyKind::Dragonfly,
        ] {
            assert_eq!(TopologyKind::from_token(kind.token()).unwrap(), kind);
        }
        // Deprecated aliases still parse.
        assert_eq!(TopologyKind::from_token("fc").unwrap(), TopologyKind::FullyConnected);
        assert_eq!(
            TopologyKind::from_token("rail-optimized").unwrap(),
            TopologyKind::RailOptimized
        );
        for algo in [
            CollectiveAlgo::Ring,
            CollectiveAlgo::HalvingDoubling,
            CollectiveAlgo::Direct,
            CollectiveAlgo::DimOrdered,
        ] {
            assert_eq!(CollectiveAlgo::from_token(algo.token()).unwrap(), algo);
        }
        assert_eq!(
            CollectiveAlgo::from_token("halving-doubling").unwrap(),
            CollectiveAlgo::HalvingDoubling
        );
        assert_eq!(
            CollectiveAlgo::from_token("dimension-ordered").unwrap(),
            CollectiveAlgo::DimOrdered
        );
        assert!(TopologyKind::from_token("blimp").is_err());
        assert!(CollectiveAlgo::from_token("psychic").is_err());
    }

    #[test]
    fn totals_and_validation() {
        let n = Network::two_tier(8, 16);
        assert_eq!(n.total_npus(), 128);
        assert!(n.validate().is_ok());
        let bad = Network::single(TopologyKind::Ring, 0, 1.0, 0.0);
        assert!(bad.validate().is_err());
        let too_deep = Network {
            dims: (0..=MAX_DIMS).map(|_| NetDim::new(TopologyKind::Ring, 2, 1.0, 0.0)).collect(),
        };
        assert!(too_deep.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let n = Network::two_tier(4, 2);
        let v = n.to_json();
        // Canonical emission is the compact spec string; the default
        // algorithm for each kind is omitted from the label.
        assert_eq!(
            v.get("spec").and_then(Value::as_str),
            Some("ring:4x300g@700ns/switch:2x25g@5us")
        );
        let n2 = Network::from_json(&v).unwrap();
        assert_eq!(n2.dims.len(), 2);
        assert_eq!(n2.dims[0].npus, 4);
        assert_eq!(n2.dims[1].kind, TopologyKind::Switch);
        assert_eq!(n2.dims[1].algo, CollectiveAlgo::HalvingDoubling);
        assert_eq!(n2.dims[1].bandwidth_gbps, 25.0);
    }

    #[test]
    fn json_legacy_dims_form_still_parses() {
        let v = crate::json::parse(
            r#"{"dims": [
                {"topology": "ring", "npus": 8, "bandwidth_gbps": 300, "latency_ns": 700},
                {"topology": "switch", "npus": 4, "bandwidth_gbps": 25, "latency_ns": 5000,
                 "algo": "direct"}
            ]}"#,
        )
        .unwrap();
        let n = Network::from_json(&v).unwrap();
        assert_eq!(n.dims[0].algo, CollectiveAlgo::Ring, "default algo fills in");
        assert_eq!(n.dims[1].algo, CollectiveAlgo::Direct, "explicit algo wins");
    }

    #[test]
    fn json_rejects_bad_config() {
        let v = crate::json::parse(r#"{"dims": [{"topology": "blimp", "npus": 2, "bandwidth_gbps": 1, "latency_ns": 0}]}"#).unwrap();
        assert!(Network::from_json(&v).is_err());
        let v = crate::json::parse(r#"{}"#).unwrap();
        assert!(Network::from_json(&v).is_err());
        // Inadmissible algo × topology is rejected at the parse boundary.
        let v = crate::json::parse(r#"{"dims": [{"topology": "ring", "npus": 4, "bandwidth_gbps": 1, "latency_ns": 0, "algo": "hd"}]}"#).unwrap();
        assert!(Network::from_json(&v).is_err());
        // Prime torus is rejected at the parse boundary too.
        let v = crate::json::parse(r#"{"dims": [{"topology": "torus2d", "npus": 7, "bandwidth_gbps": 1, "latency_ns": 0}]}"#).unwrap();
        assert!(Network::from_json(&v).is_err());
    }
}
