//! Infrastructure substrates that would normally come from external crates
//! (`rand`, `criterion`, prettytable) — implemented in-repo because the
//! build is fully offline.

pub mod bench;
pub mod rng;
pub mod table;

/// FNV-1a offset basis (the seed value for [`fnv1a_extend`] chains).
pub const FNV1A_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `bytes` into a running FNV-1a state. Start chains from
/// [`FNV1A_OFFSET`] (or use [`fnv1a`] for the one-shot form).
pub fn fnv1a_extend(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One-shot FNV-1a digest — the crate's stable non-cryptographic hash
/// (sweep grid identities, IR-cache file names, calibration
/// fingerprints). Not for adversarial inputs.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_extend(FNV1A_OFFSET, bytes)
}

/// Format a byte count with binary-prefix units (e.g. `411041792` →
/// `"392.0 MiB"`). Used by `modtrans inspect` and the report writers.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.1} {}", v, UNITS[u])
    }
}

/// Format a duration in seconds with an adaptive unit (`ns`/`µs`/`ms`/`s`).
pub fn human_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(0), "0 B");
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(411_041_792), "392.0 MiB");
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        // Chaining is identical to one-shot over the concatenation.
        let chained = fnv1a_extend(fnv1a_extend(FNV1A_OFFSET, b"foo"), b"bar");
        assert_eq!(chained, fnv1a(b"foobar"));
    }

    #[test]
    fn human_time_units() {
        assert_eq!(human_time(0.5e-9 * 2.0), "1.0 ns");
        assert_eq!(human_time(1.5e-3), "1.500 ms");
        assert_eq!(human_time(2.0), "2.000 s");
    }
}
