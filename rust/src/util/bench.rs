//! Micro-benchmark harness (criterion stand-in for the offline build).
//!
//! Provides warmup, fixed-count timed iterations, and summary statistics
//! (mean / stddev / min / max / p50) so the `benches/` targets can print
//! the same mean-and-variance series the paper's Figure 6 reports.

use std::time::Instant;

/// Summary statistics over per-iteration wall-clock samples (seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    /// Benchmark label.
    pub name: String,
    /// Number of timed samples.
    pub n: usize,
    /// Arithmetic mean (s).
    pub mean: f64,
    /// Sample standard deviation (s).
    pub stddev: f64,
    /// Minimum sample (s).
    pub min: f64,
    /// Maximum sample (s).
    pub max: f64,
    /// Median sample (s).
    pub p50: f64,
}

impl Stats {
    /// Compute statistics from raw samples.
    pub fn from_samples(name: &str, mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Stats {
            name: name.to_string(),
            n,
            mean,
            stddev: var.sqrt(),
            min: samples[0],
            max: samples[n - 1],
            p50: samples[n / 2],
        }
    }

    /// One-line human-readable summary.
    pub fn line(&self) -> String {
        format!(
            "{:<40} n={:<4} mean={:>12} ± {:<12} min={:>12} p50={:>12} max={:>12}",
            self.name,
            self.n,
            crate::util::human_time(self.mean),
            crate::util::human_time(self.stddev),
            crate::util::human_time(self.min),
            crate::util::human_time(self.p50),
            crate::util::human_time(self.max),
        )
    }
}

/// Benchmark runner: `warmup` untimed runs followed by `samples` timed runs.
pub struct Bench {
    warmup: usize,
    samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new(3, 30)
    }
}

impl Bench {
    /// Create a runner with explicit warmup/sample counts.
    ///
    /// CI's bench-smoke job sets `MODTRANS_BENCH_SAMPLES=<n>` to cap the
    /// sample count (and drop warmup to at most 1) so every bench binary
    /// finishes in seconds while still exercising its full code path.
    pub fn new(warmup: usize, samples: usize) -> Bench {
        match std::env::var("MODTRANS_BENCH_SAMPLES")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
        {
            Some(cap) => Bench { warmup: warmup.min(1), samples: samples.min(cap.max(1)) },
            None => Bench { warmup, samples },
        }
    }

    /// Run `f` and collect statistics. `f` is passed the iteration index
    /// (warmup iterations get indices `0..warmup`).
    pub fn run<F: FnMut(usize)>(&self, name: &str, mut f: F) -> Stats {
        for i in 0..self.warmup {
            f(i);
        }
        let mut samples = Vec::with_capacity(self.samples);
        for i in 0..self.samples {
            let t0 = Instant::now();
            f(self.warmup + i);
            samples.push(t0.elapsed().as_secs_f64());
        }
        let s = Stats::from_samples(name, samples);
        println!("{}", s.line());
        s
    }
}

/// Prevent the optimizer from discarding a computed value
/// (`std::hint::black_box` wrapper, kept for call-site clarity).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant_samples() {
        let s = Stats::from_samples("c", vec![2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn stats_mean_stddev() {
        let s = Stats::from_samples("x", vec![1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.stddev - 1.0).abs() < 1e-12);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        // Direct construction bypasses the MODTRANS_BENCH_SAMPLES cap so
        // this test's counts hold even under a smoke-capped environment.
        let b = Bench { warmup: 2, samples: 5 };
        let s = b.run("iters", |_| count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }
}
