//! Micro-benchmark harness (criterion stand-in for the offline build).
//!
//! Provides warmup, fixed-count timed iterations, and summary statistics
//! (mean / stddev / min / max / p50) so the `benches/` targets can print
//! the same mean-and-variance series the paper's Figure 6 reports.
//!
//! # Machine-readable output
//!
//! Every bench binary funnels its series through a [`BenchReport`],
//! which serializes each series' summary **plus the raw samples** to
//! `BENCH_<name>.json` (via the in-crate JSON writer). CI's bench-smoke
//! job uploads these files as artifacts, making per-PR perf deltas
//! diffable — the repo's perf trajectory. Set `MODTRANS_BENCH_OUT` to
//! choose the output directory (default: current directory).

use crate::json::{obj, Value};
use crate::Result;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Summary statistics over per-iteration wall-clock samples (seconds).
#[derive(Debug, Clone)]
pub struct Stats {
    /// Benchmark label.
    pub name: String,
    /// Number of timed samples.
    pub n: usize,
    /// Arithmetic mean (s).
    pub mean: f64,
    /// Sample standard deviation (s).
    pub stddev: f64,
    /// Minimum sample (s).
    pub min: f64,
    /// Maximum sample (s).
    pub max: f64,
    /// Median sample (s).
    pub p50: f64,
    /// Raw samples in measurement order (s).
    pub samples: Vec<f64>,
}

impl Stats {
    /// Compute statistics from raw samples.
    pub fn from_samples(name: &str, samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Stats {
            name: name.to_string(),
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: sorted[n / 2],
            samples,
        }
    }

    /// One-line human-readable summary.
    pub fn line(&self) -> String {
        format!(
            "{:<40} n={:<4} mean={:>12} ± {:<12} min={:>12} p50={:>12} max={:>12}",
            self.name,
            self.n,
            crate::util::human_time(self.mean),
            crate::util::human_time(self.stddev),
            crate::util::human_time(self.min),
            crate::util::human_time(self.p50),
            crate::util::human_time(self.max),
        )
    }

    /// Machine-readable form: summary statistics plus raw samples.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("name", Value::Str(self.name.clone())),
            ("n", Value::Num(self.n as f64)),
            ("mean", Value::Num(self.mean)),
            ("stddev", Value::Num(self.stddev)),
            ("p50", Value::Num(self.p50)),
            ("min", Value::Num(self.min)),
            ("max", Value::Num(self.max)),
            ("samples", Value::Arr(self.samples.iter().map(|&s| Value::Num(s)).collect())),
        ])
    }
}

/// Benchmark runner: `warmup` untimed runs followed by `samples` timed runs.
pub struct Bench {
    warmup: usize,
    samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench::new(3, 30)
    }
}

impl Bench {
    /// Create a runner with explicit warmup/sample counts.
    ///
    /// `MODTRANS_BENCH_SAMPLES=<n>` overrides the sample count in either
    /// direction: CI's bench-smoke job sets `2` so every bench binary
    /// finishes in seconds while still exercising its full code path,
    /// and the nightly baseline workflow sets `>= 30` so the uploaded
    /// artifacts carry enough samples to arm the perf gate
    /// (`perf_diff.py --min-samples`). Shrinking the run also drops
    /// warmup to at most 1; growing it keeps the declared warmup.
    pub fn new(warmup: usize, samples: usize) -> Bench {
        match std::env::var("MODTRANS_BENCH_SAMPLES")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
        {
            Some(n) if n < samples => Bench { warmup: warmup.min(1), samples: n.max(1) },
            Some(n) => Bench { warmup, samples: n.max(1) },
            None => Bench { warmup, samples },
        }
    }

    /// Run `f` and collect statistics. `f` is passed the iteration index
    /// (warmup iterations get indices `0..warmup`).
    pub fn run<F: FnMut(usize)>(&self, name: &str, mut f: F) -> Stats {
        for i in 0..self.warmup {
            f(i);
        }
        let mut samples = Vec::with_capacity(self.samples);
        for i in 0..self.samples {
            let t0 = Instant::now();
            f(self.warmup + i);
            samples.push(t0.elapsed().as_secs_f64());
        }
        let s = Stats::from_samples(name, samples);
        println!("{}", s.line());
        s
    }
}

/// Collects every series a bench binary produces and writes them to
/// `BENCH_<name>.json` — the per-PR perf-trajectory artifact.
#[derive(Debug)]
pub struct BenchReport {
    name: String,
    series: Vec<Stats>,
}

impl BenchReport {
    /// Start a report for the bench binary `name` (the file becomes
    /// `BENCH_<name>.json`).
    pub fn new(name: &str) -> BenchReport {
        BenchReport { name: name.to_string(), series: Vec::new() }
    }

    /// Run a series through `bench` and record its stats.
    pub fn run<F: FnMut(usize)>(&mut self, bench: &Bench, label: &str, f: F) -> &Stats {
        let s = bench.run(label, f);
        self.series.push(s);
        // lint: allow(no-panic) — the element was pushed on the previous line
        self.series.last().expect("series just pushed")
    }

    /// Record a hand-timed series (e.g. single-shot throughput numbers).
    pub fn add(&mut self, stats: Stats) {
        self.series.push(stats);
    }

    /// Recorded series, in run order.
    pub fn series(&self) -> &[Stats] {
        &self.series
    }

    /// Machine-readable form of the whole report.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("name", Value::Str(self.name.clone())),
            ("series", Value::Arr(self.series.iter().map(Stats::to_json).collect())),
        ])
    }

    /// Write `BENCH_<name>.json` into `$MODTRANS_BENCH_OUT` (default:
    /// current directory); returns the path written.
    pub fn write(&self) -> Result<PathBuf> {
        let dir = std::env::var("MODTRANS_BENCH_OUT").unwrap_or_else(|_| ".".to_string());
        self.write_to(Path::new(&dir))
    }

    /// Write `BENCH_<name>.json` into an explicit directory.
    pub fn write_to(&self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_json_pretty())?;
        Ok(path)
    }
}

/// Prevent the optimizer from discarding a computed value
/// (`std::hint::black_box` wrapper, kept for call-site clarity).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant_samples() {
        let s = Stats::from_samples("c", vec![2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn stats_mean_stddev() {
        let s = Stats::from_samples("x", vec![1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.stddev - 1.0).abs() < 1e-12);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn stats_keep_raw_sample_order() {
        let s = Stats::from_samples("x", vec![3.0, 1.0, 2.0]);
        assert_eq!(s.samples, vec![3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        // Direct construction bypasses the MODTRANS_BENCH_SAMPLES
        // override so this test's counts hold even under a smoke-capped
        // environment.
        let b = Bench { warmup: 2, samples: 5 };
        let s = b.run("iters", |_| count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn report_json_has_every_series_and_raw_samples() {
        let mut report = BenchReport::new("unit");
        report.add(Stats::from_samples("a", vec![1.0, 2.0]));
        report.add(Stats::from_samples("b", vec![0.5]));
        assert_eq!(report.series().len(), 2);
        let v = crate::json::parse(&report.to_json().to_json_pretty()).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("unit"));
        let series = v.get("series").unwrap().as_arr().unwrap();
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(series[0].get("n").unwrap().as_u64(), Some(2));
        let samples = series[0].get("samples").unwrap().as_arr().unwrap();
        assert_eq!(samples.len(), 2);
        assert_eq!(samples[0].as_f64(), Some(1.0));
        assert_eq!(series[1].get("mean").unwrap().as_f64(), Some(0.5));
    }

    #[test]
    fn report_writes_bench_json_file() {
        // Explicit-directory path: no process-global env mutation (the
        // test harness runs tests concurrently in one process).
        let dir = std::env::temp_dir().join("modtrans_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut report = BenchReport::new("writer_unit");
        report.add(Stats::from_samples("s", vec![0.25, 0.75]));
        let path = report.write_to(&dir).unwrap();
        assert_eq!(path.file_name().unwrap().to_str(), Some("BENCH_writer_unit.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::json::parse(&text).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("writer_unit"));
        let _ = std::fs::remove_file(&path);
    }
}
