//! Plain-text table rendering for CLI reports and the bench harness
//! (reproduces the paper's Tables 1–3 layout).

/// A simple left-aligned text table with a header row.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a data row; must match the header arity.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with `|`-separated columns and a rule under the header,
    /// matching the paper's table style.
    pub fn render(&self) -> String {
        // Widths in characters, not bytes (cells may contain 'µ').
        let w = |s: &str| s.chars().count();
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| w(h)).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(w(c));
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(widths[i] - w(&cells[i])));
                s.push_str(" |");
            }
            s
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let rule: String = {
            let mut s = String::from("|");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('|');
            }
            s
        };
        out.push_str(&rule);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_columns() {
        let mut t = Table::new(vec!["Layer Name", "Variables"]);
        t.row(vec!["vgg16-conv0-weight", "1728"]);
        t.row(vec!["x", "36864"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines same width.
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        // Multi-byte cells still align (char width, not byte width).
        let mut t2 = Table::new(vec!["a"]);
        t2.row(vec!["1.2 µs"]);
        t2.row(vec!["123456"]);
        let r2 = t2.render();
        let lens: Vec<usize> = r2.lines().map(|l| l.chars().count()).collect();
        assert!(lens.iter().all(|&l| l == lens[0]), "{r2}");
        assert!(lines[0].contains("Layer Name"));
        assert!(lines[2].contains("1728"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}
