//! Deterministic pseudo-random number generation (SplitMix64 + xoshiro256**).
//!
//! The offline build has no `rand` crate; this module provides the PRNG used
//! by the property tests, workload generators and jittered simulations.
//! xoshiro256** is the same generator family `rand`'s `SmallRng` uses on
//! 64-bit targets: fast, high-quality, and trivially seedable.

/// xoshiro256** generator, seeded via SplitMix64 so that *any* `u64` seed
/// (including 0) produces a well-mixed state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed. Two generators with the same
    /// seed produce identical streams — all tests rely on this.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into 256 bits of state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `u64` in `[0, bound)` via Lemire's multiply-shift rejection.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        // Rejection sampling to remove modulo bias.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let wide = (r as u128) * (bound as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform `u64` in the inclusive range `[lo, hi]`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform `usize` in `[lo, hi]`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// `f64` uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Choose a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample from an exponential distribution with rate `lambda`
    /// (mean `1/lambda`). Used for arrival-jitter in workload generators.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_not_constant() {
        let mut r = Rng::new(7);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen.insert(v);
        }
        assert_eq!(seen.len(), 10, "all residues should appear in 1000 draws");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "100 elems should move");
    }

    #[test]
    fn exp_mean_roughly_inverse_lambda() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} should be ~0.5");
    }
}
