//! SCALE-sim-style systolic-array compute-time model.
//!
//! The paper (§3.1) delegates per-layer compute times to SCALE-sim, a
//! cycle-accurate systolic CNN accelerator simulator. This module
//! implements SCALE-sim's *analytical* timing equations for an `R×C` PE
//! array under the three classic dataflows, plus a DRAM-bandwidth bound:
//!
//! * **Output-stationary (OS)** — each fold streams `K` partial sums
//!   through the array: `cycles/fold = 2R + C + K − 2`.
//! * **Weight-stationary (WS)** — weights preloaded per fold, activations
//!   streamed: `cycles/fold = R + C + M − 2` (+`R` load).
//! * **Input-stationary (IS)** — dual of WS with `N` streaming.
//!
//! Folds = `⌈M/R⌉ × ⌈N/C⌉` (OS) or `⌈K/R⌉ × ⌈N/C⌉` (WS/IS). Conv layers
//! are lowered to GEMM via im2col (`M = B·H·W`, `K = Cin·kh·kw`,
//! `N = Cout`), exactly how SCALE-sim and the L1 Pallas kernel treat them.
//! This mapping is also the §Hardware-Adaptation story: the systolic array
//! *is* the MXU, so the same tiling drives the TPU kernel's BlockSpec.

use crate::translator::{ComputeTimeModel, LayerInfo, LayerKind};

/// Systolic dataflow variants (SCALE-sim's `dataflow` knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataflow {
    /// Output stationary.
    Os,
    /// Weight stationary.
    Ws,
    /// Input stationary.
    Is,
}

impl Dataflow {
    /// Canonical config token (used in compute-model fingerprints).
    pub fn token(self) -> &'static str {
        match self {
            Dataflow::Os => "os",
            Dataflow::Ws => "ws",
            Dataflow::Is => "is",
        }
    }
}

/// A GEMM problem `M×K × K×N`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Gemm {
    /// Output rows (batch × spatial for conv-as-GEMM).
    pub m: u64,
    /// Inner/contraction dimension.
    pub k: u64,
    /// Output columns.
    pub n: u64,
}

impl Gemm {
    /// MAC count.
    pub fn macs(&self) -> u64 {
        self.m * self.k * self.n
    }

    /// Derive the im2col GEMM for a layer (batch folded into M).
    pub fn from_layer(layer: &LayerInfo, batch: i64) -> Gemm {
        match layer.kind {
            LayerKind::Conv => {
                // out_shape = [B, Cout, H, W]; weight vars = Cout*K.
                let cout = layer.out_shape.get(1).copied().unwrap_or(1).max(1) as u64;
                let spatial: u64 = layer
                    .out_shape
                    .iter()
                    .skip(2)
                    .map(|&d| d.max(1) as u64)
                    .product();
                let b = layer.out_shape.first().copied().unwrap_or(batch).max(1) as u64;
                let k = (layer.variables / cout).max(1);
                Gemm { m: b * spatial, k, n: cout }
            }
            LayerKind::Dense | LayerKind::MatMul => {
                let n = *layer.out_shape.last().unwrap_or(&1) as u64;
                let n = n.max(1);
                let k = (layer.variables / n).max(1);
                let m = (layer.macs / (k * n)).max(1);
                Gemm { m, k, n }
            }
            LayerKind::Embedding => Gemm { m: 1, k: 1, n: 1 },
        }
    }
}

/// SCALE-sim-like accelerator description.
#[derive(Debug, Clone, Copy)]
pub struct SystolicConfig {
    /// PE array rows.
    pub rows: u64,
    /// PE array columns.
    pub cols: u64,
    /// Clock in GHz (cycles/ns).
    pub clock_ghz: f64,
    /// DRAM bandwidth in GB/s.
    pub dram_gbps: f64,
    /// Dataflow.
    pub dataflow: Dataflow,
}

impl Default for SystolicConfig {
    fn default() -> Self {
        // A 128×128 MXU-class array at 940 MHz with 1.2 TB/s HBM.
        SystolicConfig {
            rows: 128,
            cols: 128,
            clock_ghz: 0.94,
            dram_gbps: 1200.0,
            dataflow: Dataflow::Ws,
        }
    }
}

impl SystolicConfig {
    /// Compute cycles for a GEMM under the configured dataflow.
    pub fn gemm_cycles(&self, g: Gemm) -> u64 {
        let (r, c) = (self.rows as f64, self.cols as f64);
        let (m, k, n) = (g.m as f64, g.k as f64, g.n as f64);
        let ceil = |a: f64, b: f64| (a / b).ceil();
        let cycles = match self.dataflow {
            Dataflow::Os => (2.0 * r + c + k - 2.0) * ceil(m, r) * ceil(n, c),
            Dataflow::Ws => (r + c + m - 2.0) * ceil(k, r) * ceil(n, c),
            Dataflow::Is => (r + c + n - 2.0) * ceil(k, r) * ceil(m, c),
        };
        cycles.ceil() as u64
    }

    /// GEMM wall time in ns: max of compute cycles and the DRAM bound on
    /// moving `A + B + C` once.
    pub fn gemm_ns(&self, g: Gemm, elem_bytes: u64) -> u64 {
        let compute = self.gemm_cycles(g) as f64 / self.clock_ghz;
        let bytes = (g.m * g.k + g.k * g.n + g.m * g.n) * elem_bytes;
        let dram = bytes as f64 / self.dram_gbps;
        compute.max(dram).ceil() as u64
    }

    /// Achieved MAC throughput (MACs/cycle) for a GEMM — the utilization
    /// figure DESIGN.md's roofline discussion reports.
    pub fn utilization(&self, g: Gemm) -> f64 {
        let peak = (self.rows * self.cols) as f64;
        g.macs() as f64 / (self.gemm_cycles(g) as f64 * peak)
    }
}

/// [`ComputeTimeModel`] backed by the systolic model. Backward GEMMs
/// (input-grad: `M×N × N×K`; weight-grad: `K×M × M×N`) are modeled with
/// their exact transposed shapes, not assumed equal to forward.
#[derive(Debug, Clone, Copy)]
pub struct SystolicCompute {
    /// Accelerator description.
    pub cfg: SystolicConfig,
    /// Batch size (must match the extraction batch).
    pub batch: i64,
}

impl SystolicCompute {
    /// Standard configuration at a given batch.
    pub fn new(batch: i64) -> SystolicCompute {
        SystolicCompute { cfg: SystolicConfig::default(), batch }
    }
}

impl ComputeTimeModel for SystolicCompute {
    /// The optimizer update streams parameters at the accelerator's DRAM
    /// bandwidth (GB/s == bytes/ns), not the historical 100 GB/s default.
    fn update_bandwidth(&self) -> f64 {
        self.cfg.dram_gbps
    }

    fn layer_times(&self, layer: &LayerInfo) -> (u64, u64, u64) {
        let e = layer.dtype.size_bytes().max(1);
        let f = Gemm::from_layer(layer, self.batch);
        if layer.kind == LayerKind::Embedding {
            // Lookup is bandwidth-bound on the gathered rows.
            let t = (layer.out_act_bytes as f64 / self.cfg.dram_gbps).ceil() as u64;
            return (t.max(1), t.max(1), 1);
        }
        let fwd = self.cfg.gemm_ns(f, e);
        // dX = dY × Wᵀ : (M×N)(N×K)
        let ig = self.cfg.gemm_ns(Gemm { m: f.m, k: f.n, n: f.k }, e);
        // dW = Xᵀ × dY : (K×M)(M×N)
        let wg = self.cfg.gemm_ns(Gemm { m: f.k, k: f.m, n: f.n }, e);
        (fwd.max(1), ig.max(1), wg.max(1))
    }

    /// Every timing knob: array geometry, clock, DRAM bandwidth, dataflow
    /// and the batch the GEMMs are folded at.
    fn fingerprint(&self) -> String {
        format!(
            "systolic:{}x{}@{}ghz:dram{}:{}:b{}",
            self.cfg.rows,
            self.cfg.cols,
            self.cfg.clock_ghz,
            self.cfg.dram_gbps,
            self.cfg.dataflow.token(),
            self.batch,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::DataType;

    fn cfg(df: Dataflow) -> SystolicConfig {
        SystolicConfig { dataflow: df, ..Default::default() }
    }

    #[test]
    fn square_gemm_cycles_sane() {
        // 128³ GEMM on a 128×128 WS array: one fold, R+C+M-2 = 382 cycles.
        let c = cfg(Dataflow::Ws);
        assert_eq!(c.gemm_cycles(Gemm { m: 128, k: 128, n: 128 }), 382);
        // OS: 2R+C+K-2 = 510.
        assert_eq!(cfg(Dataflow::Os).gemm_cycles(Gemm { m: 128, k: 128, n: 128 }), 510);
    }

    #[test]
    fn folds_scale_linearly() {
        let c = cfg(Dataflow::Ws);
        let one = c.gemm_cycles(Gemm { m: 128, k: 128, n: 128 });
        let four = c.gemm_cycles(Gemm { m: 128, k: 256, n: 256 });
        assert_eq!(four, one * 4);
    }

    #[test]
    fn utilization_peaks_near_large_square() {
        let c = cfg(Dataflow::Ws);
        let small = c.utilization(Gemm { m: 8, k: 8, n: 8 });
        let big = c.utilization(Gemm { m: 4096, k: 4096, n: 4096 });
        assert!(big > 0.9, "large GEMM should near peak, got {big}");
        assert!(small < 0.01, "tiny GEMM wastes the array, got {small}");
    }

    #[test]
    fn dram_bound_kicks_in_when_bandwidth_starved() {
        // Same GEMM, 12× less DRAM bandwidth → the memory bound governs.
        let fast = cfg(Dataflow::Ws);
        let slow = SystolicConfig { dram_gbps: 100.0, ..fast };
        let g = Gemm { m: 1 << 20, k: 1, n: 128 };
        let dram_ns = ((g.m * g.k + g.k * g.n + g.m * g.n) * 4) as f64 / slow.dram_gbps;
        assert_eq!(slow.gemm_ns(g, 4), dram_ns.ceil() as u64);
        // With the default 1.2 TB/s the fill-dominated compute bound wins.
        assert!(fast.gemm_ns(g, 4) < dram_ns as u64);
    }

    #[test]
    fn conv_layer_to_gemm_mapping() {
        let layer = LayerInfo {
            name: "conv".into(),
            kind: LayerKind::Conv,
            variables: 64 * 3 * 7 * 7,
            dtype: DataType::Float,
            weight_bytes: 64 * 3 * 7 * 7 * 4,
            in_act_bytes: 0,
            out_act_bytes: 0,
            macs: 0,
            out_shape: vec![8, 64, 112, 112],
        };
        let g = Gemm::from_layer(&layer, 8);
        assert_eq!(g.m, 8 * 112 * 112);
        assert_eq!(g.k, 3 * 7 * 7);
        assert_eq!(g.n, 64);
    }

    #[test]
    fn backward_times_differ_from_forward_for_rectangular() {
        let layer = LayerInfo {
            name: "fc".into(),
            kind: LayerKind::Dense,
            variables: 25088 * 4096,
            dtype: DataType::Float,
            weight_bytes: 25088 * 4096 * 4,
            in_act_bytes: 32 * 25088 * 4,
            out_act_bytes: 32 * 4096 * 4,
            macs: 32 * 25088 * 4096,
            out_shape: vec![32, 4096],
        };
        let sc = SystolicCompute::new(32);
        let (f, ig, wg) = sc.layer_times(&layer);
        assert!(f > 0 && ig > 0 && wg > 0);
        // wg GEMM is (25088×32)(32×4096): same MACs, different fold shape.
        assert_ne!(f, wg);
    }

    #[test]
    fn dataflow_changes_cycles() {
        let g = Gemm { m: 1024, k: 64, n: 1024 };
        let ws = cfg(Dataflow::Ws).gemm_cycles(g);
        let os = cfg(Dataflow::Os).gemm_cycles(g);
        let is = cfg(Dataflow::Is).gemm_cycles(g);
        // With K << M, WS folds over K are cheap relative to OS.
        assert_ne!(ws, os);
        assert_ne!(os, is);
    }
}
