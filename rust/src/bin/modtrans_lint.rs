//! `modtrans-lint` — the gating static-analysis binary.
//!
//! Walks `rust/src/**/*.rs` under the repo root and applies the rule
//! manifest (`analysis/rules.toml`). Exit codes: 0 clean, 1 findings,
//! 2 setup error (unreadable tree, malformed manifest or marker).
//!
//! ```text
//! modtrans-lint [ROOT] [--manifest PATH] [--quiet]
//! ```
//!
//! `ROOT` defaults to the current directory; CI and `make lint` run it
//! from the repo root.

use std::path::PathBuf;
use std::process::ExitCode;

use modtrans::analysis::{lint_tree, rules};

fn run() -> Result<ExitCode, String> {
    let mut root = PathBuf::from(".");
    let mut manifest_path: Option<PathBuf> = None;
    let mut quiet = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--manifest" => {
                let p = args
                    .next()
                    .ok_or_else(|| "--manifest needs a path".to_string())?;
                manifest_path = Some(PathBuf::from(p));
            }
            "--quiet" => quiet = true,
            "--help" | "-h" => {
                println!("usage: modtrans-lint [ROOT] [--manifest PATH] [--quiet]");
                return Ok(ExitCode::SUCCESS);
            }
            other if !other.starts_with('-') => root = PathBuf::from(other),
            other => return Err(format!("unknown flag: {other}")),
        }
    }
    let manifest_path =
        manifest_path.unwrap_or_else(|| root.join("analysis").join("rules.toml"));
    let text = std::fs::read_to_string(&manifest_path)
        .map_err(|e| format!("cannot read manifest {}: {e}", manifest_path.display()))?;
    let manifest = rules::parse_manifest(&text).map_err(|e| e.to_string())?;
    let report = lint_tree(&root, &manifest).map_err(|e| e.to_string())?;
    for f in &report.findings {
        println!("{f}");
    }
    if !quiet {
        eprintln!(
            "modtrans-lint: {} file(s), {} rule(s), {} finding(s), {} suppressed",
            report.files_scanned,
            manifest.rules.len(),
            report.findings.len(),
            report.suppressed
        );
    }
    if report.findings.is_empty() {
        Ok(ExitCode::SUCCESS)
    } else {
        Ok(ExitCode::FAILURE)
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(msg) => {
            eprintln!("modtrans-lint: error: {msg}");
            ExitCode::from(2)
        }
    }
}
