//! Layer-information extraction from ONNX graphs (paper §3.3).
//!
//! Walks the graph in topological order, identifies weight-bearing compute
//! layers (Conv / Gemm / MatMul), and records for each: name, parameter
//! count ("Variables"), dtype, byte size ("Model Size"), activation sizes,
//! and MAC count. Also keeps the full initializer listing for
//! `modtrans inspect --all`.

use crate::error::{Error, Result};
use crate::onnx::{infer_shapes, DataType, GraphIndex, Model, Node};

/// Classification of a compute layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// 2-D convolution.
    Conv,
    /// Fully connected (Gemm).
    Dense,
    /// Generic matrix multiply (transformer projections).
    MatMul,
    /// Embedding lookup (Gather on a parameter table).
    Embedding,
}

impl LayerKind {
    /// Short label for reports.
    pub fn label(self) -> &'static str {
        match self {
            LayerKind::Conv => "conv",
            LayerKind::Dense => "dense",
            LayerKind::MatMul => "matmul",
            LayerKind::Embedding => "embedding",
        }
    }

    /// Parse a [`LayerKind::label`] token (the et-json reader's inverse).
    pub fn from_label(s: &str) -> Result<LayerKind> {
        Ok(match s {
            "conv" => LayerKind::Conv,
            "dense" => LayerKind::Dense,
            "matmul" => LayerKind::MatMul,
            "embedding" => LayerKind::Embedding,
            other => return Err(Error::translate(format!("unknown layer kind '{other}'"))),
        })
    }
}

/// Extracted information for one weight-bearing layer.
#[derive(Debug, Clone)]
pub struct LayerInfo {
    /// Layer name: the weight initializer's name with a trailing
    /// `-weight`/`.weight` stripped (paper table convention).
    pub name: String,
    /// Operator classification.
    pub kind: LayerKind,
    /// Parameter count of the weight tensor (paper "Variables").
    pub variables: u64,
    /// Weight dtype (paper "Data Type").
    pub dtype: DataType,
    /// Weight bytes (paper "Model Size").
    pub weight_bytes: u64,
    /// Input activation bytes at the translation batch size.
    pub in_act_bytes: u64,
    /// Output activation bytes at the translation batch size.
    pub out_act_bytes: u64,
    /// Multiply-accumulate count for one forward pass at the translation
    /// batch size.
    pub macs: u64,
    /// Output spatial/feature shape (diagnostics).
    pub out_shape: Vec<i64>,
}

/// Full-model extraction result.
#[derive(Debug, Clone)]
pub struct ModelSummary {
    /// Graph name from the model.
    pub model_name: String,
    /// Weight-bearing compute layers, in topological order.
    pub layers: Vec<LayerInfo>,
    /// Every initializer as (name, variables, dtype, bytes) — the
    /// unfiltered view (`inspect --all`).
    pub all_initializers: Vec<(String, u64, DataType, u64)>,
    /// Batch size activations were sized at.
    pub batch: i64,
    /// Total parameters across all initializers.
    pub total_params: u64,
    /// Total parameter bytes.
    pub total_bytes: u64,
}

/// Extract from raw `.onnx` bytes (metadata-only decode; weight payloads
/// are never copied).
pub fn extract_from_bytes(bytes: &[u8], batch: i64) -> Result<ModelSummary> {
    let model = crate::onnx::parse_model_meta(bytes)?;
    extract(&model, batch)
}

/// Extract from an in-memory model.
pub fn extract(model: &Model, batch: i64) -> Result<ModelSummary> {
    let graph = &model.graph;
    let idx = GraphIndex::new(graph)?;
    let shapes = infer_shapes(graph, batch)?;

    let act_bytes = |edge: &str| -> u64 {
        shapes
            .get(edge)
            .map(|(dt, dims)| {
                dims.iter().map(|&d| d.max(0) as u64).product::<u64>() * dt.size_bytes()
            })
            .unwrap_or(0)
    };

    let mut layers = Vec::new();
    for node in idx.topo_nodes() {
        let Some((kind, weight_input)) = classify(node, &idx) else {
            continue;
        };
        let wname = &node.inputs[weight_input];
        let w = idx
            .initializer(wname)
            .ok_or_else(|| Error::translate(format!("weight '{wname}' not an initializer")))?;
        let out_edge = node
            .outputs
            .first()
            .ok_or_else(|| Error::translate(format!("node '{}' has no output", node.name)))?;
        let (_, out_dims) = shapes
            .get(out_edge)
            .ok_or_else(|| Error::translate(format!("no shape for '{out_edge}'")))?;
        let macs = macs_for(node, kind, w.dims.as_slice(), out_dims);
        layers.push(LayerInfo {
            name: layer_name(wname, node),
            kind,
            variables: w.num_elements(),
            dtype: w.data_type,
            weight_bytes: w.size_bytes(),
            in_act_bytes: act_bytes(&node.inputs[if kind == LayerKind::Embedding { 1 } else { 0 }]),
            out_act_bytes: act_bytes(out_edge),
            macs,
            out_shape: out_dims.clone(),
        });
    }

    let all_initializers = graph
        .initializers
        .iter()
        .map(|t| (t.name.clone(), t.num_elements(), t.data_type, t.size_bytes()))
        .collect();

    Ok(ModelSummary {
        model_name: graph.name.clone(),
        layers,
        all_initializers,
        batch,
        total_params: model.num_parameters(),
        total_bytes: model.parameter_bytes(),
    })
}

/// Identify weight-bearing compute nodes and which input is the weight.
fn classify(node: &Node, idx: &GraphIndex<'_>) -> Option<(LayerKind, usize)> {
    match node.op_type.as_str() {
        "Conv" if node.inputs.len() >= 2 && idx.is_initializer(&node.inputs[1]) => {
            Some((LayerKind::Conv, 1))
        }
        "Gemm" if node.inputs.len() >= 2 && idx.is_initializer(&node.inputs[1]) => {
            Some((LayerKind::Dense, 1))
        }
        "MatMul" if node.inputs.len() == 2 && idx.is_initializer(&node.inputs[1]) => {
            Some((LayerKind::MatMul, 1))
        }
        "Gather" if !node.inputs.is_empty() && idx.is_initializer(&node.inputs[0]) => {
            Some((LayerKind::Embedding, 0))
        }
        _ => None,
    }
}

/// Derive the table layer name from the weight tensor name (strip the
/// `-weight` / `.weight` suffix); fall back to the node name.
fn layer_name(weight_name: &str, node: &Node) -> String {
    for suffix in ["-weight", ".weight", "_weight"] {
        if let Some(stripped) = weight_name.strip_suffix(suffix) {
            return stripped.to_string();
        }
    }
    if !node.name.is_empty() {
        node.name.clone()
    } else {
        weight_name.to_string()
    }
}

/// MAC count for one forward pass.
fn macs_for(node: &Node, kind: LayerKind, w_dims: &[i64], out_dims: &[i64]) -> u64 {
    let prod = |ds: &[i64]| ds.iter().map(|&d| d.max(0) as u64).product::<u64>();
    match kind {
        // Conv: out_elems × (cin/group × kh × kw). The weight's dim 1 is
        // already cin/group, so grouping needs no extra correction.
        LayerKind::Conv => prod(out_dims) * prod(&w_dims[1..]),
        // Dense/MatMul: out_elems × K (K = shared inner dim).
        LayerKind::Dense => {
            let tb = node.attr_i("transB", 0) == 1;
            let k = if tb { w_dims[1] } else { w_dims[0] } as u64;
            prod(out_dims) * k
        }
        LayerKind::MatMul => {
            let k = w_dims[w_dims.len() - 2] as u64;
            prod(out_dims) * k
        }
        // Embedding lookup is a copy, not MACs.
        LayerKind::Embedding => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::encode_model;
    use crate::zoo::{self, WeightFill, ZooOpts};

    fn summary_of(name: &str, batch: i64) -> ModelSummary {
        let m = zoo::get(name, ZooOpts { weights: WeightFill::Empty }).unwrap();
        let bytes = encode_model(&m);
        extract_from_bytes(&bytes, batch).unwrap()
    }

    #[test]
    fn vgg16_layer_table_matches_paper() {
        let s = summary_of("vgg16", 1);
        assert_eq!(s.layers.len(), 16);
        assert_eq!(s.layers[0].name, "vgg16-conv0");
        assert_eq!(s.layers[0].variables, 1728);
        assert_eq!(s.layers[0].dtype, DataType::Float);
        assert_eq!(s.layers[0].weight_bytes, 6912);
        assert_eq!(s.layers[13].name, "vgg16-dense0");
        assert_eq!(s.layers[13].variables, 102_760_448);
        assert_eq!(s.layers[13].weight_bytes, 411_041_792);
    }

    #[test]
    fn resnet50_table3_order_and_sizes() {
        let s = summary_of("resnet50", 1);
        assert_eq!(s.layers.len(), 54);
        assert_eq!(s.layers[0].name, "resnet-conv0");
        assert_eq!(s.layers[0].weight_bytes, 37632);
        assert_eq!(s.layers[1].name, "resnet-stage1-conv0");
        assert_eq!(s.layers[1].weight_bytes, 16384);
        assert_eq!(s.layers[53].name, "resnet-dense0");
        assert_eq!(s.layers[53].weight_bytes, 8_192_000);
    }

    #[test]
    fn conv_macs_are_exact() {
        // vgg16-conv0 at batch 1: out 64x224x224, per-out 3*3*3=27 MACs.
        let s = summary_of("vgg16", 1);
        let c0 = &s.layers[0];
        assert_eq!(c0.macs, 64 * 224 * 224 * 27);
        // Activations: in 3*224*224*4 bytes, out 64*224*224*4 bytes.
        assert_eq!(c0.in_act_bytes, 3 * 224 * 224 * 4);
        assert_eq!(c0.out_act_bytes, 64 * 224 * 224 * 4);
    }

    #[test]
    fn batch_scales_activations_and_macs_not_weights() {
        let s1 = summary_of("vgg16", 1);
        let s8 = summary_of("vgg16", 8);
        assert_eq!(s1.layers[0].weight_bytes, s8.layers[0].weight_bytes);
        assert_eq!(s8.layers[0].out_act_bytes, 8 * s1.layers[0].out_act_bytes);
        assert_eq!(s8.layers[0].macs, 8 * s1.layers[0].macs);
    }

    #[test]
    fn dense_macs() {
        // mlp-dense0: 784→4096 at batch B: B*4096*784 MACs.
        let s = summary_of("mlp", 4);
        assert_eq!(s.layers[0].macs, 4 * 4096 * 784);
        assert_eq!(s.layers[0].kind, LayerKind::Dense);
    }

    #[test]
    fn transformer_has_embedding_and_matmul_layers() {
        let s = summary_of("gpt2-tiny", 1);
        assert!(s.layers.iter().any(|l| l.kind == LayerKind::Embedding));
        assert!(s.layers.iter().any(|l| l.kind == LayerKind::MatMul));
        // Embedding contributes no MACs.
        let emb = s.layers.iter().find(|l| l.kind == LayerKind::Embedding).unwrap();
        assert_eq!(emb.macs, 0);
    }

    #[test]
    fn totals_cover_every_initializer() {
        let s = summary_of("resnet50", 1);
        assert_eq!(s.total_params, 25_610_152);
        // 54 layer weights + 53 BN × 4 tensors + dense bias = 267.
        assert_eq!(s.all_initializers.len(), 54 + 53 * 4 + 1);
        let m = zoo::get("resnet50", ZooOpts { weights: WeightFill::Empty }).unwrap();
        assert_eq!(s.all_initializers.len(), m.graph.initializers.len());
    }
}
