//! Per-parallelism communication planning (DESIGN.md "Parallelism →
//! communication mapping").
//!
//! Given a layer's parameter and activation sizes, decide which collective
//! each phase issues and how many bytes it moves, following ASTRA-sim's
//! workload conventions:
//!
//! * **DATA** — weights are replicated; after the weight-gradient GEMM an
//!   `ALLREDUCE(weight_bytes)` synchronizes gradients. No activation comm.
//! * **MODEL** — weights are sharded; each NPU computes a slice of the
//!   output and `ALLGATHER(out_act_bytes)` reassembles it in the forward
//!   pass; the input-gradient pass gathers the same volume back. Weight
//!   grads stay local.
//! * **HYBRID_DATA_MODEL** — model-parallel inside a group of `mp_group`
//!   NPUs (activation all-gathers within the group), data-parallel across
//!   the `npus/mp_group` groups (`ALLREDUCE(weight_bytes/mp_group)`: each
//!   group member owns a weight shard).
//! * **HYBRID_MODEL_DATA** — the dual: data-parallel inside the group,
//!   model-parallel across groups.
//! * **PIPELINE** — stage-to-stage activation sends are point-to-point and
//!   handled by the simulator's pipeline engine, not collectives; rows
//!   carry the DP all-reduce within each stage replica group if any.
//! * **Embedding layers** under MODEL/HYBRID shard the vocabulary and use
//!   `ALLTOALL` on the looked-up rows (Megatron-style).

use super::extract::{LayerInfo, LayerKind};
use super::memory::ZeroStage;
use super::TranslateOpts;
use crate::workload::{CommType, Parallelism};

/// The (comm type, bytes) choice for each phase of one layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommPlan {
    /// Forward pass.
    pub fwd: (CommType, u64),
    /// Input-gradient pass.
    pub ig: (CommType, u64),
    /// Weight-gradient pass.
    pub wg: (CommType, u64),
}

const NONE: (CommType, u64) = (CommType::None, 0);

impl CommPlan {
    /// The all-local plan: no communication in any phase. This is the
    /// empty comm-slot value [`crate::ir::ModelIR`] layers start with.
    pub const fn none() -> CommPlan {
        CommPlan { fwd: NONE, ig: NONE, wg: NONE }
    }
}

impl Default for CommPlan {
    fn default() -> CommPlan {
        CommPlan::none()
    }
}

/// Plan communication for one layer under the chosen strategy.
pub fn comm_for_layer(layer: &LayerInfo, opts: TranslateOpts) -> CommPlan {
    match opts.parallelism {
        // ZeRO replaces the gradient all-reduce on the DP axis:
        //   stage 1 — unchanged traffic (state sharding is local);
        //   stage 2 — reduce-scatter gradients, re-gather updated params
        //             before the next forward;
        //   stage 3 — parameters sharded too: gather them in BOTH passes.
        Parallelism::Data => match opts.zero {
            ZeroStage::None | ZeroStage::OptimizerState => CommPlan {
                fwd: NONE,
                ig: NONE,
                wg: (CommType::AllReduce, layer.weight_bytes),
            },
            ZeroStage::Gradients => CommPlan {
                fwd: (CommType::AllGather, layer.weight_bytes),
                ig: NONE,
                wg: (CommType::ReduceScatter, layer.weight_bytes),
            },
            ZeroStage::Parameters => CommPlan {
                fwd: (CommType::AllGather, layer.weight_bytes),
                ig: (CommType::AllGather, layer.weight_bytes),
                wg: (CommType::ReduceScatter, layer.weight_bytes),
            },
        },
        Parallelism::Model => match layer.kind {
            LayerKind::Embedding => CommPlan {
                fwd: (CommType::AllToAll, layer.out_act_bytes),
                ig: (CommType::AllToAll, layer.out_act_bytes),
                wg: NONE,
            },
            _ => CommPlan {
                fwd: (CommType::AllGather, layer.out_act_bytes),
                ig: (CommType::AllGather, layer.in_act_bytes),
                wg: NONE,
            },
        },
        Parallelism::HybridDataModel => {
            let g = opts.mp_group.max(1) as u64;
            let act = match layer.kind {
                LayerKind::Embedding => (CommType::AllToAll, layer.out_act_bytes / g),
                _ => (CommType::AllGather, layer.out_act_bytes),
            };
            CommPlan {
                fwd: act,
                ig: (CommType::AllGather, layer.in_act_bytes),
                wg: (CommType::AllReduce, layer.weight_bytes / g),
            }
        }
        Parallelism::HybridModelData => {
            let groups = (opts.npus / opts.mp_group.max(1)).max(1) as u64;
            CommPlan {
                fwd: (CommType::AllGather, layer.out_act_bytes / groups),
                ig: (CommType::AllGather, layer.in_act_bytes / groups),
                wg: (CommType::AllReduce, layer.weight_bytes / groups),
            }
        }
        Parallelism::Pipeline => CommPlan {
            // Stage-boundary sends are handled by the pipeline engine; the
            // workload rows keep the within-stage DP all-reduce.
            fwd: NONE,
            ig: NONE,
            wg: (CommType::AllReduce, layer.weight_bytes),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::DataType;

    fn layer(kind: LayerKind) -> LayerInfo {
        LayerInfo {
            name: "l".into(),
            kind,
            variables: 1000,
            dtype: DataType::Float,
            weight_bytes: 4000,
            in_act_bytes: 256,
            out_act_bytes: 512,
            macs: 1_000_000,
            out_shape: vec![1, 8, 8, 8],
        }
    }

    fn opts(p: Parallelism) -> TranslateOpts {
        TranslateOpts { parallelism: p, npus: 16, mp_group: 4, batch: 1, zero: ZeroStage::None }
    }

    #[test]
    fn zero_stages_change_dp_collectives() {
        let l = layer(LayerKind::Dense);
        let mut o = opts(Parallelism::Data);
        o.zero = ZeroStage::OptimizerState;
        assert_eq!(comm_for_layer(&l, o).wg.0, CommType::AllReduce);
        o.zero = ZeroStage::Gradients;
        let p = comm_for_layer(&l, o);
        assert_eq!(p.wg.0, CommType::ReduceScatter);
        assert_eq!(p.fwd.0, CommType::AllGather);
        assert_eq!(p.ig, NONE);
        o.zero = ZeroStage::Parameters;
        let p = comm_for_layer(&l, o);
        assert_eq!(p.ig.0, CommType::AllGather);
    }

    #[test]
    fn data_parallel_only_wg_allreduce() {
        let p = comm_for_layer(&layer(LayerKind::Conv), opts(Parallelism::Data));
        assert_eq!(p.fwd, NONE);
        assert_eq!(p.ig, NONE);
        assert_eq!(p.wg, (CommType::AllReduce, 4000));
    }

    #[test]
    fn model_parallel_gathers_activations() {
        let p = comm_for_layer(&layer(LayerKind::Dense), opts(Parallelism::Model));
        assert_eq!(p.fwd, (CommType::AllGather, 512));
        assert_eq!(p.ig, (CommType::AllGather, 256));
        assert_eq!(p.wg, NONE);
    }

    #[test]
    fn model_parallel_embedding_uses_alltoall() {
        let p = comm_for_layer(&layer(LayerKind::Embedding), opts(Parallelism::Model));
        assert_eq!(p.fwd.0, CommType::AllToAll);
    }

    #[test]
    fn hybrid_dm_shards_weight_allreduce() {
        let p = comm_for_layer(&layer(LayerKind::Conv), opts(Parallelism::HybridDataModel));
        assert_eq!(p.wg, (CommType::AllReduce, 1000)); // 4000 / mp_group=4
        assert_eq!(p.fwd.0, CommType::AllGather);
    }

    #[test]
    fn hybrid_md_divides_by_group_count() {
        let p = comm_for_layer(&layer(LayerKind::Conv), opts(Parallelism::HybridModelData));
        // 16 npus / 4 per group = 4 groups.
        assert_eq!(p.wg, (CommType::AllReduce, 1000));
        assert_eq!(p.fwd, (CommType::AllGather, 128));
    }

    #[test]
    fn pipeline_keeps_dp_allreduce() {
        let p = comm_for_layer(&layer(LayerKind::Conv), opts(Parallelism::Pipeline));
        assert_eq!(p.wg.0, CommType::AllReduce);
        assert_eq!(p.fwd, NONE);
    }
}
