//! ModTrans — the paper's contribution.
//!
//! Translates a real-world model (ONNX bytes, an [`crate::onnx::Model`],
//! or a zoo name) into:
//!
//! 1. a layer-by-layer summary (name / variables / data type / size — the
//!    paper's Tables 1–3), and
//! 2. an ASTRA-sim [`crate::workload::Workload`] description with
//!    per-phase compute times and per-parallelism communication sizes.
//!
//! Since the IR refactor the pipeline is staged through
//! [`crate::ir::ModelIR`] as frontends → passes → emitters: this module
//! hosts the ONNX structural frontend ([`extract()`]), the pass
//! ingredients ([`ComputeTimeModel`], [`comm_for_layer`],
//! [`memory_per_npu`]) and the one-call conveniences ([`to_workload`],
//! [`translate_bytes`]) that compose the staged pipeline for callers
//! that do not need to hold the IR themselves. Deserialization uses the
//! metadata-only decoder, so weight payloads are never copied.

mod comm;
mod extract;
pub mod memory;

pub use comm::{comm_for_layer, CommPlan};
pub use extract::{extract, extract_from_bytes, LayerInfo, LayerKind, ModelSummary};
pub use memory::{memory_per_npu, MemoryOpts, MemoryReport, Optimizer, ZeroStage};

use crate::error::Result;
use crate::workload::{Parallelism, Workload};

/// Source of per-layer compute times.
pub trait ComputeTimeModel {
    /// Return (fwd_ns, input_grad_ns, weight_grad_ns) for a layer.
    fn layer_times(&self, layer: &LayerInfo) -> (u64, u64, u64);

    /// Stable identity token for this timing function: two instances with
    /// the same fingerprint must return identical [`Self::layer_times`]
    /// and [`Self::update_time`] for every layer. The persistent IR cache
    /// ([`crate::sweep::WorkloadCache`]) keys compute-annotated IRs by it,
    /// so *every* knob that changes the produced times must appear here —
    /// an under-descriptive fingerprint silently serves stale timings.
    fn fingerprint(&self) -> String;

    /// Memory bandwidth in bytes/ns (== GB/s) used to cost the optimizer
    /// update. The default, 100 GB/s, is the historical hard-coded value
    /// kept for models that declare no bandwidth of their own
    /// ([`ConstantCompute`], measured calibrations); bandwidth-aware
    /// models ([`RooflineCompute`], [`crate::compute::SystolicCompute`])
    /// override it with their configured memory bandwidth.
    fn update_bandwidth(&self) -> f64 {
        100.0
    }

    /// Optimizer update time for a layer: bandwidth-bound SGD update over
    /// 3× the parameter bytes (read w, read g, write w) at
    /// [`ComputeTimeModel::update_bandwidth`].
    fn update_time(&self, layer: &LayerInfo) -> u64 {
        ((layer.weight_bytes * 3) as f64 / self.update_bandwidth().max(f64::MIN_POSITIVE)) as u64
    }
}

/// Trivial compute model: every phase costs a fixed time. Useful for
/// isolating communication behaviour in simulator studies.
#[derive(Debug, Clone, Copy)]
pub struct ConstantCompute(pub u64);

impl ComputeTimeModel for ConstantCompute {
    fn layer_times(&self, _layer: &LayerInfo) -> (u64, u64, u64) {
        (self.0, self.0, self.0)
    }

    fn fingerprint(&self) -> String {
        format!("constant:{}", self.0)
    }
}

/// Roofline compute model: `max(macs/peak_macs, bytes/bw)` per phase, with
/// the standard 1:1:1 fwd/ig/wg MAC equality for conv/dense backprop.
#[derive(Debug, Clone, Copy)]
pub struct RooflineCompute {
    /// Peak multiply-accumulates per nanosecond (e.g. 128x128 MXU at
    /// 940 MHz ≈ 15400 MACs/ns).
    pub macs_per_ns: f64,
    /// Memory bandwidth in bytes per nanosecond (e.g. HBM ≈ 1200 GB/s =
    /// 1.2 bytes/ns... scaled by accelerator).
    pub bytes_per_ns: f64,
}

impl Default for RooflineCompute {
    fn default() -> Self {
        // TPUv4-like single core: 137.5 MACs/ns (275 TFLOP/s bf16),
        // 1.2 TB/s HBM.
        RooflineCompute { macs_per_ns: 137_500.0 / 1000.0 * 10.0, bytes_per_ns: 1200.0 }
    }
}

impl ComputeTimeModel for RooflineCompute {
    fn layer_times(&self, layer: &LayerInfo) -> (u64, u64, u64) {
        let compute = layer.macs as f64 / self.macs_per_ns;
        let mem = (layer.weight_bytes + layer.in_act_bytes + layer.out_act_bytes) as f64
            / self.bytes_per_ns;
        let t = compute.max(mem).max(1.0) as u64;
        // Backward GEMMs have the same MAC count as forward.
        (t, t, t)
    }

    /// The optimizer update streams parameters at the same memory
    /// bandwidth the roofline uses for layer phases.
    fn update_bandwidth(&self) -> f64 {
        self.bytes_per_ns
    }

    fn fingerprint(&self) -> String {
        format!("roofline:macs{}:bw{}", self.macs_per_ns, self.bytes_per_ns)
    }
}

/// Translation options.
#[derive(Debug, Clone, Copy)]
pub struct TranslateOpts {
    /// Parallelism strategy to emit.
    pub parallelism: Parallelism,
    /// Number of NPUs participating (sizes hybrid groups).
    pub npus: usize,
    /// Model-parallel group size for hybrid strategies (also the stage
    /// count under PIPELINE).
    pub mp_group: usize,
    /// Batch size used to size activations.
    pub batch: i64,
    /// ZeRO sharding stage on the data-parallel axis (changes the
    /// gradient/parameter collectives under DATA parallelism).
    pub zero: memory::ZeroStage,
}

impl Default for TranslateOpts {
    fn default() -> Self {
        TranslateOpts {
            parallelism: Parallelism::Data,
            npus: 16,
            mp_group: 4,
            batch: 32,
            zero: memory::ZeroStage::None,
        }
    }
}

/// Translate a model summary into an ASTRA-sim workload description.
///
/// One-call composition of the staged pipeline in its slice-level form:
/// run the compute and comm passes over the borrowed summary, then lower
/// through the shared emitter — no summary clone, byte-identical to the
/// pre-refactor fused loop. Callers that reuse a model across many
/// translations (the sweep) hold a compute-annotated
/// [`crate::ir::ModelIR`] instead and re-run only the comm pass per
/// scenario.
pub fn to_workload(
    summary: &ModelSummary,
    opts: TranslateOpts,
    compute: &dyn ComputeTimeModel,
) -> Result<Workload> {
    let mut costs = Vec::new();
    crate::ir::passes::compute_costs_into(summary, compute, &mut costs);
    let mut comms = Vec::new();
    crate::ir::passes::plan_comm_for_summary_into(summary, opts, &mut comms);
    crate::ir::emit::workload_from_parts(summary, &costs, &comms, opts.parallelism)
}

/// One-call convenience: ONNX bytes → workload text.
pub fn translate_bytes(
    bytes: &[u8],
    opts: TranslateOpts,
    compute: &dyn ComputeTimeModel,
) -> Result<(ModelSummary, Workload)> {
    let summary = extract_from_bytes(bytes, opts.batch)?;
    let workload = to_workload(&summary, opts, compute)?;
    Ok((summary, workload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::encode_model;
    use crate::zoo::{self, WeightFill, ZooOpts};
    use crate::workload::CommType;

    #[test]
    fn resnet50_data_parallel_workload() {
        let m = zoo::get("resnet50", ZooOpts { weights: WeightFill::Empty }).unwrap();
        let bytes = encode_model(&m);
        let opts = TranslateOpts { parallelism: Parallelism::Data, ..Default::default() };
        let (summary, w) = translate_bytes(&bytes, opts, &ConstantCompute(1000)).unwrap();
        // 54 compute layers, like the ASTRA-sim reference workload.
        assert_eq!(summary.layers.len(), 54);
        assert_eq!(w.layers.len(), 54);
        // DATA: only weight-grad communicates, with ALLREDUCE of the weight
        // bytes — first layer is the 7x7 stem: 37632 bytes (Table 3).
        let l0 = &w.layers[0];
        assert_eq!(l0.name, "resnet-conv0");
        assert_eq!(l0.fwd.comm, CommType::None);
        assert_eq!(l0.input_grad.comm, CommType::None);
        assert_eq!(l0.weight_grad.comm, CommType::AllReduce);
        assert_eq!(l0.weight_grad.comm_bytes, 37632);
        // Emits valid text that reparses.
        let text = w.emit();
        assert_eq!(crate::workload::Workload::parse(&text).unwrap(), w);
    }

    #[test]
    fn model_parallel_uses_activation_allgather() {
        let m = zoo::get("mlp", ZooOpts { weights: WeightFill::Empty }).unwrap();
        let bytes = encode_model(&m);
        let opts = TranslateOpts {
            parallelism: Parallelism::Model,
            batch: 8,
            ..Default::default()
        };
        let (summary, w) = translate_bytes(&bytes, opts, &ConstantCompute(10)).unwrap();
        let l0 = &w.layers[0];
        assert_eq!(l0.fwd.comm, CommType::AllGather);
        // mlp-dense0 output: [8, 4096] f32 = 131072 bytes.
        assert_eq!(l0.fwd.comm_bytes, 8 * 4096 * 4);
        assert_eq!(l0.weight_grad.comm, CommType::None);
        assert_eq!(summary.layers[0].out_act_bytes, 8 * 4096 * 4);
    }

    #[test]
    fn hybrid_splits_allreduce_across_groups() {
        let m = zoo::get("mlp", ZooOpts { weights: WeightFill::Empty }).unwrap();
        let bytes = encode_model(&m);
        let opts = TranslateOpts {
            parallelism: Parallelism::HybridDataModel,
            npus: 16,
            mp_group: 4,
            batch: 8, zero: crate::translator::memory::ZeroStage::None };
        let (_, w) = translate_bytes(&bytes, opts, &ConstantCompute(10)).unwrap();
        let l0 = &w.layers[0];
        // fwd allgather within MP group; wg allreduce of 1/mp_group of the
        // weights across DP groups.
        assert_eq!(l0.fwd.comm, CommType::AllGather);
        assert_eq!(l0.weight_grad.comm, CommType::AllReduce);
        assert_eq!(l0.weight_grad.comm_bytes, (784 * 4096 * 4) / 4);
    }

    #[test]
    fn update_time_tracks_the_model_bandwidth() {
        let layer = LayerInfo {
            name: "l".into(),
            kind: LayerKind::Dense,
            variables: 1_000_000,
            dtype: crate::onnx::DataType::Float,
            weight_bytes: 4_000_000,
            in_act_bytes: 0,
            out_act_bytes: 0,
            macs: 0,
            out_shape: vec![1, 1000],
        };
        // Default: the historical 100 GB/s, exactly the old integer math.
        let constant = ConstantCompute(1);
        assert_eq!(constant.update_bandwidth(), 100.0);
        assert_eq!(constant.update_time(&layer), (4_000_000 * 3) / 100);
        // Roofline: streams at its own memory bandwidth (1.2 TB/s).
        let roofline = RooflineCompute::default();
        assert_eq!(roofline.update_bandwidth(), 1200.0);
        assert_eq!(roofline.update_time(&layer), ((4_000_000u64 * 3) as f64 / 1200.0) as u64);
        // Systolic: DRAM bandwidth from its accelerator description.
        let systolic = crate::compute::SystolicCompute::new(8);
        assert_eq!(systolic.update_bandwidth(), systolic.cfg.dram_gbps);
        assert!(systolic.update_time(&layer) < constant.update_time(&layer));
    }

    #[test]
    fn roofline_times_scale_with_macs() {
        let m = zoo::get("vgg16", ZooOpts { weights: WeightFill::Empty }).unwrap();
        let bytes = encode_model(&m);
        let (summary, w) = translate_bytes(
            &bytes,
            TranslateOpts::default(),
            &RooflineCompute::default(),
        )
        .unwrap();
        // dense0 (102M params) must take longer than conv0 (1.7k params
        // but big activations) in wg; and all times nonzero.
        assert!(w.layers.iter().all(|l| l.fwd.compute_ns > 0));
        let conv0 = &w.layers[0];
        let dense_idx = summary.layers.iter().position(|l| l.name == "vgg16-dense0").unwrap();
        assert!(w.layers[dense_idx].update_ns > conv0.update_ns);
    }
}
