//! ModTrans — the paper's contribution.
//!
//! Translates a real-world model (ONNX bytes, an [`crate::onnx::Model`],
//! or a zoo name) into:
//!
//! 1. a layer-by-layer summary (name / variables / data type / size — the
//!    paper's Tables 1–3), and
//! 2. an ASTRA-sim [`crate::workload::Workload`] description with
//!    per-phase compute times and per-parallelism communication sizes.
//!
//! Pipeline (paper §3.3): deserialize protobuf → walk the graph → extract
//! layer information → attach compute times → emit. Deserialization uses
//! the metadata-only decoder, so weight payloads are never copied.

mod comm;
mod extract;
pub mod memory;

pub use comm::{comm_for_layer, CommPlan};
pub use extract::{extract, extract_from_bytes, LayerInfo, LayerKind, ModelSummary};
pub use memory::{memory_per_npu, MemoryOpts, MemoryReport, Optimizer, ZeroStage};

use crate::error::Result;
use crate::workload::{LayerSpec, Parallelism, Phase, Workload};

/// Source of per-layer compute times.
pub trait ComputeTimeModel {
    /// Return (fwd_ns, input_grad_ns, weight_grad_ns) for a layer.
    fn layer_times(&self, layer: &LayerInfo) -> (u64, u64, u64);

    /// Optimizer update time for a layer (default: bandwidth-bound SGD
    /// update at 100 GB/s over 3× the parameter bytes: read w, read g,
    /// write w).
    fn update_time(&self, layer: &LayerInfo) -> u64 {
        (layer.weight_bytes * 3) / 100
    }
}

/// Trivial compute model: every phase costs a fixed time. Useful for
/// isolating communication behaviour in simulator studies.
#[derive(Debug, Clone, Copy)]
pub struct ConstantCompute(pub u64);

impl ComputeTimeModel for ConstantCompute {
    fn layer_times(&self, _layer: &LayerInfo) -> (u64, u64, u64) {
        (self.0, self.0, self.0)
    }
}

/// Roofline compute model: `max(macs/peak_macs, bytes/bw)` per phase, with
/// the standard 1:1:1 fwd/ig/wg MAC equality for conv/dense backprop.
#[derive(Debug, Clone, Copy)]
pub struct RooflineCompute {
    /// Peak multiply-accumulates per nanosecond (e.g. 128x128 MXU at
    /// 940 MHz ≈ 15400 MACs/ns).
    pub macs_per_ns: f64,
    /// Memory bandwidth in bytes per nanosecond (e.g. HBM ≈ 1200 GB/s =
    /// 1.2 bytes/ns... scaled by accelerator).
    pub bytes_per_ns: f64,
}

impl Default for RooflineCompute {
    fn default() -> Self {
        // TPUv4-like single core: 137.5 MACs/ns (275 TFLOP/s bf16),
        // 1.2 TB/s HBM.
        RooflineCompute { macs_per_ns: 137_500.0 / 1000.0 * 10.0, bytes_per_ns: 1200.0 }
    }
}

impl ComputeTimeModel for RooflineCompute {
    fn layer_times(&self, layer: &LayerInfo) -> (u64, u64, u64) {
        let compute = layer.macs as f64 / self.macs_per_ns;
        let mem = (layer.weight_bytes + layer.in_act_bytes + layer.out_act_bytes) as f64
            / self.bytes_per_ns;
        let t = compute.max(mem).max(1.0) as u64;
        // Backward GEMMs have the same MAC count as forward.
        (t, t, t)
    }
}

/// Translation options.
#[derive(Debug, Clone, Copy)]
pub struct TranslateOpts {
    /// Parallelism strategy to emit.
    pub parallelism: Parallelism,
    /// Number of NPUs participating (sizes hybrid groups).
    pub npus: usize,
    /// Model-parallel group size for hybrid strategies (also the stage
    /// count under PIPELINE).
    pub mp_group: usize,
    /// Batch size used to size activations.
    pub batch: i64,
    /// ZeRO sharding stage on the data-parallel axis (changes the
    /// gradient/parameter collectives under DATA parallelism).
    pub zero: memory::ZeroStage,
}

impl Default for TranslateOpts {
    fn default() -> Self {
        TranslateOpts {
            parallelism: Parallelism::Data,
            npus: 16,
            mp_group: 4,
            batch: 32,
            zero: memory::ZeroStage::None,
        }
    }
}

/// Translate a model summary into an ASTRA-sim workload description.
pub fn to_workload(
    summary: &ModelSummary,
    opts: TranslateOpts,
    compute: &dyn ComputeTimeModel,
) -> Result<Workload> {
    let mut layers = Vec::with_capacity(summary.layers.len());
    for layer in &summary.layers {
        let (fwd_ns, ig_ns, wg_ns) = compute.layer_times(layer);
        let plan = comm_for_layer(layer, opts);
        layers.push(LayerSpec {
            name: layer.name.clone(),
            reserved: -1,
            fwd: Phase { compute_ns: fwd_ns, comm: plan.fwd.0, comm_bytes: plan.fwd.1 },
            input_grad: Phase { compute_ns: ig_ns, comm: plan.ig.0, comm_bytes: plan.ig.1 },
            weight_grad: Phase { compute_ns: wg_ns, comm: plan.wg.0, comm_bytes: plan.wg.1 },
            update_ns: compute.update_time(layer),
        });
    }
    Ok(Workload { parallelism: opts.parallelism, layers })
}

/// One-call convenience: ONNX bytes → workload text.
pub fn translate_bytes(
    bytes: &[u8],
    opts: TranslateOpts,
    compute: &dyn ComputeTimeModel,
) -> Result<(ModelSummary, Workload)> {
    let summary = extract_from_bytes(bytes, opts.batch)?;
    let workload = to_workload(&summary, opts, compute)?;
    Ok((summary, workload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::encode_model;
    use crate::zoo::{self, WeightFill, ZooOpts};
    use crate::workload::CommType;

    #[test]
    fn resnet50_data_parallel_workload() {
        let m = zoo::get("resnet50", ZooOpts { weights: WeightFill::Empty }).unwrap();
        let bytes = encode_model(&m);
        let opts = TranslateOpts { parallelism: Parallelism::Data, ..Default::default() };
        let (summary, w) = translate_bytes(&bytes, opts, &ConstantCompute(1000)).unwrap();
        // 54 compute layers, like the ASTRA-sim reference workload.
        assert_eq!(summary.layers.len(), 54);
        assert_eq!(w.layers.len(), 54);
        // DATA: only weight-grad communicates, with ALLREDUCE of the weight
        // bytes — first layer is the 7x7 stem: 37632 bytes (Table 3).
        let l0 = &w.layers[0];
        assert_eq!(l0.name, "resnet-conv0");
        assert_eq!(l0.fwd.comm, CommType::None);
        assert_eq!(l0.input_grad.comm, CommType::None);
        assert_eq!(l0.weight_grad.comm, CommType::AllReduce);
        assert_eq!(l0.weight_grad.comm_bytes, 37632);
        // Emits valid text that reparses.
        let text = w.emit();
        assert_eq!(crate::workload::Workload::parse(&text).unwrap(), w);
    }

    #[test]
    fn model_parallel_uses_activation_allgather() {
        let m = zoo::get("mlp", ZooOpts { weights: WeightFill::Empty }).unwrap();
        let bytes = encode_model(&m);
        let opts = TranslateOpts {
            parallelism: Parallelism::Model,
            batch: 8,
            ..Default::default()
        };
        let (summary, w) = translate_bytes(&bytes, opts, &ConstantCompute(10)).unwrap();
        let l0 = &w.layers[0];
        assert_eq!(l0.fwd.comm, CommType::AllGather);
        // mlp-dense0 output: [8, 4096] f32 = 131072 bytes.
        assert_eq!(l0.fwd.comm_bytes, 8 * 4096 * 4);
        assert_eq!(l0.weight_grad.comm, CommType::None);
        assert_eq!(summary.layers[0].out_act_bytes, 8 * 4096 * 4);
    }

    #[test]
    fn hybrid_splits_allreduce_across_groups() {
        let m = zoo::get("mlp", ZooOpts { weights: WeightFill::Empty }).unwrap();
        let bytes = encode_model(&m);
        let opts = TranslateOpts {
            parallelism: Parallelism::HybridDataModel,
            npus: 16,
            mp_group: 4,
            batch: 8, zero: crate::translator::memory::ZeroStage::None };
        let (_, w) = translate_bytes(&bytes, opts, &ConstantCompute(10)).unwrap();
        let l0 = &w.layers[0];
        // fwd allgather within MP group; wg allreduce of 1/mp_group of the
        // weights across DP groups.
        assert_eq!(l0.fwd.comm, CommType::AllGather);
        assert_eq!(l0.weight_grad.comm, CommType::AllReduce);
        assert_eq!(l0.weight_grad.comm_bytes, (784 * 4096 * 4) / 4);
    }

    #[test]
    fn roofline_times_scale_with_macs() {
        let m = zoo::get("vgg16", ZooOpts { weights: WeightFill::Empty }).unwrap();
        let bytes = encode_model(&m);
        let (summary, w) = translate_bytes(
            &bytes,
            TranslateOpts::default(),
            &RooflineCompute::default(),
        )
        .unwrap();
        // dense0 (102M params) must take longer than conv0 (1.7k params
        // but big activations) in wg; and all times nonzero.
        assert!(w.layers.iter().all(|l| l.fwd.compute_ns > 0));
        let conv0 = &w.layers[0];
        let dense_idx = summary.layers.iter().position(|l| l.name == "vgg16-dense0").unwrap();
        assert!(w.layers[dense_idx].update_ns > conv0.update_ns);
    }
}
