//! Per-NPU memory modeling: the *feasibility* side of the parallelism
//! design space.
//!
//! The paper's motivation (§2.1): "some layers are too huge to fit into
//! the rare GPU memory, and we need to split them into several partitions
//! to train (model parallelism)". Iteration-time comparisons are
//! meaningless without the memory constraint — data parallelism "wins"
//! every race it cannot actually run. This module computes the classic
//! training memory footprint per NPU and flags infeasible strategies:
//!
//! * weights + gradients (1 copy each of the parameter bytes),
//! * optimizer state (Adam: 2 extra copies; SGD+momentum: 1; SGD: 0),
//! * activations (sum of layer outputs for the backward pass, divided
//!   across microbatches for pipeline schedules).

use super::extract::ModelSummary;
use super::TranslateOpts;
use crate::workload::Parallelism;

/// Optimizer choice (determines state copies).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Optimizer {
    /// Plain SGD: no extra state.
    Sgd,
    /// SGD + momentum: one extra copy.
    Momentum,
    /// Adam/AdamW: two extra copies (m, v).
    Adam,
}

impl Optimizer {
    /// Extra parameter-sized state copies.
    pub fn state_copies(self) -> u64 {
        match self {
            Optimizer::Sgd => 0,
            Optimizer::Momentum => 1,
            Optimizer::Adam => 2,
        }
    }
}

/// ZeRO-style optimizer/gradient/parameter sharding level (applies to the
/// data-parallel axis, mirroring DeepSpeed stages).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ZeroStage {
    /// No sharding: full replication.
    None,
    /// Stage 1: optimizer state sharded across DP ranks.
    OptimizerState,
    /// Stage 2: + gradients sharded.
    Gradients,
    /// Stage 3: + parameters sharded.
    Parameters,
}

/// Memory-model options.
#[derive(Debug, Clone, Copy)]
pub struct MemoryOpts {
    /// Optimizer kind.
    pub optimizer: Optimizer,
    /// ZeRO sharding stage on the DP axis.
    pub zero: ZeroStage,
    /// Activation recomputation (checkpointing): keep only per-layer
    /// boundary activations, recompute interiors in backward.
    pub recompute: bool,
    /// Pipeline microbatches (activations divide by this under PIPELINE).
    pub microbatches: usize,
    /// Pipeline keeps all `microbatches` stage activations live (GPipe)
    /// or only the in-flight window of ≤ stages (1F1B).
    pub one_f_one_b: bool,
    /// HBM capacity per NPU in bytes, for feasibility checks.
    pub hbm_bytes: u64,
}

impl Default for MemoryOpts {
    fn default() -> Self {
        MemoryOpts {
            optimizer: Optimizer::Adam,
            zero: ZeroStage::None,
            recompute: false,
            microbatches: 8,
            one_f_one_b: false,
            hbm_bytes: 32 << 30, // 32 GiB accelerator
        }
    }
}

/// Per-NPU memory breakdown in bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoryReport {
    /// Parameter bytes resident per NPU.
    pub weights: u64,
    /// Gradient bytes per NPU.
    pub gradients: u64,
    /// Optimizer state bytes per NPU.
    pub optimizer: u64,
    /// Peak activation bytes per NPU.
    pub activations: u64,
}

impl MemoryReport {
    /// Total bytes.
    pub fn total(&self) -> u64 {
        self.weights + self.gradients + self.optimizer + self.activations
    }

    /// True if the footprint fits the given HBM capacity.
    pub fn fits(&self, hbm_bytes: u64) -> bool {
        self.total() <= hbm_bytes
    }
}

/// Compute the per-NPU memory footprint of training `summary` under the
/// given parallelism options.
pub fn memory_per_npu(
    summary: &ModelSummary,
    opts: TranslateOpts,
    mem: MemoryOpts,
) -> MemoryReport {
    let p = summary.total_bytes; // all parameters, all dtypes
    let acts_full: u64 = summary.layers.iter().map(|l| l.out_act_bytes).sum();
    let acts = if mem.recompute {
        // Keep only the per-layer inputs at block boundaries; model as the
        // largest single activation plus sqrt-N boundary copies.
        let max_act = summary.layers.iter().map(|l| l.out_act_bytes).max().unwrap_or(0);
        let n = summary.layers.len().max(1) as u64;
        max_act + acts_full / (n as f64).sqrt().max(1.0) as u64
    } else {
        acts_full
    };

    let npus = opts.npus.max(1) as u64;
    let g = opts.mp_group.clamp(1, opts.npus.max(1)) as u64;
    let dp_ranks = match opts.parallelism {
        Parallelism::Data => npus,
        Parallelism::Model | Parallelism::Pipeline => 1,
        Parallelism::HybridDataModel | Parallelism::HybridModelData => (npus / g).max(1),
    };

    // Parameter residency per NPU by strategy.
    let (weights, activations) = match opts.parallelism {
        Parallelism::Data => (p, acts),
        // Weights sharded N ways; every NPU still materializes the full
        // gathered activations.
        Parallelism::Model => (p / npus, acts),
        Parallelism::HybridDataModel | Parallelism::HybridModelData => (p / g, acts),
        // Contiguous stage split: 1/stages of weights. GPipe keeps all M
        // microbatches' stage activations live before the flush; 1F1B
        // (PipeDream-flush) caps the in-flight window at the stage depth —
        // the schedules' bubbles are identical, the memory is not.
        Parallelism::Pipeline => {
            let stages = g.max(1);
            let m = mem.microbatches.max(1) as u64;
            let window = if mem.one_f_one_b { stages.min(m) } else { m };
            (p / stages, acts / stages * window / m)
        }
    };

    // ZeRO shards along the DP axis.
    let (zw, zg, zo) = match mem.zero {
        ZeroStage::None => (1, 1, 1),
        ZeroStage::OptimizerState => (1, 1, dp_ranks),
        ZeroStage::Gradients => (1, dp_ranks, dp_ranks),
        ZeroStage::Parameters => (dp_ranks, dp_ranks, dp_ranks),
    };

    MemoryReport {
        weights: weights / zw,
        gradients: weights / zg,
        optimizer: weights * mem.optimizer.state_copies() / zo,
        activations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::translator::extract;
    use crate::zoo::{self, WeightFill, ZooOpts};

    fn summary(name: &str, batch: i64) -> ModelSummary {
        let m = zoo::get(name, ZooOpts { weights: WeightFill::Empty }).unwrap();
        extract(&m, batch).unwrap()
    }

    fn opts(p: Parallelism) -> TranslateOpts {
        TranslateOpts { parallelism: p, npus: 16, mp_group: 4, batch: 32, zero: crate::translator::memory::ZeroStage::None }
    }

    #[test]
    fn dp_replicates_mp_shards() {
        let s = summary("vgg16", 32);
        let mem = MemoryOpts::default();
        let dp = memory_per_npu(&s, opts(Parallelism::Data), mem);
        let mp = memory_per_npu(&s, opts(Parallelism::Model), mem);
        assert_eq!(dp.weights, s.total_bytes);
        assert_eq!(mp.weights, s.total_bytes / 16);
        assert!(mp.total() < dp.total());
    }

    #[test]
    fn adam_quadruples_parameter_footprint() {
        let s = summary("mlp", 8);
        let sgd = memory_per_npu(
            &s,
            opts(Parallelism::Data),
            MemoryOpts { optimizer: Optimizer::Sgd, ..Default::default() },
        );
        let adam = memory_per_npu(
            &s,
            opts(Parallelism::Data),
            MemoryOpts { optimizer: Optimizer::Adam, ..Default::default() },
        );
        // weights+grads (2P) vs weights+grads+2 state copies (4P).
        assert_eq!(adam.total() - adam.activations, 2 * (sgd.total() - sgd.activations));
    }

    #[test]
    fn zero_stages_monotonically_shrink() {
        let s = summary("gpt2-small", 8);
        let mut prev = u64::MAX;
        for z in [
            ZeroStage::None,
            ZeroStage::OptimizerState,
            ZeroStage::Gradients,
            ZeroStage::Parameters,
        ] {
            let r = memory_per_npu(
                &s,
                opts(Parallelism::Data),
                MemoryOpts { zero: z, ..Default::default() },
            );
            assert!(r.total() <= prev, "{z:?} grew the footprint");
            prev = r.total();
        }
    }

    #[test]
    fn recompute_cuts_activations() {
        let s = summary("vgg16", 64);
        let full = memory_per_npu(&s, opts(Parallelism::Data), MemoryOpts::default());
        let ckpt = memory_per_npu(
            &s,
            opts(Parallelism::Data),
            MemoryOpts { recompute: true, ..Default::default() },
        );
        assert!(ckpt.activations < full.activations / 2);
        assert_eq!(ckpt.weights, full.weights);
    }

    #[test]
    fn feasibility_motivates_model_parallelism() {
        // The paper's motivating case: a model whose DP footprint exceeds
        // HBM while MP fits. GPT-2-small with Adam at batch 8, seq 1024:
        // activations alone are huge; give the NPU 16 GiB.
        let s = summary("gpt2-small", 8);
        let mem = MemoryOpts { hbm_bytes: 16 << 30, ..Default::default() };
        let dp = memory_per_npu(&s, opts(Parallelism::Data), mem);
        let mp = memory_per_npu(&s, opts(Parallelism::Model), mem);
        assert!(mp.weights < dp.weights);
        assert!(mp.total() < dp.total());
    }

    #[test]
    fn one_f_one_b_caps_pipeline_activation_memory() {
        let s = summary("gpt2-small", 8);
        let o = opts(Parallelism::Pipeline);
        let gpipe = memory_per_npu(
            &s,
            o,
            MemoryOpts { microbatches: 32, ..Default::default() },
        );
        let ofob = memory_per_npu(
            &s,
            o,
            MemoryOpts { microbatches: 32, one_f_one_b: true, ..Default::default() },
        );
        // 4 stages, 32 microbatches: window 4/32 = 1/8 the activations.
        assert_eq!(ofob.activations, gpipe.activations / 8);
        assert_eq!(ofob.weights, gpipe.weights);
    }

    #[test]
    fn pipeline_divides_weights_by_stages() {
        let s = summary("vgg16", 32);
        let r = memory_per_npu(&s, opts(Parallelism::Pipeline), MemoryOpts::default());
        // mp_group doubles as the stage count in TranslateOpts.
        assert_eq!(r.weights, s.total_bytes / 4);
    }
}
