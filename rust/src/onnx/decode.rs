//! ONNX parsing: protobuf bytes → structs.
//!
//! Two modes:
//! * [`parse_model`] — full decode including tensor payloads (`raw_data`).
//! * [`parse_model_meta`] — metadata-only: tensor payloads are *skipped*
//!   (zero copies of the weight bytes), recording only their length. This
//!   is the translator's hot path: layer extraction needs dims + dtype +
//!   name, never the weights themselves, which is why ModTrans stays well
//!   under the paper's 1-second budget even on 0.5 GiB VGG files.
//!
//! Unknown fields are skipped (forward compatibility with newer
//! exporters), malformed input yields `Err`, never a panic.

use super::model::*;
use super::DataType;
use crate::error::{Error, Result};
use crate::proto::{Reader, WireType};

/// Decode options.
#[derive(Debug, Clone, Copy)]
pub struct DecodeOpts {
    /// Copy tensor payloads into [`Tensor::raw_data`]. When false, only
    /// [`Tensor::payload_len`] is recorded.
    pub load_payloads: bool,
    /// Payloads at or below this many bytes are copied even when
    /// `load_payloads` is false. Shape inference needs small constant
    /// tensors (e.g. `Reshape` shape inputs) but never the weights.
    pub small_payload_threshold: u64,
}

/// Full decode (payloads included).
pub fn parse_model(bytes: &[u8]) -> Result<Model> {
    parse_with(bytes, DecodeOpts { load_payloads: true, small_payload_threshold: 0 })
}

/// Metadata-only decode (weight payloads skipped, tiny constants kept) —
/// the translation fast path.
pub fn parse_model_meta(bytes: &[u8]) -> Result<Model> {
    parse_with(bytes, DecodeOpts { load_payloads: false, small_payload_threshold: 256 })
}

/// Decode with explicit options.
pub fn parse_with(bytes: &[u8], opts: DecodeOpts) -> Result<Model> {
    let mut m = Model::default();
    let mut r = Reader::new(bytes);
    while !r.is_empty() {
        let (f, wt) = r.tag()?;
        match f {
            1 => m.ir_version = expect_varint(&mut r, wt, "ir_version")? as i64,
            2 => m.producer_name = expect_str(&mut r, wt, "producer_name")?,
            3 => m.producer_version = expect_str(&mut r, wt, "producer_version")?,
            4 => m.domain = expect_str(&mut r, wt, "domain")?,
            5 => m.model_version = expect_varint(&mut r, wt, "model_version")? as i64,
            6 => m.doc_string = expect_str(&mut r, wt, "doc_string")?,
            7 => m.graph = parse_graph(expect_bytes(&mut r, wt, "graph")?, opts)?,
            8 => m.opset_import.push(parse_opset(expect_bytes(&mut r, wt, "opset")?)?),
            _ => r.skip(wt)?,
        }
    }
    Ok(m)
}

fn expect_bytes<'a>(r: &mut Reader<'a>, wt: WireType, what: &str) -> Result<&'a [u8]> {
    if wt != WireType::Len {
        return Err(Error::ProtoDecode(format!("{what}: expected LEN wire type")));
    }
    r.bytes()
}

fn expect_str(r: &mut Reader<'_>, wt: WireType, what: &str) -> Result<String> {
    if wt != WireType::Len {
        return Err(Error::ProtoDecode(format!("{what}: expected LEN wire type")));
    }
    Ok(r.str()?.to_string())
}

fn expect_varint(r: &mut Reader<'_>, wt: WireType, what: &str) -> Result<u64> {
    if wt != WireType::Varint {
        return Err(Error::ProtoDecode(format!("{what}: expected VARINT wire type")));
    }
    r.raw_varint()
}

fn parse_opset(bytes: &[u8]) -> Result<OperatorSetId> {
    let mut os = OperatorSetId::default();
    let mut r = Reader::new(bytes);
    while !r.is_empty() {
        let (f, wt) = r.tag()?;
        match f {
            1 => os.domain = expect_str(&mut r, wt, "opset.domain")?,
            2 => os.version = expect_varint(&mut r, wt, "opset.version")? as i64,
            _ => r.skip(wt)?,
        }
    }
    Ok(os)
}

fn parse_graph(bytes: &[u8], opts: DecodeOpts) -> Result<Graph> {
    let mut g = Graph::default();
    let mut r = Reader::new(bytes);
    while !r.is_empty() {
        let (f, wt) = r.tag()?;
        match f {
            1 => g.nodes.push(parse_node(expect_bytes(&mut r, wt, "node")?)?),
            2 => g.name = expect_str(&mut r, wt, "graph.name")?,
            5 => g
                .initializers
                .push(parse_tensor(expect_bytes(&mut r, wt, "initializer")?, opts)?),
            10 => g.doc_string = expect_str(&mut r, wt, "graph.doc_string")?,
            11 => g.inputs.push(parse_value_info(expect_bytes(&mut r, wt, "input")?)?),
            12 => g.outputs.push(parse_value_info(expect_bytes(&mut r, wt, "output")?)?),
            13 => g
                .value_infos
                .push(parse_value_info(expect_bytes(&mut r, wt, "value_info")?)?),
            _ => r.skip(wt)?,
        }
    }
    Ok(g)
}

fn parse_node(bytes: &[u8]) -> Result<Node> {
    let mut n = Node::default();
    let mut r = Reader::new(bytes);
    while !r.is_empty() {
        let (f, wt) = r.tag()?;
        match f {
            1 => n.inputs.push(expect_str(&mut r, wt, "node.input")?),
            2 => n.outputs.push(expect_str(&mut r, wt, "node.output")?),
            3 => n.name = expect_str(&mut r, wt, "node.name")?,
            4 => n.op_type = expect_str(&mut r, wt, "node.op_type")?,
            5 => n.attributes.push(parse_attribute(expect_bytes(&mut r, wt, "attr")?)?),
            7 => n.domain = expect_str(&mut r, wt, "node.domain")?,
            _ => r.skip(wt)?,
        }
    }
    Ok(n)
}

fn parse_attribute(bytes: &[u8]) -> Result<Attribute> {
    let mut name = String::new();
    let mut value: Option<AttributeValue> = None;
    let mut floats: Vec<f32> = Vec::new();
    let mut ints: Vec<i64> = Vec::new();
    let mut strings: Vec<String> = Vec::new();
    let mut declared_type: Option<u64> = None;
    let mut r = Reader::new(bytes);
    while !r.is_empty() {
        let (f, wt) = r.tag()?;
        match f {
            1 => name = expect_str(&mut r, wt, "attr.name")?,
            2 => {
                if wt != WireType::I32 {
                    return Err(Error::ProtoDecode("attr.f: expected I32".into()));
                }
                value = Some(AttributeValue::Float(r.float()?));
            }
            3 => value = Some(AttributeValue::Int(expect_varint(&mut r, wt, "attr.i")? as i64)),
            4 => value = Some(AttributeValue::String(
                String::from_utf8_lossy(expect_bytes(&mut r, wt, "attr.s")?).into_owned(),
            )),
            7 => match wt {
                // Packed floats.
                WireType::Len => {
                    let body = r.bytes()?;
                    if body.len() % 4 != 0 {
                        return Err(Error::ProtoDecode("attr.floats: bad packed length".into()));
                    }
                    for c in body.chunks_exact(4) {
                        floats.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                    }
                }
                WireType::I32 => floats.push(r.float()?),
                _ => return Err(Error::ProtoDecode("attr.floats: bad wire type".into())),
            },
            8 => match wt {
                WireType::Len => ints.extend(Reader::new(r.bytes()?).drain_varints()?),
                WireType::Varint => ints.push(r.raw_varint()? as i64),
                _ => return Err(Error::ProtoDecode("attr.ints: bad wire type".into())),
            },
            9 => strings.push(
                String::from_utf8_lossy(expect_bytes(&mut r, wt, "attr.strings")?).into_owned(),
            ),
            20 => declared_type = Some(expect_varint(&mut r, wt, "attr.type")?),
            _ => r.skip(wt)?,
        }
    }
    // Choose the value arm: prefer the declared type; repeated arms override
    // scalar arms when present.
    let value = match declared_type {
        Some(6) => AttributeValue::Floats(floats),
        Some(7) => AttributeValue::Ints(ints),
        Some(8) => AttributeValue::Strings(strings),
        _ if !ints.is_empty() => AttributeValue::Ints(ints),
        _ if !floats.is_empty() => AttributeValue::Floats(floats),
        _ if !strings.is_empty() => AttributeValue::Strings(strings),
        _ => value.unwrap_or(AttributeValue::Int(0)),
    };
    Ok(Attribute { name, value })
}

fn parse_tensor(bytes: &[u8], opts: DecodeOpts) -> Result<Tensor> {
    let mut t = Tensor::default();
    let mut r = Reader::new(bytes);
    while !r.is_empty() {
        let (f, wt) = r.tag()?;
        match f {
            1 => match wt {
                WireType::Len => t.dims.extend(Reader::new(r.bytes()?).drain_varints()?),
                WireType::Varint => t.dims.push(r.raw_varint()? as i64),
                _ => return Err(Error::ProtoDecode("tensor.dims: bad wire type".into())),
            },
            2 => {
                t.data_type =
                    DataType::from_i32(expect_varint(&mut r, wt, "tensor.data_type")? as i32)?
            }
            8 => t.name = expect_str(&mut r, wt, "tensor.name")?,
            9 => {
                if wt != WireType::Len {
                    return Err(Error::ProtoDecode("tensor.raw_data: expected LEN".into()));
                }
                let body = r.bytes()?;
                t.payload_len = body.len() as u64;
                if opts.load_payloads || t.payload_len <= opts.small_payload_threshold {
                    t.raw_data = body.to_vec();
                }
            }
            // float_data(4) / int32_data(5) / int64_data(7) / double_data(10):
            // count toward payload length; materialized only on request.
            4 | 5 | 7 | 10 | 11 => {
                if wt == WireType::Len {
                    let body = r.bytes()?;
                    t.payload_len += body.len() as u64;
                    if opts.load_payloads {
                        t.raw_data.extend_from_slice(body);
                    }
                } else {
                    r.skip(wt)?;
                }
            }
            _ => r.skip(wt)?,
        }
    }
    Ok(t)
}

fn parse_value_info(bytes: &[u8]) -> Result<ValueInfo> {
    let mut vi = ValueInfo::default();
    let mut r = Reader::new(bytes);
    while !r.is_empty() {
        let (f, wt) = r.tag()?;
        match f {
            1 => vi.name = expect_str(&mut r, wt, "value_info.name")?,
            2 => vi.ty = parse_type(expect_bytes(&mut r, wt, "value_info.type")?)?,
            _ => r.skip(wt)?,
        }
    }
    Ok(vi)
}

fn parse_type(bytes: &[u8]) -> Result<Option<TensorType>> {
    let mut r = Reader::new(bytes);
    while !r.is_empty() {
        let (f, wt) = r.tag()?;
        match f {
            // TypeProto.tensor_type
            1 => {
                let body = expect_bytes(&mut r, wt, "type.tensor_type")?;
                return Ok(Some(parse_tensor_type(body)?));
            }
            _ => r.skip(wt)?,
        }
    }
    Ok(None)
}

fn parse_tensor_type(bytes: &[u8]) -> Result<TensorType> {
    let mut tt = TensorType::default();
    let mut r = Reader::new(bytes);
    while !r.is_empty() {
        let (f, wt) = r.tag()?;
        match f {
            1 => {
                tt.elem_type =
                    DataType::from_i32(expect_varint(&mut r, wt, "tensor_type.elem")? as i32)?
            }
            2 => {
                let body = expect_bytes(&mut r, wt, "tensor_type.shape")?;
                tt.shape = parse_shape(body)?;
            }
            _ => r.skip(wt)?,
        }
    }
    Ok(tt)
}

fn parse_shape(bytes: &[u8]) -> Result<Vec<Dim>> {
    let mut dims = Vec::new();
    let mut r = Reader::new(bytes);
    while !r.is_empty() {
        let (f, wt) = r.tag()?;
        match f {
            1 => {
                let body = expect_bytes(&mut r, wt, "shape.dim")?;
                let mut dr = Reader::new(body);
                let mut dim = Dim::Value(0);
                while !dr.is_empty() {
                    let (df, dwt) = dr.tag()?;
                    match df {
                        1 => dim = Dim::Value(expect_varint(&mut dr, dwt, "dim_value")? as i64),
                        2 => dim = Dim::Param(expect_str(&mut dr, dwt, "dim_param")?),
                        _ => dr.skip(dwt)?,
                    }
                }
                dims.push(dim);
            }
            _ => r.skip(wt)?,
        }
    }
    Ok(dims)
}

/// Extension: drain all varints from a packed-field reader.
trait DrainVarints {
    fn drain_varints(self) -> Result<Vec<i64>>;
}
impl<'a> DrainVarints for Reader<'a> {
    fn drain_varints(mut self) -> Result<Vec<i64>> {
        let mut out = Vec::new();
        while !self.is_empty() {
            out.push(self.raw_varint()? as i64);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::encode_model;

    fn sample_model() -> Model {
        let mut g = Graph {
            name: "g".into(),
            ..Default::default()
        };
        g.initializers.push(Tensor {
            dims: vec![64, 3, 3, 3],
            data_type: DataType::Float,
            name: "conv0.weight".into(),
            raw_data: vec![0u8; 64 * 27 * 4],
            payload_len: 0,
        });
        g.nodes.push(Node {
            inputs: vec!["x".into(), "conv0.weight".into(), String::new()],
            outputs: vec!["y".into()],
            name: "conv0".into(),
            op_type: "Conv".into(),
            domain: String::new(),
            attributes: vec![
                Attribute { name: "strides".into(), value: AttributeValue::Ints(vec![2, 2]) },
                Attribute { name: "group".into(), value: AttributeValue::Int(1) },
                Attribute { name: "auto_pad".into(), value: AttributeValue::String("NOTSET".into()) },
                Attribute { name: "alpha".into(), value: AttributeValue::Float(0.5) },
            ],
        });
        g.inputs.push(ValueInfo {
            name: "x".into(),
            ty: Some(TensorType {
                elem_type: DataType::Float,
                shape: vec![Dim::Param("N".into()), Dim::Value(3), Dim::Value(224), Dim::Value(224)],
            }),
        });
        g.outputs.push(ValueInfo { name: "y".into(), ty: None });
        Model::wrap(g)
    }

    #[test]
    fn encode_parse_roundtrip_full() {
        let m = sample_model();
        let bytes = encode_model(&m);
        let m2 = parse_model(&bytes).unwrap();
        assert_eq!(m2.ir_version, 8);
        assert_eq!(m2.producer_name, "modtrans-zoo");
        assert_eq!(m2.opset_import.len(), 1);
        assert_eq!(m2.opset_import[0].version, 17);
        assert_eq!(m2.graph.name, "g");
        assert_eq!(m2.graph.initializers.len(), 1);
        let t = &m2.graph.initializers[0];
        assert_eq!(t.dims, vec![64, 3, 3, 3]);
        assert_eq!(t.data_type, DataType::Float);
        assert_eq!(t.name, "conv0.weight");
        assert_eq!(t.raw_data.len(), 6912);
        assert_eq!(t.payload_len, 6912);
        let n = &m2.graph.nodes[0];
        assert_eq!(n.op_type, "Conv");
        assert_eq!(n.inputs, vec!["x", "conv0.weight", ""]);
        assert_eq!(n.attr_ints("strides"), &[2, 2]);
        assert_eq!(n.attr_i("group", 0), 1);
        assert_eq!(
            n.attr("auto_pad"),
            Some(&AttributeValue::String("NOTSET".into()))
        );
        assert_eq!(n.attr_f("alpha", 0.0), 0.5);
        // Typed input survived.
        let x = &m2.graph.inputs[0];
        let ty = x.ty.as_ref().unwrap();
        assert_eq!(ty.elem_type, DataType::Float);
        assert_eq!(ty.shape[0], Dim::Param("N".into()));
        assert_eq!(ty.shape[3], Dim::Value(224));
    }

    #[test]
    fn meta_decode_skips_payload_but_keeps_len() {
        let m = sample_model();
        let bytes = encode_model(&m);
        let m2 = parse_model_meta(&bytes).unwrap();
        let t = &m2.graph.initializers[0];
        assert!(t.raw_data.is_empty());
        assert_eq!(t.payload_len, 6912);
        assert_eq!(t.num_elements(), 1728);
        assert_eq!(t.size_bytes(), 6912);
    }

    #[test]
    fn truncation_fuzz_no_panics() {
        let m = sample_model();
        let bytes = encode_model(&m);
        // Every truncation point must produce Err or Ok, never panic.
        let step = (bytes.len() / 257).max(1);
        for cut in (0..bytes.len()).step_by(step) {
            let _ = parse_model(&bytes[..cut]);
        }
    }

    #[test]
    fn bitflip_fuzz_no_panics() {
        use crate::util::rng::Rng;
        let m = sample_model();
        let bytes = encode_model(&m);
        let mut rng = Rng::new(0x5eed);
        for _ in 0..300 {
            let mut corrupted = bytes.clone();
            let flips = rng.range(1, 8);
            for _ in 0..flips {
                let i = rng.below(corrupted.len() as u64) as usize;
                corrupted[i] ^= 1 << rng.below(8) as u8;
            }
            let _ = parse_model(&corrupted); // must not panic
        }
    }

    #[test]
    fn unknown_fields_are_skipped() {
        // Append an unknown field (99, varint) at model level.
        let m = sample_model();
        let mut bytes = encode_model(&m);
        let mut w = crate::proto::Writer::new();
        w.uint64(99, 12345);
        bytes.extend_from_slice(&w.into_bytes());
        let m2 = parse_model(&bytes).unwrap();
        assert_eq!(m2.graph.initializers.len(), 1);
    }
}
