//! ONNX message structs (the subset of onnx.proto3 that real CNN/MLP/
//! transformer exporters emit).

use super::DataType;

/// `ModelProto` — the top-level serialized unit of an `.onnx` file.
#[derive(Debug, Clone, Default)]
pub struct Model {
    /// ONNX IR version (field 1). Exporters currently emit 7–10.
    pub ir_version: i64,
    /// Tool that produced the model (field 2), e.g. `"modtrans-zoo"`.
    pub producer_name: String,
    /// Producer version string (field 3).
    pub producer_version: String,
    /// Model namespace/domain (field 4).
    pub domain: String,
    /// Model version number (field 5).
    pub model_version: i64,
    /// Free-text documentation (field 6).
    pub doc_string: String,
    /// The computation graph (field 7).
    pub graph: Graph,
    /// Operator-set requirements (field 8).
    pub opset_import: Vec<OperatorSetId>,
}

/// `OperatorSetIdProto` (domain + version).
#[derive(Debug, Clone, Default)]
pub struct OperatorSetId {
    /// Operator domain; empty string is the default ai.onnx domain.
    pub domain: String,
    /// Opset version (field 2).
    pub version: i64,
}

/// `GraphProto` — nodes, initializers, and the graph signature.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    /// Topologically sorted compute nodes (field 1).
    pub nodes: Vec<Node>,
    /// Graph name (field 2).
    pub name: String,
    /// Constant parameters: the model's weights (field 5). ModTrans's
    /// layer extraction walks exactly this list (paper §3.3).
    pub initializers: Vec<Tensor>,
    /// Graph inputs (field 11). Real exporters list only data inputs here;
    /// initializers provide the rest.
    pub inputs: Vec<ValueInfo>,
    /// Graph outputs (field 12).
    pub outputs: Vec<ValueInfo>,
    /// Optional per-edge type annotations (field 13).
    pub value_infos: Vec<ValueInfo>,
    /// Documentation (field 10).
    pub doc_string: String,
}

/// `NodeProto` — one operator application.
#[derive(Debug, Clone, Default)]
pub struct Node {
    /// Input edge names (field 1); positional per operator spec.
    pub inputs: Vec<String>,
    /// Output edge names (field 2).
    pub outputs: Vec<String>,
    /// Optional node name (field 3).
    pub name: String,
    /// Operator type, e.g. `"Conv"`, `"Gemm"` (field 4).
    pub op_type: String,
    /// Operator domain (field 7); empty = ai.onnx.
    pub domain: String,
    /// Operator attributes (field 5).
    pub attributes: Vec<Attribute>,
}

impl Node {
    /// Fetch an attribute by name.
    pub fn attr(&self, name: &str) -> Option<&AttributeValue> {
        self.attributes.iter().find(|a| a.name == name).map(|a| &a.value)
    }

    /// Integer attribute with default.
    pub fn attr_i(&self, name: &str, default: i64) -> i64 {
        match self.attr(name) {
            Some(AttributeValue::Int(v)) => *v,
            _ => default,
        }
    }

    /// Integer-list attribute (empty slice if missing).
    pub fn attr_ints(&self, name: &str) -> &[i64] {
        match self.attr(name) {
            Some(AttributeValue::Ints(v)) => v,
            _ => &[],
        }
    }

    /// Float attribute with default.
    pub fn attr_f(&self, name: &str, default: f32) -> f32 {
        match self.attr(name) {
            Some(AttributeValue::Float(v)) => *v,
            _ => default,
        }
    }
}

/// `AttributeProto` — a named, typed constant hung off a node.
#[derive(Debug, Clone)]
pub struct Attribute {
    /// Attribute name (field 1), e.g. `"kernel_shape"`.
    pub name: String,
    /// The typed payload (discriminated by field 20 on the wire).
    pub value: AttributeValue,
}

/// The value arm of an `AttributeProto`.
#[derive(Debug, Clone, PartialEq)]
pub enum AttributeValue {
    /// FLOAT (type 1, field 2)
    Float(f32),
    /// INT (type 2, field 3)
    Int(i64),
    /// STRING (type 3, field 4)
    String(String),
    /// FLOATS (type 6, field 7)
    Floats(Vec<f32>),
    /// INTS (type 7, field 8)
    Ints(Vec<i64>),
    /// STRINGS (type 8, field 9)
    Strings(Vec<String>),
}

/// `TensorProto` — a constant tensor (initializer).
#[derive(Debug, Clone, Default)]
pub struct Tensor {
    /// Shape (field 1).
    pub dims: Vec<i64>,
    /// Element type (field 2, `DataType` enum).
    pub data_type: DataType,
    /// Tensor name (field 8) — the paper's "Layer Name" column comes from
    /// these names.
    pub name: String,
    /// Raw little-endian payload (field 9). Empty in metadata-only decode.
    pub raw_data: Vec<u8>,
    /// Length of the payload on the wire, recorded even when
    /// `raw_data` is skipped (metadata-only decode).
    pub payload_len: u64,
}

impl Default for DataType {
    fn default() -> Self {
        DataType::Undefined
    }
}

impl Tensor {
    /// Number of elements = ∏ dims (the paper's "Variables" column).
    pub fn num_elements(&self) -> u64 {
        self.dims.iter().map(|&d| d.max(0) as u64).product()
    }

    /// Bytes = elements × sizeof(dtype) (the paper's "Model Size" column).
    pub fn size_bytes(&self) -> u64 {
        self.num_elements() * self.data_type.size_bytes()
    }
}

/// `ValueInfoProto` — name + tensor type for a graph edge.
#[derive(Debug, Clone, Default)]
pub struct ValueInfo {
    /// Edge name (field 1).
    pub name: String,
    /// Tensor type; `None` when the exporter omitted it.
    pub ty: Option<TensorType>,
}

/// `TypeProto.Tensor` — element type + symbolic/concrete shape.
#[derive(Debug, Clone, Default)]
pub struct TensorType {
    /// Element dtype.
    pub elem_type: DataType,
    /// Dimensions (each concrete or a named symbol like `"batch"`).
    pub shape: Vec<Dim>,
}

/// One dimension of a `TensorShapeProto`.
#[derive(Debug, Clone, PartialEq)]
pub enum Dim {
    /// Concrete extent (`dim_value`, field 1).
    Value(i64),
    /// Symbolic name (`dim_param`, field 2), e.g. `"N"`.
    Param(String),
}

impl Dim {
    /// Concrete value if present.
    pub fn value(&self) -> Option<i64> {
        match self {
            Dim::Value(v) => Some(*v),
            Dim::Param(_) => None,
        }
    }
}

impl Model {
    /// Construct a model wrapper with the conventional metadata the zoo
    /// uses (IR version 8, ai.onnx opset 17).
    pub fn wrap(graph: Graph) -> Model {
        Model {
            ir_version: 8,
            producer_name: "modtrans-zoo".into(),
            producer_version: env!("CARGO_PKG_VERSION").into(),
            domain: String::new(),
            model_version: 1,
            doc_string: String::new(),
            graph,
            opset_import: vec![OperatorSetId { domain: String::new(), version: 17 }],
        }
    }

    /// Total parameter count across all initializers.
    pub fn num_parameters(&self) -> u64 {
        self.graph.initializers.iter().map(Tensor::num_elements).sum()
    }

    /// Total parameter bytes across all initializers.
    pub fn parameter_bytes(&self) -> u64 {
        self.graph.initializers.iter().map(Tensor::size_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_size_math() {
        let t = Tensor {
            dims: vec![64, 3, 3, 3],
            data_type: DataType::Float,
            name: "w".into(),
            raw_data: vec![],
            payload_len: 0,
        };
        assert_eq!(t.num_elements(), 1728); // vgg16-conv0 row of Table 1
        assert_eq!(t.size_bytes(), 6912);
    }

    #[test]
    fn node_attr_helpers() {
        let n = Node {
            op_type: "Conv".into(),
            attributes: vec![
                Attribute { name: "strides".into(), value: AttributeValue::Ints(vec![2, 2]) },
                Attribute { name: "group".into(), value: AttributeValue::Int(1) },
            ],
            ..Default::default()
        };
        assert_eq!(n.attr_ints("strides"), &[2, 2]);
        assert_eq!(n.attr_i("group", 7), 1);
        assert_eq!(n.attr_i("missing", 7), 7);
    }

    #[test]
    fn model_param_totals() {
        let mut g = Graph::default();
        g.initializers.push(Tensor {
            dims: vec![10, 10],
            data_type: DataType::Float,
            ..Default::default()
        });
        g.initializers.push(Tensor {
            dims: vec![10],
            data_type: DataType::Float,
            ..Default::default()
        });
        let m = Model::wrap(g);
        assert_eq!(m.num_parameters(), 110);
        assert_eq!(m.parameter_bytes(), 440);
    }
}
