//! ONNX intermediate representation.
//!
//! A faithful subset of `onnx.proto3` — `ModelProto`, `GraphProto`,
//! `NodeProto`, `TensorProto`, `ValueInfoProto`, `AttributeProto`,
//! `TensorShapeProto`, `OperatorSetIdProto` — with **wire-compatible**
//! serialization and parsing built on [`crate::proto`]. Field numbers and
//! enum values match the upstream schema, so bytes produced here load in
//! netron/onnxruntime and real `.onnx` files parse here.
//!
//! The paper's pipeline (§3.3) is: deserialize protobuf → walk graph →
//! extract layer info. The decoder ([`parse_model_meta`]) supports a
//! metadata-only mode that skips
//! tensor payload copies, which is what makes ModTrans's overhead
//! "negligible" even for half-gigabyte VGG models (Fig. 6). In the
//! staged translator this module backs the ONNX byte frontend
//! ([`crate::ir::frontend::from_onnx_bytes`]); in-memory [`Model`]s (for
//! example from the zoo builders) enter the IR without touching the wire
//! format at all.

mod decode;
mod encode;
mod graph;
mod model;
mod shape;

pub use decode::{parse_model, parse_model_meta, DecodeOpts};
pub use encode::encode_model;
pub use graph::GraphIndex;
pub use model::{
    Attribute, AttributeValue, Dim, Graph, Model, Node, OperatorSetId, Tensor, TensorType,
    ValueInfo,
};
pub use shape::{infer_shapes, ShapeMap};

use crate::error::{Error, Result};

/// ONNX `TensorProto.DataType` (values match onnx.proto3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DataType {
    /// Unknown/unset.
    Undefined = 0,
    /// IEEE float32 — `FLOAT` in the paper's tables.
    Float = 1,
    /// u8
    Uint8 = 2,
    /// i8
    Int8 = 3,
    /// u16
    Uint16 = 4,
    /// i16
    Int16 = 5,
    /// i32
    Int32 = 6,
    /// i64
    Int64 = 7,
    /// string
    String = 8,
    /// bool
    Bool = 9,
    /// IEEE half
    Float16 = 10,
    /// IEEE float64
    Double = 11,
    /// u32
    Uint32 = 12,
    /// u64
    Uint64 = 13,
    /// complex64
    Complex64 = 14,
    /// complex128
    Complex128 = 15,
    /// bfloat16
    Bfloat16 = 16,
}

impl DataType {
    /// Decode from the wire enum value.
    pub fn from_i32(v: i32) -> Result<DataType> {
        use DataType::*;
        Ok(match v {
            0 => Undefined,
            1 => Float,
            2 => Uint8,
            3 => Int8,
            4 => Uint16,
            5 => Int16,
            6 => Int32,
            7 => Int64,
            8 => String,
            9 => Bool,
            10 => Float16,
            11 => Double,
            12 => Uint32,
            13 => Uint64,
            14 => Complex64,
            15 => Complex128,
            16 => Bfloat16,
            _ => return Err(Error::onnx(format!("unknown TensorProto.DataType {v}"))),
        })
    }

    /// Size of one element in bytes (the multiplier in the paper's
    /// `Model Size = Variables × sizeof(dtype)` column).
    pub fn size_bytes(self) -> u64 {
        use DataType::*;
        match self {
            Undefined | String => 0,
            Uint8 | Int8 | Bool => 1,
            Uint16 | Int16 | Float16 | Bfloat16 => 2,
            Float | Int32 | Uint32 => 4,
            Double | Int64 | Uint64 | Complex64 => 8,
            Complex128 => 16,
        }
    }

    /// Canonical upper-case name, as printed in the paper's tables
    /// (`FLOAT`, `FLOAT16`, ...).
    pub fn name(self) -> &'static str {
        use DataType::*;
        match self {
            Undefined => "UNDEFINED",
            Float => "FLOAT",
            Uint8 => "UINT8",
            Int8 => "INT8",
            Uint16 => "UINT16",
            Int16 => "INT16",
            Int32 => "INT32",
            Int64 => "INT64",
            String => "STRING",
            Bool => "BOOL",
            Float16 => "FLOAT16",
            Double => "DOUBLE",
            Uint32 => "UINT32",
            Uint64 => "UINT64",
            Complex64 => "COMPLEX64",
            Complex128 => "COMPLEX128",
            Bfloat16 => "BFLOAT16",
        }
    }
}

impl std::fmt::Display for DataType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_roundtrip() {
        for v in 0..=16 {
            let d = DataType::from_i32(v).unwrap();
            assert_eq!(d as i32, v);
        }
        assert!(DataType::from_i32(17).is_err());
        assert!(DataType::from_i32(-1).is_err());
    }

    #[test]
    fn dtype_sizes_match_paper() {
        // Paper tables: FLOAT weights, Model Size = 4 × Variables.
        assert_eq!(DataType::Float.size_bytes(), 4);
        assert_eq!(DataType::Float16.size_bytes(), 2);
        assert_eq!(DataType::Double.size_bytes(), 8);
        assert_eq!(DataType::Float.name(), "FLOAT");
    }
}
