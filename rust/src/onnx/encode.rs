//! ONNX serialization: structs → protobuf bytes (field numbers from
//! onnx.proto3, so output is loadable by any ONNX tool).

use super::model::*;
use crate::proto::Writer;

/// Serialize a [`Model`] to `.onnx` bytes.
pub fn encode_model(m: &Model) -> Vec<u8> {
    // Preallocate: payload bytes dominate (VGG16 ≈ 0.5 GiB), so reserve the
    // sum of initializer payloads plus slack for structure.
    let payload: usize =
        m.graph.initializers.iter().map(|t| t.raw_data.len() + 64).sum::<usize>();
    let mut w = Writer::with_capacity(payload + 4096);
    w.int64(1, m.ir_version);
    w.string(2, &m.producer_name);
    w.string(3, &m.producer_version);
    w.string(4, &m.domain);
    w.int64(5, m.model_version);
    w.string(6, &m.doc_string);
    w.message(7, &encode_graph(&m.graph));
    for os in &m.opset_import {
        let mut ow = Writer::new();
        ow.string(1, &os.domain);
        ow.int64(2, os.version);
        w.message(8, &ow);
    }
    w.into_bytes()
}

fn encode_graph(g: &Graph) -> Writer {
    let payload: usize = g.initializers.iter().map(|t| t.raw_data.len() + 64).sum::<usize>();
    let mut w = Writer::with_capacity(payload + 2048);
    for n in &g.nodes {
        w.message(1, &encode_node(n));
    }
    w.string(2, &g.name);
    for t in &g.initializers {
        w.message(5, &encode_tensor(t));
    }
    w.string(10, &g.doc_string);
    for vi in &g.inputs {
        w.message(11, &encode_value_info(vi));
    }
    for vi in &g.outputs {
        w.message(12, &encode_value_info(vi));
    }
    for vi in &g.value_infos {
        w.message(13, &encode_value_info(vi));
    }
    w
}

fn encode_node(n: &Node) -> Writer {
    let mut w = Writer::new();
    for i in &n.inputs {
        // Written even when empty: ONNX uses empty input names for omitted
        // optional inputs, and position is significant.
        w.string_always(1, i);
    }
    for o in &n.outputs {
        w.string_always(2, o);
    }
    w.string(3, &n.name);
    w.string(4, &n.op_type);
    for a in &n.attributes {
        w.message(5, &encode_attribute(a));
    }
    w.string(7, &n.domain);
    w
}

fn encode_attribute(a: &Attribute) -> Writer {
    let mut w = Writer::new();
    w.string(1, &a.name);
    match &a.value {
        AttributeValue::Float(f) => {
            w.float(2, *f);
            w.uint64(20, 1);
        }
        AttributeValue::Int(i) => {
            w.int64(3, *i);
            w.uint64(20, 2);
        }
        AttributeValue::String(s) => {
            w.bytes(4, s.as_bytes());
            w.uint64(20, 3);
        }
        AttributeValue::Floats(fs) => {
            w.packed_float(7, fs);
            w.uint64(20, 6);
        }
        AttributeValue::Ints(is) => {
            w.packed_int64(8, is);
            w.uint64(20, 7);
        }
        AttributeValue::Strings(ss) => {
            for s in ss {
                w.bytes(9, s.as_bytes());
            }
            w.uint64(20, 8);
        }
    }
    w
}

fn encode_tensor(t: &Tensor) -> Writer {
    let mut w = Writer::with_capacity(t.raw_data.len() + 64);
    w.packed_int64(1, &t.dims);
    w.uint64(2, t.data_type as i32 as u64);
    w.string(8, &t.name);
    w.bytes(9, &t.raw_data);
    w
}

fn encode_value_info(vi: &ValueInfo) -> Writer {
    let mut w = Writer::new();
    w.string(1, &vi.name);
    if let Some(ty) = &vi.ty {
        // TypeProto { tensor_type = field 1 }
        let mut tt = Writer::new();
        tt.uint64(1, ty.elem_type as i32 as u64);
        // TensorShapeProto at field 2.
        let mut shape = Writer::new();
        for d in &ty.shape {
            let mut dw = Writer::new();
            match d {
                Dim::Value(v) => dw.int64(1, *v),
                Dim::Param(p) => dw.string(2, p),
            }
            shape.message(1, &dw);
        }
        tt.message(2, &shape);
        let mut tp = Writer::new();
        tp.message(1, &tt);
        w.message(2, &tp);
    }
    w
}
