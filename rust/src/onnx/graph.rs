//! Graph utilities: name indices, producer/consumer maps, topological
//! ordering. Used by shape inference and the translator's layer walk.

use super::model::{Graph, Node, Tensor};
use crate::error::{Error, Result};
use std::collections::{HashMap, HashSet};

/// Index over a [`Graph`]: initializers by name, node producing each edge,
/// and a verified topological order of node indices.
pub struct GraphIndex<'g> {
    /// The indexed graph.
    pub graph: &'g Graph,
    init_by_name: HashMap<&'g str, &'g Tensor>,
    producer: HashMap<&'g str, usize>,
    topo: Vec<usize>,
}

impl<'g> GraphIndex<'g> {
    /// Build the index; fails if the graph contains a cycle or an output
    /// name is produced twice.
    pub fn new(graph: &'g Graph) -> Result<GraphIndex<'g>> {
        let mut init_by_name = HashMap::with_capacity(graph.initializers.len());
        for t in &graph.initializers {
            init_by_name.insert(t.name.as_str(), t);
        }
        let mut producer: HashMap<&str, usize> = HashMap::new();
        for (i, n) in graph.nodes.iter().enumerate() {
            for o in &n.outputs {
                if o.is_empty() {
                    continue;
                }
                if producer.insert(o.as_str(), i).is_some() {
                    return Err(Error::onnx(format!("edge '{o}' produced by two nodes")));
                }
            }
        }
        let topo = topo_sort(graph, &producer)?;
        Ok(GraphIndex { graph, init_by_name, producer, topo })
    }

    /// Look up an initializer by edge name.
    pub fn initializer(&self, name: &str) -> Option<&'g Tensor> {
        self.init_by_name.get(name).copied()
    }

    /// True if the edge is a constant parameter (weight).
    pub fn is_initializer(&self, name: &str) -> bool {
        self.init_by_name.contains_key(name)
    }

    /// The node index producing an edge, if any.
    pub fn producer_of(&self, name: &str) -> Option<usize> {
        self.producer.get(name).copied()
    }

    /// Node indices in topological order.
    pub fn topo_order(&self) -> &[usize] {
        &self.topo
    }

    /// Nodes in topological order.
    pub fn topo_nodes(&self) -> impl Iterator<Item = &'g Node> + '_ {
        self.topo.iter().map(move |&i| &self.graph.nodes[i])
    }
}

/// Kahn's algorithm over node-index dependencies; detects cycles.
///
/// Ready nodes are popped in *node-index order* (min-heap), so when the
/// original node list is already a valid execution order — true for every
/// real exporter and for the zoo builders — the topological order equals
/// the authored order. This keeps layer extraction aligned with the
/// paper's table ordering (e.g. a ResNet projection shortcut appearing
/// after the block's main-path convs).
fn topo_sort(graph: &Graph, producer: &HashMap<&str, usize>) -> Result<Vec<usize>> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let n = graph.nodes.len();
    let mut indeg = vec![0usize; n];
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, node) in graph.nodes.iter().enumerate() {
        let mut seen: HashSet<usize> = HashSet::new();
        for input in &node.inputs {
            if let Some(&p) = producer.get(input.as_str()) {
                if p != i && seen.insert(p) {
                    succs[p].push(i);
                    indeg[i] += 1;
                }
            }
        }
    }
    let mut q: BinaryHeap<Reverse<usize>> =
        (0..n).filter(|&i| indeg[i] == 0).map(Reverse).collect();
    let mut out = Vec::with_capacity(n);
    while let Some(Reverse(i)) = q.pop() {
        out.push(i);
        for &s in &succs[i] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                q.push(Reverse(s));
            }
        }
    }
    if out.len() != n {
        return Err(Error::onnx("graph contains a cycle"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::model::*;
    use crate::onnx::DataType;

    fn node(name: &str, op: &str, ins: &[&str], outs: &[&str]) -> Node {
        Node {
            inputs: ins.iter().map(|s| s.to_string()).collect(),
            outputs: outs.iter().map(|s| s.to_string()).collect(),
            name: name.into(),
            op_type: op.into(),
            ..Default::default()
        }
    }

    #[test]
    fn topo_order_respects_deps() {
        let mut g = Graph::default();
        // Intentionally out of order: b consumes a's output but appears first.
        g.nodes.push(node("b", "Relu", &["t0"], &["t1"]));
        g.nodes.push(node("a", "Conv", &["x", "w"], &["t0"]));
        g.initializers.push(Tensor {
            name: "w".into(),
            data_type: DataType::Float,
            dims: vec![1],
            ..Default::default()
        });
        let idx = GraphIndex::new(&g).unwrap();
        assert_eq!(idx.topo_order(), &[1, 0]);
        assert!(idx.is_initializer("w"));
        assert_eq!(idx.producer_of("t1"), Some(0));
    }

    #[test]
    fn cycle_detected() {
        let mut g = Graph::default();
        g.nodes.push(node("a", "Add", &["t1", "x"], &["t0"]));
        g.nodes.push(node("b", "Relu", &["t0"], &["t1"]));
        assert!(GraphIndex::new(&g).is_err());
    }

    #[test]
    fn duplicate_producer_rejected() {
        let mut g = Graph::default();
        g.nodes.push(node("a", "Relu", &["x"], &["t"]));
        g.nodes.push(node("b", "Relu", &["x"], &["t"]));
        assert!(GraphIndex::new(&g).is_err());
    }
}
