//! Shape inference over ONNX graphs.
//!
//! Propagates concrete tensor shapes from the graph inputs through every
//! node, yielding per-edge shapes. The translator uses these to size
//! activations (model-parallel communication volumes) and the compute
//! model uses them to count MACs per layer.
//!
//! Covers the operator set emitted by the model zoo and by common CNN /
//! MLP / transformer exporters. Symbolic dims (e.g. `"N"`) are bound to a
//! caller-supplied batch size.

use super::graph::GraphIndex;
use super::model::{Dim, Graph, Node};
use super::DataType;
use crate::error::{Error, Result};
use std::collections::HashMap;

/// Edge name → (dtype, concrete shape).
pub type ShapeMap = HashMap<String, (DataType, Vec<i64>)>;

/// Infer shapes for every edge of `graph`, binding symbolic input dims to
/// `batch`.
pub fn infer_shapes(graph: &Graph, batch: i64) -> Result<ShapeMap> {
    let idx = GraphIndex::new(graph)?;
    let mut shapes: ShapeMap = HashMap::new();

    for t in &graph.initializers {
        shapes.insert(t.name.clone(), (t.data_type, t.dims.clone()));
    }
    for vi in &graph.inputs {
        if shapes.contains_key(&vi.name) {
            continue; // initializer also listed as input (IR < 4 style)
        }
        let ty = vi
            .ty
            .as_ref()
            .ok_or_else(|| Error::onnx(format!("input '{}' has no type", vi.name)))?;
        let dims: Vec<i64> = ty
            .shape
            .iter()
            .map(|d| match d {
                Dim::Value(v) => *v,
                Dim::Param(_) => batch,
            })
            .collect();
        shapes.insert(vi.name.clone(), (ty.elem_type, dims));
    }

    for node in idx.topo_nodes() {
        infer_node(node, &idx, &mut shapes)?;
    }
    Ok(shapes)
}

fn get<'a>(
    shapes: &'a ShapeMap,
    node: &Node,
    input: usize,
) -> Result<&'a (DataType, Vec<i64>)> {
    let name = node.inputs.get(input).ok_or_else(|| {
        Error::onnx(format!("{}: missing input #{input}", node.op_type))
    })?;
    shapes.get(name).ok_or_else(|| {
        Error::onnx(format!(
            "{}: input '{name}' has no inferred shape (unsupported producer?)",
            node.op_type
        ))
    })
}

fn set(shapes: &mut ShapeMap, node: &Node, output: usize, dtype: DataType, dims: Vec<i64>) {
    if let Some(name) = node.outputs.get(output) {
        if !name.is_empty() {
            shapes.insert(name.clone(), (dtype, dims));
        }
    }
}

/// Spatial output extent for a conv/pool window.
fn window_out(input: i64, kernel: i64, pad_total: i64, stride: i64, ceil: bool) -> i64 {
    let num = input + pad_total - kernel;
    if ceil {
        (num + stride - 1) / stride + 1
    } else {
        num / stride + 1
    }
}

/// Resolve conv/pool padding: explicit `pads` or `auto_pad` SAME variants.
fn resolve_pads(node: &Node, spatial: usize, kernel: &[i64], strides: &[i64], input: &[i64]) -> Vec<i64> {
    // Returns per-axis total padding (begin+end).
    let pads = node.attr_ints("pads");
    if !pads.is_empty() {
        return (0..spatial).map(|i| pads[i] + pads[i + spatial]).collect();
    }
    match node.attr("auto_pad") {
        Some(super::model::AttributeValue::String(s)) if s.starts_with("SAME") => (0..spatial)
            .map(|i| {
                let out = (input[i] + strides[i] - 1) / strides[i];
                ((out - 1) * strides[i] + kernel[i] - input[i]).max(0)
            })
            .collect(),
        _ => vec![0; spatial],
    }
}

fn infer_node(node: &Node, idx: &GraphIndex<'_>, shapes: &mut ShapeMap) -> Result<()> {
    let op = node.op_type.as_str();
    match op {
        // ---- shape-preserving elementwise / normalization ----
        "Relu" | "LeakyRelu" | "Sigmoid" | "Tanh" | "Erf" | "Gelu" | "Softmax"
        | "LogSoftmax" | "Identity" | "Dropout" | "LRN" | "Clip" | "Sqrt" | "Neg"
        | "Cast" | "BatchNormalization" | "LayerNormalization" | "Pow" => {
            let (dt, dims) = get(shapes, node, 0)?.clone();
            set(shapes, node, 0, dt, dims);
        }

        // ---- broadcast binary ----
        "Add" | "Sub" | "Mul" | "Div" => {
            let (dt, a) = get(shapes, node, 0)?.clone();
            let (_, b) = get(shapes, node, 1)?.clone();
            set(shapes, node, 0, dt, broadcast(&a, &b)?);
        }

        // ---- convolution ----
        "Conv" => {
            let (dt, x) = get(shapes, node, 0)?.clone();
            let (_, w) = get(shapes, node, 1)?.clone();
            if x.len() < 3 || w.len() != x.len() {
                return Err(Error::onnx(format!("Conv: bad ranks {x:?} {w:?}")));
            }
            let spatial = x.len() - 2;
            let kernel: Vec<i64> = if node.attr_ints("kernel_shape").is_empty() {
                w[2..].to_vec()
            } else {
                node.attr_ints("kernel_shape").to_vec()
            };
            let strides = normalize(node.attr_ints("strides"), spatial, 1);
            let dil = normalize(node.attr_ints("dilations"), spatial, 1);
            let eff_kernel: Vec<i64> =
                (0..spatial).map(|i| (kernel[i] - 1) * dil[i] + 1).collect();
            let pads = resolve_pads(node, spatial, &eff_kernel, &strides, &x[2..]);
            let mut out = vec![x[0], w[0]];
            for i in 0..spatial {
                out.push(window_out(x[2 + i], eff_kernel[i], pads[i], strides[i], false));
            }
            set(shapes, node, 0, dt, out);
        }

        // ---- pooling ----
        "MaxPool" | "AveragePool" => {
            let (dt, x) = get(shapes, node, 0)?.clone();
            let spatial = x.len() - 2;
            let kernel = node.attr_ints("kernel_shape").to_vec();
            if kernel.len() != spatial {
                return Err(Error::onnx(format!("{op}: kernel_shape rank mismatch")));
            }
            let strides = normalize(node.attr_ints("strides"), spatial, 1);
            let pads = resolve_pads(node, spatial, &kernel, &strides, &x[2..]);
            let ceil = node.attr_i("ceil_mode", 0) == 1;
            let mut out = vec![x[0], x[1]];
            for i in 0..spatial {
                out.push(window_out(x[2 + i], kernel[i], pads[i], strides[i], ceil));
            }
            set(shapes, node, 0, dt, out);
        }
        "GlobalAveragePool" | "GlobalMaxPool" => {
            let (dt, x) = get(shapes, node, 0)?.clone();
            let mut out = vec![x[0], x[1]];
            out.extend(std::iter::repeat(1).take(x.len() - 2));
            set(shapes, node, 0, dt, out);
        }

        // ---- linear algebra ----
        "Gemm" => {
            let (dt, a) = get(shapes, node, 0)?.clone();
            let (_, b) = get(shapes, node, 1)?.clone();
            let ta = node.attr_i("transA", 0) == 1;
            let tb = node.attr_i("transB", 0) == 1;
            let m = if ta { a[1] } else { a[0] };
            let n = if tb { b[0] } else { b[1] };
            set(shapes, node, 0, dt, vec![m, n]);
        }
        "MatMul" => {
            let (dt, a) = get(shapes, node, 0)?.clone();
            let (_, b) = get(shapes, node, 1)?.clone();
            set(shapes, node, 0, dt, matmul_shape(&a, &b)?);
        }

        // ---- reshaping ----
        "Flatten" => {
            let (dt, x) = get(shapes, node, 0)?.clone();
            let axis = node.attr_i("axis", 1).clamp(0, x.len() as i64) as usize;
            let d0: i64 = x[..axis].iter().product();
            let d1: i64 = x[axis..].iter().product();
            set(shapes, node, 0, dt, vec![d0, d1]);
        }
        "Reshape" => {
            let (dt, x) = get(shapes, node, 0)?.clone();
            let shape_name = node
                .inputs
                .get(1)
                .ok_or_else(|| Error::onnx("Reshape: missing shape input"))?;
            let t = idx
                .initializer(shape_name)
                .ok_or_else(|| Error::onnx("Reshape: shape input must be an initializer"))?;
            let target = int64_payload(&t.raw_data, t.num_elements() as usize)?;
            set(shapes, node, 0, dt, resolve_reshape(&x, &target)?);
        }
        "Transpose" => {
            let (dt, x) = get(shapes, node, 0)?.clone();
            let perm = node.attr_ints("perm");
            let out: Vec<i64> = if perm.is_empty() {
                x.iter().rev().copied().collect()
            } else {
                perm.iter().map(|&p| x[p as usize]).collect()
            };
            set(shapes, node, 0, dt, out);
        }
        "Concat" => {
            let axis = node.attr_i("axis", 0);
            let (dt, mut out) = get(shapes, node, 0)?.clone();
            let ax = if axis < 0 { (out.len() as i64 + axis) as usize } else { axis as usize };
            for i in 1..node.inputs.len() {
                let (_, s) = get(shapes, node, i)?;
                out[ax] += s[ax];
            }
            set(shapes, node, 0, dt, out);
        }
        "Gather" => {
            // axis-0 embedding lookup: out = indices_shape ++ data_shape[1:]
            let (dt, data) = get(shapes, node, 0)?.clone();
            let (_, indices) = get(shapes, node, 1)?.clone();
            let axis = node.attr_i("axis", 0);
            if axis != 0 {
                return Err(Error::onnx("Gather: only axis=0 supported"));
            }
            let mut out = indices;
            out.extend_from_slice(&data[1..]);
            set(shapes, node, 0, dt, out);
        }
        "ReduceMean" => {
            let (dt, x) = get(shapes, node, 0)?.clone();
            let axes = node.attr_ints("axes");
            let keep = node.attr_i("keepdims", 1) == 1;
            let mut out = Vec::new();
            for (i, &d) in x.iter().enumerate() {
                let reduced = axes
                    .iter()
                    .any(|&a| (if a < 0 { x.len() as i64 + a } else { a }) as usize == i);
                if reduced {
                    if keep {
                        out.push(1);
                    }
                } else {
                    out.push(d);
                }
            }
            set(shapes, node, 0, dt, out);
        }

        other => {
            return Err(Error::onnx(format!(
                "shape inference: unsupported op '{other}' (node '{}')",
                node.name
            )))
        }
    }
    Ok(())
}

fn normalize(attr: &[i64], n: usize, default: i64) -> Vec<i64> {
    if attr.is_empty() {
        vec![default; n]
    } else {
        attr.to_vec()
    }
}

/// Numpy-style broadcasting of two shapes.
fn broadcast(a: &[i64], b: &[i64]) -> Result<Vec<i64>> {
    let n = a.len().max(b.len());
    let mut out = vec![0i64; n];
    for i in 0..n {
        let da = if i < n - a.len() { 1 } else { a[i - (n - a.len())] };
        let db = if i < n - b.len() { 1 } else { b[i - (n - b.len())] };
        out[i] = match (da, db) {
            (x, y) if x == y => x,
            (1, y) => y,
            (x, 1) => x,
            _ => return Err(Error::onnx(format!("cannot broadcast {a:?} with {b:?}"))),
        };
    }
    Ok(out)
}

/// Batched matmul shape per numpy semantics.
fn matmul_shape(a: &[i64], b: &[i64]) -> Result<Vec<i64>> {
    if a.is_empty() || b.is_empty() {
        return Err(Error::onnx("MatMul: scalar input"));
    }
    if a.len() == 1 || b.len() == 1 {
        return Err(Error::onnx("MatMul: vector operands unsupported in zoo models"));
    }
    let (m, ka) = (a[a.len() - 2], a[a.len() - 1]);
    let (kb, n) = (b[b.len() - 2], b[b.len() - 1]);
    if ka != kb {
        return Err(Error::onnx(format!("MatMul: inner dims {ka} != {kb}")));
    }
    let batch = broadcast(&a[..a.len() - 2], &b[..b.len() - 2])?;
    let mut out = batch;
    out.push(m);
    out.push(n);
    Ok(out)
}

/// Read little-endian int64 payload (Reshape shape constants).
fn int64_payload(raw: &[u8], n: usize) -> Result<Vec<i64>> {
    if raw.len() < n * 8 {
        return Err(Error::onnx("int64 initializer payload missing (metadata-only decode dropped it?)"));
    }
    Ok(raw[..n * 8]
        .chunks_exact(8)
        .map(|c| i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

/// Resolve a Reshape target with 0 (copy) and -1 (infer) conventions.
fn resolve_reshape(input: &[i64], target: &[i64]) -> Result<Vec<i64>> {
    let total: i64 = input.iter().product();
    let mut out: Vec<i64> = Vec::with_capacity(target.len());
    let mut infer_at: Option<usize> = None;
    for (i, &t) in target.iter().enumerate() {
        match t {
            0 => out.push(*input.get(i).ok_or_else(|| Error::onnx("Reshape: 0-dim out of range"))?),
            -1 => {
                if infer_at.is_some() {
                    return Err(Error::onnx("Reshape: multiple -1 dims"));
                }
                infer_at = Some(i);
                out.push(1);
            }
            t if t > 0 => out.push(t),
            _ => return Err(Error::onnx("Reshape: negative dim")),
        }
    }
    if let Some(i) = infer_at {
        let known: i64 = out.iter().product();
        if known == 0 || total % known != 0 {
            return Err(Error::onnx(format!("Reshape: cannot infer dim ({input:?} -> {target:?})")));
        }
        out[i] = total / known;
    }
    let out_total: i64 = out.iter().product();
    if out_total != total {
        return Err(Error::onnx(format!("Reshape: element count mismatch ({input:?} -> {out:?})")));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::model::*;

    fn conv_node(name: &str, x: &str, w: &str, y: &str, stride: i64, pad: i64) -> Node {
        Node {
            inputs: vec![x.into(), w.into()],
            outputs: vec![y.into()],
            name: name.into(),
            op_type: "Conv".into(),
            attributes: vec![
                Attribute { name: "strides".into(), value: AttributeValue::Ints(vec![stride, stride]) },
                Attribute { name: "pads".into(), value: AttributeValue::Ints(vec![pad, pad, pad, pad]) },
            ],
            ..Default::default()
        }
    }

    fn weight(name: &str, dims: Vec<i64>) -> Tensor {
        Tensor { dims, data_type: DataType::Float, name: name.into(), ..Default::default() }
    }

    fn input(name: &str, dims: Vec<i64>) -> ValueInfo {
        ValueInfo {
            name: name.into(),
            ty: Some(TensorType {
                elem_type: DataType::Float,
                shape: dims.into_iter().map(Dim::Value).collect(),
            }),
        }
    }

    #[test]
    fn conv_7x7_s2_resnet_stem() {
        // ResNet-50 stem: 3x224x224, 64 filters of 7x7, stride 2, pad 3 → 64x112x112.
        let mut g = Graph::default();
        g.inputs.push(input("x", vec![1, 3, 224, 224]));
        g.initializers.push(weight("w", vec![64, 3, 7, 7]));
        g.nodes.push(conv_node("stem", "x", "w", "y", 2, 3));
        let s = infer_shapes(&g, 1).unwrap();
        assert_eq!(s["y"].1, vec![1, 64, 112, 112]);
    }

    #[test]
    fn maxpool_ceil_and_floor() {
        let mut g = Graph::default();
        g.inputs.push(input("x", vec![1, 64, 112, 112]));
        g.nodes.push(Node {
            inputs: vec!["x".into()],
            outputs: vec!["y".into()],
            op_type: "MaxPool".into(),
            attributes: vec![
                Attribute { name: "kernel_shape".into(), value: AttributeValue::Ints(vec![3, 3]) },
                Attribute { name: "strides".into(), value: AttributeValue::Ints(vec![2, 2]) },
                Attribute { name: "pads".into(), value: AttributeValue::Ints(vec![1, 1, 1, 1]) },
            ],
            ..Default::default()
        });
        let s = infer_shapes(&g, 1).unwrap();
        assert_eq!(s["y"].1, vec![1, 64, 56, 56]);
    }

    #[test]
    fn gemm_and_flatten() {
        let mut g = Graph::default();
        g.inputs.push(input("x", vec![2, 512, 7, 7]));
        g.initializers.push(weight("w", vec![4096, 25088]));
        g.nodes.push(Node {
            inputs: vec!["x".into()],
            outputs: vec!["f".into()],
            op_type: "Flatten".into(),
            ..Default::default()
        });
        g.nodes.push(Node {
            inputs: vec!["f".into(), "w".into()],
            outputs: vec!["y".into()],
            op_type: "Gemm".into(),
            attributes: vec![Attribute { name: "transB".into(), value: AttributeValue::Int(1) }],
            ..Default::default()
        });
        let s = infer_shapes(&g, 2).unwrap();
        assert_eq!(s["f"].1, vec![2, 25088]);
        assert_eq!(s["y"].1, vec![2, 4096]);
    }

    #[test]
    fn batched_matmul_broadcast() {
        assert_eq!(matmul_shape(&[8, 12, 64, 64], &[8, 12, 64, 128]).unwrap(), vec![8, 12, 64, 128]);
        assert_eq!(matmul_shape(&[5, 3, 4], &[4, 7]).unwrap(), vec![5, 3, 7]);
        assert!(matmul_shape(&[2, 3], &[4, 5]).is_err());
    }

    #[test]
    fn broadcast_rules() {
        assert_eq!(broadcast(&[1, 64, 56, 56], &[64, 1, 1]).unwrap(), vec![1, 64, 56, 56]);
        assert!(broadcast(&[2, 3], &[4, 3]).is_err());
    }

    #[test]
    fn reshape_with_infer() {
        assert_eq!(resolve_reshape(&[2, 3, 4], &[0, -1]).unwrap(), vec![2, 12]);
        assert_eq!(resolve_reshape(&[6, 4], &[2, 3, 4]).unwrap(), vec![2, 3, 4]);
        assert!(resolve_reshape(&[6, 4], &[5, -1]).is_err());
        assert!(resolve_reshape(&[6, 4], &[-1, -1]).is_err());
    }

    #[test]
    fn unsupported_op_is_error() {
        let mut g = Graph::default();
        g.inputs.push(input("x", vec![1, 3]));
        g.nodes.push(Node {
            inputs: vec!["x".into()],
            outputs: vec!["y".into()],
            op_type: "TotallyMadeUpOp".into(),
            ..Default::default()
        });
        assert!(infer_shapes(&g, 1).is_err());
    }

    #[test]
    fn symbolic_batch_binding() {
        let mut g = Graph::default();
        g.inputs.push(ValueInfo {
            name: "x".into(),
            ty: Some(TensorType {
                elem_type: DataType::Float,
                shape: vec![Dim::Param("N".into()), Dim::Value(10)],
            }),
        });
        g.nodes.push(Node {
            inputs: vec!["x".into()],
            outputs: vec!["y".into()],
            op_type: "Relu".into(),
            ..Default::default()
        });
        let s = infer_shapes(&g, 32).unwrap();
        assert_eq!(s["y"].1, vec![32, 10]);
    }
}
