//! `modtrans` binary — the L3 coordinator CLI.
//!
//! See [`modtrans::cli`] for the command grammar; `modtrans help` prints
//! usage. Python is never invoked from here: AOT artifacts are built by
//! `make artifacts` and only *loaded* at run time.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = modtrans::cli::run(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
