//! # ModTrans
//!
//! A production-grade reproduction of *"ModTrans: Translating Real-world
//! Models for Distributed Training Simulator"* (CS.DC 2026): a translator
//! from ONNX models to the workload descriptions consumed by
//! ASTRA-sim-class distributed-training simulators — plus every substrate
//! the paper depends on, built from scratch.
//!
//! # Architecture: frontends → passes → emitters
//!
//! Translation is staged around a shared typed IR ([`ir::ModelIR`]): one
//! structural record per weight-bearing layer plus independent
//! annotation slots for per-phase compute costs and per-phase collective
//! requirements.
//!
//! ```text
//!  .onnx bytes ────┐                                       ┌─► Workload (in-crate sim)
//!  onnx::Model ────┼─► ir::frontend ─► ModelIR ─► ir::emit ─► ASTRA-sim text (Fig. 3)
//!  zoo builder ────┤        │                              └─► Chakra-ET JSON graph (v2)
//!  et-json trace ──┘        ▼                                    │
//!          ▲         ir::passes: compute │ comm │ memory         │
//!          └─────────── closed loop (byte-identical) ────────────┘
//! ```
//!
//! * **Frontends** ([`ir::frontend`]) normalize every input — raw ONNX
//!   bytes (metadata-only decode), in-memory models, zoo builders
//!   *directly* (no encode/decode round-trip), and
//!   `modtrans-et-json/v2` documents
//!   ([`ir::frontend::from_et_json`], CLI `translate --from et-json`)
//!   — into the same IR. The et-json reader closes the emit→read loop:
//!   it restores a **fully annotated** IR (costs + comm plan replayed
//!   from the trace, structure from the v2 layer section) and re-emits
//!   byte-identically, so externally produced traces become simulator
//!   inputs and cached IRs survive a disk round trip unchanged.
//! * **Passes** ([`ir::passes`]) are independent: the compute pass fills
//!   cost slots from any [`translator::ComputeTimeModel`]; the comm pass
//!   plans per-phase collectives for one parallelism strategy (into the
//!   IR, or into a caller-owned buffer for the allocation-free sweep
//!   path); the memory pass reports the per-NPU training footprint.
//! * **Emitters** ([`ir::emit`]) lower an annotated IR to the in-crate
//!   [`workload::Workload`] / ASTRA-sim text description, or to a
//!   Chakra-ET-style JSON task graph (`translate --format et-json`).
//!
//! This split is what makes batched scenario execution cheap — and now
//! persistent and *triaged*. Per sweep scenario the pipeline is:
//!
//! ```text
//!  WorkloadCache IR ─► comm pass ─► sweep::bound (analytic lower bound)
//!        │                  --top K: bound > K-th best simulated?
//!        │                     ├─ yes ─► pruned (no DES, still exact)
//!        └────► emit ─────────┴─ no ──► DES simulate ─► ranked report
//! ```
//!
//! The sweep cache ([`sweep::WorkloadCache`]) has two tiers:
//!
//! 1. **In-memory**: one compute-annotated IR per typed
//!    [`sweep::CacheKey`] (model × batch × compute-model fingerprint),
//!    built once per run; each scenario re-runs only the
//!    parallelism-dependent comm pass + emit.
//! 2. **On disk** (`sweep --cache-dir DIR`): each IR is spilled as an
//!    et-json document in a key-stamped envelope; later sweeps — or
//!    sibling shards of the same grid — load instead of re-extracting,
//!    so a warm run performs **zero** translations while ranking
//!    byte-identically (CI asserts both). Corrupt or stale-fingerprint
//!    entries are invalidated and rewritten, never trusted.
//!
//! On top of the cache sits the branch-and-bound triage stage
//! ([`sweep::bound`], CLI `sweep --top K`): an admissible per-scenario
//! makespan lower bound — serial critical-path compute plus
//! ideal-bandwidth communication, read straight off the cached IR and
//! the scenario's comm plan with memoized collective latencies, no DES —
//! lets the sweep skip simulating any scenario that provably cannot
//! enter the top-K. Pruning is **exact**, not heuristic: the reported
//! top-K is byte-identical to the exhaustive ranking's first K rows
//! (CI's prune-equivalence diff pins it).
//!
//! ## The network model: N-dimension fabrics × per-dimension algorithms
//!
//! The simulator's network layer ([`sim::network`]) models a cluster as
//! an ordered hierarchy of up to [`sim::MAX_DIMS`] dimensions (scale-up
//! first), each an independent exclusive resource with its own physical
//! arrangement ([`sim::TopologyKind`]: ring, fully-connected, switch,
//! 2-D torus, rail-optimized, dragonfly), link bandwidth, per-hop
//! latency — and its own collective algorithm
//! ([`sim::CollectiveAlgo`]: ring, halving-doubling, direct exchange,
//! dimension-ordered). Topology and algorithm are orthogonal co-design
//! axes: the same 64-port switch can run its all-reduce latency-bound
//! (halving-doubling, `2·ceil(log2 N)` steps) or bandwidth-bound
//! (direct exchange), and [`sim::collective_ns`] — a total function over
//! `(comm, bytes, algo, dim)` — prices any pairing. Which pairings a
//! fabric can *realize* is a separate, typed question:
//! [`sim::CollectiveAlgo::admissible_on`] is enforced at the config
//! boundaries (spec parse, config JSON, `simulate`, the sweep's bound
//! pass) alongside the [`ir::verify`]-style checks, never inside the
//! cost model, so the hot path stays branch-light.
//!
//! The one textual form of a network is the typed [`sim::NetworkSpec`]
//! grammar — `ring:8x300g@700ns/switch:16x25g@5us+direct` — used
//! uniformly by the CLI (`--network`, `--topology`, `--topologies`),
//! config JSON (`{"spec": "..."}`), the sweep fingerprint and grid
//! digest, and report scenario labels. Bare legacy tokens (`ring`,
//! `fc`, `torus2d`, …) remain deprecated single-dimension aliases that
//! round-trip byte-identically, and every topology's pre-redesign
//! implicit algorithm is pinned as its default
//! ([`sim::CollectiveAlgo::default_for`]), so legacy scenarios keep
//! byte-identical rankings through the new API. The system layer
//! ([`sim::system`]) maps workload collectives onto the hierarchy:
//! scale-up traffic stays on dimension 0 while weight-gradient
//! all-reduces take the chunked hierarchical route (reduce-scatter on
//! dim 0 → per-dimension all-reduce across dims 1.. → all-gather on
//! dim 0), each dimension priced by its own algorithm — and the
//! analytic bound pass ([`sweep::bound`]) mirrors that routing
//! statement for statement, so `--top K` pruning stays exact on
//! co-design grids too.
//!
//! ## The orchestration layer: one command, N worker processes
//!
//! On top of the in-process worker pool sits a process-level
//! work-stealing fleet ([`sweep::fleet`], CLI `sweep fleet --procs N`):
//!
//! ```text
//!                       sweep fleet --procs N
//!                               │
//!        ┌─ cache copy-in (--cache-from: rsync'd / object-store dir)
//!        ├─ pre-warm: ONE cold translation pass → shared --cache-dir
//!        ├─ expand the grid once; order the queue longest-bound-first
//!        ├─ journal (--journal DIR): --resume replays committed leases
//!        │         through the merge guards → only uncovered scenarios
//!        │         stay queued (zero re-simulations of finished work)
//!        ├─ lease loop: idle worker steals the next scenario lease
//!        │    ┌──────────────────────────────────────────────────┐
//!        │    │ spawn: modtrans sweep --scenarios i,j,k           │
//!        │    │        (size adapts to observed per-scenario cost;│
//!        │    │        --top-cutoff carries the live K-th best)   │
//!        │    │ reap:  stream-merge the lease report, append it   │
//!        │    │        crash-atomically to the journal            │
//!        │    │ fail:  crash or --shard-timeout watchdog kill →   │
//!        │    │        re-dispatch (≤ --retries), else hard error │
//!        │    │        naming the worker + exit code + stderr tail│
//!        │    └──────────────────────────────────────────────────┘
//!        ├─ finalize: streaming merge (completeness / grid-identity /
//!        │         overlap guards) → ranking byte-identical to the
//!        │         monolithic sweep (CI: fleet-smoke)
//!        └─ cache copy-out (publish new entries back to --cache-from)
//! ```
//!
//! Every worker loads IRs from the shared cache (and reports
//! `translations == 0`); `--static-shards` swaps the stealing queue for
//! the old contiguous once-only partition (A/B-benched as
//! `fleet_skewed_static` vs `fleet_skewed_stealing` in
//! `benches/sweep_throughput.rs`). The per-worker outcome
//! ([`sweep::ShardStatus`]: attempts, leases, exit code, idle time,
//! stderr tail, translation/cache counters) is printed as a table and
//! written machine-readably via `--status-out`, so a dead worker is
//! diagnosable evidence, never just a missing report file.
//!
//! ## Module map
//!
//! * [`proto`] — protobuf wire-format codec (ONNX's serialization).
//! * [`onnx`] — an ONNX IR subset with wire-compatible serialize/parse and
//!   shape inference.
//! * [`zoo`] — model builders (ResNet, VGG, AlexNet, MLP, transformer)
//!   generating real ONNX graphs with exact parameter counts; feeds the
//!   zoo-direct IR frontend.
//! * [`translator`] — the paper's contribution: the ONNX structural
//!   frontend ([`translator::extract()`]), the pass ingredients
//!   (compute-time models, [`translator::comm_for_layer`],
//!   [`translator::memory_per_npu`]) and one-call conveniences.
//! * [`ir`] — the shared ModelIR plus its frontends, passes and emitters
//!   (see above).
//! * [`workload`] — the ASTRA-sim DNN-description file format.
//! * [`sim`] — a full discrete-event distributed-training simulator
//!   (N-dimension hierarchical network with per-dimension collective
//!   algorithms, algorithm-selected collective cost models, system
//!   scheduler, training loop — see the network-model section above).
//! * [`compute`] — SCALE-sim-style systolic-array compute-time model.
//! * [`sweep`] — the experiment-scale batch runner: expands a
//!   (model × parallelism × network × schedule) grid — the network axis
//!   takes [`sim::NetworkSpec`]s, so one grid can mix bare legacy
//!   topologies with multi-dimension per-algorithm fabrics — caches one
//!   compute-annotated IR per model (in memory, plus the persistent
//!   `--cache-dir` disk tier), fans simulations out across a
//!   `std::thread` worker pool (optionally sharded `--shard K/N` across
//!   machines, merged back with `sweep-merge`), and emits a
//!   deterministic ranked report. [`sweep::bound`] is its
//!   branch-and-bound triage pass (`--top K`): admissible analytic
//!   makespan lower bounds prune scenarios that provably cannot enter
//!   the top-K, without changing the reported ranking. [`sweep::fleet`]
//!   is the orchestration layer above it: `sweep fleet --procs N`
//!   launches N worker processes warmed from one shared cache, hands
//!   out scenario leases from a work-stealing queue
//!   ([`sweep::fleet::FleetOpts`]), journals completed leases for
//!   `--resume`, retries crashes and watchdog kills, and stream-merges
//!   in-process (see the architecture section above).
//! * `runtime` / [`calibrate`] — PJRT execution of AOT-compiled
//!   JAX/Pallas GEMM artifacts for measured per-layer compute times
//!   (behind the `pjrt` feature; see below).
//! * [`json`], [`util`], [`cli`] — config / infra substrates (no external
//!   crates).
//! * [`analysis`] — the in-crate static-analysis pass (`modtrans-lint`):
//!   a dependency-free lexer + rule engine enforcing the crate's
//!   hot-path/determinism/no-panic contracts from `analysis/rules.toml`
//!   (see *Static guarantees* below).
//!
//! The three-layer architecture keeps Python strictly at build time:
//! JAX/Pallas author + AOT-lower compute kernels to HLO text
//! (`make artifacts`); the Rust binary loads and runs them via PJRT.
//!
//! # Building & CI
//!
//! The default build is **dependency-free and fully offline**: protobuf,
//! JSON, PRNG, table rendering and the bench harness are implemented
//! in-crate, so `cargo build --release && cargo test -q` works from a
//! clean checkout with no network and no registry cache.
//!
//! ## The `pjrt` feature flag
//!
//! The PJRT execution path — the `runtime` module and
//! `calibrate::Calibration::measure` — needs the external `xla` crate
//! and real AOT artifacts (`make artifacts`). It is gated behind the
//! **off-by-default** `pjrt` cargo feature:
//!
//! ```sh
//! cargo build --release                  # default: no PJRT, no deps
//! cargo build --release --features pjrt  # requires a vendored `xla` crate
//! ```
//!
//! With the feature off, `modtrans calibrate` exits with a usage error
//! and the `measured:<cal.json>` compute model still loads previously
//! saved calibration files (loading is pure JSON).
//!
//! # Static guarantees
//!
//! The crate's two load-bearing contracts — the allocation-free hot
//! path and byte-identical rankings everywhere — are machine-checked by
//! two layers, both dependency-free:
//!
//! **1. `modtrans-lint`** ([`analysis`]; CI's gating `lint` job,
//! `make lint`) walks every `rust/src/**/*.rs` file with a token-level
//! cleaner (string/char/raw-string literals and comments blanked,
//! `#[cfg(test)]` regions excluded by default, function spans
//! brace-matched) and enforces the declarative rules in
//! `analysis/rules.toml`:
//!
//! * `no-alloc` — no `format!` / `vec!` / `to_string` / `to_owned` /
//!   `String::…` / `Vec::…` / `Box::new` / `collect::<String>` inside
//!   any function annotated `// lint: hot-path` (graph builders, the
//!   calendar queue, the collective router, dispatch and the run loop).
//! * `no-string-alloc` — whole-file string-allocation ban over the five
//!   files the retired grep guard watched (parity superset).
//! * `no-panic` — no `.unwrap()` / `.expect(` / `panic!` / `todo!` in
//!   library code (ir/, sim/, sweep/, zoo/, analysis/, json, calibrate,
//!   bench); typed [`error::Error`]s only.
//! * `index-fallible` — no direct indexing inside functions annotated
//!   `// lint: fallible-path`.
//! * `no-label-string` — per-task label `String`s stay dead (tests
//!   included).
//! * `map-iter` / `wall-clock` / `float-cmp` — determinism hazards: no
//!   hash-order containers in modules feeding ranked or serialized
//!   output, no `Instant::now`/`SystemTime` outside bench/fleet/runtime,
//!   no `partial_cmp` in ordering code (use `f64::total_cmp`).
//!
//! **Annotation grammar** (line comments; malformed markers fail the
//! lint): `// lint: hot-path` / `// lint: fallible-path` annotate the
//! next `fn`; `// lint: allow(<rule>) — <reason>` suppresses `<rule>`
//! on its own line (trailing form) or the next code line (standalone
//! form) — the reason is mandatory, so every suppression documents why
//! the site is provably fine.
//!
//! **2. Semantic verifiers** (`modtrans check`; `debug_assert!`-style
//! hooks at the frontend/emit boundaries; always-on at the disk-cache
//! load boundary): [`ir::verify`] checks a [`ir::ModelIR`]'s structural
//! invariants — slot arrays dense and in sync with the layer list,
//! annotation flags consistent with slot contents, and every per-phase
//! collective admissible for the planned parallelism — and
//! [`sim::verify_graph`] checks a built [`sim::TaskGraph`]: CSR
//! well-formedness, SoA slab sync, dense ids, in-range resources,
//! backward-only dependencies, and acyclicity (Kahn's algorithm).
//! `modtrans check` runs the whole zoo × strategy matrix through both;
//! `modtrans check FILE` verifies an et-json trace or cache envelope;
//! `modtrans check --cache-dir DIR` audits a disk cache — the same
//! verification every cache load performs before trusting an envelope.
//!
//! ## CI
//!
//! `.github/workflows/ci.yml` runs build, test, `cargo fmt --check`,
//! `cargo clippy -- -D warnings` (gating), `cargo doc --no-deps` with
//! warnings denied (gating), the gating `modtrans-lint` static-analysis
//! pass (see *Static guarantees*), a bench smoke pass
//! (`MODTRANS_BENCH_SAMPLES=2` drops every bench target to seconds) that
//! uploads `BENCH_*.json` artifacts, a **gating** perf-trajectory job
//! that diffs those artifacts against the base branch's and fails on a
//! >25% mean regression measured on ≥30-sample runs
//! (`scripts/perf_diff.py --gate --threshold 25`; 2-sample smoke
//! artifacts can never trip it, and missing/drifted series are skipped,
//! never crashed on — unit-tested in `scripts/test_perf_diff.py`), a
//! 1-thread-vs-8-thread `sweep` determinism diff (plain,
//! `--skip-infeasible`, sharded + `sweep-merge`, a warm-`--cache-dir`
//! rerun that must report 0 translations with a byte-identical ranking,
//! a prune-equivalence diff: `sweep --top 5` must reproduce the
//! exhaustive top-5 byte-identically while pruning scenarios,
//! `scripts/check_prune.py`, and an N-dimension co-design leg: a grid
//! mixing a bare legacy token with a 3-dimension per-algorithm
//! `NetworkSpec` must diff byte-identically across thread counts, and
//! `modtrans check --network rust/configs/ndim_codesign.json` must
//! admit the shipped example fabric), a `fleet-smoke` job (`sweep fleet
//! --procs 4` cold and warm must rank byte-for-byte like the monolithic
//! sweep with every worker reporting 0 translations; a journaled fleet
//! interrupted by a failpoint must `--resume` with zero re-simulations;
//! and the work-stealing scheduler must keep every worker busy on a
//! model-skewed grid — `scripts/check_fleet.py`), a `check-ci-sync`
//! job (`scripts/check_ci_sync.py`: every CI job must map to a `make ci`
//! step and vice versa), and a check that every PR touches `CHANGES.md`.
//! Reproduce the full matrix locally with `make ci` before pushing. The
//! scheduled `.github/workflows/nightly-bench.yml` additionally uploads
//! ≥30-sample `BENCH_*.json` baselines — the artifacts that actually arm
//! the perf gate (see `bench-baselines/README.md`).
//!
//! # Performance
//!
//! The scenario hot path is data-oriented from event pop to top-K
//! triage, and **allocation-free in steady state** (only the report
//! assembly at the end of a scenario allocates its
//! O(layers)/O(resources) output structures):
//!
//! * **Calendar-queue event core.** Completion events live in a
//!   monotone integer-time [`sim::CalendarQueue`] (64-slot windowed
//!   wheel, occupancy bitmask, adaptive bucket width, lazy per-bucket
//!   sort) instead of a comparison-based binary heap. The invariant it
//!   rests on: simulation time never goes backwards, so every push is
//!   `>=` the last popped time and the queue keeps a one-way cursor
//!   rather than a general priority structure. Pop order remains
//!   byte-identical to a `(finish_time, seq, task)` min-heap —
//!   randomized differential tests in `sim/queue.rs` and the goldens in
//!   `tests/determinism_regression.rs` pin it.
//! * **Batched dispatch.** The run loop drains *all* events sharing the
//!   minimum timestamp in one queue operation and processes the wave
//!   event by event, dispatching each event's dirty resources exactly
//!   once (deduplicated; within-wave order stays incremental, which
//!   LIFO backlogs and the `seq` tiebreak require — see `sim::engine`'s
//!   module docs for why coarser batching would change schedules).
//! * **SoA slabs.** The per-task fields the event loop reads —
//!   durations and resource ids — are mirrored into dense
//!   structure-of-arrays slabs ([`sim::TaskGraph::durations`] /
//!   [`sim::TaskGraph::resources`]), so dispatch indexes flat `u64`
//!   arrays instead of striding through full task records.
//! * Tasks carry a compact `Copy` [`sim::TaskTag`]
//!   (iteration × phase × layer × comm annotation) instead of a label
//!   `String`; human-readable labels are rendered only on demand (error
//!   paths, reports). The `no-alloc` and `no-label-string` lint rules
//!   (gating `lint` CI job) keep the graph builders, the calendar queue
//!   and the collective router that way.
//! * Dependency lists live in one shared pool inside [`sim::TaskGraph`]
//!   (CSR layout), not in per-task `Vec`s; the run loop's pending
//!   counts, dependents CSR, calendar queue, wave batch and spans live
//!   in a reusable [`sim::RunScratch`].
//! * [`sim::SimScratch`] bundles graph + engine + run buffers + the
//!   graph builders' temporaries. The **reuse contract**: any sequence
//!   of workloads and configs may go through one scratch via
//!   [`sim::simulate_with`], and every result is identical to a
//!   fresh-scratch run — scratch contents never leak into results
//!   (regression-tested in `tests/determinism_regression.rs`).
//! * On the sweep layer, `--top K`'s analytic bound pass fans out over
//!   the worker pool with one memo per worker — deterministic because
//!   the bound is a pure function (see [`sweep::bound`]) — so triage
//!   scales with cores just like simulation does.
//! * Workload derivation is allocation-free too: each sweep worker
//!   carries one [`sweep::ScenarioScratch`] (a `SimScratch` plus the
//!   comm-plan buffer and an emitted-workload buffer whose layer slots
//!   and name strings are reused in place), so a steady-state scenario —
//!   comm pass, emit, graph build, event loop — performs no heap
//!   allocation. (Crossing from a small model to a larger one regrows
//!   the emit buffer once per boundary; within a model group nothing
//!   allocates.) The structural extraction and compute pass run once per
//!   [`sweep::CacheKey`] inside [`sweep::WorkloadCache`] — and with
//!   `--cache-dir` not even that: repeat sweeps replace O(models)
//!   extraction with O(1) disk reads per model
//!   (`benches/sweep_throughput.rs` tracks the cold-vs-warm series).
//!
//! ## Reading `BENCH_<name>.json`
//!
//! Every bench binary writes `BENCH_<name>.json` (into
//! `$MODTRANS_BENCH_OUT`, default `.`): `{"name", "series": [{"name",
//! "n", "mean", "stddev", "p50", "min", "max", "samples": [..]}]}` —
//! all times in seconds, `samples` in measurement order. CI's
//! bench-smoke job uploads them as artifacts; diff the same series name
//! across PRs (mean/p50) to read the perf trajectory. Smoke runs use 2
//! samples — for real comparisons run the benches locally without
//! `MODTRANS_BENCH_SAMPLES`.

pub mod analysis;
pub mod calibrate;
pub mod cli;
pub mod compute;
pub mod error;
pub mod ir;
pub mod json;
pub mod onnx;
pub mod proto;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod translator;
pub mod util;
pub mod workload;
pub mod zoo;

pub use error::{Error, Result};
