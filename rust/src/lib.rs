//! # ModTrans
//!
//! A production-grade reproduction of *"ModTrans: Translating Real-world
//! Models for Distributed Training Simulator"* (CS.DC 2026): a translator
//! from ONNX models to the layer-wise workload description consumed by
//! ASTRA-sim-class distributed-training simulators — plus every substrate
//! the paper depends on, built from scratch:
//!
//! * [`proto`] — protobuf wire-format codec (ONNX's serialization).
//! * [`onnx`] — an ONNX IR subset with wire-compatible serialize/parse and
//!   shape inference.
//! * [`zoo`] — model builders (ResNet, VGG, AlexNet, MLP, transformer)
//!   generating real ONNX graphs with exact parameter counts.
//! * [`translator`] — the paper's contribution: layer extraction and
//!   ASTRA-sim workload emission.
//! * [`workload`] — the ASTRA-sim DNN-description file format.
//! * [`sim`] — a full discrete-event distributed-training simulator
//!   (network, collectives, system scheduler, training loop).
//! * [`compute`] — SCALE-sim-style systolic-array compute-time model.
//! * [`sweep`] — the experiment-scale batch runner: expands a
//!   (model × parallelism × topology × collective) grid, translates each
//!   model once into a shared cache, fans simulations out across a
//!   `std::thread` worker pool, and emits a deterministic ranked report.
//! * `runtime` / [`calibrate`] — PJRT execution of AOT-compiled
//!   JAX/Pallas GEMM artifacts for measured per-layer compute times
//!   (behind the `pjrt` feature; see below).
//! * [`json`], [`util`], [`cli`] — config / infra substrates (no external
//!   crates).
//!
//! The three-layer architecture keeps Python strictly at build time:
//! JAX/Pallas author + AOT-lower compute kernels to HLO text
//! (`make artifacts`); the Rust binary loads and runs them via PJRT.
//!
//! # Building & CI
//!
//! The default build is **dependency-free and fully offline**: protobuf,
//! JSON, PRNG, table rendering and the bench harness are implemented
//! in-crate, so `cargo build --release && cargo test -q` works from a
//! clean checkout with no network and no registry cache.
//!
//! ## The `pjrt` feature flag
//!
//! The PJRT execution path — the `runtime` module and
//! [`calibrate::Calibration::measure`] — needs the external `xla` crate
//! and real AOT artifacts (`make artifacts`). It is gated behind the
//! **off-by-default** `pjrt` cargo feature:
//!
//! ```sh
//! cargo build --release                  # default: no PJRT, no deps
//! cargo build --release --features pjrt  # requires a vendored `xla` crate
//! ```
//!
//! With the feature off, `modtrans calibrate` exits with a usage error
//! and the `measured:<cal.json>` compute model still loads previously
//! saved calibration files (loading is pure JSON).
//!
//! ## CI
//!
//! `.github/workflows/ci.yml` runs build, test, `cargo fmt --check`,
//! `cargo clippy -- -D warnings` (advisory for now), a bench smoke pass
//! (`MODTRANS_BENCH_SAMPLES=2` caps every bench target to seconds), a
//! 1-thread-vs-8-thread `sweep` determinism diff, and a check that every
//! PR touches `CHANGES.md`. Reproduce the full matrix locally with
//! `make ci` before pushing.

pub mod calibrate;
pub mod cli;
pub mod compute;
pub mod error;
pub mod json;
pub mod onnx;
pub mod proto;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod translator;
pub mod util;
pub mod workload;
pub mod zoo;

pub use error::{Error, Result};
