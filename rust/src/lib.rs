//! # ModTrans
//!
//! A production-grade reproduction of *"ModTrans: Translating Real-world
//! Models for Distributed Training Simulator"* (CS.DC 2026): a translator
//! from ONNX models to the layer-wise workload description consumed by
//! ASTRA-sim-class distributed-training simulators — plus every substrate
//! the paper depends on, built from scratch:
//!
//! * [`proto`] — protobuf wire-format codec (ONNX's serialization).
//! * [`onnx`] — an ONNX IR subset with wire-compatible serialize/parse and
//!   shape inference.
//! * [`zoo`] — model builders (ResNet, VGG, AlexNet, MLP, transformer)
//!   generating real ONNX graphs with exact parameter counts.
//! * [`translator`] — the paper's contribution: layer extraction and
//!   ASTRA-sim workload emission.
//! * [`workload`] — the ASTRA-sim DNN-description file format.
//! * [`sim`] — a full discrete-event distributed-training simulator
//!   (network, collectives, system scheduler, training loop).
//! * [`compute`] — SCALE-sim-style systolic-array compute-time model.
//! * [`runtime`] / [`calibrate`] — PJRT execution of AOT-compiled
//!   JAX/Pallas GEMM artifacts for measured per-layer compute times.
//! * [`json`], [`util`], [`cli`] — config / infra substrates (no external
//!   crates beyond `xla`, `anyhow`, `thiserror`).
//!
//! The three-layer architecture keeps Python strictly at build time:
//! JAX/Pallas author + AOT-lower compute kernels to HLO text
//! (`make artifacts`); the Rust binary loads and runs them via PJRT.

pub mod calibrate;
pub mod cli;
pub mod compute;
pub mod error;
pub mod json;
pub mod onnx;
pub mod proto;
pub mod runtime;
pub mod sim;
pub mod translator;
pub mod util;
pub mod workload;
pub mod zoo;

pub use error::{Error, Result};
