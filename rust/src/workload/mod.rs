//! The ASTRA-sim DNN-description workload format (paper Fig. 3).
//!
//! A workload file is:
//!
//! ```text
//! <ParallelismType>
//! <NumberOfLayers>
//! <name> <reserved> <fwd_ns> <fwd_comm> <fwd_bytes> <ig_ns> <ig_comm> <ig_bytes> \
//!        <wg_ns> <wg_comm> <wg_bytes> <update_ns>
//! ...one line per layer...
//! ```
//!
//! Times are integer nanoseconds, sizes integer bytes, comm types one of
//! `NONE | ALLREDUCE | ALLGATHER | REDUCESCATTER | ALLTOALL`. This is the
//! layer-wise interface the paper targets ("applicable to any simulator
//! that takes layer-wise information as input", §1) and the input the
//! [`crate::sim`] workload layer executes.

use crate::error::{Error, Result};
use std::fmt;

/// Collective communication type attached to a layer phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommType {
    /// No communication in this phase.
    None,
    /// All-reduce (data-parallel weight-gradient sync).
    AllReduce,
    /// All-gather (model-parallel activation exchange).
    AllGather,
    /// Reduce-scatter.
    ReduceScatter,
    /// All-to-all (expert/model sharding).
    AllToAll,
}

impl CommType {
    /// Canonical file token.
    pub fn token(self) -> &'static str {
        match self {
            CommType::None => "NONE",
            CommType::AllReduce => "ALLREDUCE",
            CommType::AllGather => "ALLGATHER",
            CommType::ReduceScatter => "REDUCESCATTER",
            CommType::AllToAll => "ALLTOALL",
        }
    }

    /// Parse a file token.
    pub fn from_token(s: &str) -> Result<CommType> {
        Ok(match s {
            "NONE" => CommType::None,
            "ALLREDUCE" => CommType::AllReduce,
            "ALLGATHER" => CommType::AllGather,
            "REDUCESCATTER" => CommType::ReduceScatter,
            "ALLTOALL" => CommType::AllToAll,
            other => {
                return Err(Error::WorkloadParse {
                    line: 0,
                    msg: format!("unknown comm type '{other}'"),
                })
            }
        })
    }
}

impl fmt::Display for CommType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.token())
    }
}

/// Parallelization strategy for the whole workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Parallelism {
    /// Pure data parallelism.
    Data,
    /// Pure model parallelism.
    Model,
    /// Model parallel inside a group, data parallel across groups.
    HybridDataModel,
    /// Data parallel inside a group, model parallel across groups.
    HybridModelData,
    /// Microbatch pipeline parallelism (stage-partitioned).
    Pipeline,
}

impl Parallelism {
    /// Canonical file token.
    pub fn token(self) -> &'static str {
        match self {
            Parallelism::Data => "DATA",
            Parallelism::Model => "MODEL",
            Parallelism::HybridDataModel => "HYBRID_DATA_MODEL",
            Parallelism::HybridModelData => "HYBRID_MODEL_DATA",
            Parallelism::Pipeline => "PIPELINE",
        }
    }

    /// Parse a file token.
    pub fn from_token(s: &str) -> Result<Parallelism> {
        Ok(match s {
            "DATA" => Parallelism::Data,
            "MODEL" => Parallelism::Model,
            "HYBRID_DATA_MODEL" => Parallelism::HybridDataModel,
            "HYBRID_MODEL_DATA" => Parallelism::HybridModelData,
            "PIPELINE" => Parallelism::Pipeline,
            other => {
                return Err(Error::WorkloadParse {
                    line: 1,
                    msg: format!("unknown parallelism '{other}'"),
                })
            }
        })
    }
}

impl fmt::Display for Parallelism {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.token())
    }
}

/// One phase (forward / input-grad / weight-grad) of a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Phase {
    /// Compute time in nanoseconds.
    pub compute_ns: u64,
    /// Collective issued after the compute.
    pub comm: CommType,
    /// Collective payload in bytes.
    pub comm_bytes: u64,
}

impl Phase {
    /// A compute-only phase.
    pub fn compute_only(ns: u64) -> Phase {
        Phase { compute_ns: ns, comm: CommType::None, comm_bytes: 0 }
    }
}

/// One layer row of the description file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerSpec {
    /// Layer name (paper's "Layer Name" column).
    pub name: String,
    /// Reserved field (ASTRA-sim keeps `-1` here).
    pub reserved: i64,
    /// Forward pass.
    pub fwd: Phase,
    /// Input-gradient (backward wrt activations).
    pub input_grad: Phase,
    /// Weight-gradient (backward wrt parameters).
    pub weight_grad: Phase,
    /// Local optimizer update time in ns.
    pub update_ns: u64,
}

/// A complete DNN description: parallelism + layers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Workload {
    /// Strategy announced on line 1.
    pub parallelism: Parallelism,
    /// Layer rows.
    pub layers: Vec<LayerSpec>,
}

impl Default for Workload {
    /// An empty `DATA` workload — the identity value the IR emitters'
    /// into-variants refill ([`crate::ir::emit::workload_into`]).
    fn default() -> Workload {
        Workload { parallelism: Parallelism::Data, layers: Vec::new() }
    }
}

impl Workload {
    /// Serialize to the description-file text format.
    pub fn emit(&self) -> String {
        let mut out = String::new();
        out.push_str(self.parallelism.token());
        out.push('\n');
        out.push_str(&self.layers.len().to_string());
        out.push('\n');
        for l in &self.layers {
            out.push_str(&format!(
                "{} {} {} {} {} {} {} {} {} {} {} {}\n",
                l.name,
                l.reserved,
                l.fwd.compute_ns,
                l.fwd.comm,
                l.fwd.comm_bytes,
                l.input_grad.compute_ns,
                l.input_grad.comm,
                l.input_grad.comm_bytes,
                l.weight_grad.compute_ns,
                l.weight_grad.comm,
                l.weight_grad.comm_bytes,
                l.update_ns,
            ));
        }
        out
    }

    /// Parse a description file.
    pub fn parse(text: &str) -> Result<Workload> {
        let mut lines = text
            .lines()
            .enumerate()
            .map(|(i, l)| (i + 1, l.trim()))
            .filter(|(_, l)| !l.is_empty() && !l.starts_with('#'));

        let (_, ptok) = lines
            .next()
            .ok_or(Error::WorkloadParse { line: 1, msg: "empty file".into() })?;
        let parallelism = Parallelism::from_token(ptok)?;

        let (nline, ntok) = lines
            .next()
            .ok_or(Error::WorkloadParse { line: 2, msg: "missing layer count".into() })?;
        let count: usize = ntok.parse().map_err(|_| Error::WorkloadParse {
            line: nline,
            msg: format!("bad layer count '{ntok}'"),
        })?;

        let mut layers = Vec::with_capacity(count);
        for (lineno, line) in lines {
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 12 {
                return Err(Error::WorkloadParse {
                    line: lineno,
                    msg: format!("expected 12 fields, got {}", f.len()),
                });
            }
            let num = |s: &str, what: &str| -> Result<u64> {
                s.parse().map_err(|_| Error::WorkloadParse {
                    line: lineno,
                    msg: format!("bad {what} '{s}'"),
                })
            };
            let comm = |s: &str| -> Result<CommType> {
                CommType::from_token(s).map_err(|_| Error::WorkloadParse {
                    line: lineno,
                    msg: format!("unknown comm type '{s}'"),
                })
            };
            layers.push(LayerSpec {
                name: f[0].to_string(),
                reserved: f[1].parse().map_err(|_| Error::WorkloadParse {
                    line: lineno,
                    msg: format!("bad reserved field '{}'", f[1]),
                })?,
                fwd: Phase {
                    compute_ns: num(f[2], "fwd compute")?,
                    comm: comm(f[3])?,
                    comm_bytes: num(f[4], "fwd comm size")?,
                },
                input_grad: Phase {
                    compute_ns: num(f[5], "ig compute")?,
                    comm: comm(f[6])?,
                    comm_bytes: num(f[7], "ig comm size")?,
                },
                weight_grad: Phase {
                    compute_ns: num(f[8], "wg compute")?,
                    comm: comm(f[9])?,
                    comm_bytes: num(f[10], "wg comm size")?,
                },
                update_ns: num(f[11], "update time")?,
            });
        }
        if layers.len() != count {
            return Err(Error::WorkloadParse {
                line: 2,
                msg: format!("declared {count} layers, found {}", layers.len()),
            });
        }
        Ok(Workload { parallelism, layers })
    }

    /// Total declared communication volume in bytes (all phases).
    pub fn total_comm_bytes(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.fwd.comm_bytes + l.input_grad.comm_bytes + l.weight_grad.comm_bytes)
            .sum()
    }

    /// Total per-NPU compute time in ns (one fwd+bwd pass, no overlap).
    pub fn total_compute_ns(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| {
                l.fwd.compute_ns + l.input_grad.compute_ns + l.weight_grad.compute_ns
                    + l.update_ns
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Workload {
        Workload {
            parallelism: Parallelism::Data,
            layers: vec![
                LayerSpec {
                    name: "conv0".into(),
                    reserved: -1,
                    fwd: Phase::compute_only(1000),
                    input_grad: Phase::compute_only(900),
                    weight_grad: Phase {
                        compute_ns: 800,
                        comm: CommType::AllReduce,
                        comm_bytes: 37632,
                    },
                    update_ns: 10,
                },
                LayerSpec {
                    name: "dense0".into(),
                    reserved: -1,
                    fwd: Phase {
                        compute_ns: 2000,
                        comm: CommType::AllGather,
                        comm_bytes: 4096,
                    },
                    input_grad: Phase::compute_only(1800),
                    weight_grad: Phase {
                        compute_ns: 1600,
                        comm: CommType::AllReduce,
                        comm_bytes: 8192000,
                    },
                    update_ns: 20,
                },
            ],
        }
    }

    #[test]
    fn emit_parse_roundtrip() {
        let w = sample();
        let text = w.emit();
        let w2 = Workload::parse(&text).unwrap();
        assert_eq!(w, w2);
    }

    #[test]
    fn emit_format_shape() {
        let text = sample().emit();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "DATA");
        assert_eq!(lines[1], "2");
        assert!(lines[2].starts_with("conv0 -1 1000 NONE 0 900 NONE 0 800 ALLREDUCE 37632 10"));
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Workload::parse("").is_err());
        assert!(Workload::parse("BOGUS\n0\n").is_err());
        assert!(Workload::parse("DATA\nxyz\n").is_err());
        // wrong field count
        assert!(Workload::parse("DATA\n1\nconv0 -1 1000\n").is_err());
        // count mismatch
        assert!(Workload::parse("DATA\n2\nc -1 1 NONE 0 1 NONE 0 1 NONE 0 1\n").is_err());
        // bad comm type
        assert!(
            Workload::parse("DATA\n1\nc -1 1 FOO 0 1 NONE 0 1 NONE 0 1\n").is_err()
        );
    }

    #[test]
    fn parse_tolerates_comments_and_blanks() {
        let text = "# generated by modtrans\nDATA\n\n1\nc -1 1 NONE 0 1 NONE 0 1 ALLREDUCE 64 1\n";
        let w = Workload::parse(text).unwrap();
        assert_eq!(w.layers.len(), 1);
        assert_eq!(w.layers[0].weight_grad.comm_bytes, 64);
    }

    #[test]
    fn totals() {
        let w = sample();
        assert_eq!(w.total_comm_bytes(), 37632 + 4096 + 8192000);
        assert_eq!(w.total_compute_ns(), 1000 + 900 + 800 + 10 + 2000 + 1800 + 1600 + 20);
    }

    #[test]
    fn all_tokens_roundtrip() {
        for c in [
            CommType::None,
            CommType::AllReduce,
            CommType::AllGather,
            CommType::ReduceScatter,
            CommType::AllToAll,
        ] {
            assert_eq!(CommType::from_token(c.token()).unwrap(), c);
        }
        for p in [
            Parallelism::Data,
            Parallelism::Model,
            Parallelism::HybridDataModel,
            Parallelism::HybridModelData,
            Parallelism::Pipeline,
        ] {
            assert_eq!(Parallelism::from_token(p.token()).unwrap(), p);
        }
    }
}
