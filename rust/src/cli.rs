//! Command-line interface (clap stand-in for the offline build).
//!
//! ```text
//! modtrans zoo list
//! modtrans zoo build <name> -o model.onnx [--weights zeros|random|empty]
//! modtrans inspect <file.onnx | zoo:name> [--all] [--batch N]
//! modtrans translate <file.onnx | zoo:name | trace.et.json> [-o out.txt]
//!           [--from onnx|et-json] [--parallelism P]
//!           [--npus N] [--mp-group G] [--batch B] [--compute MODEL]
//! modtrans simulate <workload.txt> [--network net.json|SPEC] [--topology SPEC]
//!           [--npus N] [--iterations I] [--policy fifo|lifo] [--chunks C]
//!           [--stages S] [--microbatches M] [--boundary-bytes B]
//! modtrans sweep [model[,model...]] [--parallelisms L] [--topologies L]
//!           [--collectives L] [--npus N] [--batch B] [--threads T]
//!           [--cache-dir DIR]
//! modtrans sweep fleet [model[,model...]] [--procs N] [--retries R]
//!           [--cache-dir DIR] [--cache-from DIR] [--status-out FILE]
//!           [--journal DIR] [--resume] [--shard-timeout SECS]
//!           [--lease N] [--static-shards]
//!           (+ every sweep option; lease assignment is fleet-owned)
//! modtrans calibrate [--artifacts DIR] [-o cal.json] [--reps R]   (pjrt feature)
//! modtrans check [trace.et.json | --cache-dir DIR]   (IR + task-graph invariants)
//! ```

use crate::calibrate::{Calibration, MeasuredCompute};
use crate::compute::SystolicCompute;
use crate::error::{Error, Result};
use crate::ir;
use crate::onnx;
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::sim::{self, Network, NetworkSpec, Policy, SimConfig};
use crate::sweep::{self, CommSchedule, SweepConfig, SweepGrid, SweepReport};
use crate::translator::{
    self, ComputeTimeModel, ConstantCompute, RooflineCompute, TranslateOpts,
};
use crate::util::table::Table;
use crate::util::{human_bytes, human_time};
use crate::workload::{Parallelism, Workload};
use crate::zoo::{self, WeightFill, ZooOpts};
use std::path::{Path, PathBuf};

/// Tiny argument cursor: positionals + `--key value` options + flags.
pub struct Args {
    positional: Vec<String>,
    options: Vec<(String, String)>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program/subcommand names).
    pub fn parse(raw: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut options = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(key) = a.strip_prefix("--") {
                // Flags that take no value.
                const FLAG_KEYS: [&str; 7] = [
                    "all",
                    "full-decode",
                    "quiet",
                    "breakdown",
                    "skip-infeasible",
                    "resume",
                    "static-shards",
                ];
                if FLAG_KEYS.contains(&key) {
                    flags.push(key.to_string());
                } else {
                    i += 1;
                    let v = raw.get(i).ok_or_else(|| {
                        Error::Usage(format!("option --{key} needs a value"))
                    })?;
                    options.push((key.to_string(), v.clone()));
                }
            } else if a == "-o" {
                i += 1;
                let v = raw
                    .get(i)
                    .ok_or_else(|| Error::Usage("-o needs a value".into()))?;
                options.push(("out".to_string(), v.clone()));
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { positional, options, flags })
    }

    fn pos(&self, i: usize, what: &str) -> Result<&str> {
        self.positional
            .get(i)
            .map(String::as_str)
            .ok_or_else(|| Error::Usage(format!("missing <{what}>")))
    }

    fn opt(&self, key: &str) -> Option<&str> {
        self.options.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn opt_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Usage(format!("bad value '{v}' for --{key}"))),
        }
    }

    fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// Entry point: dispatch a full argv (excluding binary name).
pub fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first().map(String::as_str) else {
        println!("{USAGE}");
        return Ok(());
    };
    // `sweep fleet` is a two-token subcommand: the orchestrator that
    // launches N worker processes, hands out `--scenarios` leases from a
    // work-stealing queue, and stream-merges their reports.
    if cmd == "sweep" && argv.get(1).map(String::as_str) == Some("fleet") {
        return cmd_sweep_fleet(&Args::parse(&argv[2..])?);
    }
    let args = Args::parse(&argv[1..])?;
    match cmd {
        "zoo" => cmd_zoo(&args),
        "inspect" => cmd_inspect(&args),
        "translate" => cmd_translate(&args),
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "sweep-merge" => cmd_sweep_merge(&args),
        "memory" => cmd_memory(&args),
        "calibrate" => cmd_calibrate(&args),
        "validate" => cmd_validate(&args),
        "check" => cmd_check(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(Error::Usage(format!("unknown command '{other}' (try `modtrans help`)"))),
    }
}

const USAGE: &str = "modtrans — translate real-world models for distributed-training simulators

USAGE:
  modtrans zoo list
  modtrans zoo build <name> -o model.onnx [--weights zeros|random|empty]
  modtrans inspect <file.onnx|zoo:name> [--all] [--batch N]
  modtrans translate <file.onnx|zoo:name|trace.et.json> [-o out.txt] [--from onnx|et-json]
            [--parallelism data|model|hybrid-dm|hybrid-md|pipeline]
            [--npus N] [--mp-group G] [--batch B] [--format text|et-json]
            [--compute roofline|systolic|constant:<ns>|measured:<cal.json>] [--zero 0|1|2|3]
            (--from et-json replays a modtrans-et-json/v2 trace: its durations and, when
             present, its comm plan are authoritative — comm-free documents are planned
             with the --parallelism options)
  modtrans simulate <workload.txt> [--network net.json|SPEC | --topology SPEC --npus N]
            [--iterations I] [--policy fifo|lifo] [--chunks C]
            [--stages S] [--microbatches M] [--boundary-bytes B]
            (network SPEC grammar: dim[/dim/...], each dim kind[:NxBWg@LAT][+algo] with
             kind ring|fc|switch|torus2d|rail|dragonfly and algo ring|hd|direct|dim-ordered,
             e.g. ring:8x300g@700ns/switch:16x25g@5us+direct — a bare kind token is the
             deprecated single-dimension alias, sized by --npus/--bandwidth-gbps/--latency-ns)
  modtrans sweep [model[,model...]] [--models LIST] [--parallelisms data,model,...]
            [--topologies SPEC[,SPEC...]] [--collectives direct|pipelined|pipelined-lifo]
            [--npus N] [--batch B] [--mp-group G] [--iterations I] [--shard K/N]
            [--scenarios I,J,K] [--threads T] [--hbm-gib G] [--zero 0|1|2|3]
            [--skip-infeasible] [--top K] [--top-cutoff NS] [--cache-dir DIR]
            [-o|--json-out results.json]
            (--top K ranks only the K fastest scenarios, skipping simulation for any
             scenario whose analytic lower bound exceeds the K-th best simulated time —
             exact: byte-identical to the exhaustive ranking's first K rows;
             --scenarios runs one explicit lease of grid indices and --top-cutoff seeds
             the prune cutoff — the spellings the fleet orchestrator dispatches with)
  modtrans sweep fleet [model[,model...]] [--procs N] [--retries R] [--work-dir DIR]
            [--cache-dir DIR] [--cache-from SYNC_DIR] [--status-out status.json]
            [--journal DIR] [--resume] [--shard-timeout SECS] [--lease N] [--static-shards]
            (+ every sweep option above except --shard; launches N worker processes
             warmed from one shared IR cache, hands out scenario leases from a
             work-stealing queue, journals completed leases, and stream-merges the
             reports — the merged ranking is byte-identical to the monolithic sweep)
  modtrans sweep-merge <shard.json> [shard.json ...] [-o merged.json]
  modtrans memory <file.onnx|zoo:name> [--npus N] [--mp-group G] [--batch B]
            [--optimizer sgd|momentum|adam] [--zero 0|1|2|3] [--hbm-gib G]
  modtrans calibrate [--artifacts DIR] [-o cal.json] [--reps R]   (needs --features pjrt)
  modtrans validate                      (paper §4.4 ResNet-50 sanity check)
  modtrans check [trace.et.json | --cache-dir DIR] [--network SPEC|net.json] [--batch B] [--quiet]
            (data-level verification: bare form verifies IR + task-graph invariants
             for every zoo model under every parallelism strategy — with --network it
             also validates the fabric, including per-dimension collective-algorithm
             admissibility; with a file it verifies one et-json document or sweep-cache
             envelope; with --cache-dir it verifies every .ir.json envelope in the
             directory)";

/// Load a model from `zoo:<name>` or a `.onnx` path (metadata-only).
fn load_model(spec: &str, full: bool) -> Result<onnx::Model> {
    if let Some(name) = spec.strip_prefix("zoo:") {
        zoo::get(name, ZooOpts { weights: WeightFill::Empty })
    } else {
        let bytes = std::fs::read(spec)?;
        if full {
            onnx::parse_model(&bytes)
        } else {
            onnx::parse_model_meta(&bytes)
        }
    }
}

fn parse_parallelism(s: &str) -> Result<Parallelism> {
    Ok(match s {
        "data" | "dp" => Parallelism::Data,
        "model" | "mp" => Parallelism::Model,
        "hybrid-dm" | "hybrid" => Parallelism::HybridDataModel,
        "hybrid-md" => Parallelism::HybridModelData,
        "pipeline" | "pp" => Parallelism::Pipeline,
        other => return Err(Error::Usage(format!("unknown parallelism '{other}'"))),
    })
}

fn parse_compute(spec: &str, batch: i64) -> Result<Box<dyn ComputeTimeModel>> {
    if let Some(ns) = spec.strip_prefix("constant:") {
        let ns: u64 = ns
            .parse()
            .map_err(|_| Error::Usage(format!("bad constant compute '{ns}'")))?;
        return Ok(Box::new(ConstantCompute(ns)));
    }
    if let Some(path) = spec.strip_prefix("measured:") {
        let cal = Calibration::load(Path::new(path))?;
        return Ok(Box::new(MeasuredCompute { cal, batch }));
    }
    match spec {
        "roofline" => Ok(Box::new(RooflineCompute::default())),
        "systolic" => Ok(Box::new(SystolicCompute::new(batch))),
        other => Err(Error::Usage(format!("unknown compute model '{other}'"))),
    }
}

fn cmd_zoo(args: &Args) -> Result<()> {
    match args.pos(0, "zoo subcommand")? {
        "list" => {
            let mut t = Table::new(vec!["Name", "Description"]);
            for m in zoo::MODELS {
                t.row(vec![m, zoo::describe(m)]);
            }
            print!("{t}");
            Ok(())
        }
        "build" => {
            let name = args.pos(1, "model name")?;
            let out = args.opt("out").unwrap_or("model.onnx");
            let weights = match args.opt("weights").unwrap_or("zeros") {
                "zeros" => WeightFill::Zeros,
                "random" => WeightFill::Random(args.opt_parse("seed", 0u64)?),
                "empty" => WeightFill::Empty,
                w => return Err(Error::Usage(format!("unknown weight fill '{w}'"))),
            };
            let m = zoo::get(name, ZooOpts { weights })?;
            let bytes = onnx::encode_model(&m);
            std::fs::write(out, &bytes)?;
            println!(
                "wrote {out}: {} ({} params, {})",
                human_bytes(bytes.len() as u64),
                m.num_parameters(),
                human_bytes(m.parameter_bytes()),
            );
            Ok(())
        }
        other => Err(Error::Usage(format!("unknown zoo subcommand '{other}'"))),
    }
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let spec = args.pos(0, "model")?;
    let batch = args.opt_parse("batch", 1i64)?;
    let model = load_model(spec, false)?;
    let summary = translator::extract(&model, batch)?;
    if args.flag("all") {
        let mut t = Table::new(vec!["Initializer", "Variables", "Data Type", "Size"]);
        for (name, vars, dt, bytes) in &summary.all_initializers {
            t.row(vec![
                name.clone(),
                vars.to_string(),
                dt.to_string(),
                bytes.to_string(),
            ]);
        }
        print!("{t}");
    } else {
        let mut t = Table::new(vec![
            "Layer Name",
            "Kind",
            "Variables",
            "Data Type",
            "Model Size",
            "MACs",
            "Out Activation",
        ]);
        for l in &summary.layers {
            t.row(vec![
                l.name.clone(),
                l.kind.label().to_string(),
                l.variables.to_string(),
                l.dtype.to_string(),
                l.weight_bytes.to_string(),
                l.macs.to_string(),
                human_bytes(l.out_act_bytes),
            ]);
        }
        print!("{t}");
    }
    println!(
        "total: {} parameters, {} ({} compute layers, batch {})",
        summary.total_params,
        human_bytes(summary.total_bytes),
        summary.layers.len(),
        batch,
    );
    Ok(())
}

fn cmd_translate(args: &Args) -> Result<()> {
    let spec = args.pos(0, "model")?;
    let batch = args.opt_parse("batch", 32i64)?;
    let opts = TranslateOpts {
        parallelism: parse_parallelism(args.opt("parallelism").unwrap_or("data"))?,
        npus: args.opt_parse("npus", 16usize)?,
        mp_group: args.opt_parse("mp-group", 4usize)?,
        batch,
        zero: parse_zero(args)?,
    };
    let format = args.opt("format").unwrap_or("text");
    if format != "text" && format != "et-json" {
        return Err(Error::Usage(format!(
            "unknown translate format '{format}' (expected text or et-json)"
        )));
    }
    let model_ir = match args.opt("from").unwrap_or("onnx") {
        // The staged pipeline: frontend → compute pass → comm pass → emitter.
        "onnx" => {
            let compute = parse_compute(args.opt("compute").unwrap_or("systolic"), batch)?;
            let model = load_model(spec, false)?;
            let mut ir = ir::frontend::from_model(&model, batch)?;
            ir::passes::annotate_compute(&mut ir, compute.as_ref());
            ir::passes::annotate_comm(&mut ir, opts);
            ir
        }
        // Replay path: the trace's durations (and comm plan, when it has
        // one) are authoritative — no compute model runs. A comm-free
        // document (the sweep cache's disk form) gets the comm pass for
        // the requested strategy so it can still lower to any format.
        "et-json" | "et" => {
            let text = std::fs::read_to_string(spec)?;
            let mut ir = ir::frontend::from_et_json_str(&text)?;
            if ir.comm_annotated().is_none() {
                ir::passes::annotate_comm(&mut ir, opts);
            }
            ir
        }
        other => {
            return Err(Error::Usage(format!(
                "unknown translate source '{other}' (expected onnx or et-json)"
            )))
        }
    };
    match format {
        "text" => {
            let workload = ir::emit::to_sim_workload(&model_ir)?;
            let text = workload.emit();
            match args.opt("out") {
                Some(path) => {
                    std::fs::write(path, &text)?;
                    println!(
                        "wrote {path}: {} layers, {} comm volume, {} compute per pass",
                        workload.layers.len(),
                        human_bytes(workload.total_comm_bytes()),
                        human_time(workload.total_compute_ns() as f64 * 1e-9),
                    );
                }
                None => print!("{text}"),
            }
        }
        _ => {
            let graph = ir::emit::et_json(&model_ir)?;
            let nodes = graph.get("nodes").and_then(|n| n.as_arr()).map_or(0, |n| n.len());
            let text = graph.to_json_pretty();
            match args.opt("out") {
                Some(path) => {
                    std::fs::write(path, &text)?;
                    println!(
                        "wrote {path}: {} graph nodes over {} layers ({})",
                        nodes,
                        model_ir.num_layers(),
                        ir::emit::ET_JSON_SCHEMA,
                    );
                }
                None => print!("{text}"),
            }
        }
    }
    Ok(())
}

/// Load the simulated fabric. `--network` takes either a JSON config
/// file on disk or a [`NetworkSpec`] string
/// (`ring:8x300g@700ns/switch:16x25g@5us+direct`); `--topology` takes a
/// spec too — bare legacy tokens like `ring` or `torus2d` parse as
/// single-dimension specs, sized by `--npus` / `--bandwidth-gbps` /
/// `--latency-ns` exactly as before.
fn load_network(args: &Args) -> Result<Network> {
    let npus = args.opt_parse("npus", 16usize)?;
    let bandwidth = args.opt_parse("bandwidth-gbps", 100.0f64)?;
    let latency = args.opt_parse("latency-ns", 500.0f64)?;
    if let Some(spec) = args.opt("network") {
        // A file on disk is the JSON form; anything else is a spec.
        if Path::new(spec).is_file() {
            let text = std::fs::read_to_string(spec)?;
            return Network::from_json(&crate::json::parse(&text)?);
        }
        return NetworkSpec::parse(spec)?.materialize(npus, bandwidth, latency);
    }
    NetworkSpec::parse(args.opt("topology").unwrap_or("ring"))?
        .materialize(npus, bandwidth, latency)
}

fn sim_config(args: &Args) -> Result<SimConfig> {
    let chunks = args.opt_parse("chunks", 4usize)?;
    if chunks > sim::system::MAX_CHUNKS {
        return Err(Error::Usage(format!(
            "--chunks {chunks} exceeds the supported maximum of {}",
            sim::system::MAX_CHUNKS
        )));
    }
    Ok(SimConfig {
        network: load_network(args)?,
        system: sim::SystemConfig {
            scheduling: match args.opt("policy").unwrap_or("fifo") {
                "fifo" => Policy::Fifo,
                "lifo" => Policy::Lifo,
                p => return Err(Error::Usage(format!("unknown policy '{p}'"))),
            },
            chunks: sim::ChunkCfg { chunks },
        },
        iterations: args.opt_parse("iterations", 2usize)?,
        stages: args.opt_parse("stages", 4usize)?,
        microbatches: args.opt_parse("microbatches", 8usize)?,
        boundary_bytes: args.opt_parse("boundary-bytes", 1u64 << 20)?,
        schedule: match args.opt("schedule").unwrap_or("gpipe") {
            "gpipe" => sim::PipelineSchedule::GPipe,
            "1f1b" => sim::PipelineSchedule::OneFOneB,
            x => return Err(Error::Usage(format!("unknown schedule '{x}'"))),
        },
    })
}

fn print_report(r: &sim::SimReport) {
    println!("simulated {}", human_time(r.total_ns as f64 * 1e-9));
    println!("  iteration time : {}", human_time(r.iteration_ns as f64 * 1e-9));
    println!("  compute util   : {:.1}%", r.compute_utilization * 100.0);
    println!("  exposed comm   : {}", human_time(r.exposed_ns as f64 * 1e-9));
    for (i, b) in r.net_busy_ns.iter().enumerate() {
        println!("  net dim {i} busy : {}", human_time(*b as f64 * 1e-9));
    }
    println!("  events         : {}", r.events);
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let path = args.pos(0, "workload file")?;
    let workload = Workload::parse(&std::fs::read_to_string(path)?)?;
    let cfg = sim_config(args)?;
    let report = sim::simulate(&workload, &cfg)?;
    println!(
        "workload: {} layers, {} ({})",
        workload.layers.len(),
        workload.parallelism,
        path
    );
    print_report(&report);
    if args.flag("breakdown") && !report.breakdown.is_empty() {
        let mut rows: Vec<&sim::LayerBreakdown> = report.breakdown.iter().collect();
        rows.sort_by_key(|b| std::cmp::Reverse(b.compute_ns + b.comm_ns));
        let mut t = Table::new(vec!["Layer", "Compute", "Comm"]);
        for b in rows.iter().take(15) {
            t.row(vec![
                b.name.clone(),
                human_time(b.compute_ns as f64 * 1e-9),
                human_time(b.comm_ns as f64 * 1e-9),
            ]);
        }
        println!("top layers by attributed time:");
        print!("{t}");
    }
    Ok(())
}

/// The paper's §4.4 sanity check as a CLI verb: extract ResNet-50 and
/// diff against the embedded ASTRA-sim reference sizes.
fn cmd_validate(_args: &Args) -> Result<()> {
    const TABLE3_ASTRA: [u64; 54] = [
        37632, 16384, 147456, 65536, 65536, 65536, 147456, 65536, 65536, 147456, 65536,
        131072, 589824, 262144, 524288, 262144, 589824, 262144, 262144, 589824, 262144,
        262144, 589824, 262144, 524288, 2359296, 1048576, 2097152, 1048576, 2359296,
        1048576, 1048576, 2359296, 1048576, 1048576, 2359296, 1048576, 1048576, 2359296,
        1048576, 1048576, 2359296, 1048576, 2097152, 9437184, 4194304, 8388608, 4194304,
        9437184, 4194304, 4194304, 9437184, 4194304, 8192000,
    ];
    let m = zoo::get("resnet50", ZooOpts { weights: WeightFill::Zeros })?;
    let bytes = onnx::encode_model(&m);
    // lint: allow(wall-clock) — reports real extraction wall time to the user
    let t0 = std::time::Instant::now();
    let summary = translator::extract_from_bytes(&bytes, 1)?;
    let dt = t0.elapsed();
    let mut bad = 0usize;
    for (l, expect) in summary.layers.iter().zip(TABLE3_ASTRA.iter()) {
        if l.weight_bytes != *expect {
            println!("MISMATCH {}: extracted {} reference {}", l.name, l.weight_bytes, expect);
            bad += 1;
        }
    }
    println!(
        "sanity check: {}/{} layers identical (translated {} of ONNX in {})",
        summary.layers.len() - bad,
        summary.layers.len(),
        human_bytes(bytes.len() as u64),
        human_time(dt.as_secs_f64()),
    );
    if bad > 0 {
        return Err(Error::Translate(format!("{bad} layer size mismatches")));
    }
    println!("PASS — matches the ASTRA-sim reference model (paper §4.4)");
    Ok(())
}

/// Data-level verification verb: run the IR verifier and the task-graph
/// verifier over real inputs — the runtime twin of the `modtrans-lint`
/// source pass (see *Static guarantees* in the crate docs).
///
/// * bare: every zoo model under every parallelism strategy — the IR is
///   verified at each annotation stage, then the built task graph.
/// * `<trace.et.json>`: one et-json document or sweep-cache envelope.
/// * `--cache-dir DIR`: every `.ir.json` envelope under DIR.
fn cmd_check(args: &Args) -> Result<()> {
    if let Some(dir) = args.opt("cache-dir") {
        let mut paths: Vec<PathBuf> = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            let is_entry = path
                .file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.ends_with(sweep::cache::IR_CACHE_SUFFIX));
            if is_entry && path.is_file() {
                paths.push(path);
            }
        }
        paths.sort();
        if paths.is_empty() {
            return Err(Error::Usage(format!("no cache entries (*.ir.json) under {dir}")));
        }
        for path in &paths {
            let model = sweep::verify_envelope_file(path)?;
            if !args.flag("quiet") {
                println!("ok {model:<12} {}", path.display());
            }
        }
        println!("check: {} cache envelope(s) verified", paths.len());
        return Ok(());
    }
    if let Some(path) = args.positional.first() {
        let model = sweep::verify_envelope_file(Path::new(path))?;
        println!("check: {path}: IR invariants hold ({model})");
        return Ok(());
    }

    // Bare form: the whole zoo under the whole strategy axis.
    let batch: i64 = args.opt_parse("batch", 8)?;
    let strategies = [
        Parallelism::Data,
        Parallelism::Model,
        Parallelism::HybridDataModel,
        Parallelism::HybridModelData,
        Parallelism::Pipeline,
    ];
    // `--network`/`--topology` verify the task graphs over a chosen
    // fabric — the network's own validation (dimension shape and
    // per-dimension algorithm admissibility) runs at the same boundary.
    let cfg = if args.opt("network").is_some() || args.opt("topology").is_some() {
        SimConfig { network: load_network(args)?, ..SimConfig::default() }
    } else {
        SimConfig::default()
    };
    let compute = SystolicCompute::new(batch);
    let mut graphs = 0usize;
    for name in zoo::MODELS {
        let mut base = ir::frontend::from_zoo(name, batch)?;
        ir::verify(&base)?;
        ir::passes::annotate_compute(&mut base, &compute);
        ir::verify(&base)?;
        for p in strategies {
            let mut annotated = base.clone();
            ir::passes::annotate_comm(
                &mut annotated,
                TranslateOpts { parallelism: p, ..Default::default() },
            );
            ir::verify(&annotated)?;
            let w = ir::emit::to_sim_workload(&annotated)?;
            let check = sim::verify_workload(&w, &cfg)?;
            graphs += 1;
            if !args.flag("quiet") {
                println!(
                    "ok {name:<12} {p:?}: {} tasks / {} deps over {} resources",
                    check.tasks, check.deps, check.resources
                );
            }
        }
    }
    println!(
        "check: {} model(s) x {} strategies = {graphs} task graphs verified",
        zoo::MODELS.len(),
        strategies.len()
    );
    Ok(())
}

/// Parse a comma-separated list with a per-item parser.
fn parse_list<T>(spec: &str, parse: impl Fn(&str) -> Result<T>) -> Result<Vec<T>> {
    spec.split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(parse)
        .collect()
}

/// Parse the sweep grid axes (shared by `sweep` and `sweep fleet`):
/// model list positionally or via `--models`, plus the three token
/// lists.
fn parse_sweep_grid(args: &Args) -> Result<SweepGrid> {
    let positional = args.positional.first().map(String::as_str);
    let flagged = args.opt("models");
    if positional.is_some() && flagged.is_some() {
        return Err(Error::Usage(
            "give the model list either positionally or via --models, not both".into(),
        ));
    }
    let models_spec = positional.or(flagged).unwrap_or("mlp,resnet18");
    let models = parse_list(models_spec, |s| {
        if s.ends_with(".onnx") {
            return Err(Error::Usage(format!(
                "sweep takes zoo model names, not ONNX files (got '{s}') — \
                 see `modtrans zoo list`"
            )));
        }
        Ok(s.trim_start_matches("zoo:").to_string())
    })?;
    Ok(SweepGrid {
        models,
        parallelisms: parse_list(
            args.opt("parallelisms").unwrap_or("data,model,hybrid-dm"),
            parse_parallelism,
        )?,
        networks: parse_list(args.opt("topologies").unwrap_or("ring,fc,switch"), |s| {
            NetworkSpec::parse(s)
        })?,
        collectives: parse_list(
            args.opt("collectives").unwrap_or("pipelined"),
            CommSchedule::from_token,
        )?,
    })
}

/// Parse the fixed sweep parameters (shared by `sweep` and
/// `sweep fleet`).
fn parse_sweep_config(args: &Args) -> Result<SweepConfig> {
    Ok(SweepConfig {
        npus: args.opt_parse("npus", 16usize)?,
        mp_group: args.opt_parse("mp-group", 4usize)?,
        batch: args.opt_parse("batch", 32i64)?,
        iterations: args.opt_parse("iterations", 2usize)?,
        threads: args.opt_parse("threads", 4usize)?,
        bandwidth_gbps: args.opt_parse("bandwidth-gbps", 100.0f64)?,
        latency_ns: args.opt_parse("latency-ns", 500.0f64)?,
        hbm_bytes: (args.opt_parse("hbm-gib", 32u64)?) << 30,
        zero: parse_zero(args)?,
        skip_infeasible: args.flag("skip-infeasible"),
        shard: parse_shard(args)?,
        top_k: parse_top_k(args)?,
    })
}

/// Parse `--scenarios I,J,K` — the explicit grid-expansion scenario
/// indices of one fleet lease (the spelling the fleet orchestrator uses
/// when re-invoking this binary; range/duplicate checks live in
/// [`sweep::run_sweep_scenarios`]).
fn parse_scenarios(args: &Args) -> Result<Option<Vec<usize>>> {
    let Some(spec) = args.opt("scenarios") else {
        return Ok(None);
    };
    let lease = parse_list(spec, |s| {
        s.parse::<usize>()
            .map_err(|_| Error::Usage(format!("bad scenario index '{s}' in --scenarios")))
    })?;
    if lease.is_empty() {
        return Err(Error::Usage("--scenarios needs at least one grid index".into()));
    }
    Ok(Some(lease))
}

/// Parse `--top-cutoff NS` — the fleet-wide top-K prune cutoff pushed to
/// later leases (nanoseconds; only meaningful together with `--top K`).
fn parse_top_cutoff(args: &Args) -> Result<Option<u64>> {
    match args.opt("top-cutoff") {
        None => Ok(None),
        Some(spec) => spec.parse::<u64>().map(Some).map_err(|_| {
            Error::Usage(format!("bad --top-cutoff '{spec}' — need integer nanoseconds"))
        }),
    }
}

/// Parse `--top K` (exact top-K pruning; K must be a positive integer).
fn parse_top_k(args: &Args) -> Result<Option<usize>> {
    let Some(spec) = args.opt("top") else {
        return Ok(None);
    };
    match spec.parse::<usize>() {
        Ok(k) if k >= 1 => Ok(Some(k)),
        _ => Err(Error::Usage(format!("bad --top '{spec}' — need a positive integer K"))),
    }
}

/// The report destination: `--json-out` (the spelling the fleet
/// orchestrator uses when re-invoking this binary) or the generic
/// `-o`/`--out`.
fn json_out(args: &Args) -> Option<&str> {
    args.opt("json-out").or_else(|| args.opt("out"))
}

/// Grid sweep: (model × parallelism × topology × collective) scenarios,
/// translated once per model into a shared cache and simulated across a
/// worker pool. See [`crate::sweep`].
fn cmd_sweep(args: &Args) -> Result<()> {
    let grid = parse_sweep_grid(args)?;
    let cfg = parse_sweep_config(args)?;
    // Test-only crash injection for the fleet's failure-path tests
    // (no-op unless the orchestrator exported the failpoint variable).
    sweep::fleet::shard_failpoint(cfg.shard);
    let cache_dir = args.opt("cache-dir").map(Path::new);
    let lease = parse_scenarios(args)?;
    let cutoff = parse_top_cutoff(args)?;
    let report = sweep::run_sweep_scenarios(&grid, &cfg, cache_dir, lease.as_deref(), cutoff)?;
    let shard_note = match (cfg.shard, &lease) {
        (Some((k, n)), _) => format!(" [shard {k}/{n}]"),
        (None, Some(l)) => format!(" [lease of {} scenario(s)]", l.len()),
        (None, None) => String::new(),
    };
    println!(
        "sweep{shard_note}: {} scenarios over {} models on {} worker threads \
         ({} translations + {} cache loads — one IR per model, shared by all scenarios)",
        report.ranked.len(),
        report.models,
        cfg.threads.max(1),
        report.translations,
        report.cache_loads,
    );
    if cfg.top_k.is_some() {
        println!(
            "top-{} pruning: {} scenario(s) simulated + {} skipped by analytic lower bound \
             ({} bounds evaluated, no DES)",
            cfg.top_k.unwrap_or(0),
            report.scenarios_simulated,
            report.scenarios_pruned,
            report.bounds_evaluated,
        );
    }
    print!("{}", report.render_text());
    if let Some(path) = json_out(args) {
        std::fs::write(path, report.to_json().to_json_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Fleet orchestration: expand the grid once, pre-warm a shared IR
/// cache with a single cold translation pass, launch `--procs` worker
/// processes of this binary, hand out `--scenarios` leases from a
/// work-stealing queue (re-dispatching crashes up to `--retries` times,
/// journaling completions with `--journal`), and stream-merge the lease
/// reports in-process. The merged ranking is byte-identical to a
/// monolithic `sweep` of the same grid. See [`crate::sweep::fleet`].
fn cmd_sweep_fleet(args: &Args) -> Result<()> {
    let grid = parse_sweep_grid(args)?;
    let cfg = parse_sweep_config(args)?;
    if cfg.shard.is_some() {
        return Err(Error::Usage(
            "sweep fleet assigns shards itself — drop --shard (use --procs N)".into(),
        ));
    }
    let opts = sweep::FleetOpts {
        procs: args.opt_parse("procs", 2usize)?,
        retries: args.opt_parse("retries", 1usize)?,
        binary: None, // re-invoke this very binary
        cache_dir: args.opt("cache-dir").map(PathBuf::from),
        cache_from: args.opt("cache-from").map(PathBuf::from),
        work_dir: args.opt("work-dir").map(PathBuf::from),
        // Written by run_fleet on success AND on worker failure — the
        // failure evidence is the point of the status document.
        status_out: args.opt("status-out").map(PathBuf::from),
        journal: args.opt("journal").map(PathBuf::from),
        resume: args.flag("resume"),
        shard_timeout: args
            .opt("shard-timeout")
            .map(|s| {
                s.parse::<f64>().map_err(|_| {
                    Error::Usage(format!("bad --shard-timeout '{s}' — need seconds"))
                })
            })
            .transpose()?,
        lease_size: args
            .opt("lease")
            .map(|s| {
                s.parse::<usize>().map_err(|_| {
                    Error::Usage(format!("bad --lease '{s}' — need a scenario count"))
                })
            })
            .transpose()?,
        static_shards: args.flag("static-shards"),
        // Test/CI-only crash or hang injection in worker processes
        // (see sweep::fleet::shard_failpoint for the grammar).
        failpoint: args.opt("failpoint").map(str::to_string),
    };
    let fleet = sweep::run_fleet(&grid, &cfg, &opts)?;
    println!(
        "fleet: {} worker process(es), {} lease(s) [{}] over {} scenarios — pre-warm ran \
         {} translation(s) + {} cache load(s); the workers ran {} translation(s)",
        fleet.shards.len(),
        fleet.leases_completed,
        if fleet.static_shards { "static" } else { "stealing" },
        fleet.merged.ranked.len(),
        fleet.prewarm_translations,
        fleet.prewarm_cache_loads,
        fleet.shard_translations(),
    );
    if fleet.replayed_leases > 0 {
        println!(
            "journal: replayed {} lease(s) covering {} scenario(s) — not re-simulated",
            fleet.replayed_leases, fleet.scenarios_from_journal,
        );
    }
    if opts.cache_from.is_some() {
        println!(
            "cache sync: {} entr(ies) copied in, {} published back",
            fleet.cache_copied_in, fleet.cache_copied_out,
        );
    }
    let mut t = Table::new(vec![
        "Worker",
        "Attempts",
        "Leases",
        "Exit",
        "Scenarios",
        "Translations",
        "Cache loads",
        "Pruned",
        "Simulated",
        "Bound-pruned",
        "Idle ms",
    ]);
    for s in &fleet.shards {
        t.row(vec![
            format!("{}/{}", s.shard.0, s.shard.1),
            s.attempts.to_string(),
            s.leases.to_string(),
            s.exit_code.map_or_else(|| "-".to_string(), |c| c.to_string()),
            s.scenarios.to_string(),
            s.translations.to_string(),
            s.cache_loads.to_string(),
            s.pruned.to_string(),
            s.scenarios_simulated.to_string(),
            s.scenarios_pruned.to_string(),
            s.idle_ms.to_string(),
        ]);
    }
    print!("{t}");
    print!("{}", fleet.merged.render_text());
    if let Some(path) = json_out(args) {
        std::fs::write(path, fleet.merged.to_json().to_json_pretty())?;
        println!("wrote {path}");
    }
    if let Some(path) = args.opt("status-out") {
        // run_fleet writes it best-effort (on failure too); don't claim
        // success for a write that only produced a stderr warning.
        if Path::new(path).exists() {
            println!("wrote {path}");
        }
    }
    Ok(())
}

/// Parse `--shard K/N` (1-based shard index over the deterministic
/// scenario order; grammar shared with the report's `"shard"` field via
/// [`sweep::parse_shard_spec`]).
fn parse_shard(args: &Args) -> Result<Option<(usize, usize)>> {
    let Some(spec) = args.opt("shard") else {
        return Ok(None);
    };
    match sweep::parse_shard_spec(spec) {
        Some(shard) => Ok(Some(shard)),
        None => Err(Error::Usage(format!(
            "bad --shard '{spec}' — expected K/N with 1 <= K <= N"
        ))),
    }
}

/// Merge per-shard `sweep -o` JSON reports into one re-ranked report.
fn cmd_sweep_merge(args: &Args) -> Result<()> {
    if args.positional.is_empty() {
        return Err(Error::Usage("sweep-merge needs at least one shard JSON file".into()));
    }
    let mut shards = Vec::with_capacity(args.positional.len());
    for path in &args.positional {
        // Name the file in every failure: a crashed shard process leaves
        // no (or a truncated) report, and "which shard died" must be
        // readable straight off the merge error.
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Config(format!(
                "cannot read shard report '{path}': {e} — a crashed shard leaves no \
                 report file; re-run that shard, or use `sweep fleet`, which retries \
                 crashes and records each shard's exit code and stderr"
            ))
        })?;
        let doc = crate::json::parse(&text).map_err(|e| {
            Error::Config(format!("shard report '{path}' is not valid JSON: {e}"))
        })?;
        shards.push(SweepReport::from_json(&doc).map_err(|e| {
            Error::Config(format!("shard report '{path}' is not a sweep report: {e}"))
        })?);
    }
    let merged = SweepReport::merge(&shards)?;
    println!(
        "merged {} shard file(s): {} scenarios over {} models \
         ({} translations, {} cache loads, {} pruned)",
        shards.len(),
        merged.ranked.len(),
        merged.models,
        merged.translations,
        merged.cache_loads,
        merged.pruned,
    );
    print!("{}", merged.render_text());
    if let Some(path) = json_out(args) {
        std::fs::write(path, merged.to_json().to_json_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn parse_zero(args: &Args) -> Result<translator::ZeroStage> {
    Ok(match args.opt("zero").unwrap_or("0") {
        "0" => translator::ZeroStage::None,
        "1" => translator::ZeroStage::OptimizerState,
        "2" => translator::ZeroStage::Gradients,
        "3" => translator::ZeroStage::Parameters,
        x => return Err(Error::Usage(format!("unknown zero stage '{x}'"))),
    })
}

fn cmd_memory(args: &Args) -> Result<()> {
    let spec = args.pos(0, "model")?;
    let batch = args.opt_parse("batch", 32i64)?;
    let npus = args.opt_parse("npus", 16usize)?;
    let mp_group = args.opt_parse("mp-group", 4usize)?;
    let hbm = (args.opt_parse("hbm-gib", 32u64)?) << 30;
    let optimizer = match args.opt("optimizer").unwrap_or("adam") {
        "sgd" => translator::Optimizer::Sgd,
        "momentum" => translator::Optimizer::Momentum,
        "adam" => translator::Optimizer::Adam,
        x => return Err(Error::Usage(format!("unknown optimizer '{x}'"))),
    };
    let zero = parse_zero(args)?;
    let model = load_model(spec, false)?;
    let summary = translator::extract(&model, batch)?;

    let mem = translator::MemoryOpts {
        optimizer,
        zero,
        recompute: false,
        microbatches: 8,
        one_f_one_b: false,
        hbm_bytes: hbm,
    };
    let mut t = Table::new(vec![
        "Parallelism",
        "Weights",
        "Gradients",
        "Optimizer",
        "Activations",
        "Total/NPU",
        "Fits HBM",
    ]);
    for par in [
        Parallelism::Data,
        Parallelism::Model,
        Parallelism::HybridDataModel,
        Parallelism::Pipeline,
    ] {
        let opts = TranslateOpts { parallelism: par, npus, mp_group, batch, zero };
        let r = translator::memory_per_npu(&summary, opts, mem);
        t.row(vec![
            par.token().to_string(),
            human_bytes(r.weights),
            human_bytes(r.gradients),
            human_bytes(r.optimizer),
            human_bytes(r.activations),
            human_bytes(r.total()),
            if r.fits(hbm) { "yes".to_string() } else { "NO".to_string() },
        ]);
    }
    println!(
        "per-NPU training memory for {} (batch {batch}, {npus} NPUs, mp-group {mp_group}, {} HBM, {:?}, ZeRO {:?})",
        summary.model_name,
        human_bytes(hbm),
        optimizer,
        zero,
    );
    print!("{t}");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_calibrate(args: &Args) -> Result<()> {
    let dir = args.opt("artifacts").unwrap_or("artifacts");
    let reps = args.opt_parse("reps", 5usize)?;
    let out = args.opt("out").unwrap_or("calibration.json");
    let mut rt = Runtime::cpu()?;
    let n = rt.load_dir(Path::new(dir))?;
    println!("loaded {n} artifacts from {dir} on {}", rt.platform());
    let cal = Calibration::measure(&rt, reps)?;
    let mut t = Table::new(vec!["GEMM", "MACs", "Median wall time"]);
    for (g, ns) in &cal.entries {
        t.row(vec![
            format!("{}x{}x{}", g.m, g.k, g.n),
            g.macs().to_string(),
            human_time(*ns as f64 * 1e-9),
        ]);
    }
    print!("{t}");
    cal.save(Path::new(out))?;
    println!("wrote {out}");
    Ok(())
}

/// Without the `pjrt` feature there is no PJRT client to run artifacts
/// through; previously measured calibrations still load fine via the
/// `measured:<cal.json>` compute model.
#[cfg(not(feature = "pjrt"))]
fn cmd_calibrate(_args: &Args) -> Result<()> {
    Err(Error::Usage(
        "calibrate needs the PJRT runtime — rebuild with `--features pjrt` \
         (saved calibrations still work via --compute measured:<cal.json>)"
            .into(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(&v.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn arg_parsing() {
        let a = args(&["zoo:vgg16", "--batch", "8", "--all", "-o", "out.txt"]);
        assert_eq!(a.pos(0, "m").unwrap(), "zoo:vgg16");
        assert_eq!(a.opt_parse("batch", 1i64).unwrap(), 8);
        assert!(a.flag("all"));
        assert_eq!(a.opt("out"), Some("out.txt"));
        assert!(a.pos(1, "x").is_err());
        assert!(a.opt_parse::<i64>("batch", 0).is_ok());
    }

    #[test]
    fn missing_option_value_is_usage_error() {
        let raw: Vec<String> = vec!["--batch".into()];
        assert!(Args::parse(&raw).is_err());
    }

    #[test]
    fn bad_option_value_is_usage_error() {
        let a = args(&["--batch", "not-a-number"]);
        assert!(a.opt_parse::<i64>("batch", 0).is_err());
    }

    #[test]
    fn parallelism_tokens() {
        assert_eq!(parse_parallelism("data").unwrap(), Parallelism::Data);
        assert_eq!(parse_parallelism("dp").unwrap(), Parallelism::Data);
        assert_eq!(parse_parallelism("hybrid-md").unwrap(), Parallelism::HybridModelData);
        assert!(parse_parallelism("bogus").is_err());
    }

    #[test]
    fn compute_model_specs() {
        assert!(parse_compute("roofline", 1).is_ok());
        assert!(parse_compute("systolic", 1).is_ok());
        assert!(parse_compute("constant:5000", 1).is_ok());
        assert!(parse_compute("constant:x", 1).is_err());
        assert!(parse_compute("bogus", 1).is_err());
        assert!(parse_compute("measured:/no/such/file.json", 1).is_err());
    }

    #[test]
    fn zoo_spec_loads() {
        let m = load_model("zoo:mlp", false).unwrap();
        assert!(!m.graph.initializers.is_empty());
        assert!(load_model("zoo:nope", false).is_err());
        assert!(load_model("/no/such/file.onnx", false).is_err());
    }

    #[test]
    fn unknown_command_errors() {
        let argv: Vec<String> = vec!["frobnicate".into()];
        assert!(run(&argv).is_err());
    }

    #[test]
    fn sweep_runs_on_zoo_model() {
        let argv: Vec<String> =
            ["sweep", "zoo:mlp", "--npus", "8", "--batch", "4"].iter().map(|s| s.to_string()).collect();
        run(&argv).unwrap();
    }

    #[test]
    fn network_flag_takes_a_spec_or_a_json_file() {
        // A compact spec string materializes directly…
        let a = args(&["--network", "ring:4x300g@700ns/switch:2x25g@5us+direct"]);
        let net = load_network(&a).unwrap();
        assert_eq!(net.dims.len(), 2);
        assert_eq!(net.dims[0].npus, 4);
        assert_eq!(net.dims[1].algo, crate::sim::CollectiveAlgo::Direct);
        // …while a JSON file on disk still loads (legacy dims form).
        let dir = std::env::temp_dir().join(format!("modtrans_netflag_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("net.json");
        std::fs::write(
            &path,
            r#"{"dims": [{"topology": "ring", "npus": 8, "bandwidth_gbps": 100, "latency_ns": 500}]}"#,
        )
        .unwrap();
        let a = args(&["--network", path.to_str().unwrap()]);
        let net = load_network(&a).unwrap();
        assert_eq!((net.dims.len(), net.dims[0].npus), (1, 8));
        let _ = std::fs::remove_dir_all(&dir);
        // Legacy --topology tokens are bare one-dimension specs.
        let a = args(&["--topology", "torus2d", "--npus", "16"]);
        assert_eq!(load_network(&a).unwrap().dims[0].kind, crate::sim::TopologyKind::Torus2D);
        // Malformed or inadmissible specs are typed errors, not panics.
        assert!(load_network(&args(&["--topology", "blimp"])).is_err());
        let err = load_network(&args(&["--topology", "torus2d+direct"])).unwrap_err();
        assert!(err.to_string().contains("not realizable"), "{err}");
    }

    #[test]
    fn sweep_topologies_accept_network_specs() {
        let a = args(&["mlp", "--topologies", "ring, ring:4x300g@700ns/switch:2x25g@5us+hd"]);
        let grid = parse_sweep_grid(&a).unwrap();
        assert_eq!(grid.networks.len(), 2);
        assert_eq!(grid.networks[0].label(), "ring");
        assert_eq!(grid.networks[1].label(), "ring:4x300g@700ns/switch:2x25g@5us+hd");
    }

    #[test]
    fn check_rejects_an_inadmissible_fabric_before_any_graph_work() {
        let argv: Vec<String> = ["check", "--network", "torus2d:16x100g@500ns+direct", "--quiet"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let err = run(&argv).unwrap_err();
        assert!(err.to_string().contains("not realizable"), "{err}");
    }

    #[test]
    fn chunk_count_beyond_router_maximum_is_rejected() {
        // The collective router expands chunks into a fixed stack buffer;
        // rather than silently clamping a CLI request, reject it.
        let a = args(&["--chunks", "65"]);
        let err = sim_config(&a).unwrap_err();
        assert!(err.to_string().contains("chunks"));
        let a = args(&["--chunks", "64"]);
        assert!(sim_config(&a).is_ok());
    }

    #[test]
    fn skip_infeasible_is_a_flag_not_an_option() {
        let a = args(&["mlp", "--skip-infeasible", "--npus", "8"]);
        assert!(a.flag("skip-infeasible"));
        assert_eq!(a.opt_parse("npus", 0usize).unwrap(), 8);
        // A sweep with pruning enabled still runs end to end.
        let argv: Vec<String> = ["sweep", "mlp", "--npus", "8", "--batch", "4", "--skip-infeasible"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        run(&argv).unwrap();
    }

    #[test]
    fn shard_spec_parses_and_validates() {
        assert_eq!(parse_shard(&args(&[])).unwrap(), None);
        assert_eq!(parse_shard(&args(&["--shard", "1/4"])).unwrap(), Some((1, 4)));
        assert_eq!(parse_shard(&args(&["--shard", "4/4"])).unwrap(), Some((4, 4)));
        for bad in ["0/4", "5/4", "1-4", "x/y", "1/", "/2", "1/0"] {
            assert!(parse_shard(&args(&["--shard", bad])).is_err(), "'{bad}' should fail");
        }
    }

    #[test]
    fn sharded_sweep_runs_and_merge_reconstructs_it() {
        let dir = std::env::temp_dir().join(format!("modtrans_shard_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = |name: &str| dir.join(name).to_str().unwrap().to_string();
        let run_args = |v: &[&str]| {
            let argv: Vec<String> = v.iter().map(|s| s.to_string()).collect();
            run(&argv).unwrap();
        };
        let base = ["sweep", "mlp", "--npus", "8", "--batch", "4", "--threads", "2"];
        let with = |extra: &[&str]| {
            let mut v: Vec<&str> = base.to_vec();
            v.extend_from_slice(extra);
            v
        };
        let (f, s1, s2, m) = (p("full.json"), p("s1.json"), p("s2.json"), p("merged.json"));
        run_args(&with(&["-o", &f]));
        run_args(&with(&["--shard", "1/2", "-o", &s1]));
        run_args(&with(&["--shard", "2/2", "-o", &s2]));
        run_args(&["sweep-merge", &s1, &s2, "-o", &m]);
        let full = crate::json::parse(&std::fs::read_to_string(&f).unwrap()).unwrap();
        let merged = crate::json::parse(&std::fs::read_to_string(&m).unwrap()).unwrap();
        assert_eq!(merged.get("ranked"), full.get("ranked"));
        // Overlapping shards must fail the merge.
        let overlap: Vec<String> = vec!["sweep-merge".into(), s1.clone(), s1.clone()];
        assert!(run(&overlap).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn translate_formats() {
        let dir = std::env::temp_dir().join(format!("modtrans_fmt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("mlp.et.json");
        let argv: Vec<String> = [
            "translate",
            "zoo:mlp",
            "--batch",
            "4",
            "--format",
            "et-json",
            "-o",
            out.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run(&argv).unwrap();
        let v = crate::json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str(), Some(crate::ir::emit::ET_JSON_SCHEMA));
        assert!(!v.get("nodes").unwrap().as_arr().unwrap().is_empty());
        // Unknown formats are usage errors.
        let bad: Vec<String> =
            vec!["translate".into(), "zoo:mlp".into(), "--format".into(), "yaml".into()];
        assert!(run(&bad).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn translate_from_et_json_replays_and_echoes_byte_identically() {
        let dir = std::env::temp_dir().join(format!("modtrans_etfrom_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = |name: &str| dir.join(name).to_str().unwrap().to_string();
        let run_args = |v: &[&str]| {
            let argv: Vec<String> = v.iter().map(|s| s.to_string()).collect();
            run(&argv)
        };
        let (trace, echo, text) = (p("mlp.et.json"), p("echo.et.json"), p("mlp.txt"));
        // Emit a trace, replay it back through --from et-json.
        run_args(&["translate", "zoo:mlp", "--batch", "4", "--format", "et-json", "-o", &trace])
            .unwrap();
        run_args(&["translate", &trace, "--from", "et-json", "--format", "et-json", "-o", &echo])
            .unwrap();
        assert_eq!(
            std::fs::read_to_string(&trace).unwrap(),
            std::fs::read_to_string(&echo).unwrap(),
            "et-json replay must re-emit byte-identically"
        );
        // The replayed trace also lowers to the text workload format.
        run_args(&["translate", &trace, "--from", "et-json", "-o", &text]).unwrap();
        let w = Workload::parse(&std::fs::read_to_string(&text).unwrap()).unwrap();
        assert!(!w.layers.is_empty());
        // Unknown sources are usage errors; garbage traces are rejected.
        assert!(run_args(&["translate", &trace, "--from", "carrier-pigeon"]).is_err());
        std::fs::write(&trace, "{}").unwrap();
        assert!(run_args(&["translate", &trace, "--from", "et-json"]).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sweep_cache_dir_second_run_is_load_only() {
        let dir = std::env::temp_dir().join(format!("modtrans_clicache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let p = |name: &str| dir.join(name).to_str().unwrap().to_string();
        let run_args = |v: &[&str]| {
            let argv: Vec<String> = v.iter().map(|s| s.to_string()).collect();
            run(&argv).unwrap();
        };
        let (cache, cold, warm) = (p("ircache"), p("cold.json"), p("warm.json"));
        let base = ["sweep", "mlp", "--npus", "8", "--batch", "4", "--cache-dir", &cache];
        let with = |out: &str| {
            let mut v: Vec<&str> = base.to_vec();
            v.extend_from_slice(&["-o", out]);
            v
        };
        run_args(&with(&cold));
        run_args(&with(&warm));
        let cold = crate::json::parse(&std::fs::read_to_string(&cold).unwrap()).unwrap();
        let warm = crate::json::parse(&std::fs::read_to_string(&warm).unwrap()).unwrap();
        assert_eq!(cold.get("translations").unwrap().as_u64(), Some(1));
        assert_eq!(cold.get("cache_loads").unwrap().as_u64(), Some(0));
        assert_eq!(warm.get("translations").unwrap().as_u64(), Some(0));
        assert_eq!(warm.get("cache_loads").unwrap().as_u64(), Some(1));
        assert_eq!(warm.get("ranked"), cold.get("ranked"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sweep_rejects_unknown_model_and_tokens() {
        let run_args = |v: &[&str]| {
            let argv: Vec<String> = v.iter().map(|s| s.to_string()).collect();
            run(&argv)
        };
        assert!(run_args(&["sweep", "zoo:nope"]).is_err());
        assert!(run_args(&["sweep", "mlp", "--topologies", "blimp"]).is_err());
        assert!(run_args(&["sweep", "mlp", "--collectives", "psychic"]).is_err());
        assert!(run_args(&["sweep", "mlp", "--parallelisms", "bogus"]).is_err());
        // Conflicting model specs and ONNX paths get clear usage errors.
        assert!(run_args(&["sweep", "mlp", "--models", "resnet18"]).is_err());
        assert!(run_args(&["sweep", "model.onnx"]).is_err());
    }

    #[test]
    fn sweep_accepts_json_out_as_an_output_alias() {
        let dir = std::env::temp_dir().join(format!("modtrans_jsonout_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("r.json");
        let argv: Vec<String> =
            ["sweep", "mlp", "--npus", "8", "--batch", "4", "--json-out", out.to_str().unwrap()]
                .iter()
                .map(|s| s.to_string())
                .collect();
        run(&argv).unwrap();
        let v = crate::json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert!(v.get("ranked").unwrap().as_arr().is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sweep_fleet_rejects_explicit_shards_and_zero_procs() {
        // Config errors must surface before any process spawns.
        let run_args = |v: &[&str]| {
            let argv: Vec<String> = v.iter().map(|s| s.to_string()).collect();
            run(&argv)
        };
        let err = run_args(&["sweep", "fleet", "mlp", "--shard", "1/2"]).unwrap_err();
        assert!(err.to_string().contains("assigns shards itself"), "{err}");
        let err = run_args(&["sweep", "fleet", "mlp", "--procs", "0"]).unwrap_err();
        assert!(err.to_string().contains("at least one worker process"), "{err}");
        let err = run_args(&["sweep", "fleet", "mlp", "--resume"]).unwrap_err();
        assert!(err.to_string().contains("--journal"), "{err}");
        let err = run_args(&["sweep", "fleet", "mlp", "--shard-timeout", "soonish"]).unwrap_err();
        assert!(err.to_string().contains("bad --shard-timeout"), "{err}");
        let err = run_args(&["sweep", "fleet", "mlp", "--lease", "many"]).unwrap_err();
        assert!(err.to_string().contains("bad --lease"), "{err}");
        // Unknown models fail during the in-process pre-warm pass.
        assert!(run_args(&["sweep", "fleet", "zoo:nope", "--procs", "2"]).is_err());
    }

    #[test]
    fn sweep_scenarios_lease_runs_and_echoes_indices() {
        let dir = std::env::temp_dir().join(format!("modtrans_clilease_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("lease.json");
        let argv: Vec<String> = [
            "sweep", "mlp", "--npus", "8", "--batch", "4", "--scenarios", "2,0", "-o",
            out.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        run(&argv).unwrap();
        let v = crate::json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        // The report echoes the lease as a sorted index list — the stamp
        // the orchestrator cross-checks before absorbing a worker report.
        let lease: Vec<u64> = v
            .get("lease")
            .and_then(|l| l.as_arr())
            .unwrap()
            .iter()
            .map(|i| i.as_u64().unwrap())
            .collect();
        assert_eq!(lease, vec![0, 2]);
        assert_eq!(v.get("ranked").unwrap().as_arr().unwrap().len(), 2);
        let run_args = |v: &[&str]| {
            let argv: Vec<String> = v.iter().map(|s| s.to_string()).collect();
            run(&argv)
        };
        let err = run_args(&["sweep", "mlp", "--scenarios", "zero"]).unwrap_err();
        assert!(err.to_string().contains("bad scenario index"), "{err}");
        // A lease and a modulo shard are competing partitions of the grid.
        assert!(run_args(&["sweep", "mlp", "--scenarios", "0", "--shard", "1/2"]).is_err());
        let err = run_args(&["sweep", "mlp", "--top-cutoff", "soon"]).unwrap_err();
        assert!(err.to_string().contains("bad --top-cutoff"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sweep_merge_names_the_unreadable_shard_file() {
        let argv: Vec<String> =
            vec!["sweep-merge".into(), "/no/such/shard-3.json".into()];
        let err = run(&argv).unwrap_err().to_string();
        assert!(err.contains("/no/such/shard-3.json"), "path missing from: {err}");
        assert!(err.contains("crashed shard"), "no diagnosis hint in: {err}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn calibrate_requires_pjrt_feature() {
        let argv: Vec<String> = vec!["calibrate".into()];
        let err = run(&argv).unwrap_err();
        assert!(err.to_string().contains("pjrt"));
    }
}
