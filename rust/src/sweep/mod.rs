//! Parallel scenario-sweep engine: run the whole design space in one go.
//!
//! ASTRA-sim's payoff is sweeping large (model × parallelism × topology ×
//! collective-algorithm) spaces, and ModTrans's payoff is that getting a
//! real model *into* the simulator is cheap enough to do at experiment
//! scale (the paper's cost-is-negligible claim, Fig. 6). This module puts
//! the two together:
//!
//! 1. [`SweepGrid::expand`] turns the per-axis lists into a deduplicated
//!    scenario list (deterministic order).
//! 2. [`cache::WorkloadCache`] translates **each model once** through the
//!    zoo-direct IR frontend — zoo build + layer extraction + the compute
//!    pass, the expensive model-shaped steps — and every scenario derives
//!    its workload from the shared compute-annotated
//!    [`crate::ir::ModelIR`] by re-running only the cheap
//!    parallelism-dependent comm pass (translation count == model count,
//!    never scenario count). Entries are keyed by the typed
//!    [`cache::CacheKey`] (model × batch × compute fingerprint), and with
//!    [`run_sweep_cached`]'s `--cache-dir` a second tier spills each IR
//!    to disk as et-json, so repeat sweeps (and sibling shards) load in
//!    O(1) instead of re-extracting at all.
//! 3. [`pool::run_indexed_with`] fans the simulations out over a
//!    `std::thread` worker pool fed by a channel-based work queue; each
//!    worker carries one [`ScenarioScratch`] (simulator arenas + the
//!    comm-plan and workload derivation buffers) across its scenarios,
//!    so steady-state derivation *and* simulation are allocation-free.
//!    At `threads > 1` the queue is fed longest-bound-first
//!    ([`pool::run_ordered_with`] over the descending
//!    [`bound::scenario_bound_ns`] order): the expensive scenarios start
//!    first, so no worker ends up running a straggler alone after the
//!    cheap tail drains. Dispatch order is a pure scheduling hint —
//!    results are keyed and re-sorted by scenario index, so the report
//!    bytes are identical to index-order dispatch.
//!    With `SweepConfig::top_k` set (`--top K`), a branch-and-bound
//!    layer runs first: [`bound::scenario_bound_ns`] computes an
//!    admissible analytic makespan lower bound per scenario (no DES,
//!    memoized collective latencies across siblings). The bound pass is
//!    **parallel but deterministic**: it fans out through the same
//!    index-ordered pool as simulation, with one [`bound::BoundMemo`]
//!    per worker — the bound is a pure function of the scenario, so
//!    memo placement affects only cache hit rates, never values, and
//!    the bound vector matches a serial pass byte for byte at any
//!    thread count. Scenarios are then visited most-promising-first in
//!    deterministic waves, and any scenario whose bound exceeds the
//!    current K-th best simulated iteration time is skipped — provably
//!    without changing the reported top-K (CI diffs it against the
//!    exhaustive ranking).
//! 4. [`report::SweepReport`] ranks the results (fastest simulated step
//!    first, key-ordered tiebreak) and emits text + JSON. Because every
//!    scenario is simulated deterministically and ranking is a total
//!    order, the report is **byte-identical regardless of thread count**.
//! 5. [`fleet::run_fleet`] scales past one process with a work-stealing
//!    scheduler: it expands the grid once, pre-warms the shared disk
//!    cache with a single cold translation pass, orders the scenario
//!    queue longest-bounded-first, and hands out scenario-index *leases*
//!    (adaptively sized batches, run by child processes of the current
//!    binary via `--scenarios i,j,k`) to whichever worker slot is idle —
//!    so a skewed grid keeps every process busy instead of gating
//!    wall-clock on the slowest static shard. Completed leases are
//!    appended to a crash-durable [`journal`] (`--journal DIR`; a
//!    relaunch with `--resume` replays it and re-simulates nothing) and
//!    folded into a live [`report::StreamingMerge`] ranking as they
//!    arrive, whose K-th best under `--top K` becomes a fleet-wide prune
//!    cutoff pushed to later leases. Crashed workers relaunch under a
//!    bounded-retry policy, hung workers are killed by the
//!    `--shard-timeout` watchdog and their leases re-queued — one
//!    command, N workers, one cold translation, one merged ranking (the
//!    `sweep fleet` subcommand).
//!
//! ```no_run
//! use modtrans::sweep::{run_sweep, SweepConfig, SweepGrid};
//! let grid = SweepGrid::default();
//! let report = run_sweep(&grid, &SweepConfig::default()).unwrap();
//! print!("{}", report.render_text());
//! ```

pub mod bound;
pub mod cache;
pub mod fleet;
pub mod journal;
pub mod pool;
pub mod report;

pub use bound::{scenario_bound_ns, BoundMemo};
pub use cache::{verify_envelope_file, CacheKey, WorkloadCache};
pub use fleet::{run_fleet, FleetOpts, FleetReport};
pub use journal::Journal;
pub use report::{ScenarioResult, ShardStatus, StreamingMerge, SweepReport};

use crate::error::{Error, Result};
use crate::ir::{emit, passes};
use crate::json::{obj, Value};
use crate::sim::{
    simulate_with, ChunkCfg, NetworkSpec, PipelineSchedule, Policy, SimConfig, SimScratch,
    SystemConfig, TopologyKind,
};
use crate::translator::{CommPlan, MemoryOpts, TranslateOpts, ZeroStage};
use crate::workload::{Parallelism, Workload};
use std::collections::BTreeSet;

/// Communication *schedule* for a scenario — the system-layer knobs
/// (chunked hierarchical pipelining + queue discipline) that ASTRA-sim
/// exposes as its collective scheduler configuration. This is orthogonal
/// to the per-dimension collective *algorithm*
/// ([`crate::sim::CollectiveAlgo`], carried by the [`NetworkSpec`] axis):
/// the algorithm prices one collective on one fabric dimension, the
/// schedule decides how chunks of a hierarchical collective overlap
/// across dimensions and in what order queued work drains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommSchedule {
    /// Single-shot collectives (no chunk pipelining), FIFO queues.
    Direct,
    /// Chunk-pipelined hierarchical collectives (4 chunks), FIFO queues.
    Pipelined,
    /// Chunk-pipelined collectives with LIFO communication scheduling
    /// (the paper §2.2's alternative policy).
    PipelinedLifo,
}

/// Deprecated alias for [`CommSchedule`] — the old name collided with
/// the per-dimension [`crate::sim::CollectiveAlgo`] once the N-dim
/// redesign made the actual collective algorithm an explicit axis.
pub type CollectiveAlgo = CommSchedule;

impl CommSchedule {
    /// Canonical config token.
    pub fn token(self) -> &'static str {
        match self {
            CommSchedule::Direct => "direct",
            CommSchedule::Pipelined => "pipelined",
            CommSchedule::PipelinedLifo => "pipelined-lifo",
        }
    }

    /// Parse a config token.
    pub fn from_token(s: &str) -> Result<CommSchedule> {
        Ok(match s {
            "direct" => CommSchedule::Direct,
            "pipelined" => CommSchedule::Pipelined,
            "pipelined-lifo" | "lifo" => CommSchedule::PipelinedLifo,
            other => {
                return Err(Error::Config(format!("unknown collective schedule '{other}'")))
            }
        })
    }

    /// The system-layer configuration this schedule corresponds to.
    pub fn system(self) -> SystemConfig {
        match self {
            CommSchedule::Direct => {
                SystemConfig { scheduling: Policy::Fifo, chunks: ChunkCfg { chunks: 1 } }
            }
            CommSchedule::Pipelined => {
                SystemConfig { scheduling: Policy::Fifo, chunks: ChunkCfg { chunks: 4 } }
            }
            CommSchedule::PipelinedLifo => {
                SystemConfig { scheduling: Policy::Lifo, chunks: ChunkCfg { chunks: 4 } }
            }
        }
    }
}

/// One point of the design space.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Scenario {
    /// Zoo model name.
    pub model: String,
    /// Parallelization strategy.
    pub parallelism: Parallelism,
    /// Network shape: an N-dimension [`NetworkSpec`], possibly with
    /// per-dimension collective algorithms. Bare single-kind specs (the
    /// pre-redesign topology tokens) materialize to a single-dimension
    /// fabric of `SweepConfig::npus`.
    pub network: NetworkSpec,
    /// Communication schedule (chunking + queue discipline).
    pub collective: CommSchedule,
}

impl Scenario {
    /// Stable identity string — used for dedup and as the deterministic
    /// ranking tiebreak.
    pub fn key(&self) -> String {
        format!(
            "{}/{}/{}/{}",
            self.model,
            self.parallelism.token(),
            self.network.label(),
            self.collective.token()
        )
    }

    /// Borrowed component-wise ranking key — the allocation-free total
    /// order every sort tiebreak uses (`run_sweep` and
    /// [`SweepReport::merge`] alike, so shard merges re-rank exactly like
    /// the unsharded run). Note this is component-wise order, which can
    /// differ from the joined [`Scenario::key`] string's order when one
    /// model name is a prefix of another (e.g. a future `gpt2` next to
    /// `gpt2-small`): `key()` is for identity/dedup, never for ordering.
    /// The network component is the canonical spec label, which for bare
    /// legacy specs equals the old topology token — pre-redesign
    /// rankings order identically.
    pub fn rank_key(&self) -> (&str, &'static str, &str, &'static str) {
        (
            self.model.as_str(),
            self.parallelism.token(),
            self.network.label(),
            self.collective.token(),
        )
    }
}

/// The sweep axes. The cartesian product of the four lists (after dedup)
/// is the scenario set.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    /// Zoo model names.
    pub models: Vec<String>,
    /// Parallelism strategies.
    pub parallelisms: Vec<Parallelism>,
    /// Network specs (each a full N-dim topology × per-dim algorithm
    /// choice — the co-design axis).
    pub networks: Vec<NetworkSpec>,
    /// Communication schedules.
    pub collectives: Vec<CommSchedule>,
}

impl Default for SweepGrid {
    /// The CLI's default grid: 2 models × 3 strategies × 3 networks —
    /// 18 scenarios sharing 2 translations.
    fn default() -> Self {
        SweepGrid {
            models: vec!["mlp".into(), "resnet18".into()],
            parallelisms: vec![
                Parallelism::Data,
                Parallelism::Model,
                Parallelism::HybridDataModel,
            ],
            networks: vec![
                NetworkSpec::from_kind(TopologyKind::Ring),
                NetworkSpec::from_kind(TopologyKind::FullyConnected),
                NetworkSpec::from_kind(TopologyKind::Switch),
            ],
            collectives: vec![CommSchedule::Pipelined],
        }
    }
}

impl SweepGrid {
    /// Expand to the deduplicated scenario list, in deterministic
    /// (models-major) order.
    pub fn expand(&self) -> Vec<Scenario> {
        let mut seen = BTreeSet::new();
        let mut out = Vec::new();
        for m in &self.models {
            for &p in &self.parallelisms {
                for t in &self.networks {
                    for &c in &self.collectives {
                        let sc = Scenario {
                            model: m.clone(),
                            parallelism: p,
                            network: t.clone(),
                            collective: c,
                        };
                        if seen.insert(sc.key()) {
                            out.push(sc);
                        }
                    }
                }
            }
        }
        out
    }

    /// Unique model names, first-appearance order.
    pub fn unique_models(&self) -> Vec<String> {
        let mut seen = BTreeSet::new();
        self.models.iter().filter(|m| seen.insert(m.as_str())).cloned().collect()
    }
}

/// Fixed (non-axis) sweep parameters.
#[derive(Debug, Clone, Copy)]
pub struct SweepConfig {
    /// NPUs per scenario (sizes both translation groups and the fabric).
    pub npus: usize,
    /// Model-parallel group size / pipeline stage count.
    pub mp_group: usize,
    /// Batch size used for extraction and compute modeling.
    pub batch: i64,
    /// Training iterations to simulate per scenario.
    pub iterations: usize,
    /// Worker threads in the simulation pool (clamped to ≥ 1).
    pub threads: usize,
    /// Per-link bandwidth in GB/s for the swept fabrics.
    pub bandwidth_gbps: f64,
    /// Per-hop latency in ns.
    pub latency_ns: f64,
    /// HBM capacity per NPU for the feasibility column.
    pub hbm_bytes: u64,
    /// ZeRO sharding stage on the data-parallel axis.
    pub zero: ZeroStage,
    /// Prune scenarios whose modeled `memory_per_npu` exceeds HBM before
    /// they reach the worker pool (the memory check is a cheap analytic
    /// pass over the cached summary — no simulation).
    pub skip_infeasible: bool,
    /// Run only shard `K` of `N` (`Some((k, n))`, 1-based): keep every
    /// scenario whose index in the deterministic [`SweepGrid::expand`]
    /// order satisfies `i % n == k - 1`. The N shard reports partition
    /// the full scenario set and merge back losslessly with
    /// [`SweepReport::merge`] / the `sweep-merge` subcommand.
    pub shard: Option<(usize, usize)>,
    /// Exact top-K mode (`--top K`): rank only the K fastest scenarios,
    /// skipping full simulation for any scenario whose analytic lower
    /// bound ([`bound::scenario_bound_ns`]) exceeds the current K-th
    /// best simulated iteration time. The reported top-K is
    /// byte-identical to the exhaustive ranking's first K rows — the
    /// bound is admissible, so pruning never changes the answer, only
    /// how much of the grid is simulated. Sharded runs prune against
    /// their local top-K (a weaker threshold, still exact) and
    /// [`SweepReport::merge`] re-ranks and truncates the union. Part of
    /// the config fingerprint: pruned and exhaustive reports must never
    /// merge, since a pruned shard does not cover its scenario range.
    pub top_k: Option<usize>,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            npus: 16,
            mp_group: 4,
            batch: 32,
            iterations: 2,
            threads: 4,
            bandwidth_gbps: 100.0,
            latency_ns: 500.0,
            hbm_bytes: 32 << 30,
            zero: ZeroStage::None,
            skip_infeasible: false,
            shard: None,
            top_k: None,
        }
    }
}

impl SweepConfig {
    /// The scenario-shaping subset of this config, as deterministic
    /// JSON. Worker-level knobs that must never affect results —
    /// `threads` and `shard` — are excluded, so every shard of one sweep
    /// (and every thread count) shares one fingerprint.
    /// [`SweepReport::merge`] refuses to combine reports whose
    /// fingerprints differ: a cross-config ranking would compare
    /// iteration times measured on different hardware as if they were
    /// one design space.
    pub fn fingerprint(&self) -> Value {
        let zero = match self.zero {
            ZeroStage::None => 0.0,
            ZeroStage::OptimizerState => 1.0,
            ZeroStage::Gradients => 2.0,
            ZeroStage::Parameters => 3.0,
        };
        obj(vec![
            ("npus", Value::Num(self.npus as f64)),
            ("mp_group", Value::Num(self.mp_group as f64)),
            ("batch", Value::Num(self.batch as f64)),
            ("iterations", Value::Num(self.iterations as f64)),
            ("bandwidth_gbps", Value::Num(self.bandwidth_gbps)),
            ("latency_ns", Value::Num(self.latency_ns)),
            ("hbm_bytes", Value::Num(self.hbm_bytes as f64)),
            ("zero", Value::Num(zero)),
            ("skip_infeasible", Value::Bool(self.skip_infeasible)),
            // Prune mode is result-shaping: a pruned report ranks only K
            // scenarios, so it must never merge with exhaustive shards.
            ("top_k", self.top_k.map_or(Value::Null, |k| Value::Num(k as f64))),
            // Network-axis grammar version. Bumped by the N-dim co-design
            // redesign (topology tokens → NetworkSpec labels): a report
            // written before the bump must never merge with one written
            // after, even when every label happens to coincide.
            ("net_grammar", Value::Num(2.0)),
        ])
    }
}

/// True when `(k, n)` is a valid 1-based shard-of-N spec.
fn shard_valid(k: usize, n: usize) -> bool {
    k >= 1 && n >= 1 && k <= n
}

/// Parse and validate a `K/N` shard spec (`1 <= K <= N`, whitespace
/// around the numbers tolerated). Returns `None` on any malformed input
/// — callers attach their own error context. This is the single parser
/// behind the CLI `--shard` flag and the report `"shard"` field.
pub fn parse_shard_spec(spec: &str) -> Option<(usize, usize)> {
    let (k, n) = spec.split_once('/')?;
    let k: usize = k.trim().parse().ok()?;
    let n: usize = n.trim().parse().ok()?;
    shard_valid(k, n).then_some((k, n))
}

/// Order-sensitive FNV-1a digest of the expanded scenario keys — the
/// grid identity stamped into reports so [`SweepReport::merge`] can
/// refuse shards of *different* grids that happen to share a scenario
/// count and config.
pub(crate) fn grid_digest(scenarios: &[Scenario]) -> String {
    let mut h = crate::util::FNV1A_OFFSET;
    for sc in scenarios {
        h = crate::util::fnv1a_extend(h, sc.model.as_bytes());
        h = crate::util::fnv1a_extend(h, b"/");
        h = crate::util::fnv1a_extend(h, sc.parallelism.token().as_bytes());
        h = crate::util::fnv1a_extend(h, b"/");
        h = crate::util::fnv1a_extend(h, sc.network.label().as_bytes());
        h = crate::util::fnv1a_extend(h, b"/");
        h = crate::util::fnv1a_extend(h, sc.collective.token().as_bytes());
        h = crate::util::fnv1a_extend(h, b"\n");
    }
    format!("{h:016x}")
}

/// Translation options for a scenario (shared by simulation and the
/// memory model so the feasibility check and the report always agree).
fn scenario_opts(sc: &Scenario, cfg: &SweepConfig) -> TranslateOpts {
    TranslateOpts {
        parallelism: sc.parallelism,
        npus: cfg.npus,
        mp_group: cfg.mp_group,
        batch: cfg.batch,
        zero: cfg.zero,
    }
}

/// The pipeline-shaping simulator parameters every sweep scenario uses:
/// `(stages, microbatches, boundary_bytes)`. One function feeds both
/// [`run_scenario`]'s `SimConfig` and the analytic bound pass
/// ([`bound`]) — if the two drifted apart the bound would describe a
/// different pipeline than the one simulated, silently breaking
/// admissibility.
fn scenario_pipeline_shape(
    summary: &crate::translator::ModelSummary,
    cfg: &SweepConfig,
) -> (usize, usize, u64) {
    let boundary = summary.layers.iter().map(|l| l.out_act_bytes).max().unwrap_or(1 << 20);
    (cfg.mp_group.max(1), 8, boundary)
}

/// Per-worker scratch: the simulator arenas plus the workload-derivation
/// buffers (comm plan + emitted workload), all reused across that
/// worker's scenarios so steady-state derivation and simulation perform
/// no heap allocation.
#[derive(Debug, Default)]
pub struct ScenarioScratch {
    sim: SimScratch,
    comms: Vec<CommPlan>,
    workload: Workload,
}

impl ScenarioScratch {
    /// Fresh, empty scratch (buffers grow on first use).
    pub fn new() -> ScenarioScratch {
        ScenarioScratch::default()
    }
}

/// Simulate one scenario against the shared cache, reusing the worker's
/// scratch. Only the parallelism-dependent comm pass and the
/// allocation-free workload emission run per scenario — the structural
/// extraction and the compute pass live in the cached IR. Pure with
/// respect to its inputs: the result depends only on `(sc, cache, cfg)`
/// — never on the scratch's prior contents — which is what makes the
/// ranked report independent of worker count and scheduling order.
fn run_scenario(
    sc: &Scenario,
    cache: &WorkloadCache,
    cfg: &SweepConfig,
    scratch: &mut ScenarioScratch,
) -> Result<ScenarioResult> {
    let ir = cache.ir(&sc.model).ok_or_else(|| {
        Error::Config(format!("model '{}' missing from the workload cache", sc.model))
    })?;
    let opts = scenario_opts(sc, cfg);
    passes::plan_comm_into(ir, opts, &mut scratch.comms);
    emit::workload_into(ir, &scratch.comms, opts.parallelism, &mut scratch.workload)?;
    let (stages, microbatches, boundary_bytes) = scenario_pipeline_shape(ir.summary(), cfg);
    let sim_cfg = SimConfig {
        // Unspecified dimension fields take the sweep-wide defaults, so a
        // bare legacy spec materializes to exactly the old
        // `Network::single` fabric. Admissibility (and the torus
        // factorability check) is enforced here per scenario.
        network: sc.network.materialize(cfg.npus, cfg.bandwidth_gbps, cfg.latency_ns)?,
        system: sc.collective.system(),
        iterations: cfg.iterations,
        stages,
        microbatches,
        boundary_bytes,
        schedule: PipelineSchedule::GPipe,
    };
    let r = simulate_with(&scratch.workload, &sim_cfg, &mut scratch.sim)?;
    let mem = passes::memory(ir, opts, MemoryOpts { hbm_bytes: cfg.hbm_bytes, ..Default::default() });
    Ok(ScenarioResult {
        scenario: sc.clone(),
        iteration_ns: r.iteration_ns,
        total_ns: r.total_ns,
        compute_busy_ns: r.compute_busy_ns.iter().copied().max().unwrap_or(0),
        net_busy_ns: r.net_busy_ns.iter().sum(),
        exposed_ns: r.exposed_ns,
        compute_utilization: r.compute_utilization,
        events: r.events,
        mem_per_npu_bytes: mem.total(),
        fits_hbm: mem.fits(cfg.hbm_bytes),
        bound_ns: 0,
    })
}

/// Build the sweep's shared per-model IR cache exactly as
/// [`run_sweep_cached`] does — the same compute model
/// ([`crate::compute::SystolicCompute`] at the sweep batch), hence the
/// same typed [`CacheKey`]s. The fleet's pre-warm pass goes through this
/// one function so the entries it spills are the entries every shard
/// process will look up: a drifted compute model here would silently
/// turn every shard cold again. Public for external warm-up tooling
/// (e.g. priming a cache directory before rsyncing it to a fleet).
pub fn build_sweep_cache(
    models: &[String],
    cfg: &SweepConfig,
    cache_dir: Option<&std::path::Path>,
) -> Result<WorkloadCache> {
    let compute = crate::compute::SystolicCompute::new(cfg.batch);
    WorkloadCache::build_with(models, cfg.batch, &compute, cache_dir)
}

/// Run the full sweep: expand, optionally keep only this worker's shard,
/// translate-once-per-model into the shared IR cache, optionally prune
/// infeasible scenarios, simulate across the worker pool (one reusable
/// [`ScenarioScratch`] per worker), rank. In-memory cache only; see
/// [`run_sweep_cached`] for the persistent disk tier.
pub fn run_sweep(grid: &SweepGrid, cfg: &SweepConfig) -> Result<SweepReport> {
    run_sweep_cached(grid, cfg, None)
}

/// [`run_sweep`] with an optional persistent IR-cache directory (the CLI
/// `sweep --cache-dir DIR`). When given, each model's compute-annotated
/// IR is loaded from disk if a valid entry exists — a warm run performs
/// **zero** translations — and spilled there after extraction otherwise.
/// The directory never shapes results, only where the IRs come from:
/// warm and cold runs rank byte-identically (asserted in tests and CI),
/// so like `threads`/`shard` it stays outside the config fingerprint.
pub fn run_sweep_cached(
    grid: &SweepGrid,
    cfg: &SweepConfig,
    cache_dir: Option<&std::path::Path>,
) -> Result<SweepReport> {
    run_sweep_scenarios(grid, cfg, cache_dir, None, None)
}

/// [`run_sweep_cached`] generalized to the fleet's lease protocol: an
/// optional explicit scenario-index subset (`lease`, indices into the
/// full grid's deduplicated [`SweepGrid::expand`] order — the CLI
/// `sweep --scenarios i,j,k`) and an optional fleet-wide top-K prune
/// cutoff (`cutoff_ns`, the CLI `sweep --top-cutoff NS`).
///
/// A leased run keeps exactly the named scenarios (in expand order,
/// whatever order the indices arrive in) and stamps the sorted index
/// list into the report's `lease` field so the orchestrator can verify
/// the report against the lease it dispatched. Leases and modulo shards
/// are mutually exclusive — they are two different partition protocols.
///
/// The cutoff is the fleet-wide K-th best simulated iteration time at
/// dispatch: any scenario whose admissible analytic bound *strictly*
/// exceeds it provably cannot enter the fleet's final top-K (its
/// simulated time is at least its bound), so it is skipped even before
/// the local candidate set fills. The cutoff only ever skips provable
/// losers — the merged fleet top-K stays byte-identical — but it is
/// timing-dependent, so it deliberately lives outside the config
/// fingerprint and per-lease simulated/pruned counts may vary run to
/// run (their sum never does). Ignored when `top_k` is unset.
pub fn run_sweep_scenarios(
    grid: &SweepGrid,
    cfg: &SweepConfig,
    cache_dir: Option<&std::path::Path>,
    lease: Option<&[usize]>,
    cutoff_ns: Option<u64>,
) -> Result<SweepReport> {
    let mut scenarios = grid.expand();
    if scenarios.is_empty() {
        return Err(Error::Config(
            "sweep grid is empty — every axis needs at least one entry".into(),
        ));
    }
    let grid_scenarios = scenarios.len();
    let grid = grid_digest(&scenarios);
    let mut lease_sorted: Option<Vec<usize>> = None;
    if let Some(indices) = lease {
        if cfg.shard.is_some() {
            return Err(Error::Config(
                "a scenario lease and a modulo shard are two different partition \
                 protocols — drop one of --scenarios / --shard"
                    .into(),
            ));
        }
        let mut sorted = indices.to_vec();
        sorted.sort_unstable();
        if sorted.windows(2).any(|w| w[0] == w[1]) {
            return Err(Error::Config(
                "scenario lease repeats an index — each grid scenario can be \
                 leased at most once"
                    .into(),
            ));
        }
        if let Some(&bad) = sorted.iter().find(|&&i| i >= grid_scenarios) {
            return Err(Error::Config(format!(
                "scenario lease index {bad} is out of range for the \
                 {grid_scenarios}-scenario grid"
            )));
        }
        // Keep exactly the leased scenarios, in expand order.
        let keep: BTreeSet<usize> = sorted.iter().copied().collect();
        let mut idx = 0usize;
        scenarios.retain(|_| {
            let k = keep.contains(&idx);
            idx += 1;
            k
        });
        lease_sorted = Some(sorted);
    }
    if let Some((k, n)) = cfg.shard {
        if !shard_valid(k, n) {
            return Err(Error::Config(format!("invalid shard {k}/{n} — need 1 <= K <= N")));
        }
        // Modulo filter over the deterministic expand order: the N
        // shards partition the full scenario set.
        let mut idx = 0usize;
        scenarios.retain(|_| {
            let keep = idx % n == k - 1;
            idx += 1;
            keep
        });
    }
    // Only the models this (possibly sharded) scenario list actually
    // needs are translated, in first-appearance order.
    let models: Vec<String> = {
        let mut seen = BTreeSet::new();
        scenarios
            .iter()
            .filter(|sc| seen.insert(sc.model.as_str()))
            .map(|sc| sc.model.clone())
            .collect()
    };
    let cache = build_sweep_cache(&models, cfg, cache_dir)?;
    let mut pruned = 0usize;
    if cfg.skip_infeasible {
        // Fast path: the memory pass is a cheap analytic read of the
        // cached IR, so infeasible scenarios never reach the pool.
        let before = scenarios.len();
        scenarios.retain(|sc| match cache.ir(&sc.model) {
            Some(ir) => {
                let opts = scenario_opts(sc, cfg);
                let m = MemoryOpts { hbm_bytes: cfg.hbm_bytes, ..Default::default() };
                passes::memory(ir, opts, m).fits(cfg.hbm_bytes)
            }
            // Unknown models are kept so the pool surfaces the error.
            None => true,
        });
        pruned = before - scenarios.len();
    }
    let threads = cfg.threads;
    let (ranked, scenarios_pruned, bounds_evaluated) = match cfg.top_k {
        None => {
            // Longest-processing-time dispatch: feed the queue in
            // descending analytic-bound order so no worker is left
            // finishing a straggler alone. Pure scheduling — results
            // come back index-keyed and are re-ranked below, so the
            // report bytes cannot depend on the order (and a bound
            // failure just falls back to index order rather than
            // failing a sweep that never needed bounds).
            let run =
                |s: &mut ScenarioScratch, i: usize| run_scenario(&scenarios[i], &cache, cfg, s);
            let mut ranked = match lpt_order(&scenarios, &cache, cfg) {
                Some(order) => pool::run_ordered_with(&order, threads, ScenarioScratch::new, run)?,
                None => {
                    pool::run_indexed_with(scenarios.len(), threads, ScenarioScratch::new, run)?
                }
            };
            ranked.sort_by(ScenarioResult::rank_cmp);
            (ranked, 0, 0)
        }
        Some(k) => run_top_k(&scenarios, &cache, cfg, k, cutoff_ns)?,
    };
    Ok(SweepReport {
        models: models.len(),
        translations: cache.translations(),
        cache_loads: cache.disk_loads(),
        pruned,
        scenarios_simulated: scenarios.len() - scenarios_pruned,
        scenarios_pruned,
        bounds_evaluated,
        config: cfg.fingerprint(),
        grid_scenarios,
        grid_digest: grid,
        shard: cfg.shard,
        lease: lease_sorted,
        ranked,
    })
}

/// The exhaustive path's longest-processing-time dispatch order:
/// descending [`bound::scenario_bound_ns`] (ascending-index tiebreak),
/// or `None` to use plain index order — when one thread makes ordering
/// moot, when the grid is too small to have a tail, or when the bound
/// pass fails (the exhaustive sweep never *needs* bounds, so a bound
/// error must not fail it). These ordering bounds are a scheduling hint
/// only: they are deliberately not counted in `bounds_evaluated`, which
/// reports the top-K triage pass — exhaustive reports keep the counter
/// at 0, byte-identical to pre-LPT output.
fn lpt_order(
    scenarios: &[Scenario],
    cache: &WorkloadCache,
    cfg: &SweepConfig,
) -> Option<Vec<usize>> {
    if cfg.threads <= 1 || scenarios.len() <= 2 {
        return None;
    }
    let bounds = pool::run_indexed_with(
        scenarios.len(),
        cfg.threads,
        bound::BoundMemo::new,
        |memo, i| bound::scenario_bound_ns(&scenarios[i], cache, cfg, memo),
    )
    .ok()?;
    let mut order: Vec<usize> = (0..scenarios.len()).collect();
    order.sort_by(|&a, &b| bounds[b].cmp(&bounds[a]).then(a.cmp(&b)));
    Some(order)
}

/// The exact top-K branch-and-bound driver. Bounds every scenario
/// analytically — fanned out through the same index-ordered worker pool
/// as simulation, each worker memoizing into its own
/// [`bound::BoundMemo`]; the bound is a pure function of the scenario,
/// so per-worker memos only change *which* worker pays each cache miss,
/// never a bound's value, and the bound vector stays byte-identical to
/// a serial pass at any thread count — then simulates in deterministic
/// *waves* ordered most-promising-first:
/// the first wave fills the top-K candidate set, and each later wave is
/// the maximal prefix of remaining scenarios whose bound does not
/// exceed the current K-th best simulated iteration time. When that
/// prefix is empty, every remaining scenario's bound proves it cannot
/// enter the top-K, and all of them are skipped at once.
///
/// Wave boundaries are a pure function of the (deterministic) bounds
/// and the (deterministic) simulation results, and each wave fans out
/// through the same index-ordered pool as the exhaustive path — so the
/// returned ranking and counters are thread-count independent, and the
/// ranking is byte-identical to the exhaustive ranking's first K rows.
///
/// `cutoff_ns` (the fleet-wide K-th best at dispatch, see
/// [`run_sweep_scenarios`]) caps the prune threshold from the start:
/// scenarios whose bound strictly exceeds it are skipped even while the
/// local candidate set is still filling, because the fleet already
/// holds K results at least that good.
///
/// Returns `(ranked top-K, scenarios pruned, bounds evaluated)`.
fn run_top_k(
    scenarios: &[Scenario],
    cache: &WorkloadCache,
    cfg: &SweepConfig,
    k: usize,
    cutoff_ns: Option<u64>,
) -> Result<(Vec<ScenarioResult>, usize, usize)> {
    if k == 0 {
        return Err(Error::Config("top-K pruning needs K >= 1 (got --top 0)".into()));
    }
    let cutoff = cutoff_ns.unwrap_or(u64::MAX);
    // Parallel bound pass: pure per scenario, so per-worker memos keep
    // the result exactly deterministic (see the doc comment above).
    let bounds = pool::run_indexed_with(
        scenarios.len(),
        cfg.threads,
        bound::BoundMemo::new,
        |memo, i| bound::scenario_bound_ns(&scenarios[i], cache, cfg, memo),
    )?;
    // Most-promising-first visit order, rank-key tiebreak — fully
    // deterministic, like everything else the wave boundaries read.
    let mut order: Vec<usize> = (0..scenarios.len()).collect();
    order.sort_by(|&a, &b| {
        bounds[a].cmp(&bounds[b]).then_with(|| scenarios[a].rank_key().cmp(&scenarios[b].rank_key()))
    });
    let mut results: Vec<ScenarioResult> = Vec::with_capacity(k.min(scenarios.len()));
    let mut pos = 0usize;
    while pos < order.len() {
        let wave_end = if results.len() < k {
            // Seed wave: fill the candidate set — but the fleet-wide
            // cutoff already proves scenarios bounded strictly above it
            // are global losers, so they never enter even the seed.
            let want = k - results.len();
            let mut end = pos;
            while end < order.len() && end - pos < want && bounds[order[end]] <= cutoff {
                end += 1;
            }
            end
        } else {
            // results is rank-sorted after every wave; the K-th best
            // simulated iteration time (capped by the fleet-wide
            // cutoff) is the prune threshold. Keep a scenario iff
            // bound <= threshold: an equal bound could still win the
            // rank-key tiebreak, so only a strictly larger bound is
            // safe to skip.
            let threshold = results[k - 1].iteration_ns.min(cutoff);
            let mut end = pos;
            while end < order.len() && bounds[order[end]] <= threshold {
                end += 1;
            }
            end
        };
        if wave_end == pos {
            break; // every remaining bound exceeds the threshold
        }
        let wave = &order[pos..wave_end];
        let wave_results =
            pool::run_indexed_with(wave.len(), cfg.threads, ScenarioScratch::new, |s, i| {
                run_scenario(&scenarios[wave[i]], cache, cfg, s)
            })?;
        for (j, mut r) in wave_results.into_iter().enumerate() {
            r.bound_ns = bounds[wave[j]];
            results.push(r);
        }
        results.sort_by(ScenarioResult::rank_cmp);
        pos = wave_end;
    }
    let skipped = order.len() - pos;
    results.truncate(k);
    Ok((results, skipped, scenarios.len()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_expansion_counts_and_dedups() {
        let grid = SweepGrid {
            models: vec!["mlp".into(), "mlp".into(), "resnet18".into()],
            parallelisms: vec![Parallelism::Data, Parallelism::Data, Parallelism::Model],
            networks: vec![NetworkSpec::from_kind(TopologyKind::Ring)],
            collectives: vec![CollectiveAlgo::Direct, CollectiveAlgo::Direct],
        };
        let scenarios = grid.expand();
        // Duplicates collapse: 2 models × 2 strategies × 1 × 1.
        assert_eq!(scenarios.len(), 4);
        assert_eq!(grid.unique_models(), vec!["mlp".to_string(), "resnet18".to_string()]);
        // Deterministic order: models-major.
        assert_eq!(scenarios[0].model, "mlp");
        assert_eq!(scenarios[3].model, "resnet18");
        // Keys are unique.
        let keys: BTreeSet<String> = scenarios.iter().map(Scenario::key).collect();
        assert_eq!(keys.len(), scenarios.len());
    }

    #[test]
    fn collective_algo_tokens_roundtrip() {
        for algo in [
            CollectiveAlgo::Direct,
            CollectiveAlgo::Pipelined,
            CollectiveAlgo::PipelinedLifo,
        ] {
            assert_eq!(CollectiveAlgo::from_token(algo.token()).unwrap(), algo);
        }
        assert!(CollectiveAlgo::from_token("bogus").is_err());
    }

    #[test]
    fn collective_algo_maps_to_system_config() {
        assert_eq!(CollectiveAlgo::Direct.system().chunks.chunks, 1);
        assert_eq!(CollectiveAlgo::Pipelined.system().chunks.chunks, 4);
        assert_eq!(CollectiveAlgo::PipelinedLifo.system().scheduling, Policy::Lifo);
    }

    #[test]
    fn empty_grid_is_config_error() {
        let grid = SweepGrid { models: vec![], ..Default::default() };
        assert!(run_sweep(&grid, &SweepConfig::default()).is_err());
    }

    #[test]
    fn unknown_model_is_reported() {
        let grid = SweepGrid { models: vec!["made-up".into()], ..Default::default() };
        assert!(run_sweep(&grid, &SweepConfig::default()).is_err());
    }

    #[test]
    fn skip_infeasible_prunes_before_the_pool() {
        let grid = SweepGrid {
            models: vec!["mlp".into()],
            parallelisms: vec![Parallelism::Data, Parallelism::Model],
            networks: vec![NetworkSpec::from_kind(TopologyKind::Ring)],
            collectives: vec![CollectiveAlgo::Pipelined],
        };
        let base = SweepConfig { batch: 4, npus: 8, ..Default::default() };
        // Tiny HBM: nothing fits, everything is pruned pre-pool.
        let tiny = SweepConfig { hbm_bytes: 1, skip_infeasible: true, ..base };
        let r = run_sweep(&grid, &tiny).unwrap();
        assert_eq!(r.pruned, 2);
        assert!(r.ranked.is_empty());
        // Same config without pruning simulates everything, flags misfits.
        let keep = SweepConfig { hbm_bytes: 1, skip_infeasible: false, ..base };
        let r = run_sweep(&grid, &keep).unwrap();
        assert_eq!(r.pruned, 0);
        assert_eq!(r.ranked.len(), 2);
        assert!(r.ranked.iter().all(|x| !x.fits_hbm));
        // Ample HBM: pruning is a no-op.
        let ample = SweepConfig { skip_infeasible: true, ..base };
        let r = run_sweep(&grid, &ample).unwrap();
        assert_eq!(r.pruned, 0);
        assert_eq!(r.ranked.len(), 2);
        assert!(r.ranked.iter().all(|x| x.fits_hbm));
    }

    #[test]
    fn shards_partition_the_grid_and_merge_back_to_the_full_ranking() {
        let grid = SweepGrid {
            models: vec!["mlp".into(), "resnet18".into()],
            parallelisms: vec![Parallelism::Data, Parallelism::Model],
            networks: vec![
                NetworkSpec::from_kind(TopologyKind::Ring),
                NetworkSpec::from_kind(TopologyKind::Switch),
            ],
            collectives: vec![CollectiveAlgo::Pipelined],
        };
        let base = SweepConfig { batch: 4, npus: 8, threads: 2, ..Default::default() };
        let full = run_sweep(&grid, &base).unwrap();
        let s1 = run_sweep(&grid, &SweepConfig { shard: Some((1, 3)), ..base }).unwrap();
        let s2 = run_sweep(&grid, &SweepConfig { shard: Some((2, 3)), ..base }).unwrap();
        let s3 = run_sweep(&grid, &SweepConfig { shard: Some((3, 3)), ..base }).unwrap();
        assert_eq!(s1.ranked.len() + s2.ranked.len() + s3.ranked.len(), full.ranked.len());
        let merged = SweepReport::merge(&[s1, s2, s3]).unwrap();
        assert_eq!(merged.models, full.models);
        // The merged ranking is byte-identical to the unsharded run's.
        let ranked_of = |r: &SweepReport| r.to_json().get("ranked").cloned().unwrap();
        assert_eq!(ranked_of(&merged), ranked_of(&full));
    }

    #[test]
    fn shard_beyond_scenario_count_yields_an_empty_report() {
        let grid = SweepGrid {
            models: vec!["mlp".into()],
            parallelisms: vec![Parallelism::Data],
            networks: vec![NetworkSpec::from_kind(TopologyKind::Ring)],
            collectives: vec![CollectiveAlgo::Pipelined],
        };
        let cfg = SweepConfig { batch: 4, npus: 8, shard: Some((2, 2)), ..Default::default() };
        let r = run_sweep(&grid, &cfg).unwrap();
        assert!(r.ranked.is_empty());
        assert_eq!(r.translations, 0);
        assert_eq!(r.models, 0);
    }

    #[test]
    fn invalid_shards_are_config_errors() {
        let grid = SweepGrid::default();
        for shard in [(0, 2), (3, 2), (1, 0)] {
            let cfg = SweepConfig { shard: Some(shard), ..Default::default() };
            assert!(run_sweep(&grid, &cfg).is_err(), "shard {shard:?} should be rejected");
        }
    }

    #[test]
    fn small_sweep_ranks_deterministically() {
        let grid = SweepGrid {
            models: vec!["mlp".into()],
            parallelisms: vec![Parallelism::Data, Parallelism::Model],
            networks: vec![
                NetworkSpec::from_kind(TopologyKind::Ring),
                NetworkSpec::from_kind(TopologyKind::Switch),
            ],
            collectives: vec![CollectiveAlgo::Pipelined],
        };
        let cfg = SweepConfig { batch: 4, npus: 8, ..Default::default() };
        let a = run_sweep(&grid, &cfg).unwrap();
        assert_eq!(a.ranked.len(), 4);
        assert_eq!(a.translations, 1);
        assert!(a.ranked.windows(2).all(|w| w[0].iteration_ns <= w[1].iteration_ns));
        assert!(a.ranked.iter().all(|r| r.iteration_ns > 0 && r.events > 0));
        // Same grid, different thread counts: identical report.
        let b = run_sweep(&grid, &SweepConfig { threads: 1, ..cfg }).unwrap();
        assert_eq!(a.to_json().to_json_pretty(), b.to_json().to_json_pretty());
    }

    #[test]
    fn scenario_leases_partition_the_grid_and_stream_merge_back() {
        let grid = SweepGrid {
            models: vec!["mlp".into(), "resnet18".into()],
            parallelisms: vec![Parallelism::Data, Parallelism::Model],
            networks: vec![
                NetworkSpec::from_kind(TopologyKind::Ring),
                NetworkSpec::from_kind(TopologyKind::Switch),
            ],
            collectives: vec![CollectiveAlgo::Pipelined],
        };
        let cfg = SweepConfig { batch: 4, npus: 8, threads: 2, ..Default::default() };
        let full = run_sweep(&grid, &cfg).unwrap();
        assert_eq!(full.grid_scenarios, 8);
        // Three unequal leases covering the grid, dispatched out of
        // index order (the arrival order a stealing fleet produces).
        let leases: [&[usize]; 3] = [&[6, 1, 3], &[0, 7], &[2, 4, 5]];
        let mut m = StreamingMerge::new(cfg.fingerprint(), 8, full.grid_digest.clone());
        for lease in leases {
            let r = run_sweep_scenarios(&grid, &cfg, None, Some(lease), None).unwrap();
            assert_eq!(r.shard, None);
            // The echoed lease is index-sorted regardless of input order.
            let mut sorted = lease.to_vec();
            sorted.sort_unstable();
            assert_eq!(r.lease.as_deref(), Some(&sorted[..]));
            assert_eq!(r.scenarios_simulated, lease.len());
            m.absorb(&r, &sorted).unwrap();
        }
        let merged = m.finalize().unwrap();
        // The streamed lease merge is byte-identical to the monolithic
        // ranking.
        let ranked_of = |r: &SweepReport| r.to_json().get("ranked").cloned().unwrap();
        assert_eq!(ranked_of(&merged), ranked_of(&full));
    }

    #[test]
    fn scenario_leases_reject_bad_indices_and_shard_mixes() {
        let grid = SweepGrid {
            models: vec!["mlp".into()],
            parallelisms: vec![Parallelism::Data, Parallelism::Model],
            networks: vec![NetworkSpec::from_kind(TopologyKind::Ring)],
            collectives: vec![CollectiveAlgo::Pipelined],
        };
        let cfg = SweepConfig { batch: 4, npus: 8, ..Default::default() };
        let err = run_sweep_scenarios(&grid, &cfg, None, Some(&[0, 9]), None).unwrap_err();
        assert!(err.to_string().contains("out of range"), "got: {err}");
        let err = run_sweep_scenarios(&grid, &cfg, None, Some(&[1, 1]), None).unwrap_err();
        assert!(err.to_string().contains("repeats an index"), "got: {err}");
        let sharded = SweepConfig { shard: Some((1, 2)), ..cfg };
        let err = run_sweep_scenarios(&grid, &sharded, None, Some(&[0]), None).unwrap_err();
        assert!(err.to_string().contains("two different partition protocols"), "got: {err}");
    }

    #[test]
    fn top_k_cutoff_prunes_more_but_never_changes_the_answer() {
        let grid = SweepGrid {
            models: vec!["mlp".into(), "resnet18".into()],
            parallelisms: vec![Parallelism::Data, Parallelism::Model],
            networks: vec![
                NetworkSpec::from_kind(TopologyKind::Ring),
                NetworkSpec::from_kind(TopologyKind::Switch),
            ],
            collectives: vec![CollectiveAlgo::Pipelined],
        };
        let base = SweepConfig { batch: 4, npus: 8, threads: 2, ..Default::default() };
        let exhaustive = run_sweep(&grid, &base).unwrap();
        let top = SweepConfig { top_k: Some(2), ..base };
        let plain = run_sweep(&grid, &top).unwrap();
        // A sound cutoff: the true global K-th best (what a fleet merge
        // would know once K results are in).
        let cutoff = exhaustive.ranked[1].iteration_ns;
        let cut = run_sweep_scenarios(&grid, &top, None, None, Some(cutoff)).unwrap();
        let ranked_of = |r: &SweepReport| r.to_json().get("ranked").cloned().unwrap();
        assert_eq!(ranked_of(&cut), ranked_of(&plain));
        // The cut top-K is the exhaustive ranking's first K rows.
        assert_eq!(cut.ranked.len(), 2);
        for (c, e) in cut.ranked.iter().zip(exhaustive.ranked.iter()) {
            assert_eq!(c.scenario.key(), e.scenario.key());
            assert_eq!(c.iteration_ns, e.iteration_ns);
        }
        // The cutoff can only increase pruning, never reduce coverage.
        assert!(cut.scenarios_pruned >= plain.scenarios_pruned);
        assert_eq!(cut.scenarios_simulated + cut.scenarios_pruned, 8);
        assert_eq!(cut.bounds_evaluated, 8);
        // An absurdly tight cutoff still covers the grid (everything
        // bound-pruned, nothing ranked — the merge-side counters hold).
        let tight = run_sweep_scenarios(&grid, &top, None, None, Some(0)).unwrap();
        assert_eq!(tight.scenarios_simulated + tight.scenarios_pruned, 8);
    }
}
