//! Channel-fed worker pool over scoped `std::thread`s.
//!
//! All job indices are queued on an mpsc channel up front; workers pull
//! from the shared receiver (behind a mutex — the standard multi-consumer
//! pattern for `std::sync::mpsc`) and push `(index, result)` pairs back
//! on a results channel. Collected results are re-ordered by index, so
//! the output is independent of worker count and OS scheduling — the
//! property the sweep's determinism guarantee rests on.

use crate::error::{Error, Result};
use std::sync::mpsc;
use std::sync::Mutex;

/// Run `f(0..jobs)` across `threads` workers (clamped to ≥ 1), returning
/// the results in index order. If any job fails, the error with the
/// lowest job index is returned (every job still runs to completion, so
/// the choice of surfaced error is deterministic too).
pub fn run_indexed<T, F>(jobs: usize, threads: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    if jobs == 0 {
        return Ok(Vec::new());
    }
    let threads = threads.max(1).min(jobs);

    // Work queue: every index queued up front, sender dropped so workers
    // see Err(Disconnected) once the queue drains.
    let (job_tx, job_rx) = mpsc::channel::<usize>();
    for i in 0..jobs {
        let _ = job_tx.send(i);
    }
    drop(job_tx);
    let job_rx = Mutex::new(job_rx);

    let (res_tx, res_rx) = mpsc::channel::<(usize, Result<T>)>();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let res_tx = res_tx.clone();
            let job_rx = &job_rx;
            let f = &f;
            s.spawn(move || loop {
                // Hold the lock only while pulling the next index, never
                // while running the job.
                let next = { job_rx.lock().expect("job queue poisoned").recv() };
                let Ok(i) = next else { break };
                if res_tx.send((i, f(i))).is_err() {
                    break;
                }
            });
        }
        drop(res_tx); // workers hold the only remaining senders
    });

    let mut buf: Vec<(usize, Result<T>)> = res_rx.iter().collect();
    if buf.len() != jobs {
        return Err(Error::Sim(format!(
            "worker pool lost results: got {}/{} jobs back",
            buf.len(),
            jobs
        )));
    }
    buf.sort_by_key(|(i, _)| *i);
    buf.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_index_order() {
        for threads in [1usize, 2, 4, 9] {
            let out = run_indexed(50, threads, |i| Ok(i * i)).unwrap();
            assert_eq!(out.len(), 50);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * i, "threads={threads}");
            }
        }
    }

    #[test]
    fn zero_jobs_is_empty() {
        let out: Vec<usize> = run_indexed(0, 4, |i| Ok(i)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn thread_count_exceeding_jobs_is_fine() {
        let out = run_indexed(3, 64, |i| Ok(i + 1)).unwrap();
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn job_error_propagates() {
        let r: Result<Vec<usize>> = run_indexed(20, 4, |i| {
            if i == 13 {
                Err(Error::Sim("unlucky".into()))
            } else {
                Ok(i)
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn uneven_job_durations_still_order() {
        let out = run_indexed(16, 4, |i| {
            // Stagger work so completion order differs from index order.
            std::thread::sleep(std::time::Duration::from_millis(((16 - i) % 5) as u64));
            Ok(i)
        })
        .unwrap();
        assert_eq!(out, (0..16).collect::<Vec<_>>());
    }
}
