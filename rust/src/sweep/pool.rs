//! Channel-fed worker pool over scoped `std::thread`s.
//!
//! All job indices are queued on an mpsc channel up front; workers pull
//! from the shared receiver (behind a mutex — the standard multi-consumer
//! pattern for `std::sync::mpsc`) and push `(index, result)` pairs back
//! on a results channel. Collected results are re-ordered by index, so
//! the output is independent of worker count and OS scheduling — the
//! property the sweep's determinism guarantee rests on.
//!
//! [`run_indexed_with`] additionally gives every worker a private scratch
//! value built once at worker start and threaded through all of that
//! worker's jobs — the hook the sweep uses to carry a
//! [`crate::sim::SimScratch`] arena across scenarios so steady-state
//! iterations are allocation-free.
//!
//! [`run_ordered_with`] decouples *dispatch* order from *result* order:
//! the queue is fed a caller-chosen permutation (the sweep feeds
//! descending analytic cost — longest processing time first — to shave
//! the straggler tail at high thread counts) while results are still
//! keyed and returned by index, so the output bytes cannot depend on
//! the schedule.

use crate::error::{Error, Result};
use std::sync::mpsc;
use std::sync::Mutex;

/// Like [`run_indexed_with`], but jobs are *dispatched* in the order
/// given by `order` — a permutation of `0..order.len()` — while results
/// still come back in index order. This is the longest-processing-time
/// hook: feeding the queue in descending estimated-cost order lets the
/// expensive jobs start first, so no worker is left running a straggler
/// alone after the cheap tail drains. The output is byte-identical to
/// identity-order dispatch (results are keyed and re-sorted by index),
/// only the wall-clock changes.
pub fn run_ordered_with<T, S, I, F>(
    order: &[usize],
    threads: usize,
    init: I,
    f: F,
) -> Result<Vec<T>>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> Result<T> + Sync,
{
    let jobs = order.len();
    if jobs == 0 {
        return Ok(Vec::new());
    }
    // A non-permutation would silently drop or double-run jobs; the
    // check is O(jobs) against simulation-scale work, so always on.
    let mut seen = vec![false; jobs];
    for &i in order {
        if i >= jobs || seen[i] {
            return Err(Error::Sim(format!(
                "dispatch order is not a permutation of 0..{jobs} (index {i} repeated or out \
                 of range)"
            )));
        }
        seen[i] = true;
    }
    let threads = threads.clamp(1, jobs);

    // Work queue: every index queued up front in dispatch order, sender
    // dropped so workers see Err(Disconnected) once the queue drains.
    let (job_tx, job_rx) = mpsc::channel::<usize>();
    for &i in order {
        let _ = job_tx.send(i);
    }
    drop(job_tx);
    let job_rx = Mutex::new(job_rx);

    let (res_tx, res_rx) = mpsc::channel::<(usize, Result<T>)>();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let res_tx = res_tx.clone();
            let job_rx = &job_rx;
            let init = &init;
            let f = &f;
            s.spawn(move || {
                // One scratch per worker, reused across all its jobs.
                let mut scratch = init();
                loop {
                    // Hold the lock only while pulling the next index,
                    // never while running the job.
                    // lint: allow(no-panic) — a poisoned queue means a worker already panicked
                    let next = { job_rx.lock().expect("job queue poisoned").recv() };
                    let Ok(i) = next else { break };
                    if res_tx.send((i, f(&mut scratch, i))).is_err() {
                        break;
                    }
                }
            });
        }
        drop(res_tx); // workers hold the only remaining senders
    });

    let mut buf: Vec<(usize, Result<T>)> = Vec::with_capacity(jobs);
    buf.extend(res_rx.iter());
    if buf.len() != jobs {
        return Err(Error::Sim(format!(
            "worker pool lost results: got {}/{} jobs back",
            buf.len(),
            jobs
        )));
    }
    // Always re-sort: even a single worker drains the queue in
    // *dispatch* order, which need not be index order here.
    buf.sort_by_key(|(i, _)| *i);
    buf.into_iter().map(|(_, r)| r).collect()
}

/// Run `f(scratch, 0..jobs)` across `threads` workers (clamped to ≥ 1),
/// returning the results in index order. Each worker calls `init()` once
/// and passes the resulting scratch to every job it executes; because
/// job results must not depend on the scratch's prior use, the output is
/// still deterministic and thread-count independent. If any job fails,
/// the error with the lowest job index is returned (every job still runs
/// to completion, so the choice of surfaced error is deterministic too).
pub fn run_indexed_with<T, S, I, F>(jobs: usize, threads: usize, init: I, f: F) -> Result<Vec<T>>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> Result<T> + Sync,
{
    let order: Vec<usize> = (0..jobs).collect();
    run_ordered_with(&order, threads, init, f)
}

/// Scratch-free variant: run `f(0..jobs)` across `threads` workers,
/// returning the results in index order.
pub fn run_indexed<T, F>(jobs: usize, threads: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    run_indexed_with(jobs, threads, || (), |_, i| f(i))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_index_order() {
        for threads in [1usize, 2, 4, 9] {
            let out = run_indexed(50, threads, |i| Ok(i * i)).unwrap();
            assert_eq!(out.len(), 50);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * i, "threads={threads}");
            }
        }
    }

    #[test]
    fn zero_jobs_is_empty() {
        let out: Vec<usize> = run_indexed(0, 4, |i| Ok(i)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn thread_count_exceeding_jobs_is_fine() {
        let out = run_indexed(3, 64, |i| Ok(i + 1)).unwrap();
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn job_error_propagates() {
        let r: Result<Vec<usize>> = run_indexed(20, 4, |i| {
            if i == 13 {
                Err(Error::Sim("unlucky".into()))
            } else {
                Ok(i)
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn uneven_job_durations_still_order() {
        let out = run_indexed(16, 4, |i| {
            // Stagger work so completion order differs from index order.
            std::thread::sleep(std::time::Duration::from_millis(((16 - i) % 5) as u64));
            Ok(i)
        })
        .unwrap();
        assert_eq!(out, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn ordered_dispatch_still_returns_index_order() {
        // Reverse dispatch order (the LPT shape) at every thread count —
        // including 1, where the queue drains strictly in dispatch
        // order, so an unsorted result buffer would come back reversed.
        let order: Vec<usize> = (0..20).rev().collect();
        for threads in [1usize, 2, 4, 9] {
            let out = run_ordered_with(&order, threads, || (), |_, i| Ok(i * 3)).unwrap();
            assert_eq!(out.len(), 20);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * 3, "threads={threads}");
            }
        }
    }

    #[test]
    fn ordered_dispatch_rejects_non_permutations() {
        // Repeated index.
        let err = run_ordered_with(&[0, 1, 1], 2, || (), |_, i| Ok(i)).unwrap_err();
        assert!(err.to_string().contains("not a permutation"), "got: {err}");
        // Out-of-range index.
        let err = run_ordered_with(&[0, 3], 2, || (), |_, i| Ok(i)).unwrap_err();
        assert!(err.to_string().contains("not a permutation"), "got: {err}");
        // Empty order is the empty result, not an error.
        let out: Vec<usize> = run_ordered_with(&[], 2, || (), |_, i| Ok(i)).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn per_worker_scratch_is_built_once_and_reused() {
        // Each worker's scratch counts the jobs it ran; the total across
        // workers must equal the job count (every job saw *a* scratch),
        // and results stay index-ordered regardless of which worker ran
        // which job.
        use std::sync::atomic::{AtomicUsize, Ordering};
        let inits = AtomicUsize::new(0);
        let out = run_indexed_with(
            32,
            4,
            || {
                inits.fetch_add(1, Ordering::SeqCst);
                0usize // per-worker job counter
            },
            |scratch, i| {
                *scratch += 1;
                Ok((i, *scratch))
            },
        )
        .unwrap();
        assert_eq!(out.len(), 32);
        // Index ordering holds.
        for (slot, (i, _)) in out.iter().enumerate() {
            assert_eq!(slot, *i);
        }
        // One scratch per worker, not per job — and at least one worker
        // saw its counter advance past 1 (scratch reuse across jobs).
        assert!(inits.load(Ordering::SeqCst) <= 4);
        let max_count = out.iter().map(|&(_, c)| c).max().unwrap();
        assert!(max_count > 1, "no worker reused its scratch");
    }
}
