//! Shared IR cache: translate and compute-annotate each model once,
//! reuse everywhere — in memory within a run, and (optionally) on disk
//! across runs.
//!
//! Building the zoo graph, extracting the layer structure and running
//! the compute pass are the expensive, model-shaped parts of a scenario;
//! everything parallelism-dependent (the comm pass + workload emission)
//! is a cheap linear pass. The cache therefore stores one
//! **compute-annotated** [`ModelIR`] per [`CacheKey`] — the typed
//! identity `(model, batch, compute-model fingerprint)`, not the model
//! name alone, so sweeps spanning batch sizes or compute models can
//! never serve each other stale timings — and counts how many
//! translations actually ran, so callers (and the sweep smoke test) can
//! assert **translation count == model count**, not scenario count.
//!
//! ## The disk tier
//!
//! With a cache directory ([`WorkloadCache::build_with`], CLI
//! `sweep --cache-dir DIR`), every freshly translated IR is spilled as
//! a `modtrans-ir-cache/v1` envelope wrapping the et-json form
//! ([`crate::ir::emit::et_json`]), under a file name derived from the
//! key's FNV digest. Subsequent builds — later sweeps, or other shards
//! of the same grid — **load instead of re-extracting**: a warm run
//! reports zero translations. Entries are *validated, never trusted*:
//! unreadable/corrupt JSON, a schema or key mismatch (stale
//! fingerprint), or a failed IR reconstruction all count as a miss, and
//! the entry is re-extracted and overwritten. Writes go through a
//! temp-file rename so concurrent shard processes never observe a
//! half-written entry.
//!
//! Scenarios that differ only in parallelism / topology / collective
//! re-run only [`crate::ir::passes::plan_comm_into`] against the shared
//! IR (immutable after build, hence freely shared across worker
//! threads).

use crate::compute::SystolicCompute;
use crate::error::{Error, Result};
use crate::ir::{emit, frontend, passes, ModelIR};
use crate::json::{obj, Value};
use crate::translator::{ComputeTimeModel, ModelSummary};
use crate::util::fnv1a;
use std::collections::BTreeMap;
use std::path::Path;

/// Envelope schema for on-disk cache entries.
pub const IR_CACHE_SCHEMA: &str = "modtrans-ir-cache/v1";

/// File-name suffix shared by every disk-tier entry — what
/// [`copy_entries`] recognizes when syncing cache directories.
pub const IR_CACHE_SUFFIX: &str = ".ir.json";

/// The cache identity of one compute-annotated IR. Two IRs are
/// interchangeable iff all three components match: the model, the batch
/// the activations were sized at, and the compute model's
/// [`ComputeTimeModel::fingerprint`] (which covers every timing knob).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct CacheKey {
    /// Zoo model name (the requested name, not the graph name).
    pub model: String,
    /// Batch size used for extraction and compute annotation.
    pub batch: i64,
    /// [`ComputeTimeModel::fingerprint`] of the annotating model.
    pub compute_fingerprint: String,
}

impl CacheKey {
    /// Build a key for `model` at `batch` under `compute`.
    pub fn new(model: &str, batch: i64, compute: &dyn ComputeTimeModel) -> CacheKey {
        CacheKey {
            model: model.to_string(),
            batch,
            compute_fingerprint: compute.fingerprint(),
        }
    }

    /// FNV-1a digest over all three components — the collision-resistant
    /// part of the on-disk file name.
    pub fn digest(&self) -> u64 {
        let id = format!("{}\u{0}{}\u{0}{}", self.model, self.batch, self.compute_fingerprint);
        fnv1a(id.as_bytes())
    }

    /// Deterministic on-disk file name: a sanitized human-readable
    /// prefix plus the full-key digest. Distinct fingerprints (or
    /// batches) land in distinct files, so a stale entry is simply never
    /// looked up — and the embedded key is still re-verified on load.
    pub fn file_name(&self) -> String {
        let safe: String = self
            .model
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
            .collect();
        format!("{safe}-b{}-{:016x}{IR_CACHE_SUFFIX}", self.batch, self.digest())
    }
}

/// Per-model compute-annotated IRs, built once up front and shared
/// (immutably) by every scenario.
///
/// Instances are **homogeneous**: [`WorkloadCache::build_with`] is the
/// only constructor and stamps every entry with the one
/// (batch, compute-fingerprint) pair it was called with, so the by-name
/// lookups below need no per-entry identity re-check.
#[derive(Debug)]
pub struct WorkloadCache {
    irs: BTreeMap<CacheKey, ModelIR>,
    translations: usize,
    disk_loads: usize,
}

impl WorkloadCache {
    /// Translate every unique model in `models` at the given batch size
    /// and annotate it with the sweep's compute model
    /// ([`SystolicCompute`] at that batch). Duplicate names are
    /// translated only once. In-memory only; see
    /// [`WorkloadCache::build_with`] for the disk tier.
    pub fn build(models: &[String], batch: i64) -> Result<WorkloadCache> {
        let compute = SystolicCompute::new(batch);
        WorkloadCache::build_with(models, batch, &compute, None)
    }

    /// Build the cache under an explicit compute model, optionally
    /// backed by a persistent directory. For each unique model the disk
    /// tier is consulted first (a valid entry loads with **no**
    /// translation); misses extract through the zoo-direct frontend, run
    /// the compute pass, and spill the result back to disk.
    ///
    /// Unknown or failing models do not abort at the first casualty: the
    /// whole list is attempted and every failure is reported in one
    /// error, so shard fleets see the full casualty list instead of
    /// bisecting by hand.
    pub fn build_with(
        models: &[String],
        batch: i64,
        compute: &dyn ComputeTimeModel,
        cache_dir: Option<&Path>,
    ) -> Result<WorkloadCache> {
        if let Some(dir) = cache_dir {
            std::fs::create_dir_all(dir)?;
        }
        let fingerprint = compute.fingerprint();
        let mut irs: BTreeMap<CacheKey, ModelIR> = BTreeMap::new();
        let mut translations = 0usize;
        let mut disk_loads = 0usize;
        let mut failures: Vec<String> = Vec::new();
        for name in models {
            let key = CacheKey {
                model: name.clone(),
                batch,
                compute_fingerprint: fingerprint.clone(),
            };
            if irs.contains_key(&key) {
                continue;
            }
            if let Some(dir) = cache_dir {
                if let Some(ir) = load_entry(dir, &key) {
                    disk_loads += 1;
                    irs.insert(key, ir);
                    continue;
                }
            }
            match frontend::from_zoo(name, batch) {
                Ok(mut ir) => {
                    passes::annotate_compute(&mut ir, compute);
                    translations += 1;
                    if let Some(dir) = cache_dir {
                        // Spilling is best-effort: the cache directory
                        // never shapes results, so an unwritable or full
                        // disk mid-fleet degrades to an uncached run
                        // instead of killing the sweep. (A wholly bogus
                        // path still fails fast at create_dir_all above.)
                        if let Err(e) = store_entry(dir, &key, &ir) {
                            eprintln!(
                                "warning: could not write IR cache entry for '{name}': \
                                 {e} (continuing uncached)"
                            );
                        }
                    }
                    irs.insert(key, ir);
                }
                Err(e) => failures.push(format!("{name} ({e})")),
            }
        }
        if !failures.is_empty() {
            return Err(Error::Config(format!(
                "{} sweep model(s) failed to translate: {}",
                failures.len(),
                failures.join("; ")
            )));
        }
        Ok(WorkloadCache { irs, translations, disk_loads })
    }

    /// The cached compute-annotated IR for a model (exact under this
    /// cache's single build-time identity — see the struct docs), if
    /// present. Linear scan over the handful of cached models —
    /// allocation-free, which matters because every sweep scenario calls
    /// it.
    pub fn ir(&self, model: &str) -> Option<&ModelIR> {
        self.irs.iter().find_map(|(k, ir)| if k.model == model { Some(ir) } else { None })
    }

    /// The cached IR for an explicit full key, if present.
    pub fn ir_for(&self, key: &CacheKey) -> Option<&ModelIR> {
        self.irs.get(key)
    }

    /// The full typed key of a cached model, if present.
    pub fn key(&self, model: &str) -> Option<&CacheKey> {
        self.irs.keys().find(|k| k.model == model)
    }

    /// The cached structural summary for a model, if present.
    pub fn summary(&self, model: &str) -> Option<&ModelSummary> {
        self.ir(model).map(ModelIR::summary)
    }

    /// How many translations (full extractions + compute passes) ran
    /// while building the cache. Disk-tier loads do **not** count.
    pub fn translations(&self) -> usize {
        self.translations
    }

    /// How many models were loaded from the disk tier instead of
    /// translated.
    pub fn disk_loads(&self) -> usize {
        self.disk_loads
    }

    /// Number of cached models.
    pub fn len(&self) -> usize {
        self.irs.len()
    }

    /// True when no models are cached.
    pub fn is_empty(&self) -> bool {
        self.irs.is_empty()
    }
}

/// Copy the IR-cache entries (`*.ir.json`) from `src` that `dst` lacks
/// or holds with different bytes — the fleet's cross-machine
/// cache-sharing stage (`sweep fleet --cache-from DIR`): copy-in warms
/// a fresh machine's cache from an rsync'd or object-store-synced
/// directory, copy-out publishes what the sync directory is missing
/// back. Entry contents are deterministic per key and names embed the
/// full key digest, so a byte-identical same-name destination file is
/// skipped (rewriting it would only churn mtimes and make the next
/// rsync re-upload an unchanged cache) — while a same-name file with
/// *different* bytes is overwritten: that is how a corrupt or truncated
/// entry in the synced directory gets repaired once any machine
/// re-translates it, instead of silently taxing every fresh machine
/// forever. A missing `src` counts as empty. Copies go through a temp
/// file + rename so concurrent shard processes never observe a torn
/// entry. Returns the number of entries actually copied.
pub fn copy_entries(src: &Path, dst: &Path) -> Result<usize> {
    if !src.is_dir() {
        return Ok(0);
    }
    std::fs::create_dir_all(dst)?;
    let mut names: Vec<String> = Vec::new();
    for entry in std::fs::read_dir(src)? {
        let entry = entry?;
        let Ok(name) = entry.file_name().into_string() else { continue };
        if !name.ends_with(IR_CACHE_SUFFIX) || !entry.path().is_file() {
            continue;
        }
        // Skip only byte-identical entries; differing bytes mean the
        // destination copy is corrupt/stale and must be repaired.
        let identical = match std::fs::read(dst.join(&name)) {
            Ok(have) => std::fs::read(entry.path()).map_or(false, |want| want == have),
            Err(_) => false,
        };
        if !identical {
            names.push(name);
        }
    }
    // Deterministic copy order (read_dir order is platform-dependent).
    names.sort();
    for name in &names {
        let tmp = dst.join(format!("{name}.tmp.{}", std::process::id()));
        std::fs::copy(src.join(name), &tmp)?;
        std::fs::rename(&tmp, dst.join(name))?;
    }
    Ok(names.len())
}

/// Try to load and validate one disk entry. Any failure — missing file,
/// unparseable JSON, wrong envelope schema, key mismatch (stale
/// fingerprint), or a document the et-json reader rejects — is a miss:
/// the caller re-extracts and overwrites.
fn load_entry(dir: &Path, key: &CacheKey) -> Option<ModelIR> {
    let text = std::fs::read_to_string(dir.join(key.file_name())).ok()?;
    let doc = crate::json::parse(&text).ok()?;
    if doc.get("schema")?.as_str()? != IR_CACHE_SCHEMA {
        return None;
    }
    let k = doc.get("key")?;
    if k.get("model")?.as_str()? != key.model
        || k.get("batch")?.as_f64()? != key.batch as f64
        || k.get("compute")?.as_str()? != key.compute_fingerprint
    {
        return None;
    }
    let ir = frontend::from_et_json(doc.get("ir")?).ok()?;
    if ir.batch() != key.batch || !ir.compute_annotated() {
        return None;
    }
    // Load-boundary gate: `from_et_json` already verified the IR, but
    // the disk tier's contract is *never trust an envelope*, so the
    // semantic verifier runs here explicitly too — if the reader ever
    // grows a lenient mode, a bad envelope still becomes a miss, not a
    // trusted IR.
    crate::ir::verify(&ir).ok()?;
    Some(ir)
}

/// Verify one on-disk document for `modtrans check`: either a
/// `modtrans-ir-cache/v1` envelope (the `--cache-dir` disk tier's form)
/// or a bare `modtrans-et-json/v2` trace. Runs the full reader +
/// semantic-verifier stack — exactly what a cache load trusts — and
/// returns the embedded model name on success.
pub fn verify_envelope_file(path: &Path) -> Result<String> {
    let text = std::fs::read_to_string(path)?;
    let doc = crate::json::parse(&text)?;
    let schema = doc.get("schema").and_then(Value::as_str).unwrap_or("");
    let ir = if schema == IR_CACHE_SCHEMA {
        let inner = doc
            .get("ir")
            .ok_or_else(|| Error::verify("cache envelope has no 'ir' document"))?;
        let ir = frontend::from_et_json(inner)?;
        let key_batch = doc
            .get("key")
            .and_then(|k| k.get("batch"))
            .and_then(Value::as_f64)
            .ok_or_else(|| Error::verify("cache envelope has no key.batch"))?;
        if key_batch != ir.batch() as f64 {
            return Err(Error::verify(format!(
                "cache envelope key.batch {key_batch} disagrees with the embedded IR's batch {}",
                ir.batch()
            )));
        }
        ir
    } else {
        // Bare et-json document; from_et_json rejects unknown schemas.
        frontend::from_et_json(&doc)?
    };
    crate::ir::verify(&ir)?;
    Ok(ir.model_name().to_string())
}

/// Spill one compute-annotated IR to the disk tier: an envelope stamping
/// the full key around the et-json document, written via temp-file +
/// rename so concurrent shards never read a torn entry.
fn store_entry(dir: &Path, key: &CacheKey, ir: &ModelIR) -> Result<()> {
    let doc = obj(vec![
        ("schema", Value::Str(IR_CACHE_SCHEMA.into())),
        (
            "key",
            obj(vec![
                ("batch", Value::Num(key.batch as f64)),
                ("compute", Value::Str(key.compute_fingerprint.clone())),
                ("model", Value::Str(key.model.clone())),
            ]),
        ),
        ("ir", emit::et_json(ir)?),
    ]);
    let path = dir.join(key.file_name());
    let tmp = dir.join(format!("{}.tmp.{}", key.file_name(), std::process::id()));
    std::fs::write(&tmp, doc.to_json_pretty())?;
    std::fs::rename(&tmp, &path)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mt_cache_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn duplicates_translate_once() {
        let models = vec!["mlp".to_string(), "mlp".to_string(), "mlp".to_string()];
        let cache = WorkloadCache::build(&models, 4).unwrap();
        assert_eq!(cache.translations(), 1);
        assert_eq!(cache.disk_loads(), 0);
        assert_eq!(cache.len(), 1);
        assert!(!cache.is_empty());
        let s = cache.summary("mlp").unwrap();
        assert_eq!(s.batch, 4);
        assert!(!s.layers.is_empty());
        assert!(cache.summary("resnet18").is_none());
        assert!(cache.ir("resnet18").is_none());
    }

    #[test]
    fn cached_ir_is_compute_annotated_but_comm_free() {
        let cache = WorkloadCache::build(&["mlp".to_string()], 4).unwrap();
        let ir = cache.ir("mlp").unwrap();
        assert!(ir.compute_annotated());
        assert_eq!(ir.comm_annotated(), None);
        assert!(ir.costs().iter().all(|c| c.fwd_ns > 0));
    }

    #[test]
    fn translation_count_tracks_unique_models() {
        let models = vec!["mlp".to_string(), "alexnet".to_string(), "mlp".to_string()];
        let cache = WorkloadCache::build(&models, 2).unwrap();
        assert_eq!(cache.translations(), 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn unknown_model_fails_the_build() {
        let models = vec!["mlp".to_string(), "not-a-model".to_string()];
        assert!(WorkloadCache::build(&models, 2).is_err());
    }

    #[test]
    fn every_failing_model_is_reported_in_one_error() {
        let models = vec!["mlp".to_string(), "nope-a".to_string(), "nope-b".to_string()];
        let err = WorkloadCache::build(&models, 2).unwrap_err().to_string();
        assert!(err.contains("nope-a"), "missing first casualty: {err}");
        assert!(err.contains("nope-b"), "missing second casualty: {err}");
        assert!(err.contains("2 sweep model(s)"), "missing count: {err}");
    }

    #[test]
    fn cache_key_identity_covers_batch_and_compute() {
        let systolic = SystolicCompute::new(8);
        let a = CacheKey::new("mlp", 8, &systolic);
        let b = CacheKey::new("mlp", 16, &SystolicCompute::new(16));
        let c = CacheKey::new("mlp", 8, &crate::translator::ConstantCompute(10));
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(a.file_name(), b.file_name());
        assert_ne!(a.file_name(), c.file_name());
        assert_eq!(a, CacheKey::new("mlp", 8, &SystolicCompute::new(8)));
        // File names are path-safe.
        let weird = CacheKey::new("../evil model", 4, &systolic);
        assert!(!weird.file_name().contains('/'));
        assert!(!weird.file_name().contains(' '));
    }

    #[test]
    fn disk_tier_round_trips_without_retranslation() {
        let dir = temp_dir("roundtrip");
        let models = vec!["mlp".to_string(), "alexnet".to_string()];
        let compute = SystolicCompute::new(4);
        let cold = WorkloadCache::build_with(&models, 4, &compute, Some(&dir)).unwrap();
        assert_eq!(cold.translations(), 2);
        assert_eq!(cold.disk_loads(), 0);
        let warm = WorkloadCache::build_with(&models, 4, &compute, Some(&dir)).unwrap();
        assert_eq!(warm.translations(), 0, "warm build must be load-only");
        assert_eq!(warm.disk_loads(), 2);
        // Loaded IRs carry the same annotation as freshly built ones.
        for m in &models {
            let a = cold.ir(m).unwrap();
            let b = warm.ir(m).unwrap();
            assert_eq!(a.costs(), b.costs());
            assert_eq!(a.summary().total_bytes, b.summary().total_bytes);
            assert_eq!(a.num_layers(), b.num_layers());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_and_stale_entries_are_invalidated_not_trusted() {
        let dir = temp_dir("corrupt");
        let models = vec!["mlp".to_string()];
        let compute = SystolicCompute::new(4);
        let cold = WorkloadCache::build_with(&models, 4, &compute, Some(&dir)).unwrap();
        assert_eq!(cold.translations(), 1);
        let key = CacheKey::new("mlp", 4, &compute);
        let path = dir.join(key.file_name());
        assert!(path.exists());

        // Corrupt the entry: the next build re-extracts and repairs it.
        std::fs::write(&path, "{ not json").unwrap();
        let repaired = WorkloadCache::build_with(&models, 4, &compute, Some(&dir)).unwrap();
        assert_eq!(repaired.translations(), 1, "corrupt entry must not be trusted");
        assert_eq!(repaired.disk_loads(), 0);
        let warm = WorkloadCache::build_with(&models, 4, &compute, Some(&dir)).unwrap();
        assert_eq!(warm.disk_loads(), 1, "repair must have overwritten the entry");

        // Stale embedded fingerprint: tamper the key inside the file.
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace(&compute.fingerprint(), "systolic:stale")).unwrap();
        let stale = WorkloadCache::build_with(&models, 4, &compute, Some(&dir)).unwrap();
        assert_eq!(stale.translations(), 1, "stale fingerprint must be invalidated");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn copy_entries_syncs_only_cache_files_and_warms_the_destination() {
        let src = temp_dir("sync_src");
        let dst = temp_dir("sync_dst");
        let models = vec!["mlp".to_string(), "alexnet".to_string()];
        let compute = SystolicCompute::new(4);
        let cold = WorkloadCache::build_with(&models, 4, &compute, Some(&src)).unwrap();
        assert_eq!(cold.translations(), 2);
        // Non-entry files in the source are never propagated.
        std::fs::write(src.join("README.txt"), "not a cache entry").unwrap();
        std::fs::write(src.join("stale.ir.json.tmp.123"), "torn write leftover").unwrap();
        let copied = copy_entries(&src, &dst).unwrap();
        assert_eq!(copied, 2);
        let names: Vec<String> = std::fs::read_dir(&dst)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(names.len(), 2, "only the two entries may arrive: {names:?}");
        assert!(names.iter().all(|n| n.ends_with(IR_CACHE_SUFFIX)));
        // The destination now serves a fully warm build.
        let warm = WorkloadCache::build_with(&models, 4, &compute, Some(&dst)).unwrap();
        assert_eq!(warm.translations(), 0);
        assert_eq!(warm.disk_loads(), 2);
        // A second sync is a no-op: byte-identical entries are skipped,
        // so a synced directory is never churned with rewrites.
        assert_eq!(copy_entries(&src, &dst).unwrap(), 0);
        // But a corrupt destination entry (truncated sync, torn upload)
        // is repaired, not skipped — the self-healing half of the skip
        // rule.
        let victim = dst.join(names.iter().min().unwrap());
        std::fs::write(&victim, "{ truncated garbage").unwrap();
        assert_eq!(copy_entries(&src, &dst).unwrap(), 1, "differing bytes must be re-copied");
        let healed = WorkloadCache::build_with(&models, 4, &compute, Some(&dst)).unwrap();
        assert_eq!(healed.translations(), 0, "repaired entry must load again");
        // A missing source directory counts as empty, not an error.
        assert_eq!(copy_entries(Path::new("/no/such/cache-dir"), &dst).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&src);
        let _ = std::fs::remove_dir_all(&dst);
    }

    #[test]
    fn different_batches_use_disjoint_disk_entries() {
        let dir = temp_dir("batches");
        let models = vec!["mlp".to_string()];
        let b4 = WorkloadCache::build_with(&models, 4, &SystolicCompute::new(4), Some(&dir));
        let b8 = WorkloadCache::build_with(&models, 8, &SystolicCompute::new(8), Some(&dir));
        assert_eq!(b4.unwrap().translations(), 1);
        assert_eq!(b8.unwrap().translations(), 1, "batch 8 must not reuse the batch-4 IR");
        // Both entries now exist and serve their own batch.
        let w4 = WorkloadCache::build_with(&models, 4, &SystolicCompute::new(4), Some(&dir));
        let w8 = WorkloadCache::build_with(&models, 8, &SystolicCompute::new(8), Some(&dir));
        assert_eq!(w4.unwrap().disk_loads(), 1);
        let w8 = w8.unwrap();
        assert_eq!(w8.disk_loads(), 1);
        assert_eq!(w8.summary("mlp").unwrap().batch, 8);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
