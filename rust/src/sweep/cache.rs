//! Shared workload cache: translate each model once, reuse everywhere.
//!
//! Translation — building the zoo graph and extracting the layer summary
//! from it — is the expensive, model-shaped part of a scenario; deriving
//! a parallelism-specific workload from the summary is a cheap linear
//! pass. The cache therefore stores one [`ModelSummary`] per model and
//! counts how many translations actually ran, so callers (and the sweep
//! smoke test) can assert **translation count == model count**, not
//! scenario count.

use crate::error::Result;
use crate::translator::{self, ModelSummary};
use crate::zoo::{self, WeightFill, ZooOpts};
use std::collections::BTreeMap;

/// Per-model translated summaries, built once up front and shared
/// (immutably, hence freely across worker threads) by every scenario.
#[derive(Debug)]
pub struct WorkloadCache {
    summaries: BTreeMap<String, ModelSummary>,
    translations: usize,
}

impl WorkloadCache {
    /// Translate every unique model in `models` at the given batch size.
    /// Duplicate names are translated only once.
    pub fn build(models: &[String], batch: i64) -> Result<WorkloadCache> {
        let mut summaries = BTreeMap::new();
        let mut translations = 0usize;
        for name in models {
            if summaries.contains_key(name.as_str()) {
                continue;
            }
            let model = zoo::get(name, ZooOpts { weights: WeightFill::Empty })?;
            let summary = translator::extract(&model, batch)?;
            translations += 1;
            summaries.insert(name.clone(), summary);
        }
        Ok(WorkloadCache { summaries, translations })
    }

    /// The cached summary for a model, if present.
    pub fn summary(&self, model: &str) -> Option<&ModelSummary> {
        self.summaries.get(model)
    }

    /// How many translations ran while building the cache.
    pub fn translations(&self) -> usize {
        self.translations
    }

    /// Number of cached models.
    pub fn len(&self) -> usize {
        self.summaries.len()
    }

    /// True when no models are cached.
    pub fn is_empty(&self) -> bool {
        self.summaries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duplicates_translate_once() {
        let models = vec!["mlp".to_string(), "mlp".to_string(), "mlp".to_string()];
        let cache = WorkloadCache::build(&models, 4).unwrap();
        assert_eq!(cache.translations(), 1);
        assert_eq!(cache.len(), 1);
        assert!(!cache.is_empty());
        let s = cache.summary("mlp").unwrap();
        assert_eq!(s.batch, 4);
        assert!(!s.layers.is_empty());
        assert!(cache.summary("resnet18").is_none());
    }

    #[test]
    fn translation_count_tracks_unique_models() {
        let models = vec!["mlp".to_string(), "alexnet".to_string(), "mlp".to_string()];
        let cache = WorkloadCache::build(&models, 2).unwrap();
        assert_eq!(cache.translations(), 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn unknown_model_fails_the_build() {
        let models = vec!["mlp".to_string(), "not-a-model".to_string()];
        assert!(WorkloadCache::build(&models, 2).is_err());
    }
}
