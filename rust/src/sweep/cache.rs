//! Shared IR cache: translate and compute-annotate each model once,
//! reuse everywhere.
//!
//! Building the zoo graph, extracting the layer structure and running
//! the compute pass are the expensive, model-shaped parts of a scenario;
//! everything parallelism-dependent (the comm pass + workload emission)
//! is a cheap linear pass. The cache therefore stores one
//! **compute-annotated** [`ModelIR`] per (model, batch) — built through
//! the zoo-direct frontend, so zoo models never pay an ONNX
//! encode/decode round-trip — and counts how many translations actually
//! ran, so callers (and the sweep smoke test) can assert **translation
//! count == model count**, not scenario count.
//!
//! Scenarios that differ only in parallelism / topology / collective
//! re-run only [`crate::ir::passes::plan_comm_into`] against the shared
//! IR (immutable after build, hence freely shared across worker
//! threads).

use crate::compute::SystolicCompute;
use crate::error::Result;
use crate::ir::{frontend, passes, ModelIR};
use crate::translator::ModelSummary;
use std::collections::BTreeMap;

/// Per-model compute-annotated IRs, built once up front and shared
/// (immutably) by every scenario.
#[derive(Debug)]
pub struct WorkloadCache {
    irs: BTreeMap<String, ModelIR>,
    translations: usize,
}

impl WorkloadCache {
    /// Translate every unique model in `models` at the given batch size
    /// and annotate it with the sweep's compute model
    /// ([`SystolicCompute`] at that batch). Duplicate names are
    /// translated only once.
    pub fn build(models: &[String], batch: i64) -> Result<WorkloadCache> {
        let compute = SystolicCompute::new(batch);
        let mut irs = BTreeMap::new();
        let mut translations = 0usize;
        for name in models {
            if irs.contains_key(name.as_str()) {
                continue;
            }
            let mut ir = frontend::from_zoo(name, batch)?;
            passes::annotate_compute(&mut ir, &compute);
            translations += 1;
            irs.insert(name.clone(), ir);
        }
        Ok(WorkloadCache { irs, translations })
    }

    /// The cached compute-annotated IR for a model, if present.
    pub fn ir(&self, model: &str) -> Option<&ModelIR> {
        self.irs.get(model)
    }

    /// The cached structural summary for a model, if present.
    pub fn summary(&self, model: &str) -> Option<&ModelSummary> {
        self.irs.get(model).map(ModelIR::summary)
    }

    /// How many translations ran while building the cache.
    pub fn translations(&self) -> usize {
        self.translations
    }

    /// Number of cached models.
    pub fn len(&self) -> usize {
        self.irs.len()
    }

    /// True when no models are cached.
    pub fn is_empty(&self) -> bool {
        self.irs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duplicates_translate_once() {
        let models = vec!["mlp".to_string(), "mlp".to_string(), "mlp".to_string()];
        let cache = WorkloadCache::build(&models, 4).unwrap();
        assert_eq!(cache.translations(), 1);
        assert_eq!(cache.len(), 1);
        assert!(!cache.is_empty());
        let s = cache.summary("mlp").unwrap();
        assert_eq!(s.batch, 4);
        assert!(!s.layers.is_empty());
        assert!(cache.summary("resnet18").is_none());
        assert!(cache.ir("resnet18").is_none());
    }

    #[test]
    fn cached_ir_is_compute_annotated_but_comm_free() {
        let cache = WorkloadCache::build(&["mlp".to_string()], 4).unwrap();
        let ir = cache.ir("mlp").unwrap();
        assert!(ir.compute_annotated());
        assert_eq!(ir.comm_annotated(), None);
        assert!(ir.costs().iter().all(|c| c.fwd_ns > 0));
    }

    #[test]
    fn translation_count_tracks_unique_models() {
        let models = vec!["mlp".to_string(), "alexnet".to_string(), "mlp".to_string()];
        let cache = WorkloadCache::build(&models, 2).unwrap();
        assert_eq!(cache.translations(), 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn unknown_model_fails_the_build() {
        let models = vec!["mlp".to_string(), "not-a-model".to_string()];
        assert!(WorkloadCache::build(&models, 2).is_err());
    }
}
