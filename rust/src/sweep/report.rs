//! Ranked sweep results: text table + machine-readable JSON.
//!
//! Both renderings are fully deterministic: scenarios are ranked by
//! simulated iteration time with the scenario key as total-order
//! tiebreak, JSON objects use the crate's `BTreeMap`-backed [`Value`]
//! (sorted keys), and no wall-clock, thread-count or host information is
//! included — so a 1-thread run and an N-thread run of the same grid
//! produce byte-identical output.

use super::Scenario;
use crate::json::{obj, Value};
use crate::util::table::Table;
use crate::util::{human_bytes, human_time};

/// Simulation outcome for one scenario.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    /// The design-space point.
    pub scenario: Scenario,
    /// Simulated time per training iteration (ns) — the ranking metric.
    pub iteration_ns: u64,
    /// End-to-end simulated time for all iterations (ns).
    pub total_ns: u64,
    /// Busiest worker's compute-busy time (ns).
    pub compute_busy_ns: u64,
    /// Network busy time summed across fabric dimensions (ns).
    pub net_busy_ns: u64,
    /// Communication time not hidden by compute (ns).
    pub exposed_ns: u64,
    /// Compute utilization of the busiest worker, 0..1.
    pub compute_utilization: f64,
    /// Simulator events processed.
    pub events: usize,
    /// Modeled training memory per NPU (bytes).
    pub mem_per_npu_bytes: u64,
    /// Whether the footprint fits the configured HBM capacity.
    pub fits_hbm: bool,
}

/// The ranked sweep outcome.
#[derive(Debug, Clone)]
pub struct SweepReport {
    /// Unique models in the grid.
    pub models: usize,
    /// Translations performed while building the cache (== `models`).
    pub translations: usize,
    /// Scenarios pruned by the `--skip-infeasible` memory check before
    /// reaching the worker pool.
    pub pruned: usize,
    /// Results, fastest simulated iteration first.
    pub ranked: Vec<ScenarioResult>,
}

impl SweepReport {
    /// Machine-readable form (deterministic key order and ranking).
    pub fn to_json(&self) -> Value {
        let ranked: Vec<Value> = self
            .ranked
            .iter()
            .enumerate()
            .map(|(i, r)| {
                obj(vec![
                    ("rank", Value::Num((i + 1) as f64)),
                    ("model", Value::Str(r.scenario.model.clone())),
                    ("parallelism", Value::Str(r.scenario.parallelism.token().into())),
                    ("topology", Value::Str(r.scenario.topology.token().into())),
                    ("collective", Value::Str(r.scenario.collective.token().into())),
                    ("iteration_ns", Value::Num(r.iteration_ns as f64)),
                    ("total_ns", Value::Num(r.total_ns as f64)),
                    ("compute_busy_ns", Value::Num(r.compute_busy_ns as f64)),
                    ("net_busy_ns", Value::Num(r.net_busy_ns as f64)),
                    ("exposed_ns", Value::Num(r.exposed_ns as f64)),
                    // Permille as an integer: exact, compact, and immune
                    // to float-formatting surprises across platforms.
                    (
                        "compute_utilization_permille",
                        Value::Num((r.compute_utilization * 1000.0).round()),
                    ),
                    ("events", Value::Num(r.events as f64)),
                    ("mem_per_npu_bytes", Value::Num(r.mem_per_npu_bytes as f64)),
                    ("fits_hbm", Value::Bool(r.fits_hbm)),
                ])
            })
            .collect();
        obj(vec![
            ("models", Value::Num(self.models as f64)),
            ("translations", Value::Num(self.translations as f64)),
            ("scenarios", Value::Num(self.ranked.len() as f64)),
            ("pruned", Value::Num(self.pruned as f64)),
            ("ranked", Value::Arr(ranked)),
        ])
    }

    /// Human-readable ranked table.
    pub fn render_text(&self) -> String {
        let mut t = Table::new(vec![
            "Rank",
            "Model",
            "Parallelism",
            "Topology",
            "Collective",
            "Iteration",
            "Compute util",
            "Exposed comm",
            "Mem/NPU",
            "Fits",
        ]);
        for (i, r) in self.ranked.iter().enumerate() {
            t.row(vec![
                (i + 1).to_string(),
                r.scenario.model.clone(),
                r.scenario.parallelism.token().to_string(),
                r.scenario.topology.token().to_string(),
                r.scenario.collective.token().to_string(),
                human_time(r.iteration_ns as f64 * 1e-9),
                format!("{:.1}%", r.compute_utilization * 100.0),
                human_time(r.exposed_ns as f64 * 1e-9),
                human_bytes(r.mem_per_npu_bytes),
                if r.fits_hbm { "yes".to_string() } else { "NO".to_string() },
            ]);
        }
        let mut out = t.render();
        if self.pruned > 0 {
            out.push_str(&format!(
                "pruned {} infeasible scenario(s): memory_per_npu exceeds HBM\n",
                self.pruned
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::TopologyKind;
    use crate::sweep::CollectiveAlgo;
    use crate::workload::Parallelism;

    fn sample() -> SweepReport {
        let mk = |model: &str, ns: u64| ScenarioResult {
            scenario: Scenario {
                model: model.into(),
                parallelism: Parallelism::Data,
                topology: TopologyKind::Ring,
                collective: CollectiveAlgo::Pipelined,
            },
            iteration_ns: ns,
            total_ns: ns * 2,
            compute_busy_ns: ns / 2,
            net_busy_ns: ns / 3,
            exposed_ns: ns / 4,
            compute_utilization: 0.5,
            events: 100,
            mem_per_npu_bytes: 1 << 30,
            fits_hbm: true,
        };
        SweepReport {
            models: 2,
            translations: 2,
            pruned: 0,
            ranked: vec![mk("mlp", 10), mk("vgg16", 20)],
        }
    }

    #[test]
    fn json_is_deterministic_and_parseable() {
        let r = sample();
        let a = r.to_json().to_json_pretty();
        let b = r.to_json().to_json_pretty();
        assert_eq!(a, b);
        let v = crate::json::parse(&a).unwrap();
        assert_eq!(v.get("scenarios").unwrap().as_u64(), Some(2));
        assert_eq!(v.get("translations").unwrap().as_u64(), Some(2));
        let ranked = v.get("ranked").unwrap().as_arr().unwrap();
        assert_eq!(ranked.len(), 2);
        assert_eq!(ranked[0].get("rank").unwrap().as_u64(), Some(1));
        assert_eq!(ranked[0].get("model").unwrap().as_str(), Some("mlp"));
        assert_eq!(ranked[0].get("iteration_ns").unwrap().as_u64(), Some(10));
        assert_eq!(ranked[0].get("fits_hbm").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn text_table_lists_every_scenario() {
        let r = sample();
        let text = r.render_text();
        assert!(text.contains("Rank"));
        assert!(text.contains("mlp"));
        assert!(text.contains("vgg16"));
        assert!(text.contains("DATA"));
        assert!(text.contains("pipelined"));
        assert_eq!(text.lines().count(), 2 + r.ranked.len());
    }

    #[test]
    fn pruned_count_shows_in_both_renderings() {
        let mut r = sample();
        r.pruned = 3;
        let text = r.render_text();
        assert!(text.contains("pruned 3 infeasible"));
        assert_eq!(text.lines().count(), 2 + r.ranked.len() + 1);
        let v = crate::json::parse(&r.to_json().to_json_pretty()).unwrap();
        assert_eq!(v.get("pruned").unwrap().as_u64(), Some(3));
    }
}
