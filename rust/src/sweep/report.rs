//! Ranked sweep results: text table + machine-readable JSON.
//!
//! Both renderings are fully deterministic: scenarios are ranked by
//! simulated iteration time with the scenario key as total-order
//! tiebreak, JSON objects use the crate's `BTreeMap`-backed [`Value`]
//! (sorted keys), and no wall-clock, thread-count or host information is
//! included — so a 1-thread run and an N-thread run of the same grid
//! produce byte-identical output.

use super::{CommSchedule, Scenario};
use crate::error::{Error, Result};
use crate::json::{obj, Value};
use crate::sim::NetworkSpec;
use crate::util::table::Table;
use crate::util::{human_bytes, human_time};
use crate::workload::Parallelism;
use std::collections::BTreeSet;

/// Read a non-negative integer header field as `usize`.
fn r_usize(v: &Value, key: &str) -> Result<usize> {
    v.req_u64(key).map(|x| x as usize)
}

/// Parse a report's `"shard": "K/N"` field (shared spec grammar:
/// [`super::parse_shard_spec`]).
fn parse_shard_field(spec: &str) -> Result<(usize, usize)> {
    super::parse_shard_spec(spec).ok_or_else(|| {
        Error::Config(format!("invalid shard field '{spec}' in sweep report JSON"))
    })
}

/// Outcome record for one shard *process* of a fleet run — the
/// machine-readable evidence the orchestrator keeps per shard, so a
/// failed shard surfaces its exit code and stderr tail instead of being
/// visible only as a missing report file. Emitted (as JSON, via
/// [`ShardStatus::to_json`]) in the `sweep fleet --status-out` document
/// and consumed by CI's `fleet-smoke` job.
#[derive(Debug, Clone)]
pub struct ShardStatus {
    /// Which shard of the fleet this record covers (`(k, n)`, 1-based).
    pub shard: (usize, usize),
    /// Process launches this shard needed (1 = succeeded first try;
    /// anything higher means the bounded-retry policy relaunched it).
    pub attempts: usize,
    /// Final attempt's exit code (`Some(0)` on success, `None` when the
    /// process was killed by a signal).
    pub exit_code: Option<i32>,
    /// Tail of the final attempt's captured stderr (empty on a quiet
    /// success).
    pub stderr_tail: String,
    /// Scenarios this shard ranked.
    pub scenarios: usize,
    /// Translations the shard performed — 0 whenever the fleet's
    /// pre-warm pass covered its models (the fleet acceptance counter).
    pub translations: usize,
    /// Models the shard loaded from the shared disk cache.
    pub cache_loads: usize,
    /// Scenarios the shard pruned as infeasible.
    pub pruned: usize,
    /// Scenarios the shard fully simulated (equals `scenarios` for an
    /// exhaustive shard; under `--top K` the ranked list is truncated,
    /// so this is the honest work count).
    pub scenarios_simulated: usize,
    /// Scenarios the shard's top-K bound prune skipped without
    /// simulation.
    pub scenarios_pruned: usize,
    /// Analytic lower bounds the shard evaluated (0 when not pruning).
    pub bounds_evaluated: usize,
    /// Scenario-range leases this worker slot completed under the
    /// work-stealing scheduler (0 for a legacy static `--shard` run).
    pub leases: usize,
    /// Longest observed gap (ms) between this worker finishing a lease
    /// and its next dispatch (or the fleet completing) — the
    /// work-stealing acceptance counter: a healthy stealing fleet keeps
    /// this near zero, a static partition shows each early finisher
    /// idling for the full straggler tail.
    pub idle_ms: u64,
}

impl ShardStatus {
    /// Machine-readable form (deterministic key order).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("shard", Value::Str(format!("{}/{}", self.shard.0, self.shard.1))),
            ("attempts", Value::Num(self.attempts as f64)),
            ("exit_code", self.exit_code.map_or(Value::Null, |c| Value::Num(f64::from(c)))),
            ("scenarios", Value::Num(self.scenarios as f64)),
            ("translations", Value::Num(self.translations as f64)),
            ("cache_loads", Value::Num(self.cache_loads as f64)),
            ("pruned", Value::Num(self.pruned as f64)),
            ("scenarios_simulated", Value::Num(self.scenarios_simulated as f64)),
            ("scenarios_pruned", Value::Num(self.scenarios_pruned as f64)),
            ("bounds_evaluated", Value::Num(self.bounds_evaluated as f64)),
            ("leases", Value::Num(self.leases as f64)),
            ("idle_ms", Value::Num(self.idle_ms as f64)),
            ("stderr_tail", Value::Str(self.stderr_tail.clone())),
        ])
    }
}

/// Simulation outcome for one scenario.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    /// The design-space point.
    pub scenario: Scenario,
    /// Simulated time per training iteration (ns) — the ranking metric.
    pub iteration_ns: u64,
    /// End-to-end simulated time for all iterations (ns).
    pub total_ns: u64,
    /// Busiest worker's compute-busy time (ns).
    pub compute_busy_ns: u64,
    /// Network busy time summed across fabric dimensions (ns).
    pub net_busy_ns: u64,
    /// Communication time not hidden by compute (ns).
    pub exposed_ns: u64,
    /// Compute utilization of the busiest worker, 0..1.
    pub compute_utilization: f64,
    /// Simulator events processed.
    pub events: usize,
    /// Modeled training memory per NPU (bytes).
    pub mem_per_npu_bytes: u64,
    /// Whether the footprint fits the configured HBM capacity.
    pub fits_hbm: bool,
    /// The analytic makespan lower bound this scenario was admitted
    /// under ([`crate::sweep::bound::scenario_bound_ns`]); 0 on
    /// exhaustive runs. In-memory only — deliberately NOT serialized,
    /// so a pruned report's ranked rows stay byte-identical to the
    /// exhaustive ranking's (the prune-equivalence CI contract).
    pub bound_ns: u64,
}

impl ScenarioResult {
    /// The sweep's total ranking order: fastest simulated iteration
    /// first, allocation-free scenario-key tiebreak. Shared by
    /// `run_sweep` and [`SweepReport::merge`] so a shard merge re-ranks
    /// exactly like the unsharded run.
    pub fn rank_cmp(a: &ScenarioResult, b: &ScenarioResult) -> std::cmp::Ordering {
        a.iteration_ns
            .cmp(&b.iteration_ns)
            .then_with(|| a.scenario.rank_key().cmp(&b.scenario.rank_key()))
    }
}

/// The ranked sweep outcome.
#[derive(Debug, Clone)]
pub struct SweepReport {
    /// Unique models in this run's scenario list.
    pub models: usize,
    /// Translations performed while building the cache — equal to
    /// `models` for a single cold run. A merged report sums the
    /// per-shard counts, so it can exceed `models` when several shard
    /// processes each translated the same model. A fully warm
    /// `--cache-dir` run reports **0** here (the CI warm-cache check's
    /// acceptance counter).
    pub translations: usize,
    /// Models served from the persistent disk cache instead of
    /// translated (`translations + cache_loads == models` for a single
    /// run). Zero when no `--cache-dir` was given.
    pub cache_loads: usize,
    /// Scenarios pruned by the `--skip-infeasible` memory check before
    /// reaching the worker pool.
    pub pruned: usize,
    /// Scenarios fully simulated. Equals `ranked.len()` for exhaustive
    /// runs; under `--top K` the ranked list is truncated to K, so this
    /// (not the ranked length) is what `merge` sums to verify every
    /// grid scenario was accounted for.
    pub scenarios_simulated: usize,
    /// Scenarios the top-K bound prune skipped without simulation
    /// (0 when `top_k` is unset).
    pub scenarios_pruned: usize,
    /// Analytic lower bounds evaluated (the whole post-filter scenario
    /// list under `--top K`, 0 otherwise).
    pub bounds_evaluated: usize,
    /// The scenario-shaping config fingerprint
    /// ([`super::SweepConfig::fingerprint`]) the results were produced
    /// under — `Value::Null` for reports assembled without one. `merge`
    /// refuses inputs with differing fingerprints.
    pub config: Value,
    /// Deduplicated scenario count of the *full* grid (before any shard
    /// filter or pruning) — what `merge` uses to verify a shard set
    /// actually covers the whole design space.
    pub grid_scenarios: usize,
    /// Order-sensitive digest of the full grid's scenario keys — the
    /// grid *identity*, so `merge` rejects shards of different grids
    /// even when their scenario counts and configs coincide. Empty for
    /// hand-assembled reports.
    pub grid_digest: String,
    /// Which shard of the grid this report covers (`None` = the full
    /// grid). `merge` requires a complete, uniform `1..=N` shard set.
    pub shard: Option<(usize, usize)>,
    /// The explicit scenario-index lease (indices into the full grid's
    /// deduplicated expansion order) this report covers, echoed back by
    /// a `--scenarios` child so the fleet orchestrator can verify a
    /// lease report against the lease it handed out. `None` for full or
    /// modulo-sharded runs. Mutually exclusive with `shard`.
    pub lease: Option<Vec<usize>>,
    /// Results, fastest simulated iteration first.
    pub ranked: Vec<ScenarioResult>,
}

impl SweepReport {
    /// Machine-readable form (deterministic key order and ranking).
    pub fn to_json(&self) -> Value {
        let ranked: Vec<Value> = self
            .ranked
            .iter()
            .enumerate()
            .map(|(i, r)| {
                obj(vec![
                    ("rank", Value::Num((i + 1) as f64)),
                    ("model", Value::Str(r.scenario.model.clone())),
                    ("parallelism", Value::Str(r.scenario.parallelism.token().into())),
                    // The "topology" field carries the canonical NetworkSpec label
                    // (for bare legacy specs this is the old topology token).
                    ("topology", Value::Str(r.scenario.network.label().to_string())),
                    ("collective", Value::Str(r.scenario.collective.token().into())),
                    ("iteration_ns", Value::Num(r.iteration_ns as f64)),
                    ("total_ns", Value::Num(r.total_ns as f64)),
                    ("compute_busy_ns", Value::Num(r.compute_busy_ns as f64)),
                    ("net_busy_ns", Value::Num(r.net_busy_ns as f64)),
                    ("exposed_ns", Value::Num(r.exposed_ns as f64)),
                    // Permille as an integer: exact, compact, and immune
                    // to float-formatting surprises across platforms.
                    (
                        "compute_utilization_permille",
                        Value::Num((r.compute_utilization * 1000.0).round()),
                    ),
                    ("events", Value::Num(r.events as f64)),
                    ("mem_per_npu_bytes", Value::Num(r.mem_per_npu_bytes as f64)),
                    ("fits_hbm", Value::Bool(r.fits_hbm)),
                ])
            })
            .collect();
        let shard = match self.shard {
            Some((k, n)) => Value::Str(format!("{k}/{n}")),
            None => Value::Null,
        };
        let lease = match &self.lease {
            Some(ix) => Value::Arr(ix.iter().map(|&i| Value::Num(i as f64)).collect()),
            None => Value::Null,
        };
        obj(vec![
            ("models", Value::Num(self.models as f64)),
            ("translations", Value::Num(self.translations as f64)),
            ("cache_loads", Value::Num(self.cache_loads as f64)),
            ("scenarios", Value::Num(self.ranked.len() as f64)),
            ("pruned", Value::Num(self.pruned as f64)),
            ("scenarios_simulated", Value::Num(self.scenarios_simulated as f64)),
            ("scenarios_pruned", Value::Num(self.scenarios_pruned as f64)),
            ("bounds_evaluated", Value::Num(self.bounds_evaluated as f64)),
            ("config", self.config.clone()),
            ("grid_scenarios", Value::Num(self.grid_scenarios as f64)),
            ("grid_digest", Value::Str(self.grid_digest.clone())),
            ("shard", shard),
            ("lease", lease),
            ("ranked", Value::Arr(ranked)),
        ])
    }

    /// Rebuild a report from its [`SweepReport::to_json`] form. Inverse
    /// of `to_json` up to the permille rounding of the utilization — a
    /// parse → re-emit round trip is byte-identical.
    pub fn from_json(v: &Value) -> Result<SweepReport> {
        let ranked_json = v
            .get("ranked")
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::Config("sweep report JSON has no 'ranked' array".into()))?;
        let mut ranked = Vec::with_capacity(ranked_json.len());
        for r in ranked_json {
            let scenario = Scenario {
                model: r.req_str("model")?.to_string(),
                parallelism: Parallelism::from_token(r.req_str("parallelism")?)?,
                network: NetworkSpec::parse(r.req_str("topology")?)?,
                collective: CommSchedule::from_token(r.req_str("collective")?)?,
            };
            let fits_hbm = r
                .get("fits_hbm")
                .and_then(Value::as_bool)
                .ok_or_else(|| Error::Config("missing/invalid bool field 'fits_hbm'".into()))?;
            ranked.push(ScenarioResult {
                scenario,
                iteration_ns: r.req_u64("iteration_ns")?,
                total_ns: r.req_u64("total_ns")?,
                compute_busy_ns: r.req_u64("compute_busy_ns")?,
                net_busy_ns: r.req_u64("net_busy_ns")?,
                exposed_ns: r.req_u64("exposed_ns")?,
                compute_utilization: r.req_f64("compute_utilization_permille")? / 1000.0,
                events: r.req_u64("events")? as usize,
                mem_per_npu_bytes: r.req_u64("mem_per_npu_bytes")?,
                fits_hbm,
                bound_ns: 0,
            });
        }
        // A present-but-malformed shard field is an error, never silently
        // an unstamped report (that would disable the completeness guard).
        let shard = match v.get("shard") {
            None | Some(Value::Null) => None,
            Some(Value::Str(spec)) => Some(parse_shard_field(spec)?),
            Some(_) => {
                return Err(Error::Config(
                    "invalid shard field in sweep report JSON — expected \"K/N\" or null".into(),
                ))
            }
        };
        // Same policy as `shard`: absent (pre-lease reports) and null
        // both mean "no lease"; a present-but-malformed lease is an
        // error, never silently dropped provenance.
        let lease = match v.get("lease") {
            None | Some(Value::Null) => None,
            Some(Value::Arr(ix)) => {
                let mut out = Vec::with_capacity(ix.len());
                for i in ix {
                    out.push(i.as_usize().ok_or_else(|| {
                        Error::Config(
                            "invalid lease field in sweep report JSON — expected \
                             an array of scenario indices"
                                .into(),
                        )
                    })?);
                }
                Some(out)
            }
            Some(_) => {
                return Err(Error::Config(
                    "invalid lease field in sweep report JSON — expected an index array or null"
                        .into(),
                ))
            }
        };
        Ok(SweepReport {
            models: r_usize(v, "models")?,
            translations: r_usize(v, "translations")?,
            // Absent in pre-disk-tier reports: default to 0, never fail.
            cache_loads: v.get("cache_loads").and_then(Value::as_usize).unwrap_or(0),
            pruned: r_usize(v, "pruned")?,
            // Pre-prune reports were always exhaustive: every ranked row
            // was simulated, nothing was bound-pruned.
            scenarios_simulated: v
                .get("scenarios_simulated")
                .and_then(Value::as_usize)
                .unwrap_or(ranked.len()),
            scenarios_pruned: v.get("scenarios_pruned").and_then(Value::as_usize).unwrap_or(0),
            bounds_evaluated: v.get("bounds_evaluated").and_then(Value::as_usize).unwrap_or(0),
            config: v.get("config").cloned().unwrap_or(Value::Null),
            grid_scenarios: v.get("grid_scenarios").and_then(Value::as_usize).unwrap_or(0),
            grid_digest: v
                .get("grid_digest")
                .and_then(Value::as_str)
                .unwrap_or_default()
                .to_string(),
            shard,
            lease,
            ranked,
        })
    }

    /// Merge per-shard reports into one re-ranked report (the
    /// `sweep-merge` reducer). Every shard must carry the same config
    /// fingerprint — iteration times measured under different configs
    /// are not one design space — shard-stamped inputs must form a
    /// complete, uniform `1..=N` set over the same grid (a forgotten or
    /// mixed-width shard would silently present a partial ranking as the
    /// full design space), and scenario keys must be disjoint.
    /// Translation and pruned counts sum; the model count is the number
    /// of distinct models in the merged ranking.
    pub fn merge(shards: &[SweepReport]) -> Result<SweepReport> {
        if let Some(first) = shards.first() {
            if let Some(bad) = shards.iter().position(|s| s.config != first.config) {
                return Err(Error::Config(format!(
                    "shard {} was produced under a different sweep configuration — \
                     refusing to merge rankings across configs",
                    bad + 1
                )));
            }
            if let Some(bad) = shards.iter().position(|s| {
                s.grid_scenarios != first.grid_scenarios || s.grid_digest != first.grid_digest
            }) {
                return Err(Error::Config(format!(
                    "shard {} covers a different grid ({} scenarios, digest {} vs {} scenarios, \
                     digest {}) — refusing to merge across grids",
                    bad + 1,
                    shards[bad].grid_scenarios,
                    shards[bad].grid_digest,
                    first.grid_scenarios,
                    first.grid_digest
                )));
            }
        }
        // Shard-stamped inputs must cover the whole grid: same N
        // everywhere, and every K of 1..=N present exactly once.
        // (Inputs without a shard stamp — hand-assembled reports — are
        // only overlap-checked.)
        let stamped: Vec<(usize, usize)> = shards.iter().filter_map(|s| s.shard).collect();
        if !stamped.is_empty() {
            if stamped.len() != shards.len() {
                return Err(Error::Config(
                    "cannot mix sharded and unsharded reports in one merge".into(),
                ));
            }
            // Coverage can only be verified against recorded provenance;
            // a stamped shard without it could be from any grid.
            if shards.iter().any(|s| s.grid_digest.is_empty() || s.grid_scenarios == 0) {
                return Err(Error::Config(
                    "sharded report lacks grid provenance (grid_scenarios/grid_digest) — \
                     cannot verify the shard set covers one design space"
                        .into(),
                ));
            }
            let n = stamped[0].1;
            if stamped.iter().any(|&(_, ni)| ni != n) {
                return Err(Error::Config(
                    "shard reports use different shard widths — not one partition".into(),
                ));
            }
            let mut ks: Vec<usize> = stamped.iter().map(|&(k, _)| k).collect();
            ks.sort_unstable();
            ks.dedup();
            if ks.len() != stamped.len() || ks.len() != n || ks[0] != 1 || ks[n - 1] != n {
                // Name exactly which shards are absent: a dead shard
                // process leaves no report file, so "which one" is the
                // question the operator has to answer next.
                let have: BTreeSet<usize> = ks.iter().copied().collect();
                let missing: Vec<String> =
                    (1..=n).filter(|k| !have.contains(k)).map(|k| format!("{k}/{n}")).collect();
                return Err(Error::Config(if missing.is_empty() {
                    format!(
                        "incomplete shard set: need every shard 1..={n} exactly once, \
                         got {} input(s)",
                        stamped.len()
                    )
                } else {
                    format!(
                        "incomplete shard set: missing shard(s) {} — a crashed shard leaves \
                         no report file; check that shard's stderr/exit code (or use \
                         `sweep fleet`, which retries and records both)",
                        missing.join(", ")
                    )
                }));
            }
            // Every grid scenario must be accounted for — simulated,
            // bound-pruned, or infeasible-pruned — across the complete
            // shard set; a truncated shard file must not silently
            // shrink the "full" design space. (Counted from the work
            // counters, not `ranked.len()`: a top-K shard truncates its
            // ranking but still accounts for every scenario.)
            let covered: usize = shards
                .iter()
                .map(|s| s.scenarios_simulated + s.scenarios_pruned + s.pruned)
                .sum();
            let expect = shards[0].grid_scenarios;
            if covered != expect {
                return Err(Error::Config(format!(
                    "shard set covers {covered} of {expect} grid scenarios \
                     (simulated + pruned) — a shard file is truncated or stale"
                )));
            }
        }
        let mut ranked: Vec<ScenarioResult> = Vec::new();
        let mut translations = 0usize;
        let mut cache_loads = 0usize;
        let mut pruned = 0usize;
        let mut scenarios_simulated = 0usize;
        let mut scenarios_pruned = 0usize;
        let mut bounds_evaluated = 0usize;
        for s in shards {
            translations += s.translations;
            cache_loads += s.cache_loads;
            pruned += s.pruned;
            scenarios_simulated += s.scenarios_simulated;
            scenarios_pruned += s.scenarios_pruned;
            bounds_evaluated += s.bounds_evaluated;
            ranked.extend(s.ranked.iter().cloned());
        }
        let mut keys = BTreeSet::new();
        for r in &ranked {
            if !keys.insert(r.scenario.key()) {
                return Err(Error::Config(format!(
                    "duplicate scenario '{}' across shards — inputs overlap",
                    r.scenario.key()
                )));
            }
        }
        ranked.sort_by(ScenarioResult::rank_cmp);
        let config = shards.first().map_or(Value::Null, |s| s.config.clone());
        // Top-K shards each carry their local K best; the exact global
        // top-K is the re-ranked union truncated back to K (every
        // global winner is a local winner on its own shard, so nothing
        // is lost). The config-equality guard above already ensured a
        // uniform top_k across inputs.
        if let Some(k) = config.get("top_k").and_then(Value::as_usize) {
            ranked.truncate(k);
        }
        let mut model_names = BTreeSet::new();
        for r in &ranked {
            model_names.insert(r.scenario.model.as_str());
        }
        let models = model_names.len();
        let grid_scenarios = shards.first().map_or(0, |s| s.grid_scenarios);
        let grid_digest = shards.first().map_or_else(String::new, |s| s.grid_digest.clone());
        Ok(SweepReport {
            models,
            translations,
            cache_loads,
            pruned,
            scenarios_simulated,
            scenarios_pruned,
            bounds_evaluated,
            config,
            grid_scenarios,
            grid_digest,
            shard: None,
            lease: None,
            ranked,
        })
    }

    /// Human-readable ranked table.
    pub fn render_text(&self) -> String {
        let mut t = Table::new(vec![
            "Rank",
            "Model",
            "Parallelism",
            "Topology",
            "Collective",
            "Iteration",
            "Compute util",
            "Exposed comm",
            "Mem/NPU",
            "Fits",
        ]);
        for (i, r) in self.ranked.iter().enumerate() {
            t.row(vec![
                (i + 1).to_string(),
                r.scenario.model.clone(),
                r.scenario.parallelism.token().to_string(),
                r.scenario.network.label().to_string(),
                r.scenario.collective.token().to_string(),
                human_time(r.iteration_ns as f64 * 1e-9),
                format!("{:.1}%", r.compute_utilization * 100.0),
                human_time(r.exposed_ns as f64 * 1e-9),
                human_bytes(r.mem_per_npu_bytes),
                if r.fits_hbm { "yes".to_string() } else { "NO".to_string() },
            ]);
        }
        let mut out = t.render();
        if self.pruned > 0 {
            out.push_str(&format!(
                "pruned {} infeasible scenario(s): memory_per_npu exceeds HBM\n",
                self.pruned
            ));
        }
        if self.scenarios_pruned > 0 {
            out.push_str(&format!(
                "top-K bound prune: {} scenario(s) simulated, {} skipped by \
                 analytic lower bound ({} bounds evaluated)\n",
                self.scenarios_simulated, self.scenarios_pruned, self.bounds_evaluated
            ));
        }
        out
    }
}

/// Incremental reducer over per-lease reports — [`SweepReport::merge`]
/// folded one batch at a time, under the same guard set, so the fleet
/// orchestrator can maintain a live ranking while leases are still in
/// flight instead of merging once after the last worker exits.
///
/// Guards enforced per [`StreamingMerge::absorb`] call (mirroring the
/// batch merge): config-fingerprint equality, grid identity, per-lease
/// coverage accounting (`simulated + bound-pruned + infeasible-pruned`
/// must equal the lease size), disjoint lease index ranges, disjoint
/// scenario keys, and — when the lease report echoes its index list —
/// the echo must match what the scheduler dispatched. `finalize`
/// additionally requires that the absorbed leases cover every grid
/// scenario exactly once.
///
/// Under `--top K` the folded ranking is truncated to K after every
/// batch; this loses nothing because each lease's report already ranks
/// its local K best, and every eventual global winner is a local winner
/// on its own lease. [`StreamingMerge::kth_best_ns`] exposes the
/// current K-th best iteration time — the fleet-wide prune cutoff that
/// tightens mid-run as batches arrive.
#[derive(Debug)]
pub struct StreamingMerge {
    config: Value,
    grid_scenarios: usize,
    grid_digest: String,
    top_k: Option<usize>,
    covered: Vec<bool>,
    covered_n: usize,
    seen_keys: BTreeSet<String>,
    translations: usize,
    cache_loads: usize,
    pruned: usize,
    scenarios_simulated: usize,
    scenarios_pruned: usize,
    bounds_evaluated: usize,
    ranked: Vec<ScenarioResult>,
}

impl StreamingMerge {
    /// Start an empty merge for one design space: the config
    /// fingerprint every lease must match, the full grid's deduplicated
    /// scenario count, and its order-sensitive digest.
    pub fn new(config: Value, grid_scenarios: usize, grid_digest: String) -> StreamingMerge {
        let top_k = config.get("top_k").and_then(Value::as_usize);
        StreamingMerge {
            config,
            grid_scenarios,
            grid_digest,
            top_k,
            covered: vec![false; grid_scenarios],
            covered_n: 0,
            seen_keys: BTreeSet::new(),
            translations: 0,
            cache_loads: 0,
            pruned: 0,
            scenarios_simulated: 0,
            scenarios_pruned: 0,
            bounds_evaluated: 0,
            ranked: Vec::new(),
        }
    }

    /// Fold one lease report (covering exactly the grid-expansion
    /// `indices` the scheduler dispatched) into the running merge.
    pub fn absorb(&mut self, batch: &SweepReport, indices: &[usize]) -> Result<()> {
        if batch.config != self.config {
            return Err(Error::Config(
                "lease report was produced under a different sweep configuration — \
                 refusing to fold it into the streaming merge"
                    .into(),
            ));
        }
        if batch.grid_scenarios != self.grid_scenarios || batch.grid_digest != self.grid_digest {
            return Err(Error::Config(format!(
                "lease report covers a different grid ({} scenarios, digest {} vs {} \
                 scenarios, digest {}) — refusing to merge across grids",
                batch.grid_scenarios, batch.grid_digest, self.grid_scenarios, self.grid_digest
            )));
        }
        if let Some(echo) = &batch.lease {
            if echo != indices {
                return Err(Error::Config(format!(
                    "lease report echoes {} scenario index(es) that are not the {} \
                     dispatched for this lease — stale or mixed-up report file",
                    echo.len(),
                    indices.len()
                )));
            }
        }
        let accounted = batch.scenarios_simulated + batch.scenarios_pruned + batch.pruned;
        if accounted != indices.len() {
            return Err(Error::Config(format!(
                "lease report accounts for {accounted} of {} leased scenarios \
                 (simulated + pruned) — a truncated or stale report file",
                indices.len()
            )));
        }
        for &i in indices {
            if i >= self.grid_scenarios {
                return Err(Error::Config(format!(
                    "lease scenario index {i} is outside the {}-scenario grid",
                    self.grid_scenarios
                )));
            }
            if self.covered[i] {
                return Err(Error::Config(format!(
                    "scenario index {i} is already covered — leases overlap"
                )));
            }
        }
        for r in &batch.ranked {
            if self.seen_keys.contains(&r.scenario.key()) {
                return Err(Error::Config(format!(
                    "duplicate scenario '{}' across leases — inputs overlap",
                    r.scenario.key()
                )));
            }
        }
        // All guards passed: commit the batch atomically.
        for &i in indices {
            self.covered[i] = true;
        }
        self.covered_n += indices.len();
        for r in &batch.ranked {
            self.seen_keys.insert(r.scenario.key());
        }
        self.translations += batch.translations;
        self.cache_loads += batch.cache_loads;
        self.pruned += batch.pruned;
        self.scenarios_simulated += batch.scenarios_simulated;
        self.scenarios_pruned += batch.scenarios_pruned;
        self.bounds_evaluated += batch.bounds_evaluated;
        self.ranked.extend(batch.ranked.iter().cloned());
        self.ranked.sort_by(ScenarioResult::rank_cmp);
        if let Some(k) = self.top_k {
            self.ranked.truncate(k);
        }
        Ok(())
    }

    /// Grid scenarios covered by the batches absorbed so far.
    pub fn covered(&self) -> usize {
        self.covered_n
    }

    /// The current fleet-wide K-th best simulated iteration time — a
    /// sound prune cutoff for still-undispatched leases (`None` until K
    /// results exist, or when the merge is exhaustive).
    pub fn kth_best_ns(&self) -> Option<u64> {
        let k = self.top_k?;
        if self.ranked.len() >= k {
            Some(self.ranked[k - 1].iteration_ns)
        } else {
            None
        }
    }

    /// Close the merge: every grid scenario must have been covered by
    /// exactly one absorbed lease. Produces the same report the batch
    /// [`SweepReport::merge`] of a complete shard set would.
    pub fn finalize(self) -> Result<SweepReport> {
        if self.covered_n != self.grid_scenarios {
            return Err(Error::Config(format!(
                "streaming merge covers {} of {} grid scenarios — lease set incomplete \
                 (a worker died without its lease being re-dispatched?)",
                self.covered_n, self.grid_scenarios
            )));
        }
        let mut model_names = BTreeSet::new();
        for r in &self.ranked {
            model_names.insert(r.scenario.model.as_str());
        }
        Ok(SweepReport {
            models: model_names.len(),
            translations: self.translations,
            cache_loads: self.cache_loads,
            pruned: self.pruned,
            scenarios_simulated: self.scenarios_simulated,
            scenarios_pruned: self.scenarios_pruned,
            bounds_evaluated: self.bounds_evaluated,
            config: self.config,
            grid_scenarios: self.grid_scenarios,
            grid_digest: self.grid_digest,
            shard: None,
            lease: None,
            ranked: self.ranked,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::TopologyKind;
    use crate::sweep::CollectiveAlgo;
    use crate::workload::Parallelism;

    fn sample() -> SweepReport {
        let mk = |model: &str, ns: u64| ScenarioResult {
            scenario: Scenario {
                model: model.into(),
                parallelism: Parallelism::Data,
                network: NetworkSpec::from_kind(TopologyKind::Ring),
                collective: CollectiveAlgo::Pipelined,
            },
            iteration_ns: ns,
            total_ns: ns * 2,
            compute_busy_ns: ns / 2,
            net_busy_ns: ns / 3,
            exposed_ns: ns / 4,
            compute_utilization: 0.5,
            events: 100,
            mem_per_npu_bytes: 1 << 30,
            fits_hbm: true,
            bound_ns: 0,
        };
        SweepReport {
            models: 2,
            translations: 2,
            cache_loads: 0,
            pruned: 0,
            scenarios_simulated: 2,
            scenarios_pruned: 0,
            bounds_evaluated: 0,
            config: crate::sweep::SweepConfig::default().fingerprint(),
            grid_scenarios: 2,
            grid_digest: String::new(),
            shard: None,
            lease: None,
            ranked: vec![mk("mlp", 10), mk("vgg16", 20)],
        }
    }

    #[test]
    fn json_is_deterministic_and_parseable() {
        let r = sample();
        let a = r.to_json().to_json_pretty();
        let b = r.to_json().to_json_pretty();
        assert_eq!(a, b);
        let v = crate::json::parse(&a).unwrap();
        assert_eq!(v.get("scenarios").unwrap().as_u64(), Some(2));
        assert_eq!(v.get("translations").unwrap().as_u64(), Some(2));
        let ranked = v.get("ranked").unwrap().as_arr().unwrap();
        assert_eq!(ranked.len(), 2);
        assert_eq!(ranked[0].get("rank").unwrap().as_u64(), Some(1));
        assert_eq!(ranked[0].get("model").unwrap().as_str(), Some("mlp"));
        assert_eq!(ranked[0].get("iteration_ns").unwrap().as_u64(), Some(10));
        assert_eq!(ranked[0].get("fits_hbm").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn text_table_lists_every_scenario() {
        let r = sample();
        let text = r.render_text();
        assert!(text.contains("Rank"));
        assert!(text.contains("mlp"));
        assert!(text.contains("vgg16"));
        assert!(text.contains("DATA"));
        assert!(text.contains("pipelined"));
        assert_eq!(text.lines().count(), 2 + r.ranked.len());
    }

    #[test]
    fn json_round_trips_through_from_json() {
        let r = sample();
        let emitted = r.to_json().to_json_pretty();
        let parsed = SweepReport::from_json(&crate::json::parse(&emitted).unwrap()).unwrap();
        assert_eq!(parsed.models, r.models);
        assert_eq!(parsed.translations, r.translations);
        assert_eq!(parsed.ranked.len(), r.ranked.len());
        // Re-emission is byte-identical (permille rounding is stable).
        assert_eq!(parsed.to_json().to_json_pretty(), emitted);
        // Garbage input is rejected.
        assert!(SweepReport::from_json(&Value::Num(3.0)).is_err());
    }

    #[test]
    fn merge_reranks_and_rejects_overlap() {
        let full = sample();
        // 2 ranked + 3 pruned across the shards = a 5-scenario grid.
        let shard_a = SweepReport {
            models: 1,
            translations: 1,
            cache_loads: 0,
            pruned: 1,
            scenarios_simulated: 1,
            scenarios_pruned: 0,
            bounds_evaluated: 0,
            config: full.config.clone(),
            grid_scenarios: 5,
            grid_digest: "g".into(),
            shard: Some((2, 2)),
            lease: None,
            ranked: vec![full.ranked[1].clone()],
        };
        let shard_b = SweepReport {
            models: 1,
            translations: 1,
            cache_loads: 1,
            pruned: 2,
            scenarios_simulated: 1,
            scenarios_pruned: 0,
            bounds_evaluated: 0,
            config: full.config.clone(),
            grid_scenarios: 5,
            grid_digest: "g".into(),
            shard: Some((1, 2)),
            lease: None,
            ranked: vec![full.ranked[0].clone()],
        };
        let merged = SweepReport::merge(&[shard_a, shard_b]).unwrap();
        assert_eq!(merged.models, 2);
        assert_eq!(merged.translations, 2);
        assert_eq!(merged.cache_loads, 1);
        assert_eq!(merged.pruned, 3);
        assert_eq!(merged.config, full.config);
        assert_eq!(merged.shard, None);
        assert_eq!(merged.grid_scenarios, 5);
        // Re-ranked fastest-first regardless of shard order.
        assert_eq!(merged.ranked[0].scenario.model, "mlp");
        assert_eq!(merged.ranked[1].scenario.model, "vgg16");
        // Overlapping shards are rejected.
        let dup = SweepReport::merge(&[full.clone(), full]);
        assert!(dup.is_err());
    }

    #[test]
    fn merge_requires_a_complete_uniform_shard_set() {
        let full = sample();
        let stamped = |k: usize, n: usize, ranked: Vec<ScenarioResult>| SweepReport {
            models: ranked.len(),
            translations: ranked.len(),
            cache_loads: 0,
            pruned: 0,
            scenarios_simulated: ranked.len(),
            scenarios_pruned: 0,
            bounds_evaluated: 0,
            config: full.config.clone(),
            grid_scenarios: 2,
            grid_digest: "g".into(),
            shard: Some((k, n)),
            lease: None,
            ranked,
        };
        // A forgotten shard is rejected, not silently merged — and the
        // error names exactly which shards have no report.
        let err = SweepReport::merge(&[stamped(1, 3, vec![full.ranked[0].clone()])]).unwrap_err();
        assert!(err.to_string().contains("incomplete shard set"));
        assert!(err.to_string().contains("missing shard(s) 2/3, 3/3"), "unnamed gap: {err}");
        // Mixed shard widths are rejected even when keys are disjoint.
        let err = SweepReport::merge(&[
            stamped(1, 2, vec![full.ranked[0].clone()]),
            stamped(2, 3, vec![full.ranked[1].clone()]),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("different shard widths"));
        // Mixing stamped and unstamped inputs is rejected.
        let unstamped = SweepReport {
            shard: None,
            grid_scenarios: 2,
            grid_digest: "g".into(),
            ranked: vec![full.ranked[1].clone()],
            ..full.clone()
        };
        let err = SweepReport::merge(&[stamped(1, 2, vec![full.ranked[0].clone()]), unstamped])
            .unwrap_err();
        assert!(err.to_string().contains("mix sharded and unsharded"));
        // Stamped shards without grid provenance cannot prove coverage.
        let mut bare = stamped(1, 2, vec![full.ranked[0].clone()]);
        bare.grid_digest = String::new();
        let mut bare2 = stamped(2, 2, vec![full.ranked[1].clone()]);
        bare2.grid_digest = String::new();
        let err = SweepReport::merge(&[bare, bare2]).unwrap_err();
        assert!(err.to_string().contains("grid provenance"));
        // A truncated shard file (scenarios missing entirely) is caught
        // by the ranked+pruned coverage count.
        let err = SweepReport::merge(&[
            stamped(1, 2, Vec::new()),
            stamped(2, 2, vec![full.ranked[1].clone()]),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("covers 1 of 2 grid scenarios"));
        // Differing grid sizes are rejected.
        let mut other_grid = stamped(2, 2, vec![full.ranked[1].clone()]);
        other_grid.grid_scenarios = 99;
        let err = SweepReport::merge(&[stamped(1, 2, vec![full.ranked[0].clone()]), other_grid])
            .unwrap_err();
        assert!(err.to_string().contains("different grid"));
        // Same size but a different grid identity (digest) is rejected:
        // equal counts and configs are not grid equality.
        let mut other_axes = stamped(2, 2, vec![full.ranked[1].clone()]);
        other_axes.grid_digest = "feedface00000000".into();
        let err = SweepReport::merge(&[stamped(1, 2, vec![full.ranked[0].clone()]), other_axes])
            .unwrap_err();
        assert!(err.to_string().contains("different grid"));
        // The complete set merges fine.
        let merged = SweepReport::merge(&[
            stamped(1, 2, vec![full.ranked[0].clone()]),
            stamped(2, 2, vec![full.ranked[1].clone()]),
        ])
        .unwrap();
        assert_eq!(merged.ranked.len(), 2);
    }

    #[test]
    fn merge_rejects_mismatched_configs() {
        let a = sample();
        let mut b = sample();
        // Disjoint scenarios but a different config: still rejected.
        b.ranked.clear();
        b.config = crate::sweep::SweepConfig { npus: 64, ..Default::default() }.fingerprint();
        let err = SweepReport::merge(&[a, b]).unwrap_err();
        assert!(err.to_string().contains("different sweep configuration"));
    }

    #[test]
    fn merge_rejects_mixing_pruned_and_exhaustive_shards() {
        // A pruned shard truncates its ranking to K — merging it with an
        // exhaustive shard would present partial coverage as the full
        // design space. The top_k fingerprint stamp makes that a config
        // mismatch, caught by the existing guard.
        let a = sample();
        let mut b = sample();
        b.ranked.clear();
        b.scenarios_simulated = 2;
        b.config =
            crate::sweep::SweepConfig { top_k: Some(1), ..Default::default() }.fingerprint();
        let err = SweepReport::merge(&[a, b]).unwrap_err();
        assert!(err.to_string().contains("different sweep configuration"), "got: {err}");
    }

    #[test]
    fn merge_truncates_a_top_k_shard_union_and_checks_work_counters() {
        let full = sample();
        let top1 = crate::sweep::SweepConfig { top_k: Some(1), ..Default::default() }.fingerprint();
        // Two pruned shards of a 4-scenario grid: each simulated some,
        // bound-pruned the rest, and ranks only its local best.
        let shard = |k: usize, sim: usize, bp: usize, ranked: Vec<ScenarioResult>| SweepReport {
            models: 1,
            translations: 1,
            cache_loads: 0,
            pruned: 0,
            scenarios_simulated: sim,
            scenarios_pruned: bp,
            bounds_evaluated: sim + bp,
            config: top1.clone(),
            grid_scenarios: 4,
            grid_digest: "g".into(),
            shard: Some((k, 2)),
            lease: None,
            ranked,
        };
        let merged = SweepReport::merge(&[
            shard(1, 1, 1, vec![full.ranked[0].clone()]),
            shard(2, 2, 0, vec![full.ranked[1].clone()]),
        ])
        .unwrap();
        // Union of local winners re-ranked, truncated back to K = 1.
        assert_eq!(merged.ranked.len(), 1);
        assert_eq!(merged.ranked[0].scenario.model, "mlp");
        assert_eq!(merged.scenarios_simulated, 3);
        assert_eq!(merged.scenarios_pruned, 1);
        assert_eq!(merged.bounds_evaluated, 4);
        // The coverage check reads the work counters, not ranked.len():
        // a shard whose counters don't cover its range is rejected.
        let err = SweepReport::merge(&[
            shard(1, 1, 1, vec![full.ranked[0].clone()]),
            shard(2, 1, 0, vec![full.ranked[1].clone()]),
        ])
        .unwrap_err();
        assert!(err.to_string().contains("covers 3 of 4 grid scenarios"), "got: {err}");
    }

    #[test]
    fn bound_prune_counters_show_in_both_renderings() {
        let mut r = sample();
        r.scenarios_simulated = 2;
        r.scenarios_pruned = 7;
        r.bounds_evaluated = 9;
        let text = r.render_text();
        assert!(text.contains("top-K bound prune: 2 scenario(s) simulated, 7 skipped"));
        let v = crate::json::parse(&r.to_json().to_json_pretty()).unwrap();
        assert_eq!(v.get("scenarios_simulated").unwrap().as_u64(), Some(2));
        assert_eq!(v.get("scenarios_pruned").unwrap().as_u64(), Some(7));
        assert_eq!(v.get("bounds_evaluated").unwrap().as_u64(), Some(9));
        // bound_ns never leaks into the serialized ranked rows — pruned
        // and exhaustive reports must stay byte-identical there.
        r.ranked[0].bound_ns = 123;
        let with = r.to_json().to_json_pretty();
        r.ranked[0].bound_ns = 0;
        assert_eq!(r.to_json().to_json_pretty(), with);
    }

    #[test]
    fn shard_status_json_carries_the_failure_evidence() {
        let s = ShardStatus {
            shard: (2, 4),
            attempts: 3,
            exit_code: Some(42),
            stderr_tail: "failpoint: injected crash".into(),
            scenarios: 5,
            translations: 0,
            cache_loads: 2,
            pruned: 1,
            scenarios_simulated: 5,
            scenarios_pruned: 3,
            bounds_evaluated: 8,
            leases: 2,
            idle_ms: 17,
        };
        let v = s.to_json();
        assert_eq!(v.get("shard").unwrap().as_str(), Some("2/4"));
        assert_eq!(v.get("attempts").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("exit_code").unwrap().as_u64(), Some(42));
        assert_eq!(v.get("translations").unwrap().as_u64(), Some(0));
        assert_eq!(v.get("scenarios_simulated").unwrap().as_u64(), Some(5));
        assert_eq!(v.get("scenarios_pruned").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("bounds_evaluated").unwrap().as_u64(), Some(8));
        assert_eq!(v.get("leases").unwrap().as_u64(), Some(2));
        assert_eq!(v.get("idle_ms").unwrap().as_u64(), Some(17));
        assert_eq!(v.get("stderr_tail").unwrap().as_str(), Some("failpoint: injected crash"));
        // Signal deaths have no exit code: null, not a fake number.
        let killed = ShardStatus { exit_code: None, ..s };
        assert!(matches!(killed.to_json().get("exit_code"), Some(Value::Null)));
    }

    #[test]
    fn pruned_count_shows_in_both_renderings() {
        let mut r = sample();
        r.pruned = 3;
        let text = r.render_text();
        assert!(text.contains("pruned 3 infeasible"));
        assert_eq!(text.lines().count(), 2 + r.ranked.len() + 1);
        let v = crate::json::parse(&r.to_json().to_json_pretty()).unwrap();
        assert_eq!(v.get("pruned").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn lease_field_round_trips_and_rejects_malformed_input() {
        let mut r = sample();
        r.lease = Some(vec![3, 1, 4]);
        let emitted = r.to_json().to_json_pretty();
        let parsed = SweepReport::from_json(&crate::json::parse(&emitted).unwrap()).unwrap();
        assert_eq!(parsed.lease.as_deref(), Some(&[3, 1, 4][..]));
        assert_eq!(parsed.to_json().to_json_pretty(), emitted);
        // Absent (pre-lease report) and null both mean "no lease".
        r.lease = None;
        let parsed =
            SweepReport::from_json(&crate::json::parse(&r.to_json().to_json_pretty()).unwrap())
                .unwrap();
        assert_eq!(parsed.lease, None);
        // Present-but-malformed is an error, not silently dropped.
        let mut doc = crate::json::parse(&emitted).unwrap();
        if let Value::Obj(map) = &mut doc {
            map.insert("lease".into(), Value::Str("3,1,4".into()));
        }
        assert!(SweepReport::from_json(&doc).is_err());
    }

    /// One-lease report over the given grid indices, matching the
    /// coverage accounting `StreamingMerge::absorb` enforces.
    fn lease_batch(
        full: &SweepReport,
        indices: &[usize],
        ranked: Vec<ScenarioResult>,
    ) -> SweepReport {
        SweepReport {
            models: 1,
            translations: 0,
            cache_loads: 1,
            pruned: 0,
            scenarios_simulated: indices.len(),
            scenarios_pruned: 0,
            bounds_evaluated: 0,
            config: full.config.clone(),
            grid_scenarios: full.grid_scenarios,
            grid_digest: full.grid_digest.clone(),
            shard: None,
            lease: Some(indices.to_vec()),
            ranked,
        }
    }

    #[test]
    fn streaming_merge_matches_the_batch_merge() {
        let full = sample();
        let mut m = StreamingMerge::new(full.config.clone(), 2, full.grid_digest.clone());
        m.absorb(&lease_batch(&full, &[1], vec![full.ranked[1].clone()]), &[1]).unwrap();
        assert_eq!(m.covered(), 1);
        m.absorb(&lease_batch(&full, &[0], vec![full.ranked[0].clone()]), &[0]).unwrap();
        let merged = m.finalize().unwrap();
        // Re-ranked fastest-first regardless of lease arrival order.
        assert_eq!(merged.ranked[0].scenario.model, "mlp");
        assert_eq!(merged.ranked[1].scenario.model, "vgg16");
        assert_eq!(merged.models, 2);
        assert_eq!(merged.cache_loads, 2);
        assert_eq!(merged.scenarios_simulated, 2);
        assert_eq!(merged.shard, None);
        assert_eq!(merged.lease, None);
    }

    #[test]
    fn streaming_merge_enforces_the_batch_merge_guard_set() {
        let full = sample();
        let mut m = StreamingMerge::new(full.config.clone(), 2, full.grid_digest.clone());
        // Wrong config fingerprint.
        let mut wrong_cfg = lease_batch(&full, &[0], vec![full.ranked[0].clone()]);
        wrong_cfg.config =
            crate::sweep::SweepConfig { npus: 64, ..Default::default() }.fingerprint();
        let err = m.absorb(&wrong_cfg, &[0]).unwrap_err();
        assert!(err.to_string().contains("different sweep configuration"), "got: {err}");
        // Wrong grid identity.
        let mut wrong_grid = lease_batch(&full, &[0], vec![full.ranked[0].clone()]);
        wrong_grid.grid_digest = "feedface00000000".into();
        let err = m.absorb(&wrong_grid, &[0]).unwrap_err();
        assert!(err.to_string().contains("different grid"), "got: {err}");
        // Lease echo must match the dispatched indices.
        let err = m
            .absorb(&lease_batch(&full, &[1], vec![full.ranked[1].clone()]), &[0])
            .unwrap_err();
        assert!(err.to_string().contains("not the"), "got: {err}");
        // Coverage accounting: counters must equal the lease size.
        let mut short = lease_batch(&full, &[0, 1], vec![full.ranked[0].clone()]);
        short.scenarios_simulated = 1;
        let err = m.absorb(&short, &[0, 1]).unwrap_err();
        assert!(err.to_string().contains("accounts for 1 of 2"), "got: {err}");
        // Out-of-range index.
        let err = m
            .absorb(&lease_batch(&full, &[9], vec![full.ranked[0].clone()]), &[9])
            .unwrap_err();
        assert!(err.to_string().contains("outside"), "got: {err}");
        // A good batch lands; re-leasing the same index is rejected.
        m.absorb(&lease_batch(&full, &[0], vec![full.ranked[0].clone()]), &[0]).unwrap();
        let err = m
            .absorb(&lease_batch(&full, &[0], vec![full.ranked[0].clone()]), &[0])
            .unwrap_err();
        assert!(err.to_string().contains("leases overlap"), "got: {err}");
        // A different index but a duplicate scenario key is rejected.
        let err = m
            .absorb(&lease_batch(&full, &[1], vec![full.ranked[0].clone()]), &[1])
            .unwrap_err();
        assert!(err.to_string().contains("duplicate scenario"), "got: {err}");
        // Finalizing with a hole is rejected, never a partial ranking.
        let err = m.finalize().unwrap_err();
        assert!(err.to_string().contains("covers 1 of 2"), "got: {err}");
    }

    #[test]
    fn streaming_merge_keeps_a_live_top_k_cutoff() {
        let full = sample();
        let top1 = crate::sweep::SweepConfig { top_k: Some(1), ..Default::default() }.fingerprint();
        let mut m = StreamingMerge::new(top1.clone(), 2, full.grid_digest.clone());
        assert_eq!(m.kth_best_ns(), None);
        let mut slow = lease_batch(&full, &[1], vec![full.ranked[1].clone()]);
        slow.config = top1.clone();
        m.absorb(&slow, &[1]).unwrap();
        // One result in: the cutoff is the slow scenario's time.
        assert_eq!(m.kth_best_ns(), Some(20));
        let mut fast = lease_batch(&full, &[0], vec![full.ranked[0].clone()]);
        fast.config = top1.clone();
        m.absorb(&fast, &[0]).unwrap();
        // The faster batch tightened the fleet-wide cutoff.
        assert_eq!(m.kth_best_ns(), Some(10));
        let merged = m.finalize().unwrap();
        // Folded union truncated back to K = 1.
        assert_eq!(merged.ranked.len(), 1);
        assert_eq!(merged.ranked[0].scenario.model, "mlp");
        assert_eq!(merged.scenarios_simulated, 2);
    }
}
