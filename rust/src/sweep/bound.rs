//! Analytic per-scenario makespan lower bounds — the branch-and-bound
//! pruning pass behind `sweep --top K`.
//!
//! The discrete-event makespan of any scenario is at least the total
//! busy time of its busiest resource: the simulator schedules every
//! compute phase of the flat strategies on one representative-NPU
//! stream, every collective leg on its network *dimension's* exclusive
//! resource, and the pipeline path's per-stage work on one resource per
//! stage. [`scenario_bound_ns`] therefore charges
//!
//! * **compute** — the serial critical path
//!   ([`passes::serial_compute_ns`]: fwd + input-grad + weight-grad +
//!   update per layer) for the flat strategies, or the busiest stage of
//!   the *identical* greedy partition the pipeline simulation uses
//!   ([`crate::sim::partition_compute_costs`]); and
//! * **communication** — per network dimension, the algorithm-priced α-β
//!   completion time ([`collective_ns`] with that dimension's
//!   [`crate::sim::CollectiveAlgo`]) of every collective leg the
//!   scenario's comm plan routes onto it, mirroring
//!   [`crate::sim::CommRouter`] exactly: activation collectives on the
//!   scale-up dimension, weight-grad all-reduces split into the same
//!   chunked RS → per-dim AR → AG legs (chunk count from the scenario's
//!   [`super::CommSchedule`]), stage-boundary point-to-point transfers
//!   on the outermost dimension. The comm term is the **max over
//!   dimensions** of the per-dimension busy sums — each dimension is one
//!   exclusive resource, so the makespan is at least the busiest one,
//!   whatever overlap the DES finds between dimensions,
//!
//! and the bound is the max of the two. Both terms are *exact* resource
//! busy times, never optimistic models of them, so the bound is
//! admissible: `bound(scenario) <= simulated iteration_ns`, always —
//! per collective algorithm and per dimension count (asserted across
//! the zoo and the co-design grid in `tests/prune_equivalence.rs`).
//! That admissibility is what makes `--top K` an **exact** mode rather
//! than a heuristic — a scenario is skipped only when its bound already
//! exceeds the K-th best *simulated* iteration time, which no skipped
//! scenario can beat.
//!
//! No DES runs here: the bound reads the cached compute-annotated IR
//! and the scenario's (cheap, parallelism-dependent) comm plan, so
//! bounding a scenario costs microseconds where simulating it costs
//! milliseconds. [`BoundMemo`] additionally memoizes every
//! (dimension × algorithm × collective × size) completion time across
//! sibling scenarios — grids vary parallelism and schedule far more
//! often than payload sizes, so most scenarios hit the memo instead of
//! the α-β model. The memo key is the dimension's full content (kind,
//! algorithm, size, bandwidth, latency), never a label hash: a
//! collision between two different fabrics would silently price one
//! with the other's latencies and break admissibility. The bound pass
//! runs **in parallel** (one memo per pool worker): because the bound
//! is a pure function of (scenario, cache, config), splitting the memo
//! across workers changes only which worker pays each cache miss —
//! every bound value, and therefore every pruning decision, is
//! byte-identical to a serial pass.

use super::{Scenario, SweepConfig, WorkloadCache};
use crate::error::{Error, Result};
use crate::ir::{passes, ModelIR};
use crate::sim::collectives::p2p_ns;
use crate::sim::system::MAX_CHUNKS;
use crate::sim::{
    collective_ns, partition_compute_costs, CollectiveAlgo, NetDim, Network, TopologyKind,
    MAX_DIMS,
};
use crate::translator::CommPlan;
use crate::workload::{CommType, Parallelism};
use std::collections::BTreeMap;

/// Stable scalar codes for the memo key — the enums don't carry `Ord`,
/// and the memo must not depend on discriminant layout.
fn kind_code(kind: TopologyKind) -> u8 {
    match kind {
        TopologyKind::Ring => 0,
        TopologyKind::FullyConnected => 1,
        TopologyKind::Switch => 2,
        TopologyKind::Torus2D => 3,
        TopologyKind::RailOptimized => 4,
        TopologyKind::Dragonfly => 5,
    }
}

fn algo_code(algo: CollectiveAlgo) -> u8 {
    match algo {
        CollectiveAlgo::Ring => 0,
        CollectiveAlgo::HalvingDoubling => 1,
        CollectiveAlgo::Direct => 2,
        CollectiveAlgo::DimOrdered => 3,
    }
}

fn comm_code(comm: CommType) -> u8 {
    match comm {
        CommType::None => 0,
        CommType::AllReduce => 1,
        CommType::AllGather => 2,
        CommType::ReduceScatter => 3,
        CommType::AllToAll => 4,
    }
}

/// Full-content memo key for one (dimension, collective, payload)
/// lookup. Every field that feeds the α-β model is in the key — float
/// params by bit pattern — so two dimensions price identically iff they
/// *are* identical.
type DimKey = (u8, u8, usize, u64, u64, u8, u64);

fn dim_key(dim: &NetDim, comm: CommType, bytes: u64) -> DimKey {
    (
        kind_code(dim.kind),
        algo_code(dim.algo),
        dim.npus,
        dim.bandwidth_gbps.to_bits(),
        dim.latency_ns.to_bits(),
        comm_code(comm),
        bytes,
    )
}

/// Memoized collective-latency table shared across one sweep's bound
/// pass, keyed by the dimension's full content × collective × payload.
/// Valid across any mix of scenarios (the key carries everything the
/// model reads), carrying the comm-plan buffer too, so a worker's bound
/// pass re-plans without heap allocation. The parallel bound pass builds
/// one memo per pool worker (the memo is an accelerator, never an
/// input: bounds are pure).
#[derive(Debug, Default)]
pub struct BoundMemo {
    coll: BTreeMap<DimKey, u64>,
    comms: Vec<CommPlan>,
    lookups: usize,
    misses: usize,
}

impl BoundMemo {
    /// Fresh, empty memo.
    pub fn new() -> BoundMemo {
        BoundMemo::default()
    }

    /// Collective latency lookups that were served from the memo.
    pub fn hits(&self) -> usize {
        self.lookups - self.misses
    }

    /// Total collective latency lookups.
    pub fn lookups(&self) -> usize {
        self.lookups
    }

    /// Memoized [`collective_ns`] under the dimension's own algorithm —
    /// exactly what [`crate::sim::CommRouter`] schedules.
    fn collective(&mut self, comm: CommType, bytes: u64, dim: &NetDim) -> u64 {
        if comm == CommType::None || bytes == 0 {
            return 0;
        }
        self.lookups += 1;
        *self.coll.entry(dim_key(dim, comm, bytes)).or_insert_with(|| {
            self.misses += 1;
            collective_ns(comm, bytes, dim.algo, dim)
        })
    }
}

/// Per-dimension busy accumulator for one scenario's comm plan.
type DimBusy = [u64; MAX_DIMS];

/// Mirror of [`crate::sim::CommRouter::issue`]'s routing, charging each
/// leg's duration to its dimension's busy counter instead of adding DES
/// tasks. The byte math (chunk split, shard division) matches the
/// router statement for statement — the bound prices exactly the tasks
/// the DES would schedule.
fn route_busy(
    comm: CommType,
    bytes: u64,
    prefer_scale_up: bool,
    net: &Network,
    chunks: usize,
    memo: &mut BoundMemo,
    busy: &mut DimBusy,
) {
    if comm == CommType::None || bytes == 0 {
        return;
    }
    let dims = &net.dims;
    if dims.len() == 1 || prefer_scale_up {
        busy[0] += memo.collective(comm, bytes, &dims[0]);
        return;
    }
    match comm {
        CommType::AllReduce => {
            // Hierarchical chunked route: RS(dim0) → AR(dims 1..) on the
            // shard → AG(dim0), `chunks` sub-collectives. Every chunk is
            // the same size, so one pricing per leg × the chunk count.
            let c = chunks.clamp(1, MAX_CHUNKS);
            let chunk_bytes = (bytes / c as u64).max(1);
            let d0 = &dims[0];
            let rs = memo.collective(CommType::ReduceScatter, chunk_bytes, d0);
            let ag = memo.collective(CommType::AllGather, chunk_bytes, d0);
            busy[0] += c as u64 * (rs + ag);
            let mut shard = chunk_bytes / d0.npus.max(1) as u64;
            for (i, d) in dims.iter().enumerate().skip(1) {
                busy[i] += c as u64 * memo.collective(CommType::AllReduce, shard, d);
                shard = (shard / d.npus.max(1) as u64).max(1);
            }
        }
        other => {
            let i = dims.len() - 1;
            busy[i] += memo.collective(other, bytes, &dims[i]);
        }
    }
}

/// Admissible lower bound on one scenario's simulated `iteration_ns`,
/// computed from the cached IR without running the DES. Errors on a
/// model missing from the cache or a network the scenario's spec cannot
/// materialize (inadmissible algorithm, non-factorable torus) — the
/// same errors the simulation path raises.
pub fn scenario_bound_ns(
    sc: &Scenario,
    cache: &WorkloadCache,
    cfg: &SweepConfig,
    memo: &mut BoundMemo,
) -> Result<u64> {
    let ir = cache.ir(&sc.model).ok_or_else(|| {
        Error::Config(format!("model '{}' missing from the workload cache", sc.model))
    })?;
    let opts = super::scenario_opts(sc, cfg);
    // The same network the simulation path materializes — per-dim
    // algorithms included, so every leg is priced under the algorithm
    // the DES would run it with.
    let net = sc.network.materialize(cfg.npus, cfg.bandwidth_gbps, cfg.latency_ns)?;
    let chunks = sc.collective.system().chunks.chunks;
    // The same comm plan the simulation path derives — the bound prices
    // exactly the collectives the DES would schedule, no re-modeling.
    let mut comms = std::mem::take(&mut memo.comms);
    passes::plan_comm_into(ir, opts, &mut comms);
    let ns = match sc.parallelism {
        Parallelism::Pipeline => pipeline_bound_ns(ir, &comms, cfg, &net, chunks, memo),
        _ => flat_bound_ns(ir, &comms, &net, chunks, memo),
    };
    memo.comms = comms;
    Ok(ns)
}

/// DATA / MODEL / HYBRID: one compute stream runs every phase serially,
/// and each network dimension's resource runs every leg routed onto it
/// serially — the iteration is at least the busiest of them all.
fn flat_bound_ns(
    ir: &ModelIR,
    comms: &[CommPlan],
    net: &Network,
    chunks: usize,
    memo: &mut BoundMemo,
) -> u64 {
    let compute = passes::serial_compute_ns(ir);
    let mut busy: DimBusy = [0; MAX_DIMS];
    for p in comms {
        // Activation collectives block on the scale-up dimension; the
        // weight-grad reduction takes the hierarchical route.
        route_busy(p.fwd.0, p.fwd.1, true, net, chunks, memo, &mut busy);
        route_busy(p.ig.0, p.ig.1, true, net, chunks, memo, &mut busy);
        route_busy(p.wg.0, p.wg.1, false, net, chunks, memo, &mut busy);
    }
    let comm = busy.iter().copied().max().unwrap_or(0);
    compute.max(comm)
}

/// PIPELINE: per-stage compute busy time under the *identical* greedy
/// layer partition, microbatch rounding and all; network busy time is
/// the per-stage gradient all-reduces (hierarchically routed, like the
/// DES) plus the 2·(stages−1)·microbatch stage-boundary transfers on
/// the outermost dimension, maxed across dimensions.
fn pipeline_bound_ns(
    ir: &ModelIR,
    comms: &[CommPlan],
    cfg: &SweepConfig,
    net: &Network,
    chunks: usize,
    memo: &mut BoundMemo,
) -> u64 {
    let n = ir.num_layers();
    let (stages, micro, boundary_bytes) = super::scenario_pipeline_shape(ir.summary(), cfg);
    let stages = stages.clamp(1, n);
    let costs = ir.costs();
    let bounds = partition_compute_costs(n, stages, |i| costs[i].fwd_ns);
    let micro_u = micro as u64;
    let mut compute = 0u64;
    let mut busy: DimBusy = [0; MAX_DIMS];
    for s in 0..stages {
        let stage_costs = &costs[bounds[s]..bounds[s + 1]];
        // The simulator's stage_time divides the full-batch sums by the
        // microbatch count and schedules `micro` tasks of that duration,
        // so the per-iteration busy time keeps the integer rounding.
        let fwd: u64 = stage_costs.iter().map(|c| c.fwd_ns).sum();
        let bwd: u64 = stage_costs.iter().map(|c| c.ig_ns + c.wg_ns).sum();
        let upd: u64 = stage_costs.iter().map(|c| c.update_ns).sum();
        compute = compute.max(micro_u * (fwd / micro_u) + micro_u * (bwd / micro_u) + upd);
        // One all-reduce per stage over the layers the comm pass marked
        // for gradient reduction (the pipeline path drops every other
        // planned collective — so does the bound).
        let wg_bytes: u64 = comms[bounds[s]..bounds[s + 1]]
            .iter()
            .filter(|p| p.wg.0 == CommType::AllReduce)
            .map(|p| p.wg.1)
            .sum();
        route_busy(CommType::AllReduce, wg_bytes, false, net, chunks, memo, &mut busy);
    }
    // Stage-boundary transfers run on the outermost dimension, exactly
    // like `CommRouter::p2p`.
    let last = net.dims.len() - 1;
    busy[last] += 2 * (stages as u64 - 1) * micro_u * p2p_ns(boundary_bytes / micro_u, &net.dims[last]);
    let comm = busy.iter().copied().max().unwrap_or(0);
    compute.max(comm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::NetworkSpec;
    use crate::sweep::{build_sweep_cache, CommSchedule};

    fn cache_for(model: &str, cfg: &SweepConfig) -> WorkloadCache {
        build_sweep_cache(&[model.to_string()], cfg, None).unwrap()
    }

    #[test]
    fn memo_dedups_collective_latency_lookups() {
        let cfg = SweepConfig { batch: 4, npus: 8, ..Default::default() };
        let cache = cache_for("mlp", &cfg);
        let mut memo = BoundMemo::new();
        let sc = |c| Scenario {
            model: "mlp".into(),
            parallelism: Parallelism::Data,
            network: NetworkSpec::from_kind(TopologyKind::Ring),
            collective: c,
        };
        let a = scenario_bound_ns(&sc(CommSchedule::Direct), &cache, &cfg, &mut memo).unwrap();
        assert_eq!(memo.hits(), memo.lookups() - memo.misses);
        let cold_misses = memo.misses;
        // A sibling scenario differing only in schedule prices the same
        // payloads on a single-dim fabric: every lookup hits the memo.
        let b = scenario_bound_ns(&sc(CommSchedule::Pipelined), &cache, &cfg, &mut memo).unwrap();
        assert_eq!(a, b, "schedule axis cannot change a single-dim bound");
        assert_eq!(memo.misses, cold_misses, "sibling scenario should be all memo hits");
        assert!(memo.hits() > 0);
    }

    #[test]
    fn bound_is_positive_and_strategy_dependent() {
        let cfg = SweepConfig { batch: 4, npus: 8, ..Default::default() };
        let cache = cache_for("mlp", &cfg);
        let mut memo = BoundMemo::new();
        let mut bound = |p| {
            let sc = Scenario {
                model: "mlp".into(),
                parallelism: p,
                network: NetworkSpec::from_kind(TopologyKind::Ring),
                collective: CommSchedule::Pipelined,
            };
            scenario_bound_ns(&sc, &cache, &cfg, &mut memo).unwrap()
        };
        let data = bound(Parallelism::Data);
        let model = bound(Parallelism::Model);
        let pipe = bound(Parallelism::Pipeline);
        assert!(data > 0 && model > 0 && pipe > 0);
        // The serial-compute floor holds for every flat strategy.
        let ir = cache.ir("mlp").unwrap();
        let floor = passes::serial_compute_ns(ir);
        assert!(data >= floor && model >= floor);
    }

    #[test]
    fn multi_dim_bounds_route_like_the_simulator() {
        let cfg = SweepConfig { batch: 4, npus: 8, ..Default::default() };
        let cache = cache_for("mlp", &cfg);
        let mut memo = BoundMemo::new();
        let bound = |spec: &str, memo: &mut BoundMemo| {
            let sc = Scenario {
                model: "mlp".into(),
                parallelism: Parallelism::Data,
                network: NetworkSpec::parse(spec).unwrap(),
                collective: CommSchedule::Pipelined,
            };
            scenario_bound_ns(&sc, &cache, &cfg, memo).unwrap()
        };
        // Multi-dim bounds exist and respect the serial-compute floor.
        let two = bound("ring:4x300g@700ns/switch:2x25g@5us", &mut memo);
        let three =
            bound("ring:4x300g@700ns/rail:4x50g@2us+hd/switch:2x25g@5us+direct", &mut memo);
        let ir = cache.ir("mlp").unwrap();
        let floor = passes::serial_compute_ns(ir);
        assert!(two >= floor && three >= floor);
        // The per-dimension algorithm is part of the price: swapping the
        // scale-out algorithm on an otherwise identical fabric moves the
        // per-dim busy (and the memo sees distinct keys, never a
        // colliding one).
        let misses_before = memo.misses;
        let hd = bound("ring:8x1g@700ns/switch:4x1g@5us+hd", &mut memo);
        let direct = bound("ring:8x1g@700ns/switch:4x1g@5us+direct", &mut memo);
        assert_ne!(hd, direct, "algorithm choice must reprice the scale-out legs");
        assert!(memo.misses > misses_before);
    }

    #[test]
    fn inadmissible_spec_is_a_config_error_at_bound_time() {
        let cfg = SweepConfig { batch: 4, npus: 8, ..Default::default() };
        let cache = cache_for("mlp", &cfg);
        let sc = Scenario {
            model: "mlp".into(),
            parallelism: Parallelism::Data,
            // Prime torus: parses (size is legal grammar) but cannot
            // materialize — the bound surfaces the same typed error the
            // simulation path would.
            network: NetworkSpec::parse("torus2d:7x100g@500ns").unwrap(),
            collective: CommSchedule::Pipelined,
        };
        let err = scenario_bound_ns(&sc, &cache, &cfg, &mut BoundMemo::new()).unwrap_err();
        assert!(err.to_string().contains("factor"), "got: {err}");
    }

    #[test]
    fn unknown_model_is_a_config_error() {
        let cfg = SweepConfig::default();
        let cache = cache_for("mlp", &cfg);
        let sc = Scenario {
            model: "made-up".into(),
            parallelism: Parallelism::Data,
            network: NetworkSpec::from_kind(TopologyKind::Ring),
            collective: CommSchedule::Pipelined,
        };
        assert!(scenario_bound_ns(&sc, &cache, &cfg, &mut BoundMemo::new()).is_err());
    }
}
