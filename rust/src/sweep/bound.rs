//! Analytic per-scenario makespan lower bounds — the branch-and-bound
//! pruning pass behind `sweep --top K`.
//!
//! The discrete-event makespan of any scenario is at least the total
//! busy time of its busiest resource: the simulator schedules every
//! compute phase of the flat strategies on one representative-NPU
//! stream, every collective of a single-dimension fabric on one network
//! resource, and the pipeline path's per-stage work on one resource per
//! stage. [`scenario_bound_ns`] therefore charges
//!
//! * **compute** — the serial critical path
//!   ([`passes::serial_compute_ns`]: fwd + input-grad + weight-grad +
//!   update per layer) for the flat strategies, or the busiest stage of
//!   the *identical* greedy partition the pipeline simulation uses
//!   ([`crate::sim::partition_compute_costs`]); and
//! * **communication** — the ideal-bandwidth α-β completion time
//!   ([`collective_ns`]) of every collective in the scenario's comm plan
//!   (plus the stage-boundary point-to-point transfers for pipeline),
//!
//! and the bound is the max of the two. Both terms are *exact* resource
//! busy times, never optimistic models of them, so the bound is
//! admissible: `bound(scenario) <= simulated iteration_ns`, always
//! (asserted across the zoo in `tests/prune_equivalence.rs`). That
//! admissibility is what makes `--top K` an **exact** mode rather than a
//! heuristic — a scenario is skipped only when its bound already
//! exceeds the K-th best *simulated* iteration time, which no skipped
//! scenario can beat.
//!
//! No DES runs here: the bound reads the cached compute-annotated IR
//! and the scenario's (cheap, parallelism-dependent) comm plan, so
//! bounding a scenario costs microseconds where simulating it costs
//! milliseconds. [`BoundMemo`] additionally memoizes every
//! (topology × collective × size) completion time across sibling
//! scenarios — grids vary parallelism and collective algorithm far more
//! often than payload sizes, so most scenarios hit the memo instead of
//! the α-β model. The bound pass runs **in parallel** (one memo per
//! pool worker): because the bound is a pure function of
//! (scenario, cache, config), splitting the memo across workers changes
//! only which worker pays each cache miss — every bound value, and
//! therefore every pruning decision, is byte-identical to a serial
//! pass.

use super::{Scenario, SweepConfig, WorkloadCache};
use crate::error::{Error, Result};
use crate::ir::{passes, ModelIR};
use crate::sim::collectives::p2p_ns;
use crate::sim::{collective_ns, partition_compute_costs, NetDim, TopologyKind};
use crate::translator::CommPlan;
use crate::workload::{CommType, Parallelism};
use std::collections::BTreeMap;

/// Stable map key for one (topology, collective) pair — the enums don't
/// carry `Ord`, and the memo must not depend on discriminant layout.
fn code(topology: TopologyKind, comm: CommType) -> (u8, u8) {
    let t = match topology {
        TopologyKind::Ring => 0,
        TopologyKind::FullyConnected => 1,
        TopologyKind::Switch => 2,
        TopologyKind::Torus2D => 3,
    };
    let c = match comm {
        CommType::None => 0,
        CommType::AllReduce => 1,
        CommType::AllGather => 2,
        CommType::ReduceScatter => 3,
        CommType::AllToAll => 4,
    };
    (t, c)
}

/// Memoized collective-latency table shared across one sweep's bound
/// pass, keyed by (topology × collective × payload bytes). Valid within
/// a single [`SweepConfig`] — NPU count, bandwidth and latency are
/// config-fixed, so only the scenario axes vary — and carrying the
/// comm-plan buffer too, so a worker's bound pass re-plans without heap
/// allocation. The parallel bound pass builds one memo per pool worker
/// (the memo is an accelerator, never an input: bounds are pure).
#[derive(Debug, Default)]
pub struct BoundMemo {
    coll: BTreeMap<(u8, u8, u64), u64>,
    comms: Vec<CommPlan>,
    lookups: usize,
    misses: usize,
}

impl BoundMemo {
    /// Fresh, empty memo.
    pub fn new() -> BoundMemo {
        BoundMemo::default()
    }

    /// Collective latency lookups that were served from the memo.
    pub fn hits(&self) -> usize {
        self.lookups - self.misses
    }

    /// Total collective latency lookups.
    pub fn lookups(&self) -> usize {
        self.lookups
    }

    /// Memoized [`collective_ns`].
    fn collective(&mut self, comm: CommType, bytes: u64, dim: &NetDim) -> u64 {
        if comm == CommType::None || bytes == 0 {
            return 0;
        }
        self.lookups += 1;
        let (t, c) = code(dim.kind, comm);
        *self.coll.entry((t, c, bytes)).or_insert_with(|| {
            self.misses += 1;
            collective_ns(comm, bytes, dim)
        })
    }
}

/// Admissible lower bound on one scenario's simulated `iteration_ns`,
/// computed from the cached IR without running the DES. Errors only on
/// a model missing from the cache (the same error the simulation path
/// raises).
pub fn scenario_bound_ns(
    sc: &Scenario,
    cache: &WorkloadCache,
    cfg: &SweepConfig,
    memo: &mut BoundMemo,
) -> Result<u64> {
    let ir = cache.ir(&sc.model).ok_or_else(|| {
        Error::Config(format!("model '{}' missing from the workload cache", sc.model))
    })?;
    let opts = super::scenario_opts(sc, cfg);
    let dim = NetDim {
        kind: sc.topology,
        npus: cfg.npus,
        bandwidth_gbps: cfg.bandwidth_gbps,
        latency_ns: cfg.latency_ns,
    };
    // The same comm plan the simulation path derives — the bound prices
    // exactly the collectives the DES would schedule, no re-modeling.
    let mut comms = std::mem::take(&mut memo.comms);
    passes::plan_comm_into(ir, opts, &mut comms);
    let ns = match sc.parallelism {
        Parallelism::Pipeline => pipeline_bound_ns(ir, &comms, cfg, &dim, memo),
        _ => flat_bound_ns(ir, &comms, &dim, memo),
    };
    memo.comms = comms;
    Ok(ns)
}

/// DATA / MODEL / HYBRID: one compute stream runs every phase serially,
/// one network resource runs every collective serially — the iteration
/// is at least the busier of the two.
fn flat_bound_ns(ir: &ModelIR, comms: &[CommPlan], dim: &NetDim, memo: &mut BoundMemo) -> u64 {
    let compute = passes::serial_compute_ns(ir);
    let comm: u64 = comms
        .iter()
        .map(|p| {
            memo.collective(p.fwd.0, p.fwd.1, dim)
                + memo.collective(p.ig.0, p.ig.1, dim)
                + memo.collective(p.wg.0, p.wg.1, dim)
        })
        .sum();
    compute.max(comm)
}

/// PIPELINE: per-stage compute busy time under the *identical* greedy
/// layer partition, microbatch rounding and all; network busy time is
/// the per-stage gradient all-reduces plus the 2·(stages−1)·microbatch
/// stage-boundary transfers the schedule issues per iteration.
fn pipeline_bound_ns(
    ir: &ModelIR,
    comms: &[CommPlan],
    cfg: &SweepConfig,
    dim: &NetDim,
    memo: &mut BoundMemo,
) -> u64 {
    let n = ir.num_layers();
    let (stages, micro, boundary_bytes) = super::scenario_pipeline_shape(ir.summary(), cfg);
    let stages = stages.clamp(1, n);
    let costs = ir.costs();
    let bounds = partition_compute_costs(n, stages, |i| costs[i].fwd_ns);
    let micro_u = micro as u64;
    let mut compute = 0u64;
    let mut comm = 0u64;
    for s in 0..stages {
        let stage_costs = &costs[bounds[s]..bounds[s + 1]];
        // The simulator's stage_time divides the full-batch sums by the
        // microbatch count and schedules `micro` tasks of that duration,
        // so the per-iteration busy time keeps the integer rounding.
        let fwd: u64 = stage_costs.iter().map(|c| c.fwd_ns).sum();
        let bwd: u64 = stage_costs.iter().map(|c| c.ig_ns + c.wg_ns).sum();
        let upd: u64 = stage_costs.iter().map(|c| c.update_ns).sum();
        compute = compute.max(micro_u * (fwd / micro_u) + micro_u * (bwd / micro_u) + upd);
        // One all-reduce per stage over the layers the comm pass marked
        // for gradient reduction (the pipeline path drops every other
        // planned collective — so does the bound).
        let wg_bytes: u64 = comms[bounds[s]..bounds[s + 1]]
            .iter()
            .filter(|p| p.wg.0 == CommType::AllReduce)
            .map(|p| p.wg.1)
            .sum();
        comm += memo.collective(CommType::AllReduce, wg_bytes, dim);
    }
    comm += 2 * (stages as u64 - 1) * micro_u * p2p_ns(boundary_bytes / micro_u, dim);
    compute.max(comm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::{build_sweep_cache, CollectiveAlgo};

    fn cache_for(model: &str, cfg: &SweepConfig) -> WorkloadCache {
        build_sweep_cache(&[model.to_string()], cfg, None).unwrap()
    }

    #[test]
    fn memo_dedups_collective_latency_lookups() {
        let cfg = SweepConfig { batch: 4, npus: 8, ..Default::default() };
        let cache = cache_for("mlp", &cfg);
        let mut memo = BoundMemo::new();
        let sc = |c| Scenario {
            model: "mlp".into(),
            parallelism: Parallelism::Data,
            topology: TopologyKind::Ring,
            collective: c,
        };
        let a = scenario_bound_ns(&sc(CollectiveAlgo::Direct), &cache, &cfg, &mut memo).unwrap();
        assert_eq!(memo.hits(), memo.lookups() - memo.misses);
        let cold_misses = memo.misses;
        // A sibling scenario differing only in collective algorithm
        // prices the same payloads: every lookup hits the memo.
        let b = scenario_bound_ns(&sc(CollectiveAlgo::Pipelined), &cache, &cfg, &mut memo).unwrap();
        assert_eq!(a, b, "collective-algo axis cannot change a single-dim bound");
        assert_eq!(memo.misses, cold_misses, "sibling scenario should be all memo hits");
        assert!(memo.hits() > 0);
    }

    #[test]
    fn bound_is_positive_and_strategy_dependent() {
        let cfg = SweepConfig { batch: 4, npus: 8, ..Default::default() };
        let cache = cache_for("mlp", &cfg);
        let mut memo = BoundMemo::new();
        let mut bound = |p| {
            let sc = Scenario {
                model: "mlp".into(),
                parallelism: p,
                topology: TopologyKind::Ring,
                collective: CollectiveAlgo::Pipelined,
            };
            scenario_bound_ns(&sc, &cache, &cfg, &mut memo).unwrap()
        };
        let data = bound(Parallelism::Data);
        let model = bound(Parallelism::Model);
        let pipe = bound(Parallelism::Pipeline);
        assert!(data > 0 && model > 0 && pipe > 0);
        // The serial-compute floor holds for every flat strategy.
        let ir = cache.ir("mlp").unwrap();
        let floor = passes::serial_compute_ns(ir);
        assert!(data >= floor && model >= floor);
    }

    #[test]
    fn unknown_model_is_a_config_error() {
        let cfg = SweepConfig::default();
        let cache = cache_for("mlp", &cfg);
        let sc = Scenario {
            model: "made-up".into(),
            parallelism: Parallelism::Data,
            topology: TopologyKind::Ring,
            collective: CollectiveAlgo::Pipelined,
        };
        assert!(scenario_bound_ns(&sc, &cache, &cfg, &mut BoundMemo::new()).is_err());
    }
}
