//! Persistent fleet completion journal — crash-durable lease records.
//!
//! The work-stealing fleet scheduler ([`super::fleet::run_fleet`])
//! appends one record per completed lease: the scenario indices the
//! lease covered plus the worker's full per-lease [`SweepReport`] JSON.
//! A `meta.json` header pins the journal to one design space (config
//! fingerprint + grid identity). Every file is written with the same
//! temp-file + rename idiom as the IR disk cache, so a fleet killed at
//! any instant leaves either a complete committed record or an ignored
//! `*.tmp.<pid>` leftover — never a torn record.
//!
//! On `--resume`, the orchestrator re-opens the directory, verifies the
//! header against the *current* invocation's fingerprint (a journal
//! recorded for a different sweep is rejected, never silently merged),
//! replays the committed records through the streaming merge's guard
//! set, and dispatches only the scenarios no record covers — zero
//! re-simulations of completed work.

use super::report::SweepReport;
use crate::error::{Error, Result};
use crate::json::{obj, Value};
use std::path::{Path, PathBuf};

/// Journal format identifier, bumped on incompatible layout changes so
/// an old orchestrator never misreads a newer journal (or vice versa).
pub const JOURNAL_SCHEMA: &str = "modtrans-fleet-journal/v1";

/// One committed lease record read back during `--resume` replay.
#[derive(Debug)]
pub struct ReplayedLease {
    /// The record's dispatch sequence number (also its file name).
    pub seq: usize,
    /// Grid-expansion scenario indices the lease covered.
    pub indices: Vec<usize>,
    /// The worker's per-lease report, parsed and re-validated.
    pub report: SweepReport,
}

/// An open journal directory the orchestrator appends lease records to.
#[derive(Debug)]
pub struct Journal {
    dir: PathBuf,
    next_seq: usize,
}

fn meta_path(dir: &Path) -> PathBuf {
    dir.join("meta.json")
}

fn record_name(seq: usize) -> String {
    format!("lease-{seq:06}.json")
}

/// Write `doc` to `dir/name` via temp file + rename (crash-atomic).
fn write_atomic(dir: &Path, name: &str, doc: &Value) -> Result<()> {
    let tmp = dir.join(format!("{name}.tmp.{}", std::process::id()));
    std::fs::write(&tmp, doc.to_json_pretty())?;
    std::fs::rename(&tmp, dir.join(name))?;
    Ok(())
}

impl Journal {
    /// Start a fresh journal for one design space. Refuses to clobber a
    /// directory that already holds a journal — continuing one is an
    /// explicit `--resume` decision, not a default.
    pub fn create(
        dir: &Path,
        config: &Value,
        grid_scenarios: usize,
        grid_digest: &str,
    ) -> Result<Journal> {
        std::fs::create_dir_all(dir)?;
        if meta_path(dir).exists() {
            return Err(Error::Config(format!(
                "journal directory '{}' already holds a journal — pass --resume to \
                 continue it, or point --journal at a fresh directory",
                dir.display()
            )));
        }
        let meta = obj(vec![
            ("schema", Value::Str(JOURNAL_SCHEMA.into())),
            ("config", config.clone()),
            ("grid_scenarios", Value::Num(grid_scenarios as f64)),
            ("grid_digest", Value::Str(grid_digest.into())),
        ]);
        write_atomic(dir, "meta.json", &meta)?;
        Ok(Journal { dir: dir.to_path_buf(), next_seq: 0 })
    }

    /// Re-open an existing journal and replay its committed records.
    /// The header must match the current invocation's config fingerprint
    /// and grid identity exactly — a stale journal is an error, never a
    /// silent partial merge. A directory with no journal yet (first
    /// launch under an always-`--resume` wrapper) is started fresh.
    pub fn resume(
        dir: &Path,
        config: &Value,
        grid_scenarios: usize,
        grid_digest: &str,
    ) -> Result<(Journal, Vec<ReplayedLease>)> {
        if !meta_path(dir).exists() {
            return Ok((Journal::create(dir, config, grid_scenarios, grid_digest)?, Vec::new()));
        }
        let meta_text = std::fs::read_to_string(meta_path(dir))?;
        let meta = crate::json::parse(&meta_text).map_err(|e| {
            Error::Config(format!(
                "journal header '{}/meta.json' is unreadable ({e}) — the journal \
                 cannot be trusted; remove the directory to start over",
                dir.display()
            ))
        })?;
        let schema = meta.get("schema").and_then(Value::as_str).unwrap_or_default();
        if schema != JOURNAL_SCHEMA {
            return Err(Error::Config(format!(
                "journal at '{}' uses schema '{schema}' (this build reads \
                 '{JOURNAL_SCHEMA}') — refusing to resume",
                dir.display()
            )));
        }
        let same_config = meta.get("config") == Some(config);
        let meta_scenarios = meta.get("grid_scenarios").and_then(Value::as_usize);
        let meta_digest = meta.get("grid_digest").and_then(Value::as_str);
        let same_grid = meta_scenarios == Some(grid_scenarios) && meta_digest == Some(grid_digest);
        if !same_config || !same_grid {
            return Err(Error::Config(format!(
                "journal at '{}' was recorded for a different sweep (config/grid \
                 fingerprint mismatch) — refusing to resume; point --journal at a \
                 fresh directory for this configuration",
                dir.display()
            )));
        }
        // Collect committed records in sequence order. `*.tmp.*`
        // leftovers from a crash mid-write are ignored by construction
        // (the name filter only admits fully renamed records).
        let mut names: Vec<String> = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if name.starts_with("lease-") && name.ends_with(".json") {
                names.push(name);
            }
        }
        names.sort();
        let mut replayed = Vec::with_capacity(names.len());
        let mut next_seq = 0usize;
        for name in names {
            let path = dir.join(&name);
            let text = std::fs::read_to_string(&path)?;
            let rec = crate::json::parse(&text).map_err(|e| {
                Error::Config(format!(
                    "journal record '{}' is corrupt ({e}) — a committed record \
                     should never be torn; remove the journal to start over",
                    path.display()
                ))
            })?;
            let seq = rec.get("seq").and_then(Value::as_usize).ok_or_else(|| {
                Error::Config(format!("journal record '{}' has no 'seq'", path.display()))
            })?;
            let indices_json = rec.get("indices").and_then(Value::as_arr).ok_or_else(|| {
                Error::Config(format!("journal record '{}' has no 'indices' array", path.display()))
            })?;
            let mut indices = Vec::with_capacity(indices_json.len());
            for i in indices_json {
                indices.push(i.as_usize().ok_or_else(|| {
                    Error::Config(format!(
                        "journal record '{}' has a non-integer scenario index",
                        path.display()
                    ))
                })?);
            }
            let report_json = rec.get("report").ok_or_else(|| {
                Error::Config(format!("journal record '{}' has no 'report'", path.display()))
            })?;
            let report = SweepReport::from_json(report_json).map_err(|e| {
                Error::Config(format!(
                    "journal record '{}' holds an unreadable lease report: {e}",
                    path.display()
                ))
            })?;
            next_seq = next_seq.max(seq + 1);
            replayed.push(ReplayedLease { seq, indices, report });
        }
        Ok((Journal { dir: dir.to_path_buf(), next_seq }, replayed))
    }

    /// The sequence number the next [`Journal::record`] call will use.
    pub fn next_seq(&self) -> usize {
        self.next_seq
    }

    /// Append one completed lease (crash-atomically) and return its
    /// sequence number.
    pub fn record(&mut self, indices: &[usize], report: &SweepReport) -> Result<usize> {
        let seq = self.next_seq;
        let doc = obj(vec![
            ("seq", Value::Num(seq as f64)),
            ("indices", Value::Arr(indices.iter().map(|&i| Value::Num(i as f64)).collect())),
            ("report", report.to_json()),
        ]);
        write_atomic(&self.dir, &record_name(seq), &doc)?;
        self.next_seq += 1;
        Ok(seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::SweepConfig;

    fn scratch(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("modtrans-journal-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn tiny_report(cfg: &SweepConfig, indices: &[usize]) -> SweepReport {
        SweepReport {
            models: 0,
            translations: 0,
            cache_loads: 0,
            pruned: 0,
            scenarios_simulated: indices.len(),
            scenarios_pruned: 0,
            bounds_evaluated: 0,
            config: cfg.fingerprint(),
            grid_scenarios: 8,
            grid_digest: "cafe".into(),
            shard: None,
            lease: Some(indices.to_vec()),
            ranked: Vec::new(),
        }
    }

    #[test]
    fn create_record_resume_round_trips() {
        let dir = scratch("roundtrip");
        let cfg = SweepConfig::default();
        let fp = cfg.fingerprint();
        let mut j = Journal::create(&dir, &fp, 8, "cafe").unwrap();
        assert_eq!(j.next_seq(), 0);
        j.record(&[5, 2], &tiny_report(&cfg, &[5, 2])).unwrap();
        j.record(&[0], &tiny_report(&cfg, &[0])).unwrap();
        // A torn-write leftover must be ignored on replay.
        std::fs::write(dir.join("lease-000002.json.tmp.999"), "torn").unwrap();
        let (j2, replayed) = Journal::resume(&dir, &fp, 8, "cafe").unwrap();
        assert_eq!(j2.next_seq(), 2);
        assert_eq!(replayed.len(), 2);
        assert_eq!(replayed[0].seq, 0);
        assert_eq!(replayed[0].indices, vec![5, 2]);
        assert_eq!(replayed[0].report.lease.as_deref(), Some(&[5, 2][..]));
        assert_eq!(replayed[1].indices, vec![0]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn create_refuses_to_clobber_an_existing_journal() {
        let dir = scratch("clobber");
        let fp = SweepConfig::default().fingerprint();
        Journal::create(&dir, &fp, 8, "cafe").unwrap();
        let err = Journal::create(&dir, &fp, 8, "cafe").unwrap_err();
        assert!(err.to_string().contains("--resume"), "got: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_rejects_a_stale_fingerprint_and_starts_fresh_dirs() {
        let dir = scratch("stale");
        let cfg = SweepConfig::default();
        Journal::create(&dir, &cfg.fingerprint(), 8, "cafe").unwrap();
        // Different config fingerprint.
        let other = SweepConfig { npus: 64, ..Default::default() }.fingerprint();
        let err = Journal::resume(&dir, &other, 8, "cafe").unwrap_err();
        assert!(err.to_string().contains("different sweep"), "got: {err}");
        // Different grid identity under the same config.
        let err = Journal::resume(&dir, &cfg.fingerprint(), 8, "beef").unwrap_err();
        assert!(err.to_string().contains("different sweep"), "got: {err}");
        let err = Journal::resume(&dir, &cfg.fingerprint(), 9, "cafe").unwrap_err();
        assert!(err.to_string().contains("different sweep"), "got: {err}");
        // Resume on a journal-less directory starts one fresh.
        let fresh = scratch("fresh");
        let (j, replayed) = Journal::resume(&fresh, &cfg.fingerprint(), 8, "cafe").unwrap();
        assert_eq!(j.next_seq(), 0);
        assert!(replayed.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&fresh);
    }

    #[test]
    fn resume_rejects_an_unknown_schema() {
        let dir = scratch("schema");
        let fp = SweepConfig::default().fingerprint();
        Journal::create(&dir, &fp, 8, "cafe").unwrap();
        let meta = std::fs::read_to_string(dir.join("meta.json")).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            meta.replace("modtrans-fleet-journal/v1", "modtrans-fleet-journal/v9"),
        )
        .unwrap();
        let err = Journal::resume(&dir, &fp, 8, "cafe").unwrap_err();
        assert!(err.to_string().contains("schema"), "got: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
