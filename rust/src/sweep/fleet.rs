//! Work-stealing fleet orchestration: one call launches N sweep worker
//! *processes*, warms them from a shared IR cache, hands out scenario
//! leases from a single dynamic work queue, and folds the lease reports
//! into the monolithic ranking as they land.
//!
//! `sweep --shard K/N` + `sweep-merge` (PR 3) turned a multi-node sweep
//! into a scheduler problem; this module is the scheduler. The static
//! modulo partition it started with had a straggler problem — one shard
//! holding the expensive model finishes long after the rest — so the
//! fleet now runs a work-stealing queue: whichever worker goes idle
//! steals the next lease. [`run_fleet`] stages:
//!
//! 1. **Expand once.** The grid is expanded and validated up front, so a
//!    bad grid fails before any process spawns, and the expansion index
//!    becomes each scenario's identity for leases and the journal.
//! 2. **Cache sync (copy-in).** With [`FleetOpts::cache_from`], valid IR
//!    entries are copied from an externally synced directory into the
//!    fleet's shared cache — cross-machine cache sharing.
//! 3. **Pre-warm + dispatch order.** One in-process cold translation
//!    pass ([`super::build_sweep_cache`]) spills every model's IR into
//!    the shared `--cache-dir` (each worker loads instead of extracting
//!    and reports **`translations == 0`**), and the warm cache feeds an
//!    analytic bound pass ([`super::bound::scenario_bound_ns`]) that
//!    orders the queue longest-bound-first — the expensive scenarios are
//!    leased out first, so no worker is left finishing a straggler alone
//!    ([`FleetOpts::static_shards`] restores the old contiguous
//!    once-only partition for A/B comparison).
//! 4. **Journal.** With [`FleetOpts::journal`], every completed lease is
//!    appended crash-atomically to a persistent [`Journal`];
//!    [`FleetOpts::resume`] replays the committed records through the
//!    streaming merge's guard set and dispatches only the scenarios no
//!    record covers — an interrupted fleet re-simulates **zero**
//!    completed scenarios and still ranks byte-identically.
//! 5. **Lease loop.** Idle workers receive scenario-index leases
//!    (`sweep --scenarios i,j,k`), sized adaptively from the observed
//!    per-scenario cost. A crashed worker's lease is re-dispatched up to
//!    [`FleetOpts::retries`] times; a worker that stops making progress
//!    for [`FleetOpts::shard_timeout`] seconds is killed by the watchdog
//!    and treated exactly like a crash. When retries are exhausted the
//!    fleet kills the survivors and fails hard, naming the worker and
//!    quoting its exit code and stderr tail.
//! 6. **Streaming merge.** Each lease report is folded into a
//!    [`StreamingMerge`] the moment it lands — the same guard set as
//!    `sweep-merge`, applied incrementally — so the fleet holds a live
//!    `--top K` leaderboard mid-run. The current K-th best iteration
//!    time is pushed to later leases as `--top-cutoff`, letting them
//!    prune provable losers before simulating; the cutoff only tightens
//!    and only skips scenarios whose admissible bound already exceeds
//!    it, so the merged ranking stays byte-identical to a monolithic
//!    `sweep` of the same grid (asserted in `tests/fleet_smoke.rs`,
//!    `tests/fleet_resume.rs` and CI's `fleet-smoke` job).
//! 7. **Cache sync (copy-out).** With `cache_from`, entries the synced
//!    directory lacks are published back; entries it already holds are
//!    left untouched — no mtime churn for rsync to re-upload.

use super::bound;
use super::cache;
use super::journal::Journal;
use super::pool;
use super::report::{ShardStatus, StreamingMerge, SweepReport};
use super::{Scenario, SweepConfig, SweepGrid};
use crate::error::{Error, Result};
use crate::json::{obj, Value};
use crate::translator::ZeroStage;
use crate::workload::Parallelism;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// How much of a failed worker's stderr is quoted in errors and status
/// records.
const STDERR_TAIL_BYTES: usize = 2048;

/// Exit code of the test-only [`shard_failpoint`] crash hook.
pub const FAILPOINT_EXIT_CODE: i32 = 42;

/// Adaptive lease sizing aims each lease at roughly this much work, from
/// the EWMA of observed per-scenario wall time: long enough to amortize
/// process spawn + cache load, short enough that the final straggler
/// tail stays bounded by one lease.
const TARGET_LEASE_MS: f64 = 250.0;

/// Monotonic suffix for auto-created work directories, so several fleets
/// in one process (tests, benches) never share scratch space.
static FLEET_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Orchestration knobs (the sweep itself is shaped by [`SweepGrid`] +
/// [`SweepConfig`]; nothing here may affect results, only how the work
/// is scheduled and recorded).
#[derive(Debug, Clone)]
pub struct FleetOpts {
    /// Worker processes to launch — the fleet's parallelism width.
    pub procs: usize,
    /// How many times one lease is re-dispatched after a crash (or
    /// watchdog kill) before the fleet fails hard (0 = no retries).
    pub retries: usize,
    /// The binary to re-invoke for each worker. `None` uses
    /// `std::env::current_exe()` — correct for the CLI, where the fleet
    /// *is* the `modtrans` binary. Test/bench/example callers must pass
    /// the real CLI binary (their own executable is a test harness); see
    /// [`locate_binary`].
    pub binary: Option<PathBuf>,
    /// Shared IR-cache directory every worker mounts via `--cache-dir`.
    /// `None` uses `<work_dir>/ircache` — warm within this fleet run
    /// only. Pass an explicit directory to stay warm across runs.
    pub cache_dir: Option<PathBuf>,
    /// Cross-machine cache sharing: copy valid entries *from* this
    /// directory into the shared cache before the pre-warm, and publish
    /// the cache back *to* it after the fleet completes. Point it at an
    /// rsync'd or object-store-synced directory; a missing directory is
    /// treated as empty on copy-in and created on copy-out.
    pub cache_from: Option<PathBuf>,
    /// Scratch directory for lease reports and captured stdout/stderr.
    /// `None` creates a unique temp directory, removed again on success;
    /// an explicit directory is left in place for inspection.
    pub work_dir: Option<PathBuf>,
    /// Write the machine-readable fleet status document here — on
    /// success (the [`FleetReport::status_json`] form) **and** on a
    /// retry-exhaustion failure, where it records every worker slot plus
    /// the dead worker's attempts/exit code/stderr tail. The failure
    /// case is the point: a dead worker must leave diagnosable evidence
    /// for automation, not just prose in an error message. Best-effort
    /// (an unwritable path warns on stderr, never masks the sweep
    /// outcome).
    pub status_out: Option<PathBuf>,
    /// Persistent completion-journal directory. Every completed lease is
    /// appended crash-atomically; pass the same directory again with
    /// [`FleetOpts::resume`] to continue an interrupted fleet without
    /// re-simulating completed work. `None` keeps no journal.
    pub journal: Option<PathBuf>,
    /// Replay the journal in [`FleetOpts::journal`] before dispatching:
    /// committed leases are folded into the merge from disk and only
    /// uncovered scenarios are leased out. Requires `journal`; a journal
    /// recorded for a different config or grid is rejected.
    pub resume: bool,
    /// Hang watchdog: a worker process that has neither exited nor been
    /// reaped within this many seconds of its launch is killed and its
    /// lease re-dispatched through the normal retry policy. `None`
    /// disables the watchdog.
    pub shard_timeout: Option<f64>,
    /// Fixed lease size (scenarios per lease), overriding the adaptive
    /// cost-based sizing. Mostly for tests and experiments; `None` sizes
    /// leases from the observed per-scenario cost.
    pub lease_size: Option<usize>,
    /// Disable work stealing: partition the queue once into contiguous
    /// chunks, one per worker, in plain expansion order — the old static
    /// `--shard`-style schedule, kept for A/B comparison (the paired
    /// `fleet_skewed_*` benches) and as a fallback. Results are
    /// byte-identical either way; only the wall-clock differs.
    pub static_shards: bool,
    /// Test-only crash/hang injection, exported to worker processes as
    /// `MODTRANS_FLEET_FAILPOINT` (see [`shard_failpoint`]). Never set
    /// by the CLI in production use.
    pub failpoint: Option<String>,
}

impl Default for FleetOpts {
    fn default() -> Self {
        FleetOpts {
            procs: 2,
            retries: 1,
            binary: None,
            cache_dir: None,
            cache_from: None,
            work_dir: None,
            status_out: None,
            journal: None,
            resume: false,
            shard_timeout: None,
            lease_size: None,
            static_shards: false,
            failpoint: None,
        }
    }
}

/// Everything a fleet run produced: the merged ranking plus the
/// orchestration evidence (per-worker status, pre-warm counters, cache
/// sync counts, journal replay accounting).
#[derive(Debug)]
pub struct FleetReport {
    /// The merged, re-ranked report — byte-identical in ranking to a
    /// monolithic `sweep` of the same grid and config.
    pub merged: SweepReport,
    /// Per-worker-slot outcome records, ordered by worker index.
    pub shards: Vec<ShardStatus>,
    /// Translations performed by the in-process pre-warm pass (equal to
    /// the model count on a cold shared cache, 0 on a warm one).
    pub prewarm_translations: usize,
    /// Models the pre-warm pass loaded from the shared cache instead of
    /// translating.
    pub prewarm_cache_loads: usize,
    /// Entries copied in from [`FleetOpts::cache_from`].
    pub cache_copied_in: usize,
    /// Entries published back to [`FleetOpts::cache_from`].
    pub cache_copied_out: usize,
    /// Leases completed by worker processes *this run* (journal-replayed
    /// leases are not re-run, so they are counted separately below).
    pub leases_completed: usize,
    /// Committed journal records replayed by `--resume`.
    pub replayed_leases: usize,
    /// Scenarios covered by replayed journal records — work this run did
    /// **not** re-simulate.
    pub scenarios_from_journal: usize,
    /// Whether the static once-only partition was used instead of work
    /// stealing.
    pub static_shards: bool,
}

impl FleetReport {
    /// Translations summed over the worker processes — 0 whenever the
    /// pre-warm covered the grid (the fleet's acceptance counter).
    pub fn shard_translations(&self) -> usize {
        self.shards.iter().map(|s| s.translations).sum()
    }

    /// Machine-readable orchestration status (deterministic key order) —
    /// written via [`FleetOpts::status_out`], consumed by CI's
    /// `fleet-smoke` job.
    pub fn status_json(&self) -> Value {
        status_doc(&StatusInfo {
            procs: self.shards.len(),
            mode: if self.static_shards { "static" } else { "stealing" },
            prewarm_translations: self.prewarm_translations,
            prewarm_cache_loads: self.prewarm_cache_loads,
            copied_in: self.cache_copied_in,
            copied_out: self.cache_copied_out,
            leases_completed: self.leases_completed,
            replayed_leases: self.replayed_leases,
            scenarios_from_journal: self.scenarios_from_journal,
            shards: &self.shards,
        })
    }
}

/// Everything the status document records — bundled so the success and
/// failure paths build the identical shape from one place.
struct StatusInfo<'a> {
    procs: usize,
    mode: &'a str,
    prewarm_translations: usize,
    prewarm_cache_loads: usize,
    copied_in: usize,
    copied_out: usize,
    leases_completed: usize,
    replayed_leases: usize,
    scenarios_from_journal: usize,
    shards: &'a [ShardStatus],
}

/// The status document both outcomes share: [`FleetReport::status_json`]
/// on success, the partial failure record written before a
/// retry-exhaustion error returns.
fn status_doc(info: &StatusInfo<'_>) -> Value {
    obj(vec![
        ("procs", Value::Num(info.procs as f64)),
        (
            "scheduler",
            obj(vec![
                ("mode", Value::Str(info.mode.into())),
                ("leases", Value::Num(info.leases_completed as f64)),
            ]),
        ),
        (
            "journal",
            obj(vec![
                ("replayed_leases", Value::Num(info.replayed_leases as f64)),
                ("scenarios_from_journal", Value::Num(info.scenarios_from_journal as f64)),
            ]),
        ),
        (
            "prewarm",
            obj(vec![
                ("translations", Value::Num(info.prewarm_translations as f64)),
                ("cache_loads", Value::Num(info.prewarm_cache_loads as f64)),
            ]),
        ),
        (
            "cache_sync",
            obj(vec![
                ("copied_in", Value::Num(info.copied_in as f64)),
                ("copied_out", Value::Num(info.copied_out as f64)),
            ]),
        ),
        ("shards", Value::Arr(info.shards.iter().map(ShardStatus::to_json).collect())),
    ])
}

/// Best-effort status-file write: diagnosis evidence must never mask or
/// replace the fleet outcome itself.
fn write_status(path: &Path, doc: &Value) {
    if let Err(e) = std::fs::write(path, doc.to_json_pretty()) {
        eprintln!("warning: could not write fleet status '{}': {e}", path.display());
    }
}

/// One lease currently running in a worker process.
struct LeaseRun {
    /// Scenario indices (ascending) this lease covers.
    indices: Vec<usize>,
    child: Child,
    /// Launch time of the *current* attempt — the watchdog clock.
    started: Instant,
    /// Failed attempts of this lease so far (bounded by
    /// [`FleetOpts::retries`]).
    failures: usize,
    /// The report file this attempt writes.
    out: PathBuf,
}

/// One worker slot: a stable 1-based identity `k` that successive lease
/// processes run under, accumulating that slot's lifetime counters.
struct WorkerSlot {
    k: usize,
    /// Process launches (every lease attempt, including retries).
    attempts: usize,
    /// Leases completed successfully.
    leases: usize,
    /// Exit code of the most recent attempt (`None` = never launched or
    /// killed by a signal/watchdog).
    exit_code: Option<i32>,
    /// When this slot last went idle (no lease running) while the fleet
    /// still had work in flight — cleared on the next dispatch.
    idle_since: Option<Instant>,
    /// Longest observed idle gap (ms); see [`ShardStatus::idle_ms`].
    idle_ms: u64,
    // Lifetime sums over this slot's completed leases.
    scenarios: usize,
    translations: usize,
    cache_loads: usize,
    pruned: usize,
    scenarios_simulated: usize,
    scenarios_pruned: usize,
    bounds_evaluated: usize,
    current: Option<LeaseRun>,
}

impl WorkerSlot {
    fn new(k: usize) -> WorkerSlot {
        WorkerSlot {
            k,
            attempts: 0,
            leases: 0,
            exit_code: None,
            idle_since: None,
            idle_ms: 0,
            scenarios: 0,
            translations: 0,
            cache_loads: 0,
            pruned: 0,
            scenarios_simulated: 0,
            scenarios_pruned: 0,
            bounds_evaluated: 0,
            current: None,
        }
    }

    /// Fold one completed lease report into the slot's lifetime sums.
    fn absorb_report(&mut self, report: &SweepReport) {
        self.leases += 1;
        self.exit_code = Some(0);
        self.scenarios += report.ranked.len();
        self.translations += report.translations;
        self.cache_loads += report.cache_loads;
        self.pruned += report.pruned;
        self.scenarios_simulated += report.scenarios_simulated;
        self.scenarios_pruned += report.scenarios_pruned;
        self.bounds_evaluated += report.bounds_evaluated;
    }

    /// Record the idle gap that ends now (next lease arriving or the
    /// fleet finishing), keeping the longest seen.
    fn end_idle(&mut self) {
        if let Some(t) = self.idle_since.take() {
            self.idle_ms = self.idle_ms.max(t.elapsed().as_millis() as u64);
        }
    }

    /// The slot's status record (`n` = fleet width).
    fn status(&self, n: usize, work_dir: &Path) -> ShardStatus {
        ShardStatus {
            shard: (self.k, n),
            attempts: self.attempts,
            exit_code: self.exit_code,
            stderr_tail: stderr_tail(&shard_err_path(work_dir, self.k)),
            scenarios: self.scenarios,
            translations: self.translations,
            cache_loads: self.cache_loads,
            pruned: self.pruned,
            scenarios_simulated: self.scenarios_simulated,
            scenarios_pruned: self.scenarios_pruned,
            bounds_evaluated: self.bounds_evaluated,
            leases: self.leases,
            idle_ms: self.idle_ms,
        }
    }
}

/// The launch-invariant context threaded through the lease loop, bundled
/// so dispatch helpers stay within a sane arity.
struct LaunchCtx<'a> {
    grid: &'a SweepGrid,
    cfg: &'a SweepConfig,
    opts: &'a FleetOpts,
    binary: &'a Path,
    work_dir: &'a Path,
    cache_dir: &'a Path,
}

/// Orchestrate a whole sweep: pre-warm the shared cache, launch
/// [`FleetOpts::procs`] worker processes, hand out scenario leases from
/// a work-stealing queue (re-dispatching crashes up to
/// [`FleetOpts::retries`] times), and stream-merge the lease reports
/// in-process. See the module docs for the stage-by-stage contract.
pub fn run_fleet(grid: &SweepGrid, cfg: &SweepConfig, opts: &FleetOpts) -> Result<FleetReport> {
    if opts.procs == 0 {
        return Err(Error::Config("fleet needs at least one worker process (procs >= 1)".into()));
    }
    if cfg.shard.is_some() {
        return Err(Error::Config(
            "the fleet assigns work itself — drop the shard setting from the sweep config".into(),
        ));
    }
    if cfg.hbm_bytes % (1 << 30) != 0 {
        return Err(Error::Config(
            "fleet workers receive --hbm-gib, so hbm_bytes must be a whole number of GiB".into(),
        ));
    }
    if opts.resume && opts.journal.is_none() {
        return Err(Error::Config(
            "--resume replays a completion journal — give --journal DIR as well".into(),
        ));
    }
    if opts.lease_size == Some(0) {
        return Err(Error::Config(
            "a lease must cover at least one scenario (lease size >= 1)".into(),
        ));
    }
    if opts.lease_size.is_some() && opts.static_shards {
        return Err(Error::Config(
            "--lease sizes work-stealing leases — drop it when --static-shards pins the \
             partition"
                .into(),
        ));
    }
    if let Some(t) = opts.shard_timeout {
        if t.is_nan() || t <= 0.0 {
            return Err(Error::Config(
                "the worker watchdog timeout must be a positive number of seconds".into(),
            ));
        }
    }
    if grid.expand().is_empty() {
        return Err(Error::Config(
            "sweep grid is empty — every axis needs at least one entry".into(),
        ));
    }
    let binary = match &opts.binary {
        Some(b) => b.clone(),
        None => std::env::current_exe().map_err(|e| {
            Error::Config(format!("cannot locate the modtrans binary to re-invoke: {e}"))
        })?,
    };
    let (work_dir, ephemeral_work) = match &opts.work_dir {
        Some(d) => (d.clone(), false),
        None => {
            let seq = FLEET_SEQ.fetch_add(1, Ordering::SeqCst);
            let name = format!("modtrans-fleet-{}-{seq}", std::process::id());
            (std::env::temp_dir().join(name), true)
        }
    };
    std::fs::create_dir_all(&work_dir)?;
    let result = fleet_body(grid, cfg, opts, &binary, &work_dir);
    if ephemeral_work && result.is_ok() {
        let _ = std::fs::remove_dir_all(&work_dir);
    }
    result
}

/// The fleet stages proper, once the scratch space exists (split out so
/// [`run_fleet`] can tie the work directory's lifetime to the outcome).
fn fleet_body(
    grid: &SweepGrid,
    cfg: &SweepConfig,
    opts: &FleetOpts,
    binary: &Path,
    work_dir: &Path,
) -> Result<FleetReport> {
    let cache_dir = opts.cache_dir.clone().unwrap_or_else(|| work_dir.join("ircache"));
    std::fs::create_dir_all(&cache_dir)?;

    // Stage: cache copy-in (cross-machine sharing).
    let cache_copied_in = match &opts.cache_from {
        Some(from) => cache::copy_entries(from, &cache_dir)?,
        None => 0,
    };

    // Stage: pre-warm — the fleet's single cold translation pass. Same
    // compute model and typed keys as the workers' own cache builds, so
    // every worker hits these entries and reports 0 translations. The
    // warm in-memory cache is kept briefly alive to feed the dispatch
    // ordering's bound pass below.
    let warm = super::build_sweep_cache(&grid.unique_models(), cfg, Some(&cache_dir))?;
    let prewarm_translations = warm.translations();
    let prewarm_cache_loads = warm.disk_loads();

    // Stage: the design space and its identity.
    let scenarios = grid.expand();
    let grid_n = scenarios.len();
    let digest = super::grid_digest(&scenarios);
    let fingerprint = cfg.fingerprint();

    // Stage: dispatch order. Work stealing leases longest-bound-first
    // (LPT over the analytic bound, like the in-process pool) so the
    // expensive scenarios are in flight earliest; the static partition
    // keeps plain expansion order, matching the old modulo schedule's
    // spirit of "no cost model".
    let order = if opts.static_shards {
        (0..grid_n).collect::<Vec<usize>>()
    } else {
        bound_dispatch_order(&scenarios, &warm, cfg)
    };
    drop(warm);

    // Stage: journal open / replay.
    let (mut journal, replayed) = match (&opts.journal, opts.resume) {
        (Some(dir), true) => {
            let (j, r) = Journal::resume(dir, &fingerprint, grid_n, &digest)?;
            (Some(j), r)
        }
        (Some(dir), false) => {
            (Some(Journal::create(dir, &fingerprint, grid_n, &digest)?), Vec::new())
        }
        (None, _) => (None, Vec::new()),
    };

    // Stage: streaming merge, seeded from the replayed journal records.
    // `absorb` applies the full merge guard set to each record, so a
    // tampered or inconsistent journal fails here, not at finalize.
    let mut merge = StreamingMerge::new(fingerprint, grid_n, digest);
    let mut covered = vec![false; grid_n];
    let mut scenarios_from_journal = 0usize;
    for lease in &replayed {
        merge.absorb(&lease.report, &lease.indices).map_err(|e| {
            Error::Config(format!("journal replay failed at record seq {}: {e}", lease.seq))
        })?;
        for &i in &lease.indices {
            covered[i] = true;
        }
        scenarios_from_journal += lease.indices.len();
    }
    let replayed_leases = replayed.len();
    drop(replayed);

    // The work queue: dispatch-ordered scenario indices not already
    // covered by the journal.
    let pending: Vec<usize> = order.into_iter().filter(|&i| !covered[i]).collect();
    drop(covered);

    let n = opts.procs;
    let ctx = LaunchCtx { grid, cfg, opts, binary, work_dir, cache_dir: &cache_dir };
    let mut slots: Vec<WorkerSlot> = (1..=n).map(WorkerSlot::new).collect();
    let mut cursor = 0usize;
    let mut leases_completed = 0usize;
    let mut ewma_scenario_ms: Option<f64> = None;

    // Stage: the lease loop — dispatch to idle workers, poll, fold.
    loop {
        // Dispatch: every idle slot steals the next lease while the
        // queue is non-empty. Under the static partition each slot gets
        // exactly one contiguous chunk (the whole queue is consumed on
        // the first pass, so a finished slot finds nothing to steal).
        let mut idle_now = slots.iter().filter(|s| s.current.is_none()).count();
        for slot in slots.iter_mut() {
            if cursor >= pending.len() {
                break;
            }
            if slot.current.is_some() {
                continue;
            }
            let remaining = pending.len() - cursor;
            let size = if opts.static_shards {
                // Contiguous once-only partition across the still-empty
                // slots (manual div-ceil; `usize::div_ceil` needs a
                // newer MSRV).
                (remaining + idle_now - 1) / idle_now
            } else {
                lease_size(remaining, n, opts.lease_size, ewma_scenario_ms)
            };
            let mut indices = pending[cursor..cursor + size].to_vec();
            cursor += size;
            indices.sort_unstable();
            slot.end_idle();
            let cutoff = if cfg.top_k.is_some() { merge.kth_best_ns() } else { None };
            match launch_lease(&ctx, slot.k, slot.attempts + 1, &indices, cutoff) {
                Ok(run) => {
                    slot.attempts += 1;
                    slot.current = Some(run);
                }
                Err(e) => {
                    kill_all(&mut slots);
                    return Err(e);
                }
            }
            idle_now -= 1;
        }

        if cursor >= pending.len() && slots.iter().all(|s| s.current.is_none()) {
            break;
        }

        // Poll: reap finished workers, fold their lease reports, apply
        // the watchdog, re-dispatch failed leases.
        let mut progressed = false;
        for si in 0..slots.len() {
            let Some(run) = slots[si].current.as_mut() else { continue };
            let exited = match run.child.try_wait() {
                Ok(status) => status,
                Err(e) => {
                    kill_all(&mut slots);
                    return Err(e.into());
                }
            };
            let failure = match exited {
                Some(st) if st.success() => {
                    // A zero exit with a readable, correctly stamped
                    // report is the only success; everything else goes
                    // through the retry policy.
                    match read_lease_report(&run.out, &run.indices) {
                        Ok(report) => {
                            let elapsed_ms = run.started.elapsed().as_secs_f64() * 1e3;
                            let indices = std::mem::take(&mut run.indices);
                            // Guard-checked fold first, durable record
                            // second: the journal only ever holds
                            // records the merge accepted.
                            if let Err(e) = merge.absorb(&report, &indices) {
                                kill_all(&mut slots);
                                return Err(e);
                            }
                            if let Some(j) = journal.as_mut() {
                                if let Err(e) = j.record(&indices, &report) {
                                    kill_all(&mut slots);
                                    return Err(e);
                                }
                            }
                            let slot = &mut slots[si];
                            slot.absorb_report(&report);
                            slot.current = None;
                            slot.idle_since = Some(Instant::now());
                            leases_completed += 1;
                            let per = elapsed_ms / indices.len().max(1) as f64;
                            ewma_scenario_ms = Some(match ewma_scenario_ms {
                                None => per,
                                Some(e) => 0.5 * e + 0.5 * per,
                            });
                            progressed = true;
                            continue;
                        }
                        Err(e) => Some(format!("exited 0 but its report is unusable: {e}")),
                    }
                }
                Some(st) => Some(match st.code() {
                    Some(c) => format!("exit code {c}"),
                    None => "killed by a signal".to_string(),
                }),
                None => match opts.shard_timeout {
                    // Hang watchdog: no exit within the budget is a
                    // failure like any other — kill, then retry-police.
                    Some(t) if run.started.elapsed().as_secs_f64() >= t => {
                        let _ = run.child.kill();
                        let _ = run.child.wait();
                        Some(format!("watchdog: still running after {t}s — killed"))
                    }
                    _ => None,
                },
            };
            let Some(reason) = failure else { continue };
            progressed = true;
            let exit_code = exited.and_then(|st| st.code());
            let slot = &mut slots[si];
            slot.exit_code = exit_code;
            // lint: allow(no-panic) — the failure arm is only reachable for slots with a lease
            let mut run = slot.current.take().expect("failing slot had a running lease");
            run.failures += 1;
            if run.failures > opts.retries {
                let k = slot.k;
                let attempts = run.failures;
                let mut tail = stderr_tail(&shard_err_path(work_dir, k));
                if tail.is_empty() {
                    tail = "(no stderr output)".to_string();
                }
                kill_all(&mut slots);
                // Leave machine-readable evidence behind: every worker
                // slot's record, including the dead one's exit code and
                // stderr tail — the error text alone is not a
                // diagnosable artifact.
                if let Some(path) = &opts.status_out {
                    let shards: Vec<ShardStatus> =
                        slots.iter().map(|s| s.status(n, work_dir)).collect();
                    let doc = status_doc(&StatusInfo {
                        procs: n,
                        mode: if opts.static_shards { "static" } else { "stealing" },
                        prewarm_translations,
                        prewarm_cache_loads,
                        copied_in: cache_copied_in,
                        copied_out: 0,
                        leases_completed,
                        replayed_leases,
                        scenarios_from_journal,
                        shards: &shards,
                    });
                    write_status(path, &doc);
                }
                return Err(Error::Sim(format!(
                    "fleet worker {k}/{n} failed after {attempts} attempt(s) ({reason}) — \
                     stderr tail:\n{tail}"
                )));
            }
            // Re-dispatch the same lease on the same slot (a fresh
            // process; the lease's failure budget carries over).
            let indices = std::mem::take(&mut run.indices);
            let failures = run.failures;
            let cutoff = if cfg.top_k.is_some() { merge.kth_best_ns() } else { None };
            match launch_lease(&ctx, slot.k, slot.attempts + 1, &indices, cutoff) {
                Ok(mut relaunched) => {
                    relaunched.failures = failures;
                    slot.attempts += 1;
                    slot.current = Some(relaunched);
                }
                Err(e) => {
                    kill_all(&mut slots);
                    return Err(e);
                }
            }
        }
        if !progressed {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
    }

    // Close the idle books: slots that finished before the fleet did
    // accrue their terminal gap — the straggler tail a static partition
    // shows and work stealing is built to shrink.
    for slot in slots.iter_mut() {
        slot.end_idle();
    }

    // Stage: finalize the streaming merge — every grid scenario must be
    // covered exactly once across journal replay and fresh leases.
    let statuses: Vec<ShardStatus> = slots.iter().map(|s| s.status(n, work_dir)).collect();
    let merged = match merge.finalize() {
        Ok(m) => m,
        Err(e) => {
            // Evidence first: the per-slot records are on disk even
            // when the lease accounting is rejected.
            if let Some(path) = &opts.status_out {
                let doc = status_doc(&StatusInfo {
                    procs: n,
                    mode: if opts.static_shards { "static" } else { "stealing" },
                    prewarm_translations,
                    prewarm_cache_loads,
                    copied_in: cache_copied_in,
                    copied_out: 0,
                    leases_completed,
                    replayed_leases,
                    scenarios_from_journal,
                    shards: &statuses,
                });
                write_status(path, &doc);
            }
            return Err(e);
        }
    };

    // Stage: cache copy-out (publish freshly translated entries back to
    // the synced directory).
    let cache_copied_out = match &opts.cache_from {
        Some(from) => cache::copy_entries(&cache_dir, from)?,
        None => 0,
    };

    let report = FleetReport {
        merged,
        shards: statuses,
        prewarm_translations,
        prewarm_cache_loads,
        cache_copied_in,
        cache_copied_out,
        leases_completed,
        replayed_leases,
        scenarios_from_journal,
        static_shards: opts.static_shards,
    };
    if let Some(path) = &opts.status_out {
        write_status(path, &report.status_json());
    }
    Ok(report)
}

/// Longest-bound-first dispatch order over the full grid (descending
/// analytic bound, ascending-index tiebreak), or plain expansion order
/// when the bound pass fails — the fleet never *needs* bounds, so a
/// bound error must not fail it. Pure scheduling: results are keyed by
/// scenario index, so the merged bytes cannot depend on this order.
fn bound_dispatch_order(
    scenarios: &[Scenario],
    warm: &cache::WorkloadCache,
    cfg: &SweepConfig,
) -> Vec<usize> {
    let identity: Vec<usize> = (0..scenarios.len()).collect();
    if scenarios.len() <= 2 {
        return identity;
    }
    let bounds = pool::run_indexed_with(
        scenarios.len(),
        cfg.threads.max(1),
        bound::BoundMemo::new,
        |memo, i| bound::scenario_bound_ns(&scenarios[i], warm, cfg, memo),
    );
    let Ok(bounds) = bounds else { return identity };
    let mut order = identity;
    order.sort_by(|&a, &b| bounds[b].cmp(&bounds[a]).then(a.cmp(&b)));
    order
}

/// Adaptive lease size: before any lease has finished, hand out small
/// probes (a quarter of a fair share) to learn the per-scenario cost;
/// afterwards aim each lease at [`TARGET_LEASE_MS`] of work. Always at
/// least 1 and never more than a fair share of what remains, so late in
/// the run every worker still gets something to steal.
fn lease_size(remaining: usize, procs: usize, fixed: Option<usize>, ewma_ms: Option<f64>) -> usize {
    let fair = (remaining + procs - 1) / procs; // manual div-ceil (MSRV)
    let cap = fair.max(1);
    if let Some(size) = fixed {
        return size.clamp(1, cap);
    }
    let want = match ewma_ms {
        None => remaining / (procs * 4),
        Some(ms) => (TARGET_LEASE_MS / ms.max(0.01)) as usize,
    };
    want.clamp(1, cap)
}

/// Captured-stderr path for one worker slot (truncated on every launch,
/// so it always holds the latest attempt's output).
fn shard_err_path(work_dir: &Path, k: usize) -> PathBuf {
    work_dir.join(format!("shard-{k}.stderr"))
}

/// Spawn one lease process on worker slot `k` with its report and
/// stdout/stderr paths wired up. Any stale report file is removed first
/// so a crash can never be mistaken for a completed lease. `launch` is
/// the slot's 1-based launch ordinal, exported so the failpoint's `K@A`
/// form can target one specific attempt.
fn launch_lease(
    ctx: &LaunchCtx<'_>,
    k: usize,
    launch: usize,
    indices: &[usize],
    cutoff_ns: Option<u64>,
) -> Result<LeaseRun> {
    let out = ctx.work_dir.join(format!("shard-{k}.json"));
    let _ = std::fs::remove_file(&out);
    let args = lease_args(ctx.grid, ctx.cfg, indices, ctx.cache_dir, &out, cutoff_ns);
    let mut cmd = Command::new(ctx.binary);
    cmd.args(&args)
        .stdin(Stdio::null())
        .stdout(std::fs::File::create(ctx.work_dir.join(format!("shard-{k}.stdout")))?)
        .stderr(std::fs::File::create(shard_err_path(ctx.work_dir, k))?)
        .env("MODTRANS_FLEET_WORKER", k.to_string())
        .env("MODTRANS_FLEET_LAUNCH", launch.to_string());
    match &ctx.opts.failpoint {
        Some(fp) => {
            cmd.env("MODTRANS_FLEET_FAILPOINT", fp);
        }
        // Scrub any ambient failpoint (e.g. still exported from a
        // debugging shell): only an explicit FleetOpts request may
        // crash workers — "never set in production" must hold even in a
        // polluted environment.
        None => {
            cmd.env_remove("MODTRANS_FLEET_FAILPOINT");
        }
    }
    let child = cmd.spawn().map_err(|e| {
        Error::Config(format!("failed to spawn worker process '{}': {e}", ctx.binary.display()))
    })?;
    Ok(LeaseRun { indices: indices.to_vec(), child, started: Instant::now(), failures: 0, out })
}

/// The child argv for one lease: the full grid and config re-expressed
/// in CLI tokens, plus the lease/cache/output wiring. Kept total — every
/// `SweepGrid`/`SweepConfig` field is either forwarded or fleet-owned
/// (`threads` is per worker; the scenario subset is assigned here).
fn lease_args(
    grid: &SweepGrid,
    cfg: &SweepConfig,
    indices: &[usize],
    cache_dir: &Path,
    out: &Path,
    cutoff_ns: Option<u64>,
) -> Vec<String> {
    let parallelisms: Vec<&str> =
        grid.parallelisms.iter().map(|&p| cli_parallelism_token(p)).collect();
    let networks: Vec<&str> = grid.networks.iter().map(|n| n.label()).collect();
    let collectives: Vec<&str> = grid.collectives.iter().map(|&c| c.token()).collect();
    let scenario_list: Vec<String> = indices.iter().map(|i| i.to_string()).collect();
    let mut v = vec![
        "sweep".to_string(),
        grid.models.join(","),
        "--parallelisms".to_string(),
        parallelisms.join(","),
        "--topologies".to_string(),
        networks.join(","),
        "--collectives".to_string(),
        collectives.join(","),
        "--npus".to_string(),
        cfg.npus.to_string(),
        "--mp-group".to_string(),
        cfg.mp_group.to_string(),
        "--batch".to_string(),
        cfg.batch.to_string(),
        "--iterations".to_string(),
        cfg.iterations.to_string(),
        "--threads".to_string(),
        cfg.threads.to_string(),
        "--bandwidth-gbps".to_string(),
        cfg.bandwidth_gbps.to_string(),
        "--latency-ns".to_string(),
        cfg.latency_ns.to_string(),
        "--hbm-gib".to_string(),
        (cfg.hbm_bytes >> 30).to_string(),
        "--zero".to_string(),
        zero_token(cfg.zero).to_string(),
        "--scenarios".to_string(),
        scenario_list.join(","),
        "--cache-dir".to_string(),
        cache_dir.display().to_string(),
        "--json-out".to_string(),
        out.display().to_string(),
    ];
    if cfg.skip_infeasible {
        v.push("--skip-infeasible".to_string());
    }
    if let Some(k) = cfg.top_k {
        v.push("--top".to_string());
        v.push(k.to_string());
    }
    if let Some(ns) = cutoff_ns {
        v.push("--top-cutoff".to_string());
        v.push(ns.to_string());
    }
    v
}

/// The CLI spelling of a parallelism strategy (`--parallelisms` tokens
/// are lowercase; [`Parallelism::token`] is the uppercase workload-file
/// grammar).
fn cli_parallelism_token(p: Parallelism) -> &'static str {
    match p {
        Parallelism::Data => "data",
        Parallelism::Model => "model",
        Parallelism::HybridDataModel => "hybrid-dm",
        Parallelism::HybridModelData => "hybrid-md",
        Parallelism::Pipeline => "pipeline",
    }
}

/// The CLI `--zero` token for a ZeRO stage.
fn zero_token(z: ZeroStage) -> &'static str {
    match z {
        ZeroStage::None => "0",
        ZeroStage::OptimizerState => "1",
        ZeroStage::Gradients => "2",
        ZeroStage::Parameters => "3",
    }
}

/// Load and validate one lease's report file: parseable JSON, a valid
/// report, echoing exactly the scenario indices this fleet dispatched.
fn read_lease_report(path: &Path, indices: &[usize]) -> Result<SweepReport> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        Error::Config(format!("lease report '{}' unreadable: {e}", path.display()))
    })?;
    let report = SweepReport::from_json(&crate::json::parse(&text)?)?;
    if report.shard.is_some() {
        return Err(Error::Config(format!(
            "lease report '{}' is stamped with modulo shard {:?} — the fleet dispatches \
             scenario leases, not shards",
            path.display(),
            report.shard
        )));
    }
    if report.lease.as_deref() != Some(indices) {
        return Err(Error::Config(format!(
            "lease report '{}' echoes {:?}, expected the dispatched lease {:?}",
            path.display(),
            report.lease,
            indices
        )));
    }
    Ok(report)
}

/// Last [`STDERR_TAIL_BYTES`] of a captured-stderr file, lossily decoded
/// and trimmed (empty string when the file is missing or empty).
fn stderr_tail(path: &Path) -> String {
    match std::fs::read(path) {
        Ok(bytes) => {
            let start = bytes.len().saturating_sub(STDERR_TAIL_BYTES);
            String::from_utf8_lossy(&bytes[start..]).trim().to_string()
        }
        Err(_) => String::new(),
    }
}

/// Kill and reap every still-running lease process (the fleet is
/// failing; no orphan may keep writing into the shared cache or work
/// directory).
fn kill_all(slots: &mut [WorkerSlot]) {
    for slot in slots.iter_mut() {
        if let Some(run) = slot.current.as_mut() {
            let _ = run.child.kill();
            let _ = run.child.wait();
        }
        slot.current = None;
    }
}

/// Best-effort search for the `modtrans` CLI binary when the current
/// executable is *not* it (benches, examples): `$MODTRANS_BIN` first,
/// then `modtrans` next to the current executable, then one directory up
/// (cargo puts benches in `deps/` and examples in `examples/`, one level
/// below the binary). Integration tests should prefer
/// `env!("CARGO_BIN_EXE_modtrans")`, which cargo guarantees.
pub fn locate_binary() -> Option<PathBuf> {
    let name = format!("modtrans{}", std::env::consts::EXE_SUFFIX);
    if let Ok(p) = std::env::var("MODTRANS_BIN") {
        let p = PathBuf::from(p);
        if p.is_file() {
            return Some(p);
        }
    }
    let exe = std::env::current_exe().ok()?;
    let dir = exe.parent()?;
    let candidates = [dir.join(&name), dir.parent()?.join(&name)];
    candidates.into_iter().find(|c| c.is_file())
}

/// Test-only crash/hang injection for fleet failure-path tests, driven
/// by the `MODTRANS_FLEET_FAILPOINT` environment variable (which the
/// fleet sets on its children only when [`FleetOpts::failpoint`] is
/// given — it is never set in production). The worker identity comes
/// from `MODTRANS_FLEET_WORKER`/`MODTRANS_FLEET_LAUNCH` (exported by the
/// fleet on every launch), falling back to the legacy `--shard` index
/// for hand-run processes. Grammar — `TARGET[:ACTION]`:
///
/// * TARGET `"K"` — a process running on worker slot `K` trips the
///   action on every launch.
/// * TARGET `"K@A"` — only worker `K`'s `A`-th launch (1-based) trips,
///   making the injection one-shot by construction: the retry of the
///   same lease is launch `A+1` and runs clean.
/// * ACTION absent — abort with [`FAILPOINT_EXIT_CODE`].
/// * ACTION `"once=PATH"` — abort only if `PATH` does not exist yet,
///   creating it first; the marker makes the worker fail exactly once
///   across the whole fleet, so the fleet's retry must succeed.
/// * ACTION `"hang=SECS"` — sleep `SECS` seconds (simulating a hung
///   worker for the `--shard-timeout` watchdog), then abort anyway; the
///   bounded sleep means a broken watchdog fails the test instead of
///   deadlocking it.
///
/// Called by the CLI `sweep` command after argument parsing (i.e. the
/// process dies *mid-run*, after it has been assigned real work).
pub fn shard_failpoint(shard: Option<(usize, usize)>) {
    let Ok(spec) = std::env::var("MODTRANS_FLEET_FAILPOINT") else { return };
    let worker = std::env::var("MODTRANS_FLEET_WORKER")
        .ok()
        .and_then(|w| w.parse::<usize>().ok())
        .or_else(|| shard.map(|(k, _)| k));
    let Some(k) = worker else { return };
    let launch = std::env::var("MODTRANS_FLEET_LAUNCH")
        .ok()
        .and_then(|a| a.parse::<usize>().ok());
    let (target, action) = match spec.split_once(':') {
        Some((t, rest)) => (t, Some(rest)),
        None => (spec.as_str(), None),
    };
    let (target_k, target_launch) = match target.split_once('@') {
        Some((t, a)) => (t, a.parse::<usize>().ok()),
        None => (target, None),
    };
    if !matches!(target_k.parse::<usize>(), Ok(t) if t == k) {
        return;
    }
    if let Some(a) = target_launch {
        if launch != Some(a) {
            return;
        }
    }
    if let Some(rest) = action {
        if let Some(path) = rest.strip_prefix("once=") {
            if Path::new(path).exists() {
                return;
            }
            let _ = std::fs::write(path, "crashed");
        } else if let Some(secs) = rest.strip_prefix("hang=") {
            let secs: f64 = secs.parse().unwrap_or(30.0);
            eprintln!("failpoint: injected hang in worker {k} (MODTRANS_FLEET_FAILPOINT)");
            std::thread::sleep(std::time::Duration::from_secs_f64(secs));
        }
    }
    eprintln!("failpoint: injected crash in shard {k} (MODTRANS_FLEET_FAILPOINT)");
    std::process::exit(FAILPOINT_EXIT_CODE);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_procs_is_a_config_error() {
        let opts = FleetOpts { procs: 0, ..Default::default() };
        let err = run_fleet(&SweepGrid::default(), &SweepConfig::default(), &opts).unwrap_err();
        assert!(err.to_string().contains("at least one worker process"));
    }

    #[test]
    fn preset_shard_is_rejected() {
        let cfg = SweepConfig { shard: Some((1, 2)), ..Default::default() };
        let err = run_fleet(&SweepGrid::default(), &cfg, &FleetOpts::default()).unwrap_err();
        assert!(err.to_string().contains("assigns work itself"));
    }

    #[test]
    fn fractional_gib_hbm_is_rejected() {
        let cfg = SweepConfig { hbm_bytes: (1 << 30) + 1, ..Default::default() };
        let err = run_fleet(&SweepGrid::default(), &cfg, &FleetOpts::default()).unwrap_err();
        assert!(err.to_string().contains("whole number of GiB"));
    }

    #[test]
    fn empty_grid_fails_before_any_spawn() {
        let grid = SweepGrid { models: vec![], ..Default::default() };
        let err = run_fleet(&grid, &SweepConfig::default(), &FleetOpts::default()).unwrap_err();
        assert!(err.to_string().contains("grid is empty"));
    }

    #[test]
    fn resume_without_a_journal_is_rejected() {
        let opts = FleetOpts { resume: true, ..Default::default() };
        let err = run_fleet(&SweepGrid::default(), &SweepConfig::default(), &opts).unwrap_err();
        assert!(err.to_string().contains("--journal"), "got: {err}");
    }

    #[test]
    fn degenerate_scheduler_knobs_are_rejected() {
        let zero_lease = FleetOpts { lease_size: Some(0), ..Default::default() };
        let err =
            run_fleet(&SweepGrid::default(), &SweepConfig::default(), &zero_lease).unwrap_err();
        assert!(err.to_string().contains("at least one scenario"), "got: {err}");

        let lease_and_static =
            FleetOpts { lease_size: Some(3), static_shards: true, ..Default::default() };
        let err = run_fleet(&SweepGrid::default(), &SweepConfig::default(), &lease_and_static)
            .unwrap_err();
        assert!(err.to_string().contains("--static-shards"), "got: {err}");

        let bad_watchdog = FleetOpts { shard_timeout: Some(0.0), ..Default::default() };
        let err =
            run_fleet(&SweepGrid::default(), &SweepConfig::default(), &bad_watchdog).unwrap_err();
        assert!(err.to_string().contains("positive number of seconds"), "got: {err}");
    }

    #[test]
    fn lease_sizes_probe_then_track_cost_and_never_overreach() {
        // Fixed size wins but is clamped to a fair share.
        assert_eq!(lease_size(100, 4, Some(7), None), 7);
        assert_eq!(lease_size(8, 4, Some(7), None), 2);
        // No cost estimate yet: small probes, never zero.
        assert_eq!(lease_size(100, 4, None, None), 6);
        assert_eq!(lease_size(3, 4, None, None), 1);
        // Cheap scenarios grow the lease toward the time target...
        let grown = lease_size(1000, 4, None, Some(1.0));
        assert_eq!(grown, TARGET_LEASE_MS as usize);
        // ...expensive ones shrink it, and the fair-share cap always
        // leaves work for the other workers to steal.
        assert_eq!(lease_size(1000, 4, None, Some(10_000.0)), 1);
        assert_eq!(lease_size(10, 4, None, Some(0.001)), 3);
    }

    #[test]
    fn lease_args_round_trip_through_the_cli_grammar() {
        // Every forwarded token must be accepted by the CLI parsers the
        // child process will run them through.
        let grid = SweepGrid {
            models: vec!["mlp".into(), "resnet18".into()],
            parallelisms: vec![
                Parallelism::Data,
                Parallelism::Model,
                Parallelism::HybridDataModel,
                Parallelism::HybridModelData,
                Parallelism::Pipeline,
            ],
            networks: vec![
                crate::sim::NetworkSpec::from_kind(crate::sim::TopologyKind::Ring),
                crate::sim::NetworkSpec::from_kind(crate::sim::TopologyKind::FullyConnected),
                crate::sim::NetworkSpec::from_kind(crate::sim::TopologyKind::Switch),
                crate::sim::NetworkSpec::parse(
                    "ring:4x300g@700ns/rail:4x50g@2us+hd/switch:2x25g@5us+direct",
                )
                .unwrap(),
            ],
            collectives: vec![
                super::super::CollectiveAlgo::Direct,
                super::super::CollectiveAlgo::Pipelined,
                super::super::CollectiveAlgo::PipelinedLifo,
            ],
        };
        let cfg = SweepConfig {
            zero: ZeroStage::Gradients,
            skip_infeasible: true,
            top_k: Some(5),
            ..Default::default()
        };
        let args = lease_args(
            &grid,
            &cfg,
            &[3, 5, 9],
            Path::new("/tmp/cache"),
            Path::new("/tmp/out.json"),
            Some(123_456),
        );
        assert_eq!(args[0], "sweep");
        assert_eq!(args[1], "mlp,resnet18");
        let opt = |key: &str| {
            let i = args.iter().position(|a| a == key).unwrap_or_else(|| panic!("{key} missing"));
            args[i + 1].clone()
        };
        for p in opt("--parallelisms").split(',') {
            assert!(
                matches!(p, "data" | "model" | "hybrid-dm" | "hybrid-md" | "pipeline"),
                "unforwardable parallelism token '{p}'"
            );
        }
        for t in opt("--topologies").split(',') {
            // Every forwarded network label must round-trip through the
            // NetworkSpec grammar the child CLI parses.
            let spec = crate::sim::NetworkSpec::parse(t).unwrap();
            assert_eq!(spec.label(), t);
        }
        for c in opt("--collectives").split(',') {
            super::super::CollectiveAlgo::from_token(c).unwrap();
        }
        assert_eq!(opt("--scenarios"), "3,5,9");
        assert!(!args.iter().any(|a| a == "--shard"), "leases and shards are exclusive");
        assert_eq!(opt("--zero"), "2");
        assert_eq!(opt("--hbm-gib"), "32");
        assert_eq!(opt("--cache-dir"), "/tmp/cache");
        assert_eq!(opt("--json-out"), "/tmp/out.json");
        assert!(args.iter().any(|a| a == "--skip-infeasible"));
        // Top-K pruning forwards so each lease prunes against its local
        // top-K (the streaming merge truncates the union back to K)...
        assert_eq!(opt("--top"), "5");
        // ...and the fleet-wide cutoff rides along once the live
        // leaderboard has K entries.
        assert_eq!(opt("--top-cutoff"), "123456");

        // Without a cutoff the flag is omitted entirely.
        let cold = lease_args(
            &grid,
            &cfg,
            &[0],
            Path::new("/tmp/cache"),
            Path::new("/tmp/out.json"),
            None,
        );
        assert!(!cold.iter().any(|a| a == "--top-cutoff"));
    }

    #[test]
    fn failpoint_is_inert_without_the_env_var() {
        // Never crashes here: the env var is unset (deliberately NOT
        // set in-process — concurrent setenv/getenv across test threads
        // is UB on glibc). The armed branches — crash, crash-once
        // marker, launch-targeted crash, and hang — are exercised for
        // real by tests/fleet_smoke.rs and tests/fleet_resume.rs in
        // child processes, where the variable is scoped to the spawned
        // worker.
        shard_failpoint(None);
        shard_failpoint(Some((1, 4)));
        shard_failpoint(Some((4, 4)));
    }

    #[test]
    fn stderr_tail_handles_missing_and_long_files() {
        assert_eq!(stderr_tail(Path::new("/no/such/stderr-file")), "");
        let dir = std::env::temp_dir();
        let path = dir.join(format!("mt_fleet_tail_{}", std::process::id()));
        std::fs::write(&path, format!("{}END", "x".repeat(10_000))).unwrap();
        let tail = stderr_tail(&path);
        assert!(tail.len() <= STDERR_TAIL_BYTES);
        assert!(tail.ends_with("END"));
        let _ = std::fs::remove_file(&path);
    }
}
