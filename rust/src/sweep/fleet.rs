//! Shard-fleet orchestration: one call launches N sweep shard
//! *processes*, warms them from a shared IR cache, and merges their
//! reports back into the monolithic ranking.
//!
//! `sweep --shard K/N` + `sweep-merge` (PR 3) turned a multi-node sweep
//! into a scheduler problem; this module is the scheduler. ASTRA-sim
//! 2.0-style design-space exploration is thousands of
//! (parallelism × topology × collective) points — the fleet drives our
//! own design space the same way: **one command, N workers, one cold
//! translation, one merged ranking.**
//!
//! [`run_fleet`] stages:
//!
//! 1. **Expand once.** The grid is expanded and validated up front, so a
//!    bad grid fails before any process spawns.
//! 2. **Cache sync (copy-in).** With [`FleetOpts::cache_from`], valid IR
//!    entries are copied from an externally synced directory (rsync, an
//!    object-store mirror) into the fleet's shared cache — cross-machine
//!    cache sharing: a fleet on a fresh machine warms from another
//!    machine's cold run.
//! 3. **Pre-warm.** One in-process cold translation pass
//!    ([`super::build_sweep_cache`] — the exact compute model and typed
//!    keys `run_sweep_cached` uses) spills every model's IR into the
//!    shared `--cache-dir`, so each shard process loads instead of
//!    extracting and reports **`translations == 0`**.
//! 4. **Spawn + monitor.** N child processes re-invoke the `modtrans`
//!    binary (`sweep <models> --shard k/N --cache-dir <shared>
//!    --json-out <work>/shard-k.json`), stdout/stderr captured per
//!    shard. A crashed shard is relaunched up to [`FleetOpts::retries`]
//!    times; when retries are exhausted the fleet kills the survivors
//!    and fails hard, naming the shard and quoting its exit code and
//!    stderr tail (a dead shard is never just a missing file).
//! 5. **Merge in-process.** The shard reports go through
//!    [`SweepReport::merge`], which re-checks completeness, grid
//!    identity and overlap — so the fleet inherits every guard the
//!    `sweep-merge` subcommand enforces — and the merged ranking is
//!    byte-identical to a monolithic `sweep` run of the same grid
//!    (asserted in `tests/fleet_smoke.rs` and CI's `fleet-smoke` job).
//! 6. **Cache sync (copy-out).** With `cache_from`, entries the synced
//!    directory lacks (i.e. whatever this fleet translated fresh) are
//!    published back, so the next machine's fleet starts warm; entries
//!    it already holds are left untouched — no mtime churn for rsync to
//!    re-upload.

use super::cache;
use super::report::{ShardStatus, SweepReport};
use super::{SweepConfig, SweepGrid};
use crate::error::{Error, Result};
use crate::json::{obj, Value};
use crate::translator::ZeroStage;
use crate::workload::Parallelism;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};

/// How much of a failed shard's stderr is quoted in errors and status
/// records.
const STDERR_TAIL_BYTES: usize = 2048;

/// Exit code of the test-only [`shard_failpoint`] crash hook.
pub const FAILPOINT_EXIT_CODE: i32 = 42;

/// Monotonic suffix for auto-created work directories, so several fleets
/// in one process (tests, benches) never share scratch space.
static FLEET_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Orchestration knobs (the sweep itself is shaped by [`SweepGrid`] +
/// [`SweepConfig`]; nothing here may affect results, only how the work
/// is scheduled).
#[derive(Debug, Clone)]
pub struct FleetOpts {
    /// Shard processes to launch — the `N` of every `--shard k/N`.
    pub procs: usize,
    /// How many times a crashed shard is relaunched before the fleet
    /// fails hard (0 = no retries).
    pub retries: usize,
    /// The binary to re-invoke for each shard. `None` uses
    /// `std::env::current_exe()` — correct for the CLI, where the fleet
    /// *is* the `modtrans` binary. Test/bench/example callers must pass
    /// the real CLI binary (their own executable is a test harness); see
    /// [`locate_binary`].
    pub binary: Option<PathBuf>,
    /// Shared IR-cache directory every shard mounts via `--cache-dir`.
    /// `None` uses `<work_dir>/ircache` — warm within this fleet run
    /// only. Pass an explicit directory to stay warm across runs.
    pub cache_dir: Option<PathBuf>,
    /// Cross-machine cache sharing: copy valid entries *from* this
    /// directory into the shared cache before the pre-warm, and publish
    /// the cache back *to* it after the fleet completes. Point it at an
    /// rsync'd or object-store-synced directory; a missing directory is
    /// treated as empty on copy-in and created on copy-out.
    pub cache_from: Option<PathBuf>,
    /// Scratch directory for shard reports and captured stdout/stderr.
    /// `None` creates a unique temp directory, removed again on success;
    /// an explicit directory is left in place for inspection.
    pub work_dir: Option<PathBuf>,
    /// Write the machine-readable fleet status document here — on
    /// success (the [`FleetReport::status_json`] form) **and** on a
    /// shard-exhaustion failure, where it records every completed
    /// shard plus the dead shard's attempts/exit code/stderr tail. The
    /// failure case is the point: a dead shard must leave diagnosable
    /// evidence for automation, not just prose in an error message.
    /// Best-effort (an unwritable path warns on stderr, never masks the
    /// sweep outcome).
    pub status_out: Option<PathBuf>,
    /// Test-only crash injection, exported to shard processes as
    /// `MODTRANS_FLEET_FAILPOINT` (see [`shard_failpoint`]). Never set
    /// by the CLI.
    pub failpoint: Option<String>,
}

impl Default for FleetOpts {
    fn default() -> Self {
        FleetOpts {
            procs: 2,
            retries: 1,
            binary: None,
            cache_dir: None,
            cache_from: None,
            work_dir: None,
            status_out: None,
            failpoint: None,
        }
    }
}

/// Everything a fleet run produced: the merged ranking plus the
/// orchestration evidence (per-shard status, pre-warm counters, cache
/// sync counts).
#[derive(Debug)]
pub struct FleetReport {
    /// The merged, re-ranked report — byte-identical in ranking to a
    /// monolithic `sweep` of the same grid and config.
    pub merged: SweepReport,
    /// Per-shard outcome records, ordered by shard index.
    pub shards: Vec<ShardStatus>,
    /// Translations performed by the in-process pre-warm pass (equal to
    /// the model count on a cold shared cache, 0 on a warm one).
    pub prewarm_translations: usize,
    /// Models the pre-warm pass loaded from the shared cache instead of
    /// translating.
    pub prewarm_cache_loads: usize,
    /// Entries copied in from [`FleetOpts::cache_from`].
    pub cache_copied_in: usize,
    /// Entries published back to [`FleetOpts::cache_from`].
    pub cache_copied_out: usize,
}

impl FleetReport {
    /// Translations summed over the shard processes — 0 whenever the
    /// pre-warm covered the grid (the fleet's acceptance counter).
    pub fn shard_translations(&self) -> usize {
        self.shards.iter().map(|s| s.translations).sum()
    }

    /// Machine-readable orchestration status (deterministic key order) —
    /// written via [`FleetOpts::status_out`], consumed by CI's
    /// `fleet-smoke` job.
    pub fn status_json(&self) -> Value {
        status_doc(
            self.shards.len(),
            self.prewarm_translations,
            self.prewarm_cache_loads,
            self.cache_copied_in,
            self.cache_copied_out,
            &self.shards,
        )
    }
}

/// The status document both outcomes share: [`FleetReport::status_json`]
/// on success, the partial failure record written before a
/// shard-exhaustion error returns.
fn status_doc(
    procs: usize,
    prewarm_translations: usize,
    prewarm_cache_loads: usize,
    copied_in: usize,
    copied_out: usize,
    shards: &[ShardStatus],
) -> Value {
    obj(vec![
        ("procs", Value::Num(procs as f64)),
        (
            "prewarm",
            obj(vec![
                ("translations", Value::Num(prewarm_translations as f64)),
                ("cache_loads", Value::Num(prewarm_cache_loads as f64)),
            ]),
        ),
        (
            "cache_sync",
            obj(vec![
                ("copied_in", Value::Num(copied_in as f64)),
                ("copied_out", Value::Num(copied_out as f64)),
            ]),
        ),
        ("shards", Value::Arr(shards.iter().map(ShardStatus::to_json).collect())),
    ])
}

/// Best-effort status-file write: diagnosis evidence must never mask or
/// replace the fleet outcome itself.
fn write_status(path: &Path, doc: &Value) {
    if let Err(e) = std::fs::write(path, doc.to_json_pretty()) {
        eprintln!("warning: could not write fleet status '{}': {e}", path.display());
    }
}

/// One live shard process.
struct ShardProc {
    /// 1-based shard index (the `k` of `--shard k/N`).
    k: usize,
    /// Launches so far (1 = first attempt, no retry yet).
    attempts: usize,
    child: Child,
}

/// Orchestrate a whole sharded sweep: pre-warm the shared cache, launch
/// [`FleetOpts::procs`] shard processes, relaunch crashes up to
/// [`FleetOpts::retries`] times, and merge the shard reports in-process.
/// See the module docs for the stage-by-stage contract.
pub fn run_fleet(grid: &SweepGrid, cfg: &SweepConfig, opts: &FleetOpts) -> Result<FleetReport> {
    if opts.procs == 0 {
        return Err(Error::Config("fleet needs at least one shard process (procs >= 1)".into()));
    }
    if cfg.shard.is_some() {
        return Err(Error::Config(
            "the fleet assigns shards itself — drop the shard setting from the sweep config".into(),
        ));
    }
    if cfg.hbm_bytes % (1 << 30) != 0 {
        return Err(Error::Config(
            "fleet shards receive --hbm-gib, so hbm_bytes must be a whole number of GiB".into(),
        ));
    }
    if grid.expand().is_empty() {
        return Err(Error::Config(
            "sweep grid is empty — every axis needs at least one entry".into(),
        ));
    }
    let binary = match &opts.binary {
        Some(b) => b.clone(),
        None => std::env::current_exe().map_err(|e| {
            Error::Config(format!("cannot locate the modtrans binary to re-invoke: {e}"))
        })?,
    };
    let (work_dir, ephemeral_work) = match &opts.work_dir {
        Some(d) => (d.clone(), false),
        None => {
            let seq = FLEET_SEQ.fetch_add(1, Ordering::SeqCst);
            let name = format!("modtrans-fleet-{}-{seq}", std::process::id());
            (std::env::temp_dir().join(name), true)
        }
    };
    std::fs::create_dir_all(&work_dir)?;
    let result = fleet_body(grid, cfg, opts, &binary, &work_dir);
    if ephemeral_work && result.is_ok() {
        let _ = std::fs::remove_dir_all(&work_dir);
    }
    result
}

/// The fleet stages proper, once the scratch space exists (split out so
/// [`run_fleet`] can tie the work directory's lifetime to the outcome).
fn fleet_body(
    grid: &SweepGrid,
    cfg: &SweepConfig,
    opts: &FleetOpts,
    binary: &Path,
    work_dir: &Path,
) -> Result<FleetReport> {
    let cache_dir = opts.cache_dir.clone().unwrap_or_else(|| work_dir.join("ircache"));
    std::fs::create_dir_all(&cache_dir)?;

    // Stage: cache copy-in (cross-machine sharing).
    let cache_copied_in = match &opts.cache_from {
        Some(from) => cache::copy_entries(from, &cache_dir)?,
        None => 0,
    };

    // Stage: pre-warm — the fleet's single cold translation pass. Same
    // compute model and typed keys as the shards' own cache builds, so
    // every shard hits these entries and reports 0 translations.
    let warm = super::build_sweep_cache(&grid.unique_models(), cfg, Some(&cache_dir))?;
    let prewarm_translations = warm.translations();
    let prewarm_cache_loads = warm.disk_loads();
    drop(warm);

    // Stage: spawn one process per shard.
    let n = opts.procs;
    let shard_out = |k: usize| work_dir.join(format!("shard-{k}.json"));
    let mut running: Vec<ShardProc> = Vec::with_capacity(n);
    for k in 1..=n {
        match launch_shard(grid, cfg, opts, binary, work_dir, &cache_dir, k) {
            Ok(child) => running.push(ShardProc { k, attempts: 1, child }),
            Err(e) => {
                kill_all(&mut running);
                return Err(e);
            }
        }
    }

    // Stage: monitor with bounded retries.
    let mut statuses: Vec<ShardStatus> = Vec::with_capacity(n);
    let mut done: Vec<(usize, SweepReport)> = Vec::with_capacity(n);
    while !running.is_empty() {
        let mut progressed = false;
        let mut i = 0;
        while i < running.len() {
            let exited = match running[i].child.try_wait() {
                Ok(status) => status,
                Err(e) => {
                    kill_all(&mut running);
                    return Err(e.into());
                }
            };
            let Some(st) = exited else {
                i += 1;
                continue;
            };
            progressed = true;
            let proc = running.swap_remove(i);
            let k = proc.k;
            // A zero exit with a readable, correctly stamped report is
            // the only success; everything else goes through the retry
            // policy (excluded-runner style: relaunch, never trust).
            let failure = if st.success() {
                match read_shard_report(&shard_out(k), k, n) {
                    Ok(report) => {
                        statuses.push(ShardStatus {
                            shard: (k, n),
                            attempts: proc.attempts,
                            exit_code: Some(0),
                            stderr_tail: stderr_tail(&shard_err_path(work_dir, k)),
                            scenarios: report.ranked.len(),
                            translations: report.translations,
                            cache_loads: report.cache_loads,
                            pruned: report.pruned,
                            scenarios_simulated: report.scenarios_simulated,
                            scenarios_pruned: report.scenarios_pruned,
                            bounds_evaluated: report.bounds_evaluated,
                        });
                        done.push((k, report));
                        None
                    }
                    Err(e) => Some(format!("exited 0 but its report is unusable: {e}")),
                }
            } else {
                Some(match st.code() {
                    Some(c) => format!("exit code {c}"),
                    None => "killed by a signal".to_string(),
                })
            };
            if let Some(reason) = failure {
                if proc.attempts > opts.retries {
                    let mut tail = stderr_tail(&shard_err_path(work_dir, k));
                    if tail.is_empty() {
                        tail = "(no stderr output)".to_string();
                    }
                    kill_all(&mut running);
                    // Leave machine-readable evidence behind: every
                    // completed shard plus the dead one's full record —
                    // the error text alone is not a diagnosable artifact.
                    if let Some(path) = &opts.status_out {
                        statuses.push(ShardStatus {
                            shard: (k, n),
                            attempts: proc.attempts,
                            exit_code: st.code(),
                            stderr_tail: tail.clone(),
                            scenarios: 0,
                            translations: 0,
                            cache_loads: 0,
                            pruned: 0,
                            scenarios_simulated: 0,
                            scenarios_pruned: 0,
                            bounds_evaluated: 0,
                        });
                        statuses.sort_by_key(|s| s.shard.0);
                        let doc = status_doc(
                            n,
                            prewarm_translations,
                            prewarm_cache_loads,
                            cache_copied_in,
                            0,
                            &statuses,
                        );
                        write_status(path, &doc);
                    }
                    return Err(Error::Sim(format!(
                        "fleet shard {k}/{n} failed after {} attempt(s) ({reason}) — \
                         stderr tail:\n{tail}",
                        proc.attempts
                    )));
                }
                match launch_shard(grid, cfg, opts, binary, work_dir, &cache_dir, k) {
                    Ok(child) => {
                        running.push(ShardProc { k, attempts: proc.attempts + 1, child });
                    }
                    Err(e) => {
                        kill_all(&mut running);
                        return Err(e);
                    }
                }
            }
        }
        if !running.is_empty() && !progressed {
            std::thread::sleep(std::time::Duration::from_millis(15));
        }
    }

    // Stage: merge in-process — `SweepReport::merge` re-checks shard
    // completeness, grid identity and overlap, so a lost or foreign
    // shard can never masquerade as the full design space.
    statuses.sort_by_key(|s| s.shard.0);
    done.sort_by_key(|(k, _)| *k);
    // Evidence first: should the merge below reject the shard set, the
    // per-shard records are already on disk (the success path refreshes
    // this file with the final copy-out count).
    if let Some(path) = &opts.status_out {
        let doc = status_doc(
            n,
            prewarm_translations,
            prewarm_cache_loads,
            cache_copied_in,
            0,
            &statuses,
        );
        write_status(path, &doc);
    }
    let reports: Vec<SweepReport> = done.into_iter().map(|(_, r)| r).collect();
    let merged = SweepReport::merge(&reports)?;

    // Stage: cache copy-out (publish freshly translated entries back to
    // the synced directory).
    let cache_copied_out = match &opts.cache_from {
        Some(from) => cache::copy_entries(&cache_dir, from)?,
        None => 0,
    };

    let report = FleetReport {
        merged,
        shards: statuses,
        prewarm_translations,
        prewarm_cache_loads,
        cache_copied_in,
        cache_copied_out,
    };
    if let Some(path) = &opts.status_out {
        write_status(path, &report.status_json());
    }
    Ok(report)
}

/// Captured-stderr path for one shard (truncated on every relaunch, so
/// it always holds the latest attempt's output).
fn shard_err_path(work_dir: &Path, k: usize) -> PathBuf {
    work_dir.join(format!("shard-{k}.stderr"))
}

/// Spawn one shard process with its report/stdout/stderr paths wired up.
/// Any stale report file is removed first so a crash can never be
/// mistaken for a completed shard.
fn launch_shard(
    grid: &SweepGrid,
    cfg: &SweepConfig,
    opts: &FleetOpts,
    binary: &Path,
    work_dir: &Path,
    cache_dir: &Path,
    k: usize,
) -> Result<Child> {
    let out = work_dir.join(format!("shard-{k}.json"));
    let _ = std::fs::remove_file(&out);
    let args = shard_args(grid, cfg, k, opts.procs, cache_dir, &out);
    let mut cmd = Command::new(binary);
    cmd.args(&args)
        .stdin(Stdio::null())
        .stdout(std::fs::File::create(work_dir.join(format!("shard-{k}.stdout")))?)
        .stderr(std::fs::File::create(shard_err_path(work_dir, k))?);
    match &opts.failpoint {
        Some(fp) => {
            cmd.env("MODTRANS_FLEET_FAILPOINT", fp);
        }
        // Scrub any ambient failpoint (e.g. still exported from a
        // debugging shell): only an explicit FleetOpts request may
        // crash shards — "never set in production" must hold even in a
        // polluted environment.
        None => {
            cmd.env_remove("MODTRANS_FLEET_FAILPOINT");
        }
    }
    cmd.spawn().map_err(|e| {
        Error::Config(format!("failed to spawn shard process '{}': {e}", binary.display()))
    })
}

/// The child argv for shard `k` of `n`: the full grid and config
/// re-expressed in CLI tokens, plus the shard/cache/output wiring. Kept
/// total — every `SweepGrid`/`SweepConfig` field is either forwarded or
/// fleet-owned (`threads` is per shard; `shard` is assigned here).
fn shard_args(
    grid: &SweepGrid,
    cfg: &SweepConfig,
    k: usize,
    n: usize,
    cache_dir: &Path,
    out: &Path,
) -> Vec<String> {
    let parallelisms: Vec<&str> =
        grid.parallelisms.iter().map(|&p| cli_parallelism_token(p)).collect();
    let topologies: Vec<&str> = grid.topologies.iter().map(|&t| t.token()).collect();
    let collectives: Vec<&str> = grid.collectives.iter().map(|&c| c.token()).collect();
    let mut v = vec![
        "sweep".to_string(),
        grid.models.join(","),
        "--parallelisms".to_string(),
        parallelisms.join(","),
        "--topologies".to_string(),
        topologies.join(","),
        "--collectives".to_string(),
        collectives.join(","),
        "--npus".to_string(),
        cfg.npus.to_string(),
        "--mp-group".to_string(),
        cfg.mp_group.to_string(),
        "--batch".to_string(),
        cfg.batch.to_string(),
        "--iterations".to_string(),
        cfg.iterations.to_string(),
        "--threads".to_string(),
        cfg.threads.to_string(),
        "--bandwidth-gbps".to_string(),
        cfg.bandwidth_gbps.to_string(),
        "--latency-ns".to_string(),
        cfg.latency_ns.to_string(),
        "--hbm-gib".to_string(),
        (cfg.hbm_bytes >> 30).to_string(),
        "--zero".to_string(),
        zero_token(cfg.zero).to_string(),
        "--shard".to_string(),
        format!("{k}/{n}"),
        "--cache-dir".to_string(),
        cache_dir.display().to_string(),
        "--json-out".to_string(),
        out.display().to_string(),
    ];
    if cfg.skip_infeasible {
        v.push("--skip-infeasible".to_string());
    }
    if let Some(k) = cfg.top_k {
        v.push("--top".to_string());
        v.push(k.to_string());
    }
    v
}

/// The CLI spelling of a parallelism strategy (`--parallelisms` tokens
/// are lowercase; [`Parallelism::token`] is the uppercase workload-file
/// grammar).
fn cli_parallelism_token(p: Parallelism) -> &'static str {
    match p {
        Parallelism::Data => "data",
        Parallelism::Model => "model",
        Parallelism::HybridDataModel => "hybrid-dm",
        Parallelism::HybridModelData => "hybrid-md",
        Parallelism::Pipeline => "pipeline",
    }
}

/// The CLI `--zero` token for a ZeRO stage.
fn zero_token(z: ZeroStage) -> &'static str {
    match z {
        ZeroStage::None => "0",
        ZeroStage::OptimizerState => "1",
        ZeroStage::Gradients => "2",
        ZeroStage::Parameters => "3",
    }
}

/// Load and validate one shard's report file: parseable JSON, a valid
/// report, stamped with exactly the shard this fleet assigned.
fn read_shard_report(path: &Path, k: usize, n: usize) -> Result<SweepReport> {
    let text = std::fs::read_to_string(path).map_err(|e| {
        Error::Config(format!("shard report '{}' unreadable: {e}", path.display()))
    })?;
    let report = SweepReport::from_json(&crate::json::parse(&text)?)?;
    if report.shard != Some((k, n)) {
        return Err(Error::Config(format!(
            "shard report '{}' is stamped {:?}, expected shard {k}/{n}",
            path.display(),
            report.shard
        )));
    }
    Ok(report)
}

/// Last [`STDERR_TAIL_BYTES`] of a captured-stderr file, lossily decoded
/// and trimmed (empty string when the file is missing or empty).
fn stderr_tail(path: &Path) -> String {
    match std::fs::read(path) {
        Ok(bytes) => {
            let start = bytes.len().saturating_sub(STDERR_TAIL_BYTES);
            String::from_utf8_lossy(&bytes[start..]).trim().to_string()
        }
        Err(_) => String::new(),
    }
}

/// Kill and reap every still-running shard (the fleet is failing; no
/// orphan may keep writing into the shared cache or work directory).
fn kill_all(running: &mut Vec<ShardProc>) {
    for p in running.iter_mut() {
        let _ = p.child.kill();
        let _ = p.child.wait();
    }
    running.clear();
}

/// Best-effort search for the `modtrans` CLI binary when the current
/// executable is *not* it (benches, examples): `$MODTRANS_BIN` first,
/// then `modtrans` next to the current executable, then one directory up
/// (cargo puts benches in `deps/` and examples in `examples/`, one level
/// below the binary). Integration tests should prefer
/// `env!("CARGO_BIN_EXE_modtrans")`, which cargo guarantees.
pub fn locate_binary() -> Option<PathBuf> {
    let name = format!("modtrans{}", std::env::consts::EXE_SUFFIX);
    if let Ok(p) = std::env::var("MODTRANS_BIN") {
        let p = PathBuf::from(p);
        if p.is_file() {
            return Some(p);
        }
    }
    let exe = std::env::current_exe().ok()?;
    let dir = exe.parent()?;
    let candidates = [dir.join(&name), dir.parent()?.join(&name)];
    candidates.into_iter().find(|c| c.is_file())
}

/// Test-only crash injection for fleet failure-path tests, driven by the
/// `MODTRANS_FLEET_FAILPOINT` environment variable (which the fleet sets
/// on its children only when [`FleetOpts::failpoint`] is given — it is
/// never set in production). Grammar:
///
/// * `"K"` — a process running shard `K` always aborts with
///   [`FAILPOINT_EXIT_CODE`].
/// * `"K:once=PATH"` — abort only if `PATH` does not exist yet, creating
///   it first; the marker makes the shard fail exactly once, so the
///   fleet's retry must succeed.
///
/// Called by the CLI `sweep` command after argument parsing (i.e. the
/// process dies *mid-run*, after it has been assigned real work).
pub fn shard_failpoint(shard: Option<(usize, usize)>) {
    let Some((k, _)) = shard else { return };
    let Ok(spec) = std::env::var("MODTRANS_FLEET_FAILPOINT") else { return };
    let (target, marker) = match spec.split_once(':') {
        Some((t, rest)) => (t, rest.strip_prefix("once=")),
        None => (spec.as_str(), None),
    };
    if !matches!(target.parse::<usize>(), Ok(t) if t == k) {
        return;
    }
    if let Some(path) = marker {
        if Path::new(path).exists() {
            return;
        }
        let _ = std::fs::write(path, "crashed");
    }
    eprintln!("failpoint: injected crash in shard {k} (MODTRANS_FLEET_FAILPOINT)");
    std::process::exit(FAILPOINT_EXIT_CODE);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_procs_is_a_config_error() {
        let opts = FleetOpts { procs: 0, ..Default::default() };
        let err = run_fleet(&SweepGrid::default(), &SweepConfig::default(), &opts).unwrap_err();
        assert!(err.to_string().contains("at least one shard process"));
    }

    #[test]
    fn preset_shard_is_rejected() {
        let cfg = SweepConfig { shard: Some((1, 2)), ..Default::default() };
        let err = run_fleet(&SweepGrid::default(), &cfg, &FleetOpts::default()).unwrap_err();
        assert!(err.to_string().contains("assigns shards itself"));
    }

    #[test]
    fn fractional_gib_hbm_is_rejected() {
        let cfg = SweepConfig { hbm_bytes: (1 << 30) + 1, ..Default::default() };
        let err = run_fleet(&SweepGrid::default(), &cfg, &FleetOpts::default()).unwrap_err();
        assert!(err.to_string().contains("whole number of GiB"));
    }

    #[test]
    fn empty_grid_fails_before_any_spawn() {
        let grid = SweepGrid { models: vec![], ..Default::default() };
        let err = run_fleet(&grid, &SweepConfig::default(), &FleetOpts::default()).unwrap_err();
        assert!(err.to_string().contains("grid is empty"));
    }

    #[test]
    fn shard_args_round_trip_through_the_cli_grammar() {
        // Every forwarded token must be accepted by the CLI parsers the
        // child process will run them through.
        let grid = SweepGrid {
            models: vec!["mlp".into(), "resnet18".into()],
            parallelisms: vec![
                Parallelism::Data,
                Parallelism::Model,
                Parallelism::HybridDataModel,
                Parallelism::HybridModelData,
                Parallelism::Pipeline,
            ],
            topologies: vec![
                crate::sim::TopologyKind::Ring,
                crate::sim::TopologyKind::FullyConnected,
                crate::sim::TopologyKind::Switch,
                crate::sim::TopologyKind::Torus2D,
            ],
            collectives: vec![
                super::super::CollectiveAlgo::Direct,
                super::super::CollectiveAlgo::Pipelined,
                super::super::CollectiveAlgo::PipelinedLifo,
            ],
        };
        let cfg = SweepConfig {
            zero: ZeroStage::Gradients,
            skip_infeasible: true,
            top_k: Some(5),
            ..Default::default()
        };
        let args =
            shard_args(&grid, &cfg, 2, 4, Path::new("/tmp/cache"), Path::new("/tmp/out.json"));
        assert_eq!(args[0], "sweep");
        assert_eq!(args[1], "mlp,resnet18");
        let opt = |key: &str| {
            let i = args.iter().position(|a| a == key).unwrap_or_else(|| panic!("{key} missing"));
            args[i + 1].clone()
        };
        for p in opt("--parallelisms").split(',') {
            assert!(
                matches!(p, "data" | "model" | "hybrid-dm" | "hybrid-md" | "pipeline"),
                "unforwardable parallelism token '{p}'"
            );
        }
        for t in opt("--topologies").split(',') {
            crate::sim::TopologyKind::from_token(t).unwrap();
        }
        for c in opt("--collectives").split(',') {
            super::super::CollectiveAlgo::from_token(c).unwrap();
        }
        assert_eq!(opt("--shard"), "2/4");
        assert_eq!(opt("--zero"), "2");
        assert_eq!(opt("--hbm-gib"), "32");
        assert_eq!(opt("--cache-dir"), "/tmp/cache");
        assert_eq!(opt("--json-out"), "/tmp/out.json");
        assert!(args.iter().any(|a| a == "--skip-infeasible"));
        // Top-K pruning forwards so each shard prunes against its local
        // top-K (merge truncates the union back to K).
        assert_eq!(opt("--top"), "5");
    }

    #[test]
    fn failpoint_is_inert_without_the_env_var() {
        // Never crashes here: the env var is unset (deliberately NOT
        // set in-process — concurrent setenv/getenv across test threads
        // is UB on glibc). The armed branches — crash, crash-once
        // marker, and "spec names a different shard" — are exercised
        // for real by tests/fleet_smoke.rs in child processes, where
        // the variable is scoped to the spawned shard.
        shard_failpoint(None);
        shard_failpoint(Some((1, 4)));
        shard_failpoint(Some((4, 4)));
    }

    #[test]
    fn stderr_tail_handles_missing_and_long_files() {
        assert_eq!(stderr_tail(Path::new("/no/such/stderr-file")), "");
        let dir = std::env::temp_dir();
        let path = dir.join(format!("mt_fleet_tail_{}", std::process::id()));
        std::fs::write(&path, format!("{}END", "x".repeat(10_000))).unwrap();
        let tail = stderr_tail(&path);
        assert!(tail.len() <= STDERR_TAIL_BYTES);
        assert!(tail.ends_with("END"));
        let _ = std::fs::remove_file(&path);
    }
}
