//! Measured-compute calibration: executes the AOT GEMM artifacts through
//! PJRT and turns the timings into a [`ComputeTimeModel`].
//!
//! The paper's workflow extracts per-layer compute times by profiling real
//! hardware (via SCALE-sim or GPU measurement). With no accelerator in
//! this environment, the equivalent path is: the L1 Pallas matmul kernel,
//! lowered by `python/compile/aot.py` into `artifacts/gemm_MxKxN.hlo.txt`
//! for a fixed shape menu, executed here with real inputs, timed, and
//! interpolated per layer by MAC ratio (seconds-per-MAC from the nearest
//! menu shape). The substitution is recorded in DESIGN.md.

use crate::compute::Gemm;
use crate::error::{Error, Result};
use crate::json::{self, Value};
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::translator::{ComputeTimeModel, LayerInfo, LayerKind};
use std::collections::BTreeMap;
use std::path::Path;

/// The GEMM shape menu — MUST match `python/compile/aot.py`'s `MENU`.
pub const GEMM_MENU: [Gemm; 5] = [
    Gemm { m: 128, k: 128, n: 128 },
    Gemm { m: 256, k: 256, n: 256 },
    Gemm { m: 512, k: 512, n: 512 },
    Gemm { m: 1024, k: 1024, n: 1024 },
    Gemm { m: 256, k: 2048, n: 512 },
];

/// Artifact name for a menu shape (file is `<name>.hlo.txt`).
pub fn artifact_name(g: Gemm) -> String {
    format!("gemm_{}x{}x{}", g.m, g.k, g.n)
}

/// Measured timings for the menu.
#[derive(Debug, Clone, Default)]
pub struct Calibration {
    /// (shape, median wall ns) pairs.
    pub entries: Vec<(Gemm, u64)>,
}

impl Calibration {
    /// Run every available menu artifact `reps` times (requires the
    /// `pjrt` feature — the only part of this module that executes
    /// artifacts; loading saved calibrations is pure JSON).
    #[cfg(feature = "pjrt")]
    pub fn measure(rt: &Runtime, reps: usize) -> Result<Calibration> {
        let mut entries = Vec::new();
        for g in GEMM_MENU {
            let name = artifact_name(g);
            if !rt.has(&name) {
                continue;
            }
            let a = vec![1.0f32; (g.m * g.k) as usize];
            let b = vec![0.5f32; (g.k * g.n) as usize];
            let dt = rt.time_artifact(
                &name,
                &[(&a, &[g.m as i64, g.k as i64]), (&b, &[g.k as i64, g.n as i64])],
                reps,
            )?;
            entries.push((g, dt.as_nanos() as u64));
        }
        if entries.is_empty() {
            return Err(Error::Runtime(
                "no gemm_* artifacts loaded — run `make artifacts` first".into(),
            ));
        }
        Ok(Calibration { entries })
    }

    /// Serialize to JSON.
    pub fn to_json(&self) -> Value {
        let arr: Vec<Value> = self
            .entries
            .iter()
            .map(|(g, ns)| {
                let mut m = BTreeMap::new();
                m.insert("m".into(), Value::Num(g.m as f64));
                m.insert("k".into(), Value::Num(g.k as f64));
                m.insert("n".into(), Value::Num(g.n as f64));
                m.insert("ns".into(), Value::Num(*ns as f64));
                Value::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("gemm_timings".into(), Value::Arr(arr));
        Value::Obj(m)
    }

    /// Parse from JSON.
    pub fn from_json(v: &Value) -> Result<Calibration> {
        let arr = v
            .get("gemm_timings")
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::Config("calibration: missing 'gemm_timings'".into()))?;
        let mut entries = Vec::with_capacity(arr.len());
        for e in arr {
            entries.push((
                Gemm { m: e.req_u64("m")?, k: e.req_u64("k")?, n: e.req_u64("n")? },
                e.req_u64("ns")?,
            ));
        }
        Ok(Calibration { entries })
    }

    /// Save to a file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_json_pretty())?;
        Ok(())
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Calibration> {
        let text = std::fs::read_to_string(path)?;
        Calibration::from_json(&json::parse(&text)?)
    }

    /// Estimate wall ns for an arbitrary GEMM: nearest menu entry by MAC
    /// count (log distance), scaled by the MAC ratio.
    pub fn estimate_ns(&self, g: Gemm) -> u64 {
        assert!(!self.entries.is_empty());
        let macs = g.macs().max(1) as f64;
        let mut best_d = f64::INFINITY;
        let mut best_macs = 1u64;
        let mut best_ns = 1u64;
        for (e, ns) in &self.entries {
            let d = (macs.ln() - (e.macs().max(1) as f64).ln()).abs();
            // `<=` keeps the *last* of equal minima, matching the
            // Iterator::min_by tie-break this fold replaced.
            if d <= best_d {
                best_d = d;
                best_macs = e.macs().max(1);
                best_ns = *ns;
            }
        }
        let scale = macs / best_macs as f64;
        ((best_ns as f64) * scale).ceil().max(1.0) as u64
    }
}

/// [`ComputeTimeModel`] backed by measured GEMM timings.
#[derive(Debug, Clone)]
pub struct MeasuredCompute {
    /// The calibration table.
    pub cal: Calibration,
    /// Batch size (must match extraction batch).
    pub batch: i64,
}

impl ComputeTimeModel for MeasuredCompute {
    fn layer_times(&self, layer: &LayerInfo) -> (u64, u64, u64) {
        if layer.kind == LayerKind::Embedding {
            return (1, 1, 1);
        }
        let f = Gemm::from_layer(layer, self.batch);
        let fwd = self.cal.estimate_ns(f);
        let ig = self.cal.estimate_ns(Gemm { m: f.m, k: f.n, n: f.k });
        let wg = self.cal.estimate_ns(Gemm { m: f.k, k: f.m, n: f.n });
        (fwd, ig, wg)
    }

    /// Digest of the full calibration table plus the batch: any measured
    /// entry changing (or a different calibration file) changes the
    /// fingerprint.
    fn fingerprint(&self) -> String {
        let mut h = crate::util::FNV1A_OFFSET;
        for (g, ns) in &self.cal.entries {
            for v in [g.m, g.k, g.n, *ns] {
                h = crate::util::fnv1a_extend(h, &v.to_le_bytes());
            }
        }
        format!("measured:b{}:{:016x}", self.batch, h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_cal() -> Calibration {
        Calibration {
            entries: vec![
                (Gemm { m: 128, k: 128, n: 128 }, 10_000),
                (Gemm { m: 1024, k: 1024, n: 1024 }, 5_000_000),
            ],
        }
    }

    #[test]
    fn estimate_scales_by_mac_ratio() {
        let cal = fake_cal();
        // Exactly a menu shape: returns the measured value.
        assert_eq!(cal.estimate_ns(Gemm { m: 128, k: 128, n: 128 }), 10_000);
        // 2x the MACs of the small shape: ~2x the time.
        let t = cal.estimate_ns(Gemm { m: 256, k: 128, n: 128 });
        assert_eq!(t, 20_000);
    }

    #[test]
    fn nearest_by_log_macs() {
        let cal = fake_cal();
        // A 512³ GEMM (134M MACs): nearer (in log space) to 1024³ (1G)
        // than to 128³ (2M) → scaled down from the big entry.
        let t = cal.estimate_ns(Gemm { m: 512, k: 512, n: 512 });
        let expect = (5_000_000.0 * (512f64 * 512.0 * 512.0) / (1024f64 * 1024.0 * 1024.0)).ceil();
        assert_eq!(t, expect as u64);
    }

    #[test]
    fn json_roundtrip() {
        let cal = fake_cal();
        let v = cal.to_json();
        let cal2 = Calibration::from_json(&v).unwrap();
        assert_eq!(cal2.entries.len(), 2);
        assert_eq!(cal2.entries[0].0, Gemm { m: 128, k: 128, n: 128 });
        assert_eq!(cal2.entries[1].1, 5_000_000);
    }

    #[test]
    fn menu_names_are_stable() {
        assert_eq!(artifact_name(GEMM_MENU[0]), "gemm_128x128x128");
        assert_eq!(artifact_name(GEMM_MENU[4]), "gemm_256x2048x512");
    }

    #[test]
    fn save_load_roundtrip() {
        let cal = fake_cal();
        let p = std::env::temp_dir().join("modtrans_cal_test.json");
        cal.save(&p).unwrap();
        let cal2 = Calibration::load(&p).unwrap();
        assert_eq!(cal2.entries.len(), cal.entries.len());
        let _ = std::fs::remove_file(&p);
    }
}
