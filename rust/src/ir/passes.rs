//! IR annotation passes. Each pass is independent: compute costs, comm
//! planning and memory modeling read the same structural facts but never
//! each other's outputs, so they can run in any order (or not at all —
//! emitters check for the slots they need).
//!
//! The comm pass comes in two forms: [`annotate_comm`] writes the IR's
//! own comm slots (the one-shot `translate` path), while
//! [`plan_comm_into`] plans into a caller-owned buffer without touching
//! the shared IR — the sweep hot path, where one compute-annotated IR is
//! shared read-only across worker threads and each scenario re-plans
//! only this cheap, parallelism-dependent pass. The `modtrans-lint`
//! `no-string-alloc` rule gates this module in CI: no per-layer string
//! allocation.

use super::{ModelIR, PhaseCost};
use crate::translator::{
    comm_for_layer, memory_per_npu, CommPlan, ComputeTimeModel, LayerInfo, MemoryOpts,
    MemoryReport, ModelSummary, TranslateOpts,
};

/// The compute pass's per-layer unit: one layer's cost slot.
// lint: hot-path
fn cost_of(info: &LayerInfo, compute: &dyn ComputeTimeModel) -> PhaseCost {
    let (fwd_ns, ig_ns, wg_ns) = compute.layer_times(info);
    PhaseCost { fwd_ns, ig_ns, wg_ns, update_ns: compute.update_time(info) }
}

/// Fill the per-phase compute-cost slots from a compute model. Valid for
/// every parallelism strategy at the IR's (model, batch) — this is the
/// annotation the sweep cache shares across scenarios.
// lint: hot-path
pub fn annotate_compute(ir: &mut ModelIR, compute: &dyn ComputeTimeModel) {
    let (summary, costs, _) = ir.parts_mut();
    for (info, slot) in summary.layers.iter().zip(costs.iter_mut()) {
        *slot = cost_of(info, compute);
    }
    ir.mark_compute_annotated();
}

/// Slice-level compute pass over bare structural facts: clear and refill
/// a caller-owned cost buffer. The IR-free form
/// [`crate::translator::to_workload`] composes — no summary clone, no
/// IR allocation.
// lint: hot-path
pub fn compute_costs_into(
    summary: &ModelSummary,
    compute: &dyn ComputeTimeModel,
    out: &mut Vec<PhaseCost>,
) {
    out.clear();
    out.extend(summary.layers.iter().map(|info| cost_of(info, compute)));
}

/// Fill the IR's comm slots for one parallelism strategy.
// lint: hot-path
pub fn annotate_comm(ir: &mut ModelIR, opts: TranslateOpts) {
    let (summary, _, comms) = ir.parts_mut();
    for (info, slot) in summary.layers.iter().zip(comms.iter_mut()) {
        *slot = comm_for_layer(info, opts);
    }
    ir.mark_comm_annotated(opts.parallelism);
}

/// Plan communication into a reusable caller-owned buffer, leaving the
/// (possibly shared) IR untouched. `out` is cleared and refilled; its
/// capacity is reused, so steady-state re-planning performs no heap
/// allocation.
// lint: hot-path
pub fn plan_comm_into(ir: &ModelIR, opts: TranslateOpts, out: &mut Vec<CommPlan>) {
    plan_comm_for_summary_into(ir.summary(), opts, out);
}

/// Slice-level comm pass over bare structural facts (the form
/// [`crate::translator::to_workload`] composes).
// lint: hot-path
pub fn plan_comm_for_summary_into(
    summary: &ModelSummary,
    opts: TranslateOpts,
    out: &mut Vec<CommPlan>,
) {
    out.clear();
    out.extend(summary.layers.iter().map(|info| comm_for_layer(info, opts)));
}

/// Memory pass: per-NPU training footprint under the given parallelism
/// options. Reads only the structural facts (no cost/comm slots needed).
pub fn memory(ir: &ModelIR, opts: TranslateOpts, mem: MemoryOpts) -> MemoryReport {
    memory_per_npu(ir.summary(), opts, mem)
}

/// Serial critical-path compute time of one training iteration: every
/// layer's forward, input-grad, weight-grad and optimizer-update cost,
/// summed. Exactly the per-iteration busy time of a single compute
/// resource executing the annotated costs back to back — which is what
/// the sweep's analytic lower bound ([`crate::sweep::bound`]) charges
/// for compute, since the flat simulation path schedules all four
/// phases on one representative-NPU stream. Requires the compute pass
/// to have run (unannotated cost slots are zero).
pub fn serial_compute_ns(ir: &ModelIR) -> u64 {
    ir.costs().iter().map(|c| c.fwd_ns + c.ig_ns + c.wg_ns + c.update_ns).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::frontend;
    use crate::translator::ConstantCompute;
    use crate::workload::{CommType, Parallelism};

    fn opts(p: Parallelism) -> TranslateOpts {
        TranslateOpts { parallelism: p, ..Default::default() }
    }

    #[test]
    fn compute_pass_fills_every_cost_slot() {
        let mut ir = frontend::from_zoo("mlp", 8).unwrap();
        annotate_compute(&mut ir, &ConstantCompute(42));
        assert!(ir.compute_annotated());
        for l in ir.layers() {
            assert_eq!(l.cost.fwd_ns, 42);
            assert_eq!(l.cost.ig_ns, 42);
            assert_eq!(l.cost.wg_ns, 42);
            // Default update model: 3x weight bytes at 100 bytes/ns.
            assert_eq!(l.cost.update_ns, (l.info.weight_bytes * 3) / 100);
        }
    }

    #[test]
    fn comm_pass_matches_comm_for_layer() {
        let mut ir = frontend::from_zoo("mlp", 8).unwrap();
        annotate_comm(&mut ir, opts(Parallelism::Data));
        assert_eq!(ir.comm_annotated(), Some(Parallelism::Data));
        for l in ir.layers() {
            assert_eq!(l.comm.fwd.0, CommType::None);
            assert_eq!(l.comm.wg.0, CommType::AllReduce);
            assert_eq!(l.comm.wg.1, l.info.weight_bytes);
        }
    }

    #[test]
    fn plan_into_reuses_the_buffer_and_leaves_ir_clean() {
        let ir = frontend::from_zoo("mlp", 8).unwrap();
        let mut buf = Vec::new();
        plan_comm_into(&ir, opts(Parallelism::Data), &mut buf);
        assert_eq!(buf.len(), ir.num_layers());
        let cap = buf.capacity();
        plan_comm_into(&ir, opts(Parallelism::Model), &mut buf);
        assert_eq!(buf.capacity(), cap, "re-planning should not reallocate");
        assert_eq!(buf[0].fwd.0, CommType::AllGather);
        // The shared IR's own slots stay unannotated.
        assert_eq!(ir.comm_annotated(), None);
        assert_eq!(ir.layer(0).comm.wg.0, CommType::None);
    }

    #[test]
    fn memory_pass_agrees_with_translator_memory() {
        let ir = frontend::from_zoo("vgg16", 32).unwrap();
        let o = opts(Parallelism::Data);
        let m = MemoryOpts::default();
        assert_eq!(memory(&ir, o, m), memory_per_npu(ir.summary(), o, m));
    }
}
