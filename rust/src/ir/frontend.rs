//! IR frontends: build a [`ModelIR`] from a model source.
//!
//! Three entry points cover the paper's input modes (§3.2–§3.3):
//!
//! * [`from_onnx_bytes`] — raw `.onnx` protobuf bytes (metadata-only
//!   decode; weight payloads are never copied).
//! * [`from_model`] — an already-decoded in-memory ONNX model.
//! * [`from_zoo`] — a zoo model **directly from its builder**: the graph
//!   goes straight from the in-memory builder output into extraction,
//!   skipping the ONNX encode/decode round-trip the byte path pays
//!   (`benches/fig6_translation_time.rs` tracks the win).
//!
//! All frontends converge on the same structural extraction
//! ([`crate::translator::extract()`]), so downstream passes and emitters
//! never see which source a model came from.

use super::ModelIR;
use crate::error::Result;
use crate::onnx::Model;
use crate::translator::{self, ModelSummary};
use crate::zoo::{self, WeightFill, ZooOpts};

/// Lift an already-extracted summary into an unannotated IR.
pub fn from_summary(summary: ModelSummary) -> ModelIR {
    ModelIR::from_summary(summary)
}

/// Build IR from an in-memory ONNX model at the given batch size.
pub fn from_model(model: &Model, batch: i64) -> Result<ModelIR> {
    Ok(ModelIR::from_summary(translator::extract(model, batch)?))
}

/// Build IR from raw `.onnx` bytes (metadata-only decode).
pub fn from_onnx_bytes(bytes: &[u8], batch: i64) -> Result<ModelIR> {
    Ok(ModelIR::from_summary(translator::extract_from_bytes(bytes, batch)?))
}

/// Build IR directly from a zoo model builder — no ONNX serialization
/// round-trip, no weight payload materialization.
pub fn from_zoo(name: &str, batch: i64) -> Result<ModelIR> {
    let model = zoo::get(name, ZooOpts { weights: WeightFill::Empty })?;
    from_model(&model, batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::encode_model;

    #[test]
    fn zoo_direct_matches_onnx_byte_path() {
        // The two frontends must extract identical structural facts.
        let direct = from_zoo("mlp", 8).unwrap();
        let model = zoo::get("mlp", ZooOpts { weights: WeightFill::Empty }).unwrap();
        let via_bytes = from_onnx_bytes(&encode_model(&model), 8).unwrap();
        assert_eq!(direct.num_layers(), via_bytes.num_layers());
        for (a, b) in direct.layers().zip(via_bytes.layers()) {
            assert_eq!(a.info.name, b.info.name);
            assert_eq!(a.info.kind, b.info.kind);
            assert_eq!(a.info.weight_bytes, b.info.weight_bytes);
            assert_eq!(a.info.in_act_bytes, b.info.in_act_bytes);
            assert_eq!(a.info.out_act_bytes, b.info.out_act_bytes);
            assert_eq!(a.info.macs, b.info.macs);
        }
        assert_eq!(direct.summary().total_params, via_bytes.summary().total_params);
        assert_eq!(direct.summary().total_bytes, via_bytes.summary().total_bytes);
    }

    #[test]
    fn unknown_zoo_model_is_an_error() {
        assert!(from_zoo("not-a-model", 8).is_err());
    }

    #[test]
    fn bad_bytes_are_an_error() {
        assert!(from_onnx_bytes(&[0xff, 0xff, 0xff], 8).is_err());
    }
}
