//! IR frontends: build a [`ModelIR`] from a model source.
//!
//! Four entry points cover the paper's input modes (§3.2–§3.3) plus the
//! closed emit→read loop:
//!
//! * [`from_onnx_bytes`] — raw `.onnx` protobuf bytes (metadata-only
//!   decode; weight payloads are never copied).
//! * [`from_model`] — an already-decoded in-memory ONNX model.
//! * [`from_zoo`] — a zoo model **directly from its builder**: the graph
//!   goes straight from the in-memory builder output into extraction,
//!   skipping the ONNX encode/decode round-trip the byte path pays
//!   (`benches/fig6_translation_time.rs` tracks the win).
//! * [`from_et_json`] — a `modtrans-et-json/v2` document
//!   ([`crate::ir::emit::et_json`]'s output, or an externally produced
//!   trace in the same schema) parsed back into a **fully annotated**
//!   IR: the structural `layers` section rebuilds the
//!   [`ModelSummary`], and the task graph is replayed positionally to
//!   recover every per-layer fwd/ig/wg/update cost and comm plan.
//!   Strict by design — schema/version mismatches, non-dense ids,
//!   forward-pointing deps, count mismatches or out-of-grammar nodes
//!   are all hard errors, and `et_json(from_et_json(doc))` re-emits
//!   emitter-produced documents byte-identically (the persistent sweep
//!   cache's disk-tier contract).
//!
//! The first three converge on the same structural extraction
//! ([`crate::translator::extract()`]), so downstream passes and emitters
//! never see which source a model came from; the et-json reader restores
//! annotations instead of recomputing them — replaying a trace, not
//! re-deriving one.

use super::emit::ET_JSON_SCHEMA;
use super::{ModelIR, PhaseCost};
use crate::error::{Error, Result};
use crate::json::Value;
use crate::onnx::{DataType, Model};
use crate::translator::{self, CommPlan, LayerInfo, LayerKind, ModelSummary};
use crate::workload::{CommType, Parallelism};
use crate::zoo::{self, WeightFill, ZooOpts};

/// Lift an already-extracted summary into an unannotated IR.
pub fn from_summary(summary: ModelSummary) -> ModelIR {
    ModelIR::from_summary(summary)
}

/// Build IR from an in-memory ONNX model at the given batch size.
pub fn from_model(model: &Model, batch: i64) -> Result<ModelIR> {
    let ir = ModelIR::from_summary(translator::extract(model, batch)?);
    // Frontend-boundary hook: a structural extraction that violates the
    // IR invariants is a bug here, not in the caller (debug builds only;
    // `modtrans check` exercises the verifier in release).
    debug_assert!(
        super::verify::verify(&ir).is_ok(),
        "extract() produced an invalid IR"
    );
    Ok(ir)
}

/// Build IR from raw `.onnx` bytes (metadata-only decode).
pub fn from_onnx_bytes(bytes: &[u8], batch: i64) -> Result<ModelIR> {
    Ok(ModelIR::from_summary(translator::extract_from_bytes(bytes, batch)?))
}

/// Build IR directly from a zoo model builder — no ONNX serialization
/// round-trip, no weight payload materialization.
pub fn from_zoo(name: &str, batch: i64) -> Result<ModelIR> {
    let model = zoo::get(name, ZooOpts { weights: WeightFill::Empty })?;
    from_model(&model, batch)
}

/// Reader-side error with a uniform prefix.
fn fail(msg: impl std::fmt::Display) -> Error {
    Error::translate(format!("et-json reader: {msg}"))
}

/// 2^53 as f64 — the reader refuses anything beyond it, mirroring the
/// emitter's [`super::emit::MAX_SAFE_JSON_INT`] guard: a larger value in
/// a document has already been rounded by some f64-backed writer, and
/// accepting it would silently replay corrupted durations/sizes.
const MAX_SAFE: f64 = super::emit::MAX_SAFE_JSON_INT as f64;

/// Read an integer-valued JSON number as i64 (exact in f64).
fn read_i64(v: &Value, key: &str) -> Result<i64> {
    v.get(key)
        .and_then(Value::as_f64)
        .filter(|f| f.fract() == 0.0 && f.abs() <= MAX_SAFE)
        .map(|f| f as i64)
        .ok_or_else(|| fail(format!("missing/invalid integer field '{key}'")))
}

/// Read a non-negative integer-valued JSON number as u64, bounded to the
/// exactly-representable range (unlike `Value::req_u64`, which would
/// accept an already-rounded or saturating huge float).
fn read_u64(v: &Value, key: &str) -> Result<u64> {
    v.get(key)
        .and_then(Value::as_f64)
        .filter(|f| f.fract() == 0.0 && (0.0..=MAX_SAFE).contains(f))
        .map(|f| f as u64)
        .ok_or_else(|| {
            fail(format!("missing/invalid integer field '{key}' (need 0 ..= 2^53)"))
        })
}

/// One structural layer entry → [`LayerInfo`].
fn read_layer(l: &Value, i: usize) -> Result<LayerInfo> {
    let name = l.req_str("name")?.to_string();
    if name.is_empty() {
        return Err(fail(format!("layer {i} has an empty name")));
    }
    let kind = LayerKind::from_label(l.req_str("kind")?)?;
    let dtype = DataType::from_i32(read_i64(l, "dtype")? as i32)?;
    let shape_json = l
        .get("out_shape")
        .and_then(Value::as_arr)
        .ok_or_else(|| fail(format!("layer '{name}': missing 'out_shape' array")))?;
    let mut out_shape = Vec::with_capacity(shape_json.len());
    for d in shape_json {
        let dim = d
            .as_f64()
            .filter(|f| f.fract() == 0.0 && f.abs() <= MAX_SAFE)
            .ok_or_else(|| fail(format!("layer '{name}': non-integer out_shape dim")))?;
        out_shape.push(dim as i64);
    }
    Ok(LayerInfo {
        name,
        kind,
        variables: read_u64(l, "variables")?,
        dtype,
        weight_bytes: read_u64(l, "weight_bytes")?,
        in_act_bytes: read_u64(l, "in_act_bytes")?,
        out_act_bytes: read_u64(l, "out_act_bytes")?,
        macs: read_u64(l, "macs")?,
        out_shape,
    })
}

/// Consume the node at `*c`, which must be a `COMP_NODE` named `expect`;
/// return its duration.
fn comp_node(nodes: &[Value], c: &mut usize, expect: &str) -> Result<u64> {
    let node = nodes
        .get(*c)
        .ok_or_else(|| fail(format!("node list ends before expected COMP_NODE '{expect}'")))?;
    if node.get("name").and_then(Value::as_str) != Some(expect) {
        return Err(fail(format!(
            "node {}: expected COMP_NODE '{expect}', found '{}'",
            *c,
            node.get("name").and_then(Value::as_str).unwrap_or("<unnamed>")
        )));
    }
    if node.get("type").and_then(Value::as_str) != Some("COMP_NODE") {
        return Err(fail(format!("node '{expect}' is not a COMP_NODE")));
    }
    let d = read_u64(node, "duration_ns")?;
    *c += 1;
    Ok(d)
}

/// Consume the node at `*c` iff it is the `COMM_COLL_NODE` named
/// `expect`; a different (or absent) node means the phase planned no
/// collective and nothing is consumed.
fn comm_node(nodes: &[Value], c: &mut usize, expect: &str) -> Result<Option<(CommType, u64)>> {
    let Some(node) = nodes.get(*c) else { return Ok(None) };
    if node.get("name").and_then(Value::as_str) != Some(expect)
        || node.get("type").and_then(Value::as_str) != Some("COMM_COLL_NODE")
    {
        return Ok(None);
    }
    let ty = CommType::from_token(node.req_str("comm_type")?)?;
    if ty == CommType::None {
        return Err(fail(format!("collective node '{expect}' declares comm_type NONE")));
    }
    let size = read_u64(node, "comm_size")?;
    *c += 1;
    Ok(Some((ty, size)))
}

/// Parse a `modtrans-et-json/v2` document back into a fully annotated
/// [`ModelIR`] (see the module docs for the grammar and strictness
/// guarantees). The result is always compute-annotated; it is
/// comm-annotated iff the document declares a parallelism.
pub fn from_et_json(doc: &Value) -> Result<ModelIR> {
    let schema = doc
        .get("schema")
        .and_then(Value::as_str)
        .ok_or_else(|| fail("missing 'schema' field"))?;
    if schema != ET_JSON_SCHEMA {
        return Err(fail(format!(
            "unsupported schema '{schema}' (this reader takes '{ET_JSON_SCHEMA}'; \
             v1 documents predate the structural layer section and cannot be replayed)"
        )));
    }
    let model = doc.req_str("model")?.to_string();
    let batch = read_i64(doc, "batch")?;
    let num_layers = read_u64(doc, "num_layers")? as usize;
    if num_layers == 0 {
        return Err(fail("document declares zero layers"));
    }
    let total_params = read_u64(doc, "total_params")?;
    let total_bytes = read_u64(doc, "total_bytes")?;
    let parallelism = match doc.get("parallelism") {
        Some(Value::Null) => None,
        Some(Value::Str(s)) => Some(Parallelism::from_token(s)?),
        _ => return Err(fail("missing/invalid 'parallelism' field (string or null)")),
    };

    let layers_json = doc
        .get("layers")
        .and_then(Value::as_arr)
        .ok_or_else(|| fail("missing 'layers' array"))?;
    if layers_json.len() != num_layers {
        return Err(fail(format!(
            "num_layers = {num_layers} but the 'layers' array has {} entries",
            layers_json.len()
        )));
    }
    let mut layers = Vec::with_capacity(num_layers);
    for (i, l) in layers_json.iter().enumerate() {
        layers.push(read_layer(l, i)?);
    }

    // Global node invariants: dense creation-ordered ids, backward deps.
    let nodes = doc
        .get("nodes")
        .and_then(Value::as_arr)
        .ok_or_else(|| fail("missing 'nodes' array"))?;
    for (i, node) in nodes.iter().enumerate() {
        if node.get("id").and_then(Value::as_u64) != Some(i as u64) {
            return Err(fail(format!("node {i}: ids must be dense and creation-ordered")));
        }
        let deps = node
            .get("data_deps")
            .and_then(Value::as_arr)
            .ok_or_else(|| fail(format!("node {i}: missing 'data_deps' array")))?;
        for d in deps {
            match d.as_u64() {
                Some(x) if x < i as u64 => {}
                _ => {
                    return Err(fail(format!(
                        "node {i}: data_deps must reference earlier nodes only"
                    )))
                }
            }
        }
    }

    // Replay the emitter's deterministic order — forward chain, then the
    // reverse backward sweep — recovering each layer's costs and plan.
    let mut costs = vec![PhaseCost::default(); num_layers];
    let mut comms = vec![CommPlan::none(); num_layers];
    let mut c = 0usize;
    for (i, layer) in layers.iter().enumerate() {
        let name = &layer.name;
        costs[i].fwd_ns = comp_node(nodes, &mut c, &format!("{name}.fwd"))?;
        if let Some(x) = comm_node(nodes, &mut c, &format!("{name}.fwd.comm"))? {
            comms[i].fwd = x;
        }
    }
    for (i, layer) in layers.iter().enumerate().rev() {
        let name = &layer.name;
        costs[i].ig_ns = comp_node(nodes, &mut c, &format!("{name}.ig"))?;
        if let Some(x) = comm_node(nodes, &mut c, &format!("{name}.ig.comm"))? {
            comms[i].ig = x;
        }
        costs[i].wg_ns = comp_node(nodes, &mut c, &format!("{name}.wg"))?;
        if let Some(x) = comm_node(nodes, &mut c, &format!("{name}.wg.comm"))? {
            comms[i].wg = x;
        }
        costs[i].update_ns = comp_node(nodes, &mut c, &format!("{name}.update"))?;
    }
    if c != nodes.len() {
        return Err(fail(format!(
            "{} trailing node(s) after the training-step graph",
            nodes.len() - c
        )));
    }
    if parallelism.is_none() && comms.iter().any(|p| *p != CommPlan::none()) {
        return Err(fail("collective nodes present but 'parallelism' is null"));
    }

    let mut ir = ModelIR::from_summary(ModelSummary {
        model_name: model,
        layers,
        all_initializers: Vec::new(),
        batch,
        total_params,
        total_bytes,
    });
    {
        let (_, cost_slots, comm_slots) = ir.parts_mut();
        cost_slots.copy_from_slice(&costs);
        comm_slots.copy_from_slice(&comms);
    }
    ir.mark_compute_annotated();
    if let Some(p) = parallelism {
        ir.mark_comm_annotated(p);
    }
    // Disk-boundary hook, always on (not debug_assert): an et-json
    // document is external input — the grammar replay above checks the
    // graph's shape, this checks the *semantics* (collective-plan
    // admissibility, flag/slot consistency) before anyone trusts it.
    super::verify::verify(&ir)?;
    Ok(ir)
}

/// Convenience: parse JSON text, then [`from_et_json`].
pub fn from_et_json_str(text: &str) -> Result<ModelIR> {
    from_et_json(&crate::json::parse(text)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{emit, passes};
    use crate::onnx::encode_model;
    use crate::translator::{ConstantCompute, TranslateOpts};

    #[test]
    fn zoo_direct_matches_onnx_byte_path() {
        // The two frontends must extract identical structural facts.
        let direct = from_zoo("mlp", 8).unwrap();
        let model = zoo::get("mlp", ZooOpts { weights: WeightFill::Empty }).unwrap();
        let via_bytes = from_onnx_bytes(&encode_model(&model), 8).unwrap();
        assert_eq!(direct.num_layers(), via_bytes.num_layers());
        for (a, b) in direct.layers().zip(via_bytes.layers()) {
            assert_eq!(a.info.name, b.info.name);
            assert_eq!(a.info.kind, b.info.kind);
            assert_eq!(a.info.weight_bytes, b.info.weight_bytes);
            assert_eq!(a.info.in_act_bytes, b.info.in_act_bytes);
            assert_eq!(a.info.out_act_bytes, b.info.out_act_bytes);
            assert_eq!(a.info.macs, b.info.macs);
        }
        assert_eq!(direct.summary().total_params, via_bytes.summary().total_params);
        assert_eq!(direct.summary().total_bytes, via_bytes.summary().total_bytes);
    }

    #[test]
    fn unknown_zoo_model_is_an_error() {
        assert!(from_zoo("not-a-model", 8).is_err());
    }

    #[test]
    fn bad_bytes_are_an_error() {
        assert!(from_onnx_bytes(&[0xff, 0xff, 0xff], 8).is_err());
    }

    fn annotated(p: Parallelism) -> ModelIR {
        let mut ir = from_zoo("mlp", 8).unwrap();
        passes::annotate_compute(&mut ir, &ConstantCompute(75));
        passes::annotate_comm(&mut ir, TranslateOpts { parallelism: p, ..Default::default() });
        ir
    }

    #[test]
    fn et_json_reader_recovers_the_full_annotation() {
        let ir = annotated(Parallelism::Data);
        let doc = emit::et_json(&ir).unwrap();
        let back = from_et_json(&doc).unwrap();
        assert_eq!(back.model_name(), ir.model_name());
        assert_eq!(back.batch(), ir.batch());
        assert_eq!(back.num_layers(), ir.num_layers());
        assert!(back.compute_annotated());
        assert_eq!(back.comm_annotated(), Some(Parallelism::Data));
        assert_eq!(back.costs(), ir.costs());
        assert_eq!(back.comms(), ir.comms());
        for (a, b) in back.layers().zip(ir.layers()) {
            assert_eq!(a.info.name, b.info.name);
            assert_eq!(a.info.kind, b.info.kind);
            assert_eq!(a.info.dtype, b.info.dtype);
            assert_eq!(a.info.variables, b.info.variables);
            assert_eq!(a.info.weight_bytes, b.info.weight_bytes);
            assert_eq!(a.info.in_act_bytes, b.info.in_act_bytes);
            assert_eq!(a.info.out_act_bytes, b.info.out_act_bytes);
            assert_eq!(a.info.macs, b.info.macs);
            assert_eq!(a.info.out_shape, b.info.out_shape);
        }
        assert_eq!(back.summary().total_params, ir.summary().total_params);
        assert_eq!(back.summary().total_bytes, ir.summary().total_bytes);
        // Re-emission is byte-identical — the disk-cache contract.
        assert_eq!(emit::et_json(&back).unwrap().to_json_pretty(), doc.to_json_pretty());
    }

    #[test]
    fn comm_free_documents_round_trip_too() {
        let mut ir = from_zoo("mlp", 4).unwrap();
        passes::annotate_compute(&mut ir, &ConstantCompute(9));
        let doc = emit::et_json(&ir).unwrap();
        let back = from_et_json(&doc).unwrap();
        assert!(back.compute_annotated());
        assert_eq!(back.comm_annotated(), None);
        assert_eq!(back.costs(), ir.costs());
        assert_eq!(emit::et_json(&back).unwrap().to_json_pretty(), doc.to_json_pretty());
    }

    #[test]
    fn reader_rejects_malformed_documents() {
        let good = emit::et_json(&annotated(Parallelism::Data)).unwrap();
        let text = good.to_json_pretty();

        // Wrong / missing schema version.
        let stale = text.replacen("modtrans-et-json/v2", "modtrans-et-json/v1", 1);
        let err = from_et_json_str(&stale).unwrap_err().to_string();
        assert!(err.contains("unsupported schema"), "got: {err}");
        assert!(from_et_json(&crate::json::obj(vec![])).is_err());

        // Truncated node list: the grammar walk must notice.
        let mut doc = good.clone();
        if let Value::Obj(m) = &mut doc {
            if let Some(Value::Arr(nodes)) = m.get_mut("nodes") {
                nodes.pop();
            }
        }
        assert!(from_et_json(&doc).is_err());

        // Extra trailing node: also rejected.
        let mut doc = good.clone();
        if let Value::Obj(m) = &mut doc {
            if let Some(Value::Arr(nodes)) = m.get_mut("nodes") {
                let mut extra = nodes.last().unwrap().clone();
                if let Value::Obj(e) = &mut extra {
                    e.insert("id".into(), Value::Num(nodes.len() as f64));
                }
                nodes.push(extra);
            }
        }
        assert!(from_et_json(&doc).is_err());

        // Layer-count mismatch.
        let mut doc = good.clone();
        if let Value::Obj(m) = &mut doc {
            m.insert("num_layers".into(), Value::Num(99.0));
        }
        assert!(from_et_json(&doc).is_err());

        // Forward-pointing dependency.
        let mut doc = good;
        if let Value::Obj(m) = &mut doc {
            if let Some(Value::Arr(nodes)) = m.get_mut("nodes") {
                if let Some(Value::Obj(first)) = nodes.first_mut() {
                    first.insert("data_deps".into(), Value::Arr(vec![Value::Num(5.0)]));
                }
            }
        }
        assert!(from_et_json(&doc).is_err());
    }

    #[test]
    fn reader_rejects_integers_beyond_2p53() {
        // Mirrors the emitter's lossless-int guard: a duration above 2^53
        // was already rounded by whatever f64-backed writer produced it.
        let mut doc = emit::et_json(&annotated(Parallelism::Data)).unwrap();
        if let Value::Obj(m) = &mut doc {
            if let Some(Value::Arr(nodes)) = m.get_mut("nodes") {
                if let Some(Value::Obj(first)) = nodes.first_mut() {
                    // 2^53 + 2: representable in f64, but unreachable by a
                    // lossless integer writer.
                    first.insert("duration_ns".into(), Value::Num(9_007_199_254_740_994.0));
                }
            }
        }
        let err = from_et_json(&doc).unwrap_err().to_string();
        assert!(err.contains("duration_ns"), "got: {err}");
    }

    #[test]
    fn reader_rejects_comm_nodes_without_a_parallelism() {
        // A null-parallelism doc must be collective-free.
        let with_comm = emit::et_json(&annotated(Parallelism::Data)).unwrap();
        let mut doc = with_comm;
        if let Value::Obj(m) = &mut doc {
            m.insert("parallelism".into(), Value::Null);
        }
        let err = from_et_json(&doc).unwrap_err().to_string();
        assert!(err.contains("parallelism"), "got: {err}");
    }
}
