//! The shared model intermediate representation (ModelIR) the translator
//! is staged around: **frontends → passes → emitters**.
//!
//! ModTrans's pitch is "any real-world model → simulator input" (§1,
//! §3.3). Structurally that is a classic compiler shape, and this module
//! makes it explicit:
//!
//! * **Frontends** ([`frontend`]) build a [`ModelIR`] from a model
//!   source: raw `.onnx` bytes, an in-memory [`crate::onnx::Model`],
//!   **directly from the zoo builder** — zoo models no longer pay an
//!   ONNX encode/decode round-trip on their way to the simulator — or a
//!   `modtrans-et-json/v2` document ([`frontend::from_et_json`]), which
//!   restores a *fully annotated* IR: the emit→read loop is closed, so
//!   externally produced traces (and the persistent sweep cache's disk
//!   entries) replay without re-deriving anything.
//! * **Passes** ([`passes`]) annotate the IR independently of each
//!   other: the compute pass fills per-phase cost slots from a
//!   [`crate::translator::ComputeTimeModel`]; the comm pass fills
//!   per-phase collective slots for one parallelism strategy; the memory
//!   pass reads the structural facts and reports the per-NPU footprint.
//! * **Emitters** ([`emit`]) lower an annotated IR to a consumer format:
//!   the in-crate [`crate::workload::Workload`] (which doubles as the
//!   ASTRA-sim text description via [`crate::workload::Workload::emit`])
//!   and a Chakra-ET-style JSON task graph for graph-based simulator
//!   inputs ([`emit::et_json`]) — since schema v2 a complete serialized
//!   IR that [`frontend::from_et_json`] reads back byte-identically.
//!
//! The split is what makes sweep-scale batching cheap: a compute-
//! annotated IR is valid for *every* scenario at the same (model, batch),
//! so scenarios differing only in parallelism / topology / collective
//! re-run only the comm pass plus an allocation-free emit
//! ([`passes::plan_comm_into`] + [`emit::workload_into`]) instead of
//! re-deriving the whole workload.

pub mod emit;
pub mod frontend;
pub mod passes;
pub mod verify;

pub use verify::verify;

use crate::translator::{CommPlan, LayerInfo, ModelSummary};
use crate::workload::Parallelism;

/// Per-phase compute-time slots for one layer, filled by
/// [`passes::annotate_compute`]. All times in integer nanoseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PhaseCost {
    /// Forward pass.
    pub fwd_ns: u64,
    /// Input-gradient (backward wrt activations).
    pub ig_ns: u64,
    /// Weight-gradient (backward wrt parameters).
    pub wg_ns: u64,
    /// Local optimizer update.
    pub update_ns: u64,
}

/// Read-only view of one IR layer: structural facts plus the two
/// annotation slots.
#[derive(Debug, Clone, Copy)]
pub struct IrLayer<'a> {
    /// Structural facts (kind, shapes, parameter bytes, MACs) from the
    /// frontend.
    pub info: &'a LayerInfo,
    /// Compute-pass annotation (zeros until the pass runs).
    pub cost: PhaseCost,
    /// Comm-pass annotation ([`CommPlan::none`] until the pass runs).
    pub comm: CommPlan,
}

/// The typed model IR: one structural record per weight-bearing layer
/// (stored as the frontend's [`ModelSummary`]) plus parallel slot arrays
/// for the compute and comm passes.
///
/// Slots are structure-of-arrays on purpose: the expensive, parallelism-
/// independent annotations (structure + compute cost) are cached and
/// shared, while the cheap parallelism-dependent comm plan can be
/// re-planned into a caller-owned buffer without touching the IR
/// ([`passes::plan_comm_into`]).
#[derive(Debug, Clone)]
pub struct ModelIR {
    summary: ModelSummary,
    costs: Vec<PhaseCost>,
    comms: Vec<CommPlan>,
    compute_annotated: bool,
    comm_annotated: Option<Parallelism>,
}

impl ModelIR {
    /// Lift a frontend extraction result into an unannotated IR.
    pub fn from_summary(summary: ModelSummary) -> ModelIR {
        let n = summary.layers.len();
        ModelIR {
            summary,
            costs: vec![PhaseCost::default(); n],
            comms: vec![CommPlan::none(); n],
            compute_annotated: false,
            comm_annotated: None,
        }
    }

    /// The structural facts (frontend output) this IR was built from.
    pub fn summary(&self) -> &ModelSummary {
        &self.summary
    }

    /// Graph name from the source model.
    pub fn model_name(&self) -> &str {
        &self.summary.model_name
    }

    /// Batch size the activations were sized at.
    pub fn batch(&self) -> i64 {
        self.summary.batch
    }

    /// Number of weight-bearing layers.
    pub fn num_layers(&self) -> usize {
        self.summary.layers.len()
    }

    /// True when the IR has no layers.
    pub fn is_empty(&self) -> bool {
        self.summary.layers.is_empty()
    }

    /// One layer's structure + slots.
    ///
    /// # Panics
    /// Panics if `i >= num_layers()`.
    pub fn layer(&self, i: usize) -> IrLayer<'_> {
        IrLayer { info: &self.summary.layers[i], cost: self.costs[i], comm: self.comms[i] }
    }

    /// Iterate over all layers (structure + slots).
    pub fn layers(&self) -> impl Iterator<Item = IrLayer<'_>> {
        self.summary
            .layers
            .iter()
            .zip(self.costs.iter())
            .zip(self.comms.iter())
            .map(|((info, cost), comm)| IrLayer { info, cost: *cost, comm: *comm })
    }

    /// The compute-pass slot array (parallel to `summary().layers`).
    pub fn costs(&self) -> &[PhaseCost] {
        &self.costs
    }

    /// The comm-pass slot array (parallel to `summary().layers`).
    pub fn comms(&self) -> &[CommPlan] {
        &self.comms
    }

    /// True once [`passes::annotate_compute`] has run.
    pub fn compute_annotated(&self) -> bool {
        self.compute_annotated
    }

    /// The strategy the comm slots were planned for, once
    /// [`passes::annotate_comm`] has run.
    pub fn comm_annotated(&self) -> Option<Parallelism> {
        self.comm_annotated
    }

    /// Recover the structural summary (drops the annotations).
    pub fn into_summary(self) -> ModelSummary {
        self.summary
    }

    /// Split borrows for the annotation passes: structure read-only,
    /// both slot arrays writable.
    pub(crate) fn parts_mut(&mut self) -> (&ModelSummary, &mut [PhaseCost], &mut [CommPlan]) {
        (&self.summary, &mut self.costs, &mut self.comms)
    }

    pub(crate) fn mark_compute_annotated(&mut self) {
        self.compute_annotated = true;
    }

    pub(crate) fn mark_comm_annotated(&mut self, parallelism: Parallelism) {
        self.comm_annotated = Some(parallelism);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::CommType;

    #[test]
    fn fresh_ir_has_empty_slots() {
        let ir = frontend::from_zoo("mlp", 4).unwrap();
        assert_eq!(ir.num_layers(), ir.summary().layers.len());
        assert!(!ir.is_empty());
        assert!(!ir.compute_annotated());
        assert_eq!(ir.comm_annotated(), None);
        for l in ir.layers() {
            assert_eq!(l.cost, PhaseCost::default());
            assert_eq!(l.comm.fwd.0, CommType::None);
        }
        assert_eq!(ir.batch(), 4);
        assert_eq!(ir.model_name(), "mlp");
    }

    #[test]
    fn layer_view_matches_slot_arrays() {
        let mut ir = frontend::from_zoo("mlp", 2).unwrap();
        {
            let (_, costs, _) = ir.parts_mut();
            costs[0] = PhaseCost { fwd_ns: 7, ig_ns: 8, wg_ns: 9, update_ns: 10 };
        }
        assert_eq!(ir.layer(0).cost.fwd_ns, 7);
        assert_eq!(ir.costs()[0].update_ns, 10);
        let first = ir.layers().next().unwrap();
        assert_eq!(first.cost.wg_ns, 9);
        let summary = ir.into_summary();
        assert!(!summary.layers.is_empty());
    }
}
