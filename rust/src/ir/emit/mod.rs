//! IR emitters: lower an annotated [`crate::ir::ModelIR`] to a consumer
//! format.
//!
//! * [`to_sim_workload`] / [`workload_into`] — the in-crate
//!   [`crate::workload::Workload`], which the [`crate::sim`] engine
//!   executes directly; `workload_into` is the allocation-free variant
//!   the sweep hot path uses (see [`sim`]).
//! * [`text`] — the ASTRA-sim layer-wise text description (the paper's
//!   Fig. 3 format), via `Workload::emit`.
//! * [`et_json`] — a Chakra-ET-style JSON document for graph-based
//!   simulator inputs (ASTRA-sim 2.0's direction), via [`et`]. Since
//!   schema v2 it is a complete serialized IR: the reader
//!   ([`crate::ir::frontend::from_et_json`]) restores it byte-identically,
//!   which is how the persistent sweep cache spills IRs to disk.
//!
//! Emitters validate their inputs: workload emission requires both the
//! compute and comm passes to have run on the IR (or, for
//! `workload_into`, a caller-provided comm plan); et-json emission
//! requires the compute pass, and serializes a comm-free IR with
//! `"parallelism": null`.

pub mod et;
pub mod sim;

pub use et::{et_json, ET_JSON_SCHEMA};
pub use sim::{to_sim_workload, workload_from_parts, workload_into};

use crate::error::Result;
use crate::ir::ModelIR;

/// Emit the ASTRA-sim text description from a fully annotated IR.
pub fn text(ir: &ModelIR) -> Result<String> {
    Ok(to_sim_workload(ir)?.emit())
}

#[cfg(test)]
mod tests {
    use crate::ir::{frontend, passes};
    use crate::translator::{ConstantCompute, TranslateOpts};

    #[test]
    fn text_emitter_round_trips_through_the_parser() {
        let mut ir = frontend::from_zoo("mlp", 8).unwrap();
        passes::annotate_compute(&mut ir, &ConstantCompute(10));
        passes::annotate_comm(&mut ir, TranslateOpts::default());
        let text = super::text(&ir).unwrap();
        let parsed = crate::workload::Workload::parse(&text).unwrap();
        assert_eq!(parsed.layers.len(), ir.num_layers());
        assert_eq!(parsed.emit(), text);
    }
}
