//! Workload emitter: lower an annotated IR to the in-crate
//! [`Workload`] (which `emit()`s the ASTRA-sim text format).
//!
//! Two entry points share one lowering loop:
//!
//! * [`to_sim_workload`] — one-shot: allocates a fresh [`Workload`] from
//!   an IR whose compute *and* comm passes have both run.
//! * [`workload_into`] — the sweep hot path: takes the comm plan as a
//!   caller-owned slice (from [`crate::ir::passes::plan_comm_into`]) and
//!   refills a reusable [`Workload`], reusing the layer `Vec` and each
//!   layer's name `String` capacity. Steady-state re-emission for a
//!   model performs no heap allocation — the `modtrans-lint`
//!   `no-string-alloc` rule gates this file in CI.

use crate::error::{Error, Result};
use crate::ir::{ModelIR, PhaseCost};
use crate::translator::{CommPlan, ModelSummary};
use crate::workload::{LayerSpec, Parallelism, Phase, Workload};

/// Emit a fresh workload from a fully annotated IR (compute + comm
/// passes must both have run).
pub fn to_sim_workload(ir: &ModelIR) -> Result<Workload> {
    let parallelism = ir
        .comm_annotated()
        .ok_or_else(|| Error::translate("emit: comm pass has not run on this IR"))?;
    if !ir.compute_annotated() {
        return Err(Error::translate("emit: compute pass has not run on this IR"));
    }
    let mut out = Workload { parallelism, layers: Vec::with_capacity(ir.num_layers()) };
    lower(ir.summary(), ir.costs(), ir.comms(), parallelism, &mut out);
    Ok(out)
}

/// Refill `out` from a compute-annotated IR plus an external comm plan
/// (one entry per layer). The IR's own comm slots are ignored, so a
/// cached IR can be shared read-only across scenarios while each worker
/// supplies its scenario's plan.
// lint: hot-path
pub fn workload_into(
    ir: &ModelIR,
    comms: &[CommPlan],
    parallelism: Parallelism,
    out: &mut Workload,
) -> Result<()> {
    if !ir.compute_annotated() {
        return Err(Error::translate("emit: compute pass has not run on this IR"));
    }
    if comms.len() != ir.num_layers() {
        return Err(Error::translate("emit: comm plan length does not match the IR layer count"));
    }
    lower(ir.summary(), ir.costs(), comms, parallelism, out);
    Ok(())
}

/// Lower bare structural facts plus externally computed slot arrays into
/// a fresh workload — the IR-free form [`crate::translator::to_workload`]
/// composes with the slice-level passes (no summary clone).
pub fn workload_from_parts(
    summary: &ModelSummary,
    costs: &[PhaseCost],
    comms: &[CommPlan],
    parallelism: Parallelism,
) -> Result<Workload> {
    let n = summary.layers.len();
    if costs.len() != n || comms.len() != n {
        return Err(Error::translate("emit: slot array length does not match the layer count"));
    }
    let mut out = Workload { parallelism, layers: Vec::with_capacity(n) };
    lower(summary, costs, comms, parallelism, &mut out);
    Ok(out)
}

/// The shared lowering loop. Reuses `out`'s existing layer slots (and
/// their name-string capacity) before growing.
// lint: hot-path
fn lower(
    summary: &ModelSummary,
    costs: &[PhaseCost],
    comms: &[CommPlan],
    parallelism: Parallelism,
    out: &mut Workload,
) {
    let n = summary.layers.len();
    out.parallelism = parallelism;
    out.layers.truncate(n);
    for (i, ((info, cost), plan)) in
        summary.layers.iter().zip(costs.iter()).zip(comms.iter()).enumerate()
    {
        let fwd = Phase { compute_ns: cost.fwd_ns, comm: plan.fwd.0, comm_bytes: plan.fwd.1 };
        let input_grad = Phase { compute_ns: cost.ig_ns, comm: plan.ig.0, comm_bytes: plan.ig.1 };
        let weight_grad = Phase { compute_ns: cost.wg_ns, comm: plan.wg.0, comm_bytes: plan.wg.1 };
        if i < out.layers.len() {
            let slot = &mut out.layers[i];
            slot.name.clear();
            slot.name.push_str(&info.name);
            slot.reserved = -1;
            slot.fwd = fwd;
            slot.input_grad = input_grad;
            slot.weight_grad = weight_grad;
            slot.update_ns = cost.update_ns;
        } else {
            out.layers.push(LayerSpec {
                name: info.name.clone(),
                reserved: -1,
                fwd,
                input_grad,
                weight_grad,
                update_ns: cost.update_ns,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{frontend, passes};
    use crate::translator::{ConstantCompute, TranslateOpts};
    use crate::workload::CommType;

    fn annotated(name: &str, p: Parallelism) -> ModelIR {
        let mut ir = frontend::from_zoo(name, 8).unwrap();
        passes::annotate_compute(&mut ir, &ConstantCompute(100));
        passes::annotate_comm(&mut ir, TranslateOpts { parallelism: p, ..Default::default() });
        ir
    }

    #[test]
    fn unannotated_ir_is_rejected() {
        let ir = frontend::from_zoo("mlp", 8).unwrap();
        assert!(to_sim_workload(&ir).is_err());
        let mut w = Workload::default();
        let comms = vec![CommPlan::none(); ir.num_layers()];
        assert!(workload_into(&ir, &comms, Parallelism::Data, &mut w).is_err());
    }

    #[test]
    fn comm_plan_length_mismatch_is_rejected() {
        let ir = annotated("mlp", Parallelism::Data);
        let mut w = Workload::default();
        let comms = vec![CommPlan::none(); ir.num_layers() + 1];
        assert!(workload_into(&ir, &comms, Parallelism::Data, &mut w).is_err());
    }

    #[test]
    fn into_variant_matches_one_shot_emission() {
        let ir = annotated("mlp", Parallelism::Data);
        let fresh = to_sim_workload(&ir).unwrap();
        let mut reused = Workload::default();
        let mut comms = Vec::new();
        passes::plan_comm_into(
            &ir,
            TranslateOpts { parallelism: Parallelism::Data, ..Default::default() },
            &mut comms,
        );
        workload_into(&ir, &comms, Parallelism::Data, &mut reused).unwrap();
        assert_eq!(fresh, reused);
        assert_eq!(fresh.emit(), reused.emit());
    }

    #[test]
    fn reused_workload_shrinks_and_regrows_across_models() {
        // Emit a big model, then a small one, then the big one again
        // through the same buffer: results must equal fresh emissions.
        let big = annotated("resnet18", Parallelism::Data);
        let small = annotated("mlp", Parallelism::Model);
        let mut buf = Workload::default();
        let mut comms = Vec::new();
        for (ir, p) in [
            (&big, Parallelism::Data),
            (&small, Parallelism::Model),
            (&big, Parallelism::Data),
        ] {
            passes::plan_comm_into(
                ir,
                TranslateOpts { parallelism: p, ..Default::default() },
                &mut comms,
            );
            workload_into(ir, &comms, p, &mut buf).unwrap();
            let mut fresh_ir = frontend::from_zoo(
                if ir.num_layers() == big.num_layers() { "resnet18" } else { "mlp" },
                8,
            )
            .unwrap();
            passes::annotate_compute(&mut fresh_ir, &ConstantCompute(100));
            passes::annotate_comm(
                &mut fresh_ir,
                TranslateOpts { parallelism: p, ..Default::default() },
            );
            assert_eq!(buf, to_sim_workload(&fresh_ir).unwrap());
        }
        assert_eq!(buf.parallelism, Parallelism::Data);
        assert_eq!(buf.layers[0].weight_grad.comm, CommType::AllReduce);
    }
}
