//! Chakra-ET-style JSON graph emitter.
//!
//! ASTRA-sim 2.0 moved from layer-wise text descriptions to graph-based
//! workload inputs (Chakra execution traces): compute and collective
//! nodes with explicit data dependencies. This emitter lowers an
//! annotated IR to that shape as deterministic JSON — one training step
//! of the standard schedule:
//!
//! * per layer, in order: a `COMP_NODE` for the forward pass, followed
//!   by a `COMM_COLL_NODE` when the comm pass planned a collective;
//! * the backward sweep in reverse layer order: input-gradient and
//!   weight-gradient `COMP_NODE`s both depend on the upstream gradient
//!   (they can overlap, as in the simulator's training graph), each
//!   followed by its planned collective;
//! * a `COMP_NODE` optimizer update per layer, gated on the
//!   weight-gradient collective.
//!
//! Since schema **v2** the document is a *complete serialized IR*, not
//! just the task graph: a `layers` section carries every structural fact
//! ([`crate::translator::LayerInfo`]) plus the summary totals, so
//! [`crate::ir::frontend::from_et_json`] can reconstruct a fully
//! annotated [`ModelIR`] — the round trip `from_et_json(et_json(ir))`
//! re-emits byte-identically, which is what lets the persistent sweep
//! cache ([`crate::sweep::WorkloadCache`]) spill IRs to disk in this
//! format. The comm pass is optional for v2 emission: a
//! compute-annotated, comm-free IR (the cache-tier form) emits with
//! `"parallelism": null` and no collective nodes.
//!
//! Node ids are dense and creation-ordered, and every dependency points
//! to a lower id, so the node list is already topologically sorted.
//! Keys are emitted through the crate's `BTreeMap`-backed JSON value,
//! making the output byte-deterministic — goldenable in tests. Integer
//! emission is **lossless by construction**: the JSON value is
//! f64-backed, so any integer above 2^53 (comm sizes, durations, byte
//! counts) is a hard `translate` error instead of a silent rounding.

use crate::error::{Error, Result};
use crate::ir::ModelIR;
use crate::json::{obj, Value};
use crate::translator::LayerInfo;
use crate::workload::CommType;

/// Schema identifier stamped into every emitted document.
pub const ET_JSON_SCHEMA: &str = "modtrans-et-json/v2";

/// Largest integer the f64-backed JSON number represents exactly (2^53).
pub const MAX_SAFE_JSON_INT: u64 = 1 << 53;

/// Lossless u64 → JSON number, or a `translate` error beyond 2^53.
fn num_u64(what: &str, v: u64) -> Result<Value> {
    if v > MAX_SAFE_JSON_INT {
        return Err(Error::translate(format!(
            "et-json: {what} = {v} exceeds 2^53 and would silently lose \
             precision in f64-backed JSON — refusing lossy emission"
        )));
    }
    Ok(Value::Num(v as f64))
}

/// Lossless i64 → JSON number (same 2^53 magnitude bound).
fn num_i64(what: &str, v: i64) -> Result<Value> {
    if v.unsigned_abs() > MAX_SAFE_JSON_INT {
        return Err(Error::translate(format!(
            "et-json: {what} = {v} exceeds 2^53 in magnitude and would \
             silently lose precision in f64-backed JSON — refusing lossy emission"
        )));
    }
    Ok(Value::Num(v as f64))
}

/// One layer's structural facts — the v2 section that makes the document
/// a round-trippable IR rather than a graph-only trace.
fn layer_obj(info: &LayerInfo) -> Result<Value> {
    let mut shape = Vec::with_capacity(info.out_shape.len());
    for &d in &info.out_shape {
        shape.push(num_i64("out_shape dim", d)?);
    }
    Ok(obj(vec![
        ("dtype", Value::Num(info.dtype as i32 as f64)),
        ("in_act_bytes", num_u64("in_act_bytes", info.in_act_bytes)?),
        ("kind", Value::Str(info.kind.label().into())),
        ("macs", num_u64("macs", info.macs)?),
        ("name", Value::Str(info.name.clone())),
        ("out_act_bytes", num_u64("out_act_bytes", info.out_act_bytes)?),
        ("out_shape", Value::Arr(shape)),
        ("variables", num_u64("variables", info.variables)?),
        ("weight_bytes", num_u64("weight_bytes", info.weight_bytes)?),
    ]))
}

/// Incremental node-list builder (ids are assigned in creation order).
struct EtBuilder {
    nodes: Vec<Value>,
}

impl EtBuilder {
    fn push(&mut self, name: String, fields: Vec<(&str, Value)>, deps: &[u64]) -> u64 {
        let id = self.nodes.len() as u64;
        let mut all = vec![
            ("id", Value::Num(id as f64)),
            ("name", Value::Str(name)),
            ("data_deps", Value::Arr(deps.iter().map(|&d| Value::Num(d as f64)).collect())),
        ];
        all.extend(fields);
        self.nodes.push(obj(all));
        id
    }

    fn comp(&mut self, name: String, duration_ns: u64, deps: &[u64]) -> Result<u64> {
        let duration = num_u64("duration_ns", duration_ns)?;
        Ok(self.push(
            name,
            vec![("type", Value::Str("COMP_NODE".into())), ("duration_ns", duration)],
            deps,
        ))
    }

    fn comm(&mut self, name: String, comm: (CommType, u64), deps: &[u64]) -> Result<u64> {
        let size = num_u64("comm_size", comm.1)?;
        Ok(self.push(
            name,
            vec![
                ("type", Value::Str("COMM_COLL_NODE".into())),
                ("comm_type", Value::Str(comm.0.token().into())),
                ("comm_size", size),
            ],
            deps,
        ))
    }
}

/// Emit a compute-annotated IR as a Chakra-ET-style JSON document
/// (schema v2: structural layer section + one training step's task
/// graph). The comm pass is optional: a comm-free IR emits
/// `"parallelism": null` and a collective-free graph — the persistent
/// cache's on-disk form.
pub fn et_json(ir: &ModelIR) -> Result<Value> {
    // Emit-boundary hook: never serialize an IR that violates its own
    // invariants (debug builds; the always-on reader-side verify in
    // `from_et_json` covers release round-trips).
    debug_assert!(
        crate::ir::verify::verify(ir).is_ok(),
        "et_json asked to emit an invalid IR"
    );
    if !ir.compute_annotated() {
        return Err(Error::translate("et-json: compute pass has not run on this IR"));
    }
    if ir.is_empty() {
        return Err(Error::translate("et-json: model has no weight-bearing layers"));
    }
    let parallelism = match ir.comm_annotated() {
        Some(p) => Value::Str(p.token().into()),
        None => Value::Null,
    };

    let n = ir.num_layers();
    let mut layers = Vec::with_capacity(n);
    for i in 0..n {
        layers.push(layer_obj(ir.layer(i).info)?);
    }

    let mut b = EtBuilder { nodes: Vec::with_capacity(7 * n) };

    // Forward chain.
    let mut prev: Option<u64> = None;
    for i in 0..n {
        let l = ir.layer(i);
        let deps: Vec<u64> = prev.into_iter().collect();
        let fid = b.comp(format!("{}.fwd", l.info.name), l.cost.fwd_ns, &deps)?;
        let mut finish = fid;
        if l.comm.fwd.0 != CommType::None {
            finish = b.comm(format!("{}.fwd.comm", l.info.name), l.comm.fwd, &[fid])?;
        }
        prev = Some(finish);
    }

    // Backward sweep: ig/wg both gate on the upstream gradient; the
    // update gates on the weight-gradient collective.
    let mut upstream = prev.unwrap_or(0);
    for i in (0..n).rev() {
        let l = ir.layer(i);
        let ig = b.comp(format!("{}.ig", l.info.name), l.cost.ig_ns, &[upstream])?;
        let mut ig_finish = ig;
        if l.comm.ig.0 != CommType::None {
            ig_finish = b.comm(format!("{}.ig.comm", l.info.name), l.comm.ig, &[ig])?;
        }
        let wg = b.comp(format!("{}.wg", l.info.name), l.cost.wg_ns, &[upstream])?;
        let mut wg_finish = wg;
        if l.comm.wg.0 != CommType::None {
            wg_finish = b.comm(format!("{}.wg.comm", l.info.name), l.comm.wg, &[wg])?;
        }
        b.comp(format!("{}.update", l.info.name), l.cost.update_ns, &[wg_finish])?;
        upstream = ig_finish;
    }

    Ok(obj(vec![
        ("schema", Value::Str(ET_JSON_SCHEMA.into())),
        ("model", Value::Str(ir.model_name().into())),
        ("batch", num_i64("batch", ir.batch())?),
        ("parallelism", parallelism),
        ("num_layers", num_u64("num_layers", n as u64)?),
        ("total_params", num_u64("total_params", ir.summary().total_params)?),
        ("total_bytes", num_u64("total_bytes", ir.summary().total_bytes)?),
        ("layers", Value::Arr(layers)),
        ("nodes", Value::Arr(b.nodes)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{frontend, passes, PhaseCost};
    use crate::translator::{ConstantCompute, TranslateOpts};
    use crate::workload::Parallelism;

    fn annotated(p: Parallelism) -> ModelIR {
        let mut ir = frontend::from_zoo("mlp", 8).unwrap();
        passes::annotate_compute(&mut ir, &ConstantCompute(50));
        passes::annotate_comm(&mut ir, TranslateOpts { parallelism: p, ..Default::default() });
        ir
    }

    #[test]
    fn unannotated_ir_is_rejected() {
        let ir = frontend::from_zoo("mlp", 8).unwrap();
        assert!(et_json(&ir).is_err());
    }

    #[test]
    fn comm_free_ir_emits_null_parallelism_and_no_collectives() {
        let mut ir = frontend::from_zoo("mlp", 8).unwrap();
        passes::annotate_compute(&mut ir, &ConstantCompute(50));
        let v = et_json(&ir).unwrap();
        assert_eq!(v.get("parallelism"), Some(&Value::Null));
        let nodes = v.get("nodes").unwrap().as_arr().unwrap();
        // fwd + ig + wg + update per layer, zero COMM_COLL_NODEs.
        assert_eq!(nodes.len(), 4 * ir.num_layers());
        assert!(nodes.iter().all(|x| x.get("type").unwrap().as_str() == Some("COMP_NODE")));
    }

    #[test]
    fn layers_section_carries_the_structural_facts() {
        let ir = annotated(Parallelism::Data);
        let v = et_json(&ir).unwrap();
        let layers = v.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), ir.num_layers());
        for (l, info) in layers.iter().zip(ir.summary().layers.iter()) {
            assert_eq!(l.get("name").unwrap().as_str(), Some(info.name.as_str()));
            assert_eq!(l.get("kind").unwrap().as_str(), Some(info.kind.label()));
            assert_eq!(l.get("weight_bytes").unwrap().as_u64(), Some(info.weight_bytes));
            assert_eq!(l.get("macs").unwrap().as_u64(), Some(info.macs));
            assert_eq!(l.get("dtype").unwrap().as_u64(), Some(info.dtype as i32 as u64));
            let shape = l.get("out_shape").unwrap().as_arr().unwrap();
            assert_eq!(shape.len(), info.out_shape.len());
        }
        assert_eq!(v.get("total_bytes").unwrap().as_u64(), Some(ir.summary().total_bytes));
    }

    #[test]
    fn data_parallel_graph_shape() {
        let ir = annotated(Parallelism::Data);
        let n = ir.num_layers();
        let v = et_json(&ir).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str(), Some(ET_JSON_SCHEMA));
        assert_eq!(v.get("parallelism").unwrap().as_str(), Some("DATA"));
        let nodes = v.get("nodes").unwrap().as_arr().unwrap();
        // DATA: fwd + ig + wg + wg.comm + update per layer.
        assert_eq!(nodes.len(), 5 * n);
        // Dense, creation-ordered ids; all deps topological.
        for (i, node) in nodes.iter().enumerate() {
            assert_eq!(node.get("id").unwrap().as_u64(), Some(i as u64));
            for d in node.get("data_deps").unwrap().as_arr().unwrap() {
                assert!(d.as_u64().unwrap() < i as u64, "dep must precede node {i}");
            }
        }
        // Every wg.comm carries the layer's weight bytes.
        let comms: Vec<&Value> = nodes
            .iter()
            .filter(|x| x.get("type").unwrap().as_str() == Some("COMM_COLL_NODE"))
            .collect();
        assert_eq!(comms.len(), n);
        for c in &comms {
            assert_eq!(c.get("comm_type").unwrap().as_str(), Some("ALLREDUCE"));
            assert!(c.get("comm_size").unwrap().as_u64().unwrap() > 0);
        }
    }

    #[test]
    fn emission_is_byte_deterministic() {
        let ir = annotated(Parallelism::Model);
        let a = et_json(&ir).unwrap().to_json_pretty();
        let b = et_json(&annotated(Parallelism::Model)).unwrap().to_json_pretty();
        assert_eq!(a, b);
        // And parses back.
        assert!(crate::json::parse(&a).is_ok());
    }

    #[test]
    fn integers_beyond_2p53_are_rejected_not_rounded() {
        // 2^53 itself is the last exactly-representable integer: fine.
        let mut ir = frontend::from_zoo("mlp", 8).unwrap();
        passes::annotate_compute(&mut ir, &ConstantCompute(MAX_SAFE_JSON_INT));
        let v = et_json(&ir).unwrap();
        let nodes = v.get("nodes").unwrap().as_arr().unwrap();
        assert_eq!(nodes[0].get("duration_ns").unwrap().as_u64(), Some(MAX_SAFE_JSON_INT));
        // One past it would round in f64: hard error, not silent loss.
        let mut ir = frontend::from_zoo("mlp", 8).unwrap();
        passes::annotate_compute(&mut ir, &ConstantCompute(MAX_SAFE_JSON_INT + 1));
        let err = et_json(&ir).unwrap_err().to_string();
        assert!(err.contains("precision"), "unexpected error: {err}");
        // Same guard on comm sizes.
        let mut ir = frontend::from_zoo("mlp", 8).unwrap();
        passes::annotate_compute(&mut ir, &ConstantCompute(1));
        passes::annotate_comm(&mut ir, TranslateOpts::default());
        {
            let (_, _, comms) = ir.parts_mut();
            comms[0].wg = (CommType::AllReduce, MAX_SAFE_JSON_INT + 1);
        }
        let err = et_json(&ir).unwrap_err().to_string();
        assert!(err.contains("comm_size"), "unexpected error: {err}");
        // And costs stay intact below the boundary.
        let mut ir = frontend::from_zoo("mlp", 8).unwrap();
        {
            let (_, costs, _) = ir.parts_mut();
            costs.fill(PhaseCost { fwd_ns: 1, ig_ns: 1, wg_ns: 1, update_ns: 1 });
        }
        ir.mark_compute_annotated();
        assert!(et_json(&ir).is_ok());
    }
}
