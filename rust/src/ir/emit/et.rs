//! Chakra-ET-style JSON graph emitter.
//!
//! ASTRA-sim 2.0 moved from layer-wise text descriptions to graph-based
//! workload inputs (Chakra execution traces): compute and collective
//! nodes with explicit data dependencies. This emitter lowers an
//! annotated IR to that shape as deterministic JSON — one training step
//! of the standard schedule:
//!
//! * per layer, in order: a `COMP_NODE` for the forward pass, followed
//!   by a `COMM_COLL_NODE` when the comm pass planned a collective;
//! * the backward sweep in reverse layer order: input-gradient and
//!   weight-gradient `COMP_NODE`s both depend on the upstream gradient
//!   (they can overlap, as in the simulator's training graph), each
//!   followed by its planned collective;
//! * a `COMP_NODE` optimizer update per layer, gated on the
//!   weight-gradient collective.
//!
//! Node ids are dense and creation-ordered, and every dependency points
//! to a lower id, so the node list is already topologically sorted.
//! Keys are emitted through the crate's `BTreeMap`-backed JSON value,
//! making the output byte-deterministic — goldenable in tests.

use crate::error::{Error, Result};
use crate::ir::ModelIR;
use crate::json::{obj, Value};
use crate::workload::CommType;

/// Schema identifier stamped into every emitted document.
pub const ET_JSON_SCHEMA: &str = "modtrans-et-json/v1";

/// Incremental node-list builder (ids are assigned in creation order).
struct EtBuilder {
    nodes: Vec<Value>,
}

impl EtBuilder {
    fn push(&mut self, name: String, fields: Vec<(&str, Value)>, deps: &[u64]) -> u64 {
        let id = self.nodes.len() as u64;
        let mut all = vec![
            ("id", Value::Num(id as f64)),
            ("name", Value::Str(name)),
            ("data_deps", Value::Arr(deps.iter().map(|&d| Value::Num(d as f64)).collect())),
        ];
        all.extend(fields);
        self.nodes.push(obj(all));
        id
    }

    fn comp(&mut self, name: String, duration_ns: u64, deps: &[u64]) -> u64 {
        self.push(
            name,
            vec![
                ("type", Value::Str("COMP_NODE".into())),
                ("duration_ns", Value::Num(duration_ns as f64)),
            ],
            deps,
        )
    }

    fn comm(&mut self, name: String, comm: (CommType, u64), deps: &[u64]) -> u64 {
        self.push(
            name,
            vec![
                ("type", Value::Str("COMM_COLL_NODE".into())),
                ("comm_type", Value::Str(comm.0.token().into())),
                ("comm_size", Value::Num(comm.1 as f64)),
            ],
            deps,
        )
    }
}

/// Emit one training step of a fully annotated IR as a Chakra-ET-style
/// JSON graph.
pub fn et_json(ir: &ModelIR) -> Result<Value> {
    let parallelism = ir
        .comm_annotated()
        .ok_or_else(|| Error::translate("et-json: comm pass has not run on this IR"))?;
    if !ir.compute_annotated() {
        return Err(Error::translate("et-json: compute pass has not run on this IR"));
    }
    if ir.is_empty() {
        return Err(Error::translate("et-json: model has no weight-bearing layers"));
    }

    let n = ir.num_layers();
    let mut b = EtBuilder { nodes: Vec::with_capacity(7 * n) };

    // Forward chain.
    let mut prev: Option<u64> = None;
    for i in 0..n {
        let l = ir.layer(i);
        let deps: Vec<u64> = prev.into_iter().collect();
        let fid = b.comp(format!("{}.fwd", l.info.name), l.cost.fwd_ns, &deps);
        let mut finish = fid;
        if l.comm.fwd.0 != CommType::None {
            finish = b.comm(format!("{}.fwd.comm", l.info.name), l.comm.fwd, &[fid]);
        }
        prev = Some(finish);
    }

    // Backward sweep: ig/wg both gate on the upstream gradient; the
    // update gates on the weight-gradient collective.
    let mut upstream = prev.unwrap_or(0);
    for i in (0..n).rev() {
        let l = ir.layer(i);
        let ig = b.comp(format!("{}.ig", l.info.name), l.cost.ig_ns, &[upstream]);
        let mut ig_finish = ig;
        if l.comm.ig.0 != CommType::None {
            ig_finish = b.comm(format!("{}.ig.comm", l.info.name), l.comm.ig, &[ig]);
        }
        let wg = b.comp(format!("{}.wg", l.info.name), l.cost.wg_ns, &[upstream]);
        let mut wg_finish = wg;
        if l.comm.wg.0 != CommType::None {
            wg_finish = b.comm(format!("{}.wg.comm", l.info.name), l.comm.wg, &[wg]);
        }
        b.comp(format!("{}.update", l.info.name), l.cost.update_ns, &[wg_finish]);
        upstream = ig_finish;
    }

    Ok(obj(vec![
        ("schema", Value::Str(ET_JSON_SCHEMA.into())),
        ("model", Value::Str(ir.model_name().into())),
        ("batch", Value::Num(ir.batch() as f64)),
        ("parallelism", Value::Str(parallelism.token().into())),
        ("num_layers", Value::Num(n as f64)),
        ("nodes", Value::Arr(b.nodes)),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{frontend, passes};
    use crate::translator::{ConstantCompute, TranslateOpts};
    use crate::workload::Parallelism;

    fn annotated(p: Parallelism) -> ModelIR {
        let mut ir = frontend::from_zoo("mlp", 8).unwrap();
        passes::annotate_compute(&mut ir, &ConstantCompute(50));
        passes::annotate_comm(&mut ir, TranslateOpts { parallelism: p, ..Default::default() });
        ir
    }

    #[test]
    fn unannotated_ir_is_rejected() {
        let ir = frontend::from_zoo("mlp", 8).unwrap();
        assert!(et_json(&ir).is_err());
    }

    #[test]
    fn data_parallel_graph_shape() {
        let ir = annotated(Parallelism::Data);
        let n = ir.num_layers();
        let v = et_json(&ir).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str(), Some(ET_JSON_SCHEMA));
        assert_eq!(v.get("parallelism").unwrap().as_str(), Some("DATA"));
        let nodes = v.get("nodes").unwrap().as_arr().unwrap();
        // DATA: fwd + ig + wg + wg.comm + update per layer.
        assert_eq!(nodes.len(), 5 * n);
        // Dense, creation-ordered ids; all deps topological.
        for (i, node) in nodes.iter().enumerate() {
            assert_eq!(node.get("id").unwrap().as_u64(), Some(i as u64));
            for d in node.get("data_deps").unwrap().as_arr().unwrap() {
                assert!(d.as_u64().unwrap() < i as u64, "dep must precede node {i}");
            }
        }
        // Every wg.comm carries the layer's weight bytes.
        let comms: Vec<&Value> = nodes
            .iter()
            .filter(|x| x.get("type").unwrap().as_str() == Some("COMM_COLL_NODE"))
            .collect();
        assert_eq!(comms.len(), n);
        for c in &comms {
            assert_eq!(c.get("comm_type").unwrap().as_str(), Some("ALLREDUCE"));
            assert!(c.get("comm_size").unwrap().as_u64().unwrap() > 0);
        }
    }

    #[test]
    fn emission_is_byte_deterministic() {
        let ir = annotated(Parallelism::Model);
        let a = et_json(&ir).unwrap().to_json_pretty();
        let b = et_json(&annotated(Parallelism::Model)).unwrap().to_json_pretty();
        assert_eq!(a, b);
        // And parses back.
        assert!(crate::json::parse(&a).is_ok());
    }
}
