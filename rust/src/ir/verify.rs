//! Semantic verifier for [`ModelIR`]: the data-level half of the
//! static-guarantees story (see the crate docs).
//!
//! [`verify`] checks every structural invariant an IR must satisfy
//! before the passes/emitters may trust it:
//!
//! * **Slot-array sync** — the compute and comm slot arrays are exactly
//!   as long as the layer list (the IR is structure-of-arrays; a length
//!   skew would silently mis-annotate layers).
//! * **Structural sanity** — non-empty model name, `batch >= 1`, no
//!   layer with an empty name (names key the et-json grammar replay).
//! * **Annotation-flag consistency** — a cost slot may be nonzero only
//!   after the compute pass marked the IR, and a comm slot non-`None`
//!   only after the comm pass did; a plan of `CommType::None` must
//!   carry zero bytes.
//! * **Collective-plan admissibility** — every per-phase collective is
//!   one the planner ([`crate::translator::comm_for_layer`]) could have
//!   emitted for the annotated parallelism, ZeRO stages included (e.g.
//!   a weight-gradient `AllReduce` under pure model parallelism is
//!   rejected).
//!
//! It runs from `modtrans check`, from debug-build hooks at the
//! frontend and emit boundaries, and unconditionally against every
//! et-json / cache envelope the disk tier loads (a failing envelope is
//! a cache miss, never a trusted IR).

use super::ModelIR;
use crate::error::{Error, Result};
use crate::translator::CommPlan;
use crate::workload::{CommType, Parallelism};

/// Admissible non-`None` collectives for one (parallelism, phase).
/// `CommType::None` is always admissible: small layers can legitimately
/// plan no traffic for a phase.
fn admissible(parallelism: Parallelism, phase: usize) -> &'static [CommType] {
    use CommType::{AllGather, AllReduce, AllToAll, ReduceScatter};
    const DATA: [&[CommType]; 3] = [
        &[AllGather],                // fwd (ZeRO-2/3 parameter gather)
        &[AllGather],                // ig  (ZeRO-3 re-gather)
        &[AllReduce, ReduceScatter], // wg  (plain DP / ZeRO gradient shard)
    ];
    const MODEL: [&[CommType]; 3] = [&[AllGather, AllToAll], &[AllGather, AllToAll], &[]];
    const HYBRID_DM: [&[CommType]; 3] = [&[AllGather, AllToAll], &[AllGather], &[AllReduce]];
    const HYBRID_MD: [&[CommType]; 3] = [&[AllGather], &[AllGather], &[AllReduce]];
    const PIPELINE: [&[CommType]; 3] = [&[], &[], &[AllReduce]];
    let table = match parallelism {
        Parallelism::Data => &DATA,
        Parallelism::Model => &MODEL,
        Parallelism::HybridDataModel => &HYBRID_DM,
        Parallelism::HybridModelData => &HYBRID_MD,
        Parallelism::Pipeline => &PIPELINE,
    };
    table.get(phase).copied().unwrap_or(&[])
}

fn check_phase(
    layer: usize,
    name: &str,
    phase: usize,
    slot: (CommType, u64),
    parallelism: Parallelism,
) -> Result<()> {
    const PHASES: [&str; 3] = ["fwd", "ig", "wg"];
    let phase_name = PHASES.get(phase).copied().unwrap_or("?");
    let (ty, bytes) = slot;
    if ty == CommType::None {
        if bytes != 0 {
            return Err(Error::verify(format!(
                "layer {layer} ('{name}') {phase_name}: CommType::None with {bytes} bytes"
            )));
        }
        return Ok(());
    }
    if !admissible(parallelism, phase).contains(&ty) {
        return Err(Error::verify(format!(
            "layer {layer} ('{name}') {phase_name}: {ty:?} is not admissible under {parallelism:?}"
        )));
    }
    Ok(())
}

/// Verifies every structural invariant of `ir` (see the module docs).
/// Cheap — O(layers) with no allocation beyond the error path.
pub fn verify(ir: &ModelIR) -> Result<()> {
    let n = ir.summary.layers.len();
    if ir.costs.len() != n || ir.comms.len() != n {
        return Err(Error::verify(format!(
            "slot arrays out of sync: {n} layers, {} cost slots, {} comm slots",
            ir.costs.len(),
            ir.comms.len()
        )));
    }
    if ir.summary.model_name.is_empty() {
        return Err(Error::verify("empty model name"));
    }
    if ir.summary.batch < 1 {
        return Err(Error::verify(format!(
            "batch must be >= 1, got {}",
            ir.summary.batch
        )));
    }
    for (i, l) in ir.summary.layers.iter().enumerate() {
        if l.name.is_empty() {
            return Err(Error::verify(format!(
                "layer {i} has an empty name (names key the et-json replay)"
            )));
        }
    }
    if !ir.compute_annotated {
        if let Some(i) = ir.costs.iter().position(|c| *c != super::PhaseCost::default()) {
            return Err(Error::verify(format!(
                "layer {i} has nonzero cost slots but the compute pass has not run"
            )));
        }
    }
    match ir.comm_annotated {
        None => {
            if let Some(i) = ir.comms.iter().position(|p| *p != CommPlan::none()) {
                return Err(Error::verify(format!(
                    "layer {i} has a comm plan but the comm pass has not run"
                )));
            }
        }
        Some(parallelism) => {
            for (i, (plan, l)) in ir.comms.iter().zip(ir.summary.layers.iter()).enumerate() {
                check_phase(i, &l.name, 0, plan.fwd, parallelism)?;
                check_phase(i, &l.name, 1, plan.ig, parallelism)?;
                check_phase(i, &l.name, 2, plan.wg, parallelism)?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::SystolicCompute;
    use crate::ir::{frontend, passes, PhaseCost};
    use crate::translator::TranslateOpts;

    fn annotated(parallelism: Parallelism) -> ModelIR {
        let mut ir = frontend::from_zoo("mlp", 4).unwrap();
        passes::annotate_compute(&mut ir, &SystolicCompute::new(4));
        passes::annotate_comm(
            &mut ir,
            TranslateOpts { parallelism, ..TranslateOpts::default() },
        );
        ir
    }

    #[test]
    fn clean_irs_verify_at_every_stage() {
        let mut ir = frontend::from_zoo("mlp", 4).unwrap();
        verify(&ir).unwrap();
        passes::annotate_compute(&mut ir, &SystolicCompute::new(4));
        verify(&ir).unwrap();
        for p in [
            Parallelism::Data,
            Parallelism::Model,
            Parallelism::HybridDataModel,
            Parallelism::HybridModelData,
            Parallelism::Pipeline,
        ] {
            verify(&annotated(p)).unwrap();
        }
    }

    #[test]
    fn unflagged_cost_slots_are_rejected() {
        let mut ir = frontend::from_zoo("mlp", 4).unwrap();
        {
            let (_, costs, _) = ir.parts_mut();
            costs[0] = PhaseCost { fwd_ns: 1, ..PhaseCost::default() };
        }
        let e = verify(&ir).unwrap_err().to_string();
        assert!(e.contains("compute pass has not run"), "{e}");
    }

    #[test]
    fn unflagged_comm_slots_are_rejected() {
        let mut ir = frontend::from_zoo("mlp", 4).unwrap();
        {
            let (_, _, comms) = ir.parts_mut();
            comms[0].wg = (CommType::AllReduce, 64);
        }
        let e = verify(&ir).unwrap_err().to_string();
        assert!(e.contains("comm pass has not run"), "{e}");
    }

    #[test]
    fn inadmissible_collective_is_rejected() {
        let mut ir = annotated(Parallelism::Model);
        {
            let (_, _, comms) = ir.parts_mut();
            // A weight-gradient AllReduce is a data-parallel construct;
            // pure model parallelism must reject it.
            comms[0].wg = (CommType::AllReduce, 1024);
        }
        let e = verify(&ir).unwrap_err().to_string();
        assert!(e.contains("not admissible under Model"), "{e}");
        assert!(e.starts_with("verify error:"), "{e}");
    }

    #[test]
    fn none_with_bytes_is_rejected() {
        let mut ir = annotated(Parallelism::Data);
        {
            let (_, _, comms) = ir.parts_mut();
            comms[0].fwd = (CommType::None, 8);
        }
        let e = verify(&ir).unwrap_err().to_string();
        assert!(e.contains("CommType::None with 8 bytes"), "{e}");
    }
}
