//! Unified error type for the ModTrans library.
//!
//! Hand-implemented `Display`/`Error` (no `thiserror`) so the default
//! build has zero external dependencies and compiles with no registry
//! access — the same offline constraint the rest of the crate's
//! substrates (protobuf, JSON, PRNG, tables) are built under.

use std::fmt;

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error for all ModTrans subsystems.
#[derive(Debug)]
pub enum Error {
    /// Protobuf wire-format decoding failed.
    ProtoDecode(String),

    /// ONNX model-level validation or parsing failed.
    Onnx(String),

    /// Unknown model name requested from the zoo.
    UnknownModel(String),

    /// Translator could not extract the required layer information.
    Translate(String),

    /// Workload description file is malformed.
    WorkloadParse {
        /// 1-based line number of the offending row.
        line: usize,
        /// What went wrong.
        msg: String,
    },

    /// Simulator configuration or execution error.
    Sim(String),

    /// JSON parse error with byte offset.
    Json {
        /// Byte offset of the parse failure.
        offset: usize,
        /// What went wrong.
        msg: String,
    },

    /// Configuration semantic error.
    Config(String),

    /// PJRT runtime / artifact error.
    Runtime(String),

    /// CLI usage error.
    Usage(String),

    /// Static-analysis (modtrans-lint) error: malformed manifest or
    /// marker, or an unreadable source tree.
    Lint(String),

    /// Semantic-verifier rejection: an IR or task graph violates a
    /// structural invariant (see `ir::verify` / `sim::verify_graph`).
    Verify(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::ProtoDecode(m) => write!(f, "protobuf decode error: {m}"),
            Error::Onnx(m) => write!(f, "onnx error: {m}"),
            Error::UnknownModel(m) => {
                write!(f, "unknown zoo model '{m}' (try `modtrans zoo list`)")
            }
            Error::Translate(m) => write!(f, "translate error: {m}"),
            Error::WorkloadParse { line, msg } => {
                write!(f, "workload parse error at line {line}: {msg}")
            }
            Error::Sim(m) => write!(f, "simulation error: {m}"),
            Error::Json { offset, msg } => {
                write!(f, "json parse error at offset {offset}: {msg}")
            }
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Usage(m) => write!(f, "usage error: {m}"),
            Error::Lint(m) => write!(f, "lint error: {m}"),
            Error::Verify(m) => write!(f, "verify error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor for ONNX errors.
    pub fn onnx(msg: impl Into<String>) -> Self {
        Error::Onnx(msg.into())
    }
    /// Shorthand constructor for simulator errors.
    pub fn sim(msg: impl Into<String>) -> Self {
        Error::Sim(msg.into())
    }
    /// Shorthand constructor for translator errors.
    pub fn translate(msg: impl Into<String>) -> Self {
        Error::Translate(msg.into())
    }
    /// Shorthand constructor for static-analysis errors.
    pub fn lint(msg: impl Into<String>) -> Self {
        Error::Lint(msg.into())
    }
    /// Shorthand constructor for semantic-verifier errors.
    pub fn verify(msg: impl Into<String>) -> Self {
        Error::Verify(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_stable() {
        assert_eq!(
            Error::ProtoDecode("bad tag".into()).to_string(),
            "protobuf decode error: bad tag"
        );
        assert_eq!(
            Error::WorkloadParse { line: 3, msg: "nope".into() }.to_string(),
            "workload parse error at line 3: nope"
        );
        assert_eq!(
            Error::UnknownModel("resnet999".into()).to_string(),
            "unknown zoo model 'resnet999' (try `modtrans zoo list`)"
        );
        assert_eq!(
            Error::Json { offset: 12, msg: "trailing".into() }.to_string(),
            "json parse error at offset 12: trailing"
        );
    }

    #[test]
    fn io_errors_convert_and_chain() {
        use std::error::Error as _;
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().starts_with("io error:"));
        assert!(e.source().is_some());
        assert!(Error::Sim("x".into()).source().is_none());
    }
}
