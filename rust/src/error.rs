//! Unified error type for the ModTrans library.

use thiserror::Error;

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Unified error for all ModTrans subsystems.
#[derive(Debug, Error)]
pub enum Error {
    /// Protobuf wire-format decoding failed.
    #[error("protobuf decode error: {0}")]
    ProtoDecode(String),

    /// ONNX model-level validation or parsing failed.
    #[error("onnx error: {0}")]
    Onnx(String),

    /// Unknown model name requested from the zoo.
    #[error("unknown zoo model '{0}' (try `modtrans zoo list`)")]
    UnknownModel(String),

    /// Translator could not extract the required layer information.
    #[error("translate error: {0}")]
    Translate(String),

    /// Workload description file is malformed.
    #[error("workload parse error at line {line}: {msg}")]
    WorkloadParse { line: usize, msg: String },

    /// Simulator configuration or execution error.
    #[error("simulation error: {0}")]
    Sim(String),

    /// JSON parse error with byte offset.
    #[error("json parse error at offset {offset}: {msg}")]
    Json { offset: usize, msg: String },

    /// Configuration semantic error.
    #[error("config error: {0}")]
    Config(String),

    /// PJRT runtime / artifact error.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// CLI usage error.
    #[error("usage error: {0}")]
    Usage(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Shorthand constructor for ONNX errors.
    pub fn onnx(msg: impl Into<String>) -> Self {
        Error::Onnx(msg.into())
    }
    /// Shorthand constructor for simulator errors.
    pub fn sim(msg: impl Into<String>) -> Self {
        Error::Sim(msg.into())
    }
    /// Shorthand constructor for translator errors.
    pub fn translate(msg: impl Into<String>) -> Self {
        Error::Translate(msg.into())
    }
}
