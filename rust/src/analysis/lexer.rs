//! Token-level source cleaning and region extraction.
//!
//! [`clean`] walks a Rust source file once, character by character, and
//! produces a *cleaned* copy in which the contents of comments, string
//! literals (plain, byte, and raw with any `#` depth), and char
//! literals are replaced by spaces while line structure is preserved
//! exactly. Rule patterns are then matched against the cleaned lines,
//! so `"a.unwrap()"` inside a string or a doc comment can never fire a
//! rule. Line comments are captured verbatim on the side because the
//! `// lint: …` marker grammar lives in them.
//!
//! [`FileMap`] post-processes a cleaned file into the per-line masks
//! the rule engine needs: `#[cfg(test)]` regions, hot-path /
//! fallible-path function spans (brace-matched from their marker), and
//! the per-line allow table.

use crate::error::{Error, Result};

/// A line comment captured during cleaning, verbatim (including the
/// leading slashes), with the 0-based line it starts on and whether any
/// code precedes it on that line (trailing vs. standalone comment).
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: usize,
    pub text: String,
    pub code_before: bool,
}

/// Result of [`clean`]: blanked source split into lines, plus every
/// line comment encountered.
#[derive(Debug, Clone)]
pub struct Cleaned {
    pub lines: Vec<String>,
    pub comments: Vec<Comment>,
}

/// Returns `Some((hashes, prefix_len))` when `chars[i..]` starts a raw
/// (or raw byte) string literal: optional `b`, `r`, zero or more `#`,
/// then `"`. `prefix_len` counts everything through the opening quote.
fn raw_string_start(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

/// Blanks comments and literal contents out of `src`, preserving line
/// structure, and captures line comments for marker parsing.
pub fn clean(src: &str) -> Cleaned {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(src.len());
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 0usize;
    let mut line_has_code = false;
    while i < n {
        let c = chars[i];
        // Raw / raw-byte strings first: `r"…"`, `r#"…"#`, `br##"…"##`.
        // Skip when the `r`/`b` is the tail of an identifier.
        let prev_ident = i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_');
        if (c == 'r' || c == 'b') && !prev_ident {
            if let Some((hashes, prefix)) = raw_string_start(&chars, i) {
                for _ in 0..prefix {
                    out.push(' ');
                }
                i += prefix;
                // Consume until `"` followed by `hashes` hash marks.
                while i < n {
                    if chars[i] == '"' {
                        let mut k = 0usize;
                        while k < hashes && chars.get(i + 1 + k) == Some(&'#') {
                            k += 1;
                        }
                        if k == hashes {
                            for _ in 0..(1 + hashes) {
                                out.push(' ');
                            }
                            i += 1 + hashes;
                            break;
                        }
                    }
                    if chars[i] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
                line_has_code = true;
                continue;
            }
            if c == 'b' && chars.get(i + 1) == Some(&'"') {
                // Byte string: blank the `b`, fall through via plain
                // string handling below on the quote.
                out.push(' ');
                i += 1;
                line_has_code = true;
                continue;
            }
            if c == 'b' && chars.get(i + 1) == Some(&'\'') {
                out.push(' ');
                i += 1;
                line_has_code = true;
                continue;
            }
        }
        match c {
            '\n' => {
                out.push('\n');
                line += 1;
                line_has_code = false;
                i += 1;
            }
            '/' if chars.get(i + 1) == Some(&'/') => {
                let start = i;
                let mut j = i;
                while j < n && chars[j] != '\n' {
                    j += 1;
                }
                comments.push(Comment {
                    line,
                    text: chars[start..j].iter().collect(),
                    code_before: line_has_code,
                });
                for _ in start..j {
                    out.push(' ');
                }
                i = j;
            }
            '/' if chars.get(i + 1) == Some(&'*') => {
                let mut depth = 1usize;
                out.push(' ');
                out.push(' ');
                i += 2;
                while i < n && depth > 0 {
                    if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        depth += 1;
                        out.push(' ');
                        out.push(' ');
                        i += 2;
                    } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        depth -= 1;
                        out.push(' ');
                        out.push(' ');
                        i += 2;
                    } else if chars[i] == '\n' {
                        out.push('\n');
                        line += 1;
                        line_has_code = false;
                        i += 1;
                    } else {
                        out.push(' ');
                        i += 1;
                    }
                }
            }
            '"' => {
                out.push('"');
                i += 1;
                while i < n {
                    if chars[i] == '\\' {
                        // Escape: blank the backslash, then handle the
                        // escaped char (a string-continuation newline
                        // must still advance the line counter).
                        out.push(' ');
                        i += 1;
                        if i < n {
                            if chars[i] == '\n' {
                                out.push('\n');
                                line += 1;
                            } else {
                                out.push(' ');
                            }
                            i += 1;
                        }
                        continue;
                    }
                    if chars[i] == '"' {
                        out.push('"');
                        i += 1;
                        break;
                    }
                    if chars[i] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
                line_has_code = true;
            }
            '\'' => {
                // Char literal vs. lifetime: a char literal is either
                // `'\…'` (escaped) or `'x'` (closing quote two ahead).
                if chars.get(i + 1) == Some(&'\\') {
                    out.push('\'');
                    i += 1;
                    while i < n && chars[i] != '\'' {
                        if chars[i] == '\\' && i + 1 < n && chars[i + 1] != '\n' {
                            // Skip the escaped char so `'\''` closes on
                            // its real quote, not the escaped one.
                            out.push(' ');
                            out.push(' ');
                            i += 2;
                            continue;
                        }
                        if chars[i] == '\n' {
                            out.push('\n');
                            line += 1;
                        } else {
                            out.push(' ');
                        }
                        i += 1;
                    }
                    if i < n {
                        out.push('\'');
                        i += 1;
                    }
                } else if chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\'') {
                    out.push('\'');
                    out.push(' ');
                    out.push('\'');
                    i += 3;
                } else {
                    // Lifetime (`'a`) or stray quote: keep as-is.
                    out.push('\'');
                    i += 1;
                }
                line_has_code = true;
            }
            _ => {
                if !c.is_whitespace() {
                    line_has_code = true;
                }
                out.push(c);
                i += 1;
            }
        }
    }
    Cleaned {
        lines: out.split('\n').map(str::to_string).collect(),
        comments,
    }
}

/// A parsed `// lint: …` marker.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MarkerKind {
    HotPath,
    FalliblePath,
    Allow { rule: String },
}

/// Parses the text of one line comment. Returns `Ok(None)` for
/// ordinary comments, `Ok(Some(kind))` for a well-formed marker, and
/// an error for a malformed one (unknown marker name, or an allow
/// without the mandatory `— <reason>` tail).
fn parse_marker(text: &str) -> Result<Option<MarkerKind>> {
    let t = text.trim_start_matches('/').trim_start_matches('!').trim();
    let Some(rest) = t.strip_prefix("lint:") else {
        return Ok(None);
    };
    let rest = rest.trim();
    if rest == "hot-path" {
        return Ok(Some(MarkerKind::HotPath));
    }
    if rest == "fallible-path" {
        return Ok(Some(MarkerKind::FalliblePath));
    }
    if let Some(r) = rest.strip_prefix("allow(") {
        let Some(close) = r.find(')') else {
            return Err(Error::lint(format!("unclosed allow marker: `{t}`")));
        };
        let rule = r[..close].trim();
        if rule.is_empty() {
            return Err(Error::lint(format!("allow marker names no rule: `{t}`")));
        }
        let after = r[close + 1..].trim();
        let reason = after
            .strip_prefix('\u{2014}') // em dash
            .or_else(|| after.strip_prefix('-'))
            .map(str::trim);
        return match reason {
            Some(s) if !s.is_empty() => Ok(Some(MarkerKind::Allow {
                rule: rule.to_string(),
            })),
            _ => Err(Error::lint(format!(
                "allow marker needs a reason (`// lint: allow({rule}) — <reason>`): `{t}`"
            ))),
        };
    }
    Err(Error::lint(format!("unknown lint marker: `{t}`")))
}

/// Finds the last line of the brace-delimited span opening at or after
/// `start` (0-based). Counts braces over *cleaned* lines, so literals
/// and comments cannot unbalance it. Returns the last line index, or
/// the final line when no brace ever closes (truncated input).
fn brace_span_end(lines: &[String], start: usize) -> usize {
    let mut depth: i64 = 0;
    let mut seen = false;
    for (idx, l) in lines.iter().enumerate().skip(start) {
        for ch in l.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    seen = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
            if seen && depth <= 0 {
                return idx;
            }
        }
    }
    lines.len().saturating_sub(1)
}

/// Per-line view of one source file after cleaning: the masks and the
/// allow table the rule engine consumes.
#[derive(Debug)]
pub struct FileMap {
    pub lines: Vec<String>,
    /// Line is inside a `#[cfg(test)]` item.
    pub test_mask: Vec<bool>,
    /// Line is inside a `// lint: hot-path` annotated function.
    pub hot_mask: Vec<bool>,
    /// Line is inside a `// lint: fallible-path` annotated function.
    pub fallible_mask: Vec<bool>,
    /// `(line, rule)` pairs: `rule` findings on `line` are suppressed.
    pub allows: Vec<(usize, String)>,
}

impl FileMap {
    /// Builds the map for one file. Errors on malformed markers so a
    /// typo'd annotation fails the lint instead of silently doing
    /// nothing.
    pub fn build(src: &str) -> Result<FileMap> {
        let cleaned = clean(src);
        let lines = cleaned.lines;
        let num = lines.len();
        let mut test_mask = vec![false; num];
        let mut hot_mask = vec![false; num];
        let mut fallible_mask = vec![false; num];
        let mut allows = Vec::new();

        for (idx, l) in lines.iter().enumerate() {
            if l.contains("#[cfg(test)]") {
                let end = brace_span_end(&lines, idx);
                for m in test_mask.iter_mut().take(end + 1).skip(idx) {
                    *m = true;
                }
            }
        }

        for c in &cleaned.comments {
            match parse_marker(&c.text)? {
                None => {}
                Some(MarkerKind::Allow { rule }) => {
                    // Trailing form applies to its own line; standalone
                    // form to the next line holding any code.
                    let mut target = c.line;
                    if !c.code_before {
                        let mut j = c.line + 1;
                        while j < num && lines[j].trim().is_empty() {
                            j += 1;
                        }
                        if j >= num {
                            return Err(Error::lint(format!(
                                "allow({rule}) marker at end of file has no code line to apply to"
                            )));
                        }
                        target = j;
                    }
                    allows.push((target, rule));
                }
                Some(kind) => {
                    // hot-path / fallible-path: annotate the next `fn`
                    // (the marker's own line counts, for the trailing
                    // `fn f() { // lint: hot-path` form).
                    let mut fl = c.line;
                    while fl < num && !lines[fl].contains("fn ") {
                        fl += 1;
                    }
                    if fl >= num {
                        return Err(Error::lint(
                            "hot-path/fallible-path marker is not followed by a fn".to_string(),
                        ));
                    }
                    let end = brace_span_end(&lines, fl);
                    let mask = if kind == MarkerKind::HotPath {
                        &mut hot_mask
                    } else {
                        &mut fallible_mask
                    };
                    for m in mask.iter_mut().take(end + 1).skip(fl) {
                        *m = true;
                    }
                }
            }
        }

        Ok(FileMap {
            lines,
            test_mask,
            hot_mask,
            fallible_mask,
            allows,
        })
    }

    /// True when `rule` findings on 0-based `line` are suppressed by an
    /// allow marker.
    pub fn allowed(&self, line: usize, rule: &str) -> bool {
        self.allows.iter().any(|(l, r)| *l == line && r == rule)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let c = clean("let x = \"a.unwrap()\"; // b.unwrap()\nlet y = 1;\n");
        assert!(!c.lines[0].contains("unwrap"));
        assert_eq!(c.lines[1], "let y = 1;");
        assert_eq!(c.comments.len(), 1);
        assert!(c.comments[0].code_before);
        assert!(c.comments[0].text.contains("b.unwrap()"));
    }

    #[test]
    fn raw_strings_and_chars_are_blanked() {
        let c = clean("let s = r#\"panic!(\"x\")\"#; let ch = '{'; let lt: &'static str = s;");
        assert!(!c.lines[0].contains("panic!"));
        assert!(!c.lines[0].contains('{'));
        assert!(c.lines[0].contains("'static"));
    }

    #[test]
    fn string_continuation_keeps_line_count() {
        let src = "let s = \"a \\\n   b\";\nlet t = 1;\n";
        let c = clean(src);
        assert_eq!(c.lines.len(), src.split('\n').count());
        assert_eq!(c.lines[2], "let t = 1;");
    }

    #[test]
    fn block_comments_can_nest() {
        let c = clean("/* a /* b */ c.unwrap() */ let z = 2;");
        assert!(!c.lines[0].contains("unwrap"));
        assert!(c.lines[0].contains("let z = 2;"));
    }

    #[test]
    fn cfg_test_region_is_masked() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n  fn b() {}\n}\nfn c() {}\n";
        let map = FileMap::build(src).unwrap();
        assert!(!map.test_mask[0]);
        assert!(map.test_mask[1] && map.test_mask[2] && map.test_mask[3] && map.test_mask[4]);
        assert!(!map.test_mask[5]);
    }

    #[test]
    fn hot_path_span_covers_fn_body() {
        let src = "// lint: hot-path\nfn hot(x: u64) -> u64 {\n  x + 1\n}\nfn cold() {}\n";
        let map = FileMap::build(src).unwrap();
        assert!(map.hot_mask[1] && map.hot_mask[2] && map.hot_mask[3]);
        assert!(!map.hot_mask[4]);
    }

    #[test]
    fn allow_marker_forms() {
        let src = "let a = 1; // lint: allow(no-panic) — provably fine\n\
                   // lint: allow(no-alloc) — cold path\nlet b = 2;\n";
        let map = FileMap::build(src).unwrap();
        assert!(map.allowed(0, "no-panic"));
        assert!(map.allowed(2, "no-alloc"));
        assert!(!map.allowed(2, "no-panic"));
    }

    #[test]
    fn allow_without_reason_is_an_error() {
        assert!(FileMap::build("// lint: allow(no-panic)\nlet a = 1;\n").is_err());
        assert!(FileMap::build("// lint: frobnicate\n").is_err());
    }

    #[test]
    fn marker_like_text_in_plain_comment_is_ignored() {
        let map = FileMap::build("// this mentions lint markers but is not one\nlet a = 1;\n");
        assert!(map.is_ok());
    }
}
