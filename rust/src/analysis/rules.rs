//! The declarative rule manifest and its hand-rolled parser.
//!
//! `analysis/rules.toml` (repo root) is parsed by a deliberately small
//! TOML-subset reader — tables of `[[rule]]` entries whose values are
//! strings, booleans, integers, or (possibly multi-line) arrays of
//! strings — keeping the default build dependency-free, exactly like
//! the in-crate JSON and protobuf codecs. Unknown keys, unknown scope
//! names, duplicate rule names, and empty pattern lists are hard
//! errors: a manifest typo must fail the lint run, not silently skip a
//! rule.

use crate::error::{Error, Result};

/// Where a rule applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scope {
    /// Every line of every file matched by the rule's path prefixes.
    Paths,
    /// Only lines inside `// lint: hot-path` annotated functions.
    HotPath,
    /// Only lines inside `// lint: fallible-path` annotated functions.
    FalliblePath,
}

/// How a rule matches a line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Matcher {
    /// Any of the rule's `patterns` occurs as a substring.
    Substring,
    /// A direct index expression `expr[…]` occurs (no patterns).
    Index,
}

/// One declarative rule from the manifest.
#[derive(Debug, Clone)]
pub struct Rule {
    pub name: String,
    pub scope: Scope,
    /// Path prefixes (repo-relative, forward slashes) the rule covers.
    /// Empty means every scanned file.
    pub paths: Vec<String>,
    /// Path prefixes carved back out of `paths`.
    pub exclude: Vec<String>,
    /// Scan `#[cfg(test)]` regions too (default: skip them).
    pub include_tests: bool,
    pub matcher: Matcher,
    pub patterns: Vec<String>,
    pub message: String,
}

/// The parsed manifest: an ordered list of rules.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub rules: Vec<Rule>,
}

impl Manifest {
    /// True when the manifest has a rule named `name`.
    pub fn has_rule(&self, name: &str) -> bool {
        self.rules.iter().any(|r| r.name == name)
    }
}

/// One parsed `key = value` right-hand side.
enum Val {
    Str(String),
    Bool(bool),
    Int(i64),
    Arr(Vec<String>),
}

/// Unquotes one TOML basic string token (handles `\\` and `\"`).
fn unquote(tok: &str, line_no: usize) -> Result<String> {
    let inner = tok
        .strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .ok_or_else(|| Error::lint(format!("manifest line {line_no}: expected a string: {tok}")))?;
    let mut out = String::with_capacity(inner.len());
    let mut chars = inner.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('\\') => out.push('\\'),
                Some('"') => out.push('"'),
                other => {
                    return Err(Error::lint(format!(
                        "manifest line {line_no}: unsupported escape \\{}",
                        other.map(String::from).unwrap_or_default()
                    )))
                }
            }
        } else {
            out.push(c);
        }
    }
    Ok(out)
}

/// Splits an array body `"a", "b", "c"` into unquoted strings, honoring
/// quotes and escapes.
fn split_array(body: &str, line_no: usize) -> Result<Vec<String>> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    let mut esc = false;
    for c in body.chars() {
        if in_str {
            cur.push(c);
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => {
                cur.push(c);
                in_str = true;
            }
            ',' => {
                let t = cur.trim().to_string();
                if !t.is_empty() {
                    out.push(unquote(&t, line_no)?);
                }
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    let t = cur.trim().to_string();
    if !t.is_empty() {
        out.push(unquote(&t, line_no)?);
    }
    if in_str {
        return Err(Error::lint(format!(
            "manifest line {line_no}: unterminated string in array"
        )));
    }
    Ok(out)
}

fn parse_value(raw: &str, line_no: usize) -> Result<Val> {
    let v = raw.trim();
    if v == "true" {
        return Ok(Val::Bool(true));
    }
    if v == "false" {
        return Ok(Val::Bool(false));
    }
    if v.starts_with('"') {
        return Ok(Val::Str(unquote(v, line_no)?));
    }
    if let Some(body) = v.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or_else(|| {
            Error::lint(format!("manifest line {line_no}: unterminated array"))
        })?;
        return Ok(Val::Arr(split_array(body, line_no)?));
    }
    v.parse::<i64>().map(Val::Int).map_err(|_| {
        Error::lint(format!("manifest line {line_no}: unparseable value: {v}"))
    })
}

/// Strips a trailing `# comment` that is outside any string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut esc = false;
    for (i, c) in line.char_indices() {
        if in_str {
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '#' => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Default-initialized rule, filled in key by key.
fn blank_rule() -> Rule {
    Rule {
        name: String::new(),
        scope: Scope::Paths,
        paths: Vec::new(),
        exclude: Vec::new(),
        include_tests: false,
        matcher: Matcher::Substring,
        patterns: Vec::new(),
        message: String::new(),
    }
}

fn finish_rule(rule: Rule, line_no: usize) -> Result<Rule> {
    if rule.name.is_empty() {
        return Err(Error::lint(format!(
            "manifest line {line_no}: rule has no name"
        )));
    }
    if rule.matcher == Matcher::Substring && rule.patterns.is_empty() {
        return Err(Error::lint(format!(
            "manifest: rule '{}' has no patterns",
            rule.name
        )));
    }
    if rule.message.is_empty() {
        return Err(Error::lint(format!(
            "manifest: rule '{}' has no message",
            rule.name
        )));
    }
    Ok(rule)
}

/// Parses the manifest text. See the module docs for the grammar.
pub fn parse_manifest(text: &str) -> Result<Manifest> {
    let mut rules: Vec<Rule> = Vec::new();
    let mut current: Option<Rule> = None;
    let mut lines = text.lines().enumerate();
    while let Some((idx, raw)) = lines.next() {
        let line_no = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line == "[[rule]]" {
            if let Some(r) = current.take() {
                rules.push(finish_rule(r, line_no)?);
            }
            current = Some(blank_rule());
            continue;
        }
        if line.starts_with('[') {
            return Err(Error::lint(format!(
                "manifest line {line_no}: unknown table {line}"
            )));
        }
        let Some(eq) = line.find('=') else {
            return Err(Error::lint(format!(
                "manifest line {line_no}: expected `key = value`: {line}"
            )));
        };
        let key = line[..eq].trim();
        let mut value = line[eq + 1..].trim().to_string();
        // Multi-line arrays: keep consuming until brackets balance.
        if value.starts_with('[') {
            while value.matches('[').count() > value.matches(']').count() {
                let Some((_, next)) = lines.next() else {
                    return Err(Error::lint(format!(
                        "manifest line {line_no}: unterminated array"
                    )));
                };
                value.push(' ');
                value.push_str(strip_comment(next).trim());
            }
        }
        let val = parse_value(&value, line_no)?;
        match (&mut current, key, val) {
            (None, "version", Val::Int(1)) => {}
            (None, "version", _) => {
                return Err(Error::lint(format!(
                    "manifest line {line_no}: unsupported manifest version"
                )));
            }
            (None, k, _) => {
                return Err(Error::lint(format!(
                    "manifest line {line_no}: key `{k}` outside a [[rule]] table"
                )));
            }
            (Some(r), "name", Val::Str(s)) => r.name = s,
            (Some(r), "scope", Val::Str(s)) => {
                r.scope = match s.as_str() {
                    "paths" => Scope::Paths,
                    "hot-path" => Scope::HotPath,
                    "fallible-path" => Scope::FalliblePath,
                    other => {
                        return Err(Error::lint(format!(
                            "manifest line {line_no}: unknown scope `{other}`"
                        )));
                    }
                }
            }
            (Some(r), "match", Val::Str(s)) => {
                r.matcher = match s.as_str() {
                    "substring" => Matcher::Substring,
                    "index" => Matcher::Index,
                    other => {
                        return Err(Error::lint(format!(
                            "manifest line {line_no}: unknown matcher `{other}`"
                        )));
                    }
                }
            }
            (Some(r), "paths", Val::Arr(a)) => r.paths = a,
            (Some(r), "exclude", Val::Arr(a)) => r.exclude = a,
            (Some(r), "patterns", Val::Arr(a)) => r.patterns = a,
            (Some(r), "include-tests", Val::Bool(b)) => r.include_tests = b,
            (Some(r), "message", Val::Str(s)) => r.message = s,
            (Some(_), k, _) => {
                return Err(Error::lint(format!(
                    "manifest line {line_no}: unknown or mistyped key `{k}`"
                )));
            }
        }
    }
    if let Some(r) = current.take() {
        rules.push(finish_rule(r, text.lines().count())?);
    }
    if rules.is_empty() {
        return Err(Error::lint("manifest declares no rules".to_string()));
    }
    for (i, r) in rules.iter().enumerate() {
        if rules[..i].iter().any(|p| p.name == r.name) {
            return Err(Error::lint(format!(
                "manifest: duplicate rule name '{}'",
                r.name
            )));
        }
    }
    Ok(Manifest { rules })
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = r#"
version = 1

[[rule]]
name = "no-panic"
scope = "paths"
paths = ["rust/src/ir/", "rust/src/sim/"]
exclude = ["rust/src/sim/queue.rs"]
patterns = [
  ".unwrap()",  # inline comment
  ".expect(",
]
message = "library code must return typed errors"

[[rule]]
name = "index-fallible"
scope = "fallible-path"
match = "index"
message = "no direct indexing in fallible paths"
"#;

    #[test]
    fn parses_rules_and_arrays() {
        let m = parse_manifest(GOOD).unwrap();
        assert_eq!(m.rules.len(), 2);
        assert_eq!(m.rules[0].name, "no-panic");
        assert_eq!(m.rules[0].patterns, vec![".unwrap()", ".expect("]);
        assert_eq!(m.rules[0].exclude, vec!["rust/src/sim/queue.rs"]);
        assert_eq!(m.rules[1].scope, Scope::FalliblePath);
        assert_eq!(m.rules[1].matcher, Matcher::Index);
        assert!(m.has_rule("no-panic") && !m.has_rule("no-such"));
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(parse_manifest("version = 2\n").is_err());
        assert!(parse_manifest("name = \"x\"\n").is_err());
        assert!(parse_manifest("[[rule]]\nscope = \"nope\"\n").is_err());
        assert!(parse_manifest(
            "[[rule]]\nname = \"a\"\npatterns = [\"x\"]\nmessage = \"m\"\n\
             [[rule]]\nname = \"a\"\npatterns = [\"x\"]\nmessage = \"m\"\n"
        )
        .is_err());
        assert!(
            parse_manifest("[[rule]]\nname = \"a\"\nmessage = \"m\"\n").is_err(),
            "substring rule with no patterns must be rejected"
        );
    }
}
