//! The scope-aware rule engine: applies a [`Manifest`] to cleaned
//! source files and collects [`Finding`]s.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use super::lexer::FileMap;
use super::rules::{Manifest, Matcher, Rule, Scope};
use crate::error::{Error, Result};

/// One rule violation, pinned to a file and 1-based line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: String,
    /// Repo-relative path, forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// The pattern (or construct) that fired.
    pub pattern: String,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] `{}` — {}",
            self.file, self.line, self.rule, self.pattern, self.message
        )
    }
}

/// Result of a lint run.
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    /// Matches suppressed by `// lint: allow(...)` markers.
    pub suppressed: usize,
}

/// True when `rel` (forward-slash repo-relative path) is covered by the
/// rule's path prefixes minus its excludes.
fn in_scope(rule: &Rule, rel: &str) -> bool {
    if rule.exclude.iter().any(|p| rel.starts_with(p.as_str())) {
        return false;
    }
    rule.paths.is_empty() || rule.paths.iter().any(|p| rel.starts_with(p.as_str()))
}

/// True when the cleaned line contains a direct index expression: a `[`
/// immediately preceded (modulo spaces) by an identifier char, `)`, or
/// `]` — i.e. `xs[i]`, `f(x)[0]`, `m[a][b]`, but not `#[attr]`, array
/// literals, or types like `[u8; 4]`.
fn has_index_expr(line: &str) -> bool {
    let chars: Vec<char> = line.chars().collect();
    for (i, &c) in chars.iter().enumerate() {
        if c != '[' {
            continue;
        }
        let mut j = i;
        while j > 0 && chars[j - 1] == ' ' {
            j -= 1;
        }
        if j == 0 {
            continue;
        }
        let p = chars[j - 1];
        if p.is_alphanumeric() || p == '_' || p == ')' || p == ']' {
            return true;
        }
    }
    false
}

/// Lints a single file's source text under its repo-relative path.
/// This is the unit the fixture tests drive directly: the path decides
/// which rules are in scope, the text is linted as if it lived there.
pub fn lint_source(rel: &str, src: &str, manifest: &Manifest) -> Result<LintReport> {
    let map = FileMap::build(src)
        .map_err(|e| Error::lint(format!("{rel}: {e}")))?;
    for (line, rule) in &map.allows {
        if !manifest.has_rule(rule) {
            return Err(Error::lint(format!(
                "{rel}:{}: allow marker names unknown rule '{rule}'",
                line + 1
            )));
        }
    }
    let mut report = LintReport {
        files_scanned: 1,
        ..LintReport::default()
    };
    for rule in &manifest.rules {
        if !in_scope(rule, rel) {
            continue;
        }
        for (idx, line) in map.lines.iter().enumerate() {
            if map.test_mask[idx] && !rule.include_tests {
                continue;
            }
            let in_span = match rule.scope {
                Scope::Paths => true,
                Scope::HotPath => map.hot_mask[idx],
                Scope::FalliblePath => map.fallible_mask[idx],
            };
            if !in_span {
                continue;
            }
            let hits: Vec<String> = match rule.matcher {
                Matcher::Substring => rule
                    .patterns
                    .iter()
                    .filter(|p| line.contains(p.as_str()))
                    .cloned()
                    .collect(),
                Matcher::Index => {
                    if has_index_expr(line) {
                        vec!["indexing".to_string()]
                    } else {
                        Vec::new()
                    }
                }
            };
            for pattern in hits {
                if map.allowed(idx, &rule.name) {
                    report.suppressed += 1;
                    continue;
                }
                report.findings.push(Finding {
                    rule: rule.name.clone(),
                    file: rel.to_string(),
                    line: idx + 1,
                    pattern,
                    message: rule.message.clone(),
                });
            }
        }
    }
    Ok(report)
}

/// Collects every `.rs` file under `dir` (recursively), sorted for
/// deterministic output.
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = fs::read_dir(dir)
        .map_err(|e| Error::lint(format!("cannot read {}: {e}", dir.display())))?;
    let mut paths: Vec<PathBuf> = Vec::new();
    for entry in entries {
        let entry = entry.map_err(|e| Error::lint(format!("walk error: {e}")))?;
        paths.push(entry.path());
    }
    paths.sort();
    for p in paths {
        if p.is_dir() {
            collect_rs_files(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

/// Lints every `rust/src/**/*.rs` file under the repo root `root`
/// against `manifest`. Findings come back sorted by (file, line).
pub fn lint_tree(root: &Path, manifest: &Manifest) -> Result<LintReport> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs_files(&src_root, &mut files)?;
    let mut report = LintReport::default();
    for path in &files {
        let rel = match path.strip_prefix(root) {
            Ok(r) => r
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/"),
            Err(_) => path.display().to_string(),
        };
        let src = fs::read_to_string(path)
            .map_err(|e| Error::lint(format!("cannot read {rel}: {e}")))?;
        let one = lint_source(&rel, &src, manifest)?;
        report.findings.extend(one.findings);
        report.files_scanned += 1;
        report.suppressed += one.suppressed;
    }
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::rules::parse_manifest;

    fn manifest() -> Manifest {
        parse_manifest(
            "[[rule]]\n\
             name = \"no-panic\"\n\
             paths = [\"rust/src/sim/\"]\n\
             patterns = [\".unwrap()\"]\n\
             message = \"no panics\"\n",
        )
        .unwrap()
    }

    #[test]
    fn fires_in_scope_and_not_outside() {
        let m = manifest();
        let src = "fn f() { x.unwrap(); }\n";
        let hit = lint_source("rust/src/sim/a.rs", src, &m).unwrap();
        assert_eq!(hit.findings.len(), 1);
        assert_eq!(hit.findings[0].line, 1);
        let miss = lint_source("rust/src/cli.rs", src, &m).unwrap();
        assert!(miss.findings.is_empty());
    }

    #[test]
    fn allow_suppresses_and_counts() {
        let m = manifest();
        let src = "fn f() { x.unwrap(); } // lint: allow(no-panic) — provably non-empty\n";
        let r = lint_source("rust/src/sim/a.rs", src, &m).unwrap();
        assert!(r.findings.is_empty());
        assert_eq!(r.suppressed, 1);
    }

    #[test]
    fn unknown_allow_rule_is_an_error() {
        let m = manifest();
        let src = "fn f() { x.unwrap(); } // lint: allow(no-such-rule) — oops\n";
        assert!(lint_source("rust/src/sim/a.rs", src, &m).is_err());
    }

    #[test]
    fn index_matcher_spots_indexing_only() {
        assert!(has_index_expr("let a = xs[i];"));
        assert!(has_index_expr("let a = f(x)[0];"));
        assert!(!has_index_expr("#[derive(Debug)]"));
        assert!(!has_index_expr("let a: [u8; 4] = *b;"));
        assert!(!has_index_expr("let v = [1, 2, 3];"));
    }
}
