//! `modtrans-lint`: a dependency-free static analysis pass over the
//! crate's own sources.
//!
//! The repo's two load-bearing contracts — the allocation-free sim /
//! derivation hot path and byte-identical rankings across threads,
//! shards, fleets and resumes — used to be enforced by a `sed | grep`
//! over five hard-coded files plus reviewer vigilance. This module
//! replaces that with a real (if deliberately small) analysis layer:
//!
//! * [`lexer`] — a token-level source cleaner: blanks the contents of
//!   comments, string/char/raw-string literals (preserving line
//!   structure), extracts `// lint: …` markers, and computes
//!   `#[cfg(test)]` regions and marker-annotated function spans by
//!   brace matching over the cleaned text. Rules therefore never fire
//!   on text inside a literal, a doc comment or a test module.
//! * [`rules`] — the declarative rule manifest (`analysis/rules.toml`
//!   at the repo root), hand-parsed from a small TOML subset so the
//!   default build stays dependency-free. Each rule names a scope
//!   (path prefixes, hot-path functions, or fallible-path functions),
//!   a pattern set and a message.
//! * [`engine`] — applies every rule to every `rust/src/**/*.rs` file,
//!   honoring three source markers:
//!   - `// lint: hot-path` — the next `fn` is a hot-path function: the
//!     `no-alloc` rule applies to its whole body.
//!   - `// lint: fallible-path` — the next `fn` must not use direct
//!     indexing (the `index-fallible` rule).
//!   - `// lint: allow(<rule>) — <reason>` — suppress `<rule>` on the
//!     same line (trailing form) or on the next code line (standalone
//!     form). The reason is mandatory; an allow without one is a hard
//!     error, so every suppression is self-documenting.
//!
//! The `modtrans-lint` binary (CI's gating `lint` job, `make lint`)
//! runs [`engine::lint_tree`] against the checked-out tree and fails on
//! any finding. See the "Static guarantees" section in the crate docs
//! for the full rule list and the semantic-verifier half of the story
//! ([`crate::ir::verify`] / [`crate::sim::verify_graph`], CLI
//! `modtrans check`).

pub mod engine;
pub mod lexer;
pub mod rules;

pub use engine::{lint_source, lint_tree, Finding, LintReport};
pub use rules::{Manifest, Matcher, Rule, Scope};
