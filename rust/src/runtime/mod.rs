//! PJRT runtime: loads AOT-compiled HLO artifacts and executes them.
//!
//! This is the rust end of the three-layer architecture: Python/JAX (+ the
//! Pallas kernel) lowers the compute graphs ONCE at build time
//! (`make artifacts` → `artifacts/*.hlo.txt`, HLO **text** — see
//! DESIGN.md for why not serialized protos), and this module loads,
//! compiles and runs them through the `xla` crate's PJRT CPU client.
//! Python is never on the simulation path.

use crate::error::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

fn xe(e: xla::Error) -> Error {
    Error::Runtime(e.to_string())
}

/// A loaded PJRT client plus the compiled executables by name.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(xe)?;
        Ok(Runtime { client, exes: HashMap::new() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one HLO-text artifact under `name`.
    pub fn load_artifact(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime(format!("non-utf8 path {path:?}")))?,
        )
        .map_err(xe)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xe)?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Load every `*.hlo.txt` in a directory (artifact name = file stem
    /// without the `.hlo` suffix). Returns how many were loaded.
    pub fn load_dir(&mut self, dir: &Path) -> Result<usize> {
        let mut n = 0;
        let entries = std::fs::read_dir(dir)
            .map_err(|e| Error::Runtime(format!("artifacts dir {dir:?}: {e}")))?;
        let mut paths: Vec<PathBuf> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.to_string_lossy().ends_with(".hlo.txt"))
            .collect();
        paths.sort();
        for p in paths {
            let stem = p
                .file_name()
                .unwrap()
                .to_string_lossy()
                .trim_end_matches(".hlo.txt")
                .to_string();
            self.load_artifact(&stem, &p)?;
            n += 1;
        }
        Ok(n)
    }

    /// True if an executable named `name` is loaded.
    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Loaded artifact names (sorted).
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.exes.keys().cloned().collect();
        v.sort();
        v
    }

    /// Execute artifact `name` with f32 inputs `(data, shape)`, returning
    /// the first output (flattened) and the wall-clock execution time.
    ///
    /// Artifacts are lowered with `return_tuple=True`, so the raw result
    /// is a 1-tuple (see `/opt/xla-example/gen_hlo.py`).
    pub fn execute_f32(
        &self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<(Vec<f32>, Duration)> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("artifact '{name}' not loaded")))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let lit = xla::Literal::vec1(data).reshape(shape).map_err(xe)?;
            literals.push(lit);
        }
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(&literals).map_err(xe)?[0][0]
            .to_literal_sync()
            .map_err(xe)?;
        let dt = t0.elapsed();
        let out = result.to_tuple1().map_err(xe)?;
        let values = out.to_vec::<f32>().map_err(xe)?;
        Ok((values, dt))
    }

    /// Execute artifact `name` and return all `expect` tuple outputs as
    /// flattened f32 vectors (e.g. the 5-output MLP train step).
    pub fn execute_f32_tuple(
        &self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
        expect: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("artifact '{name}' not loaded")))?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            literals.push(xla::Literal::vec1(data).reshape(shape).map_err(xe)?);
        }
        let result = exe.execute::<xla::Literal>(&literals).map_err(xe)?[0][0]
            .to_literal_sync()
            .map_err(xe)?;
        let parts = result.to_tuple().map_err(xe)?;
        if parts.len() != expect {
            return Err(Error::Runtime(format!(
                "'{name}': expected {expect} outputs, got {}",
                parts.len()
            )));
        }
        parts.into_iter().map(|l| l.to_vec::<f32>().map_err(xe)).collect()
    }

    /// Execute `name` `reps` times and return the median wall time.
    pub fn time_artifact(
        &self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
        reps: usize,
    ) -> Result<Duration> {
        let mut times = Vec::with_capacity(reps);
        for _ in 0..reps.max(1) {
            let (_, dt) = self.execute_f32(name, inputs)?;
            times.push(dt);
        }
        times.sort();
        Ok(times[times.len() / 2])
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("platform", &self.platform())
            .field("artifacts", &self.names())
            .finish()
    }
}

/// Default artifacts directory (relative to the repo root / cwd).
pub fn default_artifacts_dir() -> PathBuf {
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full PJRT round-trips live in `rust/tests/runtime_integration.rs`
    // (they need `make artifacts`). Here: client + error paths only.

    #[test]
    fn cpu_client_boots() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
        assert!(rt.names().is_empty());
    }

    #[test]
    fn missing_artifact_is_error() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.execute_f32("nope", &[]).is_err());
    }

    #[test]
    fn missing_dir_is_error() {
        let mut rt = Runtime::cpu().unwrap();
        assert!(rt.load_dir(Path::new("/definitely/not/here")).is_err());
    }

    #[test]
    fn bad_hlo_file_is_error() {
        let mut rt = Runtime::cpu().unwrap();
        let dir = std::env::temp_dir();
        let p = dir.join("modtrans_bad.hlo.txt");
        std::fs::write(&p, "this is not hlo").unwrap();
        assert!(rt.load_artifact("bad", &p).is_err());
        let _ = std::fs::remove_file(&p);
    }
}
