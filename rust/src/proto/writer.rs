//! Protobuf wire-format encoder.

use super::WireType;

/// Append-only protobuf message writer.
///
/// Field helpers follow proto3 semantics: default values (0, "", empty
/// bytes) are *omitted* unless written via the `raw_*` methods, matching
/// what real ONNX exporters emit.
#[derive(Debug, Default, Clone)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// Empty writer.
    pub fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    /// Writer with preallocated capacity (hot path for big initializers).
    pub fn with_capacity(cap: usize) -> Writer {
        Writer { buf: Vec::with_capacity(cap) }
    }

    /// Finish, returning the encoded bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Current encoded length.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True if nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Write a raw (untagged) varint.
    pub fn raw_varint(&mut self, mut v: u64) {
        loop {
            let byte = (v & 0x7F) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                return;
            }
            self.buf.push(byte | 0x80);
        }
    }

    /// Write a field tag (field number + wire type).
    pub fn tag(&mut self, field: u32, wt: WireType) {
        self.raw_varint(((field as u64) << 3) | wt as u64);
    }

    /// `uint64`/`int64`/`uint32`/`int32` (non-negative) field. Omits zero.
    pub fn uint64(&mut self, field: u32, v: u64) {
        if v != 0 {
            self.tag(field, WireType::Varint);
            self.raw_varint(v);
        }
    }

    /// `int64` field with two's-complement varint encoding (negative values
    /// take 10 bytes, like real protobuf `int64`). Omits zero.
    pub fn int64(&mut self, field: u32, v: i64) {
        if v != 0 {
            self.tag(field, WireType::Varint);
            self.raw_varint(v as u64);
        }
    }

    /// `sint64` field (zigzag). Omits zero.
    pub fn sint64(&mut self, field: u32, v: i64) {
        if v != 0 {
            self.tag(field, WireType::Varint);
            self.raw_varint(super::zigzag_encode(v));
        }
    }

    /// `bool` field. Omits false.
    pub fn bool(&mut self, field: u32, v: bool) {
        if v {
            self.tag(field, WireType::Varint);
            self.raw_varint(1);
        }
    }

    /// `double` field. Omits +0.0.
    pub fn double(&mut self, field: u32, v: f64) {
        if v != 0.0 || v.is_sign_negative() {
            self.tag(field, WireType::I64);
            self.buf.extend_from_slice(&v.to_le_bits_bytes());
        }
    }

    /// `float` field. Omits +0.0.
    pub fn float(&mut self, field: u32, v: f32) {
        if v != 0.0 || v.is_sign_negative() {
            self.tag(field, WireType::I32);
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// `fixed64` field. Omits zero.
    pub fn fixed64(&mut self, field: u32, v: u64) {
        if v != 0 {
            self.tag(field, WireType::I64);
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Append raw pre-encoded bytes (caller is responsible for validity).
    pub fn extend_raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// `string` field written even when empty (tag + zero length).
    /// ONNX `NodeProto.input` uses empty strings for omitted optional
    /// inputs, where position is significant.
    pub fn string_always(&mut self, field: u32, s: &str) {
        self.tag(field, WireType::Len);
        self.raw_varint(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// `string` field. Omits empty.
    pub fn string(&mut self, field: u32, s: &str) {
        if !s.is_empty() {
            self.tag(field, WireType::Len);
            self.raw_varint(s.len() as u64);
            self.buf.extend_from_slice(s.as_bytes());
        }
    }

    /// `bytes` field. Omits empty.
    pub fn bytes(&mut self, field: u32, b: &[u8]) {
        if !b.is_empty() {
            self.tag(field, WireType::Len);
            self.raw_varint(b.len() as u64);
            self.buf.extend_from_slice(b);
        }
    }

    /// Embedded message field (always written, even when empty, so that
    /// presence is preserved — matches `prost`'s `Option<Message>`).
    pub fn message(&mut self, field: u32, m: &Writer) {
        self.tag(field, WireType::Len);
        self.raw_varint(m.buf.len() as u64);
        self.buf.extend_from_slice(&m.buf);
    }

    /// Packed repeated `int64` field (proto3 default packing).
    pub fn packed_int64(&mut self, field: u32, vs: &[i64]) {
        if vs.is_empty() {
            return;
        }
        let mut inner = Writer::new();
        for &v in vs {
            inner.raw_varint(v as u64);
        }
        self.tag(field, WireType::Len);
        self.raw_varint(inner.buf.len() as u64);
        self.buf.extend_from_slice(&inner.buf);
    }

    /// Packed repeated `float`.
    pub fn packed_float(&mut self, field: u32, vs: &[f32]) {
        if vs.is_empty() {
            return;
        }
        self.tag(field, WireType::Len);
        self.raw_varint((vs.len() * 4) as u64);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Helper so `double` can share the byte-writing shape with `float`.
trait F64Bytes {
    fn to_le_bits_bytes(self) -> [u8; 8];
}
impl F64Bytes for f64 {
    fn to_le_bits_bytes(self) -> [u8; 8] {
        self.to_le_bytes()
    }
}
