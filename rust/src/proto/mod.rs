//! Protocol-buffers wire-format codec.
//!
//! ONNX models are "serialized with protobuf into one single block"
//! (paper §2.3). The offline build has no `prost`/`protobuf` crate, so this
//! module implements the wire format from the specification: varints,
//! zigzag, the four live wire types (VARINT, I64, LEN, I32), field tags,
//! and length-delimited framing. [`crate::onnx`] builds the ONNX message
//! schema on top of these primitives, giving byte-level compatibility with
//! real `.onnx` files.

mod reader;
mod writer;

pub use reader::Reader;
pub use writer::Writer;

use crate::error::{Error, Result};

/// Protobuf wire types (proto3). Groups (3/4) are deprecated and rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireType {
    /// int32/int64/uint32/uint64/sint32/sint64/bool/enum
    Varint = 0,
    /// fixed64/sfixed64/double
    I64 = 1,
    /// string/bytes/embedded messages/packed repeated fields
    Len = 2,
    /// fixed32/sfixed32/float
    I32 = 5,
}

impl WireType {
    /// Decode the low 3 bits of a tag.
    pub fn from_u64(v: u64) -> Result<WireType> {
        match v {
            0 => Ok(WireType::Varint),
            1 => Ok(WireType::I64),
            2 => Ok(WireType::Len),
            5 => Ok(WireType::I32),
            3 | 4 => Err(Error::ProtoDecode("deprecated group wire type".into())),
            w => Err(Error::ProtoDecode(format!("invalid wire type {w}"))),
        }
    }
}

/// ZigZag-encode a signed 64-bit integer (sint64 representation).
pub fn zigzag_encode(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Decode a ZigZag-encoded sint64.
pub fn zigzag_decode(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn zigzag_known_values() {
        // From the protobuf encoding docs.
        assert_eq!(zigzag_encode(0), 0);
        assert_eq!(zigzag_encode(-1), 1);
        assert_eq!(zigzag_encode(1), 2);
        assert_eq!(zigzag_encode(-2), 3);
        assert_eq!(zigzag_encode(i64::MAX), u64::MAX - 1);
        assert_eq!(zigzag_encode(i64::MIN), u64::MAX);
    }

    #[test]
    fn zigzag_roundtrip_random() {
        let mut r = Rng::new(17);
        for _ in 0..10_000 {
            let v = r.next_u64() as i64;
            assert_eq!(zigzag_decode(zigzag_encode(v)), v);
        }
    }

    #[test]
    fn wiretype_decode() {
        assert_eq!(WireType::from_u64(0).unwrap(), WireType::Varint);
        assert_eq!(WireType::from_u64(2).unwrap(), WireType::Len);
        assert!(WireType::from_u64(3).is_err());
        assert!(WireType::from_u64(6).is_err());
    }

    #[test]
    fn varint_roundtrip_property() {
        // Property: for random u64s, write→read is identity and the
        // encoding length matches ceil(bits/7).
        let mut r = Rng::new(99);
        for _ in 0..10_000 {
            let v = r.next_u64() >> r.below(64) as u32;
            let mut w = Writer::new();
            w.raw_varint(v);
            let buf = w.into_bytes();
            let expect_len = if v == 0 { 1 } else { (64 - v.leading_zeros() as usize + 6) / 7 };
            assert_eq!(buf.len(), expect_len, "len mismatch for {v}");
            let mut rd = Reader::new(&buf);
            assert_eq!(rd.raw_varint().unwrap(), v);
            assert!(rd.is_empty());
        }
    }

    #[test]
    fn tagged_fields_roundtrip() {
        let mut w = Writer::new();
        w.uint64(1, 300);
        w.string(2, "hello");
        w.double(3, 2.5);
        w.sint64(4, -7);
        w.float(5, 1.5);
        w.fixed64(6, 0xDEAD_BEEF);
        let buf = w.into_bytes();

        let mut rd = Reader::new(&buf);
        let (f, wt) = rd.tag().unwrap();
        assert_eq!((f, wt), (1, WireType::Varint));
        assert_eq!(rd.raw_varint().unwrap(), 300);
        let (f, wt) = rd.tag().unwrap();
        assert_eq!((f, wt), (2, WireType::Len));
        assert_eq!(rd.bytes().unwrap(), b"hello");
        let (f, _) = rd.tag().unwrap();
        assert_eq!(f, 3);
        assert_eq!(rd.double().unwrap(), 2.5);
        let (f, _) = rd.tag().unwrap();
        assert_eq!(f, 4);
        assert_eq!(zigzag_decode(rd.raw_varint().unwrap()), -7);
        let (f, _) = rd.tag().unwrap();
        assert_eq!(f, 5);
        assert_eq!(rd.float().unwrap(), 1.5);
        let (f, _) = rd.tag().unwrap();
        assert_eq!(f, 6);
        assert_eq!(rd.fixed64().unwrap(), 0xDEAD_BEEF);
        assert!(rd.is_empty());
    }

    #[test]
    fn truncated_input_is_error_not_panic() {
        // Every prefix of a valid message must decode to Err, never panic.
        let mut w = Writer::new();
        w.uint64(1, u64::MAX);
        w.string(2, "some payload here");
        w.double(3, 1.0);
        let buf = w.into_bytes();
        for cut in 0..buf.len() {
            let mut rd = Reader::new(&buf[..cut]);
            // Drain until error or empty; must not panic.
            loop {
                if rd.is_empty() {
                    break;
                }
                match rd.tag().and_then(|(_, wt)| rd.skip(wt)) {
                    Ok(()) => continue,
                    Err(_) => break,
                }
            }
        }
    }

    #[test]
    fn skip_all_wire_types() {
        let mut w = Writer::new();
        w.uint64(1, 1);
        w.double(2, 2.0);
        w.string(3, "abc");
        w.float(4, 4.0);
        w.uint64(5, 55);
        let buf = w.into_bytes();
        let mut rd = Reader::new(&buf);
        // Skip everything except field 5.
        let mut found = None;
        while !rd.is_empty() {
            let (f, wt) = rd.tag().unwrap();
            if f == 5 {
                found = Some(rd.raw_varint().unwrap());
            } else {
                rd.skip(wt).unwrap();
            }
        }
        assert_eq!(found, Some(55));
    }

    #[test]
    fn overlong_varint_rejected() {
        // 11 bytes of continuation: invalid (max is 10).
        let buf = [0xFFu8; 11];
        let mut rd = Reader::new(&buf);
        assert!(rd.raw_varint().is_err());
    }

    #[test]
    fn nested_message_framing() {
        let mut inner = Writer::new();
        inner.string(1, "inner-name");
        inner.uint64(2, 42);
        let mut outer = Writer::new();
        outer.message(7, &inner);
        let buf = outer.into_bytes();

        let mut rd = Reader::new(&buf);
        let (f, wt) = rd.tag().unwrap();
        assert_eq!((f, wt), (7, WireType::Len));
        let sub = rd.bytes().unwrap();
        let mut rd2 = Reader::new(sub);
        let (f, _) = rd2.tag().unwrap();
        assert_eq!(f, 1);
        assert_eq!(rd2.str().unwrap(), "inner-name");
        let (f, _) = rd2.tag().unwrap();
        assert_eq!(f, 2);
        assert_eq!(rd2.raw_varint().unwrap(), 42);
    }
}
