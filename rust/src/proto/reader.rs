//! Protobuf wire-format decoder.

use super::WireType;
use crate::error::{Error, Result};

/// Zero-copy protobuf reader over a byte slice.
///
/// All methods return `Err` (never panic) on truncated or malformed input —
/// the translator consumes untrusted `.onnx` files.
#[derive(Debug, Clone)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Wrap a byte slice.
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    /// True when all bytes are consumed.
    pub fn is_empty(&self) -> bool {
        self.pos >= self.buf.len()
    }

    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn need(&self, n: usize) -> Result<()> {
        if self.remaining() < n {
            Err(Error::ProtoDecode(format!(
                "truncated input: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            )))
        } else {
            Ok(())
        }
    }

    /// Read a raw varint (up to 10 bytes).
    pub fn raw_varint(&mut self) -> Result<u64> {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            self.need(1)?;
            let b = self.buf[self.pos];
            self.pos += 1;
            if shift == 63 && b > 1 {
                return Err(Error::ProtoDecode("varint overflows u64".into()));
            }
            v |= ((b & 0x7F) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift > 63 {
                return Err(Error::ProtoDecode("varint longer than 10 bytes".into()));
            }
        }
    }

    /// Read a field tag; returns (field number, wire type).
    pub fn tag(&mut self) -> Result<(u32, WireType)> {
        let t = self.raw_varint()?;
        let field = (t >> 3) as u32;
        if field == 0 {
            return Err(Error::ProtoDecode("field number 0 is invalid".into()));
        }
        Ok((field, WireType::from_u64(t & 0x7)?))
    }

    /// Read a length-delimited payload as a subslice (zero copy).
    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let len = self.raw_varint()? as usize;
        self.need(len)?;
        let s = &self.buf[self.pos..self.pos + len];
        self.pos += len;
        Ok(s)
    }

    /// Read a length-delimited payload as UTF-8.
    pub fn str(&mut self) -> Result<&'a str> {
        std::str::from_utf8(self.bytes()?)
            .map_err(|e| Error::ProtoDecode(format!("invalid utf-8 in string field: {e}")))
    }

    /// Read a little-endian fixed64.
    pub fn fixed64(&mut self) -> Result<u64> {
        self.need(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(&self.buf[self.pos..self.pos + 8]);
        self.pos += 8;
        Ok(u64::from_le_bytes(b))
    }

    /// Read a little-endian fixed32.
    pub fn fixed32(&mut self) -> Result<u32> {
        self.need(4)?;
        let mut b = [0u8; 4];
        b.copy_from_slice(&self.buf[self.pos..self.pos + 4]);
        self.pos += 4;
        Ok(u32::from_le_bytes(b))
    }

    /// Read a `double`.
    pub fn double(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.fixed64()?))
    }

    /// Read a `float`.
    pub fn float(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.fixed32()?))
    }

    /// Read an `int64` varint (two's complement).
    pub fn int64(&mut self) -> Result<i64> {
        Ok(self.raw_varint()? as i64)
    }

    /// Skip a field of the given wire type (unknown-field tolerance —
    /// required to parse `.onnx` files produced by newer exporters).
    pub fn skip(&mut self, wt: WireType) -> Result<()> {
        match wt {
            WireType::Varint => {
                self.raw_varint()?;
            }
            WireType::I64 => {
                self.need(8)?;
                self.pos += 8;
            }
            WireType::Len => {
                let len = self.raw_varint()? as usize;
                self.need(len)?;
                self.pos += len;
            }
            WireType::I32 => {
                self.need(4)?;
                self.pos += 4;
            }
        }
        Ok(())
    }

    /// Decode a packed (or single unpacked) repeated int64 field body.
    pub fn packed_int64(&mut self) -> Result<Vec<i64>> {
        let body = self.bytes()?;
        let mut rd = Reader::new(body);
        let mut out = Vec::new();
        while !rd.is_empty() {
            out.push(rd.raw_varint()? as i64);
        }
        Ok(out)
    }
}
