//! Model zoo: ONNX graph builders for the classic models the paper's
//! evaluation uses (ResNet-50, VGG-16, VGG-19) plus the rest of the
//! families a simulator user reaches for (`modtrans zoo list`).
//!
//! The paper's ModTrans "can also get classic models from the model zoo
//! ... by only giving the model name" (§3.2). With no network in this
//! environment, the zoo *generates* the models instead of downloading
//! them: each builder reproduces the exact initializer shapes (and hence
//! the exact layer-size tables) of the corresponding ONNX Model Zoo
//! export — see DESIGN.md §Substitutions.
//!
//! Builders return in-memory [`crate::onnx::Model`]s, which feed the
//! zoo-direct IR frontend ([`crate::ir::frontend::from_zoo`]) without an
//! ONNX encode/decode round-trip; `encode_model` remains available when
//! real `.onnx` bytes are wanted (`modtrans zoo build`).

pub mod alexnet;
pub mod builder;
pub mod mlp;
pub mod resnet;
pub mod transformer;
pub mod vgg;

pub use builder::{GraphBuilder, WeightFill, ZooOpts};
pub use transformer::TransformerCfg;

use crate::error::{Error, Result};
use crate::onnx::Model;

/// All model names `get` accepts.
pub const MODELS: [&str; 11] = [
    "resnet18",
    "resnet34",
    "resnet50",
    "vgg11",
    "vgg13",
    "vgg16",
    "vgg19",
    "alexnet",
    "mlp",
    "gpt2-small",
    "gpt2-tiny",
];

/// Build a zoo model by name.
pub fn get(name: &str, opts: ZooOpts) -> Result<Model> {
    match name {
        "resnet18" => Ok(resnet::build(18, opts)),
        "resnet34" => Ok(resnet::build(34, opts)),
        "resnet50" => Ok(resnet::build(50, opts)),
        "vgg11" => Ok(vgg::build(11, opts)),
        "vgg13" => Ok(vgg::build(13, opts)),
        "vgg16" => Ok(vgg::build(16, opts)),
        "vgg19" => Ok(vgg::build(19, opts)),
        "alexnet" => Ok(alexnet::build(opts)),
        "mlp" => Ok(mlp::build_default(opts)),
        "gpt2-small" => Ok(transformer::build(TransformerCfg::gpt2_small(), opts)),
        "gpt2-tiny" => Ok(transformer::build(TransformerCfg::tiny(), opts)),
        other => Err(Error::UnknownModel(other.to_string())),
    }
}

/// One-line description per model, for `modtrans zoo list`.
pub fn describe(name: &str) -> &'static str {
    match name {
        "resnet18" => "ResNet-18 (He et al. 2016), basic blocks, 11.7M params",
        "resnet34" => "ResNet-34, basic blocks, 21.8M params",
        "resnet50" => "ResNet-50, bottleneck blocks, 25.6M params (paper Table 3)",
        "vgg11" => "VGG-11 (config A), 132.9M params",
        "vgg13" => "VGG-13 (config B), 133.0M params",
        "vgg16" => "VGG-16 (config D), 138.4M params (paper Table 1)",
        "vgg19" => "VGG-19 (config E), 143.7M params (paper Table 2)",
        "alexnet" => "AlexNet (single tower), 61.1M params",
        "mlp" => "MLP 784-4096-4096-1024-10, 24.3M params",
        "gpt2-small" => "GPT-2 small decoder, 12L/768d/12h, ~163M params (untied head)",
        "gpt2-tiny" => "Tiny GPT-2-style decoder, 4L/256d/8h, ~7M params",
        _ => "",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::{encode_model, parse_model_meta};

    #[test]
    fn every_model_builds_encodes_and_reparses() {
        for name in MODELS {
            let m = get(name, ZooOpts { weights: WeightFill::Empty }).unwrap();
            assert!(!m.graph.initializers.is_empty(), "{name}: no weights");
            let bytes = encode_model(&m);
            let m2 = parse_model_meta(&bytes).unwrap();
            assert_eq!(
                m2.graph.initializers.len(),
                m.graph.initializers.len(),
                "{name}: initializer count changed over the wire"
            );
            assert_eq!(m2.num_parameters(), m.num_parameters(), "{name}");
        }
    }

    #[test]
    fn unknown_model_is_error() {
        assert!(matches!(get("resnet999", ZooOpts::default()), Err(Error::UnknownModel(_))));
    }

    #[test]
    fn describe_covers_all_models() {
        for name in MODELS {
            assert!(!describe(name).is_empty(), "{name} missing description");
        }
    }
}
