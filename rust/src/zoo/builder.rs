//! Fluent ONNX graph builder used by every zoo model.
//!
//! Handles edge naming, initializer registration with a configurable
//! weight-fill policy, and the input/output signature. Builders produce
//! graphs that pass [`crate::onnx::infer_shapes`], so translation can size
//! every activation.

use crate::onnx::{
    Attribute, AttributeValue, DataType, Dim, Graph, Model, Node, Tensor, TensorType, ValueInfo,
};
use crate::util::rng::Rng;

/// How initializer payloads are materialized.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightFill {
    /// Zero bytes of the exact on-disk size (fast; default for benches —
    /// deserialization cost only depends on length).
    Zeros,
    /// Deterministic pseudo-random bytes from the given seed.
    Random(u64),
    /// No payload at all (structure-only models; smallest files).
    Empty,
}

/// Zoo build options.
#[derive(Debug, Clone, Copy)]
pub struct ZooOpts {
    /// Initializer payload policy.
    pub weights: WeightFill,
}

impl Default for ZooOpts {
    fn default() -> Self {
        ZooOpts { weights: WeightFill::Zeros }
    }
}

/// Incremental graph builder.
pub struct GraphBuilder {
    graph: Graph,
    fill: WeightFill,
    rng: Rng,
    next_edge: usize,
}

impl GraphBuilder {
    /// Start a graph named `name` with the given weight policy.
    pub fn new(name: &str, opts: ZooOpts) -> GraphBuilder {
        let seed = match opts.weights {
            WeightFill::Random(s) => s,
            _ => 0,
        };
        GraphBuilder {
            graph: Graph { name: name.into(), ..Default::default() },
            fill: opts.weights,
            rng: Rng::new(seed),
            next_edge: 0,
        }
    }

    /// Allocate a fresh intermediate edge name.
    pub fn edge(&mut self) -> String {
        let e = format!("t{}", self.next_edge);
        self.next_edge += 1;
        e
    }

    /// Declare a float graph input with a symbolic leading batch dim.
    pub fn input(&mut self, name: &str, dims_after_batch: &[i64]) -> String {
        self.input_typed(name, dims_after_batch, DataType::Float)
    }

    /// Declare a typed graph input with a symbolic leading batch dim.
    pub fn input_typed(&mut self, name: &str, dims_after_batch: &[i64], dt: DataType) -> String {
        let mut shape = vec![Dim::Param("N".into())];
        shape.extend(dims_after_batch.iter().map(|&d| Dim::Value(d)));
        self.graph.inputs.push(ValueInfo {
            name: name.into(),
            ty: Some(TensorType { elem_type: dt, shape }),
        });
        name.to_string()
    }

    /// Declare a graph output.
    pub fn output(&mut self, edge: &str) {
        self.graph.outputs.push(ValueInfo { name: edge.into(), ty: None });
    }

    fn payload(&mut self, bytes: usize) -> Vec<u8> {
        match self.fill {
            WeightFill::Zeros => vec![0u8; bytes],
            WeightFill::Empty => Vec::new(),
            WeightFill::Random(_) => {
                let mut v = vec![0u8; bytes];
                // Fill 8 bytes at a time; fast enough for half-GiB models.
                let mut chunks = v.chunks_exact_mut(8);
                for c in &mut chunks {
                    c.copy_from_slice(&self.rng.next_u64().to_le_bytes());
                }
                let rem = chunks.into_remainder();
                if !rem.is_empty() {
                    let b = self.rng.next_u64().to_le_bytes();
                    rem.copy_from_slice(&b[..rem.len()]);
                }
                v
            }
        }
    }

    /// Register a float initializer (weight/bias/BN param) named `name`.
    pub fn weight(&mut self, name: &str, dims: &[i64]) -> String {
        let n: i64 = dims.iter().product();
        let raw = self.payload(n as usize * 4);
        let payload_len = raw.len() as u64;
        self.graph.initializers.push(Tensor {
            dims: dims.to_vec(),
            data_type: DataType::Float,
            name: name.into(),
            raw_data: raw,
            payload_len,
        });
        name.to_string()
    }

    /// Register an int64 constant initializer (e.g. Reshape shapes).
    pub fn const_i64(&mut self, name: &str, values: &[i64]) -> String {
        let mut raw = Vec::with_capacity(values.len() * 8);
        for v in values {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        let payload_len = raw.len() as u64;
        self.graph.initializers.push(Tensor {
            dims: vec![values.len() as i64],
            data_type: DataType::Int64,
            name: name.into(),
            raw_data: raw,
            payload_len,
        });
        name.to_string()
    }

    /// Append a node; returns its first output edge.
    pub fn node(
        &mut self,
        op: &str,
        name: &str,
        inputs: &[&str],
        attrs: Vec<Attribute>,
    ) -> String {
        let out = self.edge();
        self.graph.nodes.push(Node {
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            outputs: vec![out.clone()],
            name: name.into(),
            op_type: op.into(),
            domain: String::new(),
            attributes: attrs,
        });
        out
    }

    /// 2-D convolution. Weight is `{prefix}-weight` with dims
    /// `[cout, cin/group, k, k]`; optional `{prefix}-bias`.
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        &mut self,
        prefix: &str,
        x: &str,
        cin: i64,
        cout: i64,
        k: i64,
        stride: i64,
        pad: i64,
        bias: bool,
    ) -> String {
        let w = self.weight(&format!("{prefix}-weight"), &[cout, cin, k, k]);
        let attrs = vec![
            ints_attr("kernel_shape", &[k, k]),
            ints_attr("strides", &[stride, stride]),
            ints_attr("pads", &[pad, pad, pad, pad]),
        ];
        if bias {
            let b = self.weight(&format!("{prefix}-bias"), &[cout]);
            self.node("Conv", prefix, &[x, &w, &b], attrs)
        } else {
            self.node("Conv", prefix, &[x, &w], attrs)
        }
    }

    /// BatchNormalization with `{prefix}-{gamma,beta,mean,var}` params.
    pub fn batchnorm(&mut self, prefix: &str, x: &str, c: i64) -> String {
        let g = self.weight(&format!("{prefix}-gamma"), &[c]);
        let b = self.weight(&format!("{prefix}-beta"), &[c]);
        let m = self.weight(&format!("{prefix}-mean"), &[c]);
        let v = self.weight(&format!("{prefix}-var"), &[c]);
        self.node("BatchNormalization", prefix, &[x, &g, &b, &m, &v], vec![])
    }

    /// ReLU.
    pub fn relu(&mut self, x: &str) -> String {
        let name = format!("relu_{}", self.next_edge);
        self.node("Relu", &name, &[x], vec![])
    }

    /// Max pooling.
    pub fn maxpool(&mut self, x: &str, k: i64, stride: i64, pad: i64) -> String {
        let name = format!("pool_{}", self.next_edge);
        self.node(
            "MaxPool",
            &name,
            &[x],
            vec![
                ints_attr("kernel_shape", &[k, k]),
                ints_attr("strides", &[stride, stride]),
                ints_attr("pads", &[pad, pad, pad, pad]),
            ],
        )
    }

    /// Global average pooling.
    pub fn global_avg_pool(&mut self, x: &str) -> String {
        let name = format!("gap_{}", self.next_edge);
        self.node("GlobalAveragePool", &name, &[x], vec![])
    }

    /// Flatten from axis 1.
    pub fn flatten(&mut self, x: &str) -> String {
        let name = format!("flatten_{}", self.next_edge);
        self.node("Flatten", &name, &[x], vec![])
    }

    /// Fully connected layer via Gemm with `transB=1`; weight dims
    /// `[out_features, in_features]` (torch convention, which produces the
    /// paper's dense layer sizes).
    pub fn dense(&mut self, prefix: &str, x: &str, in_f: i64, out_f: i64, bias: bool) -> String {
        let w = self.weight(&format!("{prefix}-weight"), &[out_f, in_f]);
        let attrs = vec![int_attr("transB", 1)];
        if bias {
            let b = self.weight(&format!("{prefix}-bias"), &[out_f]);
            self.node("Gemm", prefix, &[x, &w, &b], attrs)
        } else {
            self.node("Gemm", prefix, &[x, &w], attrs)
        }
    }

    /// Elementwise add of two edges.
    pub fn add(&mut self, a: &str, b: &str) -> String {
        let name = format!("add_{}", self.next_edge);
        self.node("Add", &name, &[a, b], vec![])
    }

    /// Softmax along the last axis.
    pub fn softmax(&mut self, x: &str) -> String {
        let name = format!("softmax_{}", self.next_edge);
        self.node("Softmax", &name, &[x], vec![int_attr("axis", -1)])
    }

    /// Local response normalization (AlexNet).
    pub fn lrn(&mut self, x: &str) -> String {
        let name = format!("lrn_{}", self.next_edge);
        self.node("LRN", &name, &[x], vec![int_attr("size", 5)])
    }

    /// MatMul.
    pub fn matmul(&mut self, a: &str, b: &str) -> String {
        let name = format!("matmul_{}", self.next_edge);
        self.node("MatMul", &name, &[a, b], vec![])
    }

    /// Reshape via an int64 constant initializer.
    pub fn reshape(&mut self, x: &str, target: &[i64]) -> String {
        let cname = format!("shape_{}", self.next_edge);
        let c = self.const_i64(&cname, target);
        let name = format!("reshape_{}", self.next_edge);
        self.node("Reshape", &name, &[x, &c], vec![])
    }

    /// Transpose with explicit permutation.
    pub fn transpose(&mut self, x: &str, perm: &[i64]) -> String {
        let name = format!("transpose_{}", self.next_edge);
        self.node("Transpose", &name, &[x], vec![ints_attr("perm", perm)])
    }

    /// LayerNormalization with `{prefix}-{gamma,beta}` over `d` features.
    pub fn layernorm(&mut self, prefix: &str, x: &str, d: i64) -> String {
        let g = self.weight(&format!("{prefix}-gamma"), &[d]);
        let b = self.weight(&format!("{prefix}-beta"), &[d]);
        self.node("LayerNormalization", prefix, &[x, &g, &b], vec![int_attr("axis", -1)])
    }

    /// GELU activation.
    pub fn gelu(&mut self, x: &str) -> String {
        let name = format!("gelu_{}", self.next_edge);
        self.node("Gelu", &name, &[x], vec![])
    }

    /// Gather (axis-0 embedding lookup).
    pub fn gather(&mut self, table: &str, indices: &str) -> String {
        let name = format!("gather_{}", self.next_edge);
        self.node("Gather", &name, &[table, indices], vec![int_attr("axis", 0)])
    }

    /// Finish: wrap into a [`Model`] with standard zoo metadata.
    pub fn finish(self, output_edge: Option<&str>) -> Model {
        let mut graph = self.graph;
        if let Some(e) = output_edge {
            graph.outputs.push(ValueInfo { name: e.into(), ty: None });
        }
        Model::wrap(graph)
    }
}

/// Build an INTS attribute.
pub fn ints_attr(name: &str, vals: &[i64]) -> Attribute {
    Attribute { name: name.into(), value: AttributeValue::Ints(vals.to_vec()) }
}

/// Build an INT attribute.
pub fn int_attr(name: &str, val: i64) -> Attribute {
    Attribute { name: name.into(), value: AttributeValue::Int(val) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::infer_shapes;

    #[test]
    fn tiny_cnn_builds_and_infers() {
        let mut b = GraphBuilder::new("tiny", ZooOpts::default());
        let x = b.input("data", &[3, 32, 32]);
        let c = b.conv("conv0", &x, 3, 8, 3, 1, 1, true);
        let r = b.relu(&c);
        let p = b.maxpool(&r, 2, 2, 0);
        let g = b.global_avg_pool(&p);
        let f = b.flatten(&g);
        let d = b.dense("fc", &f, 8, 10, true);
        let s = b.softmax(&d);
        let m = b.finish(Some(&s));
        assert_eq!(m.graph.initializers.len(), 4); // w, b, fc-w, fc-b
        let shapes = infer_shapes(&m.graph, 2).unwrap();
        assert_eq!(shapes[&s].1, vec![2, 10]);
        // conv0 output 8x32x32
        let conv_out = &m.graph.nodes[0].outputs[0];
        assert_eq!(shapes[conv_out].1, vec![2, 8, 32, 32]);
    }

    #[test]
    fn weight_fill_policies() {
        for (fill, expect_len) in [
            (WeightFill::Zeros, 40usize),
            (WeightFill::Random(1), 40),
            (WeightFill::Empty, 0),
        ] {
            let mut b = GraphBuilder::new("t", ZooOpts { weights: fill });
            b.weight("w", &[10]);
            let m = b.finish(None);
            assert_eq!(m.graph.initializers[0].raw_data.len(), expect_len);
        }
    }

    #[test]
    fn random_fill_is_deterministic() {
        let build = || {
            let mut b = GraphBuilder::new("t", ZooOpts { weights: WeightFill::Random(7) });
            b.weight("w", &[100]);
            b.finish(None).graph.initializers[0].raw_data.clone()
        };
        assert_eq!(build(), build());
    }
}
