//! GPT-style decoder transformer builder.
//!
//! Exercises the translator on the "giant model" workloads the paper's
//! introduction motivates (PaLM/Megatron-LM), and provides the ~100M-class
//! model used by the end-to-end example. Pre-LN blocks:
//! `x + Attn(LN(x))`, `x + MLP(LN(x))`, with learned token + position
//! embeddings and a tied-shape (but separate) LM head.

use super::builder::{GraphBuilder, ZooOpts};
use crate::onnx::{DataType, Model};

/// Transformer hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct TransformerCfg {
    /// Number of decoder blocks.
    pub layers: i64,
    /// Model width.
    pub d_model: i64,
    /// Attention heads (must divide `d_model`).
    pub heads: i64,
    /// Sequence length baked into the graph.
    pub seq_len: i64,
    /// Vocabulary size.
    pub vocab: i64,
}

impl TransformerCfg {
    /// GPT-2 small (124M parameters).
    pub fn gpt2_small() -> TransformerCfg {
        TransformerCfg { layers: 12, d_model: 768, heads: 12, seq_len: 1024, vocab: 50257 }
    }

    /// A ~10M-parameter config for fast tests.
    pub fn tiny() -> TransformerCfg {
        TransformerCfg { layers: 4, d_model: 256, heads: 8, seq_len: 128, vocab: 8192 }
    }

    /// Closed-form parameter count (embeddings + blocks + final LN + head).
    pub fn param_count(&self) -> u64 {
        let d = self.d_model as u64;
        let v = self.vocab as u64;
        let t = self.seq_len as u64;
        let l = self.layers as u64;
        let block = (3 * d * d + 3 * d)       // qkv
            + (d * d + d)                     // attn out proj
            + (4 * d * d + 4 * d)             // mlp up
            + (4 * d * d + d)                 // mlp down
            + 4 * d; // two layernorms
        v * d + t * d + l * block + 2 * d + v * d
    }
}

/// Build the transformer ONNX graph.
pub fn build(cfg: TransformerCfg, opts: ZooOpts) -> Model {
    let d = cfg.d_model;
    let h = cfg.heads;
    let dh = d / h;
    assert_eq!(dh * h, d, "heads must divide d_model");
    let t_len = cfg.seq_len;

    let mut b = GraphBuilder::new("transformer", opts);
    // Token ids: [N, T] int64.
    let ids = b.input_typed("input_ids", &[t_len], DataType::Int64);

    // Embeddings.
    let wte = b.weight("transformer-wte-weight", &[cfg.vocab, d]);
    let wpe = b.weight("transformer-wpe-weight", &[t_len, d]);
    let tok = b.gather(&wte, &ids); // [N, T, d]
    let mut x = b.add(&tok, &wpe); // broadcast [T, d]

    for l in 0..cfg.layers {
        let p = |s: &str| format!("transformer-block{l}-{s}");

        // ---- attention ----
        let ln1 = b.layernorm(&p("ln1"), &x, d);
        let wqkv = b.weight(&p("attn-qkv-weight"), &[d, 3 * d]);
        let bqkv = b.weight(&p("attn-qkv-bias"), &[3 * d]);
        let qkv = b.matmul(&ln1, &wqkv);
        let qkv = b.add(&qkv, &bqkv); // [N, T, 3d]
        // Split into q/k/v via Reshape + Transpose: [N, T, 3, h, dh]
        let r = b.reshape(&qkv, &[0, 0, 3, h, dh]);
        let perm = b.transpose(&r, &[2, 0, 3, 1, 4]); // [3, N, h, T, dh]
        // Select q, k, v with Gather over axis 0 using constant indices is
        // unsupported; instead slice via three Reshape-free Gathers is
        // avoided — model q/k/v as three separate projections is closer to
        // real exports anyway, but we keep the fused qkv weight for the
        // parameter count and attach the attention math to q-like tensors.
        let _ = perm;
        // Three logical views of the fused projection: use the fused tensor
        // reshaped per head for the attention score math.
        let qh = b.reshape(&qkv, &[0, 0, 3 * h, dh]); // [N, T, 3h, dh]
        let qh = b.transpose(&qh, &[0, 2, 1, 3]); // [N, 3h, T, dh]
        let kt = b.transpose(&qh, &[0, 1, 3, 2]); // [N, 3h, dh, T]
        let scores = b.matmul(&qh, &kt); // [N, 3h, T, T]
        let scale = b.weight(&p("attn-scale"), &[1]);
        let scaled = b.node("Mul", &p("attn-scale-mul"), &[&scores, &scale], vec![]);
        let probs = b.softmax(&scaled);
        let ctx = b.matmul(&probs, &qh); // [N, 3h, T, dh]
        let ctx = b.transpose(&ctx, &[0, 2, 1, 3]); // [N, T, 3h, dh]
        let ctx = b.reshape(&ctx, &[0, 0, 3 * d]);
        // Project back to d: fold the 3x width into the output projection
        // input (keeps MAC count equal to standard MHA + proj).
        let wo = b.weight(&p("attn-out-weight"), &[d, d]);
        let bo = b.weight(&p("attn-out-bias"), &[d]);
        let ctx_d = b.reshape(&ctx, &[0, 0, 3, d]);
        let ctx_d = b.node("ReduceMean", &p("attn-merge"), &[&ctx_d], vec![
            super::builder::ints_attr("axes", &[2]),
            super::builder::int_attr("keepdims", 0),
        ]); // [N, T, d]
        let attn = b.matmul(&ctx_d, &wo);
        let attn = b.add(&attn, &bo);
        x = b.add(&x, &attn);

        // ---- mlp ----
        let ln2 = b.layernorm(&p("ln2"), &x, d);
        let w1 = b.weight(&p("mlp-up-weight"), &[d, 4 * d]);
        let b1 = b.weight(&p("mlp-up-bias"), &[4 * d]);
        let up = b.matmul(&ln2, &w1);
        let up = b.add(&up, &b1);
        let act = b.gelu(&up);
        let w2 = b.weight(&p("mlp-down-weight"), &[4 * d, d]);
        let b2 = b.weight(&p("mlp-down-bias"), &[d]);
        let down = b.matmul(&act, &w2);
        let down = b.add(&down, &b2);
        x = b.add(&x, &down);
    }

    let lnf = b.layernorm("transformer-lnf", &x, d);
    let head = b.weight("transformer-head-weight", &[d, cfg.vocab]);
    let logits = b.matmul(&lnf, &head);
    let out = b.softmax(&logits);
    b.finish(Some(&out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::infer_shapes;
    use crate::zoo::builder::WeightFill;

    #[test]
    fn tiny_transformer_builds_and_infers() {
        let cfg = TransformerCfg::tiny();
        let m = build(cfg, ZooOpts { weights: WeightFill::Empty });
        let shapes = infer_shapes(&m.graph, 2).unwrap();
        let out = &m.graph.outputs[0].name;
        assert_eq!(shapes[out].1, vec![2, cfg.seq_len, cfg.vocab]);
    }

    #[test]
    fn gpt2_small_param_count_formula() {
        let cfg = TransformerCfg::gpt2_small();
        let m = build(cfg, ZooOpts { weights: WeightFill::Empty });
        // Builder carries an extra [1] scale tensor plus 16 int64 shape
        // constants (4 Reshape nodes) per block; num_parameters counts all
        // initializer elements.
        let formula = cfg.param_count() + cfg.layers as u64 * (1 + 16);
        assert_eq!(m.num_parameters(), formula);
        // GPT-2 small scale: ~163M with untied head (124M tied).
        assert!(m.num_parameters() > 160_000_000 && m.num_parameters() < 170_000_000);
    }

    #[test]
    #[should_panic(expected = "heads must divide")]
    fn bad_heads_panics() {
        let cfg = TransformerCfg { layers: 1, d_model: 10, heads: 3, seq_len: 8, vocab: 100 };
        build(cfg, ZooOpts::default());
    }
}
