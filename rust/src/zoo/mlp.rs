//! Configurable MLP builder — the simplest zoo member; also the shape the
//! paper's Listing 1 linear-regression example generalizes to.

use super::builder::{GraphBuilder, ZooOpts};
use crate::onnx::Model;

/// Build an MLP with the given layer widths; `widths[0]` is the input
/// feature count, the rest are hidden/output widths. ReLU between layers,
/// Softmax at the end.
pub fn build(widths: &[i64], opts: ZooOpts) -> Model {
    assert!(widths.len() >= 2, "mlp needs at least input and output widths");
    let mut b = GraphBuilder::new("mlp", opts);
    let mut t = b.input("data", &[widths[0]]);
    for (i, w) in widths.windows(2).enumerate() {
        t = b.dense(&format!("mlp-dense{i}"), &t, w[0], w[1], true);
        if i + 2 < widths.len() {
            t = b.relu(&t);
        }
    }
    let out = b.softmax(&t);
    b.finish(Some(&out))
}

/// Default configuration: 784-4096-4096-1024-10 (MNIST-scale benchmark).
pub fn build_default(opts: ZooOpts) -> Model {
    build(&[784, 4096, 4096, 1024, 10], opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::infer_shapes;
    use crate::zoo::builder::WeightFill;

    #[test]
    fn mlp_params() {
        let m = build(&[10, 20, 5], ZooOpts { weights: WeightFill::Empty });
        // 10*20+20 + 20*5+5 = 220 + 105 = 325
        assert_eq!(m.num_parameters(), 325);
    }

    #[test]
    fn mlp_shapes() {
        let m = build_default(ZooOpts { weights: WeightFill::Empty });
        let s = infer_shapes(&m.graph, 64).unwrap();
        assert_eq!(s[&m.graph.outputs[0].name].1, vec![64, 10]);
    }

    #[test]
    #[should_panic]
    fn mlp_too_few_widths_panics() {
        build(&[10], ZooOpts::default());
    }
}
