//! AlexNet builder (torchvision single-tower variant).

use super::builder::{GraphBuilder, ZooOpts};
use crate::onnx::Model;

/// Build AlexNet: 5 convs + 3 dense layers, 224x224 input.
pub fn build(opts: ZooOpts) -> Model {
    let mut b = GraphBuilder::new("alexnet", opts);
    let x = b.input("data", &[3, 224, 224]);

    // conv0: 64 x 11x11 / 4, pad 2 → 64x55x55
    let mut t = b.conv("alexnet-conv0", &x, 3, 64, 11, 4, 2, true);
    t = b.relu(&t);
    t = b.lrn(&t);
    t = b.maxpool(&t, 3, 2, 0); // 64x27x27
    // conv1: 192 x 5x5, pad 2
    t = b.conv("alexnet-conv1", &t, 64, 192, 5, 1, 2, true);
    t = b.relu(&t);
    t = b.lrn(&t);
    t = b.maxpool(&t, 3, 2, 0); // 192x13x13
    // conv2-4: 3x3 pad 1
    t = b.conv("alexnet-conv2", &t, 192, 384, 3, 1, 1, true);
    t = b.relu(&t);
    t = b.conv("alexnet-conv3", &t, 384, 256, 3, 1, 1, true);
    t = b.relu(&t);
    t = b.conv("alexnet-conv4", &t, 256, 256, 3, 1, 1, true);
    t = b.relu(&t);
    t = b.maxpool(&t, 3, 2, 0); // 256x6x6

    t = b.flatten(&t);
    t = b.dense("alexnet-dense0", &t, 256 * 6 * 6, 4096, true);
    t = b.relu(&t);
    t = b.dense("alexnet-dense1", &t, 4096, 4096, true);
    t = b.relu(&t);
    t = b.dense("alexnet-dense2", &t, 4096, 1000, true);
    let out = b.softmax(&t);
    b.finish(Some(&out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::infer_shapes;
    use crate::zoo::builder::WeightFill;

    #[test]
    fn alexnet_param_count() {
        let m = build(ZooOpts { weights: WeightFill::Empty });
        // torchvision alexnet: 61,100,840 parameters.
        assert_eq!(m.num_parameters(), 61_100_840);
    }

    #[test]
    fn alexnet_shapes() {
        let m = build(ZooOpts { weights: WeightFill::Empty });
        let shapes = infer_shapes(&m.graph, 8).unwrap();
        assert_eq!(shapes[&m.graph.outputs[0].name].1, vec![8, 1000]);
    }
}
