//! ResNet-18/34/50 builders (He et al., 2016).
//!
//! ResNet-50 follows the layer naming and initializer ordering of the
//! paper's Table 3 (`resnet-conv0`, `resnet-stage{S}-conv{K}`,
//! `resnet-dense0`), which itself mirrors the ASTRA-sim repository's
//! ResNet-50 example workload: within each stage the first bottleneck
//! block contributes convs `0,1,2`, then the projection shortcut is conv
//! `3`, then the remaining blocks contribute three convs each. Conv layers
//! have no biases (BatchNorm follows each), matching the model-zoo export.

use super::builder::{GraphBuilder, ZooOpts};
use crate::onnx::Model;

/// Build `resnet{depth}` for depth ∈ {18, 34, 50}.
pub fn build(depth: usize, opts: ZooOpts) -> Model {
    match depth {
        50 => build_bottleneck(opts),
        18 => build_basic(&[2, 2, 2, 2], "resnet18", opts),
        34 => build_basic(&[3, 4, 6, 3], "resnet34", opts),
        // lint: allow(no-panic) — closed depth table; zoo::get validates the name first
        _ => panic!("unsupported ResNet depth {depth}"),
    }
}

/// ResNet-50: bottleneck blocks, stage plan [3, 4, 6, 3].
fn build_bottleneck(opts: ZooOpts) -> Model {
    let mut b = GraphBuilder::new("resnet50", opts);
    let x = b.input("data", &[3, 224, 224]);

    // Stem: 7x7/2 conv (no bias) + BN + ReLU + 3x3/2 maxpool.
    let mut t = b.conv("resnet-conv0", &x, 3, 64, 7, 2, 3, false);
    t = b.batchnorm("resnet-bn0", &t, 64);
    t = b.relu(&t);
    t = b.maxpool(&t, 3, 2, 1);

    let blocks = [3usize, 4, 6, 3];
    let mids = [64i64, 128, 256, 512];
    let mut cin = 64i64;
    for (s, (&nblocks, &mid)) in blocks.iter().zip(mids.iter()).enumerate() {
        let stage = s + 1;
        let cout = mid * 4;
        let stride = if stage == 1 { 1 } else { 2 };
        let mut conv_idx = 0usize;
        for block in 0..nblocks {
            let block_stride = if block == 0 { stride } else { 1 };
            let identity = t.clone();
            // Bottleneck: 1x1 reduce → 3x3 → 1x1 expand.
            let p = |k: usize| format!("resnet-stage{stage}-conv{k}");
            let mut y = b.conv(&p(conv_idx), &t, cin, mid, 1, 1, 0, false);
            y = b.batchnorm(&format!("resnet-stage{stage}-bn{conv_idx}"), &y, mid);
            y = b.relu(&y);
            conv_idx += 1;
            y = b.conv(&p(conv_idx), &y, mid, mid, 3, block_stride, 1, false);
            y = b.batchnorm(&format!("resnet-stage{stage}-bn{conv_idx}"), &y, mid);
            y = b.relu(&y);
            conv_idx += 1;
            y = b.conv(&p(conv_idx), &y, mid, cout, 1, 1, 0, false);
            y = b.batchnorm(&format!("resnet-stage{stage}-bn{conv_idx}"), &y, cout);
            conv_idx += 1;
            // Projection shortcut only in the first block of the stage —
            // registered *after* the block's three convs (Table 3 order).
            let shortcut = if block == 0 {
                let sc = b.conv(&p(conv_idx), &identity, cin, cout, 1, block_stride, 0, false);
                let sc = b.batchnorm(&format!("resnet-stage{stage}-bn{conv_idx}"), &sc, cout);
                conv_idx += 1;
                sc
            } else {
                identity
            };
            t = b.add(&y, &shortcut);
            t = b.relu(&t);
            cin = cout;
        }
    }

    t = b.global_avg_pool(&t);
    t = b.flatten(&t);
    t = b.dense("resnet-dense0", &t, 2048, 1000, true);
    let out = b.softmax(&t);
    b.finish(Some(&out))
}

/// ResNet-18/34: basic blocks (two 3x3 convs), expansion 1.
fn build_basic(blocks: &[usize; 4], name: &str, opts: ZooOpts) -> Model {
    let mut b = GraphBuilder::new(name, opts);
    let x = b.input("data", &[3, 224, 224]);
    let mut t = b.conv(&format!("{name}-conv0"), &x, 3, 64, 7, 2, 3, false);
    t = b.batchnorm(&format!("{name}-bn0"), &t, 64);
    t = b.relu(&t);
    t = b.maxpool(&t, 3, 2, 1);

    let chans = [64i64, 128, 256, 512];
    let mut cin = 64i64;
    for (s, (&nblocks, &c)) in blocks.iter().zip(chans.iter()).enumerate() {
        let stage = s + 1;
        let stride = if stage == 1 { 1 } else { 2 };
        let mut conv_idx = 0usize;
        for block in 0..nblocks {
            let block_stride = if block == 0 { stride } else { 1 };
            let identity = t.clone();
            let p = |k: usize| format!("{name}-stage{stage}-conv{k}");
            let mut y = b.conv(&p(conv_idx), &t, cin, c, 3, block_stride, 1, false);
            y = b.batchnorm(&format!("{name}-stage{stage}-bn{conv_idx}"), &y, c);
            y = b.relu(&y);
            conv_idx += 1;
            y = b.conv(&p(conv_idx), &y, c, c, 3, 1, 1, false);
            y = b.batchnorm(&format!("{name}-stage{stage}-bn{conv_idx}"), &y, c);
            conv_idx += 1;
            let shortcut = if block == 0 && (block_stride != 1 || cin != c) {
                let sc = b.conv(&p(conv_idx), &identity, cin, c, 1, block_stride, 0, false);
                let sc = b.batchnorm(&format!("{name}-stage{stage}-bn{conv_idx}"), &sc, c);
                conv_idx += 1;
                sc
            } else {
                identity
            };
            t = b.add(&y, &shortcut);
            t = b.relu(&t);
            cin = c;
        }
    }

    t = b.global_avg_pool(&t);
    t = b.flatten(&t);
    t = b.dense(&format!("{name}-dense0"), &t, 512, 1000, true);
    let out = b.softmax(&t);
    b.finish(Some(&out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::infer_shapes;
    use crate::zoo::builder::WeightFill;

    /// Paper Table 3, "Extracted Model" column: layer → size in BYTES.
    /// (Stem + all stage convs + dense0; conv weights only.)
    pub const TABLE3_BYTES: [(&str, u64); 54] = [
        ("resnet-conv0", 37632),
        ("resnet-stage1-conv0", 16384),
        ("resnet-stage1-conv1", 147456),
        ("resnet-stage1-conv2", 65536),
        ("resnet-stage1-conv3", 65536),
        ("resnet-stage1-conv4", 65536),
        ("resnet-stage1-conv5", 147456),
        ("resnet-stage1-conv6", 65536),
        ("resnet-stage1-conv7", 65536),
        ("resnet-stage1-conv8", 147456),
        ("resnet-stage1-conv9", 65536),
        ("resnet-stage2-conv0", 131072),
        ("resnet-stage2-conv1", 589824),
        ("resnet-stage2-conv2", 262144),
        ("resnet-stage2-conv3", 524288),
        ("resnet-stage2-conv4", 262144),
        ("resnet-stage2-conv5", 589824),
        ("resnet-stage2-conv6", 262144),
        ("resnet-stage2-conv7", 262144),
        ("resnet-stage2-conv8", 589824),
        ("resnet-stage2-conv9", 262144),
        ("resnet-stage2-conv10", 262144),
        ("resnet-stage2-conv11", 589824),
        ("resnet-stage2-conv12", 262144),
        ("resnet-stage3-conv0", 524288),
        ("resnet-stage3-conv1", 2359296),
        ("resnet-stage3-conv2", 1048576),
        ("resnet-stage3-conv3", 2097152),
        ("resnet-stage3-conv4", 1048576),
        ("resnet-stage3-conv5", 2359296),
        ("resnet-stage3-conv6", 1048576),
        ("resnet-stage3-conv7", 1048576),
        ("resnet-stage3-conv8", 2359296),
        ("resnet-stage3-conv9", 1048576),
        ("resnet-stage3-conv10", 1048576),
        ("resnet-stage3-conv11", 2359296),
        ("resnet-stage3-conv12", 1048576),
        ("resnet-stage3-conv13", 1048576),
        ("resnet-stage3-conv14", 2359296),
        ("resnet-stage3-conv15", 1048576),
        ("resnet-stage3-conv16", 1048576),
        ("resnet-stage3-conv17", 2359296),
        ("resnet-stage3-conv18", 1048576),
        ("resnet-stage4-conv0", 2097152),
        ("resnet-stage4-conv1", 9437184),
        ("resnet-stage4-conv2", 4194304),
        ("resnet-stage4-conv3", 8388608),
        ("resnet-stage4-conv4", 4194304),
        ("resnet-stage4-conv5", 9437184),
        ("resnet-stage4-conv6", 4194304),
        ("resnet-stage4-conv7", 4194304),
        ("resnet-stage4-conv8", 9437184),
        ("resnet-stage4-conv9", 4194304),
        ("resnet-dense0", 8192000),
    ];

    #[test]
    fn resnet50_matches_paper_table3() {
        let m = build(50, ZooOpts { weights: WeightFill::Empty });
        let extracted: Vec<(String, u64)> = m
            .graph
            .initializers
            .iter()
            .filter(|t| {
                t.name.ends_with("-weight")
                    && (t.name.contains("conv") || t.name.contains("dense"))
            })
            .map(|t| (t.name.trim_end_matches("-weight").to_string(), t.size_bytes()))
            .collect();
        assert_eq!(extracted.len(), TABLE3_BYTES.len());
        for ((name, bytes), (exp_name, exp_bytes)) in extracted.iter().zip(TABLE3_BYTES.iter()) {
            assert_eq!(name, exp_name);
            assert_eq!(bytes, exp_bytes, "size mismatch at {name}");
        }
    }

    #[test]
    fn resnet50_total_params() {
        let m = build(50, ZooOpts { weights: WeightFill::Empty });
        // torchvision resnet50: 25,557,032 params (incl. BN affine); ours
        // additionally carries BN running mean/var (53,120 extra stats).
        assert_eq!(m.num_parameters(), 25_610_152);
    }

    #[test]
    fn resnet50_shapes_infer() {
        let m = build(50, ZooOpts { weights: WeightFill::Empty });
        let shapes = infer_shapes(&m.graph, 2).unwrap();
        assert_eq!(shapes[&m.graph.outputs[0].name].1, vec![2, 1000]);
    }

    #[test]
    fn resnet18_34_build_and_infer() {
        for d in [18usize, 34] {
            let m = build(d, ZooOpts { weights: WeightFill::Empty });
            let shapes = infer_shapes(&m.graph, 1).unwrap();
            assert_eq!(shapes[&m.graph.outputs[0].name].1, vec![1, 1000], "resnet{d}");
        }
    }
}
