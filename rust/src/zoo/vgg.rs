//! VGG-11/13/16/19 builders (Simonyan & Zisserman, 2014).
//!
//! Layer naming follows the paper's Tables 1–2: `vgg16-convN-weight`,
//! `vgg16-denseN-weight`. Conv layers carry biases (like the ONNX model
//! zoo exports); the paper's tables list only the `-weight` tensors, which
//! is what the table renderers filter on.

use super::builder::{GraphBuilder, ZooOpts};
use crate::onnx::Model;

/// The per-stage conv channel plan: entry = output channels; `M` = maxpool.
/// Standard VGG configurations A/B/D/E.
fn plan(depth: usize) -> &'static [i64] {
    // 0 encodes a maxpool.
    match depth {
        11 => &[64, 0, 128, 0, 256, 256, 0, 512, 512, 0, 512, 512, 0],
        13 => &[64, 64, 0, 128, 128, 0, 256, 256, 0, 512, 512, 0, 512, 512, 0],
        16 => &[
            64, 64, 0, 128, 128, 0, 256, 256, 256, 0, 512, 512, 512, 0, 512, 512, 512, 0,
        ],
        19 => &[
            64, 64, 0, 128, 128, 0, 256, 256, 256, 256, 0, 512, 512, 512, 512, 0, 512, 512,
            512, 512, 0,
        ],
        // lint: allow(no-panic) — closed depth table; zoo::get validates the name first
        _ => panic!("unsupported VGG depth {depth}"),
    }
}

/// Build a VGG model of the given depth (11/13/16/19).
pub fn build(depth: usize, opts: ZooOpts) -> Model {
    let name = format!("vgg{depth}");
    let mut b = GraphBuilder::new(&name, opts);
    let mut x = b.input("data", &[3, 224, 224]);
    let mut cin = 3i64;
    let mut conv_idx = 0usize;
    for &c in plan(depth) {
        if c == 0 {
            x = b.maxpool(&x, 2, 2, 0);
        } else {
            let prefix = format!("{name}-conv{conv_idx}");
            x = b.conv(&prefix, &x, cin, c, 3, 1, 1, true);
            x = b.relu(&x);
            cin = c;
            conv_idx += 1;
        }
    }
    // Classifier: 7x7x512 = 25088 → 4096 → 4096 → 1000.
    x = b.flatten(&x);
    x = b.dense(&format!("{name}-dense0"), &x, 25088, 4096, true);
    x = b.relu(&x);
    x = b.dense(&format!("{name}-dense1"), &x, 4096, 4096, true);
    x = b.relu(&x);
    x = b.dense(&format!("{name}-dense2"), &x, 4096, 1000, true);
    let out = b.softmax(&x);
    b.finish(Some(&out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx::infer_shapes;

    /// Paper Table 1: exact Variables column for VGG16 weights.
    const VGG16_WEIGHTS: [(&str, u64); 16] = [
        ("vgg16-conv0-weight", 1728),
        ("vgg16-conv1-weight", 36864),
        ("vgg16-conv2-weight", 73728),
        ("vgg16-conv3-weight", 147456),
        ("vgg16-conv4-weight", 294912),
        ("vgg16-conv5-weight", 589824),
        ("vgg16-conv6-weight", 589824),
        ("vgg16-conv7-weight", 1179648),
        ("vgg16-conv8-weight", 2359296),
        ("vgg16-conv9-weight", 2359296),
        ("vgg16-conv10-weight", 2359296),
        ("vgg16-conv11-weight", 2359296),
        ("vgg16-conv12-weight", 2359296),
        ("vgg16-dense0-weight", 102760448),
        ("vgg16-dense1-weight", 16777216),
        ("vgg16-dense2-weight", 4096000),
    ];

    #[test]
    fn vgg16_matches_paper_table1() {
        let m = build(16, ZooOpts { weights: super::super::builder::WeightFill::Empty });
        let weights: Vec<(&str, u64)> = m
            .graph
            .initializers
            .iter()
            .filter(|t| t.name.ends_with("-weight"))
            .map(|t| (t.name.as_str(), t.num_elements()))
            .collect();
        assert_eq!(weights.len(), 16);
        for (i, (name, vars)) in VGG16_WEIGHTS.iter().enumerate() {
            assert_eq!(weights[i].0, *name);
            assert_eq!(weights[i].1, *vars, "mismatch at {name}");
            // Model Size column = 4 × Variables (FLOAT).
        }
        // Total = the well-known VGG16 parameter count (weights + biases).
        assert_eq!(m.num_parameters(), 138_357_544);
    }

    #[test]
    fn vgg19_matches_paper_table2() {
        let m = build(19, ZooOpts { weights: super::super::builder::WeightFill::Empty });
        let expected: [u64; 19] = [
            1728, 36864, 73728, 147456, 294912, 589824, 589824, 589824, 1179648, 2359296,
            2359296, 2359296, 2359296, 2359296, 2359296, 2359296, // conv0..conv15
            102760448, 16777216, 4096000, // dense0..2
        ];
        let weights: Vec<u64> = m
            .graph
            .initializers
            .iter()
            .filter(|t| t.name.ends_with("-weight"))
            .map(|t| t.num_elements())
            .collect();
        assert_eq!(weights, expected);
        assert_eq!(m.num_parameters(), 143_667_240);
    }

    #[test]
    fn vgg16_shapes_infer_end_to_end() {
        let m = build(16, ZooOpts { weights: super::super::builder::WeightFill::Empty });
        let shapes = infer_shapes(&m.graph, 4).unwrap();
        let out = &m.graph.outputs[0].name;
        assert_eq!(shapes[out].1, vec![4, 1000]);
    }

    #[test]
    fn vgg11_and_13_build() {
        for d in [11, 13] {
            let m = build(d, ZooOpts { weights: super::super::builder::WeightFill::Empty });
            assert!(infer_shapes(&m.graph, 1).is_ok(), "vgg{d}");
        }
    }
}
